//! Quickstart: characterize one IMC operating point three ways.
//!
//!   cargo run --release --example quickstart
//!
//! Builds a QS-Arch instance from Table II physics, evaluates the
//! closed-form Table III models, runs the native sample-accurate
//! Monte-Carlo simulator, and (if `make artifacts` has run) the AOT
//! JAX/Pallas simulator through PJRT — and shows all three agree.

use imclim::arch::{AdcCriterion, ImcArch, OpPoint, QsArch};
use imclim::compute::qs::QsModel;
use imclim::coordinator::{run_point, Backend, PjrtService, SweepPoint};
use imclim::mc::ArchKind;
use imclim::quant::SignalStats;
use imclim::tech::TechNode;
use imclim::util::table::{fmt_db, fmt_energy, Table};

fn main() -> anyhow::Result<()> {
    // 1. A 512-row 65 nm SRAM array read at V_WL = 0.8 V (Table II).
    let arch = QsArch::new(QsModel::new(TechNode::n65(), 0.8));
    let op = OpPoint::new(128, 6, 6, 8); // N=128, Bx=Bw=6, 8-b column ADC
    let w = SignalStats::uniform_signed(1.0);
    let x = SignalStats::uniform_unsigned(1.0);

    // 2. Closed forms (Table III).
    let nb = arch.noise(&op, &w, &x);
    let e = arch.energy(&op, AdcCriterion::Mpc, &w, &x);
    println!("closed form: SNR_a = {}, SNR_A = {}, B_ADC(min,MPC) = {}, E/DP = {}, delay = {:.1} ns",
        fmt_db(nb.snr_a_db()),
        fmt_db(nb.snr_a_total_db()),
        arch.b_adc_min(&op, &w, &x),
        fmt_energy(e.total()),
        arch.delay(&op) * 1e9,
    );

    // 3. Native sample-accurate Monte-Carlo (eq. 17 physics).
    let point = SweepPoint::new("quickstart", ArchKind::Qs, arch.pjrt_params(&op, &w, &x))
        .with_trials(4096)
        .with_seed(1);
    let native = run_point(&point, &Backend::Native)?;

    // 4. The same trial stream through the AOT JAX/Pallas artifact.
    let artifacts = imclim::runtime::default_artifacts_dir();
    let pjrt = if artifacts.join("manifest.json").exists() {
        let service = PjrtService::spawn(artifacts, 4);
        Some(run_point(
            &point,
            &Backend::Pjrt {
                handle: service.handle(),
                suffix: "",
            },
        )?)
    } else {
        eprintln!("(artifacts not built; run `make artifacts` to exercise PJRT)");
        None
    };

    let mut t = Table::new(&["metric", "closed form", "native MC", "pallas/PJRT"])
        .with_title("QS-Arch @ N=128, Bx=Bw=6, B_ADC=8, V_WL=0.8V");
    let pj = |f: fn(&imclim::mc::MeasuredSnr) -> f64| {
        pjrt.as_ref().map(|m| fmt_db(f(m))).unwrap_or_else(|| "-".into())
    };
    t.row(vec![
        "SQNR_qiy (dB)".into(),
        fmt_db(nb.sqnr_qiy_db()),
        fmt_db(native.sqnr_qiy_db),
        pj(|m| m.sqnr_qiy_db),
    ]);
    t.row(vec![
        "SNR_A (dB)".into(),
        fmt_db(nb.snr_a_total_db()),
        fmt_db(native.snr_a_total_db),
        pj(|m| m.snr_a_total_db),
    ]);
    t.row(vec![
        "SNR_T (dB)".into(),
        "-".into(),
        fmt_db(native.snr_t_db),
        pj(|m| m.snr_t_db),
    ]);
    println!("{}", t.render());

    if let Some(p) = &pjrt {
        let gap = (p.snr_a_total_db - native.snr_a_total_db).abs();
        println!("native vs pallas SNR_A gap: {gap:.2} dB (MC ensemble error)");
    }
    Ok(())
}
