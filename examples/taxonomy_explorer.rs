//! Taxonomy explorer: place every published design of Table I on the
//! paper's analytical landscape — compute the SNR_T its precision
//! choices (B_x, B_w, B_ADC) can support and whether its ADC precision
//! is MPC-efficient or BGC-conservative.
//!
//!   cargo run --release --example taxonomy_explorer

use imclim::quant::criteria::{bgc_bits, mpc_sqnr_db};
use imclim::quant::{sqnr_qiy_db, SignalStats};
use imclim::snr::snr_t_db;
use imclim::taxonomy::{table1, AdcPrecision, WeightPrecision};
use imclim::util::table::Table;

fn bits_of(w: &WeightPrecision) -> u32 {
    match w {
        WeightPrecision::Bits(b) => *b,
        WeightPrecision::Ternary => 2,
        WeightPrecision::Analog => 8,
    }
}

fn main() {
    let n = 128usize; // a representative DP dimension
    let ws = SignalStats::uniform_signed(1.0);
    let xs = SignalStats::uniform_unsigned(1.0);
    let mut t = Table::new(&[
        "design",
        "models",
        "SQNR_qiy dB",
        "B_ADC",
        "B_y(BGC)",
        "SQNR_qy dB",
        "SNR_T cap dB",
        "ADC style",
    ])
    .with_title(&format!("Table I designs on the analytical landscape (N = {n})"));

    let mut binarized = 0usize;
    for d in table1() {
        let bx = bits_of(&d.bx);
        let bw = bits_of(&d.bw);
        if bx <= 2 && bw <= 2 {
            binarized += 1;
        }
        let b_adc = match d.b_adc {
            AdcPrecision::Bits(b) => b,
            AdcPrecision::Analog => 8,
            AdcPrecision::Effective10x(b10) => (b10 as f64 / 10.0).round() as u32,
        };
        let qiy = sqnr_qiy_db(n, bw, bx, &ws, &xs);
        let qy = mpc_sqnr_db(b_adc, 4.0);
        let cap = snr_t_db(qiy, qy);
        let bgc = bgc_bits(bx, bw, n);
        let style = if b_adc >= bgc {
            "BGC"
        } else if b_adc as f64 >= (cap + 16.3) / 6.0 {
            "MPC-ish"
        } else {
            "sub-MPC"
        };
        let mut models = String::new();
        if d.qs {
            models.push_str("QS ");
        }
        if d.is {
            models.push_str("IS ");
        }
        if d.qr {
            models.push_str("QR");
        }
        t.row(vec![
            d.name.into(),
            models.trim().into(),
            format!("{qiy:.1}"),
            b_adc.to_string(),
            bgc.to_string(),
            format!("{qy:.1}"),
            format!("{cap:.1}"),
            style.into(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "{binarized}/23 designs binarize (B <= 2) — the paper's Sec. IV-B2 point that \
limited SNR_a forces binarization; none assign B_ADC by BGC (it would need {}+ bits).",
        bgc_bits(1, 1, n)
    );
}
