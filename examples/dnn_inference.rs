//! End-to-end IMC inference demo (the Fig. 2 workload, full pipeline).
//!
//!   cargo run --release --example dnn_inference
//!
//! 1. Generates a synthetic 10-class dataset and trains a 64-128-64-10
//!    MLP from scratch (logging the loss curve — EXPERIMENTS.md records
//!    a run).
//! 2. Derives each layer's SNR_T when its DPs execute on a QS-Arch IMC
//!    (closed-form Table III at the layer's fan-in), and evaluates the
//!    resulting inference accuracy by per-layer noise injection.
//! 3. If artifacts are built, runs the noisy batched forward through the
//!    AOT `mlp_fwd` executable on PJRT — Python never runs.

use imclim::arch::{ImcArch, OpPoint, QsArch};
use imclim::compute::qs::QsModel;
use imclim::coordinator::{MlpRequest, MlpWeights, PjrtService};
use imclim::dnn::*;
use imclim::quant::SignalStats;
use imclim::tech::TechNode;

fn main() -> anyhow::Result<()> {
    // 1. Train.
    let ds = Dataset::generate(&DatasetConfig::default());
    let mut mlp = Mlp::new(&[64, 128, 64, 10], 7);
    println!(
        "training {} params on {} samples...",
        mlp.n_params(),
        ds.train_len()
    );
    let curve = mlp.train(&ds, &TrainConfig::default());
    for (e, (loss, acc)) in curve.iter().enumerate() {
        if e % 5 == 0 || e + 1 == curve.len() {
            println!("  epoch {e:>3}: loss {loss:.4}  test-acc {acc:.3}");
        }
    }
    let clean = mlp.accuracy(&ds, true);
    println!("clean FL accuracy: {clean:.3}");

    // 2. Deploy each layer on QS-Arch: per-layer SNR_T from the closed
    //    forms at the layer's DP dimension (fan-in).
    let w_stats = SignalStats::uniform_signed(1.0);
    let x_stats = SignalStats::uniform_unsigned(1.0);
    for v_wl in [0.8, 0.7, 0.6] {
        let arch = QsArch::new(QsModel::new(TechNode::n65(), v_wl));
        let snrs: Vec<f64> = mlp
            .dims
            .windows(2)
            .map(|win| {
                let op = OpPoint::new(win[0], 6, 6, 8);
                let nb = arch.noise(&op, &w_stats, &x_stats);
                let b = arch.b_adc_min(&op, &w_stats, &x_stats);
                let sqnr_qy = imclim::quant::criteria::mpc_sqnr_db(b, 4.0);
                imclim::snr::snr_t_db(nb.snr_a_total_db(), sqnr_qy)
            })
            .collect();
        let acc = noisy_accuracy(&mlp, &ds, &snrs, &NoisyEvalConfig::default());
        println!(
            "QS-Arch V_WL={v_wl}: per-layer SNR_T = {:?} dB -> accuracy {acc:.3} (drop {:.1}%)",
            snrs.iter().map(|s| (s * 10.0).round() / 10.0).collect::<Vec<_>>(),
            (clean - acc) * 100.0
        );
    }

    // 3. The same batched noisy forward through the AOT PJRT executable.
    let artifacts = imclim::runtime::default_artifacts_dir();
    if artifacts.join("manifest.json").exists() {
        let service = PjrtService::spawn(artifacts, 2);
        let handle = service.handle();
        let weights = MlpWeights {
            w1: mlp.w[0].clone(),
            b1: mlp.b[0].clone(),
            w2: mlp.w[1].clone(),
            b2: mlp.b[1].clone(),
            w3: mlp.w[2].clone(),
            b3: mlp.b[2].clone(),
        };
        let stds = layer_signal_stds(&mlp, &ds, 256);
        let snr_db = 20.0; // a mid-band operating point
        let sigmas: [f32; 3] = core::array::from_fn(|l| {
            (stds[l] / 10f64.powf(snr_db / 20.0)) as f32
        });
        let batch = 256;
        let mut correct = 0usize;
        let mut total = 0usize;
        let t0 = std::time::Instant::now();
        for start in (0..ds.test_len()).step_by(batch) {
            let mut x = vec![0f32; batch * 64];
            let count = batch.min(ds.test_len() - start);
            for i in 0..count {
                let (xs, _) = ds.test_sample(start + i);
                x[i * 64..(i + 1) * 64].copy_from_slice(xs);
            }
            let logits = handle.run_mlp(MlpRequest {
                x,
                weights: weights.clone(),
                seed: [start as f32, 17.0],
                sigmas,
            })?;
            for i in 0..count {
                let row = &logits[i * 10..(i + 1) * 10];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == ds.test_sample(start + i).1 as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        let dt = t0.elapsed();
        println!(
            "PJRT mlp_fwd @ SNR_T = {snr_db} dB/layer: accuracy {:.3} over {total} samples in {dt:?} ({:.0} inf/s)",
            correct as f64 / total as f64,
            total as f64 / dt.as_secs_f64()
        );
    } else {
        println!("(run `make artifacts` to exercise the PJRT forward)");
    }
    Ok(())
}
