//! Design-space exploration: given an application SNR_T requirement
//! (Fig. 2 band), find the minimum-energy IMC configuration across all
//! three architectures, knobs and technology nodes — the workflow the
//! paper's conclusions prescribe for IMC designers.
//!
//!   cargo run --release --example design_space [-- --snr-t 25]

use imclim::arch::{AdcCriterion, CmArch, ImcArch, OpPoint, QrArch, QsArch};
use imclim::cli::args::Args;
use imclim::compute::{qr::QrModel, qs::QsModel};
use imclim::quant::SignalStats;
use imclim::tech::TechNode;
use imclim::util::table::{fmt_energy, Table};

struct Candidate {
    arch: String,
    node: u32,
    knob: String,
    snr_t_db: f64,
    b_adc: u32,
    energy: f64,
    delay: f64,
}

fn main() {
    let args = Args::from_env();
    let target_db = args.opt_parse("snr-t", 25.0f64);
    let n = args.opt_parse("n", 128usize);
    let w = SignalStats::uniform_signed(1.0);
    let x = SignalStats::uniform_unsigned(1.0);

    // precision assignment per Sec. III-B for the target
    let assign = imclim::snr::assign_precisions(target_db + 1.0, 9.0, &w, &x);
    println!(
        "target SNR_T >= {target_db} dB -> Bx = {}, Bw = {} (input quantization 9 dB below)",
        assign.bx, assign.bw
    );

    let mut candidates: Vec<Candidate> = Vec::new();
    for node in TechNode::all() {
        // QS-Arch over V_WL
        for i in 0..12 {
            let v_wl = node.v_t + 0.12 + (node.v_dd - node.v_t - 0.12) * i as f64 / 11.0;
            let arch = QsArch::new(QsModel::new(node, v_wl));
            push_if_meets(
                &mut candidates,
                &arch,
                "QS-Arch",
                node.node_nm,
                format!("V_WL={v_wl:.2}"),
                n,
                assign.bx,
                assign.bw,
                target_db,
                &w,
                &x,
            );
        }
        // QR-Arch over C_o
        for c_ff in [0.5, 1.0, 2.0, 3.0, 4.5, 6.0, 9.0, 12.0, 16.0] {
            let arch = QrArch::new(QrModel::new(node, c_ff));
            push_if_meets(
                &mut candidates,
                &arch,
                "QR-Arch",
                node.node_nm,
                format!("C_o={c_ff}fF"),
                n,
                assign.bx,
                assign.bw,
                target_db,
                &w,
                &x,
            );
        }
        // CM over V_WL
        for i in 0..8 {
            let v_wl = node.v_t + 0.15 + (node.v_dd - node.v_t - 0.15) * i as f64 / 7.0;
            let arch = CmArch::new(QsModel::new(node, v_wl), QrModel::new(node, 3.0));
            push_if_meets(
                &mut candidates,
                &arch,
                "CM",
                node.node_nm,
                format!("V_WL={v_wl:.2}"),
                n,
                assign.bx,
                assign.bw,
                target_db,
                &w,
                &x,
            );
        }
    }

    candidates.sort_by(|a, b| a.energy.partial_cmp(&b.energy).unwrap());
    let mut t = Table::new(&[
        "rank", "arch", "node", "knob", "SNR_T dB", "B_ADC", "E/DP", "delay ns", "EDP fJ*ns",
    ])
    .with_title(&format!(
        "Minimum-energy designs meeting SNR_T >= {target_db} dB at N = {n} ({} candidates)",
        candidates.len()
    ));
    for (i, c) in candidates.iter().take(12).enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            c.arch.clone(),
            format!("{}nm", c.node),
            c.knob.clone(),
            format!("{:.1}", c.snr_t_db),
            c.b_adc.to_string(),
            fmt_energy(c.energy),
            format!("{:.2}", c.delay * 1e9),
            format!("{:.0}", c.energy * 1e15 * c.delay * 1e9),
        ]);
    }
    println!("{}", t.render());
    if candidates.is_empty() {
        println!("no architecture meets the target — the paper's point: SNR_T is capped by SNR_a.");
    }
}

#[allow(clippy::too_many_arguments)]
fn push_if_meets(
    out: &mut Vec<Candidate>,
    arch: &dyn ImcArch,
    name: &str,
    node: u32,
    knob: String,
    n: usize,
    bx: u32,
    bw: u32,
    target_db: f64,
    w: &SignalStats,
    x: &SignalStats,
) {
    let op0 = OpPoint::new(n, bx, bw, 8);
    let nb = arch.noise(&op0, w, x);
    let b_adc = arch.b_adc_min(&op0, w, x);
    let sqnr_qy = imclim::quant::criteria::mpc_sqnr_db(b_adc, 4.0);
    let snr_t = imclim::snr::snr_t_db(nb.snr_a_total_db(), sqnr_qy);
    if snr_t >= target_db {
        let op = OpPoint::new(n, bx, bw, b_adc);
        let e = arch.energy(&op, AdcCriterion::Mpc, w, x);
        out.push(Candidate {
            arch: name.into(),
            node,
            knob,
            snr_t_db: snr_t,
            b_adc,
            energy: e.total(),
            delay: arch.delay(&op),
        });
    }
}
