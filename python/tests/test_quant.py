"""Quantization / bit-slicing invariants (Sec. II of the paper)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import (
    quantize_unsigned,
    signed_bits,
    signed_mag_bits,
    unsigned_bits,
)


@settings(max_examples=30, deadline=None)
@given(bx=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_unsigned_bits_reconstruct(bx, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 1, (4, 32)).astype(np.float32)
    xb, pxw, xq = unsigned_bits(x, float(bx))
    expect = np.clip(np.floor(x * 2.0**bx + 0.5), 0, 2.0**bx - 1) / 2.0**bx
    np.testing.assert_allclose(np.asarray(xq), expect, atol=1e-7)
    bits = np.asarray(xb)
    assert set(np.unique(bits)).issubset({0.0, 1.0})
    assert np.all(bits[:, bx:, :] == 0.0)  # inactive planes masked


@settings(max_examples=30, deadline=None)
@given(bw=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_signed_bits_reconstruct(bw, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(-1, 1, (4, 32)).astype(np.float32)
    wb, pw, wq = signed_bits(w, float(bw))
    t = np.clip(np.floor((w + 1.0) * 2.0 ** (bw - 1) + 0.5), 0, 2.0**bw - 1)
    expect = t * 2.0 ** (1 - bw) - 1.0
    np.testing.assert_allclose(np.asarray(wq), expect, atol=1e-7)
    # round-to-nearest: |error| <= step/2 except at the clipped top code
    err = w - np.asarray(wq)
    assert np.all(np.abs(err) <= 2.0 ** (1 - bw) + 1e-7)


@settings(max_examples=30, deadline=None)
@given(bw=st.integers(2, 8), seed=st.integers(0, 2**31 - 1))
def test_signed_mag_bits_reconstruct(bw, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(-1, 1, (4, 32)).astype(np.float32)
    mb, pm, sgn, wq = signed_mag_bits(w, float(bw))
    wq = np.asarray(wq)
    # |error| < step, sign preserved, magnitude clipped below 1
    assert np.all(np.abs(wq) <= 1.0 - 2.0 ** (1 - bw) + 1e-7)
    assert np.all(np.abs(w - wq) <= 2.0 ** (1 - bw) + 1e-7)
    nz = np.abs(wq) > 0
    assert np.all(np.sign(wq[nz]) == np.sign(w[nz]))


@settings(max_examples=20, deadline=None)
@given(bx=st.integers(1, 10), seed=st.integers(0, 2**31 - 1))
def test_quantize_unsigned_step(bx, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 1, (256,)).astype(np.float32)
    xq = np.asarray(quantize_unsigned(x, float(bx)))
    err = x - xq
    # round-to-nearest: |err| <= step/2, except up to a step at the top code
    assert np.all(np.abs(err) <= 2.0**-bx + 1e-7)
    interior = x < 1.0 - 2.0**-bx
    assert np.all(np.abs(err[interior]) <= 2.0 ** -(bx + 1) + 1e-7)


def test_sqnr_six_db_per_bit():
    """Eq. (1): each extra bit buys ~6 dB of SQNR."""
    rng = np.random.default_rng(7)
    x = rng.uniform(0, 1, (200000,)).astype(np.float64)
    prev = None
    for bx in range(4, 9):
        xq = np.asarray(quantize_unsigned(x.astype(np.float32), float(bx)), np.float64)
        sqnr = 10 * np.log10(np.var(x) / np.mean((x - xq) ** 2))
        if prev is not None:
            assert 5.0 < sqnr - prev < 7.0
        prev = sqnr
