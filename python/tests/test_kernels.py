"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and dtypes; fixed cases pin the block/grid edge
cases (single tile, many tiles, non-default block_n).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pair_dot import pair_dot
from compile.kernels.mlp_layer import mlp_layer
from compile.kernels.ref import pair_dot_ref, mlp_layer_ref


def _rand(rng, shape, dtype):
    return rng.uniform(-2.0, 2.0, shape).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 8),
    p=st.integers(1, 9),
    q=st.integers(1, 9),
    p2=st.integers(1, 4),
    q2=st.integers(1, 4),
    nblk=st.integers(1, 4),
    dtype=st.sampled_from([np.float32, np.float16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pair_dot_matches_ref(m, p, q, p2, q2, nblk, dtype, seed):
    rng = np.random.default_rng(seed)
    n = 128 * nblk
    a = _rand(rng, (m, p, n), dtype)
    b = _rand(rng, (m, q, n), dtype)
    c = _rand(rng, (m, p2, n), dtype)
    d = _rand(rng, (m, q2, n), dtype)
    o1, o2 = pair_dot(a, b, c, d)
    r1, r2 = pair_dot_ref(a, b, c, d)
    tol = 1e-4 * n if dtype == np.float16 else 1e-5 * n
    np.testing.assert_allclose(np.asarray(o1), np.asarray(r1), atol=tol, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(r2), atol=tol, rtol=1e-4)


@pytest.mark.parametrize("block_n", [32, 64, 128, 256])
def test_pair_dot_block_sizes(block_n):
    rng = np.random.default_rng(0)
    m, p, q, n = 3, 8, 8, 256
    a = _rand(rng, (m, p, n), np.float32)
    b = _rand(rng, (m, q, n), np.float32)
    c = _rand(rng, (m, 1, n), np.float32)
    d = _rand(rng, (m, 1, n), np.float32)
    o1, o2 = pair_dot(a, b, c, d, block_n=block_n)
    r1, r2 = pair_dot_ref(a, b, c, d)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(r1), atol=1e-3)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(r2), atol=1e-3)


def test_pair_dot_non_divisible_falls_back_to_single_tile():
    rng = np.random.default_rng(1)
    m, n = 2, 96  # 96 % 128 != 0
    a = _rand(rng, (m, 8, n), np.float32)
    b = _rand(rng, (m, 8, n), np.float32)
    o1, o2 = pair_dot(a, b, a, b)
    r1, _ = pair_dot_ref(a, b, a, b)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(r1), atol=1e-3)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(r1), atol=1e-3)


def test_pair_dot_shape_mismatch_raises():
    a = np.zeros((2, 8, 128), np.float32)
    bad = np.zeros((3, 8, 128), np.float32)
    with pytest.raises(ValueError):
        pair_dot(a, bad, a, a)


def test_pair_dot_zeros():
    z = np.zeros((2, 4, 128), np.float32)
    o1, o2 = pair_dot(z, z, z, z)
    assert np.all(np.asarray(o1) == 0) and np.all(np.asarray(o2) == 0)


@settings(max_examples=20, deadline=None)
@given(
    mblk=st.integers(1, 4),
    d=st.sampled_from([32, 64, 128]),
    o=st.sampled_from([10, 64, 128]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlp_layer_matches_ref(mblk, d, o, relu, seed):
    rng = np.random.default_rng(seed)
    m = 64 * mblk
    x = _rand(rng, (m, d), np.float32)
    w = _rand(rng, (o, d), np.float32)
    b = _rand(rng, (o,), np.float32)
    nz = _rand(rng, (m, o), np.float32)
    y = mlp_layer(x, w, b, nz, relu=relu)
    r = mlp_layer_ref(x, w, b, nz, relu=relu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), atol=1e-4, rtol=1e-4)


def test_mlp_layer_relu_clamps():
    x = -np.ones((64, 32), np.float32)
    w = np.ones((64, 32), np.float32)
    b = np.zeros((64,), np.float32)
    nz = np.zeros((64, 64), np.float32)
    y = mlp_layer(x, w, b, nz, relu=True)
    assert np.all(np.asarray(y) == 0.0)
