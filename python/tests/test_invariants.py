"""Hypothesis sweeps over the parameter space: structural invariants that
must hold for ANY operating point of the L2 architecture models."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import params as pp
from compile.model import cm_arch, qr_arch, qs_arch

M, N = 16, 64  # small-variant shapes for speed


def run(model, p, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 1, (M, N)).astype(np.float32)
    w = rng.uniform(-1, 1, (M, N)).astype(np.float32)
    s = np.array([seed % 1000, 7], dtype=np.float32)
    return [np.asarray(v) for v in model(x, w, s, p)]


def base(n, bx, bw, b_adc):
    p = np.zeros(pp.P, np.float32)
    p[pp.IDX_N_ACTIVE] = n
    p[pp.IDX_BX] = bx
    p[pp.IDX_BW] = bw
    p[pp.IDX_B_ADC] = b_adc
    return p


arch_params = dict(
    n=st.integers(4, N),
    bx=st.integers(1, 8),
    bw=st.integers(2, 8),
    b_adc=st.integers(2, 14),
    seed=st.integers(0, 2**20),
)


@settings(max_examples=25, deadline=None)
@given(**arch_params, sigma_d=st.floats(0.0, 0.3), k_h=st.floats(4.0, 200.0))
def test_qs_outputs_finite_and_bounded(n, bx, bw, b_adc, seed, sigma_d, k_h):
    p = base(n, bx, bw, b_adc)
    p[pp.QS_IDX_SIGMA_D] = sigma_d
    p[pp.QS_IDX_K_H] = k_h
    p[pp.QS_IDX_V_C] = min(4 * np.sqrt(3 * n), k_h, n)
    yi, yfx, ya, yh = run(qs_arch, p, seed)
    for v in (yi, yfx, ya, yh):
        assert np.all(np.isfinite(v))
    # fixed-point DP bounded by N (|w|,|x| <= 1)
    assert np.all(np.abs(yfx) <= n + 1e-3)
    # ideal DP bounded by sum |x| <= n
    assert np.all(np.abs(yi) <= n + 1e-3)
    # ADC output on a clipped range can't exceed the recombined range
    assert np.all(np.abs(yh) <= 2 * n + 1e-3)


@settings(max_examples=25, deadline=None)
@given(**arch_params, sigma_c=st.floats(0.0, 0.15))
def test_qr_rows_within_rails(n, bx, bw, b_adc, seed, sigma_c):
    p = base(n, bx, bw, b_adc)
    p[pp.QR_IDX_SIGMA_C] = sigma_c
    p[pp.QR_IDX_V_C] = 1.0
    yi, yfx, ya, yh = run(qr_arch, p, seed)
    for v in (yi, yfx, ya, yh):
        assert np.all(np.isfinite(v))
    # charge redistribution cannot clip: noiseless case equals FX exactly
    if sigma_c == 0.0:
        np.testing.assert_allclose(ya, yfx, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(**arch_params, sigma_d=st.floats(0.0, 0.3), w_h=st.floats(0.1, 2.0))
def test_cm_clipping_monotone(n, bx, bw, b_adc, seed, sigma_d, w_h):
    p = base(n, bx, bw, b_adc)
    p[pp.CM_IDX_SIGMA_D] = sigma_d
    p[pp.CM_IDX_W_H] = w_h
    p[pp.CM_IDX_V_C] = 1.0
    yi, yfx, ya, yh = run(cm_arch, p, seed)
    for v in (yi, yfx, ya, yh):
        assert np.all(np.isfinite(v))
    # per-column |analog product| <= w_h: aggregated |y_a| <= n * w_h
    assert np.all(np.abs(ya) <= n * min(w_h, 1.0) + 1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**20), b_lo=st.integers(2, 5))
def test_more_adc_bits_never_hurt(seed, b_lo):
    """SNR_T is non-decreasing in B_ADC (statistically, same noise draw)."""
    errs = []
    for b in (b_lo, b_lo + 4):
        p = base(48, 6, 6, b)
        p[pp.QS_IDX_SIGMA_D] = 0.1
        p[pp.QS_IDX_K_H] = 44.0
        p[pp.QS_IDX_V_C] = 44.0
        yi, yfx, ya, yh = run(qs_arch, p, seed)
        errs.append(np.var(yh - ya))
    assert errs[1] <= errs[0] * 1.05  # quantization error shrinks
