"""AOT lowering sanity: every artifact lowers to parseable HLO text with
the expected entry signature, and the manifest is coherent."""

import json

import jax
import pytest

from compile import aot
from compile import params as pp


@pytest.fixture(scope="module")
def lowered_smoke():
    fn, args, inputs, outputs = aot.entries()["smoke"]
    return aot.to_hlo_text(jax.jit(fn).lower(*args))


def test_entries_cover_all_architectures():
    names = set(aot.entries())
    assert {"qs_arch", "qr_arch", "cm_arch", "mlp_fwd", "smoke"} <= names
    assert {"qs_arch_small", "qr_arch_small", "cm_arch_small"} <= names


def test_smoke_hlo_text_structure(lowered_smoke):
    text = lowered_smoke
    assert "ENTRY" in text and "f32[2,2]" in text
    # return_tuple=True: the root is a tuple (rust unwraps with to_tuple)
    assert "(f32[2,2]" in text


@pytest.mark.parametrize("name", ["qs_arch_small", "qr_arch_small", "cm_arch_small"])
def test_arch_models_lower(name):
    fn, args, inputs, outputs = aot.entries()[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "ENTRY" in text
    assert len(outputs) == 4
    # 4 inputs: x, w, seed, params
    assert [i["name"] for i in inputs] == ["x", "w", "seed", "params"]
    assert inputs[3]["shape"] == [pp.P]


def test_mlp_entry_shapes():
    fn, args, inputs, outputs = aot.entries()["mlp_fwd"]
    d0, d1, d2, d3 = pp.MLP_DIMS
    assert inputs[0]["shape"] == [pp.MLP_BATCH, d0]
    assert inputs[1]["shape"] == [d1, d0]
    assert inputs[5]["shape"] == [d3, d2]
    assert outputs == ["logits"]


def test_manifest_roundtrip(tmp_path):
    import subprocess, sys, os
    out = tmp_path / "arts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--only", "smoke"],
        check=True, cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    man = json.loads((out / "manifest.json").read_text())
    assert man["p"] == pp.P and man["m_trials"] == pp.M_TRIALS
    assert "smoke" in man["artifacts"]
    assert (out / "smoke.hlo.txt").exists()
