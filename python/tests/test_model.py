"""L2 architecture-model physics tests.

Validates the sample-accurate simulators against the paper's closed-form
expressions (Table III) at a grid of operating points, plus structural
invariants (noiseless equivalence, clipping monotonicity, ADC behaviour).
"""

import numpy as np
import pytest

from compile import params as pp
from compile.model import cm_arch, qr_arch, qs_arch

M = pp.M_TRIALS


def run_ensemble(model, p, trials=16, n=pp.N_MAX, seed0=0):
    rng = np.random.default_rng(42 + seed0)
    correlated = bool(p[pp.QS_IDX_MODE] >= 0.5) and model is qs_arch
    yi, yfx, ya, yh = [], [], [], []
    for t in range(trials):
        x = rng.uniform(0, 1, (M, n)).astype(np.float32)
        w = rng.uniform(-1, 1, (M, n)).astype(np.float32)
        seed = np.array([seed0 + t, 99], dtype=np.float32)
        o = model(x, w, seed, p, correlated=correlated) if correlated else model(x, w, seed, p)
        for acc, v in zip((yi, yfx, ya, yh), o):
            acc.append(np.asarray(v))
    return tuple(np.concatenate(v) for v in (yi, yfx, ya, yh))


def snr_db(sig, noise):
    return 10 * np.log10(np.var(sig) / np.var(noise))


def qs_params(n=100, bx=6, bw=6, b_adc=14, sigma_d=0.0, sigma_t=0.0,
              t_rf=0.0, sigma_theta=0.0, k_h=1e9, v_c=300.0, mode=0.0):
    p = np.zeros(pp.P, np.float32)
    p[pp.IDX_N_ACTIVE] = n
    p[pp.IDX_BX] = bx
    p[pp.IDX_BW] = bw
    p[pp.IDX_B_ADC] = b_adc
    p[pp.QS_IDX_SIGMA_D] = sigma_d
    p[pp.QS_IDX_SIGMA_T] = sigma_t
    p[pp.QS_IDX_T_RF] = t_rf
    p[pp.QS_IDX_SIGMA_THETA] = sigma_theta
    p[pp.QS_IDX_K_H] = k_h
    p[pp.QS_IDX_V_C] = v_c
    p[pp.QS_IDX_MODE] = mode
    return p


def qr_params(n=128, bx=6, bw=7, b_adc=14, sigma_c=0.0, inj_a=0.0,
              inj_b=0.0, sigma_theta=0.0, v_c=1.0, v_lo=0.0):
    p = np.zeros(pp.P, np.float32)
    p[pp.IDX_N_ACTIVE] = n
    p[pp.IDX_BX] = bx
    p[pp.IDX_BW] = bw
    p[pp.IDX_B_ADC] = b_adc
    p[pp.QR_IDX_SIGMA_C] = sigma_c
    p[pp.QR_IDX_INJ_A] = inj_a
    p[pp.QR_IDX_INJ_B] = inj_b
    p[pp.QR_IDX_SIGMA_THETA] = sigma_theta
    p[pp.QR_IDX_V_C] = v_c
    p[pp.QR_IDX_V_LO] = v_lo
    return p


def cm_params(n=64, bx=6, bw=6, b_adc=14, sigma_d=0.0, w_h=1e9,
              sigma_c=0.0, inj_a=0.0, inj_b=0.0, sigma_theta=0.0, v_c=1.0):
    p = np.zeros(pp.P, np.float32)
    p[pp.IDX_N_ACTIVE] = n
    p[pp.IDX_BX] = bx
    p[pp.IDX_BW] = bw
    p[pp.IDX_B_ADC] = b_adc
    p[pp.CM_IDX_SIGMA_D] = sigma_d
    p[pp.CM_IDX_W_H] = w_h
    p[pp.CM_IDX_SIGMA_C] = sigma_c
    p[pp.CM_IDX_INJ_A] = inj_a
    p[pp.CM_IDX_INJ_B] = inj_b
    p[pp.CM_IDX_SIGMA_THETA] = sigma_theta
    p[pp.CM_IDX_V_C] = v_c
    return p


# --------------------------------------------------------------------------
# Noiseless structural equivalence: analog path == fixed-point arithmetic.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("model,params", [
    (qs_arch, qs_params()),
    (qr_arch, qr_params()),
    (cm_arch, cm_params()),
])
def test_noiseless_analog_equals_fixed_point(model, params):
    yi, yfx, ya, yh = run_ensemble(model, params, trials=2)
    np.testing.assert_allclose(ya, yfx, atol=2e-3)
    # 14-b ADC with wide range: digitization error tiny vs signal
    assert snr_db(yi, yh - ya + 1e-12) > 35.0


@pytest.mark.parametrize("bx,bw", [(4, 4), (6, 6), (7, 7), (8, 8)])
def test_sqnr_qiy_matches_eq8(bx, bw):
    """Input-quantization SQNR vs eq. (8) for uniform x, w."""
    p = qs_params(n=256, bx=bx, bw=bw)
    yi, yfx, _, _ = run_ensemble(qs_arch, p, trials=8, seed0=100)
    meas = snr_db(yi, yfx - yi)
    # eq. (8) with zeta_x = x_m^2/(4 E[x^2]) = 3/4, zeta_w = w_m^2/sigma_w^2 = 3
    sqnr = 6 * (bx + bw) + 4.8 - (10 * np.log10(0.75) + 10 * np.log10(3.0)) \
        - 10 * np.log10(4.0**bx / 0.75 + 4.0**bw / 3.0)
    assert abs(meas - sqnr) < 1.5, (meas, sqnr)


# --------------------------------------------------------------------------
# QS-Arch: electrical noise, clipping, correlation modes (Table III col 1).
# --------------------------------------------------------------------------

def test_qs_electrical_noise_matches_table3():
    n, sd = 100, 0.107
    p = qs_params(n=n, sigma_d=sd)
    yi, yfx, ya, _ = run_ensemble(qs_arch, p, seed0=200)
    see = n * sd * sd * (1 - 4.0**-6) ** 2 / 9  # Table III sigma_eta_e^2
    meas = np.var(ya - yfx)
    assert abs(10 * np.log10(meas / see)) < 1.0, (meas, see)


def test_qs_correlated_mode_loses_snr():
    p0 = qs_params(sigma_d=0.107, mode=0.0)
    p1 = qs_params(sigma_d=0.107, mode=1.0)
    yi0, _, ya0, _ = run_ensemble(qs_arch, p0, seed0=300)
    yi1, _, ya1, _ = run_ensemble(qs_arch, p1, seed0=300)
    drop = snr_db(yi0, ya0 - yi0) - snr_db(yi1, ya1 - yi1)
    assert 1.5 < drop < 5.0, drop  # ~3 dB predicted


def test_qs_headroom_clipping_collapses_snr():
    """Beyond N_max the BL saturates and SNR_A drops sharply (Fig. 9a)."""
    high = snr_db(*_qs_clip_probe(n=96, k_h=40.0))
    low = snr_db(*_qs_clip_probe(n=400, k_h=40.0))
    assert high - low > 10.0, (high, low)


def _qs_clip_probe(n, k_h):
    p = qs_params(n=n, sigma_d=0.05, k_h=k_h, v_c=min(4 * np.sqrt(3 * n), k_h))
    yi, _, ya, _ = run_ensemble(qs_arch, p, trials=8, seed0=400)
    return yi, ya - yi


def test_qs_pulse_noise_adds():
    p = qs_params(sigma_d=0.0, sigma_t=0.1)
    yi, yfx, ya, _ = run_ensemble(qs_arch, p, seed0=500)
    n, st_ = 100, 0.1
    see = n * st_ * st_ * (1 - 4.0**-6) ** 2 / 9
    meas = np.var(ya - yfx)
    assert abs(10 * np.log10(meas / see)) < 1.2


def test_qs_t_rf_is_deterministic_gain_loss():
    """t_rf (eq. 19) shrinks every cell discharge by a fixed fraction, so
    the noiseless analog output is exactly (1 - t_rf) * reference."""
    p = qs_params(t_rf=0.05)
    _, _, ya, _ = run_ensemble(qs_arch, p, trials=2, seed0=600)
    p_ref = qs_params(t_rf=0.0)
    _, _, ya2, _ = run_ensemble(qs_arch, p_ref, trials=2, seed0=600)
    np.testing.assert_allclose(ya, 0.95 * ya2, atol=2e-3)


def test_qs_thermal_noise_floor():
    p = qs_params(sigma_theta=0.5)
    _, yfx, ya, _ = run_ensemble(qs_arch, p, seed0=700)
    # recombined thermal variance = sum_ij (pw_i pxw_j)^2 * sigma^2
    sw = 4 / 3 * (1 - 4.0**-6)
    sx = 1 / 3 * (1 - 4.0**-6)
    expect = 0.25 * sw * sx
    meas = np.var(ya - yfx)
    assert abs(10 * np.log10(meas / expect)) < 1.0


# --------------------------------------------------------------------------
# QR-Arch (Table III col 2).
# --------------------------------------------------------------------------

def test_qr_cap_mismatch_within_table3_band():
    """Exact charge-share sim sits between the centered (refined) estimate
    and the paper's (conservative) Table III expression."""
    n, bw, sc = 128, 7, 0.08
    p = qr_params(n=n, bw=bw, sigma_c=sc)
    yi, yfx, ya, _ = run_ensemble(qr_arch, p, seed0=800)
    meas = np.var(ya - yfx)
    ex2 = 1 / 3
    mu_v = 1 / 4
    table3 = (2 / 3) * (1 - 4.0**-bw) * n * ex2 * sc * sc
    refined = (4 / 3) * (1 - 4.0**-bw) * n * sc * sc * (ex2 / 2 - mu_v**2)
    assert meas < table3 * 1.3
    assert abs(10 * np.log10(meas / refined)) < 1.0, (meas, refined, table3)


def test_qr_thermal_and_injection():
    p = qr_params(sigma_theta=0.01, inj_a=0.02, inj_b=0.03)
    yi, yfx, ya, _ = run_ensemble(qr_arch, p, seed0=900)
    resid = ya - yfx
    # injection has a systematic (mean) component; thermal adds variance
    assert np.var(resid) > 0
    p0 = qr_params()
    _, yfx0, ya0, _ = run_ensemble(qr_arch, p0, seed0=900)
    assert np.var(ya0 - yfx0) < 1e-9


def test_qr_no_headroom_clipping():
    """QR rows stay within [0, Vdd]: no clipping even at N=512 (Sec. IV-C)."""
    p = qr_params(n=512, sigma_c=0.05)
    yi, yfx, ya, _ = run_ensemble(qr_arch, p, trials=8, seed0=1000)
    snr = snr_db(yi, ya - yi)
    p2 = qr_params(n=128, sigma_c=0.05)
    yi2, _, ya2, _ = run_ensemble(qr_arch, p2, trials=8, seed0=1001)
    snr2 = snr_db(yi2, ya2 - yi2)
    assert abs(snr - snr2) < 3.0  # no catastrophic drop with N


# --------------------------------------------------------------------------
# CM (Table III col 3).
# --------------------------------------------------------------------------

def test_cm_current_mismatch_matches_table3():
    n, bw, sd = 64, 6, 0.107
    p = cm_params(n=n, bw=bw, sigma_d=sd)
    yi, yfx, ya, _ = run_ensemble(cm_arch, p, seed0=1100)
    meas = np.var(ya - yfx)
    expect = (2 / 3) * n * (1 / 3) * (0.25 - 4.0**-bw) * sd * sd
    assert abs(10 * np.log10(meas / expect)) < 1.0, (meas, expect)


def test_cm_weight_clipping_hurts_large_weights():
    p_clip = cm_params(w_h=0.25)
    p_free = cm_params(w_h=1e9)
    yi_c, _, ya_c, _ = run_ensemble(cm_arch, p_clip, trials=4, seed0=1200)
    yi_f, _, ya_f, _ = run_ensemble(cm_arch, p_free, trials=4, seed0=1200)
    assert snr_db(yi_c, ya_c - yi_c) < snr_db(yi_f, ya_f - yi_f) - 3.0


def test_cm_optimal_bw_exists():
    """Fig. 11(a): SNR_a peaks at an intermediate B_w when headroom-limited."""
    snrs = {}
    for bw in (2, 4, 6, 8):
        k_h = 16.0  # fixed headroom in Delta_w units => w_h = k_h * 2^{1-bw}
        w_h = k_h * 2.0 ** (1 - bw)
        p = cm_params(bw=bw, sigma_d=0.05, w_h=w_h)
        yi, _, ya, _ = run_ensemble(cm_arch, p, trials=6, seed0=1300 + bw)
        snrs[bw] = snr_db(yi, ya - yi)
    best = max(snrs, key=snrs.get)
    assert best in (4, 6), snrs  # interior optimum, not an endpoint


# --------------------------------------------------------------------------
# ADC / MPC behaviour.
# --------------------------------------------------------------------------

def test_adc_precision_sweep_saturates_at_snr_a():
    """SNR_T -> SNR_A as B_ADC grows (Fig. 9b): 3-b is quantization-limited,
    8-b is analog-noise-limited."""
    base = dict(n=128, sigma_d=0.107, k_h=60.0, v_c=4 * np.sqrt(3 * 128))
    out = {}
    for b_adc in (3, 6, 8, 10):
        p = qs_params(b_adc=b_adc, **base)
        yi, _, ya, yh = run_ensemble(qs_arch, p, trials=8, seed0=1400)
        out[b_adc] = (snr_db(yi, yh - yi), snr_db(yi, ya - yi))
    assert out[3][0] < out[6][0] <= out[8][0] + 0.5
    assert abs(out[8][0] - out[8][1]) < 1.0  # SNR_T within 1 dB of SNR_A
    assert abs(out[10][0] - out[10][1]) < 0.6
