"""Runtime-parameter vector layouts shared between L2 (JAX) and L3 (Rust).

One compiled artifact per architecture covers every sweep point in the
paper's evaluation: shapes are static at (M trials, N_MAX cells, B_MAX bit
planes) and all sweep knobs arrive as entries of a f32[P] parameter vector.
Rust owns the *circuit* domain (Table II constants, V_WL, C_o, technology
node) and converts to the normalized noise magnitudes consumed here; the
JAX side is a pure sample-accurate simulator in normalized units.

Mirrored by rust/src/runtime/params.rs — keep the two in sync (pinned by
tests on both sides).
"""

# Static artifact shapes.
M_TRIALS = 64  # Monte-Carlo trials per executable invocation
N_MAX = 512  # bit-cell rows (paper: 512-row SRAM array)
B_MAX = 8  # maximum bit planes for weights/activations
P = 16  # parameter-vector length (fixed for all architectures)

# Common slots (all architectures).
IDX_N_ACTIVE = 0  # DP dimension N <= N_MAX (inactive cells masked)
IDX_BX = 1  # activation precision B_x <= B_MAX
IDX_BW = 2  # weight precision B_w <= B_MAX
IDX_B_ADC = 3  # column ADC precision B_y

# QS-Arch (charge-summing, Fig. 7(a)); normalized to Delta-V_BL,unit counts.
QS_IDX_SIGMA_D = 4  # cell-current mismatch sigma_I/I, eq. (18)
QS_IDX_SIGMA_T = 5  # pulse-width mismatch sigma_Tj/T_max, eq. (20)
QS_IDX_T_RF = 6  # rise/fall-time discharge deficit t_rf/T_max, eq. (19)
QS_IDX_SIGMA_THETA = 7  # integrated thermal noise in unit counts, eq. (20)
QS_IDX_K_H = 8  # headroom clip level k_h = dV_BL,max/dV_BL,unit (counts)
QS_IDX_V_C = 9  # ADC full-scale range in unit counts (Table III V_c)
# Noise-correlation mode: 0 = paper assumption (noise independent across
# bit-plane pairs, appendix B — matches the Table III closed forms);
# 1 = physically-correlated spatial V_t mismatch, static across the B_x
# bit-serial cycles (ablation; ~3 dB lower SNR_a — see EXPERIMENTS.md).
# In the JAX path the mode is *static* (qs_arch vs qs_arch_corr
# artifacts; the param slot routes artifact selection in the Rust
# coordinator and the branch in the native Rust simulator).
QS_IDX_MODE = 10

# QR-Arch (charge-redistribution, Fig. 7(b)); voltages normalized to V_dd.
QR_IDX_SIGMA_C = 4  # capacitor mismatch sigma_C/C_o = kappa/sqrt(C_o)
QR_IDX_INJ_A = 5  # charge injection p*WL*Cox*(V_dd - V_t)/(C_o*V_dd)
QR_IDX_INJ_B = 6  # charge injection slope p*WL*Cox/C_o (times V_j)
QR_IDX_SIGMA_THETA = 7  # per-cap thermal sqrt(kT/C_o)/V_dd
QR_IDX_V_C = 8  # per-row ADC full-scale *width* (fraction of V_dd)
QR_IDX_V_LO = 9  # per-row ADC range low end (the row mean is > 0)

# CM (compute memory, Fig. 7(c)); weight domain normalized to w_m = 1.
CM_IDX_SIGMA_D = 4  # cell-current mismatch (QS stage), eq. (18)
CM_IDX_W_H = 5  # weight-domain headroom clip w_h = k_h * Delta_w
CM_IDX_SIGMA_C = 6  # capacitor mismatch (QR aggregation stage)
CM_IDX_INJ_A = 7  # charge injection intercept (normalized)
CM_IDX_INJ_B = 8  # charge injection slope
CM_IDX_SIGMA_THETA = 9  # per-cap thermal (QR stage)
CM_IDX_V_C = 10  # ADC range in normalized DP-mean units (Table III V_c)

# Multi-bank DP (Sec. VI), shared across architectures. 0.0 is the
# single-bank (legacy) encoding; a value >= 2 means the arch-specific
# slots are *per-bank* (IDX_N_ACTIVE holds ceil(N / banks)) and the DP
# is the sum of that many independent per-bank ensembles. Interpreted by
# the native Rust simulator only — the AOT artifacts model one array,
# and the Rust coordinator rejects banked points on the PJRT backend.
IDX_BANKS = 15

# MLP (Fig. 2 workload) static shapes.
MLP_BATCH = 256
MLP_DIMS = (64, 128, 64, 10)  # D0 -> D1 -> D2 -> D3
