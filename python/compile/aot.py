"""AOT compile path: lower every L2 model to HLO *text* artifacts.

Runs ONCE at build time (`make artifacts`); the Rust coordinator loads the
emitted `artifacts/*.hlo.txt` through the PJRT C API and Python never runs
again. HLO text (not `.serialize()`) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Also writes `artifacts/manifest.json` describing each artifact's I/O
signature, consumed by rust/src/runtime/registry.rs.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from . import params as pp


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _arch_entry(fn, m, n, **kw):
    """(callable, input specs, output names) for one architecture model."""
    if kw:
        import functools

        fn = functools.partial(fn, **kw)
    args = (_spec((m, n)), _spec((m, n)), _spec((2,)), _spec((pp.P,)))
    inputs = [
        {"name": "x", "shape": [m, n]},
        {"name": "w", "shape": [m, n]},
        {"name": "seed", "shape": [2]},
        {"name": "params", "shape": [pp.P]},
    ]
    outputs = ["y_ideal", "y_fx", "y_a", "y_hat"]
    return fn, args, inputs, outputs


def _mlp_entry():
    d0, d1, d2, d3 = pp.MLP_DIMS
    mb = pp.MLP_BATCH
    args = (
        _spec((mb, d0)),
        _spec((d1, d0)), _spec((d1,)),
        _spec((d2, d1)), _spec((d2,)),
        _spec((d3, d2)), _spec((d3,)),
        _spec((2,)), _spec((3,)),
    )
    inputs = [
        {"name": "x", "shape": [mb, d0]},
        {"name": "w1", "shape": [d1, d0]}, {"name": "b1", "shape": [d1]},
        {"name": "w2", "shape": [d2, d1]}, {"name": "b2", "shape": [d2]},
        {"name": "w3", "shape": [d3, d2]}, {"name": "b3", "shape": [d3]},
        {"name": "seed", "shape": [2]},
        {"name": "sigmas", "shape": [3]},
    ]
    return model.mlp_fwd, args, inputs, ["logits"]


def _smoke():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    args = (_spec((2, 2)), _spec((2, 2)))
    inputs = [{"name": "x", "shape": [2, 2]}, {"name": "y", "shape": [2, 2]}]
    return fn, args, inputs, ["out"]


def entries():
    """name -> (fn, example args, input descs, output names)."""
    m, n = pp.M_TRIALS, pp.N_MAX
    ms, ns = 16, 64  # small variants for fast Rust integration tests
    return {
        "qs_arch": _arch_entry(model.qs_arch, m, n),
        "qs_arch_corr": _arch_entry(model.qs_arch, m, n, correlated=True),
        "qr_arch": _arch_entry(model.qr_arch, m, n),
        "cm_arch": _arch_entry(model.cm_arch, m, n),
        "qs_arch_small": _arch_entry(model.qs_arch, ms, ns),
        "qs_arch_corr_small": _arch_entry(model.qs_arch, ms, ns, correlated=True),
        "qr_arch_small": _arch_entry(model.qr_arch, ms, ns),
        "cm_arch_small": _arch_entry(model.cm_arch, ms, ns),
        "mlp_fwd": _mlp_entry(),
        "smoke": _smoke(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated subset")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"m_trials": pp.M_TRIALS, "n_max": pp.N_MAX,
                "b_max": pp.B_MAX, "p": pp.P, "artifacts": {}}
    for name, (fn, ex_args, inputs, outputs) in entries().items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*ex_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
