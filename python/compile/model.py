"""L2: JAX forward models of the paper's three IMC architectures.

Sample-accurate Monte-Carlo simulation of fixed-point dot products on
QS-Arch, QR-Arch and CM (Sec. IV, Fig. 7), in normalized units, calling the
L1 Pallas kernel (`kernels.pair_dot`) for the analog-core contractions.

Each model maps M trials of (x, w) through the full signal chain

    quantize -> bit-slice -> analog core (+mismatch/thermal/injection)
             -> headroom clip -> column ADC -> digital recombination

and returns the four signals needed to measure every SNR metric of eq. (7):

    y_ideal  — FL dot product y_o                       (eq. 2)
    y_fx     — quantized-input DP, no analog noise      (y_o + q_iy)
    y_a      — analog output before the ADC             (y_o + q_iy + eta_a)
    y_hat    — final digitized output                   (eq. 6, all terms)

so the Rust coordinator can estimate SQNR_qiy, SNR_a, SNR_A and SNR_T from
ensemble statistics. Build-time only: `aot.py` lowers these once to HLO
text; Python never runs on the experiment path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import params as pp
from .kernels.pair_dot import pair_dot
from .kernels.mlp_layer import mlp_layer


# ---------------------------------------------------------------------------
# Quantization and bit-slicing (Sec. II-B/C), dynamic in B via masking.
# ---------------------------------------------------------------------------

def _plane_iota():
    """Plane indices i = 1..B_MAX as f32[B_MAX]."""
    return jnp.arange(1, pp.B_MAX + 1, dtype=jnp.float32)


def unsigned_bits(x, bx):
    """Bit-slice unsigned activations x in [0,1) to B_x planes.

    Returns (xb, pxw, xq): xb f32[..., B_MAX, N] bit planes (plane j holds
    bit of weight 2^-j), pxw f32[B_MAX] recombination weights (masked by
    j <= bx), and xq the quantized value sum_j xb_j 2^-j.
    """
    j = _plane_iota()  # [B]
    active = (j <= bx).astype(jnp.float32)  # [B]
    # Round-to-nearest (paper's additive model assumes zero-mean q noise),
    # clipped to the top code; then extract planes from the integer code.
    t = jnp.clip(jnp.floor(x * jnp.exp2(bx) + 0.5), 0.0, jnp.exp2(bx) - 1.0)
    shift = jnp.exp2(jnp.maximum(bx - j, 0.0))  # [B]
    bits = jnp.floor(t[..., None, :] / shift[:, None]) % 2.0
    xb = bits * active[:, None]  # plane j <-> integer bit (bx - j)
    pxw = jnp.exp2(-j) * active
    xq = jnp.einsum("...bn,b->...n", xb, pxw)
    return xb, pxw, xq


def signed_bits(w, bw):
    """Bit-slice signed weights w in [-1,1) into two's-complement planes.

    w_q = -b_1 + sum_{i=2..bw} b_i 2^{1-i}  (Q1.(bw-1) two's complement,
    truncation quantizer). Plane 1 stores the *complemented* MSB so that
    plane recombination weights are pw = [-1, 2^-1, ..., 2^{2-bw}, 0, ...].

    Returns (wb, pw, wq): wb f32[..., B_MAX, N], pw f32[B_MAX], wq value.
    """
    i = _plane_iota()
    active = (i <= bw).astype(jnp.float32)
    # integer code t in [0, 2^bw), round-to-nearest (zero-mean q noise)
    t = jnp.floor((w + 1.0) * jnp.exp2(bw - 1.0) + 0.5)
    t = jnp.clip(t, 0.0, jnp.exp2(bw) - 1.0)
    shift = jnp.exp2(jnp.maximum(bw - i, 0.0))  # [B]
    raw = jnp.floor(t[..., None, :] / shift[:, None]) % 2.0  # [..., B, N]
    sign_plane = (i == 1.0).astype(jnp.float32)[:, None]
    bits = raw * (1.0 - sign_plane) + (1.0 - raw) * sign_plane
    wb = bits * active[:, None]
    pw = (jnp.where(i == 1.0, -1.0, jnp.exp2(1.0 - i))) * active
    wq = jnp.einsum("...bn,b->...n", wb, pw)
    return wb, pw, wq


def quantize_unsigned(x, bx):
    """Round-to-nearest quantizer for unsigned x in [0,1) to bx bits."""
    s = jnp.exp2(bx)
    return jnp.clip(jnp.floor(x * s + 0.5), 0.0, s - 1.0) / s


def signed_mag_bits(w, bw):
    """Sign-magnitude bit-slicing used by CM (Sec. IV-D, appendix B).

    |w_q| = sum_{i=1..bw-1} b_i 2^{-i} (quantization step Delta_w =
    2^{1-bw}); the sign routes the discharge to BL vs BL-bar. Returns
    (mb, pm, sgn, wq): magnitude planes f32[..., B_MAX, N] (plane i holds
    the 2^{-i} bit, planes bw..B_MAX zero), recombination weights
    pm f32[B_MAX], sign f32[..., N] in {-1, +1}, and the quantized value
    wq = sgn * sum_i pm_i mb_i.
    """
    i = _plane_iota()
    active = (i <= bw - 1.0).astype(jnp.float32)
    sgn = jnp.where(w < 0.0, -1.0, 1.0)
    t = jnp.floor(jnp.abs(w) * jnp.exp2(bw - 1.0) + 0.5)  # round-to-nearest
    t = jnp.minimum(t, jnp.exp2(bw - 1.0) - 1.0)  # integer in [0, 2^{bw-1})
    shift = jnp.exp2(jnp.maximum(bw - 1.0 - i, 0.0))
    mb = (jnp.floor(t[..., None, :] / shift[:, None]) % 2.0) * active[:, None]
    pm = jnp.exp2(-i) * active
    wq = sgn * jnp.einsum("...bn,b->...n", mb, pm)
    return mb, pm, sgn, wq


def _adc_unsigned(v, v_c, b_adc):
    """Mid-tread uniform ADC over [0, v_c] with 2^b_adc levels."""
    delta = v_c / jnp.exp2(b_adc)
    code = jnp.clip(jnp.round(v / delta), 0.0, jnp.exp2(b_adc) - 1.0)
    return code * delta


def _adc_signed(v, v_c, b_adc):
    """Mid-tread uniform ADC over [-v_c, v_c] with 2^b_adc levels."""
    delta = 2.0 * v_c / jnp.exp2(b_adc)
    half = jnp.exp2(b_adc - 1.0)
    code = jnp.clip(jnp.round(v / delta), -half, half - 1.0)
    return code * delta


def _key_from_seed(seed):
    """Derive a PRNG key from a f32[2] seed vector (Rust-supplied)."""
    k = jax.random.PRNGKey(0)
    k = jax.random.fold_in(k, seed[0].astype(jnp.uint32))
    k = jax.random.fold_in(k, seed[1].astype(jnp.uint32))
    return k


def _n_mask(n_active, n_max):
    return (jnp.arange(n_max, dtype=jnp.float32) < n_active).astype(
        jnp.float32
    )


# ---------------------------------------------------------------------------
# QS-Arch: bit-serial binarized DPs on the bit-lines (Sec. IV-B2).
# ---------------------------------------------------------------------------

def qs_arch(x, w, seed, params, *, correlated=False):
    """Charge-summing architecture, sample-accurate per eq. (17).

    Args:
      x: f32[M, N_MAX] raw activations in [0, 1).
      w: f32[M, N_MAX] raw weights in [-1, 1).
      seed: f32[2] PRNG seed counters.
      params: f32[P] per `params.py` QS layout. Voltages normalized to
        Delta-V_BL,unit *counts* (one count = one full cell discharge).

    Returns (y_ideal, y_fx, y_a, y_hat), each f32[M].
    """
    n_active = params[pp.IDX_N_ACTIVE]
    bx = params[pp.IDX_BX]
    bw = params[pp.IDX_BW]
    b_adc = params[pp.IDX_B_ADC]
    sigma_d = params[pp.QS_IDX_SIGMA_D]
    sigma_t = params[pp.QS_IDX_SIGMA_T]
    t_rf = params[pp.QS_IDX_T_RF]
    sigma_theta = params[pp.QS_IDX_SIGMA_THETA]
    k_h = params[pp.QS_IDX_K_H]
    v_c = params[pp.QS_IDX_V_C]
    # Noise-correlation mode is *static* (separate artifacts): the
    # independent path needs no per-cell draws at all, so baking the
    # branch at lowering time removes ~0.5M threefry draws and two of the
    # three contractions per batch (EXPERIMENTS.md §Perf P1).

    mask = _n_mask(n_active, x.shape[1])[None, :]  # [1, N]
    x = x * mask
    w = w * mask
    y_ideal = jnp.sum(w * x, axis=-1)

    xb, pxw, xq = unsigned_bits(x, bx)  # [M, B, N]
    wb, pw, wq = signed_bits(w, bw)
    y_fx = jnp.sum(wq * xq, axis=-1)

    key = _key_from_seed(seed)
    kw, kx, kt, kb = jax.random.split(key, 4)
    g_th = jax.random.normal(kt, (x.shape[0], pp.B_MAX, pp.B_MAX), jnp.float32)
    g_bl = jax.random.normal(kb, (x.shape[0], pp.B_MAX, pp.B_MAX), jnp.float32)

    # Per-cell discharge (counts): wb*xb*(1 + sigma_d*g_cell)(1 + sigma_t*g_pulse)
    # ~= wb*xb*(1 + sigma_d*g + sigma_t*g') (eq. 17). Two noise modes:
    #
    #  independent (paper, appendix B): mismatch independent across the
    #   (i, j) bit-plane pairs. Conditioned on the active-cell count
    #   c_ij = sum_k wb_ik xb_jk, the summed cell noise is *exactly*
    #   N(0, c_ij (sigma_d^2 + sigma_t^2)) — sampled as sqrt(c)*sigma*g.
    #
    #  correlated (physical ablation): spatial V_t mismatch static across
    #   the B_x bit-serial cycles, WL-pulse jitter shared across the B_w
    #   columns => ~3 dB lower SNR_a. Needs per-cell draws and the full
    #   dual contraction.
    sigma_eff = jnp.sqrt(sigma_d * sigma_d + sigma_t * sigma_t)
    if correlated:
        g_cell = jax.random.normal(kw, wb.shape, jnp.float32)
        g_pulse = jax.random.normal(kx, xb.shape, jnp.float32)
        a_op = wb * (1.0 + sigma_d * g_cell)
        d_op = xb * (sigma_t * g_pulse)
        o1, o2 = pair_dot(a_op, xb, wb, d_op)
        counts, _ = pair_dot(
            wb, xb, jnp.zeros_like(wb[:, :1]), jnp.zeros_like(xb[:, :1])
        )
        y_bl = o1 + o2
    else:
        counts, _ = pair_dot(
            wb, xb, jnp.zeros_like(wb[:, :1]), jnp.zeros_like(xb[:, :1])
        )
        y_bl = counts + jnp.sqrt(jnp.maximum(counts, 0.0)) * sigma_eff * g_bl
    y_bl = y_bl - t_rf * counts  # deterministic rise/fall deficit (eq. 19)

    # Headroom clipping on the bit-line (eta_h), then integrated thermal.
    y_cl = jnp.clip(y_bl, 0.0, k_h)
    y_a_bl = y_cl + sigma_theta * g_th

    # Per-BL column ADC (one conversion per binarized DP).
    y_hat_bl = _adc_unsigned(y_a_bl, v_c, b_adc)

    # Digital recombination: y = sum_ij pw_i * pxw_j * y_BL[i, j].
    y_a = jnp.einsum("mij,i,j->m", y_a_bl, pw, pxw)
    y_hat = jnp.einsum("mij,i,j->m", y_hat_bl, pw, pxw)
    return y_ideal, y_fx, y_a, y_hat


# ---------------------------------------------------------------------------
# QR-Arch: binary-weighted rows + charge redistribution (Sec. IV-C2).
# ---------------------------------------------------------------------------

def qr_arch(x, w, seed, params):
    """Charge-redistribution architecture, sample-accurate per eq. (23).

    Voltages normalized to V_dd = 1. Each weight-bit row i computes
    V_i = sum_k (C+c_k)(x_k w_ik + noise) / sum_k (C+c_k) over the active
    cells, digitized per row, then POT-summed digitally.
    """
    n_active = params[pp.IDX_N_ACTIVE]
    bx = params[pp.IDX_BX]
    bw = params[pp.IDX_BW]
    b_adc = params[pp.IDX_B_ADC]
    sigma_c = params[pp.QR_IDX_SIGMA_C]
    inj_a = params[pp.QR_IDX_INJ_A]
    inj_b = params[pp.QR_IDX_INJ_B]
    sigma_theta = params[pp.QR_IDX_SIGMA_THETA]
    v_c = params[pp.QR_IDX_V_C]
    v_lo = params[pp.QR_IDX_V_LO]

    m = x.shape[0]
    mask = _n_mask(n_active, x.shape[1])[None, :]
    x = x * mask
    w = w * mask
    y_ideal = jnp.sum(w * x, axis=-1)

    xq = quantize_unsigned(x, bx) * mask
    wb, pw, wq = signed_bits(w, bw)
    y_fx = jnp.sum(wq * xq, axis=-1)

    key = _key_from_seed(seed)
    kc, kt = jax.random.split(key, 2)
    g_cap = jax.random.normal(kc, (m, pp.B_MAX, x.shape[1]), jnp.float32)
    g_th = jax.random.normal(kt, (m, pp.B_MAX, x.shape[1]), jnp.float32)

    v = wb * xq[:, None, :]  # per-cell product voltage (V_dd units)
    v_inj = inj_a - inj_b * v  # charge injection, eq. (24)
    cap = 1.0 + sigma_c * g_cap
    num_op = cap * (v + v_inj + sigma_theta * g_th) * mask[:, None, :]
    den_op = cap * mask[:, None, :]
    ones_row = jnp.broadcast_to(mask[:, None, :], (m, 1, x.shape[1]))
    num, den = pair_dot(num_op, ones_row, den_op, ones_row)
    v_row = num[:, :, 0] / jnp.maximum(den[:, :, 0], 1e-6)  # [M, B]

    # The row mean is positive (unsigned x, binary w), so the ADC range is
    # offset: [v_lo, v_lo + v_c] per the MPC mean +- 4 sigma rule.
    v_row_hat = v_lo + _adc_unsigned(v_row - v_lo, v_c, b_adc)

    # y = n * sum_i pw_i V_i  (charge share divides by n; eq. 22).
    y_a = n_active * jnp.einsum("mi,i->m", v_row, pw)
    y_hat = n_active * jnp.einsum("mi,i->m", v_row_hat, pw)
    return y_ideal, y_fx, y_a, y_hat


# ---------------------------------------------------------------------------
# CM: multi-bit analog DP via QS (POT pulse widths) + QR aggregation
# (Sec. IV-D).
# ---------------------------------------------------------------------------

def cm_arch(x, w, seed, params):
    """Compute-memory architecture: multi-bit DP in one compute cycle.

    The per-column BL discharge realizes a noisy multi-bit weight
    w_eff = sum_i pw_i wb_i (1 + sigma_D g_i) clipped to +-w_h (headroom),
    multiplied by xq in charge domain, then QR-aggregated over columns.
    """
    n_active = params[pp.IDX_N_ACTIVE]
    bx = params[pp.IDX_BX]
    bw = params[pp.IDX_BW]
    b_adc = params[pp.IDX_B_ADC]
    sigma_d = params[pp.CM_IDX_SIGMA_D]
    w_h = params[pp.CM_IDX_W_H]
    sigma_c = params[pp.CM_IDX_SIGMA_C]
    inj_a = params[pp.CM_IDX_INJ_A]
    inj_b = params[pp.CM_IDX_INJ_B]
    sigma_theta = params[pp.CM_IDX_SIGMA_THETA]
    v_c = params[pp.CM_IDX_V_C]

    m = x.shape[0]
    mask = _n_mask(n_active, x.shape[1])[None, :]
    x = x * mask
    w = w * mask
    y_ideal = jnp.sum(w * x, axis=-1)

    xq = quantize_unsigned(x, bx) * mask
    # CM stores weights sign-magnitude: magnitude POT pulse widths on the
    # BL, sign via differential BL/BL-bar discharge (Sec. IV-D).
    mb, pm, sgn, wq = signed_mag_bits(w, bw)
    y_fx = jnp.sum(wq * xq, axis=-1)

    key = _key_from_seed(seed)
    kd, kc, kt = jax.random.split(key, 3)
    g_cell = jax.random.normal(kd, (m, pp.B_MAX, x.shape[1]), jnp.float32)
    g_cap = jax.random.normal(kc, (m, x.shape[1]), jnp.float32)
    g_th = jax.random.normal(kt, (m, x.shape[1]), jnp.float32)

    # Analog multi-bit weight on the BL (eq. 45-46): POT pulse widths with
    # per-cell current mismatch, headroom-clipped at +-w_h (eq. 41).
    w_eff = sgn * jnp.einsum("mbn,b->mn", mb * (1.0 + sigma_d * g_cell), pm)
    w_cl = jnp.clip(w_eff, -w_h, w_h)

    u = w_cl * xq  # mixed-signal multiplier output
    v_inj = inj_a - inj_b * jnp.abs(u)
    cap = 1.0 + sigma_c * g_cap
    num_op = (cap * (u + v_inj + sigma_theta * g_th) * mask)[:, None, :]
    den_op = (cap * mask)[:, None, :]
    ones_row = jnp.broadcast_to(mask[:, None, :], (m, 1, x.shape[1]))
    num, den = pair_dot(num_op, ones_row, den_op, ones_row)
    v_out = num[:, 0, 0] / jnp.maximum(den[:, 0, 0], 1e-6)  # [M]

    v_hat = _adc_signed(v_out, v_c, b_adc)

    y_a = n_active * v_out
    y_hat = n_active * v_hat
    return y_ideal, y_fx, y_a, y_hat


# ---------------------------------------------------------------------------
# Fig. 2 workload: fixed-point MLP with per-layer output-referred noise.
# ---------------------------------------------------------------------------

def mlp_fwd(x, w1, b1, w2, b2, w3, b3, seed, sigmas):
    """3-layer MLP forward with per-layer output-referred Gaussian noise.

    sigmas: f32[3] — per-layer noise std (absolute, output-referred),
    lumping q_iy + eta_a + q_y of eq. (6); the coordinator sets them from a
    target per-layer SNR_T. Returns logits f32[MLP_BATCH, 10].
    """
    key = _key_from_seed(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    n1 = sigmas[0] * jax.random.normal(k1, (x.shape[0], w1.shape[0]), jnp.float32)
    n2 = sigmas[1] * jax.random.normal(k2, (x.shape[0], w2.shape[0]), jnp.float32)
    n3 = sigmas[2] * jax.random.normal(k3, (x.shape[0], w3.shape[0]), jnp.float32)
    h1 = mlp_layer(x, w1, b1, n1, relu=True)
    h2 = mlp_layer(h1, w2, b2, n2, relu=True)
    return mlp_layer(h2, w3, b3, n3, relu=False)
