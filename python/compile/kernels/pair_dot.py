"""L1 Pallas kernel: the analog-core hot spot shared by all three IMC models.

``pair_dot(A, B, C, D) -> (A @ B^T, C @ D^T)`` batched over Monte-Carlo
trials, reducing over the bit-cell (row) dimension N.

Every in-memory compute model in the paper reduces, per MC trial, to one or
two inner products over the N bit-cells attached to a bit-line / capacitor
bank (Sec. IV):

* QS-Arch (charge summing, Fig. 7(a)): the bit-plane matmul
  ``y_BL[i, j] = sum_k wb[i,k] * xb[j,k] * (1 + dI[i,k] + dT[j,k])``
  expands into exactly two matmuls:
  ``(wb*(1+dI)) @ xb^T  +  wb @ (xb*dT)^T`` — the two operands of pair_dot
  (the L2 model adds the two outputs).
* QR-Arch (charge redistribution, Fig. 7(b)): the charge-share numerator
  ``sum_k (C+c_k) V_k`` and denominator ``sum_k (C+c_k)`` — the two
  *separate* outputs of pair_dot.
* CM (compute memory, Fig. 7(c)): same numerator/denominator structure with
  a multi-bit effective weight per column.

Hardware adaptation (DESIGN.md §5): the per-trial work is a (P,N)x(N,Q)
matmul — MXU-shaped. The kernel tiles the reduction dimension N into
``block_n`` chunks held in VMEM and walks the (trial, chunk) grid; BlockSpec
expresses the HBM<->VMEM schedule a CUDA design would express with
threadblocks. ``interpret=True`` everywhere: the CPU PJRT client cannot run
Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default reduction-tile width. At f32 with P=Q=8 and block_n=128 the VMEM
# working set is 4 operand tiles of 8*128*4 B = 16 KiB plus two 8x8 outputs:
# far below the ~16 MiB VMEM budget, leaving room for the compiler to
# double-buffer the HBM->VMEM streams of all four operands.
DEFAULT_BLOCK_N = 128


def _pair_dot_kernel(a_ref, b_ref, c_ref, d_ref, o1_ref, o2_ref):
    """One (trial, n-chunk) grid step: accumulate both partial products."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o1_ref[...] = jnp.zeros_like(o1_ref)
        o2_ref[...] = jnp.zeros_like(o2_ref)

    a = a_ref[0]  # (P, block_n)
    b = b_ref[0]  # (Q, block_n)
    c = c_ref[0]  # (P2, block_n)
    d = d_ref[0]  # (Q2, block_n)
    # MXU-shaped contractions; accumulate in f32 regardless of input dtype.
    o1_ref[0] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o2_ref[0] += jax.lax.dot_general(
        c, d, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pair_dot(a, b, c, d, *, block_n: int = DEFAULT_BLOCK_N, interpret: bool = True):
    """Batched pair of contractions over the bit-cell dimension.

    Args:
      a: f32[M, P, N]   b: f32[M, Q, N]   c: f32[M, P2, N]   d: f32[M, Q2, N]
      block_n: reduction tile width (N must be divisible by it).
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      (f32[M, P, Q], f32[M, P2, Q2]) = (A @ B^T, C @ D^T) per trial.
    """
    m, p, n = a.shape
    q = b.shape[1]
    p2, q2 = c.shape[1], d.shape[1]
    if n % block_n != 0:
        # Small-N variants (test artifacts) fall back to a single tile.
        block_n = n
    if b.shape != (m, q, n) or c.shape != (m, p2, n) or d.shape != (m, q2, n):
        raise ValueError(
            f"shape mismatch: a={a.shape} b={b.shape} c={c.shape} d={d.shape}"
        )
    grid = (m, n // block_n)
    return pl.pallas_call(
        _pair_dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, p, block_n), lambda i, k: (i, 0, k)),
            pl.BlockSpec((1, q, block_n), lambda i, k: (i, 0, k)),
            pl.BlockSpec((1, p2, block_n), lambda i, k: (i, 0, k)),
            pl.BlockSpec((1, q2, block_n), lambda i, k: (i, 0, k)),
        ],
        out_specs=[
            pl.BlockSpec((1, p, q), lambda i, k: (i, 0, 0)),
            pl.BlockSpec((1, p2, q2), lambda i, k: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, p, q), jnp.float32),
            jax.ShapeDtypeStruct((m, p2, q2), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, c, d)
