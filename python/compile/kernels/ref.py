"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness reference: pytest asserts the Pallas kernels
match these to float tolerance across hypothesis-generated shapes/dtypes
(python/tests/test_kernels.py). They contain no Pallas, no tiling — just
the mathematical definition.
"""

from __future__ import annotations

import jax.numpy as jnp


def pair_dot_ref(a, b, c, d):
    """(A @ B^T, C @ D^T) per trial: the pair_dot definition."""
    o1 = jnp.einsum("mpn,mqn->mpq", a, b, preferred_element_type=jnp.float32)
    o2 = jnp.einsum("mpn,mqn->mpq", c, d, preferred_element_type=jnp.float32)
    return o1.astype(jnp.float32), o2.astype(jnp.float32)


def mlp_layer_ref(x, w, bias, noise, *, relu: bool):
    """Noisy fixed-point-style layer: relu(x @ W^T + b + noise)."""
    y = jnp.einsum("md,od->mo", x, w, preferred_element_type=jnp.float32)
    y = y + bias[None, :] + noise
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(jnp.float32)
