"""L1 Pallas kernel: one noisy fully-connected layer.

Used by the Fig. 2 workload (per-layer SNR_T requirements of a DNN): a
fixed-point MLP whose every layer output is perturbed by output-referred
Gaussian noise — exactly the paper's noise-injection methodology where the
DP output carries `q_iy + eta_a + q_y` (eq. 6) lumped into one
output-referred term whose variance the coordinator sets per target SNR_T.

Grid walks (batch tile, output tile); the full reduction dimension D is
held in VMEM (layer widths here are <= 256, i.e. a (64,256)x(256,64) tile
of 128 KiB — trivially VMEM-resident; see DESIGN.md §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 64
DEFAULT_BLOCK_O = 64


def _mlp_layer_kernel(x_ref, w_ref, b_ref, n_ref, o_ref, *, relu: bool):
    x = x_ref[...]  # (bm, D)
    w = w_ref[...]  # (bo, D)
    y = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    y = y + b_ref[...][None, :] + n_ref[...]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


@functools.partial(
    jax.jit, static_argnames=("relu", "block_m", "block_o", "interpret")
)
def mlp_layer(
    x,
    w,
    bias,
    noise,
    *,
    relu: bool,
    block_m: int = DEFAULT_BLOCK_M,
    block_o: int = DEFAULT_BLOCK_O,
    interpret: bool = True,
):
    """y = [relu](x @ W^T + bias + noise).

    Args:
      x: f32[M, D] activations, w: f32[O, D] weights, bias: f32[O],
      noise: f32[M, O] output-referred analog+quantization noise sample.
    Returns: f32[M, O].
    """
    m, d = x.shape
    o = w.shape[0]
    if w.shape[1] != d or bias.shape != (o,) or noise.shape != (m, o):
        raise ValueError(
            f"shape mismatch: x={x.shape} w={w.shape} b={bias.shape} n={noise.shape}"
        )
    bm = min(block_m, m)
    bo = min(block_o, o)
    if m % bm != 0 or o % bo != 0:
        raise ValueError(f"M={m}/O={o} not divisible by blocks ({bm},{bo})")
    grid = (m // bm, o // bo)
    return pl.pallas_call(
        functools.partial(_mlp_layer_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bo, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bo,), lambda i, j: (j,)),
            pl.BlockSpec((bm, bo), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, o), jnp.float32),
        interpret=interpret,
    )(x, w, bias, noise)
