//! Pareto-frontier extraction over the four objectives (max SNR_T, min
//! energy, min delay, min area), with branch-and-bound pruning instead
//! of brute-force enumeration.
//!
//! Pruning exploits the monotone structure of the closed forms:
//!
//! * the noise decomposition is B_ADC-independent, so each family is
//!   evaluated once and its B_ADC column costed from that single
//!   decomposition;
//! * along the B_ADC axis energy strictly grows, area strictly grows
//!   (the SAR cap-DAC) and SNR_T strictly grows (delay is
//!   non-decreasing), so within a family only the accuracy-improving
//!   prefix survives — a B_ADC choice whose SNR_T does not improve on a
//!   smaller one is dominated by it on all four objectives;
//! * every family is bounded by a cheap corner (energy/delay/area at
//!   the smallest grid B_ADC, per-bank SQNR_qiy as a strict SNR_T upper
//!   bound, none of which need the noise decomposition): a family whose
//!   corner is dominated by an already-kept point contains no frontier
//!   point and is skipped without evaluating its noise.
//!
//! The pruning order (families ascending by energy lower bound) only
//! affects how much is skipped, never the result: a final exact
//! dominance pass runs over the surviving pool, so the frontier is
//! invariant under axis permutations and shard counts (tested in
//! `rust/tests/opt_pareto.rs`). Banked families (`Domain::banks`) flow
//! through unchanged — their bounds come from the `arch::Banked` closed
//! forms, so the search stays exact.

use super::domain::{DesignPoint, Domain, Family, FamilyBounds, FamilyEval};
use crate::quant::SignalStats;

/// An extracted frontier plus search statistics.
#[derive(Debug, Default)]
pub struct Frontier {
    /// Non-dominated points, sorted by (energy asc, delay asc, SNR_T
    /// desc, area asc, canonical key).
    pub points: Vec<DesignPoint>,
    /// Families in the search domain.
    pub families: usize,
    /// Families skipped by the corner bound (noise never evaluated).
    pub families_pruned: usize,
    /// Candidates actually costed.
    pub points_evaluated: usize,
    /// Candidates in the full domain (families x B_ADC grid).
    pub points_total: usize,
}

/// Extract the Pareto frontier of a (normalized) domain. `shards > 1`
/// splits the family list round-robin across that many worker threads;
/// the merged result is identical to a single-shard run.
pub fn frontier(domain: &Domain, shards: usize, w: &SignalStats, x: &SignalStats) -> Frontier {
    frontier_of_families(&domain.families(), &domain.b_adcs, shards, w, x)
}

/// Frontier over an explicit family list (the lower-level entry point:
/// `figures::fig13` drives per-node scans through this).
pub fn frontier_of_families(
    families: &[Family],
    b_adcs: &[u32],
    shards: usize,
    w: &SignalStats,
    x: &SignalStats,
) -> Frontier {
    // The pruning invariants below need the B_ADC axis ascending and
    // duplicate-free (Domain::normalized guarantees it, direct callers
    // may not): canonicalize locally rather than trusting the caller.
    let mut b_adcs = b_adcs.to_vec();
    b_adcs.sort_unstable();
    b_adcs.dedup();
    let b_adcs = b_adcs.as_slice();

    let mut out = Frontier {
        families: families.len(),
        points_total: families.len() * b_adcs.len(),
        ..Frontier::default()
    };
    if families.is_empty() || b_adcs.is_empty() {
        return out;
    }

    // Bound every family cheaply, then order by ascending energy lower
    // bound so likely dominators are pooled before the families they
    // prune (ties broken canonically for determinism).
    let bounded: Vec<(Family, FamilyBounds)> = {
        let _span = crate::obs::trace::span_with("frontier_bound", "pareto", || {
            format!("{} families", families.len())
        });
        let mut bounded: Vec<(Family, FamilyBounds)> = families
            .iter()
            .map(|f| {
                let b = f.bounds(b_adcs[0], w, x);
                (f.clone(), b)
            })
            .collect();
        bounded.sort_by(|(fa, ba), (fb, bb)| {
            ba.energy_lb_j
                .total_cmp(&bb.energy_lb_j)
                .then_with(|| fa.key().cmp(&fb.key()))
        });
        bounded
    };

    let shards = shards.max(1).min(bounded.len());
    let mut pool: Vec<DesignPoint> = Vec::new();
    {
        let _span = crate::obs::trace::span_with("frontier_extract", "pareto", || {
            format!("{shards} shards")
        });
        if shards <= 1 {
            let (p, evaluated, pruned) = extract_pool(&bounded, 0, 1, b_adcs, w, x);
            pool = p;
            out.points_evaluated = evaluated;
            out.families_pruned = pruned;
        } else {
            let results = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..shards)
                    .map(|i| {
                        let bounded = &bounded;
                        scope.spawn(move || extract_pool(bounded, i, shards, b_adcs, w, x))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("frontier shard thread panicked"))
                    .collect::<Vec<_>>()
            });
            for (p, evaluated, pruned) in results {
                pool.extend(p);
                out.points_evaluated += evaluated;
                out.families_pruned += pruned;
            }
        }
    }

    out.points = prune(pool);
    out
}

/// Evaluate one round-robin shard of the bounded family list into a
/// candidate pool (within-family and corner pruning applied); returns
/// (pool, points evaluated, families corner-pruned).
fn extract_pool(
    bounded: &[(Family, FamilyBounds)],
    offset: usize,
    stride: usize,
    b_adcs: &[u32],
    w: &SignalStats,
    x: &SignalStats,
) -> (Vec<DesignPoint>, usize, usize) {
    let mut pool: Vec<DesignPoint> = Vec::new();
    let mut evaluated = 0usize;
    let mut pruned = 0usize;
    for (family, bounds) in bounded.iter().skip(offset).step_by(stride) {
        // corner bound: any kept point at least as good as the family's
        // best corner dominates the whole family (SNR_T < snr_ub is
        // strict, so the domination is strict).
        let dominated = pool.iter().any(|p| {
            p.snr_t_db >= bounds.snr_ub_db
                && p.energy_j <= bounds.energy_lb_j
                && p.delay_s <= bounds.delay_lb_s
                && p.area_mm2 <= bounds.area_lb_mm2
        });
        if dominated {
            pruned += 1;
            continue;
        }
        let eval = FamilyEval::new(family.clone(), w, x);
        let mut best_snr = f64::NEG_INFINITY;
        for &b in b_adcs {
            let p = eval.design_point(b, w, x);
            evaluated += 1;
            // monotone within-family prune: energy and area strictly
            // grow with B_ADC, so a non-improving SNR_T is dominated by
            // the previous kept member on all four objectives.
            if p.snr_t_db > best_snr {
                best_snr = p.snr_t_db;
                pool.push(p);
            }
        }
    }
    (pool, evaluated, pruned)
}

/// Exact dominance filter: sort so that every potential dominator
/// precedes what it dominates (area joins the chain after SNR_T, so
/// ties through the first three metrics are decided by the smaller
/// area — the direction dominance requires), then keep the
/// non-dominated prefix survivors. Order-independent result.
pub fn prune(mut pool: Vec<DesignPoint>) -> Vec<DesignPoint> {
    let _span = crate::obs::trace::span_with("frontier_prune", "pareto", || {
        format!("{} candidates", pool.len())
    });
    pool.sort_by(|a, b| {
        a.energy_j
            .total_cmp(&b.energy_j)
            .then_with(|| a.delay_s.total_cmp(&b.delay_s))
            .then_with(|| b.snr_t_db.total_cmp(&a.snr_t_db))
            .then_with(|| a.area_mm2.total_cmp(&b.area_mm2))
            .then_with(|| a.key().cmp(&b.key()))
    });
    let mut kept: Vec<DesignPoint> = Vec::new();
    for p in pool {
        if !kept.iter().any(|k| k.dominates(&p)) {
            kept.push(p);
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::uniform_stats;
    use crate::opt::domain::ArchChoice;
    use crate::tech::TechNode;

    fn domain() -> Domain {
        Domain {
            archs: vec![ArchChoice::Qs, ArchChoice::Qr],
            nodes: vec![TechNode::n65()],
            vwls: vec![0.6, 0.7, 0.8],
            cos: vec![1.0, 3.0],
            ns: vec![64, 128, 256],
            bxs: vec![4, 6],
            bws: vec![6],
            b_adcs: vec![3, 4, 5, 6, 7, 8],
            // banked families participate in every frontier property
            banks: vec![1, 2],
        }
        .normalized()
        .unwrap()
    }

    #[test]
    fn frontier_matches_brute_force() {
        let (w, x) = uniform_stats();
        let d = domain();
        let fr = frontier(&d, 1, &w, &x);
        // reference: full enumeration + quadratic dominance filter
        let all = d.all_points(&w, &x);
        let mut reference: Vec<&DesignPoint> = all
            .iter()
            .filter(|p| !all.iter().any(|q| q.dominates(p)))
            .collect();
        reference.sort_by_key(|p| p.key());
        let mut got: Vec<&DesignPoint> = fr.points.iter().collect();
        got.sort_by_key(|p| p.key());
        assert_eq!(got.len(), reference.len(), "frontier size");
        for (g, r) in got.iter().zip(&reference) {
            assert_eq!(g.key(), r.key());
            assert_eq!(g.energy_j.to_bits(), r.energy_j.to_bits());
            assert_eq!(g.snr_t_db.to_bits(), r.snr_t_db.to_bits());
            assert_eq!(g.delay_s.to_bits(), r.delay_s.to_bits());
            assert_eq!(g.area_mm2.to_bits(), r.area_mm2.to_bits());
        }
        assert_eq!(fr.points_total, all.len());
        assert!(fr.points_evaluated <= fr.points_total);
    }

    #[test]
    fn no_frontier_point_is_dominated_and_order_is_canonical() {
        let (w, x) = uniform_stats();
        let fr = frontier(&domain(), 1, &w, &x);
        assert!(!fr.points.is_empty());
        for a in &fr.points {
            for b in &fr.points {
                assert!(!a.dominates(b), "{} dominates {}", a.label(), b.label());
            }
        }
        for pair in fr.points.windows(2) {
            assert!(pair[0].energy_j <= pair[1].energy_j, "ascending energy");
        }
    }

    #[test]
    fn sharded_extraction_is_identical() {
        let (w, x) = uniform_stats();
        let d = domain();
        let one = frontier(&d, 1, &w, &x);
        for shards in [2, 3, 4, 7] {
            let many = frontier(&d, shards, &w, &x);
            assert_eq!(one.points.len(), many.points.len(), "{shards} shards");
            for (a, b) in one.points.iter().zip(&many.points) {
                assert_eq!(a.key(), b.key());
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
                assert_eq!(a.snr_t_db.to_bits(), b.snr_t_db.to_bits());
            }
        }
    }

    #[test]
    fn unsorted_b_adc_axis_is_canonicalized() {
        let (w, x) = uniform_stats();
        let d = domain();
        let fams = d.families();
        let sorted = frontier_of_families(&fams, &[3, 4, 5, 6, 7, 8], 1, &w, &x);
        let shuffled = frontier_of_families(&fams, &[8, 4, 6, 3, 7, 5, 4], 1, &w, &x);
        assert_eq!(sorted.points.len(), shuffled.points.len());
        for (a, b) in sorted.points.iter().zip(&shuffled.points) {
            assert_eq!(a.key(), b.key());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
    }

    #[test]
    fn empty_domain_inputs_yield_empty_frontier() {
        let (w, x) = uniform_stats();
        let fr = frontier_of_families(&[], &[4, 5], 4, &w, &x);
        assert!(fr.points.is_empty());
        assert_eq!(fr.families, 0);
    }
}
