//! Search domains for the design-space optimizer: axis sets, family
//! enumeration, and closed-form evaluation of candidate design points.
//!
//! A [`Domain`] is a *set* of candidate designs — the cartesian product
//! of its axes, with architecture-irrelevant knobs dropped (QS-Arch
//! ignores `C_o`, QR-Arch ignores `V_WL`), so the same domain written
//! with its axis values in any order describes the same design set. A
//! [`Family`] is one analog configuration (everything except B_ADC);
//! the noise decomposition is B_ADC-independent, so a family is the
//! unit of expensive evaluation and the B_ADC axis is costed from one
//! [`FamilyEval`].

use anyhow::{bail, ensure, Result};

use crate::arch::{AdcCriterion, Banked, CmArch, ImcArch, OpPoint, QrArch, QsArch};
use crate::compute::{qr::QrModel, qs::QsModel};
use crate::mc::ArchKind;
use crate::quant::criteria::snr_t_with_mpc_adc_db;
use crate::quant::SignalStats;
use crate::tech::TechNode;

/// Architecture selector for the design-space explorer.
///
/// Deliberately distinct from `mc::ArchKind`: this is the *search-axis*
/// identity (CLI short names, total order for canonical domain
/// enumeration, knob semantics), while `ArchKind` is the simulator
/// dispatch tag with artifact-naming semantics. [`ArchChoice::kind`] is
/// the one bridge — an architecture added to the models must extend
/// both enums and that mapping (the compiler's exhaustive matches flag
/// every site).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ArchChoice {
    Qs,
    Qr,
    Cm,
}

impl ArchChoice {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "qs" => ArchChoice::Qs,
            "qr" => ArchChoice::Qr,
            "cm" => ArchChoice::Cm,
            other => bail!("unknown arch '{other}' (qs, qr or cm)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ArchChoice::Qs => "qs",
            ArchChoice::Qr => "qr",
            ArchChoice::Cm => "cm",
        }
    }

    /// The simulator kind for Monte-Carlo validation of a design point.
    pub fn kind(self) -> ArchKind {
        match self {
            ArchChoice::Qs => ArchKind::Qs,
            ArchChoice::Qr => ArchKind::Qr,
            ArchChoice::Cm => ArchKind::Cm,
        }
    }
}

/// The search domain of one design-space query. Construct with a struct
/// literal and call [`Domain::normalized`] before use: axes are sorted
/// and deduplicated (a domain is a set), and the values validated.
#[derive(Clone, Debug, Default)]
pub struct Domain {
    pub archs: Vec<ArchChoice>,
    pub nodes: Vec<TechNode>,
    /// QS word-line voltages [V] (QS-Arch and CM knob).
    pub vwls: Vec<f64>,
    /// QR unit capacitances [fF] (QR-Arch and CM knob).
    pub cos: Vec<f64>,
    pub ns: Vec<usize>,
    pub bxs: Vec<u32>,
    pub bws: Vec<u32>,
    pub b_adcs: Vec<u32>,
    /// Bank counts (Sec. VI): each family's DP is split across `banks`
    /// arrays of ceil(N/banks) rows (`arch::Banked`). An empty axis
    /// normalizes to the single-bank `[1]`, so pre-banking domain
    /// literals keep their meaning.
    pub banks: Vec<usize>,
}

impl Domain {
    /// Sort + dedup every axis and validate the values. Returns the
    /// canonical form of the domain; every `opt` entry point expects it.
    pub fn normalized(mut self) -> Result<Domain> {
        self.archs.sort();
        self.archs.dedup();
        self.nodes.sort_by_key(|n| n.node_nm);
        self.nodes.dedup_by_key(|n| n.node_nm);
        for axis in [&mut self.vwls, &mut self.cos] {
            axis.sort_by(f64::total_cmp);
            axis.dedup();
        }
        self.ns.sort_unstable();
        self.ns.dedup();
        for axis in [&mut self.bxs, &mut self.bws, &mut self.b_adcs] {
            axis.sort_unstable();
            axis.dedup();
        }
        if self.banks.is_empty() {
            self.banks.push(1);
        }
        self.banks.sort_unstable();
        self.banks.dedup();
        ensure!(!self.archs.is_empty(), "domain needs at least one arch");
        ensure!(!self.nodes.is_empty(), "domain needs at least one node");
        ensure!(!self.ns.is_empty(), "domain needs an N axis");
        ensure!(!self.bxs.is_empty(), "domain needs a Bx axis");
        ensure!(!self.bws.is_empty(), "domain needs a Bw axis");
        ensure!(!self.b_adcs.is_empty(), "domain needs a B_ADC axis");
        let needs_vwl = self.archs.iter().any(|a| *a != ArchChoice::Qr);
        let needs_co = self.archs.iter().any(|a| *a != ArchChoice::Qs);
        ensure!(!needs_vwl || !self.vwls.is_empty(), "domain needs a V_WL axis");
        ensure!(!needs_co || !self.cos.is_empty(), "domain needs a C_o axis");
        for node in &self.nodes {
            for &v in &self.vwls {
                ensure!(
                    !needs_vwl || v > node.v_t,
                    "V_WL {v} V does not exceed V_t {} V at {} nm",
                    node.v_t,
                    node.node_nm
                );
                ensure!(
                    !needs_vwl || v <= node.v_dd,
                    "V_WL {v} V exceeds V_dd {} V at {} nm",
                    node.v_dd,
                    node.node_nm
                );
            }
        }
        for &c in &self.cos {
            ensure!(!needs_co || c > 0.0, "C_o must be positive, got {c} fF");
        }
        for &n in &self.ns {
            ensure!(n >= 1, "N must be >= 1");
        }
        for &b in self.bxs.iter().chain(&self.bws).chain(&self.b_adcs) {
            ensure!((1..=30).contains(&b), "precision {b} out of range 1..=30");
        }
        for &k in &self.banks {
            ensure!(k >= 1, "bank count must be >= 1, got {k}");
            ensure!(
                k <= *self.ns.iter().max().expect("ns checked non-empty"),
                "bank count {k} exceeds every N in the domain"
            );
        }
        Ok(self)
    }

    /// All families of the domain (every analog configuration, B_ADC
    /// excluded), in canonical order. Architecture-irrelevant knobs are
    /// dropped: QS families span `vwls` only, QR families `cos` only, CM
    /// families the full `vwls x cos` product. Bank counts exceeding a
    /// family's own N are dropped too — splitting an N-row DP into more
    /// than N banks describes a different, larger machine than the
    /// family's label, so such combinations are not members of the
    /// design set (normalization already guarantees every bank count
    /// fits at least one N).
    pub fn families(&self) -> Vec<Family> {
        let mut out = Vec::new();
        for &arch in &self.archs {
            for node in &self.nodes {
                let knobs: Vec<(Option<f64>, Option<f64>)> = match arch {
                    ArchChoice::Qs => self.vwls.iter().map(|&v| (Some(v), None)).collect(),
                    ArchChoice::Qr => self.cos.iter().map(|&c| (None, Some(c))).collect(),
                    ArchChoice::Cm => self
                        .vwls
                        .iter()
                        .flat_map(|&v| self.cos.iter().map(move |&c| (Some(v), Some(c))))
                        .collect(),
                };
                for (v_wl, c_ff) in knobs {
                    for &n in &self.ns {
                        for &bx in &self.bxs {
                            for &bw in &self.bws {
                                for &banks in &self.banks {
                                    if banks > n {
                                        continue;
                                    }
                                    out.push(Family {
                                        arch,
                                        node: *node,
                                        v_wl,
                                        c_ff,
                                        n,
                                        bx,
                                        bw,
                                        banks,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Total candidate count: families x B_ADC values.
    pub fn point_count(&self) -> usize {
        self.families().len() * self.b_adcs.len()
    }

    /// Brute-force evaluation of every candidate in the domain (no
    /// pruning) — the reference the frontier extractor is tested against,
    /// and the full-curve input of the crossover report.
    pub fn all_points(&self, w: &SignalStats, x: &SignalStats) -> Vec<DesignPoint> {
        let mut out = Vec::with_capacity(self.point_count());
        for family in self.families() {
            let eval = FamilyEval::new(family, w, x);
            for &b in &self.b_adcs {
                out.push(eval.design_point(b, w, x));
            }
        }
        out
    }

    /// The domain restricted to one architecture (axes unchanged).
    pub fn restricted_to(&self, arch: ArchChoice) -> Domain {
        Domain {
            archs: vec![arch],
            ..self.clone()
        }
    }
}

/// Canonical family ordering key: architecture, node, knob bits, shape,
/// bank count.
pub type FamilyKey = (u8, u32, u64, u64, usize, u32, u32, usize);

/// Canonical candidate ordering key: family key, then B_ADC.
pub type PointKey = (FamilyKey, u32);

/// One analog configuration: everything except the B_ADC axis. The
/// knob options follow the architecture: `v_wl` is `Some` for QS/CM,
/// `c_ff` for QR/CM. `banks > 1` makes the family the `arch::Banked`
/// variant of its architecture.
#[derive(Clone, Debug)]
pub struct Family {
    pub arch: ArchChoice,
    pub node: TechNode,
    pub v_wl: Option<f64>,
    pub c_ff: Option<f64>,
    pub n: usize,
    pub bx: u32,
    pub bw: u32,
    pub banks: usize,
}

impl Family {
    /// Instantiate the closed-form architecture model; `banks > 1`
    /// wraps it in [`Banked`] (a single-bank family stays the bare
    /// architecture — `Banked(·, 1)` is bit-identical anyway, this just
    /// skips the indirection).
    pub fn build(&self) -> Box<dyn ImcArch> {
        let bare: Box<dyn ImcArch> = match self.arch {
            ArchChoice::Qs => Box::new(QsArch::new(QsModel::new(
                self.node,
                self.v_wl.expect("QS family needs v_wl"),
            ))),
            ArchChoice::Qr => Box::new(QrArch::new(QrModel::new(
                self.node,
                self.c_ff.expect("QR family needs c_ff"),
            ))),
            ArchChoice::Cm => Box::new(CmArch::new(
                QsModel::new(self.node, self.v_wl.expect("CM family needs v_wl")),
                QrModel::new(self.node, self.c_ff.expect("CM family needs c_ff")),
            )),
        };
        if self.banks > 1 {
            Box::new(Banked::new(bare, self.banks))
        } else {
            bare
        }
    }

    /// The family's operating point at an ADC precision (bank count
    /// included — `Banked` divides N internally).
    pub fn op(&self, b_adc: u32) -> OpPoint {
        OpPoint::new(self.n, self.bx, self.bw, b_adc).with_banks(self.banks)
    }

    /// Cheap bounds over the whole family, computable *without* the
    /// noise decomposition (no `binomial_clip_moment`): energy, delay
    /// and area are monotone non-decreasing in B_ADC, so their values at
    /// the smallest grid B_ADC bound every family member from below, and
    /// SNR_T < SNR_A < SQNR_qiy bounds accuracy from above. For a banked
    /// family the SNR bound uses the *per-bank* dimension — the banked
    /// ratio equals the per-bank one (signal and noise both scale by
    /// `banks`), so the bound stays exact and the branch-and-bound of
    /// `opt::pareto` / `opt::optimize` never prunes a frontier point.
    pub fn bounds(&self, b_adc_min: u32, w: &SignalStats, x: &SignalStats) -> FamilyBounds {
        let arch = self.build();
        let op = self.op(b_adc_min);
        FamilyBounds {
            energy_lb_j: arch.energy(&op, AdcCriterion::Fixed(b_adc_min), w, x).total(),
            delay_lb_s: arch.delay(&op),
            area_lb_mm2: arch.area(&op).total_mm2(),
            snr_ub_db: crate::quant::sqnr_qiy_db(
                self.n.div_ceil(self.banks),
                self.bw,
                self.bx,
                w,
                x,
            ),
        }
    }

    /// Canonical ordering key (total order over families): architecture,
    /// node, knobs, shape, then bank count. Positive-float knob bits
    /// order like the values themselves.
    pub fn key(&self) -> FamilyKey {
        (
            match self.arch {
                ArchChoice::Qs => 0,
                ArchChoice::Qr => 1,
                ArchChoice::Cm => 2,
            },
            self.node.node_nm,
            self.v_wl.unwrap_or(0.0).to_bits(),
            self.c_ff.unwrap_or(0.0).to_bits(),
            self.n,
            self.bx,
            self.bw,
            self.banks,
        )
    }

    /// Sweep-style label fragment, e.g. `arch=qs/node=65/vwl=0.7/n=128/bx=6/bw=6`
    /// (a `/banks=K` suffix appears only for banked families, keeping
    /// single-bank labels identical to the pre-banking scheme).
    pub fn label(&self) -> String {
        use std::fmt::Write as _;
        let mut s = format!("arch={}/node={}", self.arch.name(), self.node.node_nm);
        if let Some(v) = self.v_wl {
            let _ = write!(s, "/vwl={v}");
        }
        if let Some(c) = self.c_ff {
            let _ = write!(s, "/co={c}");
        }
        let _ = write!(s, "/n={}/bx={}/bw={}", self.n, self.bx, self.bw);
        if self.banks > 1 {
            let _ = write!(s, "/banks={}", self.banks);
        }
        s
    }
}

/// Family-level bounds used by the branch-and-bound search.
#[derive(Clone, Copy, Debug)]
pub struct FamilyBounds {
    /// Lower bound on every member's energy/DP [J].
    pub energy_lb_j: f64,
    /// Lower bound on every member's delay/DP [s].
    pub delay_lb_s: f64,
    /// Lower bound on every member's silicon area [mm²] (the ADC block
    /// grows strictly with B_ADC; everything else is B_ADC-flat).
    pub area_lb_mm2: f64,
    /// Strict upper bound on every member's SNR_T [dB] (the input
    /// quantization limit SQNR_qiy at the per-bank dimension).
    pub snr_ub_db: f64,
}

/// A family with its (expensive, B_ADC-independent) noise decomposition
/// evaluated once; design points for every B_ADC choice are then cheap.
pub struct FamilyEval {
    pub family: Family,
    arch: Box<dyn ImcArch>,
    /// Closed-form pre-ADC SNR_A [dB] (eq. 10).
    pub snr_a_total_db: f64,
    /// MPC ADC-precision assignment (Table III row B_ADC).
    pub b_adc_mpc: u32,
}

impl FamilyEval {
    pub fn new(family: Family, w: &SignalStats, x: &SignalStats) -> Self {
        let arch = family.build();
        let op = family.op(1); // noise and MPC assignment ignore B_ADC
        let snr_a_total_db = arch.noise(&op, w, x).snr_a_total_db();
        let b_adc_mpc = arch.b_adc_min(&op, w, x);
        Self {
            family,
            arch,
            snr_a_total_db,
            b_adc_mpc,
        }
    }

    /// Cost one member of the family: closed-form SNR_T (eq. 11 + 14),
    /// energy under `AdcCriterion::Fixed(b_adc)`, delay and silicon
    /// area at `b_adc`.
    pub fn design_point(&self, b_adc: u32, w: &SignalStats, x: &SignalStats) -> DesignPoint {
        let op = self.family.op(b_adc);
        DesignPoint {
            family: self.family.clone(),
            b_adc,
            b_adc_mpc: self.b_adc_mpc,
            snr_a_total_db: self.snr_a_total_db,
            snr_t_db: snr_t_with_mpc_adc_db(self.snr_a_total_db, b_adc),
            energy_j: self
                .arch
                .energy(&op, AdcCriterion::Fixed(b_adc), w, x)
                .total(),
            delay_s: self.arch.delay(&op),
            area_mm2: self.arch.area(&op).total_mm2(),
        }
    }
}

/// One fully-costed candidate design.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub family: Family,
    pub b_adc: u32,
    /// What MPC would assign for this family (eq. 15 / Table III).
    pub b_adc_mpc: u32,
    pub snr_a_total_db: f64,
    pub snr_t_db: f64,
    pub energy_j: f64,
    pub delay_s: f64,
    /// Per-DP silicon area [mm²] (Table III geometry; `crate::area`).
    pub area_mm2: f64,
}

impl DesignPoint {
    /// Pareto dominance over the four objectives (max SNR_T, min
    /// energy, min delay, min area): no worse on every objective and
    /// strictly better on at least one.
    pub fn dominates(&self, other: &DesignPoint) -> bool {
        self.snr_t_db >= other.snr_t_db
            && self.energy_j <= other.energy_j
            && self.delay_s <= other.delay_s
            && self.area_mm2 <= other.area_mm2
            && (self.snr_t_db > other.snr_t_db
                || self.energy_j < other.energy_j
                || self.delay_s < other.delay_s
                || self.area_mm2 < other.area_mm2)
    }

    /// Canonical total order over candidates (family key, then B_ADC).
    pub fn key(&self) -> PointKey {
        (self.family.key(), self.b_adc)
    }

    /// Sweep-style label, e.g. `arch=qs/node=65/vwl=0.7/n=128/bx=6/bw=6/badc=7`.
    pub fn label(&self) -> String {
        format!("{}/badc={}", self.family.label(), self.b_adc)
    }

    pub fn delay_ns(&self) -> f64 {
        self.delay_s * 1e9
    }

    /// Monte-Carlo validation job for this design (`pareto --validate`):
    /// built through `Family::build`, the same constructor `imclim
    /// sweep` uses, so both commands share engine cache records by
    /// construction (banked families yield the `arch::Banked` parameter
    /// vector, which the native simulator runs as a banked ensemble and
    /// the PJRT backend rejects). `trials` is the ensemble size for
    /// fixed runs, or the trial *cap* when an adaptive `precision`
    /// half-width (dB) is requested.
    pub fn validation_point(
        &self,
        w: &SignalStats,
        x: &SignalStats,
        trials: usize,
        seed: u64,
        precision: Option<f64>,
    ) -> crate::coordinator::SweepPoint {
        let arch = self.family.build();
        let op = self.family.op(self.b_adc);
        let mut point = crate::coordinator::SweepPoint::new(
            format!("pareto/{}", self.label()),
            self.family.arch.kind(),
            arch.pjrt_params(&op, w, x),
        )
        .with_trials(trials)
        .with_seed(seed);
        point.precision = precision;
        point
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::uniform_stats;

    fn small_domain() -> Domain {
        Domain {
            archs: vec![ArchChoice::Qr, ArchChoice::Qs],
            nodes: vec![TechNode::n65()],
            vwls: vec![0.8, 0.6],
            cos: vec![3.0],
            ns: vec![128, 64],
            bxs: vec![6],
            bws: vec![6],
            b_adcs: vec![8, 4, 6],
            banks: vec![1],
        }
        .normalized()
        .unwrap()
    }

    #[test]
    fn normalization_sorts_dedups_and_validates() {
        let d = small_domain();
        assert_eq!(d.archs, vec![ArchChoice::Qs, ArchChoice::Qr]);
        assert_eq!(d.vwls, vec![0.6, 0.8]);
        assert_eq!(d.ns, vec![64, 128]);
        assert_eq!(d.b_adcs, vec![4, 6, 8]);
        // QS: 2 vwl x 2 n; QR: 1 co x 2 n
        assert_eq!(d.families().len(), 6);
        assert_eq!(d.point_count(), 18);
        // an empty banks axis normalizes to single-bank
        let defaulted = Domain {
            banks: vec![],
            ..small_domain()
        }
        .normalized()
        .unwrap();
        assert_eq!(defaulted.banks, vec![1]);
        assert_eq!(defaulted.point_count(), 18);
        // a banks axis multiplies the family count
        let banked = Domain {
            banks: vec![4, 1, 2],
            ..small_domain()
        }
        .normalized()
        .unwrap();
        assert_eq!(banked.banks, vec![1, 2, 4]);
        assert_eq!(banked.families().len(), 18);
        // a bank count larger than a family's own N is not a member of
        // that family's column (it would describe a bigger machine than
        // the label): only the N values that fit keep it
        let oversplit = Domain {
            banks: vec![1, 96],
            ..small_domain()
        }
        .normalized()
        .unwrap();
        // banks=96 exists only for the n=128 families: 6 + 3
        assert_eq!(oversplit.families().len(), 9);
        assert!(oversplit
            .families()
            .iter()
            .all(|f| f.banks <= f.n), "no family is split past its rows");
        // bank counts beyond every N are rejected
        assert!(Domain {
            banks: vec![256],
            ..small_domain()
        }
        .normalized()
        .is_err());
        // V_WL below V_t is rejected
        let bad = Domain {
            vwls: vec![0.3],
            ..small_domain()
        };
        assert!(bad.normalized().is_err());
        // ... and so is V_WL above the node's supply rail
        let bad_hi = Domain {
            nodes: vec![TechNode::n22()],
            vwls: vec![0.9],
            ..small_domain()
        };
        assert!(bad_hi.normalized().is_err());
        // a QR-only domain needs no V_WL axis at all
        let qr_only = Domain {
            archs: vec![ArchChoice::Qr],
            vwls: vec![],
            ..small_domain()
        };
        assert!(qr_only.normalized().is_ok());
    }

    #[test]
    fn family_eval_matches_direct_closed_forms() {
        let (w, x) = uniform_stats();
        let fam = Family {
            arch: ArchChoice::Qs,
            node: TechNode::n65(),
            v_wl: Some(0.8),
            c_ff: None,
            n: 128,
            bx: 6,
            bw: 6,
            banks: 1,
        };
        let eval = FamilyEval::new(fam.clone(), &w, &x);
        let arch = fam.build();
        let op = OpPoint::new(128, 6, 6, 8);
        let nb = arch.noise(&op, &w, &x);
        assert_eq!(eval.snr_a_total_db, nb.snr_a_total_db());
        assert_eq!(eval.b_adc_mpc, arch.b_adc_min(&op, &w, &x));
        let p = eval.design_point(8, &w, &x);
        assert_eq!(p.energy_j, arch.energy(&op, AdcCriterion::Fixed(8), &w, &x).total());
        assert_eq!(p.delay_s, arch.delay(&op));
        assert_eq!(p.area_mm2, arch.area(&op).total_mm2());
        assert!(p.snr_t_db < p.snr_a_total_db);
        assert!(p.label().contains("arch=qs/node=65/vwl=0.8/n=128"));
        assert!(!p.label().contains("banks"), "single-bank label unchanged");
        // a banked sibling costs the Banked closed forms and labels itself
        let banked = Family { banks: 4, ..fam };
        let beval = FamilyEval::new(banked.clone(), &w, &x);
        let barch = banked.build();
        let bop = OpPoint::new(128, 6, 6, 8).with_banks(4);
        assert_eq!(beval.snr_a_total_db, barch.noise(&bop, &w, &x).snr_a_total_db());
        let bp = beval.design_point(8, &w, &x);
        assert_eq!(bp.area_mm2, barch.area(&bop).total_mm2());
        assert!(bp.label().ends_with("/banks=4/badc=8"), "{}", bp.label());
        assert_ne!(banked.key(), fam.key(), "bank count is part of the key");
    }

    #[test]
    fn bounds_hold_over_the_b_adc_axis() {
        let (w, x) = uniform_stats();
        // include banked families: the bounds must stay exact for them
        let d = Domain {
            banks: vec![1, 2, 4],
            ..small_domain()
        }
        .normalized()
        .unwrap();
        for fam in d.families() {
            let bounds = fam.bounds(d.b_adcs[0], &w, &x);
            let eval = FamilyEval::new(fam, &w, &x);
            let mut prev_e = f64::MIN;
            let mut prev_d = f64::MIN;
            let mut prev_s = f64::MIN;
            let mut prev_a = f64::MIN;
            for &b in &d.b_adcs {
                let p = eval.design_point(b, &w, &x);
                assert!(p.energy_j >= bounds.energy_lb_j);
                assert!(p.delay_s >= bounds.delay_lb_s);
                assert!(p.area_mm2 >= bounds.area_lb_mm2);
                assert!(p.snr_t_db < bounds.snr_ub_db, "SNR_T below SQNR_qiy");
                // monotonicity the branch-and-bound relies on
                assert!(p.energy_j > prev_e, "energy strictly grows with B_ADC");
                assert!(p.delay_s >= prev_d, "delay non-decreasing with B_ADC");
                assert!(p.snr_t_db > prev_s, "SNR_T strictly grows with B_ADC");
                assert!(p.area_mm2 > prev_a, "area strictly grows with B_ADC");
                prev_e = p.energy_j;
                prev_d = p.delay_s;
                prev_s = p.snr_t_db;
                prev_a = p.area_mm2;
            }
        }
    }

    #[test]
    fn dominance_is_irreflexive_and_directional() {
        let (w, x) = uniform_stats();
        let d = small_domain();
        let pts = d.all_points(&w, &x);
        assert_eq!(pts.len(), d.point_count());
        for p in &pts {
            assert!(!p.dominates(p), "no self-domination");
        }
        // within one family, no B_ADC choice dominates another (energy
        // and SNR_T move together)
        for a in &pts {
            for b in &pts {
                if a.family.key() == b.family.key() && a.b_adc != b.b_adc {
                    assert!(!a.dominates(b), "{} vs {}", a.label(), b.label());
                }
            }
        }
    }
}
