//! Design-space optimizer: multi-objective exploration of the
//! closed-form noise/energy/delay models.
//!
//! The paper's headline results are optima over the design space, not
//! individual sweep points: the maximum achievable SNR_a under
//! energy/area/swing constraints, the minimal ADC precision via MPC,
//! and the QS-vs-QR preference flip of conclusion 3. This subsystem
//! answers those query shapes directly:
//!
//! * [`domain`] — search domains ([`Domain`]), family enumeration
//!   ([`Family`], including banked variants via the `banks` axis) and
//!   candidate costing ([`FamilyEval`], [`DesignPoint`]): SNR_T from
//!   eqs. (11)+(14) with the B_ADC axis as a free dimension over the
//!   MPC conversion range (`AdcCriterion::Fixed`), energy/delay from
//!   Table III, silicon area from the Table III geometry
//!   (`crate::area`);
//! * [`pareto`] — the dominance-pruned four-objective (max SNR_T, min
//!   energy, min delay, min area) frontier extractor, branch-and-bound
//!   over family corners instead of brute-force enumeration, shardable
//!   across threads with bit-identical results;
//! * [`optimize`] — constrained single-objective search (`min energy` /
//!   `min delay` / `max SNR_T` / `min area` subject to
//!   SNR_T/energy/delay/area bounds) whose lexicographic winner
//!   provably lies on the domain frontier, with the MPC assignment
//!   (`b_adc_mpc`) reported alongside every answer;
//! * [`crossover`] — the QS-vs-QR crossover report that machine-checks
//!   conclusion 3 by locating the target SNR where the cheaper
//!   architecture flips.
//!
//! The CLI exposes the subsystem as `imclim pareto` and `imclim
//! optimize` (same grid-string axis syntax as `imclim sweep`); Monte-
//! Carlo validation of frontier points runs through `engine::Engine`,
//! so the content-addressed cache, `--shard i/k` sweeps and `imclim
//! merge` compose unchanged — a cache populated by a sharded sweep over
//! the same axes serves `pareto --validate` without recomputation.

pub mod crossover;
pub mod domain;
pub mod optimize;
pub mod pareto;

pub use crossover::{crossover, CrossoverReport, CrossoverRow};
pub use domain::{ArchChoice, DesignPoint, Domain, Family, FamilyBounds, FamilyEval};
pub use optimize::{optimize, Constraints, Objective, OptReport};
pub use pareto::{frontier, frontier_of_families, Frontier};
