//! Constrained single-objective search over a design domain:
//! `min energy` / `min delay` / `max SNR_T` / `min area`, subject to
//! SNR_T, energy, delay and area bounds, by family-level
//! branch-and-bound.
//!
//! Families are processed in ascending order of their objective bound
//! (energy/delay/area lower bound, or SNR upper bound for `max-snr`);
//! constraint-infeasible families are pruned by the same cheap bounds
//! before their noise decomposition is ever computed, and the scan
//! stops outright once the bound can no longer beat the incumbent —
//! the monotone structure described in `opt::pareto`.
//!
//! The winner is the *lexicographic* optimum (objective first, then the
//! remaining objectives, then the canonical key), which makes every
//! answer a Pareto point of its own domain: a dominating design would
//! also satisfy the constraints (they are all dominance-aligned) and
//! precede it lexicographically. The comparison chains cover all four
//! metrics, so this holds for the four-objective frontier too.

use anyhow::{bail, Result};

use super::domain::{DesignPoint, Domain, Family, FamilyBounds, FamilyEval};
use crate::quant::SignalStats;

/// Optimization objective of `imclim optimize`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    MinEnergy,
    MinDelay,
    MaxSnr,
    MinArea,
}

impl Objective {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "min-energy" => Objective::MinEnergy,
            "min-delay" => Objective::MinDelay,
            "max-snr" | "max-snr-t" => Objective::MaxSnr,
            "min-area" => Objective::MinArea,
            other => bail!(
                "unknown objective '{other}' (min-energy, min-delay, max-snr or min-area)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Objective::MinEnergy => "min-energy",
            Objective::MinDelay => "min-delay",
            Objective::MaxSnr => "max-snr",
            Objective::MinArea => "min-area",
        }
    }

    /// Lexicographic preference: does `a` beat `b` under this objective?
    /// The comparison chain starts with the objective and covers all
    /// four metrics, so the optimum is always Pareto-optimal; the
    /// canonical key breaks exact metric ties deterministically. Area
    /// sits last in the pre-existing chains, so three-objective answers
    /// are unchanged except on exact three-way metric ties.
    pub fn better(self, a: &DesignPoint, b: &DesignPoint) -> bool {
        let ord = match self {
            Objective::MinEnergy => a
                .energy_j
                .total_cmp(&b.energy_j)
                .then_with(|| b.snr_t_db.total_cmp(&a.snr_t_db))
                .then_with(|| a.delay_s.total_cmp(&b.delay_s))
                .then_with(|| a.area_mm2.total_cmp(&b.area_mm2)),
            Objective::MinDelay => a
                .delay_s
                .total_cmp(&b.delay_s)
                .then_with(|| a.energy_j.total_cmp(&b.energy_j))
                .then_with(|| b.snr_t_db.total_cmp(&a.snr_t_db))
                .then_with(|| a.area_mm2.total_cmp(&b.area_mm2)),
            Objective::MaxSnr => b
                .snr_t_db
                .total_cmp(&a.snr_t_db)
                .then_with(|| a.energy_j.total_cmp(&b.energy_j))
                .then_with(|| a.delay_s.total_cmp(&b.delay_s))
                .then_with(|| a.area_mm2.total_cmp(&b.area_mm2)),
            Objective::MinArea => a
                .area_mm2
                .total_cmp(&b.area_mm2)
                .then_with(|| a.energy_j.total_cmp(&b.energy_j))
                .then_with(|| b.snr_t_db.total_cmp(&a.snr_t_db))
                .then_with(|| a.delay_s.total_cmp(&b.delay_s)),
        };
        ord.then_with(|| a.key().cmp(&b.key())).is_lt()
    }
}

/// Dominance-aligned constraint set: a design that dominates a feasible
/// design is itself feasible.
#[derive(Clone, Copy, Debug, Default)]
pub struct Constraints {
    /// SNR_T >= this many dB.
    pub snr_t_min_db: Option<f64>,
    /// Energy/DP <= this many joules.
    pub energy_max_j: Option<f64>,
    /// Delay/DP <= this many seconds.
    pub delay_max_s: Option<f64>,
    /// Silicon area <= this many mm².
    pub area_max_mm2: Option<f64>,
}

impl Constraints {
    pub fn admits(&self, p: &DesignPoint) -> bool {
        self.snr_t_min_db.is_none_or(|v| p.snr_t_db >= v)
            && self.energy_max_j.is_none_or(|v| p.energy_j <= v)
            && self.delay_max_s.is_none_or(|v| p.delay_s <= v)
            && self.area_max_mm2.is_none_or(|v| p.area_mm2 <= v)
    }

    /// Can any member of a family with these bounds be feasible?
    fn family_may_be_feasible(&self, b: &FamilyBounds) -> bool {
        self.snr_t_min_db.is_none_or(|v| b.snr_ub_db > v)
            && self.energy_max_j.is_none_or(|v| b.energy_lb_j <= v)
            && self.delay_max_s.is_none_or(|v| b.delay_lb_s <= v)
            && self.area_max_mm2.is_none_or(|v| b.area_lb_mm2 <= v)
    }
}

/// Outcome of one constrained search.
#[derive(Debug, Default)]
pub struct OptReport {
    /// The optimum, if the constraint set is feasible at all.
    pub best: Option<DesignPoint>,
    pub families: usize,
    /// Families rejected by constraint bounds (no evaluation).
    pub families_pruned: usize,
    /// Families behind the incumbent cut-off (no evaluation).
    pub families_cut: usize,
    pub families_evaluated: usize,
    pub points_evaluated: usize,
}

/// Search a (normalized) domain for the constrained optimum.
pub fn optimize(
    domain: &Domain,
    objective: Objective,
    constraints: &Constraints,
    w: &SignalStats,
    x: &SignalStats,
) -> OptReport {
    let families = domain.families();
    let mut report = OptReport {
        families: families.len(),
        ..OptReport::default()
    };
    if families.is_empty() || domain.b_adcs.is_empty() {
        return report;
    }
    let b_min = domain.b_adcs[0];

    let mut bounded: Vec<(Family, FamilyBounds)> = families
        .into_iter()
        .map(|f| {
            let b = f.bounds(b_min, w, x);
            (f, b)
        })
        .collect();
    // ascending objective bound, canonical tiebreak
    bounded.sort_by(|(fa, ba), (fb, bb)| {
        let ord = match objective {
            Objective::MinEnergy => ba.energy_lb_j.total_cmp(&bb.energy_lb_j),
            Objective::MinDelay => ba.delay_lb_s.total_cmp(&bb.delay_lb_s),
            Objective::MaxSnr => bb.snr_ub_db.total_cmp(&ba.snr_ub_db),
            Objective::MinArea => ba.area_lb_mm2.total_cmp(&bb.area_lb_mm2),
        };
        ord.then_with(|| fa.key().cmp(&fb.key()))
    });

    let mut best: Option<DesignPoint> = None;
    for (i, (family, bounds)) in bounded.iter().enumerate() {
        if let Some(incumbent) = &best {
            // the bound is monotone along the scan: once it cannot beat
            // the incumbent, nothing later can either.
            let cut = match objective {
                Objective::MinEnergy => bounds.energy_lb_j > incumbent.energy_j,
                Objective::MinDelay => bounds.delay_lb_s > incumbent.delay_s,
                // SNR_T < snr_ub strictly, so equality cannot improve
                Objective::MaxSnr => bounds.snr_ub_db <= incumbent.snr_t_db,
                Objective::MinArea => bounds.area_lb_mm2 > incumbent.area_mm2,
            };
            if cut {
                report.families_cut = bounded.len() - i;
                break;
            }
        }
        if !constraints.family_may_be_feasible(bounds) {
            report.families_pruned += 1;
            continue;
        }
        let eval = FamilyEval::new(family.clone(), w, x);
        report.families_evaluated += 1;
        for &b in &domain.b_adcs {
            let p = eval.design_point(b, w, x);
            report.points_evaluated += 1;
            if !constraints.admits(&p) {
                continue;
            }
            if best.as_ref().is_none_or(|cur| objective.better(&p, cur)) {
                best = Some(p);
            }
        }
    }
    report.best = best;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::uniform_stats;
    use crate::opt::domain::ArchChoice;
    use crate::opt::pareto::frontier;
    use crate::tech::TechNode;

    fn domain() -> Domain {
        Domain {
            archs: vec![ArchChoice::Qs, ArchChoice::Qr, ArchChoice::Cm],
            nodes: vec![TechNode::n65()],
            vwls: vec![0.6, 0.7, 0.8],
            cos: vec![1.0, 3.0],
            ns: vec![64, 128],
            bxs: vec![4, 6],
            bws: vec![4, 6],
            b_adcs: vec![3, 4, 5, 6, 7, 8, 9],
            banks: vec![1, 2],
        }
        .normalized()
        .unwrap()
    }

    /// Brute-force reference optimum by the same lexicographic rule.
    fn reference(
        d: &Domain,
        objective: Objective,
        constraints: &Constraints,
    ) -> Option<DesignPoint> {
        let (w, x) = uniform_stats();
        let mut best: Option<DesignPoint> = None;
        for p in d.all_points(&w, &x) {
            if !constraints.admits(&p) {
                continue;
            }
            if best.as_ref().is_none_or(|cur| objective.better(&p, cur)) {
                best = Some(p);
            }
        }
        best
    }

    #[test]
    fn branch_and_bound_matches_brute_force() {
        let (w, x) = uniform_stats();
        let d = domain();
        let cases = [
            (Objective::MinEnergy, Constraints::default()),
            (
                Objective::MinEnergy,
                Constraints {
                    snr_t_min_db: Some(15.0),
                    ..Constraints::default()
                },
            ),
            (
                Objective::MinDelay,
                Constraints {
                    snr_t_min_db: Some(12.0),
                    energy_max_j: Some(2e-11),
                    ..Constraints::default()
                },
            ),
            (
                Objective::MaxSnr,
                Constraints {
                    energy_max_j: Some(1e-11),
                    delay_max_s: Some(5e-9),
                    ..Constraints::default()
                },
            ),
            (
                Objective::MinArea,
                Constraints {
                    snr_t_min_db: Some(12.0),
                    ..Constraints::default()
                },
            ),
            (
                Objective::MinEnergy,
                Constraints {
                    snr_t_min_db: Some(15.0),
                    area_max_mm2: Some(3e-3),
                    ..Constraints::default()
                },
            ),
        ];
        for (objective, constraints) in cases {
            let got = optimize(&d, objective, &constraints, &w, &x);
            let want = reference(&d, objective, &constraints);
            match (&got.best, &want) {
                (Some(g), Some(r)) => {
                    assert_eq!(g.key(), r.key(), "{objective:?}");
                    assert_eq!(g.energy_j.to_bits(), r.energy_j.to_bits());
                }
                (None, None) => {}
                other => panic!("{objective:?}: {other:?}"),
            }
            assert!(got.families_evaluated <= got.families);
        }
    }

    #[test]
    fn infeasible_constraints_return_none() {
        let (w, x) = uniform_stats();
        let d = domain();
        let got = optimize(
            &d,
            Objective::MinEnergy,
            &Constraints {
                snr_t_min_db: Some(90.0),
                ..Constraints::default()
            },
            &w,
            &x,
        );
        assert!(got.best.is_none());
        assert_eq!(
            got.families_pruned,
            got.families,
            "90 dB exceeds every SQNR_qiy bound: all pruned cheaply"
        );
    }

    #[test]
    fn every_answer_lies_on_the_domain_frontier() {
        let (w, x) = uniform_stats();
        let d = domain();
        let fr = frontier(&d, 1, &w, &x);
        let cases = [
            (Objective::MinEnergy, Some(10.0), None, None),
            (Objective::MinEnergy, Some(18.0), None, None),
            (Objective::MinDelay, Some(15.0), None, None),
            (Objective::MaxSnr, None, Some(2e-11), None),
            (Objective::MaxSnr, None, None, Some(4e-9)),
            (Objective::MinEnergy, None, None, None),
            (Objective::MinArea, None, None, None),
            (Objective::MinArea, Some(14.0), None, None),
        ];
        for (objective, snr, e, dmax) in cases {
            let constraints = Constraints {
                snr_t_min_db: snr,
                energy_max_j: e,
                delay_max_s: dmax,
                area_max_mm2: None,
            };
            let got = optimize(&d, objective, &constraints, &w, &x);
            let Some(best) = got.best else {
                panic!("{objective:?} {constraints:?} infeasible");
            };
            assert!(
                fr.points.iter().any(|p| p.key() == best.key()),
                "{objective:?} answer {} not on the frontier",
                best.label()
            );
        }
    }

    #[test]
    fn incumbent_cut_skips_tail_families() {
        let (w, x) = uniform_stats();
        // unconstrained min-energy on a domain with many families: the
        // scan should stop long before evaluating everything.
        let got = optimize(
            &domain(),
            Objective::MinEnergy,
            &Constraints::default(),
            &w,
            &x,
        );
        assert!(got.best.is_some());
        assert!(got.families_cut > 0, "expected an incumbent cut: {got:?}");
    }
}
