//! QS-vs-QR crossover report (the paper's conclusion 3): locate the
//! compute-SNR target at which the preferred architecture flips from
//! QS-based to QR-based.
//!
//! For each target SNR_T the report solves `min energy s.t. SNR_T >=
//! target` separately over the domain's QS families and its QR
//! families, and marks whichever is cheaper as preferred (ties go to
//! QS, the simpler circuit). The crossover is the first target of the
//! trailing run of QR-preferred rows — above it QR is always preferred
//! (or QS is outright infeasible, its SNR_a ceiling being the other
//! half of conclusion 3); below it QS wins at least once.
//!
//! Reproduction note: with the eq. (26) ADC model the k1 = 100 fJ
//! conversion floor times B_w*B_x conversions dominates QS-Arch energy,
//! so the flip sits in the low-SNR corner and only appears when the
//! domain lets B_x/B_w scale down with the target (the paper's
//! precision-assignment discipline). A domain pinned at B_x = B_w = 6
//! reports no crossover — QR preferred throughout.

use anyhow::{ensure, Result};

use super::domain::{ArchChoice, DesignPoint, Domain};
use super::optimize::Objective;
use crate::quant::SignalStats;

/// One target row of the report.
#[derive(Debug)]
pub struct CrossoverRow {
    pub target_snr_t_db: f64,
    /// Cheapest QS design meeting the target, if any.
    pub qs: Option<DesignPoint>,
    /// Cheapest QR design meeting the target, if any.
    pub qr: Option<DesignPoint>,
    pub preferred: Option<ArchChoice>,
}

#[derive(Debug)]
pub struct CrossoverReport {
    pub rows: Vec<CrossoverRow>,
    /// First target of the trailing QR-preferred run, when the flip
    /// exists (QS preferred somewhere below, QR everywhere at/above).
    pub crossover_snr_t_db: Option<f64>,
    /// Highest feasible target per architecture (dB), `-inf` if none.
    pub qs_max_snr_t_db: f64,
    pub qr_max_snr_t_db: f64,
}

/// Build the crossover report over `targets` (dB, ascending). The
/// domain must contain both the QS and the QR architecture; CM families
/// are ignored (the report compares the paper's two pure compute
/// models).
pub fn crossover(
    domain: &Domain,
    targets: &[f64],
    w: &SignalStats,
    x: &SignalStats,
) -> Result<CrossoverReport> {
    ensure!(
        domain.archs.contains(&ArchChoice::Qs) && domain.archs.contains(&ArchChoice::Qr),
        "crossover needs both qs and qr in the domain"
    );
    ensure!(!targets.is_empty(), "crossover needs a target SNR grid");
    ensure!(
        targets.windows(2).all(|t| t[0] < t[1]),
        "crossover targets must be strictly ascending"
    );

    // Full per-arch curves, evaluated once; every target then scans the
    // curve (min-energy is a suffix query on the SNR axis).
    let qs_points = domain.restricted_to(ArchChoice::Qs).all_points(w, x);
    let qr_points = domain.restricted_to(ArchChoice::Qr).all_points(w, x);
    let max_snr = |pts: &[DesignPoint]| {
        pts.iter()
            .map(|p| p.snr_t_db)
            .fold(f64::NEG_INFINITY, f64::max)
    };
    let cheapest_at = |pts: &[DesignPoint], target: f64| -> Option<DesignPoint> {
        let mut best: Option<&DesignPoint> = None;
        for p in pts {
            if p.snr_t_db >= target
                && best.is_none_or(|cur| Objective::MinEnergy.better(p, cur))
            {
                best = Some(p);
            }
        }
        best.cloned()
    };

    let mut rows = Vec::with_capacity(targets.len());
    for &target in targets {
        let qs = cheapest_at(&qs_points, target);
        let qr = cheapest_at(&qr_points, target);
        let preferred = match (&qs, &qr) {
            (Some(a), Some(b)) => Some(if a.energy_j <= b.energy_j {
                ArchChoice::Qs
            } else {
                ArchChoice::Qr
            }),
            (Some(_), None) => Some(ArchChoice::Qs),
            (None, Some(_)) => Some(ArchChoice::Qr),
            (None, None) => None,
        };
        rows.push(CrossoverRow {
            target_snr_t_db: target,
            qs,
            qr,
            preferred,
        });
    }

    // trailing QR run strictly after the last QS-preferred row
    let crossover_snr_t_db = rows
        .iter()
        .rposition(|r| r.preferred == Some(ArchChoice::Qs))
        .and_then(|last_qs| {
            rows[last_qs + 1..]
                .iter()
                .find(|r| r.preferred == Some(ArchChoice::Qr))
                .map(|r| r.target_snr_t_db)
        });

    Ok(CrossoverReport {
        rows,
        crossover_snr_t_db,
        qs_max_snr_t_db: max_snr(&qs_points),
        qr_max_snr_t_db: max_snr(&qr_points),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::uniform_stats;
    use crate::tech::TechNode;

    #[test]
    fn report_rows_are_consistent_with_their_curves() {
        let (w, x) = uniform_stats();
        let d = Domain {
            archs: vec![ArchChoice::Qs, ArchChoice::Qr],
            nodes: vec![TechNode::n65()],
            vwls: vec![0.6, 0.8],
            cos: vec![1.0, 3.0],
            ns: vec![128],
            bxs: vec![2, 4, 6],
            bws: vec![2, 4, 6],
            b_adcs: vec![2, 4, 6, 8],
            banks: vec![1],
        }
        .normalized()
        .unwrap();
        let report = crossover(&d, &[5.0, 10.0, 15.0, 20.0, 40.0], &w, &x).unwrap();
        assert_eq!(report.rows.len(), 5);
        for row in &report.rows {
            for p in row.qs.iter().chain(&row.qr) {
                assert!(p.snr_t_db >= row.target_snr_t_db, "meets its target");
            }
            if let (Some(a), Some(b)) = (&row.qs, &row.qr) {
                let want = if a.energy_j <= b.energy_j {
                    ArchChoice::Qs
                } else {
                    ArchChoice::Qr
                };
                assert_eq!(row.preferred, Some(want));
            }
        }
        // 40 dB is beyond both ceilings in this domain
        assert!(report.rows[4].preferred.is_none());
        assert!(report.qr_max_snr_t_db > report.qs_max_snr_t_db);
    }

    #[test]
    fn rejects_domains_without_both_archs_or_bad_targets() {
        let (w, x) = uniform_stats();
        let d = Domain {
            archs: vec![ArchChoice::Qs],
            nodes: vec![TechNode::n65()],
            vwls: vec![0.8],
            cos: vec![3.0],
            ns: vec![64],
            bxs: vec![6],
            bws: vec![6],
            b_adcs: vec![8],
            banks: vec![1],
        }
        .normalized()
        .unwrap();
        assert!(crossover(&d, &[5.0], &w, &x).is_err());
        let both = Domain {
            archs: vec![ArchChoice::Qs, ArchChoice::Qr],
            ..d
        }
        .normalized()
        .unwrap();
        assert!(crossover(&both, &[], &w, &x).is_err());
        assert!(crossover(&both, &[5.0, 5.0], &w, &x).is_err());
        assert!(crossover(&both, &[5.0, 4.0], &w, &x).is_err());
        assert!(crossover(&both, &[1.0, 2.0], &w, &x).is_ok());
    }
}
