//! Lock-free span recorder with Chrome-trace-format export.
//!
//! Disabled by default: [`span`] is a near-free no-op (one relaxed
//! atomic load) until [`enable`] is called, so instrumentation can sit
//! permanently on hot paths. Once enabled, each completed span claims a
//! slot in a fixed pre-allocated slab with a single `fetch_add` — no
//! locks, no allocation on the claim path — so concurrent MC worker
//! threads never serialize on the recorder. When the slab fills, spans
//! are dropped and counted ([`registry::TRACE_SPANS_DROPPED`]) rather
//! than blocking.
//!
//! The recorder observes wall-clock only; it never feeds back into any
//! computed value. `sweep.csv` and cache records are byte-identical
//! with and without tracing (asserted by `tests/obs.rs` and CI).

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::obs::registry;
use crate::util::json::{self, Json};

/// Slab capacity. 1<<16 spans ≈ a 6-point acceptance sweep traced a
/// thousand times over; paper-scale grids overflow gracefully (dropped
/// spans are counted, the trace file reports the drop count).
const CAPACITY: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static SLAB: OnceLock<Vec<OnceLock<SpanRecord>>> = OnceLock::new();
/// Next free slab index; values ≥ CAPACITY mean the span was dropped.
static NEXT: AtomicUsize = AtomicUsize::new(0);

/// One completed span, as recorded.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub name: &'static str,
    pub cat: &'static str,
    pub detail: Option<String>,
    /// Microseconds since the process trace epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Stable per-thread id (hash of `ThreadId`), for trace lanes.
    pub tid: u64,
}

/// Turn the recorder on for the rest of the process. Idempotent.
pub fn enable() {
    EPOCH.get_or_init(Instant::now);
    SLAB.get_or_init(|| (0..CAPACITY).map(|_| OnceLock::new()).collect());
    ENABLED.store(true, Ordering::Release);
}

pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// RAII span: records on drop. When tracing is disabled this is a
/// no-op carrying no allocation.
pub struct SpanGuard {
    start: Option<Instant>,
    name: &'static str,
    cat: &'static str,
    detail: Option<String>,
}

/// Open a span. `name` is the event name shown in the trace viewer,
/// `cat` groups related spans (e.g. "engine", "mc", "pareto").
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    SpanGuard {
        start: is_enabled().then(Instant::now),
        name,
        cat,
        detail: None,
    }
}

/// Open a span with a lazily-built `args.detail` string; `detail()` is
/// only invoked when tracing is enabled, so hot paths pay nothing for
/// rich annotations.
pub fn span_with(
    name: &'static str,
    cat: &'static str,
    detail: impl FnOnce() -> String,
) -> SpanGuard {
    let start = is_enabled().then(Instant::now);
    let detail = start.is_some().then(detail);
    SpanGuard {
        start,
        name,
        cat,
        detail,
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let epoch = *EPOCH.get_or_init(Instant::now);
        let record = SpanRecord {
            name: self.name,
            cat: self.cat,
            detail: self.detail.take(),
            start_us: start.duration_since(epoch).as_micros() as u64,
            dur_us: start.elapsed().as_micros() as u64,
            tid: thread_lane(),
        };
        let idx = NEXT.fetch_add(1, Ordering::Relaxed);
        match SLAB.get().and_then(|slab| slab.get(idx)) {
            Some(slot) => {
                let _ = slot.set(record);
            }
            None => registry::TRACE_SPANS_DROPPED.add(1),
        }
    }
}

fn thread_lane() -> u64 {
    let mut h = DefaultHasher::new();
    std::thread::current().id().hash(&mut h);
    // Keep lane ids readable in trace viewers.
    h.finish() % 10_000
}

/// Snapshot every span recorded so far, in claim order.
pub fn snapshot() -> Vec<SpanRecord> {
    let Some(slab) = SLAB.get() else {
        return Vec::new();
    };
    let n = NEXT.load(Ordering::Acquire).min(CAPACITY);
    slab[..n].iter().filter_map(|s| s.get().cloned()).collect()
}

/// Number of spans dropped to slab overflow.
pub fn dropped() -> u64 {
    registry::TRACE_SPANS_DROPPED.get()
}

/// Dump all recorded spans as a Chrome trace event array (the JSON
/// array form — loadable in `chrome://tracing` and Perfetto). Returns
/// the number of spans written.
pub fn write_chrome_trace(path: &Path) -> Result<usize> {
    let spans = snapshot();
    let mut events: Vec<Json> = Vec::with_capacity(spans.len() + 1);
    // Process-name metadata event, so viewers label the single process.
    events.push(json::obj(vec![
        ("name", json::s("process_name")),
        ("ph", json::s("M")),
        ("pid", json::num(1.0)),
        ("tid", json::num(0.0)),
        (
            "args",
            json::obj(vec![("name", json::s("imclim"))]),
        ),
    ]));
    for sp in &spans {
        let mut args = vec![];
        if let Some(d) = &sp.detail {
            args.push(("detail", json::s(d)));
        }
        events.push(json::obj(vec![
            ("name", json::s(sp.name)),
            ("cat", json::s(sp.cat)),
            ("ph", json::s("X")),
            ("ts", json::num(sp.start_us as f64)),
            ("dur", json::num(sp.dur_us as f64)),
            ("pid", json::num(1.0)),
            ("tid", json::num(sp.tid as f64)),
            ("args", json::obj(args)),
        ]));
    }
    if dropped() > 0 {
        events.push(json::obj(vec![
            ("name", json::s("trace_spans_dropped")),
            ("ph", json::s("M")),
            ("pid", json::num(1.0)),
            ("tid", json::num(0.0)),
            (
                "args",
                json::obj(vec![("count", json::num(dropped() as f64))]),
            ),
        ]));
    }
    let body = Json::Arr(events).to_string();
    std::fs::write(path, body)
        .with_context(|| format!("writing trace file {}", path.display()))?;
    Ok(spans.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        // Run before any enable() in this test binary would be racy;
        // instead assert the guard itself is inert when start is None.
        let g = SpanGuard {
            start: None,
            name: "x",
            cat: "t",
            detail: None,
        };
        let before = NEXT.load(Ordering::Relaxed);
        drop(g);
        assert_eq!(NEXT.load(Ordering::Relaxed), before);
    }

    #[test]
    fn enabled_spans_are_recorded_and_exported() {
        enable();
        {
            let _g = span("unit_test_span", "test");
        }
        {
            let _g = span_with("unit_test_span_with", "test", || "d=1".to_string());
        }
        let spans = snapshot();
        assert!(spans.iter().any(|s| s.name == "unit_test_span"));
        let with = spans
            .iter()
            .find(|s| s.name == "unit_test_span_with")
            .expect("span_with recorded");
        assert_eq!(with.detail.as_deref(), Some("d=1"));

        let dir = std::env::temp_dir().join("imclim-trace-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        let n = write_chrome_trace(&path).unwrap();
        assert!(n >= 2);
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let arr = parsed.as_arr().expect("trace is a JSON array");
        assert!(arr
            .iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some("unit_test_span")
                && e.get("ph").and_then(Json::as_str) == Some("X")));
    }
}
