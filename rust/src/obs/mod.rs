//! Observability: structured tracing, a metrics registry, and progress
//! event streaming — all dependency-free and global, mirroring the
//! design of `coordinator::metrics` (global atomics, since
//! `SweepOptions` is `Copy` and no context handle is threaded through
//! the stack).
//!
//! Three cooperating pieces:
//!
//! * [`trace`] — a lock-free span recorder. Disabled by default; the
//!   CLI's `--trace FILE` enables it for the process and dumps
//!   Chrome-trace-format JSON (loadable in `chrome://tracing` or
//!   Perfetto) on exit. The hard invariant: tracing never perturbs
//!   computed output. Spans carry wall-clock only into the trace file;
//!   `sweep.csv` and cache records are byte-identical with and without
//!   `--trace`.
//! * [`registry`] — named counters, gauges, and fixed-bucket latency
//!   histograms, rendered as Prometheus text exposition format for
//!   `GET /metrics` on `imclim serve`. The five PR 8 counters behind
//!   `coordinator::metrics` now live here; that module remains as a
//!   snapshot facade.
//! * [`progress`] — structured progress events. The scheduler and the
//!   shard runner emit events through [`progress::emit`]; the human
//!   stderr lines are rendered *from* those events (rate-limited to
//!   one line per 100 ms), `--progress json` emits the raw NDJSON
//!   instead, and `imclim serve` installs a per-job collector so
//!   `GET /jobs/<id>/events` can stream them live.

pub mod progress;
pub mod registry;
pub mod trace;
