//! Named counter/gauge/histogram registry with Prometheus text
//! exposition (format version 0.0.4), served at `GET /metrics` by
//! `imclim serve`.
//!
//! Everything is a static with relaxed atomics — same pattern as the
//! PR 8 counters in `coordinator::metrics`, which now delegate here.
//! Histograms use one fixed exponential latency bucket ladder
//! ([`LATENCY_BOUNDS_US`], 100 µs … 10 s) shared by every family, and
//! store their sum in integer microseconds so snapshots stay `Copy +
//! Eq` (no floats in `MetricsSnapshot`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Monotone counter.
pub struct Counter {
    name: &'static str,
    help: &'static str,
    value: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Self {
            name,
            help,
            value: AtomicU64::new(0),
        }
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Instantaneous gauge (set-to-current-value semantics).
pub struct Gauge {
    name: &'static str,
    help: &'static str,
    value: AtomicU64,
}

impl Gauge {
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Self {
            name,
            help,
            value: AtomicU64::new(0),
        }
    }

    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Number of finite histogram buckets (the `+Inf` bucket is tracked
/// separately as `overflow`).
pub const HIST_BUCKETS: usize = 12;

/// Upper bounds of the finite buckets, in microseconds: an exponential
/// ladder from 100 µs to 10 s covering both sub-millisecond cache
/// probes and multi-second MC chunks.
pub const LATENCY_BOUNDS_US: [u64; HIST_BUCKETS] = [
    100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 1_000_000, 10_000_000,
];

/// Fixed-bucket latency histogram.
pub struct Histogram {
    name: &'static str,
    help: &'static str,
    /// Per-bucket (non-cumulative) observation counts; rendered
    /// cumulatively, as Prometheus requires.
    buckets: [AtomicU64; HIST_BUCKETS],
    /// Observations above the largest finite bound (`+Inf` residue).
    overflow: AtomicU64,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Self {
            name,
            help,
            buckets: [ZERO; HIST_BUCKETS],
            overflow: AtomicU64::new(0),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one latency observation.
    pub fn observe(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        match LATENCY_BOUNDS_US.iter().position(|&b| us <= b) {
            Some(i) => self.buckets[i].fetch_add(1, Ordering::Relaxed),
            None => self.overflow.fetch_add(1, Ordering::Relaxed),
        };
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (out, b) in buckets.iter_mut().zip(&self.buckets) {
            *out = b.load(Ordering::Relaxed);
        }
        HistogramSnapshot {
            buckets,
            overflow: self.overflow.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a [`Histogram`]. Sum is kept in integer
/// microseconds so the type (and `coordinator::MetricsSnapshot`, which
/// embeds it) stays `Copy + Eq`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: [u64; HIST_BUCKETS],
    pub overflow: u64,
    pub count: u64,
    pub sum_us: u64,
}

impl HistogramSnapshot {
    /// Delta since an earlier snapshot (wrapping, like
    /// `MetricsSnapshot::since`).
    pub fn since(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (i, out) in buckets.iter_mut().enumerate() {
            *out = self.buckets[i].wrapping_sub(earlier.buckets[i]);
        }
        HistogramSnapshot {
            buckets,
            overflow: self.overflow.wrapping_sub(earlier.overflow),
            count: self.count.wrapping_sub(earlier.count),
            sum_us: self.sum_us.wrapping_sub(earlier.sum_us),
        }
    }
}

// ---------------------------------------------------------------------
// The registry itself: every family the process exports. Dependency-
// free, so "registry" is a fixed list rather than a runtime map —
// registration is adding a static here and an entry in `render`.
// ---------------------------------------------------------------------

pub static CACHE_HITS: Counter = Counter::new(
    "imclim_cache_hits_total",
    "Sweep points served from the result cache",
);
pub static CACHE_MISSES: Counter = Counter::new(
    "imclim_cache_misses_total",
    "Sweep points not found in the result cache",
);
pub static POINTS_COMPUTED: Counter = Counter::new(
    "imclim_points_computed_total",
    "Sweep points actually simulated (cache misses that ran MC)",
);
pub static TRIALS_COMPLETED: Counter = Counter::new(
    "imclim_trials_completed_total",
    "Monte-Carlo trials completed across all points",
);
pub static MC_ERRORS: Counter = Counter::new(
    "imclim_mc_errors_total",
    "Monte-Carlo point simulations that returned an error",
);
pub static ADAPTIVE_ROUNDS: Counter = Counter::new(
    "imclim_adaptive_rounds_total",
    "Adaptive-precision refinement rounds executed",
);
pub static PROGRESS_EVENTS: Counter = Counter::new(
    "imclim_progress_events_total",
    "Structured progress events emitted",
);
pub static TRACE_SPANS_DROPPED: Counter = Counter::new(
    "imclim_trace_spans_dropped_total",
    "Trace spans dropped because the recorder slab was full",
);
pub static SHARD_LEASES: Counter = Counter::new(
    "imclim_shard_leases_total",
    "Job shards leased to remote workers",
);
pub static SHARD_COMPLETIONS: Counter = Counter::new(
    "imclim_shard_completions_total",
    "Job shards completed (worker upload or local fallback)",
);
pub static SHARD_REQUEUES: Counter = Counter::new(
    "imclim_shard_requeues_total",
    "Job shards re-queued after a worker died or reported failure",
);

pub static JOBS_QUEUED: Gauge = Gauge::new(
    "imclim_jobs_queued",
    "Serve jobs waiting in the queue",
);
pub static JOBS_RUNNING: Gauge = Gauge::new(
    "imclim_jobs_running",
    "Serve jobs currently executing",
);
pub static WORKERS_REGISTERED: Gauge = Gauge::new(
    "imclim_workers_registered",
    "Remote workers currently registered with the serve daemon",
);

pub static CACHE_PROBE_SECONDS: Histogram = Histogram::new(
    "imclim_cache_probe_seconds",
    "Latency of individual result-cache probes",
);
pub static MC_CHUNK_SECONDS: Histogram = Histogram::new(
    "imclim_mc_chunk_seconds",
    "Latency of individual Monte-Carlo trial chunks",
);

const COUNTERS: [&Counter; 11] = [
    &CACHE_HITS,
    &CACHE_MISSES,
    &POINTS_COMPUTED,
    &TRIALS_COMPLETED,
    &MC_ERRORS,
    &ADAPTIVE_ROUNDS,
    &PROGRESS_EVENTS,
    &TRACE_SPANS_DROPPED,
    &SHARD_LEASES,
    &SHARD_COMPLETIONS,
    &SHARD_REQUEUES,
];

const GAUGES: [&Gauge; 3] = [&JOBS_QUEUED, &JOBS_RUNNING, &WORKERS_REGISTERED];

const HISTOGRAMS: [&Histogram; 2] = [&CACHE_PROBE_SECONDS, &MC_CHUNK_SECONDS];

/// Format a microsecond bound as Prometheus seconds (`le` label /
/// `_sum` value). Plain decimal, no exponent — `0.0001`, `2.5`, `10`.
fn us_as_seconds(us: u64) -> String {
    let whole = us / 1_000_000;
    let frac = us % 1_000_000;
    if frac == 0 {
        format!("{whole}")
    } else {
        let s = format!("{whole}.{frac:06}");
        s.trim_end_matches('0').to_string()
    }
}

/// Render every family as Prometheus text exposition format 0.0.4.
pub fn render_prometheus() -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for c in COUNTERS {
        let _ = writeln!(out, "# HELP {} {}", c.name, c.help);
        let _ = writeln!(out, "# TYPE {} counter", c.name);
        let _ = writeln!(out, "{} {}", c.name, c.get());
    }
    for g in GAUGES {
        let _ = writeln!(out, "# HELP {} {}", g.name, g.help);
        let _ = writeln!(out, "# TYPE {} gauge", g.name);
        let _ = writeln!(out, "{} {}", g.name, g.get());
    }
    for h in HISTOGRAMS {
        let snap = h.snapshot();
        let _ = writeln!(out, "# HELP {} {}", h.name, h.help);
        let _ = writeln!(out, "# TYPE {} histogram", h.name);
        let mut cumulative = 0u64;
        for (i, &bound) in LATENCY_BOUNDS_US.iter().enumerate() {
            cumulative += snap.buckets[i];
            let _ = writeln!(
                out,
                "{}_bucket{{le=\"{}\"}} {}",
                h.name,
                us_as_seconds(bound),
                cumulative
            );
        }
        cumulative += snap.overflow;
        let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {}", h.name, cumulative);
        let _ = writeln!(out, "{}_sum {}", h.name, us_as_seconds(snap.sum_us));
        let _ = writeln!(out, "{}_count {}", h.name, snap.count);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_sum() {
        static H: Histogram = Histogram::new("imclim_test_seconds", "test");
        H.observe(Duration::from_micros(50)); // -> le=100us bucket
        H.observe(Duration::from_micros(900)); // -> le=1ms bucket
        H.observe(Duration::from_secs(60)); // -> +Inf
        let s = H.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.buckets[0], 1);
        assert_eq!(s.buckets[3], 1);
        assert_eq!(s.overflow, 1);
        assert_eq!(s.sum_us, 50 + 900 + 60_000_000);
        let d = H.snapshot().since(&s);
        assert_eq!(d, HistogramSnapshot::default());
    }

    #[test]
    fn seconds_formatting_is_plain_decimal() {
        assert_eq!(us_as_seconds(100), "0.0001");
        assert_eq!(us_as_seconds(2_500), "0.0025");
        assert_eq!(us_as_seconds(1_000_000), "1");
        assert_eq!(us_as_seconds(10_000_000), "10");
        assert_eq!(us_as_seconds(1_234_567), "1.234567");
    }

    #[test]
    fn render_is_wellformed_exposition() {
        CACHE_HITS.add(0); // touch so the family exists
        let text = render_prometheus();
        for family in [
            "imclim_cache_hits_total",
            "imclim_cache_misses_total",
            "imclim_mc_chunk_seconds",
            "imclim_cache_probe_seconds",
            "imclim_jobs_queued",
            "imclim_workers_registered",
            "imclim_shard_requeues_total",
        ] {
            assert!(
                text.contains(&format!("# HELP {family} ")),
                "missing HELP for {family}"
            );
            assert!(
                text.contains(&format!("# TYPE {family} ")),
                "missing TYPE for {family}"
            );
        }
        assert!(text.contains("imclim_mc_chunk_seconds_bucket{le=\"+Inf\"}"));
        assert!(text.contains("imclim_mc_chunk_seconds_count"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, value) = line.rsplit_once(' ').expect("metric line has a value");
            assert!(
                value.parse::<f64>().is_ok(),
                "unparseable value in line: {line}"
            );
        }
    }
}
