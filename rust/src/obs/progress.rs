//! Structured progress events.
//!
//! The scheduler and shard runner emit [`Event`]s here instead of
//! printing directly. One emission fans out to up to three sinks:
//!
//! * **Human stderr** (`ProgressMode::Human`, set by `--verbose`):
//!   the familiar `[done/total] id …` lines are rendered *from* the
//!   events, rate-limited to one line per 100 ms so huge grids stop
//!   flooding stderr through the shard-log forwarder. The first and
//!   final line of a sweep always print.
//! * **Raw NDJSON stderr** (`ProgressMode::Json`, set by
//!   `--progress json`): one JSON object per line, machine-parseable.
//! * **A per-job [`EventLog`] collector** installed by the serve
//!   executor, from which `GET /jobs/<id>/events` streams live.
//!
//! With no mode set and no collector installed (`--quiet`, or any
//!   plain run), emission is a two-atomic-load no-op.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::obs::registry;
use crate::util::json::{self, Json};

/// Where human-readable progress goes. Selected once per process by
/// the CLI (`--quiet` > `--progress json` > `--verbose` > off).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProgressMode {
    Off,
    Human,
    Json,
}

static MODE: AtomicU8 = AtomicU8::new(0);

pub fn set_mode(mode: ProgressMode) {
    let v = match mode {
        ProgressMode::Off => 0,
        ProgressMode::Human => 1,
        ProgressMode::Json => 2,
    };
    MODE.store(v, Ordering::Relaxed);
}

pub fn mode() -> ProgressMode {
    match MODE.load(Ordering::Relaxed) {
        1 => ProgressMode::Human,
        2 => ProgressMode::Json,
        _ => ProgressMode::Off,
    }
}

/// One structured progress event. `kind` discriminates; unused fields
/// are simply omitted from the JSON form.
#[derive(Clone, Debug)]
pub struct Event {
    pub kind: &'static str,
    /// Point id, shard label — whatever names the unit of work.
    pub id: String,
    pub done: u64,
    pub total: u64,
    pub trials: u64,
    /// Number of MC chunks for chunked points (0 = not chunked).
    pub chunks: u64,
    pub snr_t_db: Option<f64>,
}

impl Event {
    fn to_json_line(&self) -> String {
        let mut pairs = vec![("kind", json::s(self.kind))];
        if !self.id.is_empty() {
            pairs.push(("id", json::s(&self.id)));
        }
        if self.total > 0 {
            pairs.push(("done", json::num(self.done as f64)));
            pairs.push(("total", json::num(self.total as f64)));
        }
        if self.trials > 0 {
            pairs.push(("trials", json::num(self.trials as f64)));
        }
        if self.chunks > 0 {
            pairs.push(("chunks", json::num(self.chunks as f64)));
        }
        // Failed points carry NaN; keep the JSON valid by omitting it.
        if let Some(snr) = self.snr_t_db.filter(|v| v.is_finite()) {
            pairs.push(("snr_t_db", json::num(snr)));
        }
        json::obj(pairs).to_string()
    }

    /// The legacy stderr rendering, reproduced byte-for-byte from the
    /// pre-obs `eprintln!` sites so `--verbose` output is unchanged.
    fn render_human(&self) -> Option<String> {
        match self.kind {
            "point" if self.chunks > 0 => Some(format!(
                "[{}/{}] {} ({} chunks)",
                self.done, self.total, self.id, self.chunks
            )),
            "point" => Some(format!(
                "[{}/{}] {} snr_t={:.2} dB",
                self.done,
                self.total,
                self.id,
                self.snr_t_db.unwrap_or(f64::NAN)
            )),
            _ => None,
        }
    }

    /// Final event of a sweep — exempt from rate limiting so the last
    /// line always lands.
    fn is_final(&self) -> bool {
        self.kind == "point" && self.total > 0 && self.done == self.total
    }
}

// ---------------------------------------------------------------------
// Per-job event log (serve): append-only line buffer + condvar, so an
// HTTP handler can stream events as they arrive and learn when the
// job is finished.
// ---------------------------------------------------------------------

#[derive(Default)]
pub struct EventLog {
    lines: Mutex<Vec<String>>,
    cv: Condvar,
    closed: AtomicBool,
}

impl EventLog {
    pub fn new() -> Arc<EventLog> {
        Arc::new(EventLog::default())
    }

    pub fn append(&self, line: String) {
        self.lines.lock().unwrap().push(line);
        self.cv.notify_all();
    }

    /// Mark the log complete (terminal event already appended). After
    /// close, `wait_since` never blocks.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        // Take the lock so a waiter can't check `closed` and then
        // block just before the store becomes visible.
        let _guard = self.lines.lock().unwrap();
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Return lines `[from..]`, blocking up to `timeout` for new ones.
    /// The returned flag is true once the log is closed *and* every
    /// line up to the close has been handed out.
    pub fn wait_since(&self, from: usize, timeout: Duration) -> (Vec<String>, bool) {
        let deadline = Instant::now() + timeout;
        let mut guard = self.lines.lock().unwrap();
        loop {
            if guard.len() > from {
                return (guard[from..].to_vec(), self.is_closed());
            }
            if self.is_closed() {
                return (Vec::new(), true);
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return (Vec::new(), false);
            }
            let (g, _timeout) = self.cv.wait_timeout(guard, left).unwrap();
            guard = g;
        }
    }
}

static COLLECTOR: Mutex<Option<Arc<EventLog>>> = Mutex::new(None);
/// Lock-free fast-path mirror of `COLLECTOR.is_some()`.
static HAS_COLLECTOR: AtomicBool = AtomicBool::new(false);

/// Route subsequent events into `log` (one collector at a time; the
/// serve executor runs jobs sequentially).
pub fn install_collector(log: Arc<EventLog>) {
    *COLLECTOR.lock().unwrap() = Some(log);
    HAS_COLLECTOR.store(true, Ordering::Release);
}

pub fn clear_collector() {
    HAS_COLLECTOR.store(false, Ordering::Release);
    *COLLECTOR.lock().unwrap() = None;
}

fn collector() -> Option<Arc<EventLog>> {
    COLLECTOR.lock().unwrap().clone()
}

/// Whether anyone is listening. Callers may skip building events
/// entirely when this is false — except human-fallback paths, see
/// [`emit`].
pub fn active() -> bool {
    HAS_COLLECTOR.load(Ordering::Acquire) || mode() != ProgressMode::Off
}

/// Emit one event to every active sink. `human_fallback` preserves the
/// pre-obs library behavior: when the process never selected a mode
/// (embedders calling `run_sweep` directly with `verbose: true`), the
/// human line still prints.
pub fn emit(ev: &Event, human_fallback: bool) {
    let mode = mode();
    let collecting = HAS_COLLECTOR.load(Ordering::Acquire);
    let render_human = mode == ProgressMode::Human
        || (mode == ProgressMode::Off && !collecting && human_fallback);
    if !collecting && mode == ProgressMode::Off && !render_human {
        return;
    }
    registry::PROGRESS_EVENTS.add(1);
    if collecting {
        if let Some(log) = collector() {
            log.append(ev.to_json_line());
        }
    }
    match mode {
        ProgressMode::Json => eprintln!("{}", ev.to_json_line()),
        _ if render_human => {
            if let Some(text) = ev.render_human() {
                rate_limited_eprintln(&text, ev.is_final());
            }
        }
        _ => {}
    }
}

/// Minimum spacing between human progress lines.
const MIN_INTERVAL: Duration = Duration::from_millis(100);

static RATE_EPOCH: OnceLock<Instant> = OnceLock::new();
/// Nanoseconds-since-epoch of the last printed line, +1 so 0 can mean
/// "never printed".
static LAST_PRINT_NS: AtomicU64 = AtomicU64::new(0);

fn rate_limited_eprintln(text: &str, force: bool) {
    let epoch = *RATE_EPOCH.get_or_init(Instant::now);
    let now = epoch.elapsed().as_nanos() as u64 + 1;
    let last = LAST_PRINT_NS.load(Ordering::Relaxed);
    let due = last == 0 || now.saturating_sub(last) >= MIN_INTERVAL.as_nanos() as u64;
    if !force && !due {
        return;
    }
    // CAS so racing threads don't both print inside one window; forced
    // (final) lines print regardless of who wins.
    let won = LAST_PRINT_NS
        .compare_exchange(last, now, Ordering::Relaxed, Ordering::Relaxed)
        .is_ok();
    if won || force {
        eprintln!("{text}");
    }
}

/// Convenience: the scheduler's per-point completion event. Builds the
/// event only when a sink is active (or the legacy verbose fallback
/// asks for it).
#[allow(clippy::too_many_arguments)]
pub fn point_done(
    id: &str,
    done: u64,
    total: u64,
    trials: u64,
    chunks: u64,
    snr_t_db: Option<f64>,
    human_fallback: bool,
) {
    if !active() && !human_fallback {
        return;
    }
    emit(
        &Event {
            kind: "point",
            id: id.to_string(),
            done,
            total,
            trials,
            chunks,
            snr_t_db,
        },
        human_fallback,
    );
}

/// Convenience: sweep-start event (total points about to run).
pub fn mc_start(total: u64) {
    if !active() {
        return;
    }
    emit(
        &Event {
            kind: "mc_start",
            id: String::new(),
            done: 0,
            total,
            trials: 0,
            chunks: 0,
            snr_t_db: None,
        },
        false,
    );
}

/// Convenience: shard subprocess lifecycle event.
pub fn shard(kind: &'static str, label: &str, index: u64, total: u64) {
    if !active() {
        return;
    }
    emit(
        &Event {
            kind,
            id: label.to_string(),
            done: index,
            total,
            trials: 0,
            chunks: 0,
            snr_t_db: None,
        },
        false,
    );
}

/// Build the JSON line for a job's terminal event (appended by the
/// serve executor right before closing the log).
pub fn terminal_line(pairs: Vec<(&str, Json)>) -> String {
    let mut all = vec![("kind", json::s("terminal"))];
    all.extend(pairs);
    json::obj(all).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_json_omits_unused_fields() {
        let ev = Event {
            kind: "point",
            id: "qs-n128".into(),
            done: 3,
            total: 6,
            trials: 256,
            chunks: 0,
            snr_t_db: Some(12.5),
        };
        let line = ev.to_json_line();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("point"));
        assert_eq!(j.get("done").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("snr_t_db").unwrap().as_f64(), Some(12.5));
        assert!(j.get("chunks").is_none());
        assert!(line.ends_with('}') && !line.contains('\n'));
    }

    #[test]
    fn human_rendering_matches_legacy_formats() {
        let plain = Event {
            kind: "point",
            id: "p".into(),
            done: 1,
            total: 2,
            trials: 48,
            chunks: 0,
            snr_t_db: Some(3.25),
        };
        assert_eq!(plain.render_human().unwrap(), "[1/2] p snr_t=3.25 dB");
        let chunked = Event {
            chunks: 4,
            ..plain.clone()
        };
        assert_eq!(chunked.render_human().unwrap(), "[1/2] p (4 chunks)");
    }

    #[test]
    fn event_log_streams_and_closes() {
        let log = EventLog::new();
        log.append("a".to_string());
        let (lines, closed) = log.wait_since(0, Duration::from_millis(10));
        assert_eq!(lines, ["a"]);
        assert!(!closed);
        // Nothing new: timeout path.
        let (lines, closed) = log.wait_since(1, Duration::from_millis(10));
        assert!(lines.is_empty() && !closed);

        let log2 = Arc::clone(&log);
        let writer = std::thread::spawn(move || {
            log2.append("b".to_string());
            log2.close();
        });
        let mut from = 1;
        let mut got = Vec::new();
        loop {
            let (lines, closed) = log.wait_since(from, Duration::from_secs(5));
            from += lines.len();
            got.extend(lines);
            if closed && got.len() == 1 {
                break;
            }
        }
        writer.join().unwrap();
        assert_eq!(got, ["b"]);
    }

    #[test]
    fn collector_receives_events_regardless_of_mode() {
        let log = EventLog::new();
        install_collector(Arc::clone(&log));
        point_done("collector-test-x", 1, 1, 8, 0, Some(1.0), false);
        clear_collector();
        point_done("collector-test-y", 1, 1, 8, 0, Some(1.0), false);
        let (lines, _) = log.wait_since(0, Duration::from_millis(10));
        // Other tests may emit concurrently while the collector is
        // installed; assert only on our own ids.
        let xs = lines
            .iter()
            .filter(|l| l.contains("\"id\":\"collector-test-x\""))
            .count();
        let ys = lines
            .iter()
            .filter(|l| l.contains("\"id\":\"collector-test-y\""))
            .count();
        assert_eq!((xs, ys), (1, 0));
    }
}
