//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by the Python compile path and executes them on the PJRT CPU client.
//!
//! HLO *text* is the interchange format (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reparses
//! and reassigns instruction ids, avoiding the 64-bit-id proto
//! incompatibility between jax >= 0.5 and xla_extension 0.5.1.
//!
//! `PjRtLoadedExecutable` wraps raw pointers (!Send), so a `Runtime` is
//! thread-local; the coordinator runs all PJRT work on one dedicated
//! executor thread (see `crate::coordinator::service`).

pub mod manifest;

/// Offline stand-in for the vendored `xla` crate: same API surface,
/// fails at runtime instead of at build time. Swap for the real
/// bindings to execute artifacts (see its module docs).
mod xla;

pub use manifest::{fingerprint as artifact_fingerprint, ArtifactSpec, Manifest};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::arch::pvec;
use crate::mc::McOutput;

/// Default artifact directory: $IMCLIM_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("IMCLIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// A compiled architecture-simulation executable plus its static shapes.
pub struct ArchExec {
    exe: xla::PjRtLoadedExecutable,
    /// MC trials per invocation (leading dim of x/w).
    pub m: usize,
    /// Maximum DP dimension (trailing dim of x/w).
    pub n_max: usize,
}

impl ArchExec {
    /// Execute one MC batch. `x`: m*n_max activations in [0,1), `w`:
    /// m*n_max weights in [-1,1), row-major; `seed`: two counter words.
    pub fn run(
        &self,
        x: &[f32],
        w: &[f32],
        seed: [f32; 2],
        params: &[f64; pvec::P],
    ) -> Result<McOutput> {
        if x.len() != self.m * self.n_max || w.len() != self.m * self.n_max {
            bail!(
                "input length {} != m*n_max = {}",
                x.len(),
                self.m * self.n_max
            );
        }
        let xs = xla::Literal::vec1(x).reshape(&[self.m as i64, self.n_max as i64])?;
        let ws = xla::Literal::vec1(w).reshape(&[self.m as i64, self.n_max as i64])?;
        let sd = xla::Literal::vec1(&seed);
        let pv: Vec<f32> = params.iter().map(|&v| v as f32).collect();
        let pl = xla::Literal::vec1(&pv);
        let result = self.exe.execute::<xla::Literal>(&[xs, ws, sd, pl])?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 4 {
            bail!("expected 4 outputs, got {}", parts.len());
        }
        let grab = |l: &xla::Literal| -> Result<Vec<f64>> {
            Ok(l.to_vec::<f32>()?.into_iter().map(|v| v as f64).collect())
        };
        Ok(McOutput {
            y_ideal: grab(&parts[0])?,
            y_fx: grab(&parts[1])?,
            y_a: grab(&parts[2])?,
            y_hat: grab(&parts[3])?,
        })
    }
}

/// A compiled MLP-forward executable (Fig. 2 workload).
pub struct MlpExec {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub dims: Vec<usize>, // [d0, d1, d2, d3]
}

impl MlpExec {
    /// Run a noisy forward pass; weights row-major [out, in]. Returns
    /// logits (batch x d3, row-major).
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        x: &[f32],
        w1: &[f32],
        b1: &[f32],
        w2: &[f32],
        b2: &[f32],
        w3: &[f32],
        b3: &[f32],
        seed: [f32; 2],
        sigmas: [f32; 3],
    ) -> Result<Vec<f32>> {
        let (d0, d1, d2, d3) = (self.dims[0], self.dims[1], self.dims[2], self.dims[3]);
        let lit = |v: &[f32], r: usize, c: usize| -> Result<xla::Literal> {
            if v.len() != r * c {
                bail!("literal length {} != {}x{}", v.len(), r, c);
            }
            Ok(xla::Literal::vec1(v).reshape(&[r as i64, c as i64])?)
        };
        let args = [
            lit(x, self.batch, d0)?,
            lit(w1, d1, d0)?,
            xla::Literal::vec1(b1),
            lit(w2, d2, d1)?,
            xla::Literal::vec1(b2),
            lit(w3, d3, d2)?,
            xla::Literal::vec1(b3),
            xla::Literal::vec1(&seed),
            xla::Literal::vec1(&sigmas),
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        Ok(parts[0].to_vec::<f32>()?)
    }
}

/// Thread-local PJRT runtime: one CPU client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    arch_cache: RefCell<HashMap<String, Rc<ArchExec>>>,
    mlp_cache: RefCell<Option<Rc<MlpExec>>>,
}

impl Runtime {
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            dir: dir.to_path_buf(),
            manifest,
            arch_cache: RefCell::new(HashMap::new()),
            mlp_cache: RefCell::new(None),
        })
    }

    pub fn with_default_dir() -> Result<Self> {
        Self::new(&default_artifacts_dir())
    }

    fn compile(&self, name: &str) -> Result<(xla::PjRtLoadedExecutable, &ArtifactSpec)> {
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok((exe, spec))
    }

    /// Load (compile-and-cache) an architecture simulator artifact.
    pub fn arch(&self, name: &str) -> Result<Rc<ArchExec>> {
        if let Some(e) = self.arch_cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let (exe, spec) = self.compile(name)?;
        let xshape = spec
            .input_shape("x")
            .ok_or_else(|| anyhow!("artifact '{name}' has no input 'x'"))?;
        if xshape.len() != 2 {
            bail!("arch artifact expects 2-D x, got {xshape:?}");
        }
        let e = Rc::new(ArchExec {
            exe,
            m: xshape[0],
            n_max: xshape[1],
        });
        self.arch_cache
            .borrow_mut()
            .insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Load the MLP forward executable.
    pub fn mlp(&self) -> Result<Rc<MlpExec>> {
        if let Some(e) = self.mlp_cache.borrow().as_ref() {
            return Ok(e.clone());
        }
        let (exe, spec) = self.compile("mlp_fwd")?;
        let x = spec.input_shape("x").ok_or_else(|| anyhow!("no x input"))?;
        let w1 = spec.input_shape("w1").ok_or_else(|| anyhow!("no w1"))?;
        let w2 = spec.input_shape("w2").ok_or_else(|| anyhow!("no w2"))?;
        let w3 = spec.input_shape("w3").ok_or_else(|| anyhow!("no w3"))?;
        let e = Rc::new(MlpExec {
            exe,
            batch: x[0],
            dims: vec![x[1], w1[0], w2[0], w3[0]],
        });
        *self.mlp_cache.borrow_mut() = Some(e.clone());
        Ok(e)
    }

    /// Round-trip smoke test (matmul + 2 on 2x2), proving the AOT bridge.
    pub fn smoke(&self) -> Result<Vec<f32>> {
        let (exe, _) = self.compile("smoke")?;
        let x = xla::Literal::vec1(&[1f32, 2.0, 3.0, 4.0]).reshape(&[2, 2])?;
        let y = xla::Literal::vec1(&[1f32, 1.0, 1.0, 1.0]).reshape(&[2, 2])?;
        let result = exe.execute::<xla::Literal>(&[x, y])?;
        let out = result[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}
