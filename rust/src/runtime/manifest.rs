//! Artifact manifest (`artifacts/manifest.json`) parsing: the I/O
//! signature of every AOT-compiled executable, emitted by aot.py.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    /// (name, shape) in argument order.
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<String>,
}

impl ArtifactSpec {
    pub fn input_shape(&self, name: &str) -> Option<&[usize]> {
        self.inputs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.as_slice())
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub m_trials: usize,
    pub n_max: usize,
    pub b_max: usize,
    pub p: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

/// Stable fingerprint of a compiled artifact set: a 64-bit FNV-1a hash
/// over the raw `manifest.json` bytes *and* the HLO artifact payloads
/// (`*.hlo.txt`, name + bytes, in sorted order) — the manifest alone
/// only carries names and shapes, so a recompile that changes the
/// simulator math without changing any signature would otherwise hash
/// identically. Folded into `Backend::cache_id`, this keeps results
/// computed against one artifact build from aliasing the engine's
/// content-addressed cache records of another. An unreadable or absent
/// manifest — e.g. the offline-stubbed PJRT runtime — yields the
/// `"unmanifested"` placeholder rather than an error, matching the
/// runtime's fail-at-execute (not at startup) contract. The snapshot is
/// taken once at service spawn; swapping artifact files under a running
/// service is outside the contract (re-spawn to pick up a new build).
pub fn fingerprint(dir: &Path) -> String {
    fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        // length separator: distinct chunkings hash differently
        h ^= bytes.len() as u64;
        h.wrapping_mul(0x0000_0100_0000_01B3)
    }
    let Ok(manifest) = std::fs::read(dir.join("manifest.json")) else {
        return "unmanifested".to_string();
    };
    let mut h = fnv(0xCBF2_9CE4_8422_2325, &manifest);
    let mut artifacts: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map(|entries| {
            entries
                .flatten()
                .map(|e| e.path())
                .filter(|p| {
                    p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| n.ends_with(".hlo.txt"))
                })
                .collect()
        })
        .unwrap_or_default();
    artifacts.sort();
    for path in artifacts {
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        h = fnv(h, name.as_bytes());
        match std::fs::read(&path) {
            Ok(bytes) => h = fnv(h, &bytes),
            // an unreadable payload must not hash like an absent one —
            // fold a marker so the damaged set gets its own id (which
            // changes again once the file is readable: never aliases)
            Err(_) => h = fnv(h, b"\xffunreadable"),
        }
    }
    format!("{h:016x}")
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let usize_field = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest missing numeric field '{k}'"))
        };
        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        for (name, spec) in arts {
            let file = spec
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("artifact '{name}' missing 'file'"))?
                .to_string();
            let mut inputs = Vec::new();
            for inp in spec
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow!("artifact '{name}' missing 'inputs'"))?
            {
                let iname = inp
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| anyhow!("input missing name"))?
                    .to_string();
                let shape: Vec<usize> = inp
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| anyhow!("input missing shape"))?
                    .iter()
                    .filter_map(|v| v.as_usize())
                    .collect();
                inputs.push((iname, shape));
            }
            let outputs: Vec<String> = spec
                .get("outputs")
                .and_then(|o| o.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|v| v.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file,
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Self {
            m_trials: usize_field("m_trials")?,
            n_max: usize_field("n_max")?,
            b_max: usize_field("b_max")?,
            p: usize_field("p")?,
            artifacts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "m_trials": 64, "n_max": 512, "b_max": 8, "p": 16,
      "artifacts": {
        "qs_arch": {
          "file": "qs_arch.hlo.txt",
          "inputs": [
            {"name": "x", "shape": [64, 512]},
            {"name": "w", "shape": [64, 512]},
            {"name": "seed", "shape": [2]},
            {"name": "params", "shape": [16]}
          ],
          "outputs": ["y_ideal", "y_fx", "y_a", "y_hat"]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.m_trials, 64);
        assert_eq!(m.p, 16);
        let a = &m.artifacts["qs_arch"];
        assert_eq!(a.input_shape("x"), Some(&[64usize, 512][..]));
        assert_eq!(a.input_shape("params"), Some(&[16usize][..]));
        assert_eq!(a.outputs.len(), 4);
        assert!(a.input_shape("nope").is_none());
    }

    #[test]
    fn p_matches_pvec_constant() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.p, crate::arch::pvec::P);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse(r#"{"m_trials": 1}"#).is_err());
    }

    #[test]
    fn fingerprint_tracks_manifest_and_artifact_bytes() {
        let dir = std::env::temp_dir().join("imclim-manifest-fp");
        let _ = std::fs::remove_dir_all(&dir);
        // absent manifest: the stubbed-runtime placeholder
        assert_eq!(fingerprint(&dir), "unmanifested");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let a = fingerprint(&dir);
        assert_eq!(a.len(), 16, "64-bit hex digest: {a}");
        assert_eq!(a, fingerprint(&dir), "stable across reads");
        // a recompile that changes only an artifact payload (same
        // manifest: names and shapes unchanged) must change the id
        std::fs::write(dir.join("qs_arch.hlo.txt"), "HloModule v1").unwrap();
        let b = fingerprint(&dir);
        assert_ne!(a, b, "artifact bytes participate");
        std::fs::write(dir.join("qs_arch.hlo.txt"), "HloModule v2").unwrap();
        let c = fingerprint(&dir);
        assert_ne!(b, c, "recompiled payload, unchanged manifest");
        // non-artifact files are ignored
        std::fs::write(dir.join("notes.txt"), "irrelevant").unwrap();
        assert_eq!(c, fingerprint(&dir));
        // and a manifest change alone still changes the id
        std::fs::write(dir.join("manifest.json"), format!("{SAMPLE} ")).unwrap();
        assert_ne!(c, fingerprint(&dir));
    }
}
