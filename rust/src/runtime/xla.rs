//! Build-time stub for the vendored `xla` PJRT bindings.
//!
//! The offline build environment does not ship the XLA/PJRT native
//! closure, so this module provides the exact API surface `runtime`
//! consumes, with every fallible entry point failing cleanly at *run*
//! time ("PJRT unavailable") instead of breaking the build. The native
//! Monte-Carlo backend (`crate::mc`) is unaffected, and the PJRT-backed
//! integration tests skip themselves when `artifacts/manifest.json` is
//! absent. To re-enable real artifact execution, replace this module
//! with the vendored `xla` crate (the signatures below are the contract).

use std::path::Path;

use anyhow::{bail, Result};

fn unavailable<T>() -> Result<T> {
    bail!(
        "PJRT/XLA runtime is not available in this build (the `xla` native \
         bindings are stubbed; use --backend native)"
    )
}

/// Host-side tensor value (stub).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Device-resident buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
