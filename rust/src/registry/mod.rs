//! Portable cache artifacts and a shared result registry.
//!
//! The sweep engine's content-addressed result cache (`engine::cache`)
//! makes warm re-runs byte-identical — but only on the machine that
//! paid for the Monte-Carlo. This subsystem makes those results
//! *portable*: a cache directory is packed into a self-verifying
//! artifact (manifest + tarball), published to a dumb registry, and
//! pulled back anywhere, so one person's (or one CI shard's)
//! Monte-Carlo spend warms everyone else's cache.
//!
//! Three layers, bottom-up:
//!
//! * [`targz`] — deterministic ustar + gzip (stored DEFLATE blocks),
//!   dependency-free both directions. Identical cache contents always
//!   produce byte-identical payloads.
//! * [`artifact`] — the artifact format: `artifact.json` (schema,
//!   backend `cache_id`, per-record sha256, grid summary, provenance)
//!   next to `payload.tar.gz`, content-addressed by the record set so
//!   re-packs of the same results dedupe. `verify` re-hashes every
//!   record and rejects tampered or truncated payloads.
//! * [`store`] + [`http`] — the registry client: `push`/`pull` against
//!   `file://` and plain-`http://` stores (any static file server is a
//!   read-only registry). Pull verifies, then unions records into the
//!   destination cache through [`engine::merge_cache_dirs`], so
//!   collisions and corrupt records degrade exactly as `imclim merge`.
//!
//! Wired to the CLI as `imclim cache pack | verify | push | pull`.
//!
//! [`engine::merge_cache_dirs`]: crate::engine::merge_cache_dirs

pub mod artifact;
pub mod http;
pub mod store;
pub mod targz;

pub use artifact::{
    load_verified, pack, read_manifest, verify, Artifact, PackReport, RecordEntry, VerifyReport,
    ARTIFACT_FILE, ARTIFACT_SCHEMA, PAYLOAD_FILE,
};
pub use store::{
    list, open_store, pull, push, FileStore, HttpStore, IndexEntry, PullReport, PushReport,
    RegistryStore, INDEX_FILE,
};
