//! Minimal HTTP/1.1 client for dumb registries (offline build: no
//! reqwest/hyper, no TLS).
//!
//! A registry over HTTP is just files behind GET — any static file
//! server works as a read-only registry; PUT support (webdav, a tiny
//! upload handler) additionally enables `push`. This client speaks
//! exactly that subset: `GET` and `PUT` with `Content-Length` (or
//! chunked responses), over plain `http://`. `https://` is gated at
//! URL-parse time with a clear error — the container has no TLS stack
//! to link against, and silently downgrading would be worse.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// A parsed `http://host[:port]/base` endpoint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpEndpoint {
    pub host: String,
    pub port: u16,
    /// Base path, always starting with `/`, no trailing `/`.
    pub base: String,
}

impl HttpEndpoint {
    pub fn parse(url: &str) -> Result<Self> {
        let rest = url
            .strip_prefix("http://")
            .context("not an http:// URL")?;
        let (authority, path) = match rest.find('/') {
            Some(i) => (&rest[..i], &rest[i..]),
            None => (rest, "/"),
        };
        ensure!(!authority.is_empty(), "http URL '{url}' has no host");
        let (host, port) = if let Some(rest6) = authority.strip_prefix('[') {
            // bracketed IPv6 literal: [addr] or [addr]:port
            let (addr, after) = rest6
                .split_once(']')
                .with_context(|| format!("unterminated IPv6 literal in '{url}'"))?;
            ensure!(!addr.is_empty(), "empty IPv6 literal in '{url}'");
            let port = match after.strip_prefix(':') {
                Some(p) => p
                    .parse::<u16>()
                    .map_err(|_| anyhow::anyhow!("bad port in '{url}'"))?,
                None => {
                    ensure!(after.is_empty(), "garbage after IPv6 literal in '{url}'");
                    80
                }
            };
            (addr.to_string(), port)
        } else {
            ensure!(
                authority.matches(':').count() <= 1,
                "IPv6 literals must be bracketed, e.g. http://[::1]:8080 (got '{url}')"
            );
            match authority.rsplit_once(':') {
                Some((h, p)) => (
                    h.to_string(),
                    p.parse::<u16>()
                        .map_err(|_| anyhow::anyhow!("bad port in '{url}'"))?,
                ),
                None => (authority.to_string(), 80),
            }
        };
        ensure!(!host.is_empty(), "http URL '{url}' has no host");
        Ok(Self {
            host,
            port,
            base: path.trim_end_matches('/').to_string(),
        })
    }

    /// Host as it appears in URLs and `Host:` headers (IPv6 literals
    /// re-bracketed; `self.host` itself stays connect-ready).
    fn host_display(&self) -> String {
        if self.host.contains(':') {
            format!("[{}]", self.host)
        } else {
            self.host.clone()
        }
    }

    pub fn url_for(&self, rel: &str) -> String {
        format!("http://{}:{}{}/{rel}", self.host_display(), self.port, self.base)
    }

    /// A sibling endpoint on the same host/port with a different base
    /// path. This is how a worker turns its coordinator connection into
    /// the per-shard artifact store a lease names (`/fabric/jobs/...`).
    pub fn with_base(&self, base: &str) -> HttpEndpoint {
        let trimmed = base.trim_end_matches('/');
        let base = if trimmed.is_empty() || trimmed.starts_with('/') {
            trimmed.to_string()
        } else {
            format!("/{trimmed}")
        };
        HttpEndpoint {
            host: self.host.clone(),
            port: self.port,
            base,
        }
    }

    fn connect(&self) -> Result<TcpStream> {
        let stream = TcpStream::connect((self.host.as_str(), self.port))
            .with_context(|| format!("connecting to {}:{}", self.host, self.port))?;
        let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
        let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
        Ok(stream)
    }

    /// GET a path relative to the base. `Ok(None)` on 404/410 (a miss,
    /// not an error); any other non-2xx status is an error.
    pub fn get(&self, rel: &str) -> Result<Option<Vec<u8>>> {
        let mut stream = self.connect()?;
        let path = format!("{}/{rel}", self.base);
        write!(
            stream,
            "GET {path} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\nAccept: */*\r\n\r\n",
            self.host_display()
        )?;
        stream.flush()?;
        let (status, body) = read_response(&mut stream)
            .with_context(|| format!("reading response for GET {}", self.url_for(rel)))?;
        match status {
            200..=299 => Ok(Some(body)),
            404 | 410 => Ok(None),
            s => bail!("GET {} failed with HTTP {s}", self.url_for(rel)),
        }
    }

    /// PUT bytes to a path relative to the base.
    pub fn put(&self, rel: &str, data: &[u8]) -> Result<()> {
        let mut stream = self.connect()?;
        let path = format!("{}/{rel}", self.base);
        write!(
            stream,
            "PUT {path} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\n\
             Content-Type: application/octet-stream\r\nContent-Length: {}\r\n\r\n",
            self.host_display(),
            data.len()
        )?;
        stream.write_all(data)?;
        stream.flush()?;
        let (status, _) = read_response(&mut stream)
            .with_context(|| format!("reading response for PUT {}", self.url_for(rel)))?;
        match status {
            200..=299 => Ok(()),
            405 | 501 => bail!(
                "PUT {} rejected (HTTP {status}): this registry is read-only — \
                 push needs a server that accepts uploads",
                self.url_for(rel)
            ),
            s => bail!("PUT {} failed with HTTP {s}", self.url_for(rel)),
        }
    }

    /// POST bytes to a path relative to the base and return the raw
    /// `(status, body)`. Unlike `get`/`put`, every status is handed to
    /// the caller — the serve daemon uses 4xx replies as meaningful
    /// answers (backpressure, bad request), not transport failures.
    pub fn post(&self, rel: &str, data: &[u8], content_type: &str) -> Result<(u16, Vec<u8>)> {
        let mut stream = self.connect()?;
        let path = format!("{}/{rel}", self.base);
        write!(
            stream,
            "POST {path} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\n\
             Content-Type: {content_type}\r\nContent-Length: {}\r\n\r\n",
            self.host_display(),
            data.len()
        )?;
        stream.write_all(data)?;
        stream.flush()?;
        read_response(&mut stream)
            .with_context(|| format!("reading response for POST {}", self.url_for(rel)))
    }

    /// GET a path and consume the response body incrementally: for a
    /// chunked response, `on_data` is called with each newly decoded
    /// slice as its chunk arrives (this is how live NDJSON progress
    /// streams from `imclim serve` are consumed before the job ends);
    /// for a `Content-Length` or close-delimited body it is called once
    /// with the whole body. Returns the complete body. Any non-2xx
    /// status is an error — a stream is only useful once accepted.
    pub fn get_stream(&self, rel: &str, mut on_data: impl FnMut(&[u8])) -> Result<Vec<u8>> {
        let mut stream = self.connect()?;
        let path = format!("{}/{rel}", self.base);
        write!(
            stream,
            "GET {path} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\nAccept: */*\r\n\r\n",
            self.host_display()
        )?;
        stream.flush()?;
        let mut raw = Vec::new();
        let mut buf = [0u8; 8192];
        let header_end = loop {
            if let Some(i) = find_header_end(&raw) {
                break i;
            }
            let n = stream.read(&mut buf)?;
            ensure!(
                n > 0,
                "connection closed mid-header on GET {}",
                self.url_for(rel)
            );
            raw.extend_from_slice(&buf[..n]);
        };
        let head = std::str::from_utf8(&raw[..header_end]).context("non-UTF-8 response header")?;
        let mut lines = head.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("bad HTTP status line '{status_line}'"))?;
        ensure!(
            (200..300).contains(&status),
            "GET {} failed with HTTP {status}",
            self.url_for(rel)
        );
        let mut content_length: Option<usize> = None;
        let mut chunked = false;
        for line in lines {
            let Some((name, value)) = line.split_once(':') else {
                continue;
            };
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.parse().ok();
            } else if name.eq_ignore_ascii_case("transfer-encoding")
                && value.to_ascii_lowercase().contains("chunked")
            {
                chunked = true;
            }
        }
        let mut leftover = raw[header_end + 4..].to_vec();
        if chunked {
            let mut body = Vec::new();
            loop {
                let before = body.len();
                let done = drain_chunk_frames(&mut leftover, &mut body)?;
                if body.len() > before {
                    on_data(&body[before..]);
                }
                if done {
                    return Ok(body);
                }
                let n = stream.read(&mut buf)?;
                if n == 0 {
                    // a close right after `0\r\n` is tolerated, as in
                    // `read_response`; anything else is truncation
                    ensure!(
                        leftover == b"0\r\n",
                        "connection closed mid-stream on GET {}",
                        self.url_for(rel)
                    );
                    return Ok(body);
                }
                leftover.extend_from_slice(&buf[..n]);
            }
        }
        let mut body = leftover;
        match content_length {
            Some(len) => {
                while body.len() < len {
                    let n = stream.read(&mut buf)?;
                    ensure!(n > 0, "connection closed mid-body ({}/{len} bytes)", body.len());
                    body.extend_from_slice(&buf[..n]);
                }
                body.truncate(len);
            }
            None => read_to_end(&mut stream, &mut body)?,
        }
        if !body.is_empty() {
            on_data(&body);
        }
        Ok(body)
    }

    /// GET returning the raw `(status, body)` without miss/error
    /// mapping; the daemon client's status polling wants 404 and 409
    /// as answers, not errors.
    pub fn get_raw(&self, rel: &str) -> Result<(u16, Vec<u8>)> {
        let mut stream = self.connect()?;
        let path = format!("{}/{rel}", self.base);
        write!(
            stream,
            "GET {path} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\nAccept: */*\r\n\r\n",
            self.host_display()
        )?;
        stream.flush()?;
        read_response(&mut stream)
            .with_context(|| format!("reading response for GET {}", self.url_for(rel)))
    }
}

/// Read a full HTTP/1.1 response: status code + body. Understands
/// `Content-Length`, `Transfer-Encoding: chunked`, and close-delimited
/// bodies; that covers every dumb file server worth pointing at.
fn read_response(stream: &mut TcpStream) -> Result<(u16, Vec<u8>)> {
    let mut raw = Vec::new();
    let mut buf = [0u8; 8192];
    // read until we have the full header block
    let header_end = loop {
        if let Some(i) = find_header_end(&raw) {
            break i;
        }
        let n = stream.read(&mut buf)?;
        if n == 0 {
            if raw.is_empty() {
                bail!("empty HTTP response");
            }
            bail!("connection closed mid-header");
        }
        raw.extend_from_slice(&buf[..n]);
    };
    let head = std::str::from_utf8(&raw[..header_end]).context("non-UTF-8 response header")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad HTTP status line '{status_line}'"))?;
    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value.parse().ok();
        } else if name.eq_ignore_ascii_case("transfer-encoding")
            && value.to_ascii_lowercase().contains("chunked")
        {
            chunked = true;
        }
    }
    let mut body = raw[header_end + 4..].to_vec();
    if chunked {
        // Decode incrementally from the chunk framing and stop at the
        // terminator. Draining to EOF first would stall against any
        // keep-alive server until the read timeout fired.
        loop {
            if let Some(decoded) = decode_chunked_step(&body, false)? {
                return Ok((status, decoded));
            }
            let n = stream.read(&mut buf)?;
            if n == 0 {
                // connection closed: a close right after `0\r\n` is
                // tolerated, anything else is truncation
                return Ok((status, decode_chunked(&body)?));
            }
            body.extend_from_slice(&buf[..n]);
        }
    }
    match content_length {
        Some(len) => {
            while body.len() < len {
                let n = stream.read(&mut buf)?;
                ensure!(n > 0, "connection closed mid-body ({}/{len} bytes)", body.len());
                body.extend_from_slice(&buf[..n]);
            }
            body.truncate(len);
        }
        None => read_to_end(stream, &mut body)?,
    }
    Ok((status, body))
}

fn read_to_end(stream: &mut TcpStream, body: &mut Vec<u8>) -> Result<()> {
    let mut buf = [0u8; 8192];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => return Ok(()),
            Ok(n) => body.extend_from_slice(&buf[..n]),
            Err(e) => return Err(e.into()),
        }
    }
}

fn find_header_end(raw: &[u8]) -> Option<usize> {
    raw.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Decode a complete chunked body (connection already at EOF).
fn decode_chunked(data: &[u8]) -> Result<Vec<u8>> {
    decode_chunked_step(data, true)?.context("truncated chunk stream")
}

/// Consume every *complete* chunk at the front of `framing`, appending
/// the payload bytes to `decoded` and draining the consumed framing.
/// Returns `true` once the terminating 0-size chunk and its (optional)
/// trailer block have been consumed; `false` means the framing so far
/// is valid but more bytes are needed. Unlike [`decode_chunked_step`],
/// partial progress is kept — this is the incremental decoder behind
/// [`HttpEndpoint::get_stream`].
fn drain_chunk_frames(framing: &mut Vec<u8>, decoded: &mut Vec<u8>) -> Result<bool> {
    loop {
        let Some(rel) = framing.windows(2).position(|w| w == b"\r\n") else {
            return Ok(false);
        };
        let size_str = std::str::from_utf8(&framing[..rel]).context("bad chunk size")?;
        let size = usize::from_str_radix(size_str.trim().split(';').next().unwrap_or("").trim(), 16)
            .with_context(|| format!("bad chunk size '{size_str}'"))?;
        if size == 0 {
            // skip trailer lines until the empty line that ends the body
            let mut pos = rel + 2;
            loop {
                let Some(tr) = framing[pos..].windows(2).position(|w| w == b"\r\n") else {
                    return Ok(false);
                };
                let line_end = pos + tr;
                if framing[pos..line_end].is_empty() {
                    framing.drain(..line_end + 2);
                    return Ok(true);
                }
                ensure!(
                    framing[pos..line_end].contains(&b':'),
                    "malformed trailer after final chunk: '{}'",
                    String::from_utf8_lossy(&framing[pos..line_end])
                );
                pos = line_end + 2;
            }
        }
        let body_start = rel + 2;
        if framing.len() < body_start + size + 2 {
            return Ok(false);
        }
        ensure!(
            &framing[body_start + size..body_start + size + 2] == b"\r\n",
            "chunk body not terminated by CRLF (malformed framing)"
        );
        decoded.extend_from_slice(&framing[body_start..body_start + size]);
        framing.drain(..body_start + size + 2);
    }
}

/// One incremental decoding attempt over the chunked-framing bytes
/// received so far. `Ok(Some(body))` once the terminating chunk and its
/// trailer block are complete; `Ok(None)` when the framing is valid but
/// incomplete and more bytes are needed; `Err` on malformed framing.
/// With `eof` set, "incomplete" hardens into an error — except a close
/// directly after `0\r\n`, which is tolerated.
fn decode_chunked_step(data: &[u8], eof: bool) -> Result<Option<Vec<u8>>> {
    let mut out = Vec::new();
    let mut pos = 0;
    loop {
        let Some(rel) = data[pos..].windows(2).position(|w| w == b"\r\n") else {
            ensure!(!eof, "truncated chunk header");
            return Ok(None);
        };
        let line_end = pos + rel;
        let size_str = std::str::from_utf8(&data[pos..line_end]).context("bad chunk size")?;
        let size = usize::from_str_radix(size_str.trim().split(';').next().unwrap_or("").trim(), 16)
            .with_context(|| format!("bad chunk size '{size_str}'"))?;
        pos = line_end + 2;
        if size == 0 {
            // after the 0-size chunk: optional trailer headers, then a
            // final CRLF. Anything else is malformed framing. (A server
            // that closes right after `0\r\n` is tolerated.)
            loop {
                if pos == data.len() {
                    return if eof { Ok(Some(out)) } else { Ok(None) };
                }
                let Some(rel) = data[pos..].windows(2).position(|w| w == b"\r\n") else {
                    ensure!(!eof, "garbage after final chunk (no CRLF)");
                    return Ok(None);
                };
                let line_end = pos + rel;
                let line = &data[pos..line_end];
                pos = line_end + 2;
                if line.is_empty() {
                    ensure!(
                        pos == data.len(),
                        "{} trailing bytes after chunked body terminator",
                        data.len() - pos
                    );
                    return Ok(Some(out));
                }
                ensure!(
                    line.contains(&b':'),
                    "malformed trailer after final chunk: '{}'",
                    String::from_utf8_lossy(line)
                );
            }
        }
        if pos + size + 2 > data.len() {
            ensure!(!eof, "truncated chunk body");
            return Ok(None);
        }
        ensure!(
            &data[pos + size..pos + size + 2] == b"\r\n",
            "chunk body not terminated by CRLF (malformed framing)"
        );
        out.extend_from_slice(&data[pos..pos + size]);
        pos += size + 2;
    }
}

// ---------------------------------------------------------------------
// Server-side primitives — the daemon's half of the protocol, built on
// the same dumb subset as the client above: HTTP/1.1 request lines,
// `Content-Length` bodies, one request per connection (`Connection:
// close`), optional chunked responses for progress streaming.
// ---------------------------------------------------------------------

/// One parsed incoming request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    /// Raw request path (no percent-decoding; the daemon's routes are
    /// all plain ASCII).
    pub path: String,
    pub body: Vec<u8>,
}

/// Hard cap on request-line + header bytes; beyond this the request is
/// rejected with 431 instead of buffering until the connection timeout.
pub const MAX_HEADER_BYTES: usize = 64 * 1024;
/// Hard cap on request body bytes (covers artifact payload uploads with
/// room to spare); larger declared bodies are rejected with 413 before
/// a single body byte is buffered.
pub const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// Why reading a request failed. Transport failures close the
/// connection silently; protocol violations carry the status the server
/// should answer with before closing.
#[derive(Debug)]
pub enum RequestError {
    /// I/O failure or client hang-up — nothing useful can be written back.
    Io(anyhow::Error),
    /// Protocol violation — answer `status` with `reason`, then close.
    Rejected { status: u16, reason: String },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Io(e) => write!(f, "{e:#}"),
            RequestError::Rejected { status, reason } => write!(f, "{status}: {reason}"),
        }
    }
}

impl std::error::Error for RequestError {}

fn reject(status: u16, reason: impl Into<String>) -> RequestError {
    RequestError::Rejected {
        status,
        reason: reason.into(),
    }
}

/// Read one HTTP/1.1 request from a stream: request line, headers
/// (only `Content-Length` is interpreted), then the body. Memory is
/// bounded: headers beyond [`MAX_HEADER_BYTES`] are rejected with 431
/// and bodies beyond [`MAX_BODY_BYTES`] with 413 — in both cases
/// without buffering the excess. A `Content-Length` that does not
/// parse is a 400 (never silently treated as an empty body), and
/// `Transfer-Encoding` framing, which this server does not speak, is
/// a 411 (chunked) or 501 (anything else).
pub fn read_request(stream: &mut impl Read) -> Result<HttpRequest, RequestError> {
    let mut raw = Vec::new();
    let mut buf = [0u8; 8192];
    let header_end = loop {
        if let Some(i) = find_header_end(&raw) {
            break i;
        }
        if raw.len() > MAX_HEADER_BYTES {
            return Err(reject(
                431,
                format!("request headers exceed {MAX_HEADER_BYTES} bytes"),
            ));
        }
        let n = stream.read(&mut buf).map_err(|e| RequestError::Io(e.into()))?;
        if n == 0 {
            let what = if raw.is_empty() { "before a request" } else { "mid-header" };
            return Err(RequestError::Io(anyhow::anyhow!(
                "connection closed {what}"
            )));
        }
        raw.extend_from_slice(&buf[..n]);
    };
    if header_end > MAX_HEADER_BYTES {
        return Err(reject(
            431,
            format!("request headers exceed {MAX_HEADER_BYTES} bytes"),
        ));
    }
    let head = std::str::from_utf8(&raw[..header_end])
        .map_err(|_| reject(400, "non-UTF-8 request header"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| reject(400, "empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| reject(400, format!("request line '{request_line}' has no path")))?
        .to_string();
    let mut content_length = 0usize;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value.trim().parse().map_err(|_| {
                reject(400, format!("malformed Content-Length '{}'", value.trim()))
            })?;
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            // This server only understands Content-Length framing;
            // parsing a framed body as raw bytes would corrupt it.
            let enc = value.trim();
            if enc.to_ascii_lowercase().contains("chunked") {
                return Err(reject(
                    411,
                    "chunked request bodies are not supported; send Content-Length",
                ));
            }
            return Err(reject(
                501,
                format!("Transfer-Encoding '{enc}' is not supported"),
            ));
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(reject(
            413,
            format!("request body of {content_length} bytes exceeds the {MAX_BODY_BYTES}-byte cap"),
        ));
    }
    let mut body = raw[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut buf).map_err(|e| RequestError::Io(e.into()))?;
        if n == 0 {
            return Err(RequestError::Io(anyhow::anyhow!(
                "connection closed mid-body ({}/{content_length} bytes)",
                body.len()
            )));
        }
        body.extend_from_slice(&buf[..n]);
    }
    body.truncate(content_length);
    Ok(HttpRequest { method, path, body })
}

/// Write a complete response with a `Content-Length` body.
pub fn write_response(
    stream: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        status_reason(status),
        body.len()
    )?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// Start a chunked-transfer response; follow with [`write_chunk`] calls
/// and a final [`finish_chunked`]. This is how the daemon streams job
/// progress without knowing the total length up front.
pub fn write_chunked_head(stream: &mut impl Write, status: u16, content_type: &str) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
        status_reason(status)
    )?;
    Ok(())
}

/// Write one chunk. Empty data is skipped — a zero-length chunk would
/// terminate the stream ([`finish_chunked`]'s job).
pub fn write_chunk(stream: &mut impl Write, data: &[u8]) -> Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(stream, "{:x}\r\n", data.len())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()?;
    Ok(())
}

/// Terminate a chunked response.
pub fn finish_chunked(stream: &mut impl Write) -> Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()?;
    Ok(())
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        204 => "No Content",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        501 => "Not Implemented",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_urls() {
        let e = HttpEndpoint::parse("http://reg.example.com/imclim/v1/").unwrap();
        assert_eq!(e.host, "reg.example.com");
        assert_eq!(e.port, 80);
        assert_eq!(e.base, "/imclim/v1");
        let e = HttpEndpoint::parse("http://127.0.0.1:8080").unwrap();
        assert_eq!(e.port, 8080);
        assert_eq!(e.base, "");
        assert_eq!(e.url_for("index.json"), "http://127.0.0.1:8080/index.json");
        assert!(HttpEndpoint::parse("https://x").is_err());
        assert!(HttpEndpoint::parse("http://:80/x").is_err());
        assert!(HttpEndpoint::parse("http://h:notaport/x").is_err());
    }

    #[test]
    fn parses_ipv6_urls() {
        let e = HttpEndpoint::parse("http://[::1]:8080/base").unwrap();
        assert_eq!(e.host, "::1");
        assert_eq!(e.port, 8080);
        assert_eq!(e.base, "/base");
        assert_eq!(e.url_for("index.json"), "http://[::1]:8080/base/index.json");
        let e = HttpEndpoint::parse("http://[fe80::2]/x").unwrap();
        assert_eq!(e.host, "fe80::2");
        assert_eq!(e.port, 80);
        // unbracketed IPv6 authorities are ambiguous — explicit error
        let err = HttpEndpoint::parse("http://::1:8080/x").unwrap_err().to_string();
        assert!(err.contains("bracketed"), "{err}");
        assert!(HttpEndpoint::parse("http://[::1/x").is_err());
        assert!(HttpEndpoint::parse("http://[]:80/x").is_err());
        assert!(HttpEndpoint::parse("http://[::1]garbage/x").is_err());
        assert!(HttpEndpoint::parse("http://[::1]:notaport/x").is_err());
    }

    #[test]
    fn decodes_chunked_bodies() {
        let body = b"4\r\nWiki\r\n5\r\npedia\r\n0\r\n\r\n";
        assert_eq!(decode_chunked(body).unwrap(), b"Wikipedia");
        assert!(decode_chunked(b"zz\r\n").is_err());
        assert!(decode_chunked(b"5\r\nab").is_err());
        // server closing right after the 0-size chunk is tolerated
        assert_eq!(decode_chunked(b"3\r\nabc\r\n0\r\n").unwrap(), b"abc");
        // optional trailers before the final CRLF are accepted
        assert_eq!(
            decode_chunked(b"3\r\nabc\r\n0\r\nX-Sum: 1\r\n\r\n").unwrap(),
            b"abc"
        );
    }

    #[test]
    fn rejects_malformed_chunked_framing() {
        // chunk body not followed by CRLF
        let err = decode_chunked(b"4\r\nWikiXX5\r\npedia\r\n0\r\n\r\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("CRLF"), "{err}");
        // trailing garbage after the terminator
        let err = decode_chunked(b"4\r\nWiki\r\n0\r\n\r\ngarbage")
            .unwrap_err()
            .to_string();
        assert!(err.contains("trailing bytes"), "{err}");
        // non-header garbage where trailers belong
        assert!(decode_chunked(b"4\r\nWiki\r\n0\r\ngarbage\r\n\r\n").is_err());
        // chunk body truncated before its CRLF
        assert!(decode_chunked(b"4\r\nWiki").is_err());
    }

    #[test]
    fn incremental_decode_waits_for_the_terminator() {
        let full = b"4\r\nWiki\r\n5\r\npedia\r\n0\r\n\r\n";
        // every proper prefix is "incomplete, read more" — never an
        // error, never a premature body
        for cut in 0..full.len() {
            let step = decode_chunked_step(&full[..cut], false).unwrap();
            assert!(step.is_none(), "prefix of {cut} bytes must not resolve");
        }
        let body = decode_chunked_step(full, false).unwrap().unwrap();
        assert_eq!(body, b"Wikipedia");
        // trailers delay the terminator but still resolve without EOF
        let trailed = b"3\r\nabc\r\n0\r\nX-Sum: 1\r\n\r\n";
        assert_eq!(
            decode_chunked_step(trailed, false).unwrap().unwrap(),
            b"abc"
        );
        // malformed framing is a hard error even mid-stream
        assert!(decode_chunked_step(b"4\r\nWikiXX", false).is_err());
        assert!(decode_chunked_step(b"zz\r\n", false).is_err());
    }

    #[test]
    fn incremental_frame_drain_keeps_partial_progress() {
        let full = b"4\r\nWiki\r\n5\r\npedia\r\n0\r\n\r\n";
        // feed byte by byte: decoded bytes must appear as soon as each
        // chunk completes, well before the terminator
        let mut framing = Vec::new();
        let mut decoded = Vec::new();
        let mut done_at = None;
        for (i, b) in full.iter().enumerate() {
            framing.push(*b);
            let done = drain_chunk_frames(&mut framing, &mut decoded).unwrap();
            if done {
                done_at = Some(i);
                break;
            }
            if i >= 9 {
                // "4\r\nWiki\r\n" is 9 bytes: the first chunk is out
                assert!(decoded.starts_with(b"Wiki"), "at byte {i}");
            }
        }
        assert_eq!(done_at, Some(full.len() - 1));
        assert_eq!(decoded, b"Wikipedia");
        assert!(framing.is_empty());
        // trailers are skipped; malformed framing still errors
        let mut f = b"3\r\nabc\r\n0\r\nX-Sum: 1\r\n\r\n".to_vec();
        let mut d = Vec::new();
        assert!(drain_chunk_frames(&mut f, &mut d).unwrap());
        assert_eq!(d, b"abc");
        let mut f = b"4\r\nWikiXX".to_vec();
        assert!(drain_chunk_frames(&mut f, &mut Vec::new()).is_err());
    }

    #[test]
    fn parses_requests_and_writes_responses() {
        let mut req: &[u8] =
            b"POST /jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nbody";
        let r = read_request(&mut req).unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/jobs");
        assert_eq!(r.body, b"body");

        let mut req: &[u8] = b"GET /healthz HTTP/1.1\r\n\r\n";
        let r = read_request(&mut req).unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert!(r.body.is_empty());

        let mut empty: &[u8] = b"";
        assert!(read_request(&mut empty).is_err());
        let mut truncated: &[u8] = b"POST /jobs HTTP/1.1\r\nContent-Length: 9\r\n\r\nbo";
        assert!(read_request(&mut truncated).is_err());

        let mut out = Vec::new();
        write_response(&mut out, 404, "text/plain", b"no such job").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("Content-Length: 11\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\nno such job"), "{text}");
    }

    #[test]
    fn derives_sibling_endpoints_with_a_new_base() {
        let e = HttpEndpoint::parse("http://127.0.0.1:7878").unwrap();
        let s = e.with_base("/fabric/jobs/3/shards/0");
        assert_eq!(s.host, "127.0.0.1");
        assert_eq!(s.port, 7878);
        assert_eq!(s.base, "/fabric/jobs/3/shards/0");
        assert_eq!(
            s.url_for("index.json"),
            "http://127.0.0.1:7878/fabric/jobs/3/shards/0/index.json"
        );
        // trailing slashes and missing leading slashes are normalized
        assert_eq!(e.with_base("fabric/x/").base, "/fabric/x");
        assert_eq!(e.with_base("").base, "");
    }

    fn rejected_status(r: Result<HttpRequest, RequestError>) -> u16 {
        match r {
            Err(RequestError::Rejected { status, .. }) => status,
            other => panic!("expected a protocol rejection, got {other:?}"),
        }
    }

    #[test]
    fn caps_header_bytes_with_431() {
        // a header that never terminates stops buffering at the cap
        let mut big = b"GET / HTTP/1.1\r\nX-Pad: ".to_vec();
        big.resize(MAX_HEADER_BYTES + 64, b'a');
        let mut r: &[u8] = &big;
        assert_eq!(rejected_status(read_request(&mut r)), 431);
        // oversized but terminated headers are rejected too
        let mut big = b"GET / HTTP/1.1\r\nX-Pad: ".to_vec();
        big.resize(MAX_HEADER_BYTES + 64, b'a');
        big.extend_from_slice(b"\r\n\r\n");
        let mut r: &[u8] = &big;
        assert_eq!(rejected_status(read_request(&mut r)), 431);
    }

    #[test]
    fn rejects_malformed_content_length_with_400() {
        // previously parsed as 0 and silently dropped the body
        let mut r: &[u8] = b"POST /jobs HTTP/1.1\r\nContent-Length: banana\r\n\r\nbody";
        assert_eq!(rejected_status(read_request(&mut r)), 400);
        let mut r: &[u8] = b"POST /jobs HTTP/1.1\r\nContent-Length: -1\r\n\r\n";
        assert_eq!(rejected_status(read_request(&mut r)), 400);
        let mut r: &[u8] = b"POST /jobs HTTP/1.1\r\nContent-Length: 4 4\r\n\r\nbody";
        assert_eq!(rejected_status(read_request(&mut r)), 400);
    }

    #[test]
    fn rejects_transfer_encoding_framing() {
        let mut r: &[u8] = b"POST /jobs HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                             4\r\nWiki\r\n0\r\n\r\n";
        assert_eq!(rejected_status(read_request(&mut r)), 411);
        let mut r: &[u8] = b"POST /jobs HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n";
        assert_eq!(rejected_status(read_request(&mut r)), 501);
    }

    #[test]
    fn caps_declared_body_bytes_with_413() {
        // rejected from the declared length alone, before any body read
        let head =
            format!("PUT /fabric/x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let mut r: &[u8] = head.as_bytes();
        assert_eq!(rejected_status(read_request(&mut r)), 413);
        // a body exactly at the cap would be fine (declared length only
        // — don't actually allocate 16 MiB in a unit test)
        let mut r: &[u8] = b"POST /jobs HTTP/1.1\r\nContent-Length: 4\r\n\r\nbody";
        assert!(read_request(&mut r).is_ok());
    }

    #[test]
    fn hangups_are_io_errors_not_rejections() {
        let mut empty: &[u8] = b"";
        assert!(matches!(read_request(&mut empty), Err(RequestError::Io(_))));
        let mut truncated: &[u8] = b"POST /jobs HTTP/1.1\r\nContent-Length: 9\r\n\r\nbo";
        assert!(matches!(
            read_request(&mut truncated),
            Err(RequestError::Io(_))
        ));
    }

    #[test]
    fn chunked_writer_roundtrips_through_the_decoder() {
        let mut out = Vec::new();
        write_chunked_head(&mut out, 200, "text/plain").unwrap();
        write_chunk(&mut out, b"Wiki").unwrap();
        write_chunk(&mut out, b"").unwrap(); // skipped, not a terminator
        write_chunk(&mut out, b"pedia").unwrap();
        finish_chunked(&mut out).unwrap();
        let head_end = out.windows(4).position(|w| w == b"\r\n\r\n").unwrap();
        let head = std::str::from_utf8(&out[..head_end]).unwrap();
        assert!(head.contains("Transfer-Encoding: chunked"), "{head}");
        assert_eq!(decode_chunked(&out[head_end + 4..]).unwrap(), b"Wikipedia");
    }
}
