//! Minimal HTTP/1.1 client for dumb registries (offline build: no
//! reqwest/hyper, no TLS).
//!
//! A registry over HTTP is just files behind GET — any static file
//! server works as a read-only registry; PUT support (webdav, a tiny
//! upload handler) additionally enables `push`. This client speaks
//! exactly that subset: `GET` and `PUT` with `Content-Length` (or
//! chunked responses), over plain `http://`. `https://` is gated at
//! URL-parse time with a clear error — the container has no TLS stack
//! to link against, and silently downgrading would be worse.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// A parsed `http://host[:port]/base` endpoint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpEndpoint {
    pub host: String,
    pub port: u16,
    /// Base path, always starting with `/`, no trailing `/`.
    pub base: String,
}

impl HttpEndpoint {
    pub fn parse(url: &str) -> Result<Self> {
        let rest = url
            .strip_prefix("http://")
            .context("not an http:// URL")?;
        let (authority, path) = match rest.find('/') {
            Some(i) => (&rest[..i], &rest[i..]),
            None => (rest, "/"),
        };
        ensure!(!authority.is_empty(), "http URL '{url}' has no host");
        let (host, port) = if let Some(rest6) = authority.strip_prefix('[') {
            // bracketed IPv6 literal: [addr] or [addr]:port
            let (addr, after) = rest6
                .split_once(']')
                .with_context(|| format!("unterminated IPv6 literal in '{url}'"))?;
            ensure!(!addr.is_empty(), "empty IPv6 literal in '{url}'");
            let port = match after.strip_prefix(':') {
                Some(p) => p
                    .parse::<u16>()
                    .map_err(|_| anyhow::anyhow!("bad port in '{url}'"))?,
                None => {
                    ensure!(after.is_empty(), "garbage after IPv6 literal in '{url}'");
                    80
                }
            };
            (addr.to_string(), port)
        } else {
            ensure!(
                authority.matches(':').count() <= 1,
                "IPv6 literals must be bracketed, e.g. http://[::1]:8080 (got '{url}')"
            );
            match authority.rsplit_once(':') {
                Some((h, p)) => (
                    h.to_string(),
                    p.parse::<u16>()
                        .map_err(|_| anyhow::anyhow!("bad port in '{url}'"))?,
                ),
                None => (authority.to_string(), 80),
            }
        };
        ensure!(!host.is_empty(), "http URL '{url}' has no host");
        Ok(Self {
            host,
            port,
            base: path.trim_end_matches('/').to_string(),
        })
    }

    /// Host as it appears in URLs and `Host:` headers (IPv6 literals
    /// re-bracketed; `self.host` itself stays connect-ready).
    fn host_display(&self) -> String {
        if self.host.contains(':') {
            format!("[{}]", self.host)
        } else {
            self.host.clone()
        }
    }

    pub fn url_for(&self, rel: &str) -> String {
        format!("http://{}:{}{}/{rel}", self.host_display(), self.port, self.base)
    }

    fn connect(&self) -> Result<TcpStream> {
        let stream = TcpStream::connect((self.host.as_str(), self.port))
            .with_context(|| format!("connecting to {}:{}", self.host, self.port))?;
        let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
        let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
        Ok(stream)
    }

    /// GET a path relative to the base. `Ok(None)` on 404/410 (a miss,
    /// not an error); any other non-2xx status is an error.
    pub fn get(&self, rel: &str) -> Result<Option<Vec<u8>>> {
        let mut stream = self.connect()?;
        let path = format!("{}/{rel}", self.base);
        write!(
            stream,
            "GET {path} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\nAccept: */*\r\n\r\n",
            self.host_display()
        )?;
        stream.flush()?;
        let (status, body) = read_response(&mut stream)
            .with_context(|| format!("reading response for GET {}", self.url_for(rel)))?;
        match status {
            200..=299 => Ok(Some(body)),
            404 | 410 => Ok(None),
            s => bail!("GET {} failed with HTTP {s}", self.url_for(rel)),
        }
    }

    /// PUT bytes to a path relative to the base.
    pub fn put(&self, rel: &str, data: &[u8]) -> Result<()> {
        let mut stream = self.connect()?;
        let path = format!("{}/{rel}", self.base);
        write!(
            stream,
            "PUT {path} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\n\
             Content-Type: application/octet-stream\r\nContent-Length: {}\r\n\r\n",
            self.host_display(),
            data.len()
        )?;
        stream.write_all(data)?;
        stream.flush()?;
        let (status, _) = read_response(&mut stream)
            .with_context(|| format!("reading response for PUT {}", self.url_for(rel)))?;
        match status {
            200..=299 => Ok(()),
            405 | 501 => bail!(
                "PUT {} rejected (HTTP {status}): this registry is read-only — \
                 push needs a server that accepts uploads",
                self.url_for(rel)
            ),
            s => bail!("PUT {} failed with HTTP {s}", self.url_for(rel)),
        }
    }
}

/// Read a full HTTP/1.1 response: status code + body. Understands
/// `Content-Length`, `Transfer-Encoding: chunked`, and close-delimited
/// bodies; that covers every dumb file server worth pointing at.
fn read_response(stream: &mut TcpStream) -> Result<(u16, Vec<u8>)> {
    let mut raw = Vec::new();
    let mut buf = [0u8; 8192];
    // read until we have the full header block
    let header_end = loop {
        if let Some(i) = find_header_end(&raw) {
            break i;
        }
        let n = stream.read(&mut buf)?;
        if n == 0 {
            if raw.is_empty() {
                bail!("empty HTTP response");
            }
            bail!("connection closed mid-header");
        }
        raw.extend_from_slice(&buf[..n]);
    };
    let head = std::str::from_utf8(&raw[..header_end]).context("non-UTF-8 response header")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad HTTP status line '{status_line}'"))?;
    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value.parse().ok();
        } else if name.eq_ignore_ascii_case("transfer-encoding")
            && value.to_ascii_lowercase().contains("chunked")
        {
            chunked = true;
        }
    }
    let mut body = raw[header_end + 4..].to_vec();
    if chunked {
        // drain the stream, then decode the chunked framing
        read_to_end(stream, &mut body)?;
        return Ok((status, decode_chunked(&body)?));
    }
    match content_length {
        Some(len) => {
            while body.len() < len {
                let n = stream.read(&mut buf)?;
                ensure!(n > 0, "connection closed mid-body ({}/{len} bytes)", body.len());
                body.extend_from_slice(&buf[..n]);
            }
            body.truncate(len);
        }
        None => read_to_end(stream, &mut body)?,
    }
    Ok((status, body))
}

fn read_to_end(stream: &mut TcpStream, body: &mut Vec<u8>) -> Result<()> {
    let mut buf = [0u8; 8192];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => return Ok(()),
            Ok(n) => body.extend_from_slice(&buf[..n]),
            Err(e) => return Err(e.into()),
        }
    }
}

fn find_header_end(raw: &[u8]) -> Option<usize> {
    raw.windows(4).position(|w| w == b"\r\n\r\n")
}

fn decode_chunked(data: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    let mut pos = 0;
    loop {
        ensure!(pos <= data.len(), "truncated chunk stream");
        let line_end = data[pos..]
            .windows(2)
            .position(|w| w == b"\r\n")
            .context("truncated chunk header")?
            + pos;
        let size_str = std::str::from_utf8(&data[pos..line_end]).context("bad chunk size")?;
        let size = usize::from_str_radix(size_str.trim().split(';').next().unwrap_or("").trim(), 16)
            .with_context(|| format!("bad chunk size '{size_str}'"))?;
        pos = line_end + 2;
        if size == 0 {
            // after the 0-size chunk: optional trailer headers, then a
            // final CRLF. Anything else is malformed framing. (A server
            // that closes right after `0\r\n` is tolerated.)
            while pos < data.len() {
                let line_end = data[pos..]
                    .windows(2)
                    .position(|w| w == b"\r\n")
                    .context("garbage after final chunk (no CRLF)")?
                    + pos;
                let line = &data[pos..line_end];
                pos = line_end + 2;
                if line.is_empty() {
                    ensure!(
                        pos == data.len(),
                        "{} trailing bytes after chunked body terminator",
                        data.len() - pos
                    );
                    break;
                }
                ensure!(
                    line.contains(&b':'),
                    "malformed trailer after final chunk: '{}'",
                    String::from_utf8_lossy(line)
                );
            }
            return Ok(out);
        }
        ensure!(pos + size + 2 <= data.len(), "truncated chunk body");
        ensure!(
            &data[pos + size..pos + size + 2] == b"\r\n",
            "chunk body not terminated by CRLF (malformed framing)"
        );
        out.extend_from_slice(&data[pos..pos + size]);
        pos += size + 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_urls() {
        let e = HttpEndpoint::parse("http://reg.example.com/imclim/v1/").unwrap();
        assert_eq!(e.host, "reg.example.com");
        assert_eq!(e.port, 80);
        assert_eq!(e.base, "/imclim/v1");
        let e = HttpEndpoint::parse("http://127.0.0.1:8080").unwrap();
        assert_eq!(e.port, 8080);
        assert_eq!(e.base, "");
        assert_eq!(e.url_for("index.json"), "http://127.0.0.1:8080/index.json");
        assert!(HttpEndpoint::parse("https://x").is_err());
        assert!(HttpEndpoint::parse("http://:80/x").is_err());
        assert!(HttpEndpoint::parse("http://h:notaport/x").is_err());
    }

    #[test]
    fn parses_ipv6_urls() {
        let e = HttpEndpoint::parse("http://[::1]:8080/base").unwrap();
        assert_eq!(e.host, "::1");
        assert_eq!(e.port, 8080);
        assert_eq!(e.base, "/base");
        assert_eq!(e.url_for("index.json"), "http://[::1]:8080/base/index.json");
        let e = HttpEndpoint::parse("http://[fe80::2]/x").unwrap();
        assert_eq!(e.host, "fe80::2");
        assert_eq!(e.port, 80);
        // unbracketed IPv6 authorities are ambiguous — explicit error
        let err = HttpEndpoint::parse("http://::1:8080/x").unwrap_err().to_string();
        assert!(err.contains("bracketed"), "{err}");
        assert!(HttpEndpoint::parse("http://[::1/x").is_err());
        assert!(HttpEndpoint::parse("http://[]:80/x").is_err());
        assert!(HttpEndpoint::parse("http://[::1]garbage/x").is_err());
        assert!(HttpEndpoint::parse("http://[::1]:notaport/x").is_err());
    }

    #[test]
    fn decodes_chunked_bodies() {
        let body = b"4\r\nWiki\r\n5\r\npedia\r\n0\r\n\r\n";
        assert_eq!(decode_chunked(body).unwrap(), b"Wikipedia");
        assert!(decode_chunked(b"zz\r\n").is_err());
        assert!(decode_chunked(b"5\r\nab").is_err());
        // server closing right after the 0-size chunk is tolerated
        assert_eq!(decode_chunked(b"3\r\nabc\r\n0\r\n").unwrap(), b"abc");
        // optional trailers before the final CRLF are accepted
        assert_eq!(
            decode_chunked(b"3\r\nabc\r\n0\r\nX-Sum: 1\r\n\r\n").unwrap(),
            b"abc"
        );
    }

    #[test]
    fn rejects_malformed_chunked_framing() {
        // chunk body not followed by CRLF
        let err = decode_chunked(b"4\r\nWikiXX5\r\npedia\r\n0\r\n\r\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("CRLF"), "{err}");
        // trailing garbage after the terminator
        let err = decode_chunked(b"4\r\nWiki\r\n0\r\n\r\ngarbage")
            .unwrap_err()
            .to_string();
        assert!(err.contains("trailing bytes"), "{err}");
        // non-header garbage where trailers belong
        assert!(decode_chunked(b"4\r\nWiki\r\n0\r\ngarbage\r\n\r\n").is_err());
        // chunk body truncated before its CRLF
        assert!(decode_chunked(b"4\r\nWiki").is_err());
    }
}
