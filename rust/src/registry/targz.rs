//! Deterministic tar + gzip codec for artifact payloads.
//!
//! `payload.tar.gz` must be *reproducible*: packing the same cache twice
//! — on any machine, at any time — must emit identical bytes, so the
//! artifact's content address is a pure function of the records it
//! carries. To that end the writer pins every nondeterministic tar
//! field (mtime 0, uid/gid 0, mode 0644, sorted entries) and the gzip
//! layer emits *stored* (uncompressed) DEFLATE blocks: still a valid
//! gzip stream any `gunzip` can read, but byte-stable and
//! dependency-free in both directions. The reader checks the gzip CRC32
//! and length trailer, so a truncated or bit-flipped payload fails
//! before any record is even unpacked; it accepts only the stored
//! blocks this writer emits (artifact payloads are always written by
//! `imclim cache pack` — a compressed foreign gzip is rejected with a
//! clear error, not mis-read).

use anyhow::{bail, ensure, Result};

/// One file in a payload archive.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    /// Path inside the archive (relative, `/`-separated).
    pub name: String,
    pub data: Vec<u8>,
}

// ---------------------------------------------------------------------
// tar (ustar)
// ---------------------------------------------------------------------

const BLOCK: usize = 512;

/// Serialize entries as a ustar archive. Entries are sorted by name and
/// all metadata fields are pinned, so the output is deterministic.
pub fn tar_pack(entries: &[Entry]) -> Result<Vec<u8>> {
    let mut sorted: Vec<&Entry> = entries.iter().collect();
    sorted.sort_by(|a, b| a.name.cmp(&b.name));
    let mut out = Vec::new();
    for e in sorted {
        ensure!(
            e.name.len() <= 100,
            "tar entry name '{}' exceeds 100 bytes",
            e.name
        );
        ensure!(!e.name.is_empty(), "empty tar entry name");
        let mut hdr = [0u8; BLOCK];
        hdr[..e.name.len()].copy_from_slice(e.name.as_bytes());
        hdr[100..108].copy_from_slice(b"0000644\0"); // mode
        hdr[108..116].copy_from_slice(b"0000000\0"); // uid
        hdr[116..124].copy_from_slice(b"0000000\0"); // gid
        let size = format!("{:011o}\0", e.data.len());
        hdr[124..136].copy_from_slice(size.as_bytes());
        hdr[136..148].copy_from_slice(b"00000000000\0"); // mtime 0
        hdr[148..156].copy_from_slice(b"        "); // checksum placeholder
        hdr[156] = b'0'; // regular file
        hdr[257..263].copy_from_slice(b"ustar\0");
        hdr[263..265].copy_from_slice(b"00");
        let sum: u32 = hdr.iter().map(|&b| b as u32).sum();
        let chk = format!("{sum:06o}\0 ");
        hdr[148..156].copy_from_slice(chk.as_bytes());
        out.extend_from_slice(&hdr);
        out.extend_from_slice(&e.data);
        let pad = (BLOCK - e.data.len() % BLOCK) % BLOCK;
        out.resize(out.len() + pad, 0);
    }
    out.resize(out.len() + 2 * BLOCK, 0); // end-of-archive marker
    Ok(out)
}

/// Parse a ustar archive produced by [`tar_pack`] (regular files only).
pub fn tar_unpack(bytes: &[u8]) -> Result<Vec<Entry>> {
    let mut out = Vec::new();
    let mut pos = 0;
    loop {
        ensure!(pos + BLOCK <= bytes.len(), "truncated tar header");
        let hdr = &bytes[pos..pos + BLOCK];
        if hdr.iter().all(|&b| b == 0) {
            break; // end-of-archive marker
        }
        let name_end = hdr[..100].iter().position(|&b| b == 0).unwrap_or(100);
        let name = std::str::from_utf8(&hdr[..name_end])
            .map_err(|_| anyhow::anyhow!("non-UTF-8 tar entry name"))?
            .to_string();
        let stored_chk = parse_octal(&hdr[148..156])?;
        let mut summed = hdr.to_vec();
        summed[148..156].copy_from_slice(b"        ");
        let actual: u64 = summed.iter().map(|&b| b as u64).sum();
        ensure!(
            stored_chk == actual,
            "tar header checksum mismatch for '{name}'"
        );
        let size = parse_octal(&hdr[124..136])? as usize;
        let typeflag = hdr[156];
        ensure!(
            typeflag == b'0' || typeflag == 0,
            "unsupported tar entry type {typeflag} for '{name}'"
        );
        pos += BLOCK;
        ensure!(pos + size <= bytes.len(), "truncated tar data for '{name}'");
        out.push(Entry {
            name,
            data: bytes[pos..pos + size].to_vec(),
        });
        pos += size + (BLOCK - size % BLOCK) % BLOCK;
    }
    Ok(out)
}

fn parse_octal(field: &[u8]) -> Result<u64> {
    let mut v: u64 = 0;
    let mut seen = false;
    for &b in field {
        match b {
            b'0'..=b'7' => {
                v = v
                    .checked_mul(8)
                    .and_then(|v| v.checked_add((b - b'0') as u64))
                    .ok_or_else(|| anyhow::anyhow!("tar octal field overflows"))?;
                seen = true;
            }
            0 | b' ' => {}
            _ => bail!("bad tar octal field byte {b}"),
        }
    }
    ensure!(seen, "empty tar octal field");
    Ok(v)
}

// ---------------------------------------------------------------------
// gzip (stored DEFLATE blocks)
// ---------------------------------------------------------------------

/// Wrap bytes in a gzip stream of stored (uncompressed) DEFLATE blocks.
/// Header mtime/OS are pinned, so the output is deterministic.
pub fn gzip(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() + data.len() / 65_535 * 5 + 23);
    out.extend_from_slice(&[0x1f, 0x8b, 8, 0, 0, 0, 0, 0, 0, 0xff]);
    let mut chunks = data.chunks(65_535).peekable();
    if data.is_empty() {
        out.extend_from_slice(&[1, 0, 0, 0xff, 0xff]); // final empty stored block
    }
    while let Some(chunk) = chunks.next() {
        out.push(if chunks.peek().is_none() { 1 } else { 0 }); // BFINAL, BTYPE=00
        let len = chunk.len() as u16;
        out.extend_from_slice(&len.to_le_bytes());
        out.extend_from_slice(&(!len).to_le_bytes());
        out.extend_from_slice(chunk);
    }
    out.extend_from_slice(&crc32(data).to_le_bytes());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out
}

/// Decode a gzip stream of stored DEFLATE blocks, verifying the CRC32
/// and length trailer. Compressed (Huffman) blocks — which this codec
/// never writes — are rejected, as is any truncation or corruption.
pub fn gunzip(bytes: &[u8]) -> Result<Vec<u8>> {
    ensure!(bytes.len() >= 18, "gzip stream too short");
    ensure!(
        bytes[0] == 0x1f && bytes[1] == 0x8b,
        "not a gzip stream (bad magic)"
    );
    ensure!(bytes[2] == 8, "unsupported gzip compression method");
    let flg = bytes[3];
    let mut pos = 10;
    if flg & 0x04 != 0 {
        // FEXTRA
        ensure!(pos + 2 <= bytes.len(), "truncated gzip FEXTRA");
        let xlen = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]) as usize;
        pos += 2 + xlen;
    }
    for bit in [0x08u8, 0x10] {
        // FNAME, FCOMMENT: zero-terminated strings
        if flg & bit != 0 {
            while pos < bytes.len() && bytes[pos] != 0 {
                pos += 1;
            }
            pos += 1;
        }
    }
    if flg & 0x02 != 0 {
        pos += 2; // FHCRC
    }
    ensure!(pos + 8 <= bytes.len(), "truncated gzip stream");

    let mut out = Vec::new();
    loop {
        ensure!(pos < bytes.len() - 8, "gzip deflate stream ran off the end");
        let hdr = bytes[pos];
        let bfinal = hdr & 1;
        let btype = (hdr >> 1) & 3;
        ensure!(
            btype == 0,
            "unsupported deflate block type {btype} (artifact payloads use stored blocks)"
        );
        pos += 1;
        ensure!(pos + 4 <= bytes.len() - 8, "truncated stored block header");
        let len = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]) as usize;
        let nlen = u16::from_le_bytes([bytes[pos + 2], bytes[pos + 3]]);
        ensure!(
            nlen == !(len as u16),
            "stored block LEN/NLEN mismatch (corrupt payload)"
        );
        pos += 4;
        ensure!(pos + len <= bytes.len() - 8, "truncated stored block data");
        out.extend_from_slice(&bytes[pos..pos + len]);
        pos += len;
        if bfinal == 1 {
            break;
        }
    }
    let crc = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
    let isize = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
    ensure!(
        crc == crc32(&out),
        "gzip CRC32 mismatch (payload corrupt or truncated)"
    );
    ensure!(
        isize == out.len() as u32,
        "gzip length trailer mismatch (payload corrupt or truncated)"
    );
    Ok(out)
}

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc: u32 = !0;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries() -> Vec<Entry> {
        vec![
            Entry {
                name: "b.json".into(),
                data: b"{\"v\": 2}".to_vec(),
            },
            Entry {
                name: "a.json".into(),
                data: vec![0u8; 700], // spans two tar blocks
            },
            Entry {
                name: "empty.json".into(),
                data: Vec::new(),
            },
        ]
    }

    #[test]
    fn tar_roundtrip_sorts_and_preserves_bytes() {
        let packed = tar_pack(&entries()).unwrap();
        assert_eq!(packed.len() % BLOCK, 0);
        let got = tar_unpack(&packed).unwrap();
        let names: Vec<&str> = got.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["a.json", "b.json", "empty.json"]);
        assert_eq!(got[0].data, vec![0u8; 700]);
        assert_eq!(got[1].data, b"{\"v\": 2}");
        assert!(got[2].data.is_empty());
    }

    #[test]
    fn tar_pack_is_deterministic_under_input_order() {
        let a = tar_pack(&entries()).unwrap();
        let mut rev = entries();
        rev.reverse();
        assert_eq!(a, tar_pack(&rev).unwrap());
    }

    #[test]
    fn tar_rejects_damage() {
        let packed = tar_pack(&entries()).unwrap();
        // header corruption breaks the checksum
        let mut bad = packed.clone();
        bad[0] ^= 0xff;
        assert!(tar_unpack(&bad).is_err());
        // truncation inside a data block
        assert!(tar_unpack(&packed[..600]).is_err());
        assert!(tar_pack(&[Entry {
            name: "x".repeat(101),
            data: vec![],
        }])
        .is_err());
    }

    #[test]
    fn gzip_roundtrip_all_sizes() {
        for n in [0usize, 1, 100, 65_535, 65_536, 200_000] {
            let data: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
            let z = gzip(&data);
            assert_eq!(gunzip(&z).unwrap(), data, "size {n}");
        }
    }

    #[test]
    fn gzip_is_deterministic() {
        let data = b"same bytes in, same bytes out".to_vec();
        assert_eq!(gzip(&data), gzip(&data));
    }

    #[test]
    fn gunzip_rejects_corruption_and_truncation() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 256) as u8).collect();
        let z = gzip(&data);
        // single-byte payload tamper -> CRC failure
        for idx in [15, z.len() / 2, z.len() - 9] {
            let mut bad = z.clone();
            bad[idx] ^= 1;
            assert!(gunzip(&bad).is_err(), "tamper at byte {idx}");
        }
        // truncation at several points
        for keep in [0, 5, 17, z.len() / 2, z.len() - 1] {
            assert!(gunzip(&z[..keep]).is_err(), "truncated to {keep}");
        }
        // not gzip at all
        assert!(gunzip(b"definitely not gzip bytes").is_err());
    }

    #[test]
    fn crc32_known_answer() {
        // the classic check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
