//! The portable cache artifact format: `artifact.json` + `payload.tar.gz`.
//!
//! An artifact is a self-describing, verifiable snapshot of one
//! content-addressed cache directory (RFC-0005-style manifest+tarball):
//!
//! * `artifact.json` — schema version, the backend `cache_id` that
//!   produced the records, per-record SHA-256 / size / label, record
//!   count, a grid/axis summary decoded from the records themselves,
//!   and provenance (crate version + the creating invocation);
//! * `payload.tar.gz` — the record files (plus the cache's
//!   `manifest.json` label index when present), packed deterministically
//!   (`registry::targz`), so identical cache contents produce
//!   byte-identical artifacts and therefore the same content address.
//!
//! The artifact **id** is a SHA-256 over the sorted `(key, sha256)`
//! record pairs, the backend id and the label-index hash — a pure
//! content address: *what* results, not when/where/why they were packed
//! (provenance deliberately does not participate, so re-packing the
//! same cache from a different invocation dedupes in the registry).
//!
//! [`verify`] re-hashes every record against the manifest and rejects
//! tampered, truncated, reordered, padded or mislabeled payloads;
//! [`load_verified`] additionally hands back the payload entries for
//! unpacking (the `pull` path), so nothing unverified ever reaches a
//! cache directory.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::arch::pvec;
use crate::engine::{list_record_files, manifest_backend, manifest_labels, MANIFEST_FILE};
use crate::registry::targz::{self, Entry};
use crate::util::json::{num, obj, s, Json};
use crate::util::sha256::{sha256_hex, Sha256};

/// Artifact schema version; bump on any incompatible layout change.
pub const ARTIFACT_SCHEMA: f64 = 1.0;
/// Manifest filename inside an artifact directory.
pub const ARTIFACT_FILE: &str = "artifact.json";
/// Payload tarball filename inside an artifact directory.
pub const PAYLOAD_FILE: &str = "payload.tar.gz";

/// Domain-separation prefix for the artifact content address.
const ID_PREFIX: &str = "imclim-artifact-v1";

/// One record as listed in `artifact.json`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RecordEntry {
    pub sha256: String,
    pub bytes: u64,
    /// Human label from the cache manifest (may be empty).
    pub label: String,
}

/// Decoded `artifact.json`.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub schema: f64,
    /// Content address: SHA-256 over the sorted record hashes + backend.
    pub id: String,
    /// Backend `cache_id` of the packed cache (e.g. `native@0.2.0`).
    pub backend: String,
    /// Crate version that packed the artifact.
    pub crate_version: String,
    /// The creating invocation (`imclim cache pack ...`), free-form.
    pub creation_params: String,
    pub record_count: usize,
    pub records: BTreeMap<String, RecordEntry>,
    /// SHA-256 of the embedded cache `manifest.json`, when present.
    pub cache_manifest_sha256: Option<String>,
    pub payload_sha256: String,
    pub payload_bytes: u64,
    /// Grid/axis summary decoded from the records (informational).
    pub summary: Json,
}

impl Artifact {
    /// One-line provenance for `cache stats` and reports.
    pub fn provenance_line(&self) -> String {
        format!(
            "schema {}, id {}..., backend {}, {} records, packed by imclim {}{}",
            self.schema as u64,
            &self.id[..12.min(self.id.len())],
            self.backend,
            self.record_count,
            self.crate_version,
            if self.creation_params.is_empty() {
                String::new()
            } else {
                format!(" ({})", self.creation_params)
            }
        )
    }
}

/// What [`pack`] did.
#[derive(Clone, Debug)]
pub struct PackReport {
    pub id: String,
    pub records: usize,
    pub payload_bytes: u64,
}

/// What [`verify`] established.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub id: String,
    pub backend: String,
    pub records: usize,
    pub payload_bytes: u64,
}

/// Compute the content address over sorted record hashes, the backend
/// id, and the label-index hash.
fn artifact_id(
    backend: &str,
    records: &BTreeMap<String, RecordEntry>,
    cache_manifest_sha256: Option<&str>,
) -> String {
    let mut h = Sha256::new();
    h.update(ID_PREFIX.as_bytes());
    h.update(b"\nbackend:");
    h.update(backend.as_bytes());
    for (key, r) in records {
        // BTreeMap iterates sorted by key
        h.update(b"\nrecord:");
        h.update(key.as_bytes());
        h.update(b":");
        h.update(r.sha256.as_bytes());
    }
    if let Some(m) = cache_manifest_sha256 {
        h.update(b"\nmanifest:");
        h.update(m.as_bytes());
    }
    h.finish_hex()
}

/// Pack `cache_dir` into `artifact_dir/{artifact.json,payload.tar.gz}`.
/// `creation_params` is recorded as provenance (it does not affect the
/// content address). Deterministic: identical cache contents produce
/// byte-identical payloads and ids.
pub fn pack(cache_dir: &Path, artifact_dir: &Path, creation_params: &str) -> Result<PackReport> {
    let files = list_record_files(cache_dir)?;
    ensure!(
        !files.is_empty(),
        "nothing to pack: no cache records in {}",
        cache_dir.display()
    );
    let labels = manifest_labels(cache_dir);
    let backend = manifest_backend(cache_dir).unwrap_or_else(|| "unknown".into());

    let mut records: BTreeMap<String, RecordEntry> = BTreeMap::new();
    let mut entries: Vec<Entry> = Vec::with_capacity(files.len() + 1);
    let mut parsed: Vec<Json> = Vec::with_capacity(files.len());
    for (key, path) in &files {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        if let Ok(j) = Json::parse(&String::from_utf8_lossy(&bytes)) {
            parsed.push(j);
        }
        records.insert(
            key.clone(),
            RecordEntry {
                sha256: sha256_hex(&bytes),
                bytes: bytes.len() as u64,
                label: labels.get(key).cloned().unwrap_or_default(),
            },
        );
        entries.push(Entry {
            name: format!("{key}.json"),
            data: bytes,
        });
    }
    let cache_manifest_sha256 = match std::fs::read(cache_dir.join(MANIFEST_FILE)) {
        Ok(bytes) => {
            let hash = sha256_hex(&bytes);
            entries.push(Entry {
                name: MANIFEST_FILE.to_string(),
                data: bytes,
            });
            Some(hash)
        }
        Err(_) => None,
    };

    let payload = targz::gzip(&targz::tar_pack(&entries)?);
    let id = artifact_id(&backend, &records, cache_manifest_sha256.as_deref());
    let artifact = Artifact {
        schema: ARTIFACT_SCHEMA,
        id: id.clone(),
        backend,
        crate_version: env!("CARGO_PKG_VERSION").to_string(),
        creation_params: creation_params.to_string(),
        record_count: records.len(),
        records,
        cache_manifest_sha256,
        payload_sha256: sha256_hex(&payload),
        payload_bytes: payload.len() as u64,
        summary: summarize(&parsed),
    };

    std::fs::create_dir_all(artifact_dir)
        .with_context(|| format!("creating {}", artifact_dir.display()))?;
    let payload_path = artifact_dir.join(PAYLOAD_FILE);
    std::fs::write(&payload_path, &payload)
        .with_context(|| format!("writing {}", payload_path.display()))?;
    let manifest_path = artifact_dir.join(ARTIFACT_FILE);
    std::fs::write(&manifest_path, encode(&artifact).to_string())
        .with_context(|| format!("writing {}", manifest_path.display()))?;
    Ok(PackReport {
        id: artifact.id,
        records: artifact.record_count,
        payload_bytes: artifact.payload_bytes,
    })
}

/// Grid/axis summary decoded from the record JSONs: how many sweep vs
/// memo records, which architectures, the distinct trial counts, and
/// the N range. Informational only — never trusted by `verify`.
fn summarize(parsed: &[Json]) -> Json {
    let mut kinds: BTreeMap<String, usize> = BTreeMap::new();
    let mut trials: Vec<u64> = Vec::new();
    let mut memo = 0usize;
    let mut sweep = 0usize;
    let (mut n_min, mut n_max) = (f64::INFINITY, f64::NEG_INFINITY);
    for j in parsed {
        if j.get("tag").is_some() {
            memo += 1;
            continue;
        }
        let Some(kind) = j.get("kind").and_then(|k| k.as_str()) else {
            continue;
        };
        sweep += 1;
        *kinds.entry(kind.to_string()).or_insert(0) += 1;
        if let Some(t) = j.get("trials").and_then(|t| t.as_f64()) {
            let t = t as u64;
            if !trials.contains(&t) {
                trials.push(t);
            }
        }
        // params are stored as IEEE-754 hex strings; slot 0 is N
        if let Some(hex) = j
            .get("params")
            .and_then(|p| p.idx(pvec::IDX_N_ACTIVE))
            .and_then(|v| v.as_str())
        {
            if let Ok(bits) = u64::from_str_radix(hex, 16) {
                let n = f64::from_bits(bits);
                n_min = n_min.min(n);
                n_max = n_max.max(n);
            }
        }
    }
    trials.sort_unstable();
    let mut fields = vec![
        ("sweep_records", num(sweep as f64)),
        ("memo_records", num(memo as f64)),
        (
            "kinds",
            Json::Obj(
                kinds
                    .into_iter()
                    .map(|(k, v)| (k, num(v as f64)))
                    .collect(),
            ),
        ),
        (
            "trials",
            Json::Arr(trials.into_iter().map(|t| num(t as f64)).collect()),
        ),
    ];
    if n_min.is_finite() {
        fields.push(("n_min", num(n_min)));
        fields.push(("n_max", num(n_max)));
    }
    obj(fields)
}

fn encode(a: &Artifact) -> Json {
    let records = Json::Obj(
        a.records
            .iter()
            .map(|(k, r)| {
                (
                    k.clone(),
                    obj(vec![
                        ("sha256", s(&r.sha256)),
                        ("bytes", num(r.bytes as f64)),
                        ("label", s(&r.label)),
                    ]),
                )
            })
            .collect(),
    );
    let mut fields = vec![
        ("schema", num(a.schema)),
        ("id", s(&a.id)),
        ("backend", s(&a.backend)),
        (
            "provenance",
            obj(vec![
                ("crate_version", s(&a.crate_version)),
                ("creation_params", s(&a.creation_params)),
            ]),
        ),
        ("record_count", num(a.record_count as f64)),
        ("records", records),
        (
            "payload",
            obj(vec![
                ("file", s(PAYLOAD_FILE)),
                ("sha256", s(&a.payload_sha256)),
                ("bytes", num(a.payload_bytes as f64)),
            ]),
        ),
        ("summary", a.summary.clone()),
    ];
    if let Some(m) = &a.cache_manifest_sha256 {
        fields.push(("cache_manifest_sha256", s(m)));
    }
    obj(fields)
}

/// Decode `artifact.json` text. Structural defects are hard errors here
/// (unlike cache records, an artifact is an exchange format: silently
/// degrading a bad manifest to "empty" would defeat verification).
pub fn decode(text: &str) -> Result<Artifact> {
    let j = Json::parse(text).map_err(|e| anyhow::anyhow!("artifact.json is not JSON: {e}"))?;
    let schema = j
        .get("schema")
        .and_then(|v| v.as_f64())
        .context("artifact.json: missing schema")?;
    ensure!(
        schema == ARTIFACT_SCHEMA,
        "unsupported artifact schema {schema} (this build reads schema {ARTIFACT_SCHEMA})"
    );
    let str_field = |name: &str| -> Result<String> {
        j.get(name)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .with_context(|| format!("artifact.json: missing {name}"))
    };
    let provenance = j.get("provenance").context("artifact.json: missing provenance")?;
    let payload = j.get("payload").context("artifact.json: missing payload")?;
    let mut records = BTreeMap::new();
    for (key, v) in j
        .get("records")
        .and_then(|r| r.as_obj())
        .context("artifact.json: missing records")?
    {
        records.insert(
            key.clone(),
            RecordEntry {
                sha256: v
                    .get("sha256")
                    .and_then(|x| x.as_str())
                    .with_context(|| format!("record {key}: missing sha256"))?
                    .to_string(),
                bytes: v
                    .get("bytes")
                    .and_then(|x| x.as_f64())
                    .with_context(|| format!("record {key}: missing bytes"))? as u64,
                label: v
                    .get("label")
                    .and_then(|x| x.as_str())
                    .unwrap_or_default()
                    .to_string(),
            },
        );
    }
    Ok(Artifact {
        schema,
        id: str_field("id")?,
        backend: str_field("backend")?,
        crate_version: provenance
            .get("crate_version")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string(),
        creation_params: provenance
            .get("creation_params")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string(),
        record_count: j
            .get("record_count")
            .and_then(|v| v.as_f64())
            .context("artifact.json: missing record_count")? as usize,
        records,
        cache_manifest_sha256: j
            .get("cache_manifest_sha256")
            .and_then(|v| v.as_str())
            .map(str::to_string),
        payload_sha256: payload
            .get("sha256")
            .and_then(|v| v.as_str())
            .context("artifact.json: payload missing sha256")?
            .to_string(),
        payload_bytes: payload
            .get("bytes")
            .and_then(|v| v.as_f64())
            .context("artifact.json: payload missing bytes")? as u64,
        summary: j.get("summary").cloned().unwrap_or(Json::Null),
    })
}

/// Read an artifact directory's manifest without verifying the payload
/// (for `cache stats` and listings).
pub fn read_manifest(artifact_dir: &Path) -> Result<Artifact> {
    let path = artifact_dir.join(ARTIFACT_FILE);
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
    decode(&text)
}

/// Verify manifest+payload from raw bytes and hand back the verified
/// payload entries. Every check is a hard error: payload hash/size,
/// per-record hash/size, record-count agreement, extra or missing
/// payload members, label-index hash, and the recomputed content
/// address.
pub fn verify_bytes(manifest_text: &str, payload: &[u8]) -> Result<(Artifact, Vec<Entry>)> {
    let artifact = decode(manifest_text)?;
    ensure!(
        artifact.record_count == artifact.records.len(),
        "record count mismatch: artifact.json claims {} records but lists {}",
        artifact.record_count,
        artifact.records.len()
    );
    ensure!(
        artifact.payload_bytes == payload.len() as u64,
        "payload size mismatch: artifact.json says {} bytes, payload is {} (truncated?)",
        artifact.payload_bytes,
        payload.len()
    );
    let payload_hash = sha256_hex(payload);
    ensure!(
        payload_hash == artifact.payload_sha256,
        "payload sha256 mismatch: expected {}, got {payload_hash} (payload tampered)",
        artifact.payload_sha256
    );
    let entries = targz::tar_unpack(&targz::gunzip(payload)?)?;

    let mut seen: BTreeMap<&str, &Entry> = BTreeMap::new();
    let mut cache_manifest: Option<&Entry> = None;
    for e in &entries {
        if e.name == MANIFEST_FILE {
            ensure!(
                cache_manifest.is_none(),
                "payload carries duplicate {MANIFEST_FILE}"
            );
            cache_manifest = Some(e);
            continue;
        }
        let key = e
            .name
            .strip_suffix(".json")
            .with_context(|| format!("unexpected payload member '{}'", e.name))?;
        let listed = artifact
            .records
            .get(key)
            .with_context(|| format!("payload member '{}' is not in artifact.json", e.name))?;
        let hash = sha256_hex(&e.data);
        ensure!(
            hash == listed.sha256,
            "record {key} sha256 mismatch: expected {}, got {hash} (record tampered)",
            listed.sha256
        );
        ensure!(
            e.data.len() as u64 == listed.bytes,
            "record {key} size mismatch: expected {} bytes, got {}",
            listed.bytes,
            e.data.len()
        );
        ensure!(
            seen.insert(key, e).is_none(),
            "payload carries duplicate record {key}"
        );
    }
    for key in artifact.records.keys() {
        ensure!(
            seen.contains_key(key.as_str()),
            "record {key} listed in artifact.json is missing from the payload"
        );
    }
    match (&artifact.cache_manifest_sha256, cache_manifest) {
        (Some(expect), Some(e)) => {
            let hash = sha256_hex(&e.data);
            ensure!(
                &hash == expect,
                "cache manifest sha256 mismatch: expected {expect}, got {hash}"
            );
        }
        (Some(_), None) => bail!("cache manifest listed in artifact.json is missing"),
        (None, Some(_)) => bail!("payload carries an unlisted cache manifest"),
        (None, None) => {}
    }
    let recomputed = artifact_id(
        &artifact.backend,
        &artifact.records,
        artifact.cache_manifest_sha256.as_deref(),
    );
    ensure!(
        recomputed == artifact.id,
        "artifact id mismatch: manifest claims {}, content hashes to {recomputed}",
        artifact.id
    );
    Ok((artifact, entries))
}

/// Verify an artifact directory and hand back the verified entries.
pub fn load_verified(artifact_dir: &Path) -> Result<(Artifact, Vec<Entry>)> {
    let manifest_path = artifact_dir.join(ARTIFACT_FILE);
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let payload_path = artifact_dir.join(PAYLOAD_FILE);
    let payload =
        std::fs::read(&payload_path).with_context(|| format!("reading {}", payload_path.display()))?;
    verify_bytes(&text, &payload)
}

/// Verify an artifact directory: re-hash every record against the
/// manifest, rejecting tampered/truncated payloads.
pub fn verify(artifact_dir: &Path) -> Result<VerifyReport> {
    let (artifact, _) = load_verified(artifact_dir)?;
    Ok(VerifyReport {
        id: artifact.id,
        backend: artifact.backend,
        records: artifact.record_count,
        payload_bytes: artifact.payload_bytes,
    })
}

/// Write verified payload entries out as a cache directory (records +
/// label index). The result is a plain cache dir, ready for
/// `merge_cache_dirs`.
pub fn unpack_entries(entries: &[Entry], cache_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(cache_dir)
        .with_context(|| format!("creating {}", cache_dir.display()))?;
    for e in entries {
        ensure!(
            !e.name.contains('/') && !e.name.contains('\\') && !e.name.starts_with('.'),
            "refusing payload member with path component: '{}'",
            e.name
        );
        let path = cache_dir.join(&e.name);
        std::fs::write(&path, &e.data).with_context(|| format!("writing {}", path.display()))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("imclim-artifact-unit-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// A minimal fake cache dir: two records + a manifest.
    fn fake_cache(name: &str) -> std::path::PathBuf {
        let dir = tmp(name);
        std::fs::write(dir.join("aaaa.json"), b"{\"version\": 1, \"v\": 1}").unwrap();
        std::fs::write(dir.join("bbbb.json"), b"{\"version\": 1, \"v\": 2}").unwrap();
        std::fs::write(
            dir.join(MANIFEST_FILE),
            b"{\"version\":1,\"backend\":\"native@test\",\"entries\":{\"aaaa\":\"lbl/a\",\"bbbb\":\"lbl/b\"}}",
        )
        .unwrap();
        dir
    }

    #[test]
    fn pack_verify_roundtrip_and_determinism() {
        let cache = fake_cache("roundtrip");
        let art1 = tmp("roundtrip-art1");
        let art2 = tmp("roundtrip-art2");
        let r1 = pack(&cache, &art1, "cache pack --out-dir x").unwrap();
        assert_eq!(r1.records, 2);
        let report = verify(&art1).unwrap();
        assert_eq!(report.records, 2);
        assert_eq!(report.backend, "native@test");
        assert_eq!(report.id, r1.id);
        // packing the same cache again is byte-identical (same address)
        let r2 = pack(&cache, &art2, "cache pack --out-dir x").unwrap();
        assert_eq!(r1.id, r2.id);
        assert_eq!(
            std::fs::read(art1.join(PAYLOAD_FILE)).unwrap(),
            std::fs::read(art2.join(PAYLOAD_FILE)).unwrap()
        );
        assert_eq!(
            std::fs::read(art1.join(ARTIFACT_FILE)).unwrap(),
            std::fs::read(art2.join(ARTIFACT_FILE)).unwrap()
        );
        // labels rode along
        let a = read_manifest(&art1).unwrap();
        assert_eq!(a.records["aaaa"].label, "lbl/a");
        // ...but provenance does not move the content address
        let art3 = tmp("roundtrip-art3");
        let r3 = pack(&cache, &art3, "some other invocation").unwrap();
        assert_eq!(r1.id, r3.id);
    }

    #[test]
    fn unpack_restores_the_cache_byte_identically() {
        let cache = fake_cache("unpack");
        let art = tmp("unpack-art");
        pack(&cache, &art, "").unwrap();
        let (_, entries) = load_verified(&art).unwrap();
        let restored = tmp("unpack-restored");
        unpack_entries(&entries, &restored).unwrap();
        for f in ["aaaa.json", "bbbb.json", MANIFEST_FILE] {
            assert_eq!(
                std::fs::read(cache.join(f)).unwrap(),
                std::fs::read(restored.join(f)).unwrap(),
                "{f}"
            );
        }
    }

    #[test]
    fn verify_rejects_payload_tamper_and_truncation() {
        let cache = fake_cache("tamper");
        let art = tmp("tamper-art");
        pack(&cache, &art, "").unwrap();
        let payload = std::fs::read(art.join(PAYLOAD_FILE)).unwrap();
        // flip one byte at several offsets
        for idx in [0, payload.len() / 2, payload.len() - 1] {
            let mut bad = payload.clone();
            bad[idx] ^= 1;
            std::fs::write(art.join(PAYLOAD_FILE), &bad).unwrap();
            assert!(verify(&art).is_err(), "tamper at byte {idx} must fail");
        }
        // truncation
        std::fs::write(art.join(PAYLOAD_FILE), &payload[..payload.len() - 7]).unwrap();
        assert!(verify(&art).is_err(), "truncated payload must fail");
        // restore -> verifies again
        std::fs::write(art.join(PAYLOAD_FILE), &payload).unwrap();
        verify(&art).unwrap();
    }

    #[test]
    fn verify_rejects_manifest_defects() {
        let cache = fake_cache("manifest-defects");
        let art = tmp("manifest-defects-art");
        pack(&cache, &art, "").unwrap();
        let text = std::fs::read_to_string(art.join(ARTIFACT_FILE)).unwrap();
        // record-count mismatch
        let bad = text.replace("\"record_count\":2", "\"record_count\":3");
        assert_ne!(bad, text);
        std::fs::write(art.join(ARTIFACT_FILE), &bad).unwrap();
        let err = verify(&art).unwrap_err().to_string();
        assert!(err.contains("record count mismatch"), "{err}");
        // tampered record hash
        let a = decode(&text).unwrap();
        let victim = a.records["aaaa"].sha256.clone();
        let head = if victim.starts_with('0') { "1" } else { "0" };
        let forged = format!("{head}{}", &victim[1..]);
        assert_ne!(forged, victim);
        let bad = text.replace(&victim, &forged);
        std::fs::write(art.join(ARTIFACT_FILE), &bad).unwrap();
        assert!(verify(&art).is_err(), "forged record hash must fail");
        // unsupported schema
        let bad = text.replace("\"schema\":1", "\"schema\":99");
        std::fs::write(art.join(ARTIFACT_FILE), &bad).unwrap();
        let err = verify(&art).unwrap_err().to_string();
        assert!(err.contains("unsupported artifact schema"), "{err}");
        // garbage manifest is a hard error, not an empty artifact
        std::fs::write(art.join(ARTIFACT_FILE), "{ not json").unwrap();
        assert!(verify(&art).is_err());
    }

    fn rec(sha: &str) -> RecordEntry {
        RecordEntry {
            sha256: sha.to_string(),
            bytes: 1,
            label: String::new(),
        }
    }

    /// The content address must be reproducible by external sha256
    /// tooling over the documented preimage. The expected digest was
    /// computed with `printf ... | sha256sum`, NOT with this crate.
    #[test]
    fn artifact_id_known_answer_matches_external_sha256() {
        let mut records = BTreeMap::new();
        records.insert("k1".to_string(), rec(&"1".repeat(64)));
        records.insert("k2".to_string(), rec(&"2".repeat(64)));
        let id = artifact_id("native@test", &records, Some(&"3".repeat(64)));
        assert_eq!(
            id,
            "6b918653d47a0385403d5d846d2f9cd783ce9ef349b105f411188f71a38c3d29"
        );
        // and the streaming hash agrees with a one-shot over the
        // concatenated preimage
        let preimage = format!(
            "imclim-artifact-v1\nbackend:native@test\nrecord:k1:{}\nrecord:k2:{}\nmanifest:{}",
            "1".repeat(64),
            "2".repeat(64),
            "3".repeat(64)
        );
        assert_eq!(id, sha256_hex(preimage.as_bytes()));
    }

    /// The id commits to record *keys*, the backend, and the label
    /// index — not just the record content hashes.
    #[test]
    fn artifact_id_changes_with_key_backend_or_manifest() {
        let mut records = BTreeMap::new();
        records.insert("k1".to_string(), rec(&"1".repeat(64)));
        records.insert("k2".to_string(), rec(&"2".repeat(64)));
        let base = artifact_id("native@test", &records, Some(&"3".repeat(64)));

        // same record bytes under a different key
        let mut renamed = records.clone();
        let r = renamed.remove("k2").unwrap();
        renamed.insert("k9".to_string(), r);
        assert_ne!(base, artifact_id("native@test", &renamed, Some(&"3".repeat(64))));

        // different backend, identical records
        assert_ne!(base, artifact_id("pjrt@test", &records, Some(&"3".repeat(64))));

        // different or absent label-index hash
        assert_ne!(base, artifact_id("native@test", &records, Some(&"4".repeat(64))));
        assert_ne!(base, artifact_id("native@test", &records, None));
    }

    #[test]
    fn pack_refuses_an_empty_cache() {
        let dir = tmp("empty");
        let art = tmp("empty-art");
        assert!(pack(&dir, &art, "").is_err());
    }

    #[test]
    fn unpack_refuses_path_traversal() {
        let dst = tmp("traversal");
        let evil = vec![Entry {
            name: "../evil.json".into(),
            data: vec![],
        }];
        assert!(unpack_entries(&evil, &dst).is_err());
    }
}
