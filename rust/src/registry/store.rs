//! The registry client: publish and fetch cache artifacts against a
//! *dumb* store — no server-side logic beyond GET (and PUT for push).
//!
//! Registry layout (package-repo-index style, cf. wolfpack's
//! `packagesite` / `sum`+`path` metadata):
//!
//! ```text
//!   <base>/index.json                       # id -> {backend, records, bytes}
//!   <base>/artifacts/<id>/artifact.json     # the verifiable manifest
//!   <base>/artifacts/<id>/payload.tar.gz    # the record tarball
//! ```
//!
//! Artifacts live under their *content address* (`Artifact::id`), so a
//! re-push of identical content is a no-op and two registries can be
//! mirrored by plain file copy. `push` verifies locally before
//! publishing (a registry never receives bytes that don't check out);
//! `pull` verifies after fetching and then unions the records into the
//! destination cache through the same [`merge_cache_dirs`] path a
//! distributed sweep uses — collisions and corrupt records degrade
//! exactly as they do for `imclim merge`.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::engine::merge_cache_dirs;
use crate::registry::artifact::{
    load_verified, unpack_entries, verify_bytes, Artifact, ARTIFACT_FILE, PAYLOAD_FILE,
};
use crate::registry::http::HttpEndpoint;
use crate::registry::targz::Entry;
use crate::util::json::{num, obj, s, Json};

/// Registry index filename.
pub const INDEX_FILE: &str = "index.json";
const INDEX_VERSION: f64 = 1.0;

/// A dumb blob store addressed by relative `/`-separated paths.
pub trait RegistryStore {
    /// Fetch a blob; `Ok(None)` means "not there" (a miss, not an error).
    fn get(&self, rel: &str) -> Result<Option<Vec<u8>>>;
    /// Publish a blob (creating parents as needed).
    fn put(&self, rel: &str, data: &[u8]) -> Result<()>;
    /// Human-readable location for reports.
    fn describe(&self) -> String;
}

/// `file://` (or bare-path) store: a registry is just a directory.
pub struct FileStore {
    root: PathBuf,
}

impl FileStore {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        Self { root: root.into() }
    }
}

impl RegistryStore for FileStore {
    fn get(&self, rel: &str) -> Result<Option<Vec<u8>>> {
        let path = self.root.join(rel);
        match std::fs::read(&path) {
            Ok(b) => Ok(Some(b)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e).with_context(|| format!("reading {}", path.display())),
        }
    }

    fn put(&self, rel: &str, data: &[u8]) -> Result<()> {
        let path = self.root.join(rel);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        std::fs::write(&path, data).with_context(|| format!("writing {}", path.display()))
    }

    fn describe(&self) -> String {
        format!("file://{}", self.root.display())
    }
}

/// `http://` store backed by the minimal client in `registry::http`.
pub struct HttpStore {
    endpoint: HttpEndpoint,
}

impl HttpStore {
    /// Point at an explicit endpoint (workers build these from a
    /// coordinator connection plus a lease's store path; everyone else
    /// goes through [`open_store`]).
    pub fn new(endpoint: HttpEndpoint) -> Self {
        HttpStore { endpoint }
    }
}

impl RegistryStore for HttpStore {
    fn get(&self, rel: &str) -> Result<Option<Vec<u8>>> {
        self.endpoint.get(rel)
    }

    fn put(&self, rel: &str, data: &[u8]) -> Result<()> {
        self.endpoint.put(rel, data)
    }

    fn describe(&self) -> String {
        self.endpoint.url_for("")
    }
}

/// Open a registry URL: `file:///path`, `http://host[:port]/base`, or a
/// bare filesystem path. `https://` is gated (no TLS in the offline
/// build) with an explicit error rather than a silent downgrade.
pub fn open_store(url: &str) -> Result<Box<dyn RegistryStore>> {
    if let Some(path) = url.strip_prefix("file://") {
        ensure!(!path.is_empty(), "file:// URL '{url}' has no path");
        return Ok(Box::new(FileStore::new(path)));
    }
    if url.starts_with("http://") {
        return Ok(Box::new(HttpStore {
            endpoint: HttpEndpoint::parse(url)?,
        }));
    }
    if url.starts_with("https://") {
        bail!(
            "https:// registries are not supported in this offline build (no TLS stack); \
             use http:// inside a trusted network or a file:// mirror"
        );
    }
    if url.contains("://") {
        bail!("unsupported registry URL scheme in '{url}' (file:// or http://)");
    }
    // bare path: treat as a file registry for convenience
    Ok(Box::new(FileStore::new(url)))
}

fn artifact_path(id: &str, file: &str) -> String {
    format!("artifacts/{id}/{file}")
}

/// One `index.json` row.
#[derive(Clone, Debug)]
pub struct IndexEntry {
    pub id: String,
    pub backend: String,
    pub records: usize,
    pub payload_bytes: u64,
}

/// Parse `index.json` (missing/corrupt tolerated as empty on push — the
/// index is a convenience listing; artifacts themselves are the truth).
fn parse_index(bytes: Option<&[u8]>) -> Vec<IndexEntry> {
    let Some(bytes) = bytes else {
        return Vec::new();
    };
    let Ok(text) = std::str::from_utf8(bytes) else {
        return Vec::new();
    };
    let Ok(j) = Json::parse(text) else {
        return Vec::new();
    };
    let Some(arts) = j.get("artifacts").and_then(|a| a.as_obj()) else {
        return Vec::new();
    };
    arts.iter()
        .map(|(id, v)| IndexEntry {
            id: id.clone(),
            backend: v
                .get("backend")
                .and_then(|b| b.as_str())
                .unwrap_or_default()
                .to_string(),
            records: v
                .get("records")
                .and_then(|r| r.as_f64())
                .unwrap_or_default() as usize,
            payload_bytes: v
                .get("payload_bytes")
                .and_then(|b| b.as_f64())
                .unwrap_or_default() as u64,
        })
        .collect()
}

fn encode_index(entries: &[IndexEntry]) -> Json {
    obj(vec![
        ("version", num(INDEX_VERSION)),
        (
            "artifacts",
            Json::Obj(
                entries
                    .iter()
                    .map(|e| {
                        (
                            e.id.clone(),
                            obj(vec![
                                ("backend", s(&e.backend)),
                                ("records", num(e.records as f64)),
                                ("payload_bytes", num(e.payload_bytes as f64)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
    ])
}

/// List a registry's artifacts (sorted by id; empty registry is empty).
pub fn list(store: &dyn RegistryStore) -> Result<Vec<IndexEntry>> {
    let mut entries = parse_index(store.get(INDEX_FILE)?.as_deref());
    entries.sort_by(|a, b| a.id.cmp(&b.id));
    Ok(entries)
}

/// What [`push`] did.
#[derive(Clone, Debug)]
pub struct PushReport {
    pub id: String,
    pub records: usize,
    pub payload_bytes: u64,
    /// The artifact was already present under its content address.
    pub already_present: bool,
}

/// Publish a packed artifact directory. The artifact is re-verified
/// locally first, then written under its content address (payload
/// before manifest, so a half-push is never listable), and the index is
/// refreshed. Pushing content that is already present is a no-op.
///
/// The index refresh is a read-modify-write with no locking — the dumb
/// store contract has no conditional PUT to build one on. The registry
/// therefore assumes a **single pusher at a time**: two concurrent
/// pushes can lose each other's index row. The damage is bounded — the
/// artifact itself stays fetchable by id (`pull --id`), only
/// `list`/pull-everything misses it — and repair is a re-push of the
/// dropped artifact, which is cheap because the content blobs dedupe.
///
/// The worker fabric (`coordinator::remote`) obeys the same rule from
/// the other side: shard leases live in one `imclim serve` process's
/// memory, so there is exactly **one coordinator per shared cache** —
/// it alone merges worker artifacts (each pushed to a private
/// single-pusher `/fabric` store) into that cache. Standing up two
/// coordinators over one cache directory is as unsupported as two
/// concurrent pushers to one registry.
pub fn push(artifact_dir: &Path, store: &dyn RegistryStore) -> Result<PushReport> {
    let (artifact, _) = load_verified(artifact_dir)
        .with_context(|| format!("verifying {} before push", artifact_dir.display()))?;
    let id = artifact.id.clone();
    let already_present = store.get(&artifact_path(&id, ARTIFACT_FILE))?.is_some();
    if !already_present {
        let payload = std::fs::read(artifact_dir.join(PAYLOAD_FILE))?;
        store.put(&artifact_path(&id, PAYLOAD_FILE), &payload)?;
        let manifest = std::fs::read(artifact_dir.join(ARTIFACT_FILE))?;
        store.put(&artifact_path(&id, ARTIFACT_FILE), &manifest)?;
    }
    // refresh the index either way (it may be missing or stale)
    let mut entries = parse_index(store.get(INDEX_FILE)?.as_deref());
    entries.retain(|e| e.id != id);
    entries.push(IndexEntry {
        id: id.clone(),
        backend: artifact.backend,
        records: artifact.record_count,
        payload_bytes: artifact.payload_bytes,
    });
    entries.sort_by(|a, b| a.id.cmp(&b.id));
    store.put(INDEX_FILE, encode_index(&entries).to_string().as_bytes())?;
    Ok(PushReport {
        id,
        records: artifact.record_count,
        payload_bytes: artifact.payload_bytes,
        already_present,
    })
}

/// What [`pull`] did.
#[derive(Clone, Debug, Default)]
pub struct PullReport {
    /// Ids of the artifacts fetched and merged.
    pub artifacts: Vec<String>,
    /// Records newly copied into the destination cache.
    pub copied: usize,
    /// Records already present with byte-identical payloads.
    pub identical: usize,
    /// Keys whose incoming payload differed from the destination's
    /// (destination kept — same rule as `imclim merge`).
    pub collisions: Vec<String>,
    /// Distinct backends across the pulled artifacts + destination.
    pub backends: Vec<String>,
}

/// Fetch one artifact's manifest+payload and verify them together,
/// handing back the verified payload entries for unpacking.
fn fetch_verified(store: &dyn RegistryStore, id: &str) -> Result<(Artifact, Vec<Entry>)> {
    let manifest = store
        .get(&artifact_path(id, ARTIFACT_FILE))?
        .with_context(|| format!("artifact {id} not found at {}", store.describe()))?;
    let manifest_text = String::from_utf8(manifest).context("artifact.json is not UTF-8")?;
    let payload = store
        .get(&artifact_path(id, PAYLOAD_FILE))?
        .with_context(|| format!("artifact {id} has no payload at {}", store.describe()))?;
    let (artifact, entries) = verify_bytes(&manifest_text, &payload)
        .with_context(|| format!("verifying artifact {id}"))?;
    ensure!(
        artifact.id == id,
        "artifact at address {id} declares id {} (registry corrupt)",
        artifact.id
    );
    Ok((artifact, entries))
}

/// Pull artifacts into `<cache_dst>`: fetch, verify, unpack to a
/// scratch dir, then [`merge_cache_dirs`] into the destination so
/// key collisions follow the exact `imclim merge` rules. With `id`
/// only that artifact is pulled; otherwise every artifact in the index.
pub fn pull(store: &dyn RegistryStore, cache_dst: &Path, id: Option<&str>) -> Result<PullReport> {
    let ids: Vec<String> = match id {
        Some(one) => vec![one.to_string()],
        None => {
            let entries = list(store)?;
            ensure!(
                !entries.is_empty(),
                "registry {} has no index (or an empty one): nothing to pull \
                 (push an artifact first, or pass --id)",
                store.describe()
            );
            entries.into_iter().map(|e| e.id).collect()
        }
    };

    let mut report = PullReport::default();
    let scratch_root = cache_dst.with_extension("pull-tmp");
    let _ = std::fs::remove_dir_all(&scratch_root);
    for id in &ids {
        let (artifact, entries) = fetch_verified(store, id)?;
        let scratch = scratch_root.join(id);
        unpack_entries(&entries, &scratch)?;
        let merged = merge_cache_dirs(cache_dst, &[scratch.clone()])?;
        report.copied += merged.copied;
        report.identical += merged.identical;
        report.collisions.extend(merged.collisions);
        for b in merged.backends {
            if !report.backends.contains(&b) {
                report.backends.push(b);
            }
        }
        if !report.backends.contains(&artifact.backend) {
            report.backends.push(artifact.backend);
        }
        report.artifacts.push(id.clone());
    }
    let _ = std::fs::remove_dir_all(&scratch_root);
    report.collisions.sort();
    report.collisions.dedup();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::artifact::pack;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("imclim-store-unit-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn fake_cache(name: &str) -> PathBuf {
        let dir = tmp(name);
        std::fs::write(dir.join("k1.json"), b"{\"r\": 1}").unwrap();
        std::fs::write(dir.join("k2.json"), b"{\"r\": 2}").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            b"{\"version\":1,\"backend\":\"native@test\",\"entries\":{\"k1\":\"a\",\"k2\":\"b\"}}",
        )
        .unwrap();
        dir
    }

    #[test]
    fn open_store_dispatches_schemes() {
        assert!(open_store("file:///tmp/reg").is_ok());
        assert!(open_store("/tmp/bare-path").is_ok());
        assert!(open_store("http://localhost:1234/reg").is_ok());
        let err = open_store("https://reg.example.com")
            .err()
            .expect("https must be gated")
            .to_string();
        assert!(err.contains("no TLS"), "{err}");
        assert!(open_store("ftp://nope").is_err());
        assert!(open_store("file://").is_err());
    }

    #[test]
    fn push_pull_roundtrip_through_a_file_store() {
        let cache = fake_cache("pp-cache");
        let art = tmp("pp-art");
        pack(&cache, &art, "test").unwrap();
        let store = FileStore::new(tmp("pp-registry"));

        let pushed = push(&art, &store).unwrap();
        assert!(!pushed.already_present);
        assert_eq!(pushed.records, 2);
        // re-push of identical content is a no-op
        let again = push(&art, &store).unwrap();
        assert!(again.already_present);
        assert_eq!(again.id, pushed.id);
        let listed = list(&store).unwrap();
        assert_eq!(listed.len(), 1);
        assert_eq!(listed[0].id, pushed.id);
        assert_eq!(listed[0].backend, "native@test");

        // pull into a fresh cache dir: byte-identical to the source
        let dst = tmp("pp-dst").join("cache");
        let report = pull(&store, &dst, None).unwrap();
        assert_eq!(report.copied, 2);
        assert_eq!(report.artifacts, vec![pushed.id.clone()]);
        assert!(report.collisions.is_empty());
        for f in ["k1.json", "k2.json"] {
            assert_eq!(
                std::fs::read(cache.join(f)).unwrap(),
                std::fs::read(dst.join(f)).unwrap(),
                "{f}"
            );
        }
        // pulling again finds everything already present
        let report = pull(&store, &dst, Some(&pushed.id)).unwrap();
        assert_eq!(report.copied, 0);
        assert_eq!(report.identical, 2);
    }

    #[test]
    fn pull_applies_merge_collision_rules() {
        let cache = fake_cache("coll-cache");
        let art = tmp("coll-art");
        pack(&cache, &art, "").unwrap();
        let store = FileStore::new(tmp("coll-registry"));
        push(&art, &store).unwrap();

        // destination already holds k1 with a *different* payload
        let dst = tmp("coll-dst").join("cache");
        std::fs::create_dir_all(&dst).unwrap();
        std::fs::write(dst.join("k1.json"), b"{\"r\": 111}").unwrap();
        let report = pull(&store, &dst, None).unwrap();
        assert_eq!(report.collisions, vec!["k1".to_string()]);
        assert_eq!(report.copied, 1, "only k2 is new");
        // existing record wins, exactly like imclim merge
        assert_eq!(std::fs::read(dst.join("k1.json")).unwrap(), b"{\"r\": 111}");
    }

    #[test]
    fn pull_rejects_a_tampered_registry() {
        let cache = fake_cache("reg-tamper-cache");
        let art = tmp("reg-tamper-art");
        pack(&cache, &art, "").unwrap();
        let root = tmp("reg-tamper-registry");
        let store = FileStore::new(root.clone());
        let pushed = push(&art, &store).unwrap();

        // corrupt the published payload in place
        let payload_path = root
            .join("artifacts")
            .join(&pushed.id)
            .join(PAYLOAD_FILE);
        let mut bytes = std::fs::read(&payload_path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&payload_path, &bytes).unwrap();

        let dst = tmp("reg-tamper-dst").join("cache");
        let err = pull(&store, &dst, None).unwrap_err().to_string();
        assert!(err.contains(&pushed.id[..12]), "{err}");
        // nothing landed in the destination cache
        assert!(crate::engine::list_record_files(&dst).unwrap().is_empty());
    }

    #[test]
    fn pull_from_an_empty_registry_is_a_clear_error() {
        let store = FileStore::new(tmp("empty-registry"));
        let dst = tmp("empty-dst").join("cache");
        let err = pull(&store, &dst, None).unwrap_err().to_string();
        assert!(err.contains("nothing to pull"), "{err}");
    }
}
