//! The charge-summing (QS) in-memory compute model (Sec. IV-B):
//! variable mapping (y_o -> V_o, w_j -> I_j, x_j -> T_j), eq. (16), with
//! noise (eqs. 17-20), energy (eq. 21) and delay models.

use crate::tech::{TechNode, K_BOLTZMANN, TEMPERATURE};

/// A configured QS analog core: one bit-line with `rows` cells driven at
/// `v_wl`, integrating cell current over up to `t_max` on `c_bl`.
#[derive(Clone, Copy, Debug)]
pub struct QsModel {
    pub tech: TechNode,
    /// Word-line voltage [V] — the paper's energy/accuracy knob.
    pub v_wl: f64,
    /// Bit-line capacitance [F].
    pub c_bl: f64,
    /// Maximum WL pulse width T_max [s].
    pub t_max: f64,
    /// Access transistor W/L.
    pub wl_ratio: f64,
    /// Switch/pulse-generation setup energy per BL op [J].
    pub e_su: f64,
    /// Precharge + current setup time [s].
    pub t_su: f64,
}

impl QsModel {
    pub fn new(tech: TechNode, v_wl: f64) -> Self {
        Self {
            tech,
            v_wl,
            c_bl: tech.c_bl_512,
            t_max: tech.t0,
            // W/L = 1.5 calibrates k_h(0.8 V) ~ 44, reproducing both the
            // QS-Arch N_max ~ 125 of Fig. 9(a) and the CM eta_h/eta_e
            // balance of Fig. 11(a) (see DESIGN.md §1).
            wl_ratio: 1.5,
            e_su: 0.5e-15,
            t_su: 100e-12,
        }
    }

    pub fn with_rows(mut self, rows: usize) -> Self {
        self.c_bl = self.tech.c_bl(rows);
        self
    }

    /// Cell read current I_j [A] (eq. 31).
    pub fn cell_current(&self) -> f64 {
        self.tech.cell_current(self.v_wl, self.wl_ratio)
    }

    /// Unit BL discharge Delta-V_BL,unit = I (T_max - t_rf) / C_BL [V].
    ///
    /// Includes the deterministic rise/fall discharge deficit of eq. (36):
    /// every active cell integrates over (T_j - t_rf), so t_rf is a pure
    /// gain factor absorbed into the unit (the ADC reference is set by
    /// the realized unit discharge, not the ideal-pulse one). The
    /// zero-mean pulse-width *mismatch* remains a noise term.
    pub fn delta_v_unit(&self) -> f64 {
        self.cell_current() * (self.t_max - self.t_rf()).max(0.1 * self.t_max)
            / self.c_bl
    }

    /// Headroom clip level in unit counts: k_h = dV_max / dV_unit.
    pub fn k_h(&self) -> f64 {
        self.tech.dv_bl_max / self.delta_v_unit()
    }

    /// Eq. (18): normalized current mismatch sigma_D.
    pub fn sigma_d(&self) -> f64 {
        self.tech.sigma_d(self.v_wl)
    }

    /// Eq. (19): rise/fall discharge deficit t_rf [s]; normalized fraction
    /// of T_max returned by `t_rf_rel`.
    pub fn t_rf(&self) -> f64 {
        let t = &self.tech;
        let tr = t.t_rise;
        let tf = t.t_rise;
        tr - ((self.v_wl - t.v_t) / self.v_wl) * (tr + tf) / (t.alpha + 1.0)
    }

    pub fn t_rf_rel(&self) -> f64 {
        (self.t_rf() / self.t_max).clamp(0.0, 1.0)
    }

    /// Eq. (20): pulse-width mismatch sigma_Tj = sqrt(h_j) sigma_T0 with
    /// h_j = T_max / T_0 driver stages; returned normalized to T_max.
    pub fn sigma_t_rel(&self) -> f64 {
        let h = (self.t_max / self.tech.t0).max(1.0);
        h.sqrt() * self.tech.sigma_t0 / self.t_max
    }

    /// Eq. (20): integrated BL thermal noise sigma_theta [V] for `n` rows.
    pub fn sigma_theta_volts(&self, n: usize) -> f64 {
        let var = n as f64 * self.t_max * self.tech.g_m * K_BOLTZMANN * TEMPERATURE
            / 3.0
            / (self.c_bl * self.c_bl);
        var.sqrt()
    }

    /// Thermal noise in unit counts.
    pub fn sigma_theta_counts(&self, n: usize) -> f64 {
        self.sigma_theta_volts(n) / self.delta_v_unit()
    }

    /// Eq. (21): average energy of one binarized BL operation [J], given
    /// the expected (clipped) discharge in unit counts.
    pub fn energy_per_bl_op(&self, expected_counts: f64) -> f64 {
        let ev = (expected_counts * self.delta_v_unit()).min(self.tech.dv_bl_max);
        ev * self.tech.v_dd * self.c_bl + self.e_su
    }

    /// Delay of one QS compute cycle: T_QS = T_max + T_su.
    pub fn delay(&self) -> f64 {
        self.t_max + self.t_su
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qs(v_wl: f64) -> QsModel {
        QsModel::new(TechNode::n65(), v_wl)
    }

    #[test]
    fn unit_discharge_is_millivolts() {
        // tens-of-uA cell current on hundreds-of-fF over ~100 ps: mV scale.
        let m = qs(0.8);
        let dv = m.delta_v_unit();
        assert!(dv > 5e-3 && dv < 40e-3, "{dv}");
    }

    #[test]
    fn k_h_decreases_with_v_wl() {
        // Higher V_WL -> larger unit discharge -> earlier clipping.
        assert!(qs(0.8).k_h() < qs(0.6).k_h());
        let kh = qs(0.8).k_h();
        assert!(kh > 20.0 && kh < 120.0, "{kh}");
    }

    #[test]
    fn sigma_d_increases_as_v_wl_drops() {
        assert!(qs(0.6).sigma_d() > qs(0.8).sigma_d());
        assert!((qs(0.8).sigma_d() - 0.107).abs() < 0.003);
    }

    #[test]
    fn pulse_noise_small_relative_to_current_noise() {
        // Paper Sec. IV-B: sigma_T/T 0.5%-3%, far below sigma_D 8%-25%.
        let m = qs(0.7);
        assert!(m.sigma_t_rel() < 0.05);
        assert!(m.sigma_t_rel() < m.sigma_d() / 3.0);
    }

    #[test]
    fn thermal_noise_sub_millivolt() {
        let m = qs(0.7);
        let s = m.sigma_theta_volts(512);
        assert!(s < 1e-3, "{s}");
        assert!(s > 0.0);
        // grows with sqrt(N)
        assert!(
            (m.sigma_theta_volts(512) / m.sigma_theta_volts(128) - 2.0).abs() < 1e-9
        );
    }

    #[test]
    fn energy_clips_at_headroom() {
        let m = qs(0.8);
        let e_lo = m.energy_per_bl_op(10.0);
        let e_hi = m.energy_per_bl_op(1e6);
        assert!(e_lo < e_hi);
        // clipped at dv_bl_max * v_dd * c_bl + e_su
        let cap = m.tech.dv_bl_max * m.tech.v_dd * m.c_bl + m.e_su;
        assert!((e_hi - cap).abs() / cap < 1e-12);
    }

    #[test]
    fn t_rf_positive_and_small() {
        let m = qs(0.7);
        let rel = m.t_rf_rel();
        assert!((0.0..0.2).contains(&rel), "{rel}");
    }
}
