//! The three in-memory compute models of Sec. IV-A (Fig. 5): charge
//! summing (QS), current summing (IS) and charge redistribution (QR).
//! Architectures in `crate::arch` compose these into full DP engines.

pub mod is_model;
pub mod qr;
pub mod qs;
