//! The charge-redistribution (QR) in-memory compute model (Sec. IV-C):
//! eq. (22) mapping, noise sources (eq. 24: capacitor mismatch, charge
//! injection, thermal), energy (eq. 25) and delay models.

use crate::tech::{TechNode, K_BOLTZMANN, TEMPERATURE};

#[derive(Clone, Copy, Debug)]
pub struct QrModel {
    pub tech: TechNode,
    /// Per-cell MOM capacitor C_o [F] (1-10 fF typical).
    pub c_o: f64,
    /// Switch setup energy per charge-share op [J].
    pub e_su: f64,
    /// Charge-share settling time [s].
    pub t_share: f64,
    /// Precharge time [s].
    pub t_su: f64,
}

impl QrModel {
    pub fn new(tech: TechNode, c_o_ff: f64) -> Self {
        Self {
            tech,
            c_o: c_o_ff * 1e-15,
            e_su: 0.2e-15,
            t_share: 200e-12,
            t_su: 300e-12,
        }
    }

    pub fn c_o_ff(&self) -> f64 {
        self.c_o * 1e15
    }

    /// Eq. (24): relative capacitor mismatch sigma_C/C = kappa / sqrt(C).
    /// (Pelgrom law for MOM fringe caps, kappa in fF^0.5.)
    pub fn sigma_c_rel(&self) -> f64 {
        self.tech.kappa_ff / self.c_o_ff().sqrt()
    }

    /// Eq. (24): per-cap thermal noise sqrt(kT/C) [V].
    pub fn sigma_theta_volts(&self) -> f64 {
        (K_BOLTZMANN * TEMPERATURE / self.c_o).sqrt()
    }

    /// Normalized to V_dd.
    pub fn sigma_theta_rel(&self) -> f64 {
        self.sigma_theta_volts() / self.tech.v_dd
    }

    /// Eq. (24) charge injection v = p WL Cox (V_dd - V_t - V_j) / C_j,
    /// linear in V_j: v = inj_a - inj_b * V_j, both normalized to V_dd.
    pub fn inj_a_rel(&self) -> f64 {
        self.tech.p_inj * self.tech.wl_cox * (self.tech.v_dd - self.tech.v_t)
            / self.c_o
            / self.tech.v_dd
    }

    pub fn inj_b_rel(&self) -> f64 {
        self.tech.p_inj * self.tech.wl_cox / self.c_o
    }

    /// Charge-injection variance used in the Table III closed form. The
    /// paper's footnote reads sigma_inj^2 = E[x^2] WL Cox / C_o, which is
    /// dimensionally a first power of the cap ratio; we read it as
    /// (p WL Cox / C_o)^2 E[x^2] — the variance of the data-dependent
    /// injection term v_j = p WL Cox (V_dd - V_t - V_j)/C_o, whose
    /// constant part is a calibratable offset (see EXPERIMENTS.md
    /// §Deviations).
    pub fn sigma_inj2(&self, ex2: f64) -> f64 {
        let r = self.inj_b_rel();
        r * r * ex2
    }

    /// Eq. (25): average charge-share energy over `n` caps at mean cell
    /// voltage `mean_v` [V]: sum_j E[(V_dd - V_j)] V_dd C_j + E_su.
    pub fn energy_share(&self, n: usize, mean_v: f64) -> f64 {
        n as f64 * (self.tech.v_dd - mean_v).max(0.0) * self.tech.v_dd * self.c_o
            + self.e_su
    }

    /// Table III: per-cell multiply energy E_mult = E[x(1-w)] C_o V_dd.
    pub fn energy_mult(&self, e_x_one_minus_w: f64) -> f64 {
        e_x_one_minus_w * self.c_o * self.tech.v_dd * self.tech.v_dd
    }

    /// Delay T_QR = T_share + T_su.
    pub fn delay(&self) -> f64 {
        self.t_share + self.t_su
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qr(c_ff: f64) -> QrModel {
        QrModel::new(TechNode::n65(), c_ff)
    }

    #[test]
    fn mismatch_follows_pelgrom() {
        // kappa = 0.08 fF^0.5: 1 fF -> 8%, 4 fF -> 4%, 9 fF -> 2.67%.
        assert!((qr(1.0).sigma_c_rel() - 0.08).abs() < 1e-9);
        assert!((qr(4.0).sigma_c_rel() - 0.04).abs() < 1e-9);
        assert!((qr(9.0).sigma_c_rel() - 0.08 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn thermal_ktc_magnitude() {
        // kT/C at 1 fF: sqrt(4.14e-21/1e-15) ~ 2 mV.
        let s = qr(1.0).sigma_theta_volts();
        assert!((s - 2.03e-3).abs() < 0.1e-3, "{s}");
        // halves for 4x the cap
        assert!((qr(4.0).sigma_theta_volts() - s / 2.0).abs() < 1e-5);
    }

    #[test]
    fn injection_shrinks_with_cap() {
        assert!(qr(1.0).inj_a_rel() > qr(9.0).inj_a_rel());
        let a = qr(1.0).inj_a_rel();
        // p*WLCox*(Vdd-Vt)/Co/Vdd = 0.5*0.31*0.6 = 0.093
        assert!((a - 0.093).abs() < 1e-3, "{a}");
    }

    #[test]
    fn energy_scales_with_cap_and_n() {
        let e1 = qr(1.0).energy_share(128, 0.2);
        let e3 = qr(3.0).energy_share(128, 0.2);
        assert!((e3 - qr(3.0).e_su) / (e1 - qr(1.0).e_su) > 2.9);
        assert!(qr(1.0).energy_share(256, 0.2) > e1);
    }

    #[test]
    fn noise_energy_tradeoff() {
        // Sec. IV-C: bigger caps -> less noise, more energy.
        let small = qr(1.0);
        let big = qr(9.0);
        assert!(big.sigma_c_rel() < small.sigma_c_rel());
        assert!(big.sigma_theta_rel() < small.sigma_theta_rel());
        assert!(big.energy_share(128, 0.2) > small.energy_share(128, 0.2));
    }
}
