//! The current-summing (IS) in-memory compute model (Sec. IV-A, Fig. 5(b)).
//!
//! IS maps w_j to cell current and sums currents on the BL, sensing the
//! aggregate over a fixed window (XNOR-SRAM-style designs [7], [11],
//! [13]). The paper develops QS/QR in detail and treats IS as the third
//! member of the compute-model set; we model its dominant noise (current
//! mismatch, identical sigma_D physics to QS) and its headroom limit
//! (sense-amp input range), enough to place IS designs in the taxonomy
//! and ablation studies.

use crate::tech::TechNode;

#[derive(Clone, Copy, Debug)]
pub struct IsModel {
    pub tech: TechNode,
    pub v_wl: f64,
    /// Sense window [s].
    pub t_sense: f64,
    /// Sense capacitance [F].
    pub c_sense: f64,
    /// Sense-amp max input swing [V].
    pub v_swing_max: f64,
}

impl IsModel {
    pub fn new(tech: TechNode, v_wl: f64) -> Self {
        Self {
            tech,
            v_wl,
            t_sense: 50e-12,
            c_sense: 50e-15,
            v_swing_max: 0.4 * tech.v_dd,
        }
    }

    /// Normalized current mismatch (same eq. 18 physics as QS).
    pub fn sigma_d(&self) -> f64 {
        self.tech.sigma_d(self.v_wl)
    }

    /// Unit swing per active cell [V].
    pub fn delta_v_unit(&self) -> f64 {
        self.tech.cell_current(self.v_wl, 1.0) * self.t_sense / self.c_sense
    }

    /// Headroom in unit counts.
    pub fn k_h(&self) -> f64 {
        self.v_swing_max / self.delta_v_unit()
    }

    /// Energy per sum: full-rail sensing of n cells.
    pub fn energy_per_op(&self, expected_counts: f64) -> f64 {
        let ev = (expected_counts * self.delta_v_unit()).min(self.v_swing_max);
        ev * self.tech.v_dd * self.c_sense
    }

    pub fn delay(&self) -> f64 {
        self.t_sense
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_has_less_headroom_than_qs() {
        // IS senses on a small cap within the sense-amp swing: its k_h is
        // below the QS bit-line's, which is why IS designs are binary
        // (Table I: IS rows have B_x = B_w = 1).
        let is = IsModel::new(TechNode::n65(), 0.8);
        let qs = crate::compute::qs::QsModel::new(TechNode::n65(), 0.8);
        assert!(is.k_h() < qs.k_h());
        assert!(is.k_h() > 1.0);
    }

    #[test]
    fn shares_mismatch_physics_with_qs() {
        let is = IsModel::new(TechNode::n65(), 0.7);
        assert_eq!(is.sigma_d(), TechNode::n65().sigma_d(0.7));
    }

    #[test]
    fn is_is_fast() {
        let is = IsModel::new(TechNode::n65(), 0.8);
        assert!(is.delay() < 100e-12);
    }
}
