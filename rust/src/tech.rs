//! Technology parameters: the paper's Table II (65 nm) plus ITRS-trend
//! scaled nodes for the Fig. 13 technology-scaling study.
//!
//! Substitution note (DESIGN.md §1): the paper cites the ITRS roadmap
//! tables for scaled-node parameters without reproducing them; the values
//! here encode the publicly-known trends the paper's conclusions rest on
//! (lower V_dd and V_dd/V_t ratio, smaller capacitances, faster gates,
//! larger normalized V_t variation; FDSOI at <= 22 nm).

/// Boltzmann constant [J/K].
pub const K_BOLTZMANN: f64 = 1.38e-23;

/// Absolute temperature [K] (Table II).
pub const TEMPERATURE: f64 = 300.0;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TechNode {
    /// Feature size in nm (identifier).
    pub node_nm: u32,
    /// Supply voltage V_dd [V].
    pub v_dd: f64,
    /// Access-transistor threshold V_t [V].
    pub v_t: f64,
    /// Threshold-voltage variation sigma_Vt [V].
    pub sigma_vt: f64,
    /// alpha-law exponent (Table II: 1.8 at 65 nm).
    pub alpha: f64,
    /// Current factor k' [A/V^alpha] at W/L = 1.
    pub k_prime: f64,
    /// Unit WL-driver stage delay T_0 [s].
    pub t0: f64,
    /// Stage-delay variation sigma_T0 [s].
    pub sigma_t0: f64,
    /// WL pulse rise/fall time [s] (T_r = T_f assumed).
    pub t_rise: f64,
    /// Bit-line capacitance for a 512-row array [F].
    pub c_bl_512: f64,
    /// Maximum BL discharge Delta-V_BL,max [V].
    pub dv_bl_max: f64,
    /// Access-transistor transconductance g_m [A/V].
    pub g_m: f64,
    /// Switch-gate charge-injection capacitance W*L*C_ox [F].
    pub wl_cox: f64,
    /// MOM-capacitor Pelgrom coefficient kappa [sqrt(F) * 1e-7.5...] in
    /// fF^0.5 units: sigma_C = kappa * sqrt(C/fF) fF.
    pub kappa_ff: f64,
    /// Charge-injection layout constant p in [0, 1].
    pub p_inj: f64,
    /// Energy of one two-input digital adder slice of the multi-bank
    /// recombination tree [J] (Sec. VI banking): a banked DP performs
    /// `banks - 1` of these adds. Scales roughly as C V_dd^2 — wire/gate
    /// capacitance shrinks with the node, supply with V_dd.
    pub e_bank_add: f64,
}

impl TechNode {
    /// The paper's Table II 65 nm CMOS process.
    pub fn n65() -> Self {
        Self {
            node_nm: 65,
            v_dd: 1.0,
            v_t: 0.4,
            sigma_vt: 23.8e-3,
            alpha: 1.8,
            k_prime: 220e-6,
            t0: 100e-12,
            sigma_t0: 2.3e-12,
            t_rise: 20e-12,
            c_bl_512: 270e-15,
            dv_bl_max: 0.9,
            g_m: 66e-6,
            wl_cox: 0.31e-15,
            kappa_ff: 0.08,
            p_inj: 0.5,
            e_bank_add: 5e-15,
        }
    }

    pub fn n45() -> Self {
        Self {
            node_nm: 45,
            v_dd: 0.95,
            v_t: 0.38,
            sigma_vt: 26.0e-3,
            k_prime: 300e-6,
            t0: 80e-12,
            sigma_t0: 2.0e-12,
            t_rise: 16e-12,
            c_bl_512: 187e-15,
            dv_bl_max: 0.85,
            g_m: 75e-6,
            wl_cox: 0.24e-15,
            e_bank_add: 3.1e-15,
            ..Self::n65()
        }
    }

    pub fn n32() -> Self {
        Self {
            node_nm: 32,
            v_dd: 0.9,
            v_t: 0.36,
            sigma_vt: 28.5e-3,
            k_prime: 380e-6,
            t0: 60e-12,
            sigma_t0: 1.8e-12,
            t_rise: 12e-12,
            c_bl_512: 133e-15,
            dv_bl_max: 0.8,
            g_m: 85e-6,
            wl_cox: 0.18e-15,
            e_bank_add: 2.0e-15,
            ..Self::n65()
        }
    }

    /// FDSOI from 22 nm down (paper Sec. V-D): lower A_vt resets sigma_Vt.
    pub fn n22() -> Self {
        Self {
            node_nm: 22,
            v_dd: 0.8,
            v_t: 0.33,
            sigma_vt: 22.0e-3,
            k_prime: 450e-6,
            t0: 45e-12,
            sigma_t0: 1.5e-12,
            t_rise: 9e-12,
            c_bl_512: 91e-15,
            dv_bl_max: 0.7,
            g_m: 100e-6,
            wl_cox: 0.14e-15,
            kappa_ff: 0.07,
            e_bank_add: 1.1e-15,
            ..Self::n65()
        }
    }

    pub fn n11() -> Self {
        Self {
            node_nm: 11,
            v_dd: 0.72,
            v_t: 0.31,
            sigma_vt: 26.0e-3,
            k_prime: 600e-6,
            t0: 30e-12,
            sigma_t0: 1.2e-12,
            t_rise: 6e-12,
            c_bl_512: 46e-15,
            dv_bl_max: 0.62,
            g_m: 120e-6,
            wl_cox: 0.08e-15,
            kappa_ff: 0.065,
            e_bank_add: 0.44e-15,
            ..Self::n65()
        }
    }

    pub fn n7() -> Self {
        Self {
            node_nm: 7,
            v_dd: 0.65,
            v_t: 0.30,
            sigma_vt: 30.0e-3,
            k_prime: 700e-6,
            t0: 22e-12,
            sigma_t0: 1.0e-12,
            t_rise: 5e-12,
            c_bl_512: 29e-15,
            dv_bl_max: 0.55,
            g_m: 140e-6,
            wl_cox: 0.06e-15,
            kappa_ff: 0.06,
            e_bank_add: 0.23e-15,
            ..Self::n65()
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "65" | "65nm" => Some(Self::n65()),
            "45" | "45nm" => Some(Self::n45()),
            "32" | "32nm" => Some(Self::n32()),
            "22" | "22nm" => Some(Self::n22()),
            "11" | "11nm" => Some(Self::n11()),
            "7" | "7nm" => Some(Self::n7()),
            _ => None,
        }
    }

    /// The Fig. 13 node set.
    pub fn scaling_set() -> Vec<Self> {
        vec![Self::n65(), Self::n22(), Self::n11(), Self::n7()]
    }

    /// All supported nodes, largest first.
    pub fn all() -> Vec<Self> {
        vec![
            Self::n65(),
            Self::n45(),
            Self::n32(),
            Self::n22(),
            Self::n11(),
            Self::n7(),
        ]
    }

    /// Bit-line capacitance for an `rows`-row array (proportional).
    pub fn c_bl(&self, rows: usize) -> f64 {
        self.c_bl_512 * rows as f64 / 512.0
    }

    /// SRAM cell read current at a given WL voltage (alpha-law, eq. 31).
    pub fn cell_current(&self, v_wl: f64, wl_ratio: f64) -> f64 {
        let vov = (v_wl - self.v_t).max(0.0);
        wl_ratio * self.k_prime * vov.powf(self.alpha)
    }

    /// Eq. (18): normalized cell-current mismatch sigma_D = sigma_I/I.
    pub fn sigma_d(&self, v_wl: f64) -> f64 {
        let vov = v_wl - self.v_t;
        assert!(vov > 0.0, "V_WL {} must exceed V_t {}", v_wl, self.v_t);
        self.alpha * self.sigma_vt / vov
    }

    /// Stage delay of one bank-adder tree level [s]: a banked DP adds
    /// `ceil(log2(banks))` of these on top of the per-bank conversion
    /// (see `arch::Banked`). Tracks the node's unit gate delay (half a
    /// WL-driver stage), so banking overhead scales with technology.
    pub fn t_bank_add(&self) -> f64 {
        self.t0 / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values_match_paper() {
        let t = TechNode::n65();
        assert_eq!(t.k_prime, 220e-6);
        assert_eq!(t.alpha, 1.8);
        assert_eq!(t.sigma_vt, 23.8e-3);
        assert_eq!(t.v_t, 0.4);
        assert_eq!(t.t0, 100e-12);
        assert_eq!(t.kappa_ff, 0.08);
        assert_eq!(t.p_inj, 0.5);
        assert_eq!(t.wl_cox, 0.31e-15);
        assert_eq!(t.g_m, 66e-6);
    }

    #[test]
    fn sigma_d_range_matches_paper_8_to_25_pct() {
        // Paper Sec. IV-B: sigma_Ij/Ij ranges 8% to 25% over the V_WL range.
        let t = TechNode::n65();
        let hi = t.sigma_d(0.58); // low V_WL end
        let lo = t.sigma_d(0.93); // high V_WL end
        assert!(lo > 0.07 && lo < 0.09, "{lo}");
        assert!(hi > 0.2 && hi < 0.26, "{hi}");
    }

    #[test]
    fn cell_current_magnitude() {
        // ~ tens of uA per Sec. IV-B.
        let t = TechNode::n65();
        let i = t.cell_current(0.8, 1.0);
        assert!(i > 10e-6 && i < 100e-6, "{i}");
    }

    #[test]
    fn scaling_trends() {
        let nodes = TechNode::all();
        for pair in nodes.windows(2) {
            let (a, b) = (&pair[0], &pair[1]);
            assert!(b.node_nm < a.node_nm);
            assert!(b.v_dd < a.v_dd, "V_dd decreases");
            assert!(b.c_bl_512 < a.c_bl_512, "C_BL decreases");
            assert!(b.t0 < a.t0, "gates get faster");
            // V_dd/V_t headroom ratio shrinks with scaling
            assert!(b.v_dd / b.v_t < a.v_dd / a.v_t + 1e-9);
            // digital bank-recombination cost shrinks with scaling too
            assert!(b.e_bank_add < a.e_bank_add, "bank adds get cheaper");
            assert!(b.t_bank_add() < a.t_bank_add(), "bank adds get faster");
        }
    }

    #[test]
    fn bank_adder_constants_at_65nm() {
        // The values the pre-parameterization code hard-coded in
        // arch::Banked (5 fJ per add, 50 ps per tree stage) are now the
        // 65 nm tech parameters; golden_snr.rs pins them too.
        let t = TechNode::n65();
        assert_eq!(t.e_bank_add, 5e-15);
        assert_eq!(t.t_bank_add(), 50e-12);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(TechNode::by_name("65").unwrap().node_nm, 65);
        assert_eq!(TechNode::by_name("7nm").unwrap().node_nm, 7);
        assert!(TechNode::by_name("3").is_none());
    }

    #[test]
    fn c_bl_scales_with_rows() {
        let t = TechNode::n65();
        assert!((t.c_bl(512) - 270e-15).abs() < 1e-20);
        assert!((t.c_bl(256) - 135e-15).abs() < 1e-20);
    }
}
