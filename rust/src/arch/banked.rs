//! Multi-bank IMC (the paper's conclusion bullet 4): a high-dimensional
//! DP split across `banks` arrays of N/banks rows each, partial DPs
//! digitized per bank and summed digitally.
//!
//! Banking restores SNR for N > N_max: each bank stays inside its
//! headroom (clipping noise vanishes), electrical noise still grows with
//! total N but the *signal* does too, and the energy cost is `banks`
//! ADC conversions plus the same total analog work.

use super::{AdcCriterion, EnergyBreakdown, ImcArch, NoiseBreakdown, OpPoint};
use crate::quant::SignalStats;

/// An architecture partitioned over equally-sized banks.
pub struct Banked<'a> {
    pub inner: &'a dyn ImcArch,
    pub banks: usize,
}

impl<'a> Banked<'a> {
    pub fn new(inner: &'a dyn ImcArch, banks: usize) -> Self {
        assert!(banks >= 1);
        Self { inner, banks }
    }

    fn bank_op(&self, op: &OpPoint) -> OpPoint {
        OpPoint {
            n: op.n.div_ceil(self.banks),
            ..*op
        }
    }

    /// Noise of the banked DP: per-bank noise variances add (independent
    /// banks), signal variances add too.
    pub fn noise(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> NoiseBreakdown {
        let sub = self.inner.noise(&self.bank_op(op), w, x);
        NoiseBreakdown {
            sigma_yo2: sub.sigma_yo2 * self.banks as f64,
            sigma_qiy2: sub.sigma_qiy2 * self.banks as f64,
            sigma_eta_h2: sub.sigma_eta_h2 * self.banks as f64,
            sigma_eta_e2: sub.sigma_eta_e2 * self.banks as f64,
        }
    }

    /// Energy: `banks` x the per-bank cost (analog + ADC), one shared
    /// digital recombination.
    pub fn energy(
        &self,
        op: &OpPoint,
        crit: AdcCriterion,
        w: &SignalStats,
        x: &SignalStats,
    ) -> EnergyBreakdown {
        let sub = self.inner.energy(&self.bank_op(op), crit, w, x);
        EnergyBreakdown {
            analog: sub.analog * self.banks as f64,
            adc: sub.adc * self.banks as f64,
            misc: sub.misc + 5e-15 * self.banks as f64, // bank adder tree
        }
    }

    /// Delay: banks operate in parallel; the adder tree adds log2(banks)
    /// stages.
    pub fn delay(&self, op: &OpPoint) -> f64 {
        self.inner.delay(&self.bank_op(op))
            + (self.banks as f64).log2().ceil() * 50e-12
    }

    /// Smallest bank count that keeps each bank's clipping noise below
    /// its electrical noise (the Fig. 9(a) plateau condition).
    pub fn min_banks_for_plateau(
        inner: &dyn ImcArch,
        op: &OpPoint,
        w: &SignalStats,
        x: &SignalStats,
    ) -> usize {
        for banks in 1..=op.n {
            let b = Banked::new(inner, banks);
            let nb = b.noise(op, w, x);
            if nb.sigma_eta_h2 <= nb.sigma_eta_e2 {
                return banks;
            }
        }
        op.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::QsArch;
    use crate::compute::qs::QsModel;
    use crate::tech::TechNode;

    fn setup() -> (QsArch, SignalStats, SignalStats) {
        (
            QsArch::new(QsModel::new(TechNode::n65(), 0.8)),
            SignalStats::uniform_signed(1.0),
            SignalStats::uniform_unsigned(1.0),
        )
    }

    #[test]
    fn banking_restores_snr_beyond_n_max() {
        let (arch, w, x) = setup();
        let op = OpPoint::new(512, 6, 6, 8);
        let single = Banked::new(&arch, 1).noise(&op, &w, &x).snr_a_total_db();
        let banked = Banked::new(&arch, 8).noise(&op, &w, &x).snr_a_total_db();
        assert!(single < 5.0, "N=512 single-bank collapses: {single}");
        assert!(banked > 15.0, "8 banks restore the plateau: {banked}");
    }

    #[test]
    fn banking_below_n_max_changes_little() {
        let (arch, w, x) = setup();
        let op = OpPoint::new(64, 6, 6, 8);
        let single = Banked::new(&arch, 1).noise(&op, &w, &x).snr_a_total_db();
        let banked = Banked::new(&arch, 2).noise(&op, &w, &x).snr_a_total_db();
        assert!((single - banked).abs() < 1.5, "{single} {banked}");
    }

    #[test]
    fn banking_costs_adc_energy() {
        let (arch, w, x) = setup();
        let op = OpPoint::new(512, 6, 6, 8);
        let e1 = Banked::new(&arch, 1).energy(&op, AdcCriterion::Mpc, &w, &x);
        let e8 = Banked::new(&arch, 8).energy(&op, AdcCriterion::Mpc, &w, &x);
        assert!(e8.adc > e1.adc, "{} {}", e8.adc, e1.adc);
    }

    #[test]
    fn min_banks_matches_n_max_scaling() {
        let (arch, w, x) = setup();
        // roughly N/N_max banks needed; N_max(0.8 V) ~ 128
        let b512 = Banked::min_banks_for_plateau(&arch, &OpPoint::new(512, 6, 6, 8), &w, &x);
        let b128 = Banked::min_banks_for_plateau(&arch, &OpPoint::new(128, 6, 6, 8), &w, &x);
        assert!(b128 <= 2, "{b128}");
        assert!((3..=10).contains(&b512), "{b512}");
        assert!(b512 > b128);
    }

    #[test]
    fn delay_adds_adder_tree() {
        let (arch, _, _) = setup();
        let op = OpPoint::new(512, 6, 6, 8);
        let d1 = Banked::new(&arch, 1).delay(&op);
        let d8 = Banked::new(&arch, 8).delay(&op);
        // per-bank compute is the same cycle count; only the tree adds
        assert!(d8 - d1 < 1e-9);
        assert!(d8 > d1);
    }

    /// Monte-Carlo cross-check: simulate 8 banks natively and verify the
    /// closed-form banked SNR.
    #[test]
    fn banked_mc_matches_closed_form() {
        let (arch, w, x) = setup();
        let op = OpPoint::new(512, 6, 6, 14);
        let banks = 8;
        let bank_op = OpPoint::new(64, 6, 6, 14);
        let params = arch.pjrt_params(&bank_op, &w, &x);
        // sum of 8 independent bank DPs == banked DP of N=512
        let mut acc = crate::mc::SnrAccumulator::new();
        let mut outs = Vec::new();
        for b in 0..banks {
            outs.push(crate::mc::simulate(
                crate::mc::ArchKind::Qs,
                &params,
                2000,
                100 + b as u64,
                crate::mc::InputDist::Uniform,
            ));
        }
        let mut combined = crate::mc::McOutput::default();
        for i in 0..2000 {
            let sum = |f: fn(&crate::mc::McOutput) -> &Vec<f64>| -> f64 {
                outs.iter().map(|o| f(o)[i]).sum()
            };
            combined.push(
                sum(|o| &o.y_ideal),
                sum(|o| &o.y_fx),
                sum(|o| &o.y_a),
                sum(|o| &o.y_hat),
            );
        }
        acc.push_chunk(&combined);
        let measured = acc.finalize();
        let closed = Banked::new(&arch, banks).noise(&op, &w, &x);
        assert!(
            (measured.snr_a_total_db - closed.snr_a_total_db()).abs() < 1.0,
            "mc {} vs closed {}",
            measured.snr_a_total_db,
            closed.snr_a_total_db()
        );
    }
}
