//! Multi-bank IMC (the paper's conclusion bullet 4, Sec. VI): a
//! high-dimensional DP split across `banks` arrays of ceil(N/banks)
//! rows each, partial DPs digitized per bank and summed digitally.
//!
//! Banking restores SNR for N > N_max: each bank stays inside its
//! headroom (clipping noise vanishes), electrical noise still grows with
//! total N but the *signal* does too, and the cost is `banks` ADC
//! conversions, a `banks - 1`-slice digital adder tree
//! (`TechNode::e_bank_add` / `TechNode::t_bank_add`), and `banks` copies
//! of the per-bank silicon.
//!
//! [`Banked`] is a full [`ImcArch`]: it composes any inner architecture
//! into its banked variant, so it flows through the design-space
//! optimizer (`opt::Family` with `banks > 1`), the sweep engine (the
//! bank count rides in parameter-vector slot [`pvec::IDX_BANKS`], which
//! the native Monte-Carlo simulator interprets by summing independent
//! per-bank ensembles) and the CLI (`--banks`) like any other design.
//!
//! Contract (property-tested in `tests/prop_banked.rs`):
//! `Banked::new(inner, 1)` is *bit-identical* to the bare inner
//! architecture for noise, energy, delay, area and the parameter vector
//! — slot `IDX_BANKS` stays `0.0` at one bank, so single-bank cache
//! keys are unchanged too. For `banks >= 2` every noise variance is
//! exactly `banks x` the per-bank decomposition.

use super::{pvec, AdcCriterion, EnergyBreakdown, ImcArch, NoiseBreakdown, OpPoint};
use crate::area::AreaBreakdown;
use crate::quant::SignalStats;
use crate::tech::TechNode;

/// An architecture partitioned over equally-sized banks.
pub struct Banked {
    pub inner: Box<dyn ImcArch>,
    pub banks: usize,
}

impl Banked {
    pub fn new(inner: Box<dyn ImcArch>, banks: usize) -> Self {
        assert!(banks >= 1);
        Self { inner, banks }
    }

    /// The per-bank operating point: `ceil(N / banks)` rows, one bank.
    pub fn bank_op(&self, op: &OpPoint) -> OpPoint {
        OpPoint {
            n: op.n.div_ceil(self.banks),
            banks: 1,
            ..*op
        }
    }

    /// Number of adder-tree stages: ceil(log2(banks)).
    fn tree_stages(&self) -> f64 {
        (self.banks as f64).log2().ceil()
    }

    /// Smallest bank count that keeps each bank's clipping noise below
    /// its electrical noise (the Fig. 9(a) plateau condition). Both
    /// sides of the comparison scale by `banks`, so the per-bank
    /// decomposition decides it directly.
    pub fn min_banks_for_plateau(
        inner: &dyn ImcArch,
        op: &OpPoint,
        w: &SignalStats,
        x: &SignalStats,
    ) -> usize {
        for banks in 1..=op.n {
            let bank_op = OpPoint {
                n: op.n.div_ceil(banks),
                banks: 1,
                ..*op
            };
            let nb = inner.noise(&bank_op, w, x);
            if nb.sigma_eta_h2 <= nb.sigma_eta_e2 {
                return banks;
            }
        }
        op.n
    }
}

impl ImcArch for Banked {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn artifact_name(&self) -> &'static str {
        self.inner.artifact_name()
    }

    fn tech(&self) -> TechNode {
        self.inner.tech()
    }

    /// Noise of the banked DP: per-bank noise variances add (independent
    /// banks), signal variances add too — so every SNR ratio equals the
    /// per-bank one, which is how banking escapes the SNR_a ceiling.
    fn noise(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> NoiseBreakdown {
        let sub = self.inner.noise(&self.bank_op(op), w, x);
        NoiseBreakdown {
            sigma_yo2: sub.sigma_yo2 * self.banks as f64,
            sigma_qiy2: sub.sigma_qiy2 * self.banks as f64,
            sigma_eta_h2: sub.sigma_eta_h2 * self.banks as f64,
            sigma_eta_e2: sub.sigma_eta_e2 * self.banks as f64,
        }
    }

    fn v_c_volts(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> f64 {
        self.inner.v_c_volts(&self.bank_op(op), w, x)
    }

    fn v_c_full_volts(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> f64 {
        self.inner.v_c_full_volts(&self.bank_op(op), w, x)
    }

    fn b_adc_bgc(&self, op: &OpPoint) -> u32 {
        self.inner.b_adc_bgc(&self.bank_op(op))
    }

    /// MPC assignment per bank ADC. The banked pre-ADC SNR equals the
    /// per-bank one (both signal and noise scale by `banks`), so the
    /// per-bank assignment is the banked assignment.
    fn b_adc_min(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> u32 {
        self.inner.b_adc_min(&self.bank_op(op), w, x)
    }

    /// Energy: `banks` x the per-bank cost (analog + ADC), plus the
    /// `banks - 1` adds of the digital recombination tree
    /// (`TechNode::e_bank_add`, node-scaled; zero at one bank, so a
    /// single-bank wrapper costs exactly the bare architecture).
    fn energy(
        &self,
        op: &OpPoint,
        crit: AdcCriterion,
        w: &SignalStats,
        x: &SignalStats,
    ) -> EnergyBreakdown {
        let sub = self.inner.energy(&self.bank_op(op), crit, w, x);
        let tree = (self.banks - 1) as f64 * self.tech().e_bank_add;
        EnergyBreakdown {
            analog: sub.analog * self.banks as f64,
            adc: sub.adc * self.banks as f64,
            misc: sub.misc + tree,
        }
    }

    /// Delay: banks operate in parallel; the adder tree adds
    /// ceil(log2(banks)) stages of `TechNode::t_bank_add` (zero at one
    /// bank).
    fn delay(&self, op: &OpPoint) -> f64 {
        self.inner.delay(&self.bank_op(op)) + self.tree_stages() * self.tech().t_bank_add()
    }

    /// Area: `banks` copies of the per-bank geometry plus the adder
    /// tree (counted as periphery).
    fn area(&self, op: &OpPoint) -> AreaBreakdown {
        let sub = self.inner.area(&self.bank_op(op)).scaled(self.banks as f64);
        AreaBreakdown {
            periphery_mm2: sub.periphery_mm2
                + crate::area::bank_adder_mm2(&self.tech(), self.banks),
            ..sub
        }
    }

    /// Per-bank parameter vector; the bank count rides in slot
    /// [`pvec::IDX_BANKS`] *only when banks >= 2* (see the pvec docs:
    /// `0.0` is the single-bank encoding, keeping single-bank cache
    /// keys bit-identical to the unbanked layout).
    fn pjrt_params(
        &self,
        op: &OpPoint,
        w: &SignalStats,
        x: &SignalStats,
    ) -> [f64; pvec::P] {
        let mut p = self.inner.pjrt_params(&self.bank_op(op), w, x);
        if self.banks >= 2 {
            p[pvec::IDX_BANKS] = self.banks as f64;
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::QsArch;
    use crate::compute::qs::QsModel;
    use crate::tech::TechNode;

    fn setup() -> (QsArch, SignalStats, SignalStats) {
        (
            QsArch::new(QsModel::new(TechNode::n65(), 0.8)),
            SignalStats::uniform_signed(1.0),
            SignalStats::uniform_unsigned(1.0),
        )
    }

    fn banked(banks: usize) -> Banked {
        let (arch, _, _) = setup();
        Banked::new(Box::new(arch), banks)
    }

    #[test]
    fn banking_restores_snr_beyond_n_max() {
        let (_, w, x) = setup();
        let op = OpPoint::new(512, 6, 6, 8);
        let single = banked(1).noise(&op, &w, &x).snr_a_total_db();
        let eight = banked(8).noise(&op, &w, &x).snr_a_total_db();
        assert!(single < 5.0, "N=512 single-bank collapses: {single}");
        assert!(eight > 15.0, "8 banks restore the plateau: {eight}");
    }

    #[test]
    fn banking_below_n_max_changes_little() {
        let (_, w, x) = setup();
        let op = OpPoint::new(64, 6, 6, 8);
        let single = banked(1).noise(&op, &w, &x).snr_a_total_db();
        let two = banked(2).noise(&op, &w, &x).snr_a_total_db();
        assert!((single - two).abs() < 1.5, "{single} {two}");
    }

    #[test]
    fn banking_costs_adc_energy_and_adder_tree() {
        let (arch, w, x) = setup();
        let op = OpPoint::new(512, 6, 6, 8);
        let e1 = banked(1).energy(&op, AdcCriterion::Mpc, &w, &x);
        let e8 = banked(8).energy(&op, AdcCriterion::Mpc, &w, &x);
        assert!(e8.adc > e1.adc, "{} {}", e8.adc, e1.adc);
        // the tree is (banks - 1) node-scaled adds on top of misc
        let bare = arch.energy(&banked(8).bank_op(&op), AdcCriterion::Mpc, &w, &x);
        assert_eq!(
            e8.misc.to_bits(),
            (bare.misc + 7.0 * TechNode::n65().e_bank_add).to_bits()
        );
        assert_eq!(e1.misc.to_bits(), bare.misc.to_bits(), "no tree at 1 bank");
    }

    #[test]
    fn banking_replicates_area_and_adds_tree() {
        let (arch, _, _) = setup();
        let op = OpPoint::new(512, 6, 6, 8);
        let b4 = banked(4);
        let a4 = b4.area(&op);
        let per_bank = arch.area(&b4.bank_op(&op));
        assert_eq!(a4.array_mm2.to_bits(), (per_bank.array_mm2 * 4.0).to_bits());
        assert_eq!(a4.adc_mm2.to_bits(), (per_bank.adc_mm2 * 4.0).to_bits());
        let tree = crate::area::bank_adder_mm2(&TechNode::n65(), 4);
        assert!((a4.periphery_mm2 - (per_bank.periphery_mm2 * 4.0 + tree)).abs() < 1e-18);
        // 4 banks of N/4 rows hold the same cell count as one N-row array
        let whole = arch.area(&op);
        assert_eq!(a4.array_mm2.to_bits(), whole.array_mm2.to_bits());
        assert!(a4.adc_mm2 > whole.adc_mm2, "4x the column ADCs");
    }

    #[test]
    fn min_banks_matches_n_max_scaling() {
        let (arch, w, x) = setup();
        // roughly N/N_max banks needed; N_max(0.8 V) ~ 128
        let b512 = Banked::min_banks_for_plateau(&arch, &OpPoint::new(512, 6, 6, 8), &w, &x);
        let b128 = Banked::min_banks_for_plateau(&arch, &OpPoint::new(128, 6, 6, 8), &w, &x);
        assert!(b128 <= 2, "{b128}");
        assert!((3..=10).contains(&b512), "{b512}");
        assert!(b512 > b128);
    }

    #[test]
    fn delay_adds_adder_tree() {
        let op = OpPoint::new(512, 6, 6, 8);
        let d1 = banked(1).delay(&op);
        let d8 = banked(8).delay(&op);
        // per-bank compute is the same cycle count; only the tree adds
        assert!(d8 - d1 < 1e-9);
        assert!(d8 > d1);
        assert!(
            (d8 - d1 - 3.0 * TechNode::n65().t_bank_add()).abs() < 1e-15,
            "3 tree stages for 8 banks"
        );
    }

    #[test]
    fn params_carry_the_bank_count_only_when_banked() {
        let (arch, w, x) = setup();
        let op = OpPoint::new(512, 6, 6, 8);
        let p1 = banked(1).pjrt_params(&op, &w, &x);
        assert_eq!(p1[pvec::IDX_BANKS], 0.0, "single-bank keeps slot 15 at 0");
        assert_eq!(p1, arch.pjrt_params(&op, &w, &x), "bit-identical at 1 bank");
        let p8 = banked(8).pjrt_params(&op, &w, &x);
        assert_eq!(p8[pvec::IDX_BANKS], 8.0);
        assert_eq!(p8[pvec::IDX_N_ACTIVE], 64.0, "per-bank rows in slot 0");
    }

    /// Monte-Carlo cross-check: the native simulator's banked path (sum
    /// of `banks` independent per-bank ensembles, driven by the
    /// `IDX_BANKS` slot) must agree with the closed-form banked SNR.
    #[test]
    fn banked_mc_matches_closed_form() {
        let (_, w, x) = setup();
        let op = OpPoint::new(512, 6, 6, 14);
        let b = banked(8);
        let params = b.pjrt_params(&op, &w, &x);
        let out = crate::mc::simulate(
            crate::mc::ArchKind::Qs,
            &params,
            2000,
            100,
            crate::mc::InputDist::Uniform,
        );
        let mut acc = crate::mc::SnrAccumulator::new();
        acc.push_chunk(&out);
        let measured = acc.finalize();
        let closed = b.noise(&op, &w, &x);
        assert!(
            (measured.snr_a_total_db - closed.snr_a_total_db()).abs() < 1.0,
            "mc {} vs closed {}",
            measured.snr_a_total_db,
            closed.snr_a_total_db()
        );
    }
}
