//! QR-Arch (Sec. IV-C2, Fig. 7(b), Table III column 2): binary-weighted
//! DPs across B_w rows of capacitor-augmented bitcells; a DAC drives the
//! multi-bit activation, per-row charge redistribution aggregates, one
//! ADC conversion per row, digital POT summing.

use super::{pvec, AdcCriterion, EnergyBreakdown, ImcArch, NoiseBreakdown, OpPoint};
use crate::compute::qr::QrModel;
use crate::energy::adc::AdcEnergyModel;
use crate::quant::SignalStats;

#[derive(Clone, Copy, Debug)]
pub struct QrArch {
    pub qr: QrModel,
    pub adc: AdcEnergyModel,
    /// Per-DP misc (DAC amortized share + digital POT sum) [J].
    pub e_misc: f64,
    /// ADC comparator period [s].
    pub t_comp: f64,
    /// Use the refined (mean-centered) mismatch model instead of the
    /// paper's Table III expression (see DESIGN.md §6): the exact
    /// charge-share output normalizes by the realized total capacitance,
    /// cancelling the common-mode mismatch the paper's form retains.
    pub refined: bool,
}

impl QrArch {
    pub fn new(qr: QrModel) -> Self {
        let adc = AdcEnergyModel::paper(qr.tech.v_dd);
        Self {
            qr,
            adc,
            e_misc: 30e-15,
            t_comp: 100e-12,
            refined: true,
        }
    }

    pub fn with_refined(mut self, refined: bool) -> Self {
        self.refined = refined;
        self
    }

    fn weight_plane_factor(bw: u32) -> f64 {
        4.0 / 3.0 * (1.0 - 4f64.powi(-(bw as i32)))
    }

    /// Per-row ADC statistics: mean and std of V_row = (1/N) sum x_k w_ik
    /// (V_dd units), w binary Bernoulli(1/2).
    pub fn row_stats(&self, n: usize, x: &SignalStats) -> (f64, f64) {
        let v_dd = self.qr.tech.v_dd;
        let mu_x = x.second_moment_to_mean();
        let mean = v_dd * mu_x / 2.0;
        let var = v_dd * v_dd / (4.0 * n as f64)
            * (2.0 * x.second_moment - mu_x * mu_x);
        (mean, var.sqrt())
    }
}

/// E[x] helper: for the unsigned uniform default, E[x] = peak/2. We keep
/// SignalStats minimal; this derives the mean consistently for the
/// distributions used in the paper (uniform).
pub trait MeanExt {
    fn second_moment_to_mean(&self) -> f64;
}

impl MeanExt for SignalStats {
    fn second_moment_to_mean(&self) -> f64 {
        // mean^2 = E[x^2] - Var
        (self.second_moment - self.variance).max(0.0).sqrt()
    }
}

impl ImcArch for QrArch {
    fn name(&self) -> &'static str {
        "QR-Arch"
    }

    fn artifact_name(&self) -> &'static str {
        "qr_arch"
    }

    fn tech(&self) -> crate::tech::TechNode {
        self.qr.tech
    }

    fn area(&self, op: &OpPoint) -> crate::area::AreaBreakdown {
        crate::area::qr_area(&self.qr.tech, self.qr.c_o_ff(), op)
    }

    fn noise(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> NoiseBreakdown {
        let n = op.n as f64;
        let sigma_yo2 = crate::quant::dp_signal_variance(op.n, w, x);
        let sigma_qiy2 = crate::quant::qiy_variance(op.n, op.bw, op.bx, w, x);

        let sc2 = self.qr.sigma_c_rel().powi(2);
        let sth2 = self.qr.sigma_theta_rel().powi(2);
        let sinj2 = self.qr.sigma_inj2(x.second_moment / x.peak / x.peak);
        let sigma_eta_e2 = if self.refined {
            // centered: (4/3)(1-4^-Bw) N [ (sc^2+injb^2) Var(v) + sth^2 ]
            let ex2 = x.second_moment / (x.peak * x.peak);
            let mu_x = x.second_moment_to_mean() / x.peak;
            let var_v = ex2 / 2.0 - mu_x * mu_x / 4.0;
            let injb2 = self.qr.inj_b_rel().powi(2);
            Self::weight_plane_factor(op.bw) * n * ((sc2 + injb2) * var_v + sth2)
        } else {
            // Table III: (2/3)(1-4^-Bw) N [E[x^2] sc^2 + 2 sth^2 + sinj^2]
            let ex2 = x.second_moment / (x.peak * x.peak);
            0.5 * Self::weight_plane_factor(op.bw)
                * n
                * (ex2 * sc2 + 2.0 * sth2 + sinj2)
        };

        NoiseBreakdown {
            sigma_yo2,
            sigma_qiy2,
            sigma_eta_h2: 0.0, // QR has no headroom clipping (Sec. IV-C)
            sigma_eta_e2,
        }
    }

    fn v_c_volts(&self, op: &OpPoint, _w: &SignalStats, x: &SignalStats) -> f64 {
        // Row-ADC range: mean +- 4 sigma (8 sigma width), Table III.
        let (_, sd) = self.row_stats(op.n, x);
        8.0 * sd
    }

    fn b_adc_bgc(&self, op: &OpPoint) -> u32 {
        // per-row binary-weighted DP: B_x-bit inputs summed over N
        op.bx + (op.n as f64).log2().ceil() as u32
    }

    fn v_c_full_volts(&self, _op: &OpPoint, _w: &SignalStats, _x: &SignalStats) -> f64 {
        // worst-case row output: all weights 1, x at full scale
        self.qr.tech.v_dd
    }

    fn b_adc_min(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> u32 {
        let snr_a_db = self.noise(op, w, x).snr_a_total_db();
        let mpc = (snr_a_db + 16.2) / 6.0;
        let alt = op.bx as f64 + (op.n as f64).log2();
        mpc.min(alt).ceil().max(1.0) as u32
    }

    fn energy(
        &self,
        op: &OpPoint,
        crit: AdcCriterion,
        w: &SignalStats,
        x: &SignalStats,
    ) -> EnergyBreakdown {
        // Table III: E = Bw (E_QR + N E_mult + E_ADC) + E_misc.
        let b_adc = self.b_adc_for(op, crit, w, x);
        let mu_x = x.second_moment_to_mean();
        let mean_v = self.qr.tech.v_dd * mu_x / 2.0;
        let e_qr = self.qr.energy_share(op.n, mean_v);
        // E[x (1 - w)] with binary w Bernoulli(1/2): E[x]/2 (normalized).
        let e_mult = self.qr.energy_mult(mu_x / x.peak / 2.0);
        let v_c = self.v_c_for(op, crit, w, x);
        let e_adc = self.adc.energy(b_adc, v_c);
        let bw = op.bw as f64;
        EnergyBreakdown {
            analog: bw * (e_qr + op.n as f64 * e_mult),
            adc: bw * e_adc,
            misc: self.e_misc,
        }
    }

    fn delay(&self, op: &OpPoint) -> f64 {
        // One compute cycle (rows in parallel) + row ADC.
        self.qr.delay() + self.adc.delay(op.b_adc, self.t_comp)
    }

    fn pjrt_params(
        &self,
        op: &OpPoint,
        _w: &SignalStats,
        x: &SignalStats,
    ) -> [f64; pvec::P] {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = op.n as f64;
        p[pvec::IDX_BX] = op.bx as f64;
        p[pvec::IDX_BW] = op.bw as f64;
        p[pvec::IDX_B_ADC] = op.b_adc as f64;
        p[pvec::QR_IDX_SIGMA_C] = self.qr.sigma_c_rel();
        p[pvec::QR_IDX_INJ_A] = self.qr.inj_a_rel();
        p[pvec::QR_IDX_INJ_B] = self.qr.inj_b_rel();
        p[pvec::QR_IDX_SIGMA_THETA] = self.qr.sigma_theta_rel();
        let (mean, sd) = self.row_stats(op.n, x);
        // normalized to V_dd = 1 in the simulator
        let v_dd = self.qr.tech.v_dd;
        p[pvec::QR_IDX_V_C] = 8.0 * sd / v_dd;
        p[pvec::QR_IDX_V_LO] = ((mean - 4.0 * sd) / v_dd).max(0.0);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::TechNode;

    fn arch(c_ff: f64) -> QrArch {
        QrArch::new(QrModel::new(TechNode::n65(), c_ff))
    }

    fn uni() -> (SignalStats, SignalStats) {
        (
            SignalStats::uniform_signed(1.0),
            SignalStats::uniform_unsigned(1.0),
        )
    }

    #[test]
    fn snr_improves_with_cap_size() {
        // Fig. 10(a): C_o 1 -> 3 -> 9 fF buys ~8 dB and ~12 dB of SNR_a.
        let (w, x) = uni();
        let op = OpPoint::new(128, 6, 7, 8);
        let s1 = arch(1.0).noise(&op, &w, &x).snr_a_db();
        let s3 = arch(3.0).noise(&op, &w, &x).snr_a_db();
        let s9 = arch(9.0).noise(&op, &w, &x).snr_a_db();
        assert!((s3 - s1 - 8.0).abs() < 3.0, "{s1} {s3}");
        assert!((s9 - s1 - 12.0).abs() < 3.5, "{s1} {s9}");
    }

    #[test]
    fn no_headroom_clipping() {
        let (w, x) = uni();
        for n in [64usize, 256, 512] {
            let nb = arch(1.0).noise(&OpPoint::new(n, 6, 7, 8), &w, &x);
            assert_eq!(nb.sigma_eta_h2, 0.0);
        }
    }

    #[test]
    fn refined_model_predicts_less_noise_than_table3() {
        let (w, x) = uni();
        let op = OpPoint::new(128, 6, 7, 8);
        let refined = arch(1.0).noise(&op, &w, &x).sigma_eta_e2;
        let table3 = arch(1.0).with_refined(false).noise(&op, &w, &x).sigma_eta_e2;
        assert!(refined < table3, "{refined} {table3}");
        assert!(refined > table3 * 0.3);
    }

    #[test]
    fn b_adc_6_to_8_bits_at_paper_point() {
        // Fig. 10(b): MPC assigns 6-8 bits where BGC would assign 13.
        let (w, x) = uni();
        let op = OpPoint::new(128, 6, 7, 8);
        for c in [1.0, 3.0, 9.0] {
            let b = arch(c).b_adc_min(&op, &w, &x);
            assert!((5..=9).contains(&b), "C_o={c}: {b}");
        }
        assert_eq!(crate::quant::criteria::bgc_bits(6, 7, 128), 20);
    }

    #[test]
    fn adc_energy_grows_with_n_under_mpc() {
        // Fig. 12(b): V_c ~ 1/sqrt(N) so E_ADC grows ~N under MPC, ~N^2
        // under BGC.
        let (w, x) = uni();
        let a = arch(3.0);
        let e = |n: usize, crit| a.energy(&OpPoint::new(n, 6, 6, 8), crit, &w, &x).adc;
        assert!(e(256, AdcCriterion::Mpc) > e(64, AdcCriterion::Mpc) * 1.5);
        let bgc_ratio = e(256, AdcCriterion::Bgc) / e(64, AdcCriterion::Bgc);
        let mpc_ratio = e(256, AdcCriterion::Mpc) / e(64, AdcCriterion::Mpc);
        assert!(bgc_ratio > mpc_ratio * 2.0, "{bgc_ratio} {mpc_ratio}");
    }

    #[test]
    fn energy_grows_with_cap() {
        let (w, x) = uni();
        let op = OpPoint::new(128, 6, 7, 8);
        let e1 = arch(1.0).energy(&op, AdcCriterion::Mpc, &w, &x).analog;
        let e9 = arch(9.0).energy(&op, AdcCriterion::Mpc, &w, &x).analog;
        assert!(e9 > e1 * 4.0);
    }

    #[test]
    fn row_stats_match_appendix() {
        let (_, x) = uni();
        let a = arch(1.0);
        let (mean, sd) = a.row_stats(128, &x);
        assert!((mean - 0.25).abs() < 1e-9); // E[x]/2 = 0.25
        let expect = (1.0f64 / (4.0 * 128.0) * (2.0 / 3.0 - 0.25)).sqrt();
        assert!((sd - expect).abs() < 1e-12);
    }

    #[test]
    fn params_vector_layout() {
        let (w, x) = uni();
        let p = arch(1.0).pjrt_params(&OpPoint::new(128, 6, 7, 8), &w, &x);
        assert!((p[pvec::QR_IDX_SIGMA_C] - 0.08).abs() < 1e-9);
        assert!(p[pvec::QR_IDX_V_C] > 0.0 && p[pvec::QR_IDX_V_C] < 1.0);
        assert!(p[pvec::QR_IDX_V_LO] >= 0.0);
    }
}
