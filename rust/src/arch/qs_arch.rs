//! QS-Arch (Sec. IV-B2, Fig. 7(a), Table III column 1): fully-binarized
//! bit-serial DPs on the bit-lines of a 6T/8T SRAM array using the QS
//! compute model, one column ADC conversion per binarized DP, digital
//! power-of-two recombination.

use super::{binomial_clip_moment, pvec, AdcCriterion, EnergyBreakdown, ImcArch, NoiseBreakdown, OpPoint};
use crate::compute::qs::QsModel;
use crate::energy::adc::AdcEnergyModel;
use crate::quant::SignalStats;

#[derive(Clone, Copy, Debug)]
pub struct QsArch {
    pub qs: QsModel,
    pub adc: AdcEnergyModel,
    /// Per-DP digital recombination + misc energy [J].
    pub e_misc: f64,
    /// ADC comparator period [s].
    pub t_comp: f64,
}

impl QsArch {
    pub fn new(qs: QsModel) -> Self {
        let adc = AdcEnergyModel::paper(qs.tech.v_dd);
        Self {
            qs,
            adc,
            e_misc: 20e-15,
            t_comp: 100e-12,
        }
    }

    /// Sum of squared plane recombination weights:
    /// sum_i 4^{1-i} = (4/3)(1-4^-B) over weight planes, (1/3)(1-4^-B)
    /// over input planes.
    fn weight_plane_factor(bw: u32) -> f64 {
        4.0 / 3.0 * (1.0 - 4f64.powi(-(bw as i32)))
    }

    fn input_plane_factor(bx: u32) -> f64 {
        1.0 / 3.0 * (1.0 - 4f64.powi(-(bx as i32)))
    }

    /// Combined per-(i,j) factor (4/9)(1-4^-Bw)(1-4^-Bx) of appendix B.
    fn plane_factor(bw: u32, bx: u32) -> f64 {
        Self::weight_plane_factor(bw) * Self::input_plane_factor(bx)
    }

    /// ADC range in unit counts (Table III):
    /// V_c = min(4 sqrt(3N) dV_unit, dV_max, N dV_unit).
    pub fn v_c_counts(&self, n: usize) -> f64 {
        let nf = n as f64;
        (4.0 * (3.0 * nf).sqrt()).min(self.qs.k_h()).min(nf)
    }
}

impl ImcArch for QsArch {
    fn name(&self) -> &'static str {
        "QS-Arch"
    }

    fn artifact_name(&self) -> &'static str {
        "qs_arch"
    }

    fn tech(&self) -> crate::tech::TechNode {
        self.qs.tech
    }

    fn area(&self, op: &OpPoint) -> crate::area::AreaBreakdown {
        crate::area::qs_area(&self.qs.tech, op)
    }

    fn noise(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> NoiseBreakdown {
        let n = op.n;
        let sigma_yo2 = crate::quant::dp_signal_variance(n, w, x);
        let sigma_qiy2 = crate::quant::qiy_variance(n, op.bw, op.bx, w, x);

        // sigma_eta_h^2 (Table III): plane factor * binomial clip moment.
        let clip = binomial_clip_moment(n, 0.25, self.qs.k_h());
        let sigma_eta_h2 = Self::plane_factor(op.bw, op.bx) * clip;

        // sigma_eta_e^2: current mismatch + pulse jitter (per active cell,
        // E[active] = N/4 per plane pair) + integrated thermal noise.
        let sd2 = self.qs.sigma_d().powi(2) + self.qs.sigma_t_rel().powi(2);
        let per_bl_var = n as f64 / 4.0 * sd2;
        let thermal = self.qs.sigma_theta_counts(n).powi(2);
        let sigma_eta_e2 = Self::plane_factor(op.bw, op.bx) * (per_bl_var + thermal);

        NoiseBreakdown {
            sigma_yo2,
            sigma_qiy2,
            sigma_eta_h2,
            sigma_eta_e2,
        }
    }

    fn v_c_volts(&self, op: &OpPoint, _w: &SignalStats, _x: &SignalStats) -> f64 {
        self.v_c_counts(op.n) * self.qs.delta_v_unit()
    }

    fn v_c_full_volts(&self, op: &OpPoint, _w: &SignalStats, _x: &SignalStats) -> f64 {
        // full BL range: N cells or the headroom, whichever clips first
        (op.n as f64).min(self.qs.k_h()) * self.qs.delta_v_unit()
    }

    fn b_adc_bgc(&self, op: &OpPoint) -> u32 {
        // binarized BL DP has N + 1 levels, headroom-limited at k_h
        (op.n as f64).min(self.qs.k_h()).log2().ceil().max(1.0) as u32
    }

    fn b_adc_min(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> u32 {
        let snr_a_db = self.noise(op, w, x).snr_a_total_db();
        let mpc = (snr_a_db + 16.2) / 6.0;
        let kh_bits = self.qs.k_h().log2();
        let n_bits = (op.n as f64).log2();
        mpc.min(kh_bits).min(n_bits).ceil().max(1.0) as u32
    }

    fn energy(
        &self,
        op: &OpPoint,
        crit: AdcCriterion,
        w: &SignalStats,
        x: &SignalStats,
    ) -> EnergyBreakdown {
        // Table III: E = Bw * Bx * (E_QS + E_ADC) + E_misc.
        let b_adc = self.b_adc_for(op, crit, w, x);
        let e_qs = self.qs.energy_per_bl_op(op.n as f64 / 4.0);
        let v_c = self.v_c_for(op, crit, w, x);
        let e_adc = self.adc.energy(b_adc, v_c);
        let planes = (op.bw * op.bx) as f64;
        EnergyBreakdown {
            analog: planes * e_qs,
            adc: planes * e_adc,
            misc: self.e_misc,
        }
    }

    fn delay(&self, op: &OpPoint) -> f64 {
        // Bit-serial over B_x input bits; B_w columns in parallel; ADC
        // conversion pipelined with the next compute cycle (bounded by
        // the slower of the two).
        let adc_t = self.adc.delay(op.b_adc, self.t_comp);
        op.bx as f64 * self.qs.delay().max(adc_t)
    }

    fn pjrt_params(
        &self,
        op: &OpPoint,
        w: &SignalStats,
        x: &SignalStats,
    ) -> [f64; pvec::P] {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = op.n as f64;
        p[pvec::IDX_BX] = op.bx as f64;
        p[pvec::IDX_BW] = op.bw as f64;
        p[pvec::IDX_B_ADC] = op.b_adc as f64;
        p[pvec::QS_IDX_SIGMA_D] = self.qs.sigma_d();
        p[pvec::QS_IDX_SIGMA_T] = self.qs.sigma_t_rel();
        // t_rf is calibrated into Delta-V_BL,unit (see QsModel::
        // delta_v_unit); the simulator's unit is the realized discharge.
        p[pvec::QS_IDX_T_RF] = 0.0;
        p[pvec::QS_IDX_SIGMA_THETA] = self.qs.sigma_theta_counts(op.n);
        p[pvec::QS_IDX_K_H] = self.qs.k_h();
        p[pvec::QS_IDX_V_C] = self.v_c_counts(op.n);
        p[pvec::QS_IDX_MODE] = 0.0;
        let _ = (w, x);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::TechNode;

    fn arch(v_wl: f64) -> QsArch {
        QsArch::new(QsModel::new(TechNode::n65(), v_wl))
    }

    fn uni() -> (SignalStats, SignalStats) {
        (
            SignalStats::uniform_signed(1.0),
            SignalStats::uniform_unsigned(1.0),
        )
    }

    #[test]
    fn snr_a_plateau_then_collapse_with_n() {
        // Fig. 9(a): SNR_A flat-ish in N below N_max, sharp drop above.
        let a = arch(0.8);
        let (w, x) = uni();
        let at = |n: usize| a.noise(&OpPoint::new(n, 6, 6, 8), &w, &x).snr_a_total_db();
        let lo_n = at(64);
        let hi_n = at(512);
        assert!(lo_n > 15.0, "{lo_n}");
        assert!(lo_n - hi_n > 10.0, "collapse: {lo_n} -> {hi_n}");
        // below N_max the curve is ~flat (electrical noise matches signal growth)
        assert!((at(32) - at(96)).abs() < 1.5);
    }

    #[test]
    fn higher_v_wl_higher_peak_snr_lower_n_max() {
        let (w, x) = uni();
        let snr = |v: f64, n: usize| {
            arch(v).noise(&OpPoint::new(n, 6, 6, 8), &w, &x).snr_a_db()
        };
        // at small N (no clipping), higher V_WL wins (lower sigma_D)
        assert!(snr(0.8, 48) > snr(0.6, 48) + 3.0);
        // at large N, the lower V_WL (bigger k_h) wins
        assert!(snr(0.6, 400) > snr(0.8, 400));
    }

    #[test]
    fn n_max_doubles_per_3db_snr_drop() {
        // Paper Sec. V-B1. Find N where clipping noise equals electrical.
        let (w, x) = uni();
        let n_max = |v_wl: f64| {
            let a = arch(v_wl);
            (8..2048)
                .find(|&n| {
                    let nb = a.noise(&OpPoint::new(n, 6, 6, 8), &w, &x);
                    nb.sigma_eta_h2 > nb.sigma_eta_e2
                })
                .unwrap_or(2048)
        };
        let (w1, w2) = (n_max(0.8), n_max(0.7));
        // lower V_WL: ~3 dB lower SNR_a, ~2x larger N_max
        let ratio = w2 as f64 / w1 as f64;
        assert!(ratio > 1.5 && ratio < 4.5, "{w1} {w2}");
    }

    #[test]
    fn b_adc_min_small_and_saturating() {
        // Fig. 9(b): MPC assigns <= 8 bits; bounded by log2(N) at small N.
        let a = arch(0.7);
        let (w, x) = uni();
        let b = a.b_adc_min(&OpPoint::new(128, 6, 6, 8), &w, &x);
        assert!(b <= 8, "{b}");
        let b_small = a.b_adc_min(&OpPoint::new(16, 6, 6, 8), &w, &x);
        assert!(b_small <= 4, "{b_small}");
    }

    #[test]
    fn adc_energy_flat_or_falling_with_n_under_mpc() {
        // Fig. 12(a): QS-Arch ADC energy non-increasing with N under MPC.
        let a = arch(0.7);
        let (w, x) = uni();
        let e = |n: usize| {
            a.energy(&OpPoint::new(n, 6, 6, 8), AdcCriterion::Mpc, &w, &x).adc
        };
        assert!(e(512) <= e(64) * 1.05, "{} {}", e(64), e(512));
    }

    #[test]
    fn mpc_adc_energy_never_exceeds_bgc_and_falls_with_n() {
        // Fig. 12(a): BGC E_ADC ~flat with N (V_c ~ N); MPC E_ADC falls
        // with N (V_c ~ sqrt(N)) until the two ranges coincide at the
        // headroom clip.
        let a = arch(0.7);
        let (w, x) = uni();
        for n in [16usize, 64, 256, 512] {
            let op = OpPoint::new(n, 6, 6, 8);
            let mpc = a.energy(&op, AdcCriterion::Mpc, &w, &x).adc;
            let bgc = a.energy(&op, AdcCriterion::Bgc, &w, &x).adc;
            // within 10%: eq. (26)'s (V_dd/V_c)^2 term slightly penalizes
            // MPC's narrower range when bit counts coincide
            assert!(mpc <= bgc * 1.1, "N={n}: {mpc} {bgc}");
        }
        let small = a.energy(&OpPoint::new(16, 6, 6, 8), AdcCriterion::Mpc, &w, &x).adc;
        let big = a.energy(&OpPoint::new(512, 6, 6, 8), AdcCriterion::Mpc, &w, &x).adc;
        assert!(big < small, "{big} {small}");
    }

    #[test]
    fn params_vector_layout() {
        let a = arch(0.8);
        let (w, x) = uni();
        let p = a.pjrt_params(&OpPoint::new(128, 6, 7, 8), &w, &x);
        assert_eq!(p[pvec::IDX_N_ACTIVE], 128.0);
        assert_eq!(p[pvec::IDX_BX], 6.0);
        assert_eq!(p[pvec::IDX_BW], 7.0);
        assert!((p[pvec::QS_IDX_SIGMA_D] - 0.107).abs() < 0.01);
        assert!(p[pvec::QS_IDX_K_H] > 20.0);
    }

    #[test]
    fn delay_scales_with_input_bits() {
        let a = arch(0.8);
        let d4 = a.delay(&OpPoint::new(128, 4, 6, 8));
        let d8 = a.delay(&OpPoint::new(128, 8, 6, 8));
        assert!((d8 / d4 - 2.0).abs() < 1e-9);
    }
}
