//! IMC architectures (Sec. IV, Fig. 7, Table III): QS-Arch, QR-Arch and
//! CM, each composing the compute models of `crate::compute` into a full
//! multi-bit dot-product engine with closed-form noise, precision, energy
//! and delay models, plus the normalized parameter vector consumed by the
//! PJRT simulation artifacts and the native Monte-Carlo simulator.

pub mod banked;
pub mod cm;
pub mod qr_arch;
pub mod qs_arch;

pub use banked::Banked;
pub use cm::CmArch;
pub use qr_arch::QrArch;
pub use qs_arch::QsArch;

use crate::quant::SignalStats;
use crate::util::stats::db;

/// Shared runtime parameter-vector layout (mirror of python/compile/params.py;
/// pinned by tests on both sides).
pub mod pvec {
    pub const P: usize = 16;
    pub const IDX_N_ACTIVE: usize = 0;
    pub const IDX_BX: usize = 1;
    pub const IDX_BW: usize = 2;
    pub const IDX_B_ADC: usize = 3;

    pub const QS_IDX_SIGMA_D: usize = 4;
    pub const QS_IDX_SIGMA_T: usize = 5;
    pub const QS_IDX_T_RF: usize = 6;
    pub const QS_IDX_SIGMA_THETA: usize = 7;
    pub const QS_IDX_K_H: usize = 8;
    pub const QS_IDX_V_C: usize = 9;
    pub const QS_IDX_MODE: usize = 10;

    pub const QR_IDX_SIGMA_C: usize = 4;
    pub const QR_IDX_INJ_A: usize = 5;
    pub const QR_IDX_INJ_B: usize = 6;
    pub const QR_IDX_SIGMA_THETA: usize = 7;
    pub const QR_IDX_V_C: usize = 8;
    pub const QR_IDX_V_LO: usize = 9;

    pub const CM_IDX_SIGMA_D: usize = 4;
    pub const CM_IDX_W_H: usize = 5;
    pub const CM_IDX_SIGMA_C: usize = 6;
    pub const CM_IDX_INJ_A: usize = 7;
    pub const CM_IDX_INJ_B: usize = 8;
    pub const CM_IDX_SIGMA_THETA: usize = 9;
    pub const CM_IDX_V_C: usize = 10;

    /// Bank count of a multi-bank DP (shared across architectures; the
    /// arch-specific slots stay per-bank). Encoding contract: `0.0`
    /// means single-bank — the pre-banking parameter layout — and
    /// [`crate::arch::Banked::pjrt_params`] writes the bank count only
    /// when it is >= 2, so every single-bank parameter vector (and
    /// therefore every existing result-cache key, which hashes this
    /// vector) is bit-identical to the unbanked encoding.
    pub const IDX_BANKS: usize = 15;
}

/// One operating point of a DP engine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpPoint {
    /// DP dimension N.
    pub n: usize,
    /// Activation precision B_x.
    pub bx: u32,
    /// Weight precision B_w.
    pub bw: u32,
    /// Column-ADC precision B_ADC.
    pub b_adc: u32,
    /// Bank count (Sec. VI): the N-dimensional DP is split across
    /// `banks` arrays of `ceil(N / banks)` rows each. The bare
    /// architecture models describe a single array and ignore this
    /// field; callers route multi-bank points through [`Banked`], which
    /// is the one interpreter of the bank count. Declarative carrier
    /// for the `--banks` sweep/domain axis.
    pub banks: usize,
}

impl OpPoint {
    pub fn new(n: usize, bx: u32, bw: u32, b_adc: u32) -> Self {
        Self {
            n,
            bx,
            bw,
            b_adc,
            banks: 1,
        }
    }

    pub fn with_banks(mut self, banks: usize) -> Self {
        assert!(banks >= 1, "bank count must be >= 1");
        self.banks = banks;
        self
    }
}

/// ADC-precision assignment criterion (Sec. III-C/D).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdcCriterion {
    /// Minimum precision criterion, clipping at 4 sigma (eq. 15).
    Mpc,
    /// Bit growth criterion (eq. 12).
    Bgc,
    /// Truncated BGC at a fixed B_y.
    TBgc(u32),
    /// Explicit ADC precision over the MPC statistical (4-sigma) range —
    /// the design-space explorer's B_ADC axis (`crate::opt`), where the
    /// bit count is a search dimension rather than an assignment rule.
    Fixed(u32),
}

/// Closed-form noise decomposition at one operating point (Table III).
#[derive(Clone, Copy, Debug, Default)]
pub struct NoiseBreakdown {
    /// Signal power sigma_yo^2 (eq. 5).
    pub sigma_yo2: f64,
    /// Input quantization sigma_qiy^2 (eq. 5).
    pub sigma_qiy2: f64,
    /// Headroom clipping sigma_eta_h^2.
    pub sigma_eta_h2: f64,
    /// Circuit/electrical sigma_eta_e2.
    pub sigma_eta_e2: f64,
}

impl NoiseBreakdown {
    pub fn sigma_eta_a2(&self) -> f64 {
        self.sigma_eta_h2 + self.sigma_eta_e2
    }

    /// SNR_a (analog-only, eq. 7).
    pub fn snr_a_db(&self) -> f64 {
        db(self.sigma_yo2 / self.sigma_eta_a2())
    }

    /// Pre-ADC SNR_A (eq. 10).
    pub fn snr_a_total_db(&self) -> f64 {
        db(self.sigma_yo2 / (self.sigma_qiy2 + self.sigma_eta_a2()))
    }

    pub fn sqnr_qiy_db(&self) -> f64 {
        db(self.sigma_yo2 / self.sigma_qiy2)
    }

    /// SNR_T given an additional output-quantization variance.
    pub fn snr_t_db(&self, sigma_qy2: f64) -> f64 {
        db(self.sigma_yo2 / (self.sigma_qiy2 + self.sigma_eta_a2() + sigma_qy2))
    }
}

/// Per-DP energy decomposition (Table III "Energy cost per DP").
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    /// Analog core (BL discharge / charge share / multipliers) [J].
    pub analog: f64,
    /// Column ADC conversions [J].
    pub adc: f64,
    /// Digital recombination, DAC amortization, misc [J].
    pub misc: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.analog + self.adc + self.misc
    }
}

/// A full IMC architecture: Table III closed forms + runtime param vector.
pub trait ImcArch {
    fn name(&self) -> &'static str;

    /// The technology node the model is instantiated on.
    fn tech(&self) -> crate::tech::TechNode;

    /// Closed-form per-DP silicon area (Table III array geometry; see
    /// `crate::area` for the per-block constants and scaling rules).
    fn area(&self, op: &OpPoint) -> crate::area::AreaBreakdown;

    /// Closed-form noise decomposition (Table III).
    fn noise(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> NoiseBreakdown;

    /// ADC input range V_c [V at the ADC] (Table III — the MPC
    /// statistical 4-sigma range).
    fn v_c_volts(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> f64;

    /// Worst-case (full-scale) ADC range used by BGC/tBGC, which cover
    /// the entire arithmetic range instead of clipping.
    fn v_c_full_volts(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> f64;

    /// ADC range under a criterion.
    fn v_c_for(
        &self,
        op: &OpPoint,
        crit: AdcCriterion,
        w: &SignalStats,
        x: &SignalStats,
    ) -> f64 {
        match crit {
            AdcCriterion::Mpc | AdcCriterion::Fixed(_) => self.v_c_volts(op, w, x),
            _ => self.v_c_full_volts(op, w, x),
        }
    }

    /// Minimum ADC precision (Table III row B_ADC) for SNR_T within
    /// 0.5 dB of SNR_A.
    fn b_adc_min(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> u32;

    /// Per-DP energy decomposition under an ADC criterion.
    fn energy(
        &self,
        op: &OpPoint,
        crit: AdcCriterion,
        w: &SignalStats,
        x: &SignalStats,
    ) -> EnergyBreakdown;

    /// Per-DP latency [s].
    fn delay(&self, op: &OpPoint) -> f64;

    /// Normalized parameter vector for the PJRT artifact / native MC.
    fn pjrt_params(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats)
        -> [f64; pvec::P];

    /// Which artifact family simulates this architecture.
    fn artifact_name(&self) -> &'static str;

    /// Column-ADC precision under BGC (eq. 12 applied to what the ADC
    /// actually digitizes): QS-Arch digitizes a *binarized* BL DP
    /// (log2 N bits), QR-Arch a binary-weighted row (B_x + log2 N), CM
    /// the full multi-bit DP (B_x + B_w + log2 N).
    fn b_adc_bgc(&self, op: &OpPoint) -> u32;

    /// Effective ADC bits under a criterion (MPC bound vs BGC growth).
    fn b_adc_for(
        &self,
        op: &OpPoint,
        crit: AdcCriterion,
        w: &SignalStats,
        x: &SignalStats,
    ) -> u32 {
        match crit {
            AdcCriterion::Mpc => self.b_adc_min(op, w, x),
            AdcCriterion::Bgc => self.b_adc_bgc(op),
            AdcCriterion::TBgc(b) | AdcCriterion::Fixed(b) => b,
        }
    }
}

/// Binomial upper-tail clipping moment used by QS-Arch (appendix B):
/// E[(K - k_h)^2 ; K >= k_h] for K ~ Bin(n, p), computed by a stable pmf
/// recurrence with a Gaussian-tail fallback when the pmf underflows.
pub fn binomial_clip_moment(n: usize, p: f64, k_h: f64) -> f64 {
    if k_h >= n as f64 {
        return 0.0;
    }
    let ln_p0 = n as f64 * (1.0 - p).ln();
    if ln_p0 < -700.0 {
        // Gaussian approximation for very large n.
        let mu = n as f64 * p;
        let sd = (n as f64 * p * (1.0 - p)).sqrt();
        let z = (k_h - mu) / sd;
        let q = crate::quant::criteria::q_func(z);
        let phi = (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt();
        // E[(X-c)^2; X>c] for X~N(mu, sd^2): sd^2[(1+z^2)Q(z) - z phi(z)]
        return sd * sd * ((1.0 + z * z) * q - z * phi);
    }
    let mut pmf = ln_p0.exp();
    let ratio = p / (1.0 - p);
    let mut acc = 0.0;
    for k in 0..=n {
        let kf = k as f64;
        if kf > k_h {
            let d = kf - k_h;
            acc += d * d * pmf;
        }
        pmf *= ratio * (n - k) as f64 / (k as f64 + 1.0);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_clip_moment_zero_beyond_n() {
        assert_eq!(binomial_clip_moment(100, 0.25, 100.0), 0.0);
    }

    #[test]
    fn binomial_clip_moment_monotone_in_kh() {
        let a = binomial_clip_moment(512, 0.25, 100.0);
        let b = binomial_clip_moment(512, 0.25, 140.0);
        assert!(a > b && b >= 0.0, "{a} {b}");
    }

    #[test]
    fn binomial_clip_moment_matches_mc() {
        let (n, kh) = (256usize, 72.0);
        let pred = binomial_clip_moment(n, 0.25, kh);
        let mut rng = crate::util::rng::Pcg64::new(21);
        let mut acc = 0.0;
        let trials = 200_000;
        for _ in 0..trials {
            let mut k = 0u32;
            for _ in 0..n {
                if rng.uniform() < 0.25 {
                    k += 1;
                }
            }
            let d = k as f64 - kh;
            if d > 0.0 {
                acc += d * d;
            }
        }
        let mc = acc / trials as f64;
        assert!(
            (mc - pred).abs() / pred.max(1e-12) < 0.15,
            "mc={mc} pred={pred}"
        );
    }

    #[test]
    fn gaussian_fallback_continuous() {
        // near the underflow switch the two methods should agree
        let a = binomial_clip_moment(2000, 0.25, 560.0);
        let mu = 500.0;
        let sd = (2000.0f64 * 0.25 * 0.75).sqrt();
        let z: f64 = (560.0 - mu) / sd;
        let q = crate::quant::criteria::q_func(z);
        let phi = (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt();
        let g = sd * sd * ((1.0 + z * z) * q - z * phi);
        // binomial tails are skewed; the Gaussian fallback is a ~20%
        // approximation near the switch point
        assert!((a - g).abs() / g < 0.3, "{a} {g}");
    }

    #[test]
    fn noise_breakdown_composition() {
        let nb = NoiseBreakdown {
            sigma_yo2: 10.0,
            sigma_qiy2: 0.01,
            sigma_eta_h2: 0.04,
            sigma_eta_e2: 0.05,
        };
        assert!(nb.snr_a_total_db() < nb.snr_a_db());
        assert!(nb.snr_t_db(0.01) < nb.snr_a_total_db());
        assert!((nb.snr_a_db() - db(10.0 / 0.09)).abs() < 1e-9);
    }
}
