//! Compute Memory (Sec. IV-D, Fig. 7(c), Table III column 3): multi-bit
//! DP in a single compute cycle — POT-weighted WL pulse widths realize a
//! multi-bit analog weight on each column's BL (QS model), a per-column
//! mixed-signal multiplier forms w_j * x_j, and a QR stage aggregates the
//! N columns; one ADC conversion per DP.

use super::{pvec, AdcCriterion, EnergyBreakdown, ImcArch, NoiseBreakdown, OpPoint};
use crate::compute::qr::QrModel;
use crate::compute::qs::QsModel;
use crate::energy::adc::AdcEnergyModel;
use crate::quant::SignalStats;

#[derive(Clone, Copy, Debug)]
pub struct CmArch {
    pub qs: QsModel,
    pub qr: QrModel,
    pub adc: AdcEnergyModel,
    pub e_misc: f64,
    pub t_comp: f64,
    /// Use the exact uniform-weight clipping moment instead of the
    /// Chebyshev-bounded Table III estimate (DESIGN.md §6).
    pub exact_clip: bool,
}

impl CmArch {
    pub fn new(qs: QsModel, qr: QrModel) -> Self {
        let adc = AdcEnergyModel::paper(qs.tech.v_dd);
        Self {
            qs,
            qr,
            adc,
            e_misc: 25e-15,
            t_comp: 100e-12,
            exact_clip: true,
        }
    }

    pub fn with_exact_clip(mut self, exact: bool) -> Self {
        self.exact_clip = exact;
        self
    }

    /// Weight-domain headroom clip w_h = k_h * Delta_w (appendix B), with
    /// k_h = dV_BL,max / dV_BL,unit and Delta_w = 2^{1-Bw} (w_m = 1).
    pub fn w_h(&self, bw: u32) -> f64 {
        let k_h = self.qs.k_h();
        (k_h * 2f64.powi(1 - bw as i32)).min(1.0)
    }

    /// T_max for a B_w-bit POT pulse train: 2^{Bw-1} T_0.
    pub fn t_max(&self, bw: u32) -> f64 {
        2f64.powi(bw as i32 - 1) * self.qs.tech.t0
    }
}

impl ImcArch for CmArch {
    fn name(&self) -> &'static str {
        "CM"
    }

    fn artifact_name(&self) -> &'static str {
        "cm_arch"
    }

    fn tech(&self) -> crate::tech::TechNode {
        self.qs.tech
    }

    fn area(&self, op: &OpPoint) -> crate::area::AreaBreakdown {
        crate::area::cm_area(&self.qs.tech, self.qr.c_o_ff(), op)
    }

    fn noise(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> NoiseBreakdown {
        let n = op.n as f64;
        let sigma_yo2 = crate::quant::dp_signal_variance(op.n, w, x);
        let sigma_qiy2 = crate::quant::qiy_variance(op.n, op.bw, op.bx, w, x);

        let ex2 = x.second_moment / (x.peak * x.peak);
        let w_h = self.w_h(op.bw);
        let sigma_eta_h2 = if self.exact_clip {
            // Exact for w ~ U[-1, 1): E[lambda^2] = (1 - w_h)_+^3 / 3.
            let t = (1.0 - w_h).max(0.0);
            n * ex2 * t * t * t / 3.0
        } else {
            // Table III (Chebyshev-bounded) estimate.
            let k_h = self.qs.k_h();
            let t = (1.0 - 2.0 * k_h * 2f64.powi(-(op.bw as i32))).max(0.0);
            n * ex2 / 12.0
                * w.variance
                * k_h.powi(-2)
                * 4f64.powi(op.bw as i32)
                * t
                * t
        };

        // sigma_eta_e^2 (Table III): (2/3) N E[x^2] (1/4 - 4^-Bw) sigma_D^2
        // — current mismatch on the sign-magnitude POT planes — plus the
        // (small) QR aggregation-stage terms.
        let sd2 = self.qs.sigma_d().powi(2);
        let mismatch =
            2.0 / 3.0 * n * ex2 * (0.25 - 4f64.powi(-(op.bw as i32))) * sd2;
        let var_v = ex2 * w.variance / (x.peak * x.peak).max(1e-30); // Var(w x)
        let qr_stage = n
            * (self.qr.sigma_c_rel().powi(2) * var_v
                + self.qr.sigma_theta_rel().powi(2));
        let sigma_eta_e2 = mismatch + qr_stage;

        NoiseBreakdown {
            sigma_yo2,
            sigma_qiy2,
            sigma_eta_h2,
            sigma_eta_e2,
        }
    }

    fn v_c_volts(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> f64 {
        // Table III: V_c = 8 sigma_w 2^Bw dV_unit sqrt(E[x^2]) / sqrt(N)
        // (half-range 4 sigma_y of the aggregated output voltage).
        let n = op.n as f64;
        let ex2 = x.second_moment / (x.peak * x.peak);
        4.0 * w.variance.sqrt()
            * 2f64.powi(op.bw as i32 - 1)
            * self.qs.delta_v_unit()
            * ex2.sqrt()
            / n.sqrt()
            * 2.0
    }

    fn b_adc_bgc(&self, op: &OpPoint) -> u32 {
        // single conversion of the full multi-bit DP (eq. 12)
        crate::quant::criteria::bgc_bits(op.bx, op.bw, op.n)
    }

    fn v_c_full_volts(&self, op: &OpPoint, _w: &SignalStats, _x: &SignalStats) -> f64 {
        // worst case |y/n| <= w_h: full-scale aggregated voltage
        self.w_h(op.bw).min(1.0)
            * 2f64.powi(op.bw as i32 - 1)
            * self.qs.delta_v_unit()
    }

    fn b_adc_min(&self, op: &OpPoint, w: &SignalStats, x: &SignalStats) -> u32 {
        let snr_a_db = self.noise(op, w, x).snr_a_total_db();
        ((snr_a_db + 16.2) / 6.0).ceil().max(1.0) as u32
    }

    fn energy(
        &self,
        op: &OpPoint,
        crit: AdcCriterion,
        w: &SignalStats,
        x: &SignalStats,
    ) -> EnergyBreakdown {
        // Table III: E_CM = 2N E_QS + E_QR + E_mult + E_ADC + E_misc.
        let b_adc = self.b_adc_for(op, crit, w, x);
        // Per-column BL discharge: expected |w| * 2^{Bw-1} counts on both
        // BL and BLB (factor 2), at the CM pulse train length.
        let mut qs = self.qs;
        qs.t_max = self.t_max(op.bw);
        let e_w = 0.5 * 2f64.powi(op.bw as i32 - 1); // E[|w|] 2^{Bw-1} counts
        let e_qs_col = qs.energy_per_bl_op(e_w);
        let mu_x = (x.second_moment - x.variance).max(0.0).sqrt();
        let e_qr = self.qr.energy_share(op.n, self.qr.tech.v_dd * mu_x / 2.0);
        let e_mult = op.n as f64 * self.qr.energy_mult(mu_x / x.peak / 2.0);
        let v_c = self.v_c_for(op, crit, w, x);
        let e_adc = self.adc.energy(b_adc, v_c);
        EnergyBreakdown {
            analog: 2.0 * op.n as f64 * e_qs_col + e_qr + e_mult,
            adc: e_adc,
            misc: self.e_misc,
        }
    }

    fn delay(&self, op: &OpPoint) -> f64 {
        self.t_max(op.bw) + self.qs.t_su + self.qr.delay()
            + self.adc.delay(op.b_adc, self.t_comp)
    }

    fn pjrt_params(
        &self,
        op: &OpPoint,
        w: &SignalStats,
        x: &SignalStats,
    ) -> [f64; pvec::P] {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = op.n as f64;
        p[pvec::IDX_BX] = op.bx as f64;
        p[pvec::IDX_BW] = op.bw as f64;
        p[pvec::IDX_B_ADC] = op.b_adc as f64;
        p[pvec::CM_IDX_SIGMA_D] = self.qs.sigma_d();
        p[pvec::CM_IDX_W_H] = self.w_h(op.bw);
        p[pvec::CM_IDX_SIGMA_C] = self.qr.sigma_c_rel();
        p[pvec::CM_IDX_INJ_A] = self.qr.inj_a_rel();
        p[pvec::CM_IDX_INJ_B] = self.qr.inj_b_rel();
        p[pvec::CM_IDX_SIGMA_THETA] = self.qr.sigma_theta_rel();
        // ADC range in normalized per-column mean units: V = y/n, 4 sigma.
        let n = op.n as f64;
        let ex2 = x.second_moment / (x.peak * x.peak);
        p[pvec::CM_IDX_V_C] = 4.0 * (w.variance * ex2).sqrt() / n.sqrt();
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::TechNode;

    fn arch(v_wl: f64) -> CmArch {
        CmArch::new(
            QsModel::new(TechNode::n65(), v_wl),
            QrModel::new(TechNode::n65(), 3.0),
        )
    }

    fn uni() -> (SignalStats, SignalStats) {
        (
            SignalStats::uniform_signed(1.0),
            SignalStats::uniform_unsigned(1.0),
        )
    }

    #[test]
    fn optimal_bw_exists() {
        // Fig. 11(a): SNR_A has an interior optimum in B_w.
        let (w, x) = uni();
        let a = arch(0.8);
        let snr = |bw: u32| {
            a.noise(&OpPoint::new(64, 6, bw, 8), &w, &x).snr_a_total_db()
        };
        let snrs: Vec<(u32, f64)> = (2..=8).map(|b| (b, snr(b))).collect();
        let best = snrs
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
            .0;
        assert!((4..=7).contains(&best), "{snrs:?}");
    }

    #[test]
    fn lower_v_wl_shifts_optimum_right() {
        // Fig. 11(a): optimum B_w is ~6 at 0.8 V, ~7 at 0.7 V.
        let (w, x) = uni();
        let best_bw = |v: f64| {
            let a = arch(v);
            (2..=8)
                .max_by(|&p, &q| {
                    let sp = a.noise(&OpPoint::new(64, 6, p, 8), &w, &x).snr_a_db();
                    let sq = a.noise(&OpPoint::new(64, 6, q, 8), &w, &x).snr_a_db();
                    sp.partial_cmp(&sq).unwrap()
                })
                .unwrap()
        };
        assert!(best_bw(0.7) >= best_bw(0.8), "{} {}", best_bw(0.7), best_bw(0.8));
    }

    #[test]
    fn clipping_vs_electrical_balance_near_07v() {
        // Fig. 11(a): at B_w = 7 eta_e dominates at 0.6 V, eta_h at 0.8 V.
        let (w, x) = uni();
        let op = OpPoint::new(64, 6, 7, 8);
        let lo = arch(0.6).noise(&op, &w, &x);
        let hi = arch(0.8).noise(&op, &w, &x);
        assert!(lo.sigma_eta_e2 > lo.sigma_eta_h2, "0.6 V: eta_e dominates");
        assert!(hi.sigma_eta_h2 > hi.sigma_eta_e2, "0.8 V: eta_h dominates");
    }

    #[test]
    fn w_h_halves_per_weight_bit() {
        let a = arch(0.8);
        let w4 = a.w_h(4);
        let w5 = a.w_h(5);
        if w4 < 1.0 {
            assert!((w4 / w5 - 2.0).abs() < 1e-9);
        }
        assert!(a.w_h(2) >= a.w_h(8));
    }

    #[test]
    fn single_adc_conversion_per_dp() {
        // CM avoids per-plane ADC cost: at the same op point its ADC
        // energy is below QS-Arch's Bw*Bx conversions.
        let (w, x) = uni();
        let op = OpPoint::new(64, 6, 6, 8);
        let cm = arch(0.8).energy(&op, AdcCriterion::Mpc, &w, &x);
        let qs = crate::arch::QsArch::new(QsModel::new(TechNode::n65(), 0.8))
            .energy(&op, AdcCriterion::Mpc, &w, &x);
        assert!(cm.adc < qs.adc, "{} {}", cm.adc, qs.adc);
    }

    #[test]
    fn adc_energy_grows_with_n_under_mpc() {
        // Fig. 12(c): V_c ~ 1/sqrt(N).
        let (w, x) = uni();
        let a = arch(0.8);
        let e64 = a.energy(&OpPoint::new(64, 6, 6, 8), AdcCriterion::Mpc, &w, &x).adc;
        let e512 =
            a.energy(&OpPoint::new(512, 6, 6, 8), AdcCriterion::Mpc, &w, &x).adc;
        assert!(e512 > e64, "{e64} {e512}");
    }

    #[test]
    fn exact_clip_below_chebyshev_bound() {
        let (w, x) = uni();
        let op = OpPoint::new(64, 6, 7, 8);
        let exact = arch(0.8).noise(&op, &w, &x).sigma_eta_h2;
        let bound = arch(0.8).with_exact_clip(false).noise(&op, &w, &x).sigma_eta_h2;
        if bound > 0.0 {
            assert!(exact <= bound * 1.5, "{exact} {bound}");
        }
    }

    #[test]
    fn params_vector_layout() {
        let (w, x) = uni();
        let p = arch(0.8).pjrt_params(&OpPoint::new(64, 6, 6, 8), &w, &x);
        assert_eq!(p[pvec::IDX_N_ACTIVE], 64.0);
        assert!(p[pvec::CM_IDX_W_H] > 0.0 && p[pvec::CM_IDX_W_H] <= 1.0);
        assert!(p[pvec::CM_IDX_V_C] > 0.0);
    }
}
