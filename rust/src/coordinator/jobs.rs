//! Job manager for `imclim serve`: a bounded submission queue with
//! backpressure, monotone job ids, a queued → running → done/failed
//! lifecycle (plus canceled), and graceful drain.
//!
//! Execution policy: one sequential executor thread. Sweep jobs already
//! saturate the machine through the scheduler's worker pool, so running
//! jobs back-to-back (instead of concurrently) keeps cache writes
//! race-free and makes per-job metrics exact — the executor differences
//! two [`metrics::snapshot`]s around each run. The actual work is an
//! injected [`JobRunner`] closure, which keeps this module independent
//! of the CLI layer that knows how to execute a sweep.

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::metrics::{self, MetricsSnapshot};
use crate::obs::progress::{self, EventLog};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Canceled,
}

impl JobState {
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Canceled => "canceled",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Canceled)
    }
}

/// What a client submits: a CLI verb (`sweep`, `pareto`, `optimize`)
/// plus the exact option/switch strings the CLI would parse, so a
/// served query and its command-line twin build identical grids.
#[derive(Clone, Debug, Default)]
pub struct JobSpec {
    pub verb: String,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

/// A job's externally visible state.
#[derive(Clone, Debug)]
pub struct JobStatus {
    pub id: u64,
    pub verb: String,
    pub state: JobState,
    pub error: Option<String>,
    /// The result CSV, once the job is done.
    pub result_path: Option<PathBuf>,
    /// Counters accumulated while this job ran (exact: the executor is
    /// single-threaded, so exactly one job runs at a time).
    pub metrics: MetricsSnapshot,
    /// Wall-clock lifecycle stamps (Unix milliseconds): submission,
    /// executor claim, terminal transition.
    pub queued_at_ms: u64,
    pub started_at_ms: Option<u64>,
    pub finished_at_ms: Option<u64>,
}

impl JobStatus {
    /// Running time (`finished - started`), once both stamps exist.
    pub fn duration_ms(&self) -> Option<u64> {
        match (self.started_at_ms, self.finished_at_ms) {
            (Some(a), Some(b)) => Some(b.saturating_sub(a)),
            _ => None,
        }
    }
}

/// Current wall clock as Unix milliseconds.
fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::SystemTime::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is at capacity — retry later (HTTP 429).
    QueueFull,
    /// The daemon is draining — no new work (HTTP 503).
    ShuttingDown,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelOutcome {
    Canceled,
    /// In-flight jobs run to completion; only queued jobs cancel.
    Running,
    Finished,
    Unknown,
}

/// Per-state job counts, for the `/stats` surface.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    pub queued: usize,
    pub running: usize,
    pub done: usize,
    pub failed: usize,
    pub canceled: usize,
}

/// Executes one job: gets the job id and spec, returns the result CSV.
pub type JobRunner = dyn Fn(u64, &JobSpec) -> anyhow::Result<PathBuf> + Send + Sync;

struct Job {
    spec: JobSpec,
    status: JobStatus,
    /// Structured progress events collected while the job runs, closed
    /// with a terminal event — the backing store of
    /// `GET /jobs/<id>/events`.
    events: Arc<EventLog>,
}

/// Append the job's terminal event and close its log. Called exactly
/// once per job, on whichever path finishes it (run, cancel, drain).
fn finish_events(job: &Job) {
    let m = &job.status.metrics;
    let mut pairs = vec![
        ("id", crate::util::json::num(job.status.id as f64)),
        ("state", crate::util::json::s(job.status.state.as_str())),
        ("cache_hits", crate::util::json::num(m.cache_hits as f64)),
        (
            "cache_misses",
            crate::util::json::num(m.cache_misses as f64),
        ),
        (
            "points_computed",
            crate::util::json::num(m.points_computed as f64),
        ),
        (
            "trials_completed",
            crate::util::json::num(m.trials_completed as f64),
        ),
    ];
    if let Some(d) = job.status.duration_ms() {
        pairs.push(("duration_ms", crate::util::json::num(d as f64)));
    }
    if let Some(e) = &job.status.error {
        pairs.push(("error", crate::util::json::s(e)));
    }
    job.events.append(progress::terminal_line(pairs));
    job.events.close();
}

#[derive(Default)]
struct State {
    jobs: BTreeMap<u64, Job>,
    queue: VecDeque<u64>,
    next_id: u64,
    shutting_down: bool,
}

struct Shared {
    state: Mutex<State>,
    cv: Condvar,
    capacity: usize,
    runner: Box<JobRunner>,
}

pub struct JobManager {
    shared: Arc<Shared>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl JobManager {
    /// Start the executor. `capacity` bounds the number of *queued*
    /// jobs (the in-flight one rides for free).
    pub fn new(capacity: usize, runner: Box<JobRunner>) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
            capacity: capacity.max(1),
            runner,
        });
        let for_worker = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("serve-executor".into())
            .spawn(move || executor_loop(for_worker))
            .expect("spawn serve executor");
        Self {
            shared,
            worker: Mutex::new(Some(worker)),
        }
    }

    pub fn submit(&self, spec: JobSpec) -> Result<u64, SubmitError> {
        let mut st = self.shared.state.lock().unwrap();
        if st.shutting_down {
            return Err(SubmitError::ShuttingDown);
        }
        if st.queue.len() >= self.shared.capacity {
            return Err(SubmitError::QueueFull);
        }
        st.next_id += 1;
        let id = st.next_id;
        let status = JobStatus {
            id,
            verb: spec.verb.clone(),
            state: JobState::Queued,
            error: None,
            result_path: None,
            metrics: MetricsSnapshot::default(),
            queued_at_ms: now_ms(),
            started_at_ms: None,
            finished_at_ms: None,
        };
        st.jobs.insert(
            id,
            Job {
                spec,
                status,
                events: EventLog::new(),
            },
        );
        st.queue.push_back(id);
        self.shared.cv.notify_all();
        Ok(id)
    }

    pub fn status(&self, id: u64) -> Option<JobStatus> {
        let st = self.shared.state.lock().unwrap();
        st.jobs.get(&id).map(|j| j.status.clone())
    }

    /// The job's progress event log (streamed by `GET /jobs/<id>/events`).
    pub fn events(&self, id: u64) -> Option<Arc<EventLog>> {
        let st = self.shared.state.lock().unwrap();
        st.jobs.get(&id).map(|j| Arc::clone(&j.events))
    }

    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let mut st = self.shared.state.lock().unwrap();
        let state = match st.jobs.get(&id) {
            None => return CancelOutcome::Unknown,
            Some(j) => j.status.state,
        };
        match state {
            JobState::Queued => {
                st.queue.retain(|&q| q != id);
                let job = st.jobs.get_mut(&id).expect("job exists");
                job.status.state = JobState::Canceled;
                job.status.finished_at_ms = Some(now_ms());
                finish_events(job);
                CancelOutcome::Canceled
            }
            JobState::Running => CancelOutcome::Running,
            _ => CancelOutcome::Finished,
        }
    }

    pub fn queue_stats(&self) -> QueueStats {
        let st = self.shared.state.lock().unwrap();
        let mut out = QueueStats::default();
        for j in st.jobs.values() {
            match j.status.state {
                JobState::Queued => out.queued += 1,
                JobState::Running => out.running += 1,
                JobState::Done => out.done += 1,
                JobState::Failed => out.failed += 1,
                JobState::Canceled => out.canceled += 1,
            }
        }
        out
    }

    pub fn is_shutting_down(&self) -> bool {
        self.shared.state.lock().unwrap().shutting_down
    }

    /// Graceful drain: stop accepting submissions, let the in-flight
    /// job run to completion, cancel everything still queued, and join
    /// the executor. Idempotent.
    pub fn shutdown(&self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutting_down = true;
            self.shared.cv.notify_all();
        }
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn executor_loop(shared: Arc<Shared>) {
    loop {
        let (id, spec, events) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutting_down {
                    // drain: the in-flight job (if any) already finished
                    // before we got here; whatever is still queued is
                    // canceled rather than started.
                    while let Some(id) = st.queue.pop_front() {
                        if let Some(job) = st.jobs.get_mut(&id) {
                            job.status.state = JobState::Canceled;
                            job.status.finished_at_ms = Some(now_ms());
                            finish_events(job);
                        }
                    }
                    return;
                }
                if let Some(id) = st.queue.pop_front() {
                    let job = st.jobs.get_mut(&id).expect("queued job exists");
                    job.status.state = JobState::Running;
                    job.status.started_at_ms = Some(now_ms());
                    break (id, job.spec.clone(), Arc::clone(&job.events));
                }
                st = shared.cv.wait(st).unwrap();
            }
        };

        let before = metrics::snapshot();
        // route the scheduler's progress events into this job's log
        // while it runs (one collector at a time: jobs are sequential)
        progress::install_collector(Arc::clone(&events));
        // a panicking runner must not take the executor (and with it the
        // whole daemon) down — it fails the one job
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (shared.runner)(id, &spec)))
                .unwrap_or_else(|_| Err(anyhow::anyhow!("job execution panicked")));
        progress::clear_collector();
        let delta = metrics::snapshot().since(&before);

        let mut st = shared.state.lock().unwrap();
        if let Some(job) = st.jobs.get_mut(&id) {
            job.status.metrics = delta;
            job.status.finished_at_ms = Some(now_ms());
            match result {
                Ok(path) => {
                    job.status.state = JobState::Done;
                    job.status.result_path = Some(path);
                }
                Err(e) => {
                    job.status.state = JobState::Failed;
                    job.status.error = Some(format!("{e:#}"));
                }
            }
            finish_events(job);
        }
        shared.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;
    use std::sync::mpsc;
    use std::time::Duration;

    fn spec(verb: &str) -> JobSpec {
        JobSpec {
            verb: verb.into(),
            ..JobSpec::default()
        }
    }

    fn wait_terminal(mgr: &JobManager, id: u64) -> JobStatus {
        for _ in 0..5_000 {
            let s = mgr.status(id).expect("job exists");
            if s.state.is_terminal() {
                return s;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        panic!("job {id} never reached a terminal state");
    }

    #[test]
    fn jobs_run_in_order_and_report_result_or_error() {
        let ran = Arc::new(Mutex::new(Vec::new()));
        let ran2 = Arc::clone(&ran);
        let mgr = JobManager::new(
            8,
            Box::new(move |id, spec| {
                ran2.lock().unwrap().push((id, spec.verb.clone()));
                anyhow::ensure!(spec.verb != "boom", "synthetic failure");
                Ok(PathBuf::from(format!("/out/{id}.csv")))
            }),
        );
        let a = mgr.submit(spec("sweep")).unwrap();
        let b = mgr.submit(spec("boom")).unwrap();
        let sb = wait_terminal(&mgr, b);
        let sa = wait_terminal(&mgr, a);
        assert_eq!(sa.state, JobState::Done);
        assert_eq!(sa.result_path.as_deref(), Some(Path::new("/out/1.csv")));
        assert_eq!(sb.state, JobState::Failed);
        assert!(sb.error.unwrap().contains("synthetic failure"));
        assert_eq!(
            ran.lock().unwrap().as_slice(),
            &[(a, "sweep".to_string()), (b, "boom".to_string())]
        );
        assert_eq!(mgr.status(999).map(|s| s.id), None);
        mgr.shutdown();
    }

    #[test]
    fn lifecycle_stamps_and_terminal_event() {
        let mgr = JobManager::new(8, Box::new(|id, _| Ok(PathBuf::from(format!("/out/{id}.csv")))));
        let id = mgr.submit(spec("sweep")).unwrap();
        let st = wait_terminal(&mgr, id);
        assert!(st.queued_at_ms > 0);
        assert!(st.started_at_ms.unwrap() >= st.queued_at_ms);
        assert!(st.finished_at_ms.unwrap() >= st.started_at_ms.unwrap());
        assert!(st.duration_ms().is_some());
        let log = mgr.events(id).expect("event log exists");
        let (lines, closed) = log.wait_since(0, Duration::from_secs(5));
        assert!(closed, "log closes at terminal state");
        let last = lines.last().expect("terminal event present");
        assert!(last.contains("\"kind\":\"terminal\""), "{last}");
        assert!(last.contains("\"state\":\"done\""), "{last}");

        // canceled-while-queued jobs also get a closed log + terminal
        let (tx, rx) = mpsc::channel::<()>();
        let rx = Mutex::new(rx);
        let mgr2 = JobManager::new(
            8,
            Box::new(move |_, _| {
                let _ = rx.lock().unwrap().recv();
                Ok(PathBuf::from("/out/slow.csv"))
            }),
        );
        let _running = mgr2.submit(spec("sweep")).unwrap();
        let queued = mgr2.submit(spec("sweep")).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(mgr2.cancel(queued), CancelOutcome::Canceled);
        let log = mgr2.events(queued).unwrap();
        let (lines, closed) = log.wait_since(0, Duration::from_secs(5));
        assert!(closed);
        assert!(lines.last().unwrap().contains("\"state\":\"canceled\""));
        tx.send(()).unwrap();
        mgr2.shutdown();
        mgr.shutdown();
    }

    #[test]
    fn panicking_jobs_fail_without_killing_the_executor() {
        let mgr = JobManager::new(
            8,
            Box::new(|_, spec| {
                assert!(spec.verb != "panic", "deliberate test panic");
                Ok(PathBuf::from("/out/ok.csv"))
            }),
        );
        let p = mgr.submit(spec("panic")).unwrap();
        let ok = mgr.submit(spec("sweep")).unwrap();
        let sp = wait_terminal(&mgr, p);
        assert_eq!(sp.state, JobState::Failed);
        assert!(sp.error.unwrap().contains("panicked"));
        assert_eq!(wait_terminal(&mgr, ok).state, JobState::Done);
        mgr.shutdown();
    }

    #[test]
    fn backpressure_cancellation_and_graceful_drain() {
        let (started_tx, started_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let release_rx = Mutex::new(release_rx);
        let mgr = JobManager::new(
            2,
            Box::new(move |_, _| {
                started_tx.send(()).unwrap();
                let _ = release_rx.lock().unwrap().recv();
                Ok(PathBuf::from("/out/slow.csv"))
            }),
        );
        let a = mgr.submit(spec("sweep")).unwrap();
        started_rx.recv().unwrap(); // `a` is in flight, queue empty
        let b = mgr.submit(spec("sweep")).unwrap();
        let c = mgr.submit(spec("sweep")).unwrap();
        assert_eq!(mgr.submit(spec("sweep")), Err(SubmitError::QueueFull));

        assert_eq!(mgr.cancel(c), CancelOutcome::Canceled);
        assert_eq!(mgr.status(c).unwrap().state, JobState::Canceled);
        assert_eq!(mgr.cancel(a), CancelOutcome::Running);
        assert_eq!(mgr.cancel(c), CancelOutcome::Finished);
        assert_eq!(mgr.cancel(999), CancelOutcome::Unknown);
        // canceling `c` freed a queue slot
        let d = mgr.submit(spec("sweep")).unwrap();

        // shutdown while `a` runs: the in-flight job completes, the
        // queued jobs are canceled, and new submissions are refused
        let unblock = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            release_tx.send(()).unwrap();
            let _ = release_tx.send(()); // tolerate one more started job
        });
        mgr.shutdown();
        unblock.join().unwrap();
        assert_eq!(mgr.status(a).unwrap().state, JobState::Done);
        assert!(mgr.status(b).unwrap().state.is_terminal());
        assert!(mgr.status(d).unwrap().state.is_terminal());
        assert_eq!(mgr.submit(spec("sweep")), Err(SubmitError::ShuttingDown));
        assert!(mgr.is_shutting_down());
        let qs = mgr.queue_stats();
        assert_eq!(qs.queued + qs.running, 0, "drained: {qs:?}");
    }
}
