//! The L3 coordinator: experiment orchestration over the analog-core
//! simulators.
//!
//! * `service` — the PJRT executor service (single-owner thread for the
//!   !Send XLA objects, bounded-queue backpressure) and the shard
//!   subprocess runner for distributed sweeps (spawn/stream/join of
//!   `imclim sweep --shard i/k` children).
//! * `scheduler` — sweep scheduling: lock-free atomic work claiming ->
//!   worker pool with per-worker result buffers -> trial batching ->
//!   order-independent statistical aggregation.
//! * `jobs` — the `imclim serve` job manager: bounded submission queue
//!   with backpressure, job lifecycle, cancellation, graceful drain.
//! * `metrics` — process-wide execution counters (cache hits/misses,
//!   trials completed) feeding the daemon's `/stats` endpoint.
//!
//! Cached execution (grid building, content-addressed result reuse)
//! lives one layer up in `crate::engine`, which drives this scheduler.
//!
//! Python never appears here: the executor consumes AOT-compiled HLO
//! artifacts; the native Monte-Carlo backend needs nothing at all.

pub mod jobs;
pub mod metrics;
pub mod remote;
pub mod scheduler;
pub mod service;

pub use jobs::{
    CancelOutcome, JobManager, JobRunner, JobSpec, JobState, JobStatus, QueueStats, SubmitError,
};
pub use metrics::MetricsSnapshot;
pub use scheduler::{run_point, run_sweep, Backend, SweepOptions, SweepPoint, SweepResult};
pub use service::{
    run_shard_procs, ArchRequest, MlpRequest, MlpWeights, PjrtHandle, PjrtService, ShardCommand,
};
