//! Process-wide execution counters — the observability surface behind
//! `imclim serve`'s `GET /stats`.
//!
//! The scheduler hands `SweepOptions` around by value (`Copy`), so
//! there is no place to thread a metrics handle through the worker
//! pool; global atomics are the honest fit. Counters are monotone
//! totals since process start: consumers report them as-is (the daemon)
//! or difference two [`snapshot`]s around a region of interest
//! (per-job accounting).

use std::sync::atomic::{AtomicU64, Ordering};

static CACHE_HITS: AtomicU64 = AtomicU64::new(0);
static CACHE_MISSES: AtomicU64 = AtomicU64::new(0);
static POINTS_COMPUTED: AtomicU64 = AtomicU64::new(0);
static TRIALS_COMPLETED: AtomicU64 = AtomicU64::new(0);
static MC_ERRORS: AtomicU64 = AtomicU64::new(0);

/// One consistent-enough view of the counters (reads are relaxed and
/// independent; totals are exact once the measured region is quiescent).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub points_computed: u64,
    pub trials_completed: u64,
    pub mc_errors: u64,
}

impl MetricsSnapshot {
    /// Counter deltas accumulated since an earlier snapshot.
    pub fn since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            cache_hits: self.cache_hits.wrapping_sub(earlier.cache_hits),
            cache_misses: self.cache_misses.wrapping_sub(earlier.cache_misses),
            points_computed: self.points_computed.wrapping_sub(earlier.points_computed),
            trials_completed: self.trials_completed.wrapping_sub(earlier.trials_completed),
            mc_errors: self.mc_errors.wrapping_sub(earlier.mc_errors),
        }
    }
}

pub fn snapshot() -> MetricsSnapshot {
    MetricsSnapshot {
        cache_hits: CACHE_HITS.load(Ordering::Relaxed),
        cache_misses: CACHE_MISSES.load(Ordering::Relaxed),
        points_computed: POINTS_COMPUTED.load(Ordering::Relaxed),
        trials_completed: TRIALS_COMPLETED.load(Ordering::Relaxed),
        mc_errors: MC_ERRORS.load(Ordering::Relaxed),
    }
}

pub fn add_cache_hits(n: u64) {
    CACHE_HITS.fetch_add(n, Ordering::Relaxed);
}

pub fn add_cache_misses(n: u64) {
    CACHE_MISSES.fetch_add(n, Ordering::Relaxed);
}

pub fn add_points_computed(n: u64) {
    POINTS_COMPUTED.fetch_add(n, Ordering::Relaxed);
}

pub fn add_trials_completed(n: u64) {
    TRIALS_COMPLETED.fetch_add(n, Ordering::Relaxed);
}

pub fn add_mc_errors(n: u64) {
    MC_ERRORS.fetch_add(n, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshots_difference_cleanly() {
        // counters are process-global, so assert on deltas only — other
        // tests may be incrementing concurrently
        let before = snapshot();
        add_cache_hits(3);
        add_trials_completed(512);
        add_mc_errors(1);
        let delta = snapshot().since(&before);
        assert!(delta.cache_hits >= 3);
        assert!(delta.trials_completed >= 512);
        assert!(delta.mc_errors >= 1);
    }
}
