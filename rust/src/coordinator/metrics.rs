//! Process-wide execution counters — the observability surface behind
//! `imclim serve`'s `GET /stats`.
//!
//! The scheduler hands `SweepOptions` around by value (`Copy`), so
//! there is no place to thread a metrics handle through the worker
//! pool; global atomics are the honest fit. Since PR 9 the atomics
//! themselves live in [`crate::obs::registry`] (where they are also
//! exported as Prometheus text at `GET /metrics`); this module remains
//! the snapshot/delta facade the engine and serve executor use.
//! Counters are monotone totals since process start: consumers report
//! them as-is (the daemon) or difference two [`snapshot`]s around a
//! region of interest (per-job accounting).

use crate::obs::registry::{self, HistogramSnapshot};

/// One consistent-enough view of the counters (reads are relaxed and
/// independent; totals are exact once the measured region is quiescent).
///
/// The first five fields are the PR 8 counters and keep the JSON shape
/// of `GET /stats` unchanged; the remaining families (adaptive rounds,
/// cache-probe and MC-chunk latency histograms) are additive.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub points_computed: u64,
    pub trials_completed: u64,
    pub mc_errors: u64,
    pub adaptive_rounds: u64,
    pub cache_probe: HistogramSnapshot,
    pub mc_chunk: HistogramSnapshot,
}

impl MetricsSnapshot {
    /// Counter deltas accumulated since an earlier snapshot.
    pub fn since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            cache_hits: self.cache_hits.wrapping_sub(earlier.cache_hits),
            cache_misses: self.cache_misses.wrapping_sub(earlier.cache_misses),
            points_computed: self.points_computed.wrapping_sub(earlier.points_computed),
            trials_completed: self.trials_completed.wrapping_sub(earlier.trials_completed),
            mc_errors: self.mc_errors.wrapping_sub(earlier.mc_errors),
            adaptive_rounds: self.adaptive_rounds.wrapping_sub(earlier.adaptive_rounds),
            cache_probe: self.cache_probe.since(&earlier.cache_probe),
            mc_chunk: self.mc_chunk.since(&earlier.mc_chunk),
        }
    }
}

pub fn snapshot() -> MetricsSnapshot {
    MetricsSnapshot {
        cache_hits: registry::CACHE_HITS.get(),
        cache_misses: registry::CACHE_MISSES.get(),
        points_computed: registry::POINTS_COMPUTED.get(),
        trials_completed: registry::TRIALS_COMPLETED.get(),
        mc_errors: registry::MC_ERRORS.get(),
        adaptive_rounds: registry::ADAPTIVE_ROUNDS.get(),
        cache_probe: registry::CACHE_PROBE_SECONDS.snapshot(),
        mc_chunk: registry::MC_CHUNK_SECONDS.snapshot(),
    }
}

pub fn add_cache_hits(n: u64) {
    registry::CACHE_HITS.add(n);
}

pub fn add_cache_misses(n: u64) {
    registry::CACHE_MISSES.add(n);
}

pub fn add_points_computed(n: u64) {
    registry::POINTS_COMPUTED.add(n);
}

pub fn add_trials_completed(n: u64) {
    registry::TRIALS_COMPLETED.add(n);
}

pub fn add_mc_errors(n: u64) {
    registry::MC_ERRORS.add(n);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshots_difference_cleanly() {
        // counters are process-global, so assert on deltas only — other
        // tests may be incrementing concurrently
        let before = snapshot();
        add_cache_hits(3);
        add_trials_completed(512);
        add_mc_errors(1);
        let delta = snapshot().since(&before);
        assert!(delta.cache_hits >= 3);
        assert!(delta.trials_completed >= 512);
        assert!(delta.mc_errors >= 1);
    }

    #[test]
    fn histogram_families_flow_into_snapshots() {
        let before = snapshot();
        registry::CACHE_PROBE_SECONDS.observe(std::time::Duration::from_micros(80));
        registry::MC_CHUNK_SECONDS.observe(std::time::Duration::from_millis(2));
        registry::ADAPTIVE_ROUNDS.add(2);
        let delta = snapshot().since(&before);
        assert!(delta.cache_probe.count >= 1);
        assert!(delta.cache_probe.sum_us >= 80);
        assert!(delta.mc_chunk.count >= 1);
        assert!(delta.adaptive_rounds >= 2);
    }
}
