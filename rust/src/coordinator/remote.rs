//! Remote execution fabric for served jobs.
//!
//! `imclim serve` stays the single coordinator: it owns the job queue,
//! the shared result cache, and the canonical CSV. What this module
//! adds is the ability to fan one job's grid out across `imclim
//! worker` processes on other hosts:
//!
//! - Workers **register** over the daemon's HTTP port and then poll
//!   for **leases**. A lease names one deterministic `--shard i/k`
//!   slice of the running job's grid (`SweepSpec::shard` — same point
//!   ids and cache keys as a local run) plus the URL path of a
//!   per-shard artifact store on the coordinator.
//! - A worker executes its slice against a local scratch cache, then
//!   publishes the records back through the cache-artifact contract:
//!   `registry::pack` → `registry::push` against the coordinator's
//!   `/fabric/...` store. The artifact is content-addressed and
//!   re-verified by the coordinator before a single record lands in
//!   the shared cache.
//! - The coordinator's executor thread is the **only writer** of the
//!   shared cache: it pulls each uploaded artifact (verify → unpack →
//!   [`merge_cache_dirs`]) sequentially, then runs the canonical warm
//!   full-grid pass that emits `sweep.csv` — byte-identical to a
//!   single-process run because every record is content-addressed by
//!   the same keys.
//!
//! Robustness: every lease doubles as a heartbeat, and a dedicated
//! heartbeat runs while a worker is busy. A worker silent for longer
//! than the lease timeout is reaped and its shards re-queued
//! (`shard_requeued` in the job's event stream). A shard that keeps
//! failing — or that nobody is left to run — is executed locally by
//! the coordinator, so a fleet dying mid-job degrades to the old
//! single-process behaviour instead of wedging the queue.
//!
//! Lease bookkeeping lives in coordinator memory and is valid for
//! exactly one coordinator: this is the compute-side twin of the
//! registry's single-pusher rule (see `registry::store::push`). Run
//! one `imclim serve` per shared cache; point any number of workers
//! at it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::jobs::JobSpec;
use crate::obs::progress;
use crate::obs::registry as obs_registry;
use crate::registry::http::HttpEndpoint;
use crate::registry::{pack, pull, push, FileStore, HttpStore};
use crate::util::json::{arr, num, obj, s, Json};

/// How long a worker may go silent before the coordinator declares it
/// dead and re-queues its leased shards.
pub const DEFAULT_LEASE_TIMEOUT: Duration = Duration::from_secs(30);
/// URL prefix of the coordinator's per-shard artifact stores.
pub const FABRIC_PREFIX: &str = "/fabric";
/// A shard is handed to workers at most this many times; after that the
/// coordinator runs it locally, so a deterministic grid error surfaces
/// with its real message instead of bouncing between workers forever.
const MAX_WORKER_ATTEMPTS: u32 = 3;
/// Executor poll interval while waiting on remote shards.
const WAIT_POLL: Duration = Duration::from_millis(100);
/// Consecutive lease/transport failures after which a worker assumes
/// the coordinator is gone and exits cleanly.
const MAX_CONNECT_FAILURES: u32 = 5;

// ---------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------

struct WorkerInfo {
    name: String,
    last_seen: Instant,
}

#[derive(Clone, Debug, PartialEq)]
enum SlotState {
    Pending,
    /// Leased to a worker id (0 = the coordinator's local fallback).
    Leased { worker: u64 },
    /// Worker finished and pushed an artifact (`None` for an empty
    /// shard); the executor still has to pull/verify/merge it.
    Uploaded { artifact: Option<String> },
    Done,
}

struct Slot {
    state: SlotState,
    /// Times this shard has been leased to a worker.
    attempts: u32,
    /// Most recent worker-reported execution error, kept for the job's
    /// failure message.
    last_error: Option<String>,
}

impl Slot {
    fn new() -> Self {
        Slot {
            state: SlotState::Pending,
            attempts: 0,
            last_error: None,
        }
    }
}

struct DistJob {
    id: u64,
    spec: JobSpec,
    slots: Vec<Slot>,
}

struct FabricState {
    next_worker: u64,
    workers: BTreeMap<u64, WorkerInfo>,
    /// Shards of the currently distributed job. The serve executor is
    /// sequential, so at most one job ever has shards outstanding.
    job: Option<DistJob>,
}

/// Coordinator-side lease bookkeeping: registered workers, the running
/// job's shard slots, and the filesystem root of the per-shard artifact
/// stores served under [`FABRIC_PREFIX`].
pub struct Fabric {
    state: Mutex<FabricState>,
    cv: Condvar,
    store_root: PathBuf,
    lease_timeout: Duration,
}

/// One shard lease as handed to a worker.
#[derive(Clone, Debug)]
pub struct ShardLease {
    pub job_id: u64,
    pub index: usize,
    pub total: usize,
    pub spec: JobSpec,
    /// URL path (on the coordinator) of this shard's artifact store.
    pub store_path: String,
}

/// Outcome of a lease request.
pub enum LeaseReply {
    /// The worker id is unknown (reaped or never registered) — 404,
    /// the worker should re-register.
    UnknownWorker,
    /// Nothing to do right now — 204.
    NoWork,
    Lease(ShardLease),
}

/// Outcome of a completion report.
#[derive(Debug, PartialEq, Eq)]
pub enum CompleteReply {
    Accepted,
    UnknownWorker,
    /// The shard is no longer leased to this worker (it was reaped and
    /// the shard re-queued) — the upload is ignored, which is harmless:
    /// artifacts are content-addressed and re-verified on pull.
    NotLeased,
}

/// A registered worker, as reported by `GET /workers`.
#[derive(Clone, Debug)]
pub struct WorkerRow {
    pub id: u64,
    pub name: String,
    /// Shards of the running job currently leased to this worker.
    pub leased: usize,
    /// Milliseconds since the last heartbeat/lease/completion.
    pub idle_ms: u64,
}

/// What [`Fabric::run_distributed`] did.
#[derive(Clone, Debug, Default)]
pub struct DistReport {
    /// Shards the job was split into (0 = no workers, caller ran the
    /// whole grid locally).
    pub shards: usize,
    /// Shards merged from worker artifacts.
    pub merged: usize,
    /// Shards executed locally by the coordinator (fallback path).
    pub local: usize,
    /// Records newly copied into the shared cache from worker uploads.
    pub records: usize,
}

fn shard_store_path(job_id: u64, index: usize) -> String {
    format!("{FABRIC_PREFIX}/jobs/{job_id}/shards/{index}")
}

impl Fabric {
    pub fn new(store_root: PathBuf, lease_timeout: Duration) -> Self {
        Fabric {
            state: Mutex::new(FabricState {
                next_worker: 0,
                workers: BTreeMap::new(),
                job: None,
            }),
            cv: Condvar::new(),
            store_root,
            lease_timeout,
        }
    }

    pub fn lease_timeout(&self) -> Duration {
        self.lease_timeout
    }

    pub fn store_root(&self) -> &Path {
        &self.store_root
    }

    /// Register a worker, returning its id. Names are display-only;
    /// ids are what leases are bound to.
    pub fn register(&self, name: &str) -> u64 {
        let mut st = self.state.lock().unwrap();
        st.next_worker += 1;
        let id = st.next_worker;
        st.workers.insert(
            id,
            WorkerInfo {
                name: name.to_string(),
                last_seen: Instant::now(),
            },
        );
        obs_registry::WORKERS_REGISTERED.set(st.workers.len() as u64);
        self.cv.notify_all();
        id
    }

    /// Refresh a worker's liveness. Returns false for unknown ids.
    pub fn heartbeat(&self, id: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        match st.workers.get_mut(&id) {
            Some(w) => {
                w.last_seen = Instant::now();
                true
            }
            None => false,
        }
    }

    /// Workers that have been heard from within the lease timeout.
    /// Reaps the rest (re-queueing their shards) as a side effect.
    pub fn live_workers(&self) -> usize {
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        st.workers.len()
    }

    /// Snapshot of registered workers for `GET /workers`.
    pub fn workers(&self) -> Vec<WorkerRow> {
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        let now = Instant::now();
        st.workers
            .iter()
            .map(|(&id, w)| WorkerRow {
                id,
                name: w.name.clone(),
                leased: st
                    .job
                    .as_ref()
                    .map(|j| {
                        j.slots
                            .iter()
                            .filter(|s| s.state == SlotState::Leased { worker: id })
                            .count()
                    })
                    .unwrap_or(0),
                idle_ms: now.duration_since(w.last_seen).as_millis() as u64,
            })
            .collect()
    }

    /// Shard counts of the running distribution for `/stats`:
    /// (pending, active = leased or awaiting merge, done).
    pub fn shard_counts(&self) -> (usize, usize, usize) {
        let st = self.state.lock().unwrap();
        let Some(job) = st.job.as_ref() else {
            return (0, 0, 0);
        };
        let mut counts = (0, 0, 0);
        for slot in &job.slots {
            match slot.state {
                SlotState::Pending => counts.0 += 1,
                SlotState::Leased { .. } | SlotState::Uploaded { .. } => counts.1 += 1,
                SlotState::Done => counts.2 += 1,
            }
        }
        counts
    }

    /// Hand out the next pending shard of the running job, refreshing
    /// the worker's liveness either way.
    pub fn lease(&self, worker: u64) -> LeaseReply {
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        let Some(w) = st.workers.get_mut(&worker) else {
            return LeaseReply::UnknownWorker;
        };
        w.last_seen = Instant::now();
        let name = w.name.clone();
        let Some(job) = st.job.as_mut() else {
            return LeaseReply::NoWork;
        };
        let total = job.slots.len();
        let Some(i) = job.slots.iter().position(|slot| {
            slot.state == SlotState::Pending && slot.attempts < MAX_WORKER_ATTEMPTS
        }) else {
            return LeaseReply::NoWork;
        };
        job.slots[i].state = SlotState::Leased { worker };
        job.slots[i].attempts += 1;
        obs_registry::SHARD_LEASES.add(1);
        progress::shard("shard_leased", &name, i as u64, total as u64);
        LeaseReply::Lease(ShardLease {
            job_id: job.id,
            index: i,
            total,
            spec: job.spec.clone(),
            store_path: shard_store_path(job.id, i),
        })
    }

    /// Record a worker's completion report for a shard it holds.
    /// `outcome` is `Ok(artifact_id)` (`None` for an empty shard) or
    /// the worker's execution error.
    pub fn complete(
        &self,
        worker: u64,
        job_id: u64,
        index: usize,
        outcome: Result<Option<String>, String>,
    ) -> CompleteReply {
        let mut st = self.state.lock().unwrap();
        let Some(w) = st.workers.get_mut(&worker) else {
            return CompleteReply::UnknownWorker;
        };
        w.last_seen = Instant::now();
        let name = w.name.clone();
        let Some(job) = st.job.as_mut() else {
            return CompleteReply::NotLeased;
        };
        if job.id != job_id || index >= job.slots.len() {
            return CompleteReply::NotLeased;
        }
        let total = job.slots.len();
        let slot = &mut job.slots[index];
        if slot.state != (SlotState::Leased { worker }) {
            return CompleteReply::NotLeased;
        }
        match outcome {
            Ok(artifact) => {
                slot.state = SlotState::Uploaded { artifact };
                obs_registry::SHARD_COMPLETIONS.add(1);
                progress::shard("shard_completed", &name, index as u64, total as u64);
            }
            Err(msg) => {
                slot.state = SlotState::Pending;
                slot.last_error = Some(msg);
                obs_registry::SHARD_REQUEUES.add(1);
                progress::shard("shard_requeued", &name, index as u64, total as u64);
            }
        }
        self.cv.notify_all();
        CompleteReply::Accepted
    }

    /// Drop workers whose last sign of life is older than the lease
    /// timeout, re-queueing any shards they were holding.
    fn reap_locked(&self, st: &mut FabricState) {
        let now = Instant::now();
        let dead: Vec<u64> = st
            .workers
            .iter()
            .filter(|(_, w)| now.duration_since(w.last_seen) > self.lease_timeout)
            .map(|(&id, _)| id)
            .collect();
        if dead.is_empty() {
            return;
        }
        for id in dead {
            let info = st.workers.remove(&id).expect("dead id was present");
            if let Some(job) = st.job.as_mut() {
                let total = job.slots.len();
                for (i, slot) in job.slots.iter_mut().enumerate() {
                    if slot.state == (SlotState::Leased { worker: id }) {
                        slot.state = SlotState::Pending;
                        obs_registry::SHARD_REQUEUES.add(1);
                        progress::shard("shard_requeued", &info.name, i as u64, total as u64);
                    }
                }
            }
        }
        obs_registry::WORKERS_REGISTERED.set(st.workers.len() as u64);
        self.cv.notify_all();
    }

    /// Distribute a job's grid across the registered workers and merge
    /// their shard artifacts into `cache_dst`, returning once every
    /// shard is in. With no live workers this is a no-op (`shards: 0`)
    /// and the caller runs the grid locally as before. `local_exec`
    /// runs one `(index, total)` shard in-process — the fallback for
    /// shards whose workers died or that exhausted their attempts.
    ///
    /// Called only from the serve executor thread, which is the single
    /// writer of `cache_dst`.
    pub fn run_distributed(
        &self,
        job_id: u64,
        spec: &JobSpec,
        cache_dst: &Path,
        local_exec: &dyn Fn(usize, usize) -> Result<()>,
    ) -> Result<DistReport> {
        let total = {
            let mut st = self.state.lock().unwrap();
            self.reap_locked(&mut st);
            let k = st.workers.len();
            if k == 0 {
                return Ok(DistReport::default());
            }
            st.job = Some(DistJob {
                id: job_id,
                spec: spec.clone(),
                slots: (0..k).map(|_| Slot::new()).collect(),
            });
            self.cv.notify_all();
            k
        };
        let result = self.drive(job_id, total, cache_dst, local_exec);
        // Always clear the slots so a failed job can't leak leases
        // into the next one.
        self.state.lock().unwrap().job = None;
        result
    }

    fn drive(
        &self,
        job_id: u64,
        total: usize,
        cache_dst: &Path,
        local_exec: &dyn Fn(usize, usize) -> Result<()>,
    ) -> Result<DistReport> {
        enum Next {
            Wait,
            Merge(usize, Option<String>),
            Local(usize),
            Finished,
        }
        let mut report = DistReport {
            shards: total,
            ..DistReport::default()
        };
        loop {
            let next = {
                let mut guard = self.state.lock().unwrap();
                self.reap_locked(&mut guard);
                // Reborrow through the guard once, so `job` and
                // `st.workers` below are disjoint field borrows.
                let st = &mut *guard;
                let job = st.job.as_mut().expect("distributed job present");
                let uploaded = job
                    .slots
                    .iter()
                    .position(|s| matches!(s.state, SlotState::Uploaded { .. }));
                if let Some(i) = uploaded {
                    // Claim the upload by marking Done now; a failed
                    // merge reverts to Pending below. Only this thread
                    // merges, so the intermediate state is never seen
                    // as "finished" (the all-Done check runs here too).
                    let prev = std::mem::replace(&mut job.slots[i].state, SlotState::Done);
                    let SlotState::Uploaded { artifact } = prev else {
                        unreachable!("position() matched Uploaded");
                    };
                    Next::Merge(i, artifact)
                } else if let Some(i) = job.slots.iter().position(|s| {
                    s.state == SlotState::Pending
                        && (s.attempts >= MAX_WORKER_ATTEMPTS || st.workers.is_empty())
                }) {
                    // Nobody left to run it, or workers keep failing
                    // it: the coordinator takes the shard itself.
                    job.slots[i].state = SlotState::Leased { worker: 0 };
                    job.slots[i].attempts += 1;
                    Next::Local(i)
                } else if job.slots.iter().all(|s| s.state == SlotState::Done) {
                    Next::Finished
                } else {
                    Next::Wait
                }
            };
            match next {
                Next::Wait => {
                    let st = self.state.lock().unwrap();
                    let _unused = self.cv.wait_timeout(st, WAIT_POLL).unwrap();
                }
                Next::Merge(i, artifact) => match self.merge_shard(job_id, i, artifact.as_deref(), cache_dst) {
                    Ok(added) => {
                        report.merged += 1;
                        report.records += added;
                    }
                    Err(e) => {
                        // Corrupt or vanished upload: put the shard
                        // back; a worker (or the local fallback) will
                        // redo it.
                        let mut st = self.state.lock().unwrap();
                        if let Some(job) = st.job.as_mut() {
                            job.slots[i].state = SlotState::Pending;
                            job.slots[i].last_error = Some(format!("{e:#}"));
                        }
                        obs_registry::SHARD_REQUEUES.add(1);
                        progress::shard("shard_requeued", "artifact-verify", i as u64, total as u64);
                    }
                },
                Next::Local(i) => {
                    progress::shard("shard_leased", "coordinator", i as u64, total as u64);
                    local_exec(i, total).with_context(|| {
                        let detail = {
                            let st = self.state.lock().unwrap();
                            st.job
                                .as_ref()
                                .and_then(|j| j.slots[i].last_error.clone())
                                .map(|e| format!(" (last worker error: {e})"))
                                .unwrap_or_default()
                        };
                        format!("local fallback for shard {i}/{total} failed{detail}")
                    })?;
                    let mut st = self.state.lock().unwrap();
                    if let Some(job) = st.job.as_mut() {
                        job.slots[i].state = SlotState::Done;
                    }
                    report.local += 1;
                    obs_registry::SHARD_COMPLETIONS.add(1);
                    progress::shard("shard_completed", "coordinator", i as u64, total as u64);
                }
                Next::Finished => return Ok(report),
            }
        }
    }

    /// Pull one uploaded shard artifact (verify → unpack → merge) into
    /// the shared cache. `None` means the shard produced no records
    /// (possible when the grid is smaller than the worker count).
    fn merge_shard(
        &self,
        job_id: u64,
        index: usize,
        artifact: Option<&str>,
        cache_dst: &Path,
    ) -> Result<usize> {
        let Some(id) = artifact else {
            return Ok(0);
        };
        let store = FileStore::new(
            self.store_root
                .join(format!("jobs/{job_id}/shards/{index}")),
        );
        let rep = pull(&store, cache_dst, Some(id))
            .with_context(|| format!("merging shard {index} artifact {id}"))?;
        Ok(rep.copied)
    }
}

/// Map a `/fabric/...` URL path component-by-component onto the store
/// root, refusing traversal (`..`), hidden components, and anything
/// outside `[A-Za-z0-9._-]`.
pub fn sanitize_store_rel(root: &Path, rel: &str) -> Option<PathBuf> {
    if rel.is_empty() {
        return None;
    }
    let mut path = root.to_path_buf();
    for comp in rel.split('/') {
        let ok = !comp.is_empty()
            && !comp.starts_with('.')
            && comp
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'));
        if !ok {
            return None;
        }
        path.push(comp);
    }
    Some(path)
}

// ---------------------------------------------------------------------
// Wire format — hand-rolled JSON, same as the rest of the daemon
// ---------------------------------------------------------------------

/// Encode a job spec for a lease body.
pub fn spec_json(spec: &JobSpec) -> Json {
    obj(vec![
        ("cmd", s(&spec.verb)),
        (
            "options",
            Json::Obj(
                spec.options
                    .iter()
                    .map(|(k, v)| (k.clone(), s(v)))
                    .collect(),
            ),
        ),
        (
            "switches",
            arr(spec.switches.iter().map(|w| s(w)).collect()),
        ),
    ])
}

fn decode_spec(j: &Json) -> Result<JobSpec> {
    let verb = j
        .get("cmd")
        .and_then(Json::as_str)
        .context("lease spec has no cmd")?
        .to_string();
    let mut options = BTreeMap::new();
    if let Some(o) = j.get("options").and_then(Json::as_obj) {
        for (k, v) in o {
            options.insert(
                k.clone(),
                v.as_str().context("non-string option value")?.to_string(),
            );
        }
    }
    let mut switches = Vec::new();
    if let Some(a) = j.get("switches").and_then(Json::as_arr) {
        for w in a {
            switches.push(w.as_str().context("non-string switch")?.to_string());
        }
    }
    Ok(JobSpec {
        verb,
        options,
        switches,
    })
}

/// Encode a lease for the `POST /workers/lease` 200 body.
pub fn lease_json(l: &ShardLease) -> Json {
    obj(vec![
        ("job_id", num(l.job_id as f64)),
        ("shard", num(l.index as f64)),
        ("total", num(l.total as f64)),
        ("store", s(&l.store_path)),
        ("spec", spec_json(&l.spec)),
    ])
}

fn decode_lease(j: &Json) -> Result<ShardLease> {
    let field = |k: &str| {
        j.get(k)
            .and_then(Json::as_usize)
            .with_context(|| format!("lease has no numeric '{k}'"))
    };
    let spec = decode_spec(j.get("spec").context("lease has no spec")?)?;
    let lease = ShardLease {
        job_id: field("job_id")? as u64,
        index: field("shard")?,
        total: field("total")?,
        spec,
        store_path: j
            .get("store")
            .and_then(Json::as_str)
            .context("lease has no store path")?
            .to_string(),
    };
    ensure!(
        lease.total > 0 && lease.index < lease.total,
        "lease shard {}/{} out of range",
        lease.index,
        lease.total
    );
    Ok(lease)
}

// ---------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------

/// Everything a worker process needs to talk to its coordinator.
pub struct WorkerConfig {
    pub coordinator: HttpEndpoint,
    /// Display name reported on registration (host:pid by default).
    pub name: String,
    /// Scratch directory: per-shard out-dirs, artifact staging, and a
    /// persistent local cache that stays warm across leases.
    pub scratch: PathBuf,
    /// Idle delay between lease polls when there is no work.
    pub poll: Duration,
    /// Interval of the keep-alive heartbeat while executing a shard.
    pub heartbeat: Duration,
    /// Testing/chaos knob: dwell this long between taking a lease and
    /// executing it (heartbeats continue), so tests and CI can observe
    /// — or kill — a worker that provably holds a lease.
    pub hold: Duration,
}

/// Executes one leased shard: `(lease, out_dir, cache_dir)`.
pub type ShardExec = dyn Fn(&ShardLease, &Path, &Path) -> Result<()> + Sync;

/// Run the worker loop until the coordinator drains, the stop flag
/// (SIGINT/SIGTERM) is raised, or the coordinator becomes unreachable.
/// All three are clean exits: workers are disposable by design — the
/// coordinator re-queues anything they were holding.
pub fn run_worker(
    cfg: &WorkerConfig,
    exec: &ShardExec,
    stop: &(dyn Fn() -> bool + Sync),
) -> Result<()> {
    std::fs::create_dir_all(&cfg.scratch)
        .with_context(|| format!("creating scratch dir {}", cfg.scratch.display()))?;
    let mut worker_id = register_with_retry(cfg, stop)?;
    println!(
        "imclim worker: registered as '{}' (id {worker_id}) with {}",
        cfg.name,
        cfg.coordinator.url_for("")
    );
    let mut failures = 0u32;
    loop {
        if stop() {
            println!("imclim worker: stop requested, exiting");
            return Ok(());
        }
        let body = obj(vec![("worker_id", num(worker_id as f64))]).to_string();
        match cfg
            .coordinator
            .post("workers/lease", body.as_bytes(), "application/json")
        {
            Ok((200, reply)) => {
                failures = 0;
                let text = String::from_utf8(reply).context("non-UTF-8 lease body")?;
                let json = Json::parse(&text).map_err(|e| anyhow!("parsing lease: {e}"))?;
                let lease = decode_lease(&json)?;
                println!(
                    "imclim worker: leased shard {}/{} of job {}",
                    lease.index, lease.total, lease.job_id
                );
                execute_lease(cfg, worker_id, &lease, exec)?;
            }
            Ok((204, _)) => {
                failures = 0;
                std::thread::sleep(cfg.poll);
            }
            Ok((404, _)) => {
                // Reaped (e.g. after a long coordinator pause):
                // re-register and carry on.
                worker_id = register_with_retry(cfg, stop)?;
                println!("imclim worker: lease expired, re-registered as id {worker_id}");
            }
            Ok((503, _)) => {
                println!("imclim worker: coordinator draining, exiting");
                return Ok(());
            }
            Ok((code, _)) => bail!("unexpected HTTP {code} from lease request"),
            Err(_) => {
                failures += 1;
                if failures >= MAX_CONNECT_FAILURES {
                    println!("imclim worker: coordinator unreachable, exiting");
                    return Ok(());
                }
                std::thread::sleep(cfg.poll);
            }
        }
    }
}

fn register_with_retry(cfg: &WorkerConfig, stop: &(dyn Fn() -> bool + Sync)) -> Result<u64> {
    let body = obj(vec![("name", s(&cfg.name))]).to_string();
    let mut last = String::new();
    for _ in 0..MAX_CONNECT_FAILURES {
        if stop() {
            bail!("stop requested during registration");
        }
        match cfg
            .coordinator
            .post("workers/register", body.as_bytes(), "application/json")
        {
            Ok((200, reply)) => {
                let text = String::from_utf8_lossy(&reply).into_owned();
                let id = Json::parse(&text)
                    .ok()
                    .and_then(|j| j.get("worker_id").and_then(Json::as_usize))
                    .with_context(|| format!("registration reply unparseable: {text}"))?;
                return Ok(id as u64);
            }
            Ok((503, _)) => bail!("coordinator is draining, not accepting workers"),
            Ok((code, _)) => last = format!("HTTP {code}"),
            Err(e) => last = format!("{e:#}"),
        }
        std::thread::sleep(cfg.poll);
    }
    bail!("registering with {}: {last}", cfg.coordinator.url_for(""))
}

/// Execute one lease end to end: dwell (if configured), run the shard,
/// pack + push the scratch cache, and report completion. Execution and
/// publish errors are reported to the coordinator (which re-queues the
/// shard); only transport-level failures bubble out.
fn execute_lease(
    cfg: &WorkerConfig,
    worker_id: u64,
    lease: &ShardLease,
    exec: &ShardExec,
) -> Result<()> {
    let shard_dir = cfg
        .scratch
        .join(format!("job-{}-shard-{}", lease.job_id, lease.index));
    let _ = std::fs::remove_dir_all(&shard_dir);
    let cache_dir = cfg.scratch.join("cache");

    // Keep-alive while we work, so shards longer than the lease
    // timeout don't get re-queued under us.
    let stop_hb = Arc::new(AtomicBool::new(false));
    let hb = {
        let stop_hb = Arc::clone(&stop_hb);
        let endpoint = cfg.coordinator.clone();
        let interval = cfg.heartbeat;
        let body = obj(vec![("worker_id", num(worker_id as f64))]).to_string();
        std::thread::spawn(move || {
            while !stop_hb.load(Ordering::SeqCst) {
                let _ = endpoint.post("workers/heartbeat", body.as_bytes(), "application/json");
                let mut slept = Duration::ZERO;
                while slept < interval && !stop_hb.load(Ordering::SeqCst) {
                    let step = Duration::from_millis(50).min(interval - slept);
                    std::thread::sleep(step);
                    slept += step;
                }
            }
        })
    };
    if !cfg.hold.is_zero() {
        std::thread::sleep(cfg.hold);
    }
    let outcome: Result<Option<String>, String> = match exec(lease, &shard_dir, &cache_dir) {
        Err(e) => Err(format!("{e:#}")),
        Ok(()) => publish_shard(cfg, lease, &cache_dir)
            .map_err(|e| format!("publishing shard artifact: {e:#}")),
    };
    stop_hb.store(true, Ordering::SeqCst);
    let _ = hb.join();
    let _ = std::fs::remove_dir_all(&shard_dir);

    let mut fields = vec![
        ("worker_id", num(worker_id as f64)),
        ("job_id", num(lease.job_id as f64)),
        ("shard", num(lease.index as f64)),
    ];
    match &outcome {
        Ok(Some(id)) => fields.push(("artifact", s(id))),
        Ok(None) => {}
        Err(msg) => fields.push(("error", s(msg))),
    }
    let body = obj(fields).to_string();
    let (code, _) = cfg
        .coordinator
        .post("workers/complete", body.as_bytes(), "application/json")
        .context("reporting shard completion")?;
    match &outcome {
        Ok(art) => println!(
            "imclim worker: shard {}/{} of job {} done ({})",
            lease.index,
            lease.total,
            lease.job_id,
            art.as_deref().unwrap_or("empty shard")
        ),
        Err(msg) => eprintln!(
            "imclim worker: shard {}/{} of job {} failed: {msg}",
            lease.index, lease.total, lease.job_id
        ),
    }
    if !(200..300).contains(&code) {
        // Reaped mid-shard and the shard re-leased elsewhere; the
        // upload is ignored (content-addressed, so no harm done).
        eprintln!("imclim worker: completion for shard {} not accepted (HTTP {code})", lease.index);
    }
    Ok(())
}

/// Pack the worker's whole scratch cache and push it to the lease's
/// store on the coordinator. Packing the full cache (not just this
/// shard's records) is deliberate: records are content-addressed, so
/// extras merge as no-ops at worst and warm the coordinator's shared
/// cache at best.
fn publish_shard(cfg: &WorkerConfig, lease: &ShardLease, cache_dir: &Path) -> Result<Option<String>> {
    if crate::engine::list_record_files(cache_dir)?.is_empty() {
        return Ok(None);
    }
    let art_dir = cfg
        .scratch
        .join(format!("artifact-{}-{}", lease.job_id, lease.index));
    let _ = std::fs::remove_dir_all(&art_dir);
    let rep = pack(
        cache_dir,
        &art_dir,
        &format!(
            "worker={} job={} shard={}/{}",
            cfg.name, lease.job_id, lease.index, lease.total
        ),
    )?;
    let store = HttpStore::new(cfg.coordinator.with_base(&lease.store_path));
    push(&art_dir, &store)?;
    let _ = std::fs::remove_dir_all(&art_dir);
    Ok(Some(rep.id))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec {
            verb: "sweep".into(),
            options: BTreeMap::from([("n".into(), "8,12".into())]),
            switches: vec!["no-cache".into()],
        }
    }

    #[test]
    fn lease_json_roundtrips() {
        let lease = ShardLease {
            job_id: 7,
            index: 1,
            total: 3,
            spec: spec(),
            store_path: shard_store_path(7, 1),
        };
        let text = lease_json(&lease).to_string();
        let back = decode_lease(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.job_id, 7);
        assert_eq!(back.index, 1);
        assert_eq!(back.total, 3);
        assert_eq!(back.store_path, "/fabric/jobs/7/shards/1");
        assert_eq!(back.spec.verb, "sweep");
        assert_eq!(back.spec.options["n"], "8,12");
        assert_eq!(back.spec.switches, vec!["no-cache".to_string()]);
        // out-of-range shards are rejected
        let bad = text.replace("\"shard\":1", "\"shard\":9");
        assert_ne!(bad, text, "compact JSON key not found");
        assert!(decode_lease(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn sanitizes_fabric_store_paths() {
        let root = Path::new("/srv/fabric");
        let ok = sanitize_store_rel(root, "jobs/3/shards/0/artifacts/ab12/payload.tar.gz");
        assert_eq!(
            ok.unwrap(),
            Path::new("/srv/fabric/jobs/3/shards/0/artifacts/ab12/payload.tar.gz")
        );
        for bad in [
            "",
            "jobs/../../../etc/passwd",
            "jobs//x",
            "jobs/./x",
            "jobs/.hidden",
            "jobs/a b",
            "jobs/x\\y",
            "/absolute",
        ] {
            assert!(sanitize_store_rel(root, bad).is_none(), "{bad:?} accepted");
        }
    }

    #[test]
    fn fabric_leases_requeues_and_reaps() {
        let fx = Fabric::new(PathBuf::from("/tmp/unused"), Duration::from_millis(60));
        assert_eq!(fx.live_workers(), 0);
        let w1 = fx.register("alpha");
        let w2 = fx.register("beta");
        assert_eq!(fx.live_workers(), 2);
        assert!(fx.heartbeat(w1));
        assert!(!fx.heartbeat(999));

        // No job yet: nothing to lease.
        assert!(matches!(fx.lease(w1), LeaseReply::NoWork));
        assert!(matches!(fx.lease(999), LeaseReply::UnknownWorker));

        // Seed a 2-shard job directly (run_distributed drives this in
        // production; here we poke the state machine).
        {
            let mut st = fx.state.lock().unwrap();
            st.job = Some(DistJob {
                id: 42,
                spec: spec(),
                slots: vec![Slot::new(), Slot::new()],
            });
        }
        let LeaseReply::Lease(l1) = fx.lease(w1) else {
            panic!("expected a lease");
        };
        assert_eq!((l1.job_id, l1.index, l1.total), (42, 0, 2));
        assert_eq!(l1.store_path, "/fabric/jobs/42/shards/0");

        // Completion with an error re-queues; with an artifact uploads.
        assert_eq!(
            fx.complete(w1, 42, 0, Err("boom".into())),
            CompleteReply::Accepted
        );
        assert_eq!(fx.shard_counts(), (2, 0, 0));
        let LeaseReply::Lease(l1b) = fx.lease(w1) else {
            panic!("expected shard 0 back");
        };
        assert_eq!(l1b.index, 0);
        assert_eq!(
            fx.complete(w1, 42, 0, Ok(Some("abc123".into()))),
            CompleteReply::Accepted
        );
        assert_eq!(fx.shard_counts(), (1, 1, 0));
        // Stale completion for a shard not leased to the sender.
        assert_eq!(
            fx.complete(w2, 42, 0, Ok(None)),
            CompleteReply::NotLeased
        );

        // w2 leases shard 1 then goes silent past the lease timeout:
        // reaped, shard re-queued, and its next call must re-register.
        let LeaseReply::Lease(l2) = fx.lease(w2) else {
            panic!("expected a lease");
        };
        assert_eq!(l2.index, 1);
        std::thread::sleep(Duration::from_millis(90));
        fx.heartbeat(w1); // alpha's clock resets before the reap runs
        assert_eq!(fx.live_workers(), 1); // beta is gone
        let rows = fx.workers();
        assert!(rows.iter().all(|r| r.name != "beta"));
        assert!(matches!(fx.lease(w2), LeaseReply::UnknownWorker));
        // shard 1 is pending again
        let (pending, _, _) = fx.shard_counts();
        assert!(pending >= 1);
    }

    #[test]
    fn attempt_exhausted_shards_stop_going_to_workers() {
        let fx = Fabric::new(PathBuf::from("/tmp/unused"), Duration::from_secs(60));
        let w = fx.register("flaky");
        {
            let mut st = fx.state.lock().unwrap();
            st.job = Some(DistJob {
                id: 1,
                spec: spec(),
                slots: vec![Slot::new()],
            });
        }
        for _ in 0..MAX_WORKER_ATTEMPTS {
            let LeaseReply::Lease(l) = fx.lease(w) else {
                panic!("expected a lease");
            };
            assert_eq!(
                fx.complete(w, 1, l.index, Err("always fails".into())),
                CompleteReply::Accepted
            );
        }
        // The shard is pending but reserved for the local fallback now.
        assert!(matches!(fx.lease(w), LeaseReply::NoWork));
        assert_eq!(fx.shard_counts(), (1, 0, 0));
    }
}
