//! Sweep scheduler: turns a list of operating points into Monte-Carlo
//! jobs, fans them out over a worker pool, batches trials into
//! fixed-shape executor invocations, and aggregates ensemble statistics.
//!
//! Scheduling is lock-free: workers claim jobs with a single atomic
//! fetch-add over the shared (immutable) point slice and collect their
//! results into per-worker buffers, which are merged back into input
//! order after the pool joins. There is no job-queue mutex and no shared
//! result-store mutex on the hot path.
//!
//! Invariants (enforced by tests in rust/tests/prop_coordinator.rs):
//!  * every submitted point produces exactly one result;
//!  * per-point trial counts are met or exceeded (batch round-up);
//!  * results are deterministic given (point id, seed), independent of
//!    worker count and completion order;
//!  * a failing point never stalls the pool (fail-fast per point).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::arch::pvec;
use crate::mc::{ArchKind, InputDist, McOutput, MeasuredSnr, SnrAccumulator};
use crate::util::rng::Pcg64;

use super::service::{ArchRequest, PjrtHandle};

/// One sweep point: an architecture operating point to characterize with
/// `trials` Monte-Carlo trials.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Caller-meaningful identifier (e.g. "fig9a/vwl=0.8/n=128").
    pub id: String,
    pub kind: ArchKind,
    pub params: [f64; pvec::P],
    pub trials: usize,
    pub seed: u64,
    pub dist: InputDist,
}

impl SweepPoint {
    pub fn new(id: impl Into<String>, kind: ArchKind, params: [f64; pvec::P]) -> Self {
        Self {
            id: id.into(),
            kind,
            params,
            trials: 1024,
            seed: 0xC0FFEE,
            dist: InputDist::Uniform,
        }
    }

    pub fn with_trials(mut self, trials: usize) -> Self {
        self.trials = trials;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

#[derive(Clone, Debug)]
pub struct SweepResult {
    pub id: String,
    pub index: usize,
    pub measured: MeasuredSnr,
    pub error: Option<String>,
    /// True when the result was served from the engine's result cache
    /// rather than computed by this run (see `crate::engine`).
    pub cached: bool,
}

/// Execution backend for the analog-core simulation.
#[derive(Clone)]
pub enum Backend {
    /// Native Rust Monte-Carlo (always available).
    Native,
    /// AOT JAX/Pallas artifacts through the PJRT executor service. The
    /// artifact name is derived from the point's `ArchKind`, with an
    /// optional suffix (e.g. "_small" for test artifacts).
    Pjrt {
        handle: PjrtHandle,
        suffix: &'static str,
    },
}

impl Backend {
    /// Stable identifier folded into the engine's content-addressed cache
    /// keys, so results from different execution backends never alias —
    /// including different *builds* of the same backend: the PJRT id
    /// carries the artifact-set fingerprint (manifest + HLO payload
    /// bytes), so a recompiled artifact set never serves records
    /// computed by its predecessor; the native id carries the crate
    /// version, which isolates *released* simulator generations — a
    /// physics change must bump the crate version (or the cache
    /// KEY_PREFIX) to invalidate old records, as Cargo.toml documents.
    pub fn cache_id(&self) -> String {
        match self {
            Backend::Native => format!("native@{}", env!("CARGO_PKG_VERSION")),
            Backend::Pjrt { handle, suffix } => {
                format!("pjrt{suffix}@{}", handle.artifact_fingerprint())
            }
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SweepOptions {
    pub workers: usize,
    /// Print progress lines.
    pub verbose: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16);
        Self {
            workers,
            verbose: false,
        }
    }
}

/// Run all points; the returned vector is ordered like the input.
///
/// Work distribution is an atomic-index claiming loop over the shared
/// point slice: each worker does `next.fetch_add(1)` to claim the next
/// unprocessed point and appends the result to its own buffer, so no
/// lock is taken anywhere on the execution path. Per-point seeding is
/// part of the point itself, so results are bit-identical regardless of
/// worker count or completion order.
pub fn run_sweep(
    points: Vec<SweepPoint>,
    backend: Backend,
    opts: SweepOptions,
) -> Vec<SweepResult> {
    let n_points = points.len();
    if n_points == 0 {
        return Vec::new();
    }
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let points_slice: &[SweepPoint] = &points;

    let workers = opts.workers.clamp(1, n_points);
    let buffers: Vec<Vec<SweepResult>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let backend = backend.clone();
                let next = &next;
                let done = &done;
                scope.spawn(move || {
                    let mut local: Vec<SweepResult> = Vec::new();
                    loop {
                        let index = next.fetch_add(1, Ordering::Relaxed);
                        if index >= n_points {
                            break;
                        }
                        let point = &points_slice[index];
                        let res = run_point(point, &backend);
                        let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                        if opts.verbose {
                            eprintln!(
                                "[{finished}/{n_points}] {} snr_t={:.2} dB",
                                point.id,
                                res.as_ref().map(|m| m.snr_t_db).unwrap_or(f64::NAN)
                            );
                        }
                        local.push(match res {
                            Ok(measured) => SweepResult {
                                id: point.id.clone(),
                                index,
                                measured,
                                error: None,
                                cached: false,
                            },
                            Err(e) => SweepResult {
                                id: point.id.clone(),
                                index,
                                measured: MeasuredSnr::default(),
                                error: Some(e.to_string()),
                                cached: false,
                            },
                        });
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });

    let mut slots: Vec<Option<SweepResult>> = vec![None; n_points];
    for buffer in buffers {
        for result in buffer {
            let index = result.index;
            debug_assert!(slots[index].is_none(), "point {index} claimed twice");
            slots[index] = Some(result);
        }
    }
    slots
        .into_iter()
        .map(|r| r.expect("every point produces a result"))
        .collect()
}

/// Execute one point to completion on the chosen backend.
pub fn run_point(point: &SweepPoint, backend: &Backend) -> anyhow::Result<MeasuredSnr> {
    match backend {
        Backend::Native => {
            let out = crate::mc::simulate(
                point.kind,
                &point.params,
                point.trials,
                point.seed,
                point.dist,
            );
            Ok(crate::mc::measure(&out))
        }
        Backend::Pjrt { handle, suffix } => {
            // Banked points are native-only: the AOT artifacts model a
            // single array and would silently ignore the bank slot.
            anyhow::ensure!(
                point.params[pvec::IDX_BANKS] < 2.0,
                "point {} is banked (banks={}): multi-bank simulation is \
                 native-only, rerun with --backend native",
                point.id,
                point.params[pvec::IDX_BANKS]
            );
            // QS correlated-mismatch mode is a separate (heavier) artifact
            let corr = point.kind == ArchKind::Qs
                && point.params[pvec::QS_IDX_MODE] >= 0.5;
            let artifact = if corr {
                format!("{}_corr{}", point.kind.artifact_name(), suffix)
            } else {
                format!("{}{}", point.kind.artifact_name(), suffix)
            };
            let (m, n_max) = handle.arch_shape(&artifact)?;
            let n = point.params[pvec::IDX_N_ACTIVE] as usize;
            anyhow::ensure!(
                n <= n_max,
                "point {} wants N={n} > artifact n_max={n_max}",
                point.id
            );
            let batches = point.trials.div_ceil(m);
            let mut acc = SnrAccumulator::new();
            let mut rng = Pcg64::new(point.seed);
            let mut x = vec![0f32; m * n_max];
            let mut w = vec![0f32; m * n_max];
            for b in 0..batches {
                fill_inputs(&mut x, &mut w, n, n_max, &point.dist, &mut rng);
                let seed = [(point.seed % 0x7fff_ffff) as f32, b as f32];
                let out: McOutput = handle.run_arch(ArchRequest {
                    artifact: artifact.clone(),
                    x: x.clone(),
                    w: w.clone(),
                    seed,
                    params: point.params,
                })?;
                acc.push_chunk(&out);
            }
            Ok(acc.finalize())
        }
    }
}

/// Fill the fixed-shape input buffers: active lanes get fresh draws,
/// inactive lanes are zeroed (the artifact masks them anyway).
fn fill_inputs(
    x: &mut [f32],
    w: &mut [f32],
    n: usize,
    n_max: usize,
    dist: &InputDist,
    rng: &mut Pcg64,
) {
    let m = x.len() / n_max;
    for t in 0..m {
        let row = t * n_max;
        for k in 0..n_max {
            if k < n {
                x[row + k] = draw_x(dist, rng) as f32;
                w[row + k] = draw_w(dist, rng) as f32;
            } else {
                x[row + k] = 0.0;
                w[row + k] = 0.0;
            }
        }
    }
}

fn draw_x(dist: &InputDist, rng: &mut Pcg64) -> f64 {
    match dist {
        InputDist::Uniform => rng.uniform(),
        InputDist::ClippedGaussian { sx, .. } => (rng.normal().abs() * sx).min(0.999_999),
    }
}

fn draw_w(dist: &InputDist, rng: &mut Pcg64) -> f64 {
    match dist {
        InputDist::Uniform => rng.uniform_in(-1.0, 1.0),
        InputDist::ClippedGaussian { sw, .. } => {
            (rng.normal() * sw).clamp(-0.999_999, 0.999_999)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pvec;

    fn qs_point(id: &str, n: usize, seed: u64) -> SweepPoint {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = n as f64;
        p[pvec::IDX_BX] = 6.0;
        p[pvec::IDX_BW] = 6.0;
        p[pvec::IDX_B_ADC] = 8.0;
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 60.0;
        p[pvec::QS_IDX_V_C] = 60.0;
        SweepPoint::new(id, ArchKind::Qs, p)
            .with_trials(256)
            .with_seed(seed)
    }

    #[test]
    fn native_sweep_returns_every_point_in_order() {
        let points: Vec<SweepPoint> =
            (0..10).map(|i| qs_point(&format!("p{i}"), 32 + i, i as u64)).collect();
        let res = run_sweep(points, Backend::Native, SweepOptions { workers: 4, verbose: false });
        assert_eq!(res.len(), 10);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.id, format!("p{i}"));
            assert!(r.error.is_none());
            assert!(!r.cached, "scheduler never serves cached results");
            assert_eq!(r.measured.trials, 256);
        }
    }

    #[test]
    fn more_workers_than_points_is_fine() {
        let points: Vec<SweepPoint> = (0..3).map(|i| qs_point(&format!("p{i}"), 16, 1)).collect();
        let res = run_sweep(
            points,
            Backend::Native,
            SweepOptions { workers: 16, verbose: false },
        );
        assert_eq!(res.len(), 3);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.index, i);
        }
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let mk = || (0..6).map(|i| qs_point(&format!("p{i}"), 64, 7)).collect::<Vec<_>>();
        let a = run_sweep(mk(), Backend::Native, SweepOptions { workers: 1, verbose: false });
        let b = run_sweep(mk(), Backend::Native, SweepOptions { workers: 8, verbose: false });
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.measured.snr_t_db, y.measured.snr_t_db);
        }
    }

    #[test]
    fn empty_sweep_is_fine() {
        let res = run_sweep(Vec::new(), Backend::Native, SweepOptions::default());
        assert!(res.is_empty());
    }

    #[test]
    fn cache_id_carries_backend_identity_and_version() {
        let id = Backend::Native.cache_id();
        assert_eq!(id, format!("native@{}", env!("CARGO_PKG_VERSION")));
        // the stubbed offline runtime has no manifest: its artifact
        // fingerprint degrades to the placeholder, still distinct from
        // the native id (and from any real artifact build's hash)
        let service = crate::coordinator::PjrtService::spawn(
            std::env::temp_dir().join("imclim-no-artifacts-here"),
            1,
        );
        let pjrt = Backend::Pjrt {
            handle: service.handle(),
            suffix: "_small",
        };
        assert_eq!(pjrt.cache_id(), "pjrt_small@unmanifested");
        assert_ne!(pjrt.cache_id(), id);
    }
}
