//! Sweep scheduler: turns a list of operating points into Monte-Carlo
//! jobs, fans them out over a worker pool, batches trials into
//! fixed-shape executor invocations, and aggregates ensemble statistics.
//!
//! Scheduling is lock-free: workers claim jobs with a single atomic
//! fetch-add over a shared (immutable) job slice and collect their
//! results into per-worker buffers, which are merged back into input
//! order after the pool joins. There is no job-queue mutex and no shared
//! result-store mutex on the hot path.
//!
//! Jobs are finer than points: a fixed-trials point on the native
//! backend fans out into one job per [`crate::mc::CHUNK_TRIALS`]-sized
//! chunk (each on its own `chunk_seed`-derived RNG stream), so a
//! 1-point `pareto --validate` or `figure` run saturates every worker
//! instead of one. Chunk outputs are re-assembled in chunk order after
//! the pool joins, which makes the pooled measurement bit-identical to
//! a sequential `measure(simulate(..))` — worker count and completion
//! order can't change a single bit of the result. Adaptive-precision
//! points (`precision: Some(..)`) are inherently sequential (the
//! stopping rule decides the trial count as it goes) and stay one job.
//!
//! Invariants (enforced by tests in rust/tests/prop_coordinator.rs):
//!  * every submitted point produces exactly one result;
//!  * per-point trial counts are met or exceeded (batch round-up);
//!  * results are deterministic given (point id, seed), independent of
//!    worker count and completion order;
//!  * a failing point never stalls the pool (fail-fast per point).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::arch::pvec;
use crate::mc::{ArchKind, InputDist, McOutput, MeasuredSnr, SnrAccumulator};
use crate::util::rng::Pcg64;

use super::service::{ArchRequest, PjrtHandle};

/// One sweep point: an architecture operating point to characterize with
/// `trials` Monte-Carlo trials.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Caller-meaningful identifier (e.g. "fig9a/vwl=0.8/n=128").
    pub id: String,
    pub kind: ArchKind,
    pub params: [f64; pvec::P],
    /// For fixed-trials points: the exact ensemble size. For adaptive
    /// points (`precision: Some(..)`): the trial *cap* the stopping rule
    /// may not exceed.
    pub trials: usize,
    pub seed: u64,
    pub dist: InputDist,
    /// `Some(half_width_db)`: run adaptively until the 95% CI of the
    /// measured SNR estimators fits the target (see
    /// `mc::simulate_adaptive`) instead of a fixed trial count. A new
    /// cache-key dimension — adaptive records never alias fixed-trials
    /// records (see `engine::cache::cache_key`).
    pub precision: Option<f64>,
}

impl SweepPoint {
    pub fn new(id: impl Into<String>, kind: ArchKind, params: [f64; pvec::P]) -> Self {
        Self {
            id: id.into(),
            kind,
            params,
            trials: 1024,
            seed: 0xC0FFEE,
            dist: InputDist::Uniform,
            precision: None,
        }
    }

    pub fn with_trials(mut self, trials: usize) -> Self {
        self.trials = trials;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_precision(mut self, half_width_db: f64) -> Self {
        self.precision = Some(half_width_db);
        self
    }
}

#[derive(Clone, Debug)]
pub struct SweepResult {
    pub id: String,
    pub index: usize,
    pub measured: MeasuredSnr,
    pub error: Option<String>,
    /// True when the result was served from the engine's result cache
    /// rather than computed by this run (see `crate::engine`).
    pub cached: bool,
}

/// Execution backend for the analog-core simulation.
#[derive(Clone)]
pub enum Backend {
    /// Native Rust Monte-Carlo (always available).
    Native,
    /// AOT JAX/Pallas artifacts through the PJRT executor service. The
    /// artifact name is derived from the point's `ArchKind`, with an
    /// optional suffix (e.g. "_small" for test artifacts).
    Pjrt {
        handle: PjrtHandle,
        suffix: &'static str,
    },
}

impl Backend {
    /// Stable identifier folded into the engine's content-addressed cache
    /// keys, so results from different execution backends never alias —
    /// including different *builds* of the same backend: the PJRT id
    /// carries the artifact-set fingerprint (manifest + HLO payload
    /// bytes), so a recompiled artifact set never serves records
    /// computed by its predecessor; the native id carries the crate
    /// version, which isolates *released* simulator generations — a
    /// physics change must bump the crate version (or the cache
    /// KEY_PREFIX) to invalidate old records, as Cargo.toml documents.
    pub fn cache_id(&self) -> String {
        match self {
            Backend::Native => format!("native@{}", env!("CARGO_PKG_VERSION")),
            Backend::Pjrt { handle, suffix } => {
                format!("pjrt{suffix}@{}", handle.artifact_fingerprint())
            }
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SweepOptions {
    pub workers: usize,
    /// Print progress lines. Progress is emitted as structured events
    /// through `crate::obs::progress` (which renders the human lines,
    /// rate-limited); this flag is the library-level fallback that
    /// keeps those lines printing for embedders that never select a
    /// CLI progress mode.
    pub verbose: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16);
        Self {
            workers,
            verbose: false,
        }
    }
}

/// One schedulable unit of work: a whole point, or one chunk of a
/// fixed-trials native point (intra-point parallelism).
enum Job {
    Point(usize),
    Chunk {
        point: usize,
        chunk: usize,
        trials: usize,
        seed: u64,
    },
}

/// What a worker hands back for one claimed job.
enum WorkItem {
    Result(SweepResult),
    Chunk {
        point: usize,
        chunk: usize,
        out: McOutput,
    },
}

/// Does this point fan out into per-chunk jobs on this backend?
/// Fixed-trials native points with 2+ chunks do; adaptive points are
/// sequential by construction, and the PJRT path batches internally.
fn fans_out(point: &SweepPoint, backend: &Backend) -> bool {
    matches!(backend, Backend::Native)
        && point.precision.is_none()
        && crate::mc::n_chunks(point.trials) >= 2
}

/// Run all points; the returned vector is ordered like the input.
///
/// Work distribution is an atomic-index claiming loop over a shared job
/// slice: each worker does `next.fetch_add(1)` to claim the next
/// unprocessed job and appends the result to its own buffer, so no
/// lock is taken anywhere on the execution path. Per-point (and
/// per-chunk) seeding is part of the job itself, and chunk outputs are
/// merged in chunk order after the pool joins, so results are
/// bit-identical regardless of worker count or completion order.
pub fn run_sweep(
    points: Vec<SweepPoint>,
    backend: Backend,
    opts: SweepOptions,
) -> Vec<SweepResult> {
    let n_points = points.len();
    if n_points == 0 {
        return Vec::new();
    }
    crate::obs::progress::mc_start(n_points as u64);

    let mut jobs: Vec<Job> = Vec::new();
    for (i, point) in points.iter().enumerate() {
        if fans_out(point, &backend) {
            for c in 0..crate::mc::n_chunks(point.trials) {
                let offset = c * crate::mc::CHUNK_TRIALS;
                jobs.push(Job::Chunk {
                    point: i,
                    chunk: c,
                    trials: crate::mc::CHUNK_TRIALS.min(point.trials - offset),
                    seed: crate::mc::chunk_seed(point.seed, c as u64),
                });
            }
        } else {
            jobs.push(Job::Point(i));
        }
    }
    let n_jobs = jobs.len();
    let jobs_slice: &[Job] = &jobs;

    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    // per-point outstanding-job counters, so the progress line fires
    // exactly once per point no matter how its chunks interleave
    let remaining: Vec<AtomicUsize> = points
        .iter()
        .map(|p| {
            AtomicUsize::new(if fans_out(p, &backend) {
                crate::mc::n_chunks(p.trials)
            } else {
                1
            })
        })
        .collect();
    let remaining_slice: &[AtomicUsize] = &remaining;
    let points_slice: &[SweepPoint] = &points;

    let workers = opts.workers.clamp(1, n_jobs);
    let buffers: Vec<Vec<WorkItem>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let backend = backend.clone();
                let next = &next;
                let done = &done;
                scope.spawn(move || {
                    let mut local: Vec<WorkItem> = Vec::new();
                    loop {
                        let job_index = next.fetch_add(1, Ordering::Relaxed);
                        if job_index >= n_jobs {
                            break;
                        }
                        match jobs_slice[job_index] {
                            Job::Point(index) => {
                                let point = &points_slice[index];
                                let res = run_point(point, &backend);
                                if let Ok(m) = &res {
                                    super::metrics::add_trials_completed(m.trials);
                                }
                                let left = remaining_slice[index]
                                    .fetch_sub(1, Ordering::Relaxed)
                                    - 1;
                                debug_assert_eq!(left, 0);
                                let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                                crate::obs::progress::point_done(
                                    &point.id,
                                    finished as u64,
                                    n_points as u64,
                                    res.as_ref().map(|m| m.trials).unwrap_or(0),
                                    0,
                                    Some(
                                        res.as_ref()
                                            .map(|m| m.snr_t_db)
                                            .unwrap_or(f64::NAN),
                                    ),
                                    opts.verbose,
                                );
                                local.push(WorkItem::Result(match res {
                                    Ok(measured) => SweepResult {
                                        id: point.id.clone(),
                                        index,
                                        measured,
                                        error: None,
                                        cached: false,
                                    },
                                    Err(e) => SweepResult {
                                        id: point.id.clone(),
                                        index,
                                        measured: MeasuredSnr::default(),
                                        error: Some(e.to_string()),
                                        cached: false,
                                    },
                                }));
                            }
                            Job::Chunk {
                                point: index,
                                chunk,
                                trials,
                                seed,
                            } => {
                                let point = &points_slice[index];
                                let out = crate::mc::simulate_chunk(
                                    point.kind,
                                    &point.params,
                                    trials,
                                    seed,
                                    point.dist,
                                );
                                super::metrics::add_trials_completed(trials as u64);
                                let left = remaining_slice[index]
                                    .fetch_sub(1, Ordering::Relaxed)
                                    - 1;
                                if left == 0 {
                                    let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                                    crate::obs::progress::point_done(
                                        &point.id,
                                        finished as u64,
                                        n_points as u64,
                                        point.trials as u64,
                                        crate::mc::n_chunks(point.trials) as u64,
                                        None,
                                        opts.verbose,
                                    );
                                }
                                local.push(WorkItem::Chunk {
                                    point: index,
                                    chunk,
                                    out,
                                });
                            }
                        }
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });

    // Re-assemble: whole-point results drop into their slot; chunked
    // points gather their chunk outputs and are measured in chunk order
    // (the exact push sequence of a sequential measure(simulate(..))).
    let mut slots: Vec<Option<SweepResult>> = vec![None; n_points];
    let mut chunk_slots: Vec<Vec<Option<McOutput>>> = points
        .iter()
        .map(|p| {
            if fans_out(p, &backend) {
                let mut v = Vec::new();
                v.resize_with(crate::mc::n_chunks(p.trials), || None);
                v
            } else {
                Vec::new()
            }
        })
        .collect();
    for buffer in buffers {
        for item in buffer {
            match item {
                WorkItem::Result(result) => {
                    let index = result.index;
                    debug_assert!(slots[index].is_none(), "point {index} claimed twice");
                    slots[index] = Some(result);
                }
                WorkItem::Chunk { point, chunk, out } => {
                    debug_assert!(
                        chunk_slots[point][chunk].is_none(),
                        "chunk {chunk} of point {point} claimed twice"
                    );
                    chunk_slots[point][chunk] = Some(out);
                }
            }
        }
    }
    for (index, chunks) in chunk_slots.into_iter().enumerate() {
        if chunks.is_empty() {
            continue;
        }
        let mut acc = SnrAccumulator::new();
        for out in &chunks {
            acc.push_chunk(out.as_ref().expect("every chunk produces an output"));
        }
        slots[index] = Some(SweepResult {
            id: points[index].id.clone(),
            index,
            measured: acc.finalize(),
            error: None,
            cached: false,
        });
    }
    slots
        .into_iter()
        .map(|r| r.expect("every point produces a result"))
        .collect()
}

/// Execute one point to completion on the chosen backend.
pub fn run_point(point: &SweepPoint, backend: &Backend) -> anyhow::Result<MeasuredSnr> {
    match backend {
        Backend::Native => {
            if let Some(half_width_db) = point.precision {
                let run = crate::mc::simulate_adaptive(
                    point.kind,
                    &point.params,
                    half_width_db,
                    point.seed,
                    point.dist,
                    point.trials,
                );
                return Ok(run.measured);
            }
            let out = crate::mc::simulate(
                point.kind,
                &point.params,
                point.trials,
                point.seed,
                point.dist,
            );
            Ok(crate::mc::measure(&out))
        }
        Backend::Pjrt { handle, suffix } => {
            anyhow::ensure!(
                point.precision.is_none(),
                "point {} requests adaptive --precision: the sequential \
                 stopping rule is native-only, rerun with --backend native \
                 or a fixed --trials count",
                point.id
            );
            // Banked points are native-only: the AOT artifacts model a
            // single array and would silently ignore the bank slot.
            anyhow::ensure!(
                point.params[pvec::IDX_BANKS] < 2.0,
                "point {} is banked (banks={}): multi-bank simulation is \
                 native-only, rerun with --backend native",
                point.id,
                point.params[pvec::IDX_BANKS]
            );
            // QS correlated-mismatch mode is a separate (heavier) artifact
            let corr = point.kind == ArchKind::Qs
                && point.params[pvec::QS_IDX_MODE] >= 0.5;
            let artifact = if corr {
                format!("{}_corr{}", point.kind.artifact_name(), suffix)
            } else {
                format!("{}{}", point.kind.artifact_name(), suffix)
            };
            let (m, n_max) = handle.arch_shape(&artifact)?;
            let n = point.params[pvec::IDX_N_ACTIVE] as usize;
            anyhow::ensure!(
                n <= n_max,
                "point {} wants N={n} > artifact n_max={n_max}",
                point.id
            );
            let batches = point.trials.div_ceil(m);
            let mut acc = SnrAccumulator::new();
            let mut rng = Pcg64::new(point.seed);
            let mut x = vec![0f32; m * n_max];
            let mut w = vec![0f32; m * n_max];
            for b in 0..batches {
                fill_inputs(&mut x, &mut w, n, n_max, &point.dist, &mut rng);
                let seed = [(point.seed % 0x7fff_ffff) as f32, b as f32];
                let out: McOutput = handle.run_arch(ArchRequest {
                    artifact: artifact.clone(),
                    x: x.clone(),
                    w: w.clone(),
                    seed,
                    params: point.params,
                })?;
                acc.push_chunk(&out);
            }
            Ok(acc.finalize())
        }
    }
}

/// Fill the fixed-shape input buffers: active lanes get fresh draws,
/// inactive lanes are zeroed (the artifact masks them anyway).
fn fill_inputs(
    x: &mut [f32],
    w: &mut [f32],
    n: usize,
    n_max: usize,
    dist: &InputDist,
    rng: &mut Pcg64,
) {
    let m = x.len() / n_max;
    for t in 0..m {
        let row = t * n_max;
        for k in 0..n_max {
            if k < n {
                x[row + k] = draw_x(dist, rng) as f32;
                w[row + k] = draw_w(dist, rng) as f32;
            } else {
                x[row + k] = 0.0;
                w[row + k] = 0.0;
            }
        }
    }
}

fn draw_x(dist: &InputDist, rng: &mut Pcg64) -> f64 {
    match dist {
        InputDist::Uniform => rng.uniform(),
        InputDist::ClippedGaussian { sx, .. } => (rng.normal().abs() * sx).min(0.999_999),
    }
}

fn draw_w(dist: &InputDist, rng: &mut Pcg64) -> f64 {
    match dist {
        InputDist::Uniform => rng.uniform_in(-1.0, 1.0),
        InputDist::ClippedGaussian { sw, .. } => {
            (rng.normal() * sw).clamp(-0.999_999, 0.999_999)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pvec;

    fn qs_point(id: &str, n: usize, seed: u64) -> SweepPoint {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = n as f64;
        p[pvec::IDX_BX] = 6.0;
        p[pvec::IDX_BW] = 6.0;
        p[pvec::IDX_B_ADC] = 8.0;
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 60.0;
        p[pvec::QS_IDX_V_C] = 60.0;
        SweepPoint::new(id, ArchKind::Qs, p)
            .with_trials(256)
            .with_seed(seed)
    }

    #[test]
    fn native_sweep_returns_every_point_in_order() {
        let points: Vec<SweepPoint> =
            (0..10).map(|i| qs_point(&format!("p{i}"), 32 + i, i as u64)).collect();
        let res = run_sweep(points, Backend::Native, SweepOptions { workers: 4, verbose: false });
        assert_eq!(res.len(), 10);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.id, format!("p{i}"));
            assert!(r.error.is_none());
            assert!(!r.cached, "scheduler never serves cached results");
            assert_eq!(r.measured.trials, 256);
        }
    }

    #[test]
    fn more_workers_than_points_is_fine() {
        let points: Vec<SweepPoint> = (0..3).map(|i| qs_point(&format!("p{i}"), 16, 1)).collect();
        let res = run_sweep(
            points,
            Backend::Native,
            SweepOptions { workers: 16, verbose: false },
        );
        assert_eq!(res.len(), 3);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.index, i);
        }
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let mk = || (0..6).map(|i| qs_point(&format!("p{i}"), 64, 7)).collect::<Vec<_>>();
        let a = run_sweep(mk(), Backend::Native, SweepOptions { workers: 1, verbose: false });
        let b = run_sweep(mk(), Backend::Native, SweepOptions { workers: 8, verbose: false });
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.measured.snr_t_db, y.measured.snr_t_db);
        }
    }

    #[test]
    fn empty_sweep_is_fine() {
        let res = run_sweep(Vec::new(), Backend::Native, SweepOptions::default());
        assert!(res.is_empty());
    }

    #[test]
    fn single_point_fans_out_and_stays_bitwise_deterministic() {
        // a 1-point fixed-trials run splits into chunks across the pool;
        // the assembled measurement is bit-identical to the sequential
        // run_point path for every worker count
        let point = qs_point("solo", 64, 9).with_trials(1024);
        let direct = run_point(&point, &Backend::Native).unwrap();
        for workers in [1, 3, 8] {
            let res = run_sweep(
                vec![point.clone()],
                Backend::Native,
                SweepOptions { workers, verbose: false },
            );
            assert_eq!(res.len(), 1);
            assert!(res[0].error.is_none());
            assert_eq!(res[0].measured.trials, 1024);
            assert_eq!(
                res[0].measured.snr_t_db.to_bits(),
                direct.snr_t_db.to_bits(),
                "workers={workers}"
            );
            assert_eq!(
                res[0].measured.snr_a_total_db.to_bits(),
                direct.snr_a_total_db.to_bits()
            );
            assert_eq!(
                res[0].measured.sigma_eta_a2.to_bits(),
                direct.sigma_eta_a2.to_bits()
            );
        }
    }

    #[test]
    fn adaptive_point_runs_through_scheduler() {
        let point = qs_point("adaptive", 64, 9)
            .with_trials(1 << 14)
            .with_precision(2.0);
        let res = run_sweep(
            vec![point],
            Backend::Native,
            SweepOptions { workers: 4, verbose: false },
        );
        assert_eq!(res.len(), 1);
        assert!(res[0].error.is_none());
        let trials = res[0].measured.trials as usize;
        assert_eq!(trials % crate::mc::CHUNK_TRIALS, 0, "whole chunks only");
        assert!(trials >= 4 * crate::mc::CHUNK_TRIALS, "min batch means");
        assert!(trials <= 1 << 14, "cap respected");
    }

    #[test]
    fn pjrt_rejects_adaptive_precision() {
        let service = crate::coordinator::PjrtService::spawn(
            std::env::temp_dir().join("imclim-no-artifacts-here"),
            1,
        );
        let backend = Backend::Pjrt {
            handle: service.handle(),
            suffix: "",
        };
        let point = qs_point("ad-pjrt", 32, 1).with_precision(0.5);
        let err = run_point(&point, &backend).unwrap_err().to_string();
        assert!(err.contains("native-only"), "{err}");
    }

    #[test]
    fn cache_id_carries_backend_identity_and_version() {
        let id = Backend::Native.cache_id();
        assert_eq!(id, format!("native@{}", env!("CARGO_PKG_VERSION")));
        // the stubbed offline runtime has no manifest: its artifact
        // fingerprint degrades to the placeholder, still distinct from
        // the native id (and from any real artifact build's hash)
        let service = crate::coordinator::PjrtService::spawn(
            std::env::temp_dir().join("imclim-no-artifacts-here"),
            1,
        );
        let pjrt = Backend::Pjrt {
            handle: service.handle(),
            suffix: "_small",
        };
        assert_eq!(pjrt.cache_id(), "pjrt_small@unmanifested");
        assert_ne!(pjrt.cache_id(), id);
    }
}
