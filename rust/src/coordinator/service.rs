//! Executor services for the coordinator:
//!
//! * the PJRT executor — one dedicated thread owning the (!Send) PJRT
//!   client and compiled executables, fed by a bounded request channel
//!   (backpressure: producers block when the executor falls behind);
//! * the shard-subprocess runner ([`run_shard_procs`]) — parent-side
//!   orchestration for distributed sweeps: spawn one `imclim sweep
//!   --shard i/k` subprocess per shard, stream their progress lines
//!   with a per-shard prefix, and report any failures.
//!
//! This is the serving-style split the three-layer architecture calls
//! for: worker threads generate workloads and aggregate statistics; all
//! XLA execution funnels through the single-owner PJRT service, and all
//! multi-process execution funnels through the shard runner.

use std::io::{BufRead, BufReader, Read};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::arch::pvec;
use crate::mc::McOutput;
use crate::runtime::Runtime;

pub struct ArchRequest {
    pub artifact: String,
    pub x: Vec<f32>,
    pub w: Vec<f32>,
    pub seed: [f32; 2],
    pub params: [f64; pvec::P],
}

#[allow(clippy::large_enum_variant)]
pub struct MlpRequest {
    pub x: Vec<f32>,
    pub weights: MlpWeights,
    pub seed: [f32; 2],
    pub sigmas: [f32; 3],
}

#[derive(Clone, Debug, Default)]
pub struct MlpWeights {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub w3: Vec<f32>,
    pub b3: Vec<f32>,
}

enum Msg {
    Arch(ArchRequest, SyncSender<Result<McOutput>>),
    Mlp(MlpRequest, SyncSender<Result<Vec<f32>>>),
    Smoke(SyncSender<Result<Vec<f32>>>),
    /// (artifact) -> (m, n_max)
    Shape(String, SyncSender<Result<(usize, usize)>>),
    Shutdown,
}

/// Cloneable, Send handle to the executor thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: SyncSender<Msg>,
    /// Fingerprint of the artifact set this executor serves (see
    /// `runtime::artifact_fingerprint`); folded into cache keys so
    /// recompiled artifacts never alias older cached results.
    artifact_fingerprint: String,
}

impl PjrtHandle {
    pub fn run_arch(&self, req: ArchRequest) -> Result<McOutput> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Msg::Arch(req, rtx))
            .map_err(|_| anyhow!("PJRT service stopped"))?;
        rrx.recv().map_err(|_| anyhow!("PJRT service dropped reply"))?
    }

    pub fn run_mlp(&self, req: MlpRequest) -> Result<Vec<f32>> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Msg::Mlp(req, rtx))
            .map_err(|_| anyhow!("PJRT service stopped"))?;
        rrx.recv().map_err(|_| anyhow!("PJRT service dropped reply"))?
    }

    pub fn smoke(&self) -> Result<Vec<f32>> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Msg::Smoke(rtx))
            .map_err(|_| anyhow!("PJRT service stopped"))?;
        rrx.recv().map_err(|_| anyhow!("PJRT service dropped reply"))?
    }

    /// Fingerprint of the artifact set behind this executor.
    pub fn artifact_fingerprint(&self) -> &str {
        &self.artifact_fingerprint
    }

    /// Static (m_trials, n_max) shape of an arch artifact.
    pub fn arch_shape(&self, artifact: &str) -> Result<(usize, usize)> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Msg::Shape(artifact.to_string(), rtx))
            .map_err(|_| anyhow!("PJRT service stopped"))?;
        rrx.recv().map_err(|_| anyhow!("PJRT service dropped reply"))?
    }
}

/// The running service; dropping it shuts the executor thread down.
pub struct PjrtService {
    handle: Option<JoinHandle<()>>,
    tx: SyncSender<Msg>,
    artifact_fingerprint: String,
}

impl PjrtService {
    /// Spawn the executor thread. `queue_depth` bounds in-flight requests
    /// (backpressure); startup errors (missing artifacts) surface on the
    /// first request.
    pub fn spawn(artifacts_dir: PathBuf, queue_depth: usize) -> Self {
        let (tx, rx): (SyncSender<Msg>, Receiver<Msg>) = sync_channel(queue_depth);
        let artifact_fingerprint = crate::runtime::artifact_fingerprint(&artifacts_dir);
        let handle = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_loop(artifacts_dir, rx))
            .expect("spawn pjrt executor");
        Self {
            handle: Some(handle),
            tx,
            artifact_fingerprint,
        }
    }

    pub fn handle(&self) -> PjrtHandle {
        PjrtHandle {
            tx: self.tx.clone(),
            artifact_fingerprint: self.artifact_fingerprint.clone(),
        }
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn executor_loop(dir: PathBuf, rx: Receiver<Msg>) {
    let runtime = Runtime::new(&dir);
    for msg in rx {
        match msg {
            Msg::Shutdown => break,
            Msg::Arch(req, reply) => {
                let res = runtime.as_ref().map_err(clone_err).and_then(|rt| {
                    let exe = rt.arch(&req.artifact)?;
                    exe.run(&req.x, &req.w, req.seed, &req.params)
                });
                let _ = reply.send(res);
            }
            Msg::Mlp(req, reply) => {
                let res = runtime.as_ref().map_err(clone_err).and_then(|rt| {
                    let exe = rt.mlp()?;
                    let w = &req.weights;
                    exe.run(
                        &req.x, &w.w1, &w.b1, &w.w2, &w.b2, &w.w3, &w.b3, req.seed,
                        req.sigmas,
                    )
                });
                let _ = reply.send(res);
            }
            Msg::Smoke(reply) => {
                let res = runtime.as_ref().map_err(clone_err).and_then(|rt| rt.smoke());
                let _ = reply.send(res);
            }
            Msg::Shape(name, reply) => {
                let res = runtime.as_ref().map_err(clone_err).and_then(|rt| {
                    let exe = rt.arch(&name)?;
                    Ok((exe.m, exe.n_max))
                });
                let _ = reply.send(res);
            }
        }
    }
}

fn clone_err(e: &anyhow::Error) -> anyhow::Error {
    anyhow!("PJRT runtime init failed: {e}")
}

// ---------------------------------------------------------------------
// Shard-subprocess orchestration (distributed sweeps).
//
// Each shard writes an ordinary out-dir whose `cache/` is a complete,
// self-contained cache directory. That makes shard results portable
// *before* the parent merges them: `imclim cache pack --dir
// shard-i/cache` snapshots one shard into a registry artifact
// (`registry::artifact`), so distributed runs can publish per-shard
// and let any consumer `cache pull` + merge instead of shipping raw
// directories.
// ---------------------------------------------------------------------

/// One shard subprocess of a distributed sweep: a display label (used to
/// prefix streamed progress lines, e.g. `shard 2/4`) and the prepared
/// command.
pub struct ShardCommand {
    pub label: String,
    pub command: Command,
}

/// Spawn every shard subprocess concurrently, stream each one's stdout
/// and stderr to this process's stderr line-by-line (prefixed with the
/// shard label), and wait for all of them. Every failure — spawn, wait,
/// or a non-zero exit — is collected rather than returned early, so a
/// failing shard never orphans its siblings: all spawned children are
/// drained and waited on before the combined error is reported.
pub fn run_shard_procs(shards: Vec<ShardCommand>) -> Result<()> {
    let n_shards = shards.len();
    let _span = crate::obs::trace::span_with("shard_procs", "coordinator", || {
        format!("{n_shards} shards")
    });
    let mut failures: Vec<String> = Vec::new();
    let mut children: Vec<(String, Child)> = Vec::new();
    for (i, mut shard) in shards.into_iter().enumerate() {
        shard.command.stdout(Stdio::piped()).stderr(Stdio::piped());
        match shard.command.spawn() {
            Ok(child) => {
                crate::obs::progress::shard(
                    "shard_start",
                    &shard.label,
                    i as u64 + 1,
                    n_shards as u64,
                );
                children.push((shard.label, child));
            }
            Err(e) => failures.push(format!("spawning {} failed: {e}", shard.label)),
        }
    }
    let mut readers: Vec<JoinHandle<()>> = Vec::new();
    for (label, child) in &mut children {
        if let Some(out) = child.stdout.take() {
            readers.push(stream_lines(label.clone(), out));
        }
        if let Some(err) = child.stderr.take() {
            readers.push(stream_lines(label.clone(), err));
        }
    }
    let n_spawned = children.len();
    for (i, (label, mut child)) in children.into_iter().enumerate() {
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => failures.push(format!("{label} exited with {status}")),
            Err(e) => failures.push(format!("waiting on {label} failed: {e}")),
        }
        crate::obs::progress::shard("shard_exit", &label, i as u64 + 1, n_spawned as u64);
    }
    for r in readers {
        let _ = r.join();
    }
    anyhow::ensure!(
        failures.is_empty(),
        "shard subprocess failure: {}",
        failures.join("; ")
    );
    Ok(())
}

/// Forward a child pipe to stderr, one prefixed line at a time.
fn stream_lines(label: String, pipe: impl Read + Send + 'static) -> JoinHandle<()> {
    std::thread::spawn(move || {
        forward_lines(pipe, |line| eprintln!("[{label}] {line}"));
    })
}

/// Pump a pipe line-by-line into `emit`. Non-UTF-8 bytes are decoded
/// lossily — a shard crashing mid-write must not silence the rest of
/// its output — and a read error is surfaced as a final diagnostic
/// line instead of silently truncating the stream (the old
/// `.lines().map_while(Result::ok)` did both).
fn forward_lines(pipe: impl Read, mut emit: impl FnMut(&str)) {
    let mut reader = BufReader::new(pipe);
    let mut buf = Vec::new();
    loop {
        buf.clear();
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => break,
            Ok(_) => {
                if buf.last() == Some(&b'\n') {
                    buf.pop();
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                }
                emit(&String::from_utf8_lossy(&buf));
            }
            Err(e) => {
                emit(&format!("<stream read error: {e}>"));
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io;

    /// Reader that yields its buffered bytes, then fails.
    struct ErrAfter(io::Cursor<Vec<u8>>);

    impl Read for ErrAfter {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            match self.0.read(buf)? {
                0 => Err(io::Error::new(io::ErrorKind::BrokenPipe, "pipe burst")),
                n => Ok(n),
            }
        }
    }

    fn collect(pipe: impl Read) -> Vec<String> {
        let mut out = Vec::new();
        forward_lines(pipe, |l| out.push(l.to_string()));
        out
    }

    #[test]
    fn non_utf8_lines_are_decoded_lossily_not_dropped() {
        let lines = collect(io::Cursor::new(b"ok\n\xffbad\xfe\nafter".to_vec()));
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "ok");
        assert!(lines[1].contains('\u{FFFD}'), "{:?}", lines[1]);
        assert!(lines[1].contains("bad"), "{:?}", lines[1]);
        assert_eq!(lines[2], "after", "lines after bad bytes must survive");
    }

    #[test]
    fn read_errors_surface_as_a_diagnostic_line() {
        let lines = collect(ErrAfter(io::Cursor::new(b"first\n".to_vec())));
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0], "first");
        assert!(lines[1].contains("stream read error"), "{:?}", lines[1]);
        assert!(lines[1].contains("pipe burst"), "{:?}", lines[1]);
    }

    #[test]
    fn crlf_and_missing_final_newline_are_handled() {
        let lines = collect(io::Cursor::new(b"a\r\nb".to_vec()));
        assert_eq!(lines, ["a", "b"]);
    }
}
