//! CSV emission for figure/table data (`results/*.csv`).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Column-ordered CSV writer with RFC-4180-style quoting.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: row of f64 values formatted with 6 significant digits.
    pub fn row_f64(&mut self, cells: &[f64]) {
        let formatted: Vec<String> = cells.iter().map(|x| fmt_num(*x)).collect();
        self.row(&formatted);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        out.push_str(&join_quoted(&self.header));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&join_quoted(r));
            out.push('\n');
        }
        out
    }

    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(self.to_string().as_bytes())
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

pub fn fmt_num(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    if x == x.trunc() && x.abs() < 1e12 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6}")
    }
}

fn join_quoted(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_csv() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into(), "x,y".into()]);
        w.row_f64(&[2.5, 3.0]);
        let s = w.to_string();
        assert_eq!(s, "a,b\n1,\"x,y\"\n2.500000,3\n");
        assert_eq!(w.n_rows(), 2);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut w = CsvWriter::new(&["a"]);
        w.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn quoting() {
        let mut w = CsvWriter::new(&["q"]);
        w.row(&["say \"hi\"".into()]);
        assert!(w.to_string().contains("\"say \"\"hi\"\"\""));
    }
}
