//! SHA-256 (FIPS 180-4), dependency-free.
//!
//! The registry's artifact format (`registry::artifact`) checksums every
//! cache record and the payload tarball with SHA-256 so published
//! results can be verified end-to-end on any machine; the offline build
//! has no crypto crate, so the compression function lives here. This is
//! an integrity hash for tamper/corruption detection, not a substitute
//! for authenticated channels.

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256 state.
pub struct Sha256 {
    h: [u32; 8],
    block: [u8; 64],
    block_len: usize,
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Self {
            h: H0,
            block: [0; 64],
            block_len: 0,
            total_len: 0,
        }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        if self.block_len > 0 {
            let take = data.len().min(64 - self.block_len);
            self.block[self.block_len..self.block_len + take].copy_from_slice(&data[..take]);
            self.block_len += take;
            data = &data[take..];
            if self.block_len == 64 {
                let block = self.block;
                self.compress(&block);
                self.block_len = 0;
            }
            // data exhausted into a still-partial block: the buffered
            // bytes must survive; the tail copy below would reset
            // block_len to 0 and drop them.
            if data.is_empty() {
                return;
            }
        }
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        self.block[..data.len()].copy_from_slice(data);
        self.block_len = data.len();
    }

    /// Consume the state, returning the 32-byte digest.
    pub fn finish(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        // padding: 0x80, zeros to 56 mod 64, then the bit length
        let mut pad = [0u8; 72];
        pad[0] = 0x80;
        let pad_len = if self.block_len < 56 {
            56 - self.block_len
        } else {
            120 - self.block_len
        };
        pad[pad_len..pad_len + 8].copy_from_slice(&bit_len.to_be_bytes());
        let tail: Vec<u8> = pad[..pad_len + 8].to_vec();
        self.update_nolen(&tail);
        let mut out = [0u8; 32];
        for (i, word) in self.h.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    /// Hex digest of the consumed state.
    pub fn finish_hex(self) -> String {
        let digest = self.finish();
        let mut s = String::with_capacity(64);
        for b in digest {
            use std::fmt::Write as _;
            let _ = write!(s, "{b:02x}");
        }
        s
    }

    /// `update` without advancing `total_len` (padding only).
    fn update_nolen(&mut self, data: &[u8]) {
        let total = self.total_len;
        self.update(data);
        self.total_len = total;
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes(chunk.try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a.wrapping_add(t2);
            a = t1.wrapping_add(t2);
        }
        for (s, v) in self.h.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }
}

/// One-shot hex digest.
pub fn sha256_hex(data: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(data);
    h.finish_hex()
}

#[cfg(test)]
mod tests {
    use super::*;

    // FIPS 180-4 / NIST known-answer vectors.
    #[test]
    fn known_answer_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a_vector() {
        let mut h = Sha256::new();
        let chunk = [b'a'; 1000];
        for _ in 0..1000 {
            h.update(&chunk);
        }
        assert_eq!(
            h.finish_hex(),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn streaming_matches_oneshot_at_block_boundaries() {
        let data: Vec<u8> = (0..300u32).map(|i| (i * 7 % 251) as u8).collect();
        for split in [0, 1, 55, 56, 63, 64, 65, 128, 299, 300] {
            let mut h = Sha256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finish_hex(), sha256_hex(&data), "split at {split}");
        }
    }

    #[test]
    fn streaming_matches_oneshot_byte_at_a_time() {
        let data: Vec<u8> = (0..300u32).map(|i| (i * 13 % 251) as u8).collect();
        let mut h = Sha256::new();
        for b in &data {
            h.update(std::slice::from_ref(b));
        }
        assert_eq!(h.finish_hex(), sha256_hex(&data));
    }

    #[test]
    fn streaming_matches_oneshot_with_mixed_small_chunks() {
        // chunk sizes chosen to repeatedly leave a partial block, then
        // extend it — exercises every branch of update(), including
        // empty updates onto a partially-filled buffer.
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 31 % 251) as u8).collect();
        let mut h = Sha256::new();
        let mut pos = 0usize;
        for size in [3usize, 0, 1, 61, 64, 0, 7, 130, 5].iter().cycle() {
            let take = (*size).min(data.len() - pos);
            h.update(&data[pos..pos + take]);
            pos += take;
            if pos == data.len() {
                break;
            }
        }
        assert_eq!(h.finish_hex(), sha256_hex(&data));
    }

    #[test]
    fn empty_updates_are_noops() {
        let mut h = Sha256::new();
        h.update(b"");
        h.update(b"ab");
        h.update(b"");
        h.update(b"c");
        h.update(b"");
        assert_eq!(
            h.finish_hex(),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }
}
