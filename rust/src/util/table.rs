//! ASCII table rendering for CLI figure/table output.

pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:>width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

/// Format a dB value for display.
pub fn fmt_db(x: f64) -> String {
    if x.is_infinite() {
        if x > 0.0 { "inf".into() } else { "-inf".into() }
    } else {
        format!("{x:.2}")
    }
}

/// Format a silicon area given in mm²: small macros read better in µm².
pub fn fmt_area(mm2: f64) -> String {
    if mm2.abs() < 0.01 {
        format!("{:.1} um2", mm2 * 1e6)
    } else {
        format!("{mm2:.4} mm2")
    }
}

/// Format an energy in joules with an SI prefix (fJ/pJ/nJ).
pub fn fmt_energy(x: f64) -> String {
    let ax = x.abs();
    if ax < 1e-12 {
        format!("{:.2} fJ", x * 1e15)
    } else if ax < 1e-9 {
        format!("{:.2} pJ", x * 1e12)
    } else if ax < 1e-6 {
        format!("{:.2} nJ", x * 1e9)
    } else {
        format!("{:.3e} J", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new(&["name", "v"]).with_title("T");
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.starts_with("T\n"));
        assert!(s.contains("long-name"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn energy_prefixes() {
        assert_eq!(fmt_energy(3.2e-15), "3.20 fJ");
        assert_eq!(fmt_energy(4.5e-12), "4.50 pJ");
        assert_eq!(fmt_energy(7.0e-9), "7.00 nJ");
    }

    #[test]
    fn area_units_switch_at_macro_scale() {
        assert_eq!(fmt_area(2.6e-3), "2600.0 um2");
        assert_eq!(fmt_area(0.25), "0.2500 mm2");
    }
}
