//! Minimal JSON parser/writer (offline build: no serde).
//!
//! Parses the artifact manifest emitted by `python/compile/aot.py` and
//! serializes experiment results. Supports the full JSON value grammar
//! with the usual escape sequences; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (keys sorted — Obj is a BTreeMap — so output is stable).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (got {:?})",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{txt}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("short \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"o":{"k":true}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "m_trials": 64, "n_max": 512, "b_max": 8, "p": 16,
          "artifacts": {"qs_arch": {"file": "qs_arch.hlo.txt",
            "inputs": [{"name": "x", "shape": [64, 512]}],
            "outputs": ["y_ideal"]}}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("p").unwrap().as_usize(), Some(16));
        let arts = j.get("artifacts").unwrap().as_obj().unwrap();
        assert!(arts.contains_key("qs_arch"));
    }
}
