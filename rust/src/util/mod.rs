//! Dependency-free infrastructure: RNG, statistics, JSON, CSV, tables,
//! SHA-256.
//!
//! The offline build vendors only the `xla` crate closure, so everything a
//! typical project would pull from crates.io lives here, each module with
//! its own unit tests.

pub mod csv;
pub mod json;
pub mod rng;
pub mod sha256;
pub mod stats;
pub mod table;
