//! Streaming statistics and dB helpers used by every SNR measurement.

/// Numerically-stable streaming mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    /// Merge another accumulator (parallel aggregation; Chan's formula).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Paired-sample SNR accumulator: signal power from the reference stream,
/// noise power from (observed - reference). This is how every compute-SNR
/// metric of eq. (7) is estimated from Monte-Carlo ensembles.
#[derive(Clone, Debug, Default)]
pub struct SnrAccumulator {
    pub signal: Welford,
    pub noise: Welford,
}

impl SnrAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, reference: f64, observed: f64) {
        self.signal.push(reference);
        self.noise.push(observed - reference);
    }

    pub fn merge(&mut self, other: &SnrAccumulator) {
        self.signal.merge(&other.signal);
        self.noise.merge(&other.noise);
    }

    pub fn snr(&self) -> f64 {
        let nv = self.noise.variance();
        if nv <= 0.0 {
            f64::INFINITY
        } else {
            self.signal.variance() / nv
        }
    }

    pub fn snr_db(&self) -> f64 {
        db(self.snr())
    }

    pub fn count(&self) -> u64 {
        self.signal.count()
    }
}

/// 10*log10 with -inf guard.
#[inline]
pub fn db(x: f64) -> f64 {
    if x <= 0.0 {
        f64::NEG_INFINITY
    } else {
        10.0 * x.log10()
    }
}

/// Inverse of `db`.
#[inline]
pub fn from_db(x_db: f64) -> f64 {
    10f64.powf(x_db / 10.0)
}

/// Population variance of a slice.
pub fn variance(xs: &[f64]) -> f64 {
    let mut w = Welford::new();
    w.extend(xs);
    w.variance()
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// p-quantile (0..=1) by sorting a copy; fine for reporting-sized data.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() - 1) as f64 * p).round() as usize;
    v[idx]
}

/// Median absolute deviation (robust spread for bench reporting).
pub fn median_abs_dev(xs: &[f64]) -> f64 {
    let med = quantile(xs, 0.5);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    quantile(&devs, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        w.extend(&xs);
        let m = xs.iter().sum::<f64>() / 5.0;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 5.0;
        assert!((w.mean() - m).abs() < 1e-12);
        assert!((w.variance() - v).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut a = Welford::new();
        a.extend(&xs[..37]);
        let mut b = Welford::new();
        b.extend(&xs[37..]);
        a.merge(&b);
        let mut full = Welford::new();
        full.extend(&xs);
        assert!((a.mean() - full.mean()).abs() < 1e-12);
        assert!((a.variance() - full.variance()).abs() < 1e-12);
        assert_eq!(a.count(), 100);
    }

    #[test]
    fn snr_accumulator_known_ratio() {
        // signal var 4, noise var 0.04 -> SNR = 100 = 20 dB
        let mut acc = SnrAccumulator::new();
        let mut rng = crate::util::rng::Pcg64::new(5);
        for _ in 0..200_000 {
            let s = rng.normal_scaled(0.0, 2.0);
            let n = rng.normal_scaled(0.0, 0.2);
            acc.push(s, s + n);
        }
        assert!((acc.snr_db() - 20.0).abs() < 0.2, "{}", acc.snr_db());
    }

    #[test]
    fn db_roundtrip() {
        for x in [0.01, 1.0, 42.0, 1e6] {
            assert!((from_db(db(x)) - x).abs() / x < 1e-12);
        }
        assert_eq!(db(0.0), f64::NEG_INFINITY);
    }

    #[test]
    fn quantile_and_mad() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(median_abs_dev(&xs), 1.0);
    }
}
