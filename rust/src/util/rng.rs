//! Deterministic pseudo-random number generation for the native
//! Monte-Carlo simulator and the property-testing framework.
//!
//! The build is fully offline (no `rand` crate), so we implement the two
//! standard small generators used throughout: SplitMix64 for seeding and
//! PCG64 (XSL-RR 128/64) as the workhorse stream, plus Gaussian sampling
//! via the polar (Marsaglia) method.

/// SplitMix64: used to expand a small seed into independent stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Banked second output of the last polar-method pair: Marsaglia's
    /// transform yields *two* independent N(0,1) samples per accepted
    /// (u, v) draw, so [`Pcg64::normal`] serves the spare before
    /// consuming fresh uniforms (halves the RNG + ln/sqrt cost of
    /// Gaussian-heavy Monte-Carlo kernels).
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed from a u64; stream id derives from the seed via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let i = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let mut rng = Self {
            state: 0,
            inc: (i << 1) | 1,
            spare_normal: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(s);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Independent child stream `k` (stable: does not advance `self`).
    pub fn stream(&self, k: u64) -> Self {
        let mut sm = SplitMix64::new((self.state >> 64) as u64 ^ k.wrapping_mul(0x9E37_79B9));
        Pcg64::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let v = self.next_u64();
            let hi = ((v as u128 * n as u128) >> 64) as u64;
            let lo = (v as u128 * n as u128) as u64;
            if lo >= n.wrapping_neg() % n || n.is_power_of_two() {
                return hi;
            }
            // retry only in the tiny biased region
            if lo >= n {
                return hi;
            }
        }
    }

    /// Standard normal via the polar (Marsaglia) method. Each accepted
    /// (u, v) pair yields two independent samples; the second is banked
    /// and served by the next call.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let r = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * r);
                return u * r;
            }
        }
    }

    /// N(mu, sigma^2) sample.
    #[inline]
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fill a slice with U[lo, hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f64], lo: f64, hi: f64) {
        for v in out.iter_mut() {
            *v = self.uniform_in(lo, hi);
        }
    }

    /// Fill a slice with standard normal samples.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn pcg_is_deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_are_independent() {
        let root = Pcg64::new(7);
        let mut s1 = root.stream(1);
        let mut s2 = root.stream(2);
        let same = (0..64).filter(|_| s1.next_u64() == s2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Pcg64::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = r.uniform();
            s += u;
            s2 += u * u;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(13);
        let n = 200_000;
        let (mut s, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let g = r.normal();
            s += g;
            s2 += g * g;
            s4 += g * g * g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        let kurt = s4 / n as f64 / (var * var);
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!((kurt - 3.0).abs() < 0.1, "kurt={kurt}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
