//! Output-precision assignment criteria (Sec. III-C/D): BGC, truncated
//! BGC, the paper's Minimum Precision Criterion (MPC), and a Lloyd-Max
//! quantizer as the optimality reference.

use super::SignalStats;
use crate::util::stats::db;

/// Eq. (12): bit growth criterion B_y = B_x + B_w + log2(N).
pub fn bgc_bits(bx: u32, bw: u32, n: usize) -> u32 {
    bx + bw + (n as f64).log2().ceil() as u32
}

/// Eq. (13): SQNR_qy under BGC, in dB.
pub fn bgc_sqnr_db(bx: u32, bw: u32, n: usize, w: &SignalStats, x: &SignalStats) -> f64 {
    6.02 * (bx + bw) as f64 + 4.77 - (x.par_db_unsigned() + w.par_db_signed())
        + db(n as f64)
}

/// Standard normal pdf / upper-tail probability.
fn phi(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Upper tail Q(z) = P(Z > z) via Abramowitz-Stegun 7.1.26 erfc approx.
pub fn q_func(z: f64) -> f64 {
    // erfc(x)/2 with x = z/sqrt(2)
    let x = z / std::f64::consts::SQRT_2;
    let sign_neg = x < 0.0;
    let ax = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * ax);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736
                + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    let erfc_half = poly * (-ax * ax).exp() / 2.0;
    if sign_neg {
        1.0 - erfc_half
    } else {
        erfc_half
    }
}

/// Clipping statistics for a Gaussian y_o ~ N(0, sigma^2) clipped at
/// +-(zeta * sigma): (p_c, sigma_cc^2) of eq. (14).
pub fn gaussian_clip_stats(zeta: f64) -> (f64, f64) {
    // p_c = 2 Q(zeta); sigma_cc^2 = E[(|y|-yc)^2 | |y|>yc] in sigma^2 units
    let pc = 2.0 * q_func(zeta);
    if pc <= 0.0 {
        return (0.0, 0.0);
    }
    // For the one-sided tail: E[(y-c)^2 | y>c] with c = zeta (sigma=1):
    // = (1+c^2) - 2c*E[y|y>c] + ... use moments: E[y|y>c] = phi(c)/Q(c),
    // E[y^2|y>c] = 1 + c*phi(c)/Q(c).
    let qc = q_func(zeta);
    let ratio = phi(zeta) / qc;
    let e1 = ratio; // E[y | y > c]
    let e2 = 1.0 + zeta * ratio; // E[y^2 | y > c]
    let sigma_cc2 = e2 - 2.0 * zeta * e1 + zeta * zeta;
    (pc, sigma_cc2)
}

/// Eq. (14): SQNR_qy under MPC with clipping level y_c = zeta * sigma_yo,
/// in dB (Gaussian output assumption).
pub fn mpc_sqnr_db(by: u32, zeta: f64) -> f64 {
    let (pc, sigma_cc2) = gaussian_clip_stats(zeta);
    let sigma_qy2 = zeta * zeta * 4f64.powi(-(by as i32)) / 3.0; // (zeta^2/3) 2^-2By
    6.02 * by as f64 + 4.77 - db(zeta * zeta) - db(1.0 + pc * sigma_cc2 / sigma_qy2)
}

/// The MPC-based SQNR-maximizing clipping level: zeta = 4 (y_c = 4 sigma).
pub const MPC_ZETA: f64 = 4.0;

/// Eq. (15): minimum B_y such that SNR_A - SNR_T <= gamma dB, with
/// y_c = 4 sigma and p_c ~ 1e-3.
pub fn mpc_min_bits(snr_a_db: f64, gamma_db: f64) -> u32 {
    let t = snr_a_db + 7.2 - gamma_db - db(1.0 - 10f64.powf(-gamma_db / 10.0));
    (t / 6.0).ceil().max(1.0) as u32
}

/// Closed-form SNR_T of an analog core at `snr_a_total_db` digitized by
/// a `by`-bit MPC output quantizer (4-sigma clipped uniform levels):
/// eq. (11) composed with eq. (14). This is the per-point accuracy
/// metric of the design-space explorer (`crate::opt`), where B_ADC is a
/// search axis and MPC fixes the conversion range.
pub fn snr_t_with_mpc_adc_db(snr_a_total_db: f64, by: u32) -> f64 {
    crate::snr::snr_t_db(snr_a_total_db, mpc_sqnr_db(by, MPC_ZETA))
}

/// Required digitization SQNR margin: SQNR_qy >= SNR_A + margin ensures
/// SNR_T within gamma of SNR_A (Sec. III-B: margin 9 dB -> gamma 0.5 dB).
pub fn required_sqnr_db(snr_a_db: f64, gamma_db: f64) -> f64 {
    snr_a_db - gamma_db - db(1.0 - 10f64.powf(-gamma_db / 10.0))
}

/// Inverse standard-normal CDF by bisection on `q_func`.
pub fn probit(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0);
    let (mut lo, mut hi) = (-10.0f64, 10.0f64);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if 1.0 - q_func(mid) < p {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Lloyd-Max quantizer for an empirical sample (the paper's optimality
/// note in Sec. III-E): returns (levels, sqnr_db).
pub fn lloyd_max(samples: &[f64], bits: u32, iters: usize) -> (Vec<f64>, f64) {
    let k = 1usize << bits;
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Companding init (Panter-Dite): for an approximately Gaussian
    // source the MSE-optimal level density is ~ pdf^{1/3}, i.e. a
    // Gaussian of width sqrt(3) sigma — levels at sqrt(3) sigma *
    // probit((i+0.5)/k). Lloyd iterations then polish; naive uniform or
    // quantile inits converge far too slowly at 2^8 levels.
    let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
    let sigma = (sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / sorted.len() as f64)
        .sqrt();
    let mut levels: Vec<f64> = (0..k)
        .map(|i| {
            let p = (i as f64 + 0.5) / k as f64;
            mean + 3f64.sqrt() * sigma * probit(p)
        })
        .collect();
    for _ in 0..iters {
        // assignment boundaries are midpoints; accumulate per-cell means
        let mut sums = vec![0.0; k];
        let mut counts = vec![0usize; k];
        let mut cell = 0usize;
        for &x in &sorted {
            while cell + 1 < k && x > 0.5 * (levels[cell] + levels[cell + 1]) {
                cell += 1;
            }
            sums[cell] += x;
            counts[cell] += 1;
        }
        for i in 0..k {
            if counts[i] > 0 {
                levels[i] = sums[i] / counts[i] as f64;
            }
        }
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    // measure SQNR
    let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
    let mut sig = 0.0;
    let mut noise = 0.0;
    let mut cell = 0usize;
    for &x in &sorted {
        while cell + 1 < k && x > 0.5 * (levels[cell] + levels[cell + 1]) {
            cell += 1;
        }
        sig += (x - mean) * (x - mean);
        noise += (x - levels[cell]) * (x - levels[cell]);
    }
    (levels, db(sig / noise))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::adc_signed;
    use crate::util::rng::Pcg64;
    use crate::util::stats::{db, Welford};

    #[test]
    fn q_func_known_values() {
        assert!((q_func(0.0) - 0.5).abs() < 1e-4);
        assert!((q_func(1.0) - 0.1587).abs() < 1e-3);
        assert!((q_func(3.0) - 0.00135).abs() < 2e-4);
        assert!((q_func(-1.0) - 0.8413).abs() < 1e-3);
    }

    #[test]
    fn clip_probability_at_4sigma_below_1e3() {
        let (pc, _) = gaussian_clip_stats(4.0);
        assert!(pc < 1e-3, "{pc}");
        assert!(pc > 1e-5);
    }

    #[test]
    fn bgc_bits_grow_with_n() {
        assert_eq!(bgc_bits(7, 7, 256), 22);
        assert_eq!(bgc_bits(6, 6, 512), 21);
        assert!(bgc_bits(7, 7, 1024) > bgc_bits(7, 7, 128));
    }

    #[test]
    fn mpc_sqnr_maximized_near_zeta_4() {
        // Fig. 4(b): SQNR^MPC at B_y = 8 peaks around zeta = 4.
        let at = |z: f64| mpc_sqnr_db(8, z);
        let peak_region = at(4.0);
        assert!(peak_region > at(1.5), "clipping-dominated side");
        assert!(peak_region > at(7.0), "quantization-dominated side");
        assert!((at(3.5) - peak_region).abs() < 1.5);
        // Paper: MPC at B_y=8, zeta=4 achieves ~40.8 dB (LM = 41.31 is
        // only ~0.5 dB better).
        assert!((peak_region - 40.8).abs() < 1.0, "{peak_region}");
    }

    #[test]
    fn mpc_min_bits_paper_example() {
        // gamma = 0.5 dB => B_y >= (SNR_A + 16.3)/6  (Sec. III-D)
        for snr_a in [20.0, 30.0, 40.0] {
            let b = mpc_min_bits(snr_a, 0.5);
            let expect = ((snr_a + 16.3) / 6.0).ceil() as u32;
            assert_eq!(b, expect);
        }
    }

    #[test]
    fn required_margin_is_9db_for_half_db() {
        let m = required_sqnr_db(30.0, 0.5) - 30.0;
        assert!((m - 9.1).abs() < 0.3, "{m}");
    }

    #[test]
    fn snr_t_with_mpc_adc_is_monotone_and_approaches_snr_a() {
        // eq. (11): SNR_T < SNR_A always, strictly improving in B_y and
        // converging onto SNR_A once SQNR_qy clears the 9 dB margin.
        let snr_a = 21.99;
        let mut prev = f64::MIN;
        for by in 1..=14 {
            let st = snr_t_with_mpc_adc_db(snr_a, by);
            assert!(st < snr_a, "B_y={by}: {st}");
            assert!(st > prev, "monotone in B_y: {prev} -> {st}");
            prev = st;
        }
        assert!(snr_a - snr_t_with_mpc_adc_db(snr_a, 14) < 0.1);
        // within 0.5 dB exactly at the eq. (15) MPC bit count
        let by = mpc_min_bits(snr_a, 0.5);
        assert!(snr_a - snr_t_with_mpc_adc_db(snr_a, by) <= 0.5);
        assert!(snr_a - snr_t_with_mpc_adc_db(snr_a, by - 1) > 0.5);
    }

    #[test]
    fn mpc_beats_bgc_bits_at_fixed_sqnr() {
        // Fig. 4(a): to reach 40 dB, MPC needs 8 bits flat; BGC assigns
        // 16-20 growing with N.
        let w = crate::quant::SignalStats::uniform_signed(1.0);
        let x = crate::quant::SignalStats::uniform_unsigned(1.0);
        assert!(mpc_sqnr_db(8, 4.0) >= 40.0);
        for n in [64usize, 256, 1024, 4096] {
            let bits = bgc_bits(7, 7, n);
            assert!(bits >= 16 && bits <= 26);
            assert!(bgc_sqnr_db(7, 7, n, &w, &x) > 40.0);
        }
    }

    #[test]
    fn mpc_formula_matches_mc_simulation() {
        // Monte-Carlo of clip+quantize on a Gaussian vs eq. (14).
        let mut r = Pcg64::new(9);
        let (by, zeta) = (8u32, 4.0);
        let mut sig = Welford::new();
        let mut noise = Welford::new();
        for _ in 0..300_000 {
            let y = r.normal();
            let yq = adc_signed(y.clamp(-zeta, zeta), zeta, by);
            sig.push(y);
            noise.push(yq - y);
        }
        let meas = db(sig.variance() / noise.variance());
        let pred = mpc_sqnr_db(by, zeta);
        assert!((meas - pred).abs() < 0.6, "meas={meas} pred={pred}");
    }

    #[test]
    fn lloyd_max_beats_uniform_slightly() {
        let mut r = Pcg64::new(10);
        let samples: Vec<f64> = (0..150_000).map(|_| r.normal()).collect();
        let (_, lm_db) = lloyd_max(&samples, 8, 200);
        let mpc_db = mpc_sqnr_db(8, 4.0);
        // LM beats MPC's uniform 4-sigma-clipped quantizer, approaching
        // the Panter-Dite limit for a Gaussian (~43.9 dB at 8 b); the
        // paper quotes a smaller 0.5 dB edge on its (non-ideal) DP
        // output ensemble. Either way MPC gives up only a few dB while
        // keeping uniform levels (Sec. III-E note).
        assert!(lm_db > mpc_db - 0.2, "lm={lm_db} mpc={mpc_db}");
        assert!(lm_db - mpc_db < 4.0, "lm={lm_db} mpc={mpc_db}");
        // Panter-Dite sanity: 2^{2B} * 2/(pi*sqrt(3)) -> ~43.8 dB
        assert!((lm_db - 43.8).abs() < 0.7, "{lm_db}");
    }
}
