//! Quantization preliminaries (Sec. II) and compute-SNR metrics (Sec. III-A).
//!
//! Everything is expressed both as closed-form dB expressions (eqs. 1, 5,
//! 8, 9) and as executable quantizers used by the native Monte-Carlo
//! simulator, so the two can be cross-checked in tests.

pub mod criteria;

use crate::util::stats::db;

/// Signal statistics entering the SQNR expressions: range, second moment
/// and variance. For the paper's defaults: unsigned activations
/// x ~ U[0, x_m) and signed weights w ~ U[-w_m, w_m).
#[derive(Clone, Copy, Debug)]
pub struct SignalStats {
    /// Peak magnitude (x_m or w_m).
    pub peak: f64,
    /// E[s^2].
    pub second_moment: f64,
    /// Var(s).
    pub variance: f64,
}

impl SignalStats {
    /// Unsigned uniform on [0, peak).
    pub fn uniform_unsigned(peak: f64) -> Self {
        Self {
            peak,
            second_moment: peak * peak / 3.0,
            variance: peak * peak / 12.0,
        }
    }

    /// Signed uniform on [-peak, peak).
    pub fn uniform_signed(peak: f64) -> Self {
        Self {
            peak,
            second_moment: peak * peak / 3.0,
            variance: peak * peak / 3.0,
        }
    }

    /// PAR in dB as used in eq. (8): unsigned activations use
    /// x_m^2 / (4 E[x^2]); signed weights use w_m^2 / sigma_w^2.
    pub fn par_db_unsigned(&self) -> f64 {
        db(self.peak * self.peak / (4.0 * self.second_moment))
    }

    pub fn par_db_signed(&self) -> f64 {
        db(self.peak * self.peak / self.variance)
    }
}

/// Quantization step sizes (Sec. II-C): Delta_w = w_m 2^{-(B_w-1)},
/// Delta_x = x_m 2^{-B_x}, Delta_y = y_m 2^{-(B_y-1)}.
pub fn step_signed(peak: f64, bits: u32) -> f64 {
    peak * 2f64.powi(1 - bits as i32)
}

pub fn step_unsigned(peak: f64, bits: u32) -> f64 {
    peak * 2f64.powi(-(bits as i32))
}

/// Eq. (1): SQNR_x(dB) = 6 B_x + 4.78 - PAR(dB).
pub fn sqnr_db_eq1(bits: u32, par_db: f64) -> f64 {
    6.02 * bits as f64 + 4.77 - par_db
}

/// DP signal variance (eq. 5): sigma_yo^2 = N sigma_w^2 E[x^2].
pub fn dp_signal_variance(n: usize, w: &SignalStats, x: &SignalStats) -> f64 {
    n as f64 * w.variance * x.second_moment
}

/// Output-referred input-quantization noise variance (eq. 5):
/// sigma_qiy^2 = (N/12)(Delta_w^2 E[x^2] + Delta_x^2 sigma_w^2).
pub fn qiy_variance(
    n: usize,
    bw: u32,
    bx: u32,
    w: &SignalStats,
    x: &SignalStats,
) -> f64 {
    let dw = step_signed(w.peak, bw);
    let dx = step_unsigned(x.peak, bx);
    n as f64 / 12.0 * (dw * dw * x.second_moment + dx * dx * w.variance)
}

/// Eq. (8): output-referred SQNR due to input quantization, in dB.
pub fn sqnr_qiy_db(n: usize, bw: u32, bx: u32, w: &SignalStats, x: &SignalStats) -> f64 {
    db(dp_signal_variance(n, w, x) / qiy_variance(n, bw, bx, w, x))
}

/// Eq. (9): digitization SQNR for a B_y-bit output quantizer over the full
/// range y_m = N x_m w_m, in dB:
/// 6 B_y + 4.8 - [zeta_x + zeta_w](dB) - 10 log10(N).
pub fn sqnr_qy_db(n: usize, by: u32, w: &SignalStats, x: &SignalStats) -> f64 {
    sqnr_db_eq1(by, w.par_db_signed() + x.par_db_unsigned() + db(n as f64))
}

/// Executable round-to-nearest quantizers (match python/compile/model.py).
pub fn quantize_unsigned(x: f64, peak: f64, bits: u32) -> f64 {
    let s = 2f64.powi(bits as i32) / peak;
    ((x * s + 0.5).floor().clamp(0.0, 2f64.powi(bits as i32) - 1.0)) / s
}

pub fn quantize_signed(w: f64, peak: f64, bits: u32) -> f64 {
    // Two's complement Q1.(bits-1) code, round-to-nearest.
    let half = 2f64.powi(bits as i32 - 1);
    let t = ((w / peak + 1.0) * half + 0.5)
        .floor()
        .clamp(0.0, 2.0 * half - 1.0);
    (t / half - 1.0) * peak
}

/// Sign-magnitude quantizer used by CM.
pub fn quantize_sign_mag(w: f64, peak: f64, bits: u32) -> f64 {
    let half = 2f64.powi(bits as i32 - 1);
    let t = ((w.abs() / peak) * half + 0.5).floor().min(half - 1.0);
    w.signum() * t / half * peak
}

/// Mid-tread uniform ADC over [0, range] with 2^bits levels.
pub fn adc_unsigned(v: f64, range: f64, bits: u32) -> f64 {
    let delta = range / 2f64.powi(bits as i32);
    let code = (v / delta).round().clamp(0.0, 2f64.powi(bits as i32) - 1.0);
    code * delta
}

/// Mid-tread uniform ADC over [-range, range] with 2^bits levels.
pub fn adc_signed(v: f64, range: f64, bits: u32) -> f64 {
    let delta = 2.0 * range / 2f64.powi(bits as i32);
    let half = 2f64.powi(bits as i32 - 1);
    let code = (v / delta).round().clamp(-half, half - 1.0);
    code * delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::util::stats::Welford;

    fn default_w() -> SignalStats {
        SignalStats::uniform_signed(1.0)
    }

    fn default_x() -> SignalStats {
        SignalStats::uniform_unsigned(1.0)
    }

    #[test]
    fn paper_par_values() {
        // Sec. III-E: zeta_x = -1.3 dB (unsigned uniform), zeta_w = 4.8 dB.
        assert!((default_x().par_db_unsigned() - (-1.25)).abs() < 0.1);
        assert!((default_w().par_db_signed() - 4.77).abs() < 0.1);
    }

    #[test]
    fn paper_sqnr_qiy_41db_at_7b() {
        // Sec. III-E: B_x = B_w = 7 gives SQNR_qiy = 41 dB.
        let v = sqnr_qiy_db(256, 7, 7, &default_w(), &default_x());
        assert!((v - 41.0).abs() < 0.5, "{v}");
    }

    #[test]
    fn sqnr_qiy_at_6b_matches_eq8_exactly() {
        // Eq. (8) at B_x = B_w = 6 with uniform signals gives 35.2 dB.
        // (The paper's Sec. V-A quotes 38.9 dB for this point, which is
        // inconsistent with its own eq. (8) — the 41 dB value quoted for
        // B_x = B_w = 7 in Sec. III-E *does* match eq. (8), and 35.2 =
        // 41.2 - 6.02. We pin the equation; see EXPERIMENTS.md
        // §Deviations.)
        let v = sqnr_qiy_db(512, 6, 6, &default_w(), &default_x());
        assert!((v - 35.2).abs() < 0.5, "{v}");
        let v7 = sqnr_qiy_db(512, 7, 7, &default_w(), &default_x());
        assert!((v7 - v - 6.02).abs() < 0.05);
    }

    #[test]
    fn sqnr_qiy_independent_of_n() {
        let a = sqnr_qiy_db(16, 6, 6, &default_w(), &default_x());
        let b = sqnr_qiy_db(1024, 6, 6, &default_w(), &default_x());
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn sqnr_qy_drops_3db_per_doubling_n() {
        let a = sqnr_qy_db(128, 8, &default_w(), &default_x());
        let b = sqnr_qy_db(256, 8, &default_w(), &default_x());
        assert!((a - b - 3.0).abs() < 0.05, "{a} {b}");
    }

    #[test]
    fn six_db_per_bit() {
        let a = sqnr_qy_db(128, 8, &default_w(), &default_x());
        let b = sqnr_qy_db(128, 9, &default_w(), &default_x());
        assert!((b - a - 6.02).abs() < 0.01);
    }

    #[test]
    fn quantizers_bound_error() {
        let mut r = Pcg64::new(1);
        for _ in 0..10_000 {
            let x = r.uniform();
            let q = quantize_unsigned(x, 1.0, 6);
            assert!((x - q).abs() <= 2f64.powi(-6) + 1e-12);
            let w = r.uniform_in(-1.0, 1.0);
            let qs = quantize_signed(w, 1.0, 6);
            assert!((w - qs).abs() <= 2f64.powi(-5) + 1e-12);
            let qm = quantize_sign_mag(w, 1.0, 6);
            assert!((w - qm).abs() <= 2f64.powi(-5) + 1e-12);
            assert!(qm == 0.0 || qm.signum() == w.signum());
        }
    }

    #[test]
    fn mc_sqnr_matches_eq1() {
        // Monte-Carlo SQNR of the executable signed quantizer vs eq. (1)
        // (eq. 1's step convention Delta = x_m 2^{-(B-1)} is the signed
        // two's-complement one).
        let mut r = Pcg64::new(2);
        let mut sig = Welford::new();
        let mut noise = Welford::new();
        for _ in 0..400_000 {
            let w = r.uniform_in(-1.0, 1.0);
            sig.push(w);
            noise.push(w - quantize_signed(w, 1.0, 7));
        }
        let meas = db(sig.variance() / noise.variance());
        let pred = sqnr_db_eq1(7, default_w().par_db_signed());
        assert!((meas - pred).abs() < 0.3, "meas={meas} pred={pred}");
    }

    #[test]
    fn qiy_variance_matches_mc() {
        let (n, bw, bx) = (64usize, 5u32, 5u32);
        let w_s = default_w();
        let x_s = default_x();
        let pred = qiy_variance(n, bw, bx, &w_s, &x_s);
        let mut r = Pcg64::new(3);
        let mut noise = Welford::new();
        for _ in 0..20_000 {
            let mut err = 0.0;
            for _ in 0..n {
                let x = r.uniform();
                let w = r.uniform_in(-1.0, 1.0);
                let yq = quantize_signed(w, 1.0, bw) * quantize_unsigned(x, 1.0, bx);
                err += yq - w * x;
            }
            noise.push(err);
        }
        let ratio = noise.variance() / pred;
        assert!((0.8..1.25).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn adc_mid_tread_behaviour() {
        assert_eq!(adc_unsigned(0.0, 1.0, 4), 0.0);
        assert!((adc_unsigned(0.52, 1.0, 4) - 0.5).abs() < 0.04);
        assert_eq!(adc_signed(0.0, 1.0, 4), 0.0);
        let top = adc_unsigned(2.0, 1.0, 4);
        assert!(top <= 1.0); // clips at full scale
        assert!(adc_signed(-2.0, 1.0, 4) >= -1.0);
    }
}
