//! The unified sweep engine: declarative grids, a content-addressed
//! result cache, and the cached execution front-end shared by every
//! figure driver, `imclim sweep`, and the benches.
//!
//! Layering: `spec` builds grids of labelled operating points, the
//! [`Engine`] partitions them into cache hits and misses, the misses run
//! through the lock-free `coordinator::scheduler` worker pool, and fresh
//! results are persisted by `cache` so the next invocation — same figure
//! re-run, an overlapping CLI sweep, a different driver touching the
//! same physical operating point — computes nothing twice. `report`
//! holds the CSV/summary emission patterns the drivers share.
//!
//! ```text
//!   SweepSpec ──> Vec<SweepPoint> ──> Engine::run ──┬─ hits:   ResultCache
//!                                                   └─ misses: run_sweep()
//!                                                              └──> ResultCache::store
//! ```
//!
//! Results keep their submission order, and a cache hit is bit-identical
//! to the run that produced it, so a warm re-run of any driver is
//! byte-identical to a cold one.

pub mod cache;
pub mod report;
pub mod spec;

pub use cache::{cache_key, ResultCache};
pub use report::{BoundReport, EsReport};
pub use spec::{
    parse_grid_f64, parse_grid_u32, parse_grid_usize, Axis, AxisValue, GridPoint, SweepSpec,
};

use std::path::PathBuf;

use crate::coordinator::{run_sweep, Backend, SweepOptions, SweepPoint, SweepResult};

/// What one [`Engine::run_with_stats`] call did.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    /// Points served from the result cache (no Monte-Carlo executed).
    pub hits: usize,
    /// Points computed this run (and, on success, newly cached).
    pub misses: usize,
    /// Computed points that ended in error (never cached).
    pub errors: usize,
}

/// Cached sweep executor: the one entry point every consumer drives.
pub struct Engine {
    backend: Backend,
    opts: SweepOptions,
    cache: Option<ResultCache>,
}

impl Engine {
    pub fn new(backend: Backend, opts: SweepOptions) -> Self {
        Self {
            backend,
            opts,
            cache: None,
        }
    }

    /// Enable the content-addressed result cache rooted at `dir`.
    pub fn with_cache(mut self, dir: impl Into<PathBuf>) -> Self {
        let backend_id = self.backend.cache_id();
        self.cache = Some(ResultCache::new(dir, backend_id));
        self
    }

    pub fn cache(&self) -> Option<&ResultCache> {
        self.cache.as_ref()
    }

    /// Run all points (cache-aware); results are ordered like the input.
    pub fn run(&self, points: Vec<SweepPoint>) -> Vec<SweepResult> {
        self.run_with_stats(points).0
    }

    /// Like [`Engine::run`], also reporting hit/miss/error counts.
    pub fn run_with_stats(&self, points: Vec<SweepPoint>) -> (Vec<SweepResult>, RunStats) {
        let mut stats = RunStats::default();
        let Some(cache) = &self.cache else {
            let results = run_sweep(points, self.backend.clone(), self.opts);
            stats.misses = results.len();
            stats.errors = results.iter().filter(|r| r.error.is_some()).count();
            return (results, stats);
        };

        let n = points.len();
        let mut slots: Vec<Option<SweepResult>> = vec![None; n];
        let mut miss_idx: Vec<usize> = Vec::new();
        for (i, point) in points.iter().enumerate() {
            if let Some(measured) = cache.load(point) {
                slots[i] = Some(SweepResult {
                    id: point.id.clone(),
                    index: i,
                    measured,
                    error: None,
                    cached: true,
                });
                stats.hits += 1;
            } else {
                miss_idx.push(i);
            }
        }

        let miss_points: Vec<SweepPoint> = miss_idx.iter().map(|&i| points[i].clone()).collect();
        let computed = run_sweep(miss_points, self.backend.clone(), self.opts);
        stats.misses = computed.len();
        let mut manifest: Vec<(String, String)> = Vec::new();
        for (j, mut result) in computed.into_iter().enumerate() {
            let i = miss_idx[j];
            if result.error.is_none() {
                let point = &points[i];
                if cache.store(point, &result.measured).is_ok() {
                    manifest.push((cache.key(point), point.id.clone()));
                }
            } else {
                stats.errors += 1;
            }
            result.index = i;
            slots[i] = Some(result);
        }
        let _ = cache.update_manifest(&manifest);

        let results = slots
            .into_iter()
            .map(|r| r.expect("every point produces a result"))
            .collect();
        (results, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pvec;
    use crate::mc::ArchKind;

    fn qs_point(id: &str, n: usize, seed: u64) -> SweepPoint {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = n as f64;
        p[pvec::IDX_BX] = 4.0;
        p[pvec::IDX_BW] = 4.0;
        p[pvec::IDX_B_ADC] = 8.0;
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 40.0;
        p[pvec::QS_IDX_V_C] = 40.0;
        SweepPoint::new(id, ArchKind::Qs, p)
            .with_trials(64)
            .with_seed(seed)
    }

    #[test]
    fn cacheless_engine_is_a_passthrough() {
        let engine = Engine::new(
            Backend::Native,
            SweepOptions {
                workers: 2,
                verbose: false,
            },
        );
        let points: Vec<SweepPoint> = (0..4).map(|i| qs_point(&format!("p{i}"), 16, i)).collect();
        let (results, stats) = engine.run_with_stats(points);
        assert_eq!(results.len(), 4);
        assert_eq!(stats.hits, 0);
        assert_eq!(stats.misses, 4);
        assert_eq!(stats.errors, 0);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            assert!(!r.cached);
        }
    }

    #[test]
    fn identical_content_under_different_labels_shares_one_record() {
        let dir = std::env::temp_dir().join("imclim-engine-unit-dedupe");
        let _ = std::fs::remove_dir_all(&dir);
        let engine = Engine::new(
            Backend::Native,
            SweepOptions {
                workers: 2,
                verbose: false,
            },
        )
        .with_cache(dir);
        // same physics, different labels: first run computes both misses,
        // second run serves both from the single shared record.
        let mk = || vec![qs_point("label/a", 24, 5), qs_point("label/b", 24, 5)];
        let (first, s1) = engine.run_with_stats(mk());
        assert_eq!(s1.misses, 2);
        let (second, s2) = engine.run_with_stats(mk());
        assert_eq!(s2.hits, 2);
        assert_eq!(s2.misses, 0);
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.measured.snr_t_db.to_bits(),
                b.measured.snr_t_db.to_bits()
            );
        }
    }
}
