//! The unified sweep engine: declarative grids, a content-addressed
//! result cache, and the cached execution front-end shared by every
//! figure driver, `imclim sweep`, and the benches.
//!
//! Layering: `spec` builds grids of labelled operating points, the
//! [`Engine`] partitions them into cache hits and misses, the misses run
//! through the lock-free `coordinator::scheduler` worker pool, and fresh
//! results are persisted by `cache` so the next invocation — same figure
//! re-run, an overlapping CLI sweep, a different driver touching the
//! same physical operating point — computes nothing twice. `report`
//! holds the CSV/summary emission patterns the drivers share.
//!
//! ```text
//!   SweepSpec ──> Vec<SweepPoint> ──> Engine::run ──┬─ hits:   ResultCache
//!                                                   └─ misses: run_sweep()
//!                                                              └──> ResultCache::store
//! ```
//!
//! Results keep their submission order, and a cache hit is bit-identical
//! to the run that produced it, so a warm re-run of any driver is
//! byte-identical to a cold one.

pub mod cache;
pub mod report;
pub mod spec;

pub use cache::{
    cache_key, gc, list_record_files, manifest_backend, manifest_labels, memo_key,
    merge_cache_dirs, scan_records, GcOptions, GcReport, MergeReport, RecordInfo, ResultCache,
    MANIFEST_FILE,
};
pub use report::{BoundReport, EsReport};
pub use spec::{
    parse_grid_f64, parse_grid_u32, parse_grid_usize, parse_shard, Axis, AxisValue, GridPoint,
    SweepSpec,
};

use std::cell::RefCell;
use std::path::PathBuf;

use crate::coordinator::{run_sweep, Backend, SweepOptions, SweepPoint, SweepResult};

/// What one [`Engine::run_with_stats`] call did.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    /// Points served without running Monte-Carlo: pre-existing cache
    /// records, plus in-run duplicates of a just-computed point (same
    /// content key under a different label).
    pub hits: usize,
    /// Unique points computed this run (and, on success, newly cached).
    pub misses: usize,
    /// Points whose computation ended in error (never cached).
    pub errors: usize,
}

/// Cached sweep executor: the one entry point every consumer drives.
pub struct Engine {
    backend: Backend,
    opts: SweepOptions,
    cache: Option<ResultCache>,
    /// Manifest entries for memo records, batched into one
    /// `manifest.json` rewrite (see [`Engine::flush_manifest`]) instead
    /// of one rewrite per [`Engine::memo`] call.
    pending_manifest: RefCell<Vec<(String, String)>>,
}

impl Engine {
    pub fn new(backend: Backend, opts: SweepOptions) -> Self {
        Self {
            backend,
            opts,
            cache: None,
            pending_manifest: RefCell::new(Vec::new()),
        }
    }

    /// Enable the content-addressed result cache rooted at `dir`.
    pub fn with_cache(mut self, dir: impl Into<PathBuf>) -> Self {
        let backend_id = self.backend.cache_id();
        self.cache = Some(ResultCache::new(dir, backend_id));
        self
    }

    pub fn cache(&self) -> Option<&ResultCache> {
        self.cache.as_ref()
    }

    /// Run all points (cache-aware); results are ordered like the input.
    pub fn run(&self, points: Vec<SweepPoint>) -> Vec<SweepResult> {
        self.run_with_stats(points).0
    }

    /// Like [`Engine::run`], also reporting hit/miss/error counts.
    pub fn run_with_stats(&self, points: Vec<SweepPoint>) -> (Vec<SweepResult>, RunStats) {
        let mut stats = RunStats::default();
        let Some(cache) = &self.cache else {
            let results = run_sweep(points, self.backend.clone(), self.opts);
            stats.misses = results.len();
            stats.errors = results.iter().filter(|r| r.error.is_some()).count();
            record_metrics(&stats);
            return (results, stats);
        };

        let n = points.len();
        let mut slots: Vec<Option<SweepResult>> = vec![None; n];
        let mut miss_idx: Vec<usize> = Vec::new();
        {
            let _span =
                crate::obs::trace::span_with("cache_probe", "engine", || format!("{n} points"));
            for (i, point) in points.iter().enumerate() {
                if let Some(measured) = cache.load(point) {
                    slots[i] = Some(SweepResult {
                        id: point.id.clone(),
                        index: i,
                        measured,
                        error: None,
                        cached: true,
                    });
                    stats.hits += 1;
                } else {
                    miss_idx.push(i);
                }
            }
        }

        // group misses by content key: identical-content points reached
        // under different labels (e.g. a cross-grid axis that one arch
        // ignores) compute once and share the result
        let mut rep_of_key: std::collections::BTreeMap<String, usize> =
            std::collections::BTreeMap::new();
        let mut rep_point_idx: Vec<usize> = Vec::new(); // rep -> index into `points`
        let mut rep_for_miss: Vec<usize> = Vec::with_capacity(miss_idx.len());
        for &i in &miss_idx {
            let key = cache.key(&points[i]);
            let rep = *rep_of_key.entry(key).or_insert_with(|| {
                rep_point_idx.push(i);
                rep_point_idx.len() - 1
            });
            rep_for_miss.push(rep);
        }
        let rep_points: Vec<SweepPoint> =
            rep_point_idx.iter().map(|&i| points[i].clone()).collect();
        let computed = run_sweep(rep_points, self.backend.clone(), self.opts);
        stats.misses = computed.len();

        let mut manifest: Vec<(String, String)> = Vec::new();
        for (r, result) in computed.iter().enumerate() {
            if result.error.is_none() {
                let point = &points[rep_point_idx[r]];
                if cache.store(point, &result.measured).is_ok() {
                    manifest.push((cache.key(point), point.id.clone()));
                }
            }
        }
        // fan the computed results out to every miss slot; duplicates of
        // a representative count as hits on the freshly-stored record
        for (j, &i) in miss_idx.iter().enumerate() {
            let src = &computed[rep_for_miss[j]];
            let duplicate = rep_point_idx[rep_for_miss[j]] != i;
            if src.error.is_some() {
                stats.errors += 1;
            } else if duplicate {
                stats.hits += 1;
            }
            slots[i] = Some(SweepResult {
                id: points[i].id.clone(),
                index: i,
                measured: src.measured,
                error: src.error.clone(),
                cached: duplicate && src.error.is_none(),
            });
        }
        let _ = cache.update_manifest(&manifest);

        let results = slots
            .into_iter()
            .map(|r| r.expect("every point produces a result"))
            .collect();
        record_metrics(&stats);
        (results, stats)
    }

    /// Serve a bespoke Monte-Carlo quantity through the result cache:
    /// returns the values for `(tag, params)` and whether they were a
    /// cache hit (in which case `f` was never called). This is how the
    /// fig2/fig4 drivers — whose measurements are not per-`SweepPoint`
    /// ensembles — share the engine's content-addressed cache; `label`
    /// only feeds the human-readable manifest.
    pub fn memo(
        &self,
        tag: &str,
        params: &[f64],
        label: &str,
        f: impl FnOnce() -> Vec<f64>,
    ) -> (Vec<f64>, bool) {
        let Some(cache) = &self.cache else {
            return (f(), false);
        };
        if let Some(values) = cache.load_memo(tag, params) {
            return (values, true);
        }
        let values = f();
        if cache.store_memo(tag, params, &values).is_ok() {
            self.pending_manifest
                .borrow_mut()
                .push((cache::memo_key(tag, params), label.to_string()));
        }
        (values, false)
    }

    /// Overwrite the memo record for `(tag, params)` with freshly
    /// computed values — the repair path for a record that decoded but
    /// failed the caller's shape validation, so the next run is a true
    /// cache hit again instead of a perpetual recompute.
    pub fn memo_repair(&self, tag: &str, params: &[f64], label: &str, values: &[f64]) {
        let Some(cache) = &self.cache else {
            return;
        };
        if cache.store_memo(tag, params, values).is_ok() {
            self.pending_manifest
                .borrow_mut()
                .push((cache::memo_key(tag, params), label.to_string()));
        }
    }

    /// Write the batched memo manifest entries out (one `manifest.json`
    /// rewrite for any number of `memo` misses). Also runs on drop, so
    /// drivers that create an engine per run never need to call this.
    pub fn flush_manifest(&self) {
        let Some(cache) = &self.cache else {
            return;
        };
        let pending = std::mem::take(&mut *self.pending_manifest.borrow_mut());
        let _ = cache.update_manifest(&pending);
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.flush_manifest();
    }
}

/// Feed one run's hit/miss/error counts into the process-wide
/// [`coordinator::metrics`] counters (the daemon's `/stats` surface).
/// Trials-completed is counted at the scheduler, which knows actual
/// ensemble sizes.
///
/// [`coordinator::metrics`]: crate::coordinator::metrics
fn record_metrics(stats: &RunStats) {
    use crate::coordinator::metrics;
    metrics::add_cache_hits(stats.hits as u64);
    metrics::add_cache_misses(stats.misses as u64);
    metrics::add_points_computed(stats.misses as u64);
    metrics::add_mc_errors(stats.errors as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pvec;
    use crate::mc::ArchKind;

    fn qs_point(id: &str, n: usize, seed: u64) -> SweepPoint {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = n as f64;
        p[pvec::IDX_BX] = 4.0;
        p[pvec::IDX_BW] = 4.0;
        p[pvec::IDX_B_ADC] = 8.0;
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 40.0;
        p[pvec::QS_IDX_V_C] = 40.0;
        SweepPoint::new(id, ArchKind::Qs, p)
            .with_trials(64)
            .with_seed(seed)
    }

    #[test]
    fn cacheless_engine_is_a_passthrough() {
        let engine = Engine::new(
            Backend::Native,
            SweepOptions {
                workers: 2,
                verbose: false,
            },
        );
        let points: Vec<SweepPoint> = (0..4).map(|i| qs_point(&format!("p{i}"), 16, i)).collect();
        let (results, stats) = engine.run_with_stats(points);
        assert_eq!(results.len(), 4);
        assert_eq!(stats.hits, 0);
        assert_eq!(stats.misses, 4);
        assert_eq!(stats.errors, 0);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            assert!(!r.cached);
        }
    }

    #[test]
    fn memo_calls_f_once_then_serves_hits() {
        let dir = std::env::temp_dir().join("imclim-engine-unit-memo");
        let _ = std::fs::remove_dir_all(&dir);
        let opts = SweepOptions {
            workers: 1,
            verbose: false,
        };
        let engine = Engine::new(Backend::Native, opts).with_cache(&dir);
        let mut calls = 0;
        let (v1, hit1) = engine.memo("t/x", &[1.0, 2.0], "label/a", || {
            calls += 1;
            vec![3.25]
        });
        assert!(!hit1);
        assert_eq!(v1, vec![3.25]);
        let (v2, hit2) = engine.memo("t/x", &[1.0, 2.0], "label/a", || {
            calls += 1;
            vec![999.0]
        });
        assert!(hit2, "second lookup is a cache hit");
        assert_eq!(v2[0].to_bits(), 3.25f64.to_bits());
        assert_eq!(calls, 1, "the compute closure ran exactly once");
        // the batched manifest entry lands when the engine goes away
        drop(engine);
        let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        assert!(manifest.contains("label/a"), "memo label in manifest");
        // cacheless engines just pass through
        let bare = Engine::new(Backend::Native, opts);
        let (v3, hit3) = bare.memo("t/x", &[1.0, 2.0], "label/a", || vec![7.0]);
        assert!(!hit3);
        assert_eq!(v3, vec![7.0]);
    }

    #[test]
    fn identical_content_under_different_labels_shares_one_record() {
        let dir = std::env::temp_dir().join("imclim-engine-unit-dedupe");
        let _ = std::fs::remove_dir_all(&dir);
        let engine = Engine::new(
            Backend::Native,
            SweepOptions {
                workers: 2,
                verbose: false,
            },
        )
        .with_cache(dir);
        // same physics, different labels: the first run computes the
        // shared content once (the duplicate is a same-run hit), the
        // second run serves both from the single shared record.
        let mk = || vec![qs_point("label/a", 24, 5), qs_point("label/b", 24, 5)];
        let (first, s1) = engine.run_with_stats(mk());
        assert_eq!(s1.misses, 1, "identical content computes once");
        assert_eq!(s1.hits, 1, "the duplicate is served, not recomputed");
        assert!(first[1].cached, "duplicate flagged as cached");
        let (second, s2) = engine.run_with_stats(mk());
        assert_eq!(s2.hits, 2);
        assert_eq!(s2.misses, 0);
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.measured.snr_t_db.to_bits(),
                b.measured.snr_t_db.to_bits()
            );
        }
    }
}
