//! Declarative sweep grids: named axes, cartesian products, generated
//! point ids.
//!
//! A [`SweepSpec`] is a list of named axes; [`SweepSpec::points`] emits
//! the row-major cartesian product (first axis slowest), each point
//! carrying a generated id of the form `name/axis1=v1/axis2=v2/...` —
//! the exact label scheme the figure drivers used to hand-format, e.g.
//! `fig9a/vwl=0.8/n=128`. An axis may span several dimensions that vary
//! together ([`SweepSpec::axis_tuples`]), which models paired
//! configurations such as Fig. 9(b)'s `(V_WL, N)` operating points.
//!
//! The module also provides the grid-string parsers behind the
//! `imclim sweep` CLI: `"a,b,c"` lists and `"lo:hi[:step]"` inclusive
//! ranges.

use std::fmt;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, ensure, Result};

/// One value along a grid dimension.
#[derive(Clone, Debug, PartialEq)]
pub enum AxisValue {
    Num(f64),
    Int(i64),
    Str(String),
}

impl fmt::Display for AxisValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AxisValue::Num(v) => write!(f, "{v}"),
            AxisValue::Int(v) => write!(f, "{v}"),
            AxisValue::Str(s) => f.write_str(s),
        }
    }
}

/// One axis of a sweep grid: one or more named dimensions whose values
/// vary together (a plain axis has exactly one dimension).
#[derive(Clone, Debug)]
pub struct Axis {
    pub names: Vec<String>,
    /// Each entry is one tuple of values, aligned with `names`.
    pub values: Vec<Vec<AxisValue>>,
}

/// A declarative sweep grid, optionally restricted to one shard of a
/// k-way round-robin partition (see [`SweepSpec::shard`]).
#[derive(Clone, Debug, Default)]
pub struct SweepSpec {
    /// Id prefix for every generated point (e.g. `"fig9a"`).
    pub name: String,
    pub axes: Vec<Axis>,
    /// `Some((index, count))` keeps only points whose global row-major
    /// index ≡ index (mod count).
    shard: Option<(usize, usize)>,
}

impl SweepSpec {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            axes: Vec::new(),
            shard: None,
        }
    }

    fn push_single(mut self, name: &str, values: Vec<AxisValue>) -> Self {
        self.axes.push(Axis {
            names: vec![name.to_string()],
            values: values.into_iter().map(|v| vec![v]).collect(),
        });
        self
    }

    pub fn axis_f64(self, name: &str, values: &[f64]) -> Self {
        self.push_single(name, values.iter().map(|&v| AxisValue::Num(v)).collect())
    }

    pub fn axis_usize(self, name: &str, values: &[usize]) -> Self {
        self.push_single(
            name,
            values.iter().map(|&v| AxisValue::Int(v as i64)).collect(),
        )
    }

    pub fn axis_u32(self, name: &str, values: &[u32]) -> Self {
        self.push_single(
            name,
            values.iter().map(|&v| AxisValue::Int(v as i64)).collect(),
        )
    }

    pub fn axis_strs(self, name: &str, values: &[&str]) -> Self {
        self.push_single(
            name,
            values.iter().map(|v| AxisValue::Str(v.to_string())).collect(),
        )
    }

    /// A multi-dimension axis: the named dimensions vary *together*, one
    /// tuple per grid step (e.g. paired `(v_wl, n)` configurations).
    pub fn axis_tuples(mut self, names: &[&str], values: Vec<Vec<AxisValue>>) -> Self {
        for v in &values {
            assert_eq!(
                v.len(),
                names.len(),
                "axis tuple arity {} != {} names",
                v.len(),
                names.len()
            );
        }
        self.axes.push(Axis {
            names: names.iter().map(|s| s.to_string()).collect(),
            values,
        });
        self
    }

    /// Restrict this spec to shard `index` of a `count`-way round-robin
    /// partition of the full grid: the shard keeps exactly the points
    /// whose global row-major index ≡ `index` (mod `count`). Point ids
    /// (and therefore result-cache keys) are identical to the unsharded
    /// grid's, so shard caches stay content-address-compatible and can
    /// be merged by plain file union. The k shards of a grid are
    /// pairwise disjoint and their union is the full grid.
    pub fn shard(mut self, index: usize, count: usize) -> Result<Self> {
        ensure!(count >= 1, "shard count must be >= 1, got {count}");
        ensure!(
            index < count,
            "shard index {index} out of range for {count} shards"
        );
        ensure!(
            self.shard.is_none(),
            "spec is already sharded; shard the full grid instead"
        );
        self.shard = Some((index, count));
        Ok(self)
    }

    /// The active `(index, count)` shard restriction, if any.
    pub fn shard_params(&self) -> Option<(usize, usize)> {
        self.shard
    }

    /// Number of grid points in the full cartesian product (ignoring any
    /// shard restriction; 1 with no axes).
    pub fn full_len(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product()
    }

    /// Number of grid points this spec emits (shard-aware).
    pub fn len(&self) -> usize {
        let total = self.full_len();
        match self.shard {
            None => total,
            Some((i, k)) if total > i => (total - i).div_ceil(k),
            Some(_) => 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Row-major cartesian product: first axis slowest, last fastest.
    /// With a shard restriction, only that shard's points are emitted
    /// (ids unchanged from the full grid).
    pub fn points(&self) -> Vec<GridPoint> {
        if self.axes.iter().any(|a| a.values.is_empty()) {
            return Vec::new();
        }
        let (shard_index, shard_count) = self.shard.unwrap_or((0, 1));
        let mut out = Vec::with_capacity(self.len());
        let mut idx = vec![0usize; self.axes.len()];
        let mut global = 0usize;
        loop {
            if global % shard_count == shard_index {
                let mut values = Vec::new();
                let mut id = self.name.clone();
                for (axis, &i) in self.axes.iter().zip(&idx) {
                    for (name, value) in axis.names.iter().zip(&axis.values[i]) {
                        id.push('/');
                        id.push_str(name);
                        id.push('=');
                        let _ = write!(id, "{value}");
                        values.push(value.clone());
                    }
                }
                out.push(GridPoint { id, values });
            }
            global += 1;
            // odometer increment, last axis fastest
            let mut k = self.axes.len();
            loop {
                if k == 0 {
                    return out;
                }
                k -= 1;
                idx[k] += 1;
                if idx[k] < self.axes[k].values.len() {
                    break;
                }
                idx[k] = 0;
            }
        }
    }
}

/// Parse a `--shard i/k` argument: shard index `i` of `k` total shards.
pub fn parse_shard(s: &str) -> Result<(usize, usize)> {
    let (i, k) = s
        .split_once('/')
        .ok_or_else(|| anyhow!("bad shard '{s}' (want i/k, e.g. 0/4)"))?;
    let i = i
        .trim()
        .parse::<usize>()
        .map_err(|_| anyhow!("bad shard index '{i}'"))?;
    let k = k
        .trim()
        .parse::<usize>()
        .map_err(|_| anyhow!("bad shard count '{k}'"))?;
    ensure!(k >= 1, "shard count must be >= 1, got {k}");
    ensure!(i < k, "shard index {i} out of range for {k} shards");
    Ok((i, k))
}

/// One generated grid point: its id and the flattened dimension values
/// (in axis order, tuples expanded in place).
#[derive(Clone, Debug)]
pub struct GridPoint {
    pub id: String,
    pub values: Vec<AxisValue>,
}

impl GridPoint {
    /// Numeric value of dimension `dim` (accepts `Num` and `Int`).
    pub fn num(&self, dim: usize) -> f64 {
        match &self.values[dim] {
            AxisValue::Num(v) => *v,
            AxisValue::Int(v) => *v as f64,
            AxisValue::Str(s) => panic!("grid dim {dim} is '{s}', not numeric"),
        }
    }

    /// Integer value of dimension `dim`.
    pub fn int(&self, dim: usize) -> i64 {
        match &self.values[dim] {
            AxisValue::Int(v) => *v,
            other => panic!("grid dim {dim} is {other:?}, not an integer"),
        }
    }

    /// String value of dimension `dim`.
    pub fn text(&self, dim: usize) -> &str {
        match &self.values[dim] {
            AxisValue::Str(s) => s,
            other => panic!("grid dim {dim} is {other:?}, not a string"),
        }
    }
}

// ---------------------------------------------------------------------
// CLI grid-string parsing: "a,b,c" lists and "lo:hi[:step]" ranges.
// ---------------------------------------------------------------------

fn parse_f64_token(token: &str) -> Result<f64> {
    token
        .parse::<f64>()
        .map_err(|_| anyhow!("bad number '{token}'"))
}

fn parse_usize_token(token: &str) -> Result<usize> {
    token
        .parse::<usize>()
        .map_err(|_| anyhow!("bad integer '{token}'"))
}

/// Parse a float grid: `"0.6,0.8"`, `"0.5:0.8:0.1"` (inclusive). A
/// step-less range uses step 1; a sub-unit range like `"0.6:0.8"` is
/// rejected rather than silently collapsing to its lower bound.
pub fn parse_grid_f64(grid: &str) -> Result<Vec<f64>> {
    let mut out = Vec::new();
    for part in grid.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let fields: Vec<&str> = part.split(':').collect();
        match fields.as_slice() {
            [v] => out.push(parse_f64_token(v)?),
            [lo, hi] => {
                let (lo, hi) = (parse_f64_token(lo)?, parse_f64_token(hi)?);
                ensure!(
                    hi <= lo || hi - lo >= 1.0,
                    "range {lo}:{hi} needs an explicit step (lo:hi:step)"
                );
                push_f64_range(&mut out, lo, hi, 1.0)?
            }
            [lo, hi, step] => push_f64_range(
                &mut out,
                parse_f64_token(lo)?,
                parse_f64_token(hi)?,
                parse_f64_token(step)?,
            )?,
            _ => bail!("bad grid segment '{part}' (want v, lo:hi or lo:hi:step)"),
        }
    }
    ensure!(!out.is_empty(), "empty grid '{grid}'");
    Ok(out)
}

/// Expand an inclusive float range deterministically.
///
/// Endpoint rule: `hi` is included iff `(hi - lo) / step` is within
/// relative tolerance 1e-9 of an integer (so non-dividing steps stop at
/// the last in-range value, and representation error in `lo`/`hi`/`step`
/// cannot flip the decision). Emitted values are `lo + i * step` —
/// multiplication, never accumulation, so there is no drift — except
/// the final value, which is snapped to exactly `hi` when the endpoint
/// divides: `0.55:0.9:0.05` ends on the literal `0.9`, not
/// `0.55 + 7 * 0.05`.
fn push_f64_range(out: &mut Vec<f64>, lo: f64, hi: f64, step: f64) -> Result<()> {
    ensure!(step > 0.0, "range step must be positive, got {step}");
    ensure!(hi >= lo, "range {lo}:{hi} is descending");
    let exact = (hi - lo) / step;
    let rounded = exact.round();
    let divides = (exact - rounded).abs() <= 1e-9 * rounded.abs().max(1.0);
    let steps = if divides { rounded } else { exact.floor() };
    ensure!(steps < 1e6, "range {lo}:{hi}:{step} is too large");
    let steps = steps as usize;
    for i in 0..=steps {
        if divides && i == steps {
            out.push(hi);
        } else {
            out.push(lo + step * i as f64);
        }
    }
    Ok(())
}

/// Parse an integer grid: `"64,128"`, `"2:11"`, `"16:128:16"`.
pub fn parse_grid_usize(grid: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for part in grid.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let fields: Vec<&str> = part.split(':').collect();
        match fields.as_slice() {
            [v] => out.push(parse_usize_token(v)?),
            [lo, hi] => {
                push_usize_range(&mut out, parse_usize_token(lo)?, parse_usize_token(hi)?, 1)?
            }
            [lo, hi, step] => push_usize_range(
                &mut out,
                parse_usize_token(lo)?,
                parse_usize_token(hi)?,
                parse_usize_token(step)?,
            )?,
            _ => bail!("bad grid segment '{part}' (want v, lo:hi or lo:hi:step)"),
        }
    }
    ensure!(!out.is_empty(), "empty grid '{grid}'");
    Ok(out)
}

fn push_usize_range(out: &mut Vec<usize>, lo: usize, hi: usize, step: usize) -> Result<()> {
    ensure!(step >= 1, "range step must be >= 1");
    ensure!(hi >= lo, "range {lo}:{hi} is descending");
    let mut v = lo;
    while v <= hi {
        out.push(v);
        v += step;
    }
    Ok(())
}

/// Parse a `u32` grid (same syntax as [`parse_grid_usize`]).
pub fn parse_grid_u32(grid: &str) -> Result<Vec<u32>> {
    parse_grid_usize(grid)?
        .into_iter()
        .map(|v| u32::try_from(v).map_err(|_| anyhow!("{v} does not fit in u32")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_product_ids_match_driver_scheme() {
        let spec = SweepSpec::new("fig9a")
            .axis_f64("vwl", &[0.5, 0.8])
            .axis_usize("n", &[16, 128]);
        assert_eq!(spec.len(), 4);
        let points = spec.points();
        let ids: Vec<&str> = points.iter().map(|p| p.id.as_str()).collect();
        assert_eq!(
            ids,
            vec![
                "fig9a/vwl=0.5/n=16",
                "fig9a/vwl=0.5/n=128",
                "fig9a/vwl=0.8/n=16",
                "fig9a/vwl=0.8/n=128",
            ]
        );
        assert_eq!(points[3].num(0), 0.8);
        assert_eq!(points[3].int(1), 128);
    }

    #[test]
    fn integer_valued_floats_format_like_hand_written_ids() {
        // format!("{}", 3.0f64) == "3", which is what the drivers emitted.
        let spec = SweepSpec::new("fig10a").axis_f64("c", &[1.0, 3.0, 9.0]);
        let ids: Vec<String> = spec.points().into_iter().map(|p| p.id).collect();
        assert_eq!(ids, vec!["fig10a/c=1", "fig10a/c=3", "fig10a/c=9"]);
    }

    #[test]
    fn tuple_axis_varies_dims_together() {
        let configs = vec![
            vec![AxisValue::Num(0.8), AxisValue::Int(128)],
            vec![AxisValue::Num(0.7), AxisValue::Int(128)],
            vec![AxisValue::Num(0.8), AxisValue::Int(48)],
        ];
        let spec = SweepSpec::new("fig9b")
            .axis_tuples(&["vwl", "n"], configs)
            .axis_u32("b", &[2, 3]);
        assert_eq!(spec.len(), 6);
        let points = spec.points();
        assert_eq!(points[0].id, "fig9b/vwl=0.8/n=128/b=2");
        assert_eq!(points[5].id, "fig9b/vwl=0.8/n=48/b=3");
        assert_eq!(points[5].num(0), 0.8);
        assert_eq!(points[5].int(1), 48);
        assert_eq!(points[5].int(2), 3);
    }

    #[test]
    fn no_axes_is_a_single_point_and_empty_axis_is_empty() {
        let spec = SweepSpec::new("solo");
        assert_eq!(spec.len(), 1);
        let pts = spec.points();
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].id, "solo");
        let empty = SweepSpec::new("none").axis_f64("x", &[]);
        assert!(empty.is_empty());
        assert!(empty.points().is_empty());
    }

    #[test]
    fn grid_strings_parse_lists_and_ranges() {
        assert_eq!(parse_grid_usize("64,128").unwrap(), vec![64, 128]);
        assert_eq!(parse_grid_usize("2:5").unwrap(), vec![2, 3, 4, 5]);
        assert_eq!(parse_grid_usize("16:64:16").unwrap(), vec![16, 32, 48, 64]);
        assert_eq!(parse_grid_u32("4:6").unwrap(), vec![4, 5, 6]);
        let v = parse_grid_f64("0.5:0.8:0.1").unwrap();
        assert_eq!(v.len(), 4);
        assert!((v[3] - 0.8).abs() < 1e-9);
        assert_eq!(parse_grid_f64("1,2.5").unwrap(), vec![1.0, 2.5]);
        // mixed lists and ranges compose
        assert_eq!(parse_grid_usize("8,16:18").unwrap(), vec![8, 16, 17, 18]);
    }

    #[test]
    fn shards_partition_the_grid_with_unchanged_ids() {
        let spec = SweepSpec::new("s")
            .axis_usize("n", &[1, 2, 3, 4, 5])
            .axis_u32("b", &[7, 8]);
        let full: Vec<String> = spec.points().into_iter().map(|p| p.id).collect();
        assert_eq!(full.len(), 10);
        let k = 4;
        let mut merged: Vec<(usize, String)> = Vec::new();
        for i in 0..k {
            let shard = spec.clone().shard(i, k).unwrap();
            let pts = shard.points();
            assert_eq!(pts.len(), shard.len(), "len() matches points() for {i}/{k}");
            for (j, p) in pts.into_iter().enumerate() {
                // point j of shard i sits at global index i + j*k
                merged.push((i + j * k, p.id));
            }
        }
        merged.sort();
        let ids: Vec<String> = merged.into_iter().map(|(_, id)| id).collect();
        assert_eq!(ids, full, "union of shards == full grid, ids unchanged");
    }

    #[test]
    fn shard_rejects_bad_parameters() {
        let spec = SweepSpec::new("s").axis_usize("n", &[1, 2]);
        assert!(spec.clone().shard(0, 0).is_err());
        assert!(spec.clone().shard(3, 3).is_err());
        assert!(spec.clone().shard(0, 2).unwrap().shard(0, 2).is_err());
        assert_eq!(spec.shard_params(), None);
    }

    #[test]
    fn more_shards_than_points_leaves_some_empty() {
        let spec = SweepSpec::new("s").axis_usize("n", &[1, 2]);
        let sizes: Vec<usize> = (0..5)
            .map(|i| spec.clone().shard(i, 5).unwrap().points().len())
            .collect();
        assert_eq!(sizes, vec![1, 1, 0, 0, 0]);
    }

    #[test]
    fn parse_shard_accepts_i_slash_k() {
        assert_eq!(parse_shard("0/4").unwrap(), (0, 4));
        assert_eq!(parse_shard("3/4").unwrap(), (3, 4));
        assert!(parse_shard("4/4").is_err());
        assert!(parse_shard("0/0").is_err());
        assert!(parse_shard("1").is_err());
        assert!(parse_shard("a/b").is_err());
    }

    #[test]
    fn grid_strings_reject_garbage() {
        assert!(parse_grid_usize("").is_err());
        assert!(parse_grid_usize("abc").is_err());
        assert!(parse_grid_usize("5:2").is_err());
        assert!(parse_grid_f64("1:2:0").is_err());
        assert!(parse_grid_f64("1:2:3:4").is_err());
        // a sub-unit step-less float range must not collapse silently
        assert!(parse_grid_f64("0.6:0.8").is_err());
        assert_eq!(parse_grid_f64("1:3").unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(parse_grid_f64("2:2").unwrap(), vec![2.0]);
        assert!(parse_grid_u32("99999999999").is_err());
    }
}
