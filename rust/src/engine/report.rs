//! Shared figure-driver reporting: the two CSV/summary emission patterns
//! every sweep-backed driver used to duplicate inline.
//!
//! * [`EsReport`] — closed-form (E) vs simulated (S) comparison rows
//!   `[axes..., e_db, s_db]` plus the running max |E-S| gap, optionally
//!   gated to points where both values are meaningful (away from
//!   clipping cliffs where the closed-form tail approximations are
//!   loose).
//! * [`BoundReport`] — ADC-precision sweeps: arbitrary numeric rows plus
//!   the max `SNR_A - SNR_T` gap *at the predicted minimum B_ADC* and
//!   the largest predicted bound.

use std::path::Path;

use crate::util::csv::CsvWriter;

/// Which points count toward the max-gap statistic.
#[derive(Clone, Copy, Debug)]
enum Gate {
    /// Every point counts.
    None,
    /// Both closed-form and simulated values must clear the threshold.
    Both(f64),
    /// Only the closed-form value must clear the threshold (the
    /// simulated value still counts even if it collapsed — that *is*
    /// the disagreement the statistic exists to expose).
    Expected(f64),
}

/// Closed-form vs simulation report (fig9a/10a/11a/fig4b shape).
pub struct EsReport {
    csv: CsvWriter,
    gate: Gate,
    max_gap: f64,
}

impl EsReport {
    /// `header` must end with the two comparison columns (closed, sim).
    pub fn new(header: &[&str]) -> Self {
        Self {
            csv: CsvWriter::new(header),
            gate: Gate::None,
            max_gap: 0.0,
        }
    }

    /// Like [`EsReport::new`], but only points with both values above
    /// `gate_db` count toward the max-gap statistic.
    pub fn gated(header: &[&str], gate_db: f64) -> Self {
        Self {
            gate: Gate::Both(gate_db),
            ..Self::new(header)
        }
    }

    /// Like [`EsReport::gated`], but gated on the closed-form value only.
    pub fn gated_on_expected(header: &[&str], gate_db: f64) -> Self {
        Self {
            gate: Gate::Expected(gate_db),
            ..Self::new(header)
        }
    }

    /// Emit one row `[axes..., e_db, s_db]` and fold the |E-S| gap.
    pub fn push(&mut self, axes: &[f64], e_db: f64, s_db: f64) {
        let mut row = axes.to_vec();
        row.push(e_db);
        row.push(s_db);
        self.csv.row_f64(&row);
        let counted = match self.gate {
            Gate::None => true,
            Gate::Both(gate) => e_db > gate && s_db > gate,
            Gate::Expected(gate) => e_db > gate,
        };
        if counted {
            self.max_gap = self.max_gap.max((e_db - s_db).abs());
        }
    }

    pub fn max_gap(&self) -> f64 {
        self.max_gap
    }

    pub fn rows(&self) -> usize {
        self.csv.n_rows()
    }

    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        self.csv.write_to(path)
    }
}

/// ADC-precision bound report (fig9b/10b/11b shape).
pub struct BoundReport {
    csv: CsvWriter,
    gap_at_bound: f64,
    bound_max: u32,
}

impl BoundReport {
    pub fn new(header: &[&str]) -> Self {
        Self {
            csv: CsvWriter::new(header),
            gap_at_bound: f64::MIN,
            bound_max: 0,
        }
    }

    /// Emit one numeric row; `b_adc`/`bound` and the two simulated SNRs
    /// feed the at-the-bound gap and max-bound statistics.
    pub fn push(
        &mut self,
        row: &[f64],
        b_adc: u32,
        bound: u32,
        snr_a_sim_db: f64,
        snr_t_sim_db: f64,
    ) {
        self.csv.row_f64(row);
        self.bound_max = self.bound_max.max(bound);
        if b_adc == bound {
            self.gap_at_bound = self.gap_at_bound.max(snr_a_sim_db - snr_t_sim_db);
        }
    }

    /// Max simulated `SNR_A - SNR_T` at the predicted minimum B_ADC
    /// (`f64::MIN` if the grid never hit a bound).
    pub fn gap_at_bound(&self) -> f64 {
        self.gap_at_bound
    }

    pub fn bound_max(&self) -> u32 {
        self.bound_max
    }

    pub fn rows(&self) -> usize {
        self.csv.n_rows()
    }

    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        self.csv.write_to(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn es_report_tracks_gap_with_gate() {
        let mut r = EsReport::gated(&["n", "e", "s"], 5.0);
        r.push(&[16.0], 30.0, 29.0); // counted: gap 1
        r.push(&[32.0], 4.0, -20.0); // below gate: ignored
        r.push(&[64.0], 20.0, 26.5); // counted: gap 6.5
        assert_eq!(r.rows(), 3);
        assert!((r.max_gap() - 6.5).abs() < 1e-12);

        let mut ungated = EsReport::new(&["n", "e", "s"]);
        ungated.push(&[1.0], 4.0, -20.0);
        assert!((ungated.max_gap() - 24.0).abs() < 1e-12);

        // expected-only gate: a collapsed simulated value still counts
        let mut exp = EsReport::gated_on_expected(&["n", "e", "s"], 5.0);
        exp.push(&[1.0], 20.0, 2.0); // e above gate, s collapsed: gap 18
        exp.push(&[2.0], 4.0, 30.0); // e below gate: ignored
        assert!((exp.max_gap() - 18.0).abs() < 1e-12);
    }

    #[test]
    fn bound_report_only_counts_gap_at_bound() {
        let mut r = BoundReport::new(&["b", "bound", "snr_t"]);
        r.push(&[4.0, 6.0, 10.0], 4, 6, 30.0, 10.0); // not at bound
        r.push(&[6.0, 6.0, 28.0], 6, 6, 30.0, 28.0); // at bound: gap 2
        r.push(&[7.0, 8.0, 29.0], 7, 8, 30.0, 29.0); // not at bound
        assert_eq!(r.rows(), 3);
        assert!((r.gap_at_bound() - 2.0).abs() < 1e-12);
        assert_eq!(r.bound_max(), 8);
    }
}
