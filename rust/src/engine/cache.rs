//! Content-addressed result cache for Monte-Carlo sweep points.
//!
//! Every [`SweepPoint`] is identified by a stable 128-bit key over its
//! *content*: (arch kind, normalized parameter vector, trials, seed,
//! input distribution, backend id). The point `id` (display label)
//! deliberately does not participate, so the same physical operating
//! point reached from different figures or CLI sweeps shares one record.
//!
//! Records are JSON files `<dir>/<key>.json` (same hand-rolled JSON
//! style as `runtime::manifest`) holding the [`MeasuredSnr`] with every
//! `f64` serialized as its exact IEEE-754 bit pattern in hex, so a cache
//! hit is *bit-identical* to the run that produced it — including
//! non-finite values, which plain JSON numbers cannot represent. A
//! `manifest.json` in the same directory indexes key -> label for humans
//! and tooling.
//!
//! Robustness contract: any unreadable, corrupt, version-skewed or
//! key-mismatched record is treated as a cache miss (recompute), never
//! an error.
//!
//! Beyond the per-point sweep records, this module provides:
//!
//! * **memo records** ([`ResultCache::load_memo`] /
//!   [`ResultCache::store_memo`]) — content-addressed `Vec<f64>` values
//!   for the bespoke Monte-Carlo quantities of the fig2/fig4 drivers,
//!   keyed by `(tag, params)` under a separate domain prefix;
//! * **shard-directory merge** ([`merge_cache_dirs`]) — plain file union
//!   of content-addressed records from distributed sweep shards, with
//!   collision detection and a rebuilt consolidated manifest;
//! * **garbage collection** ([`gc`]) — size/age-based LRU eviction for
//!   long-lived out-dirs, driven by the manifest and record metadata
//!   (cache hits refresh a record's mtime, making mtime order LRU order).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime};

use anyhow::{Context, Result};

use crate::coordinator::SweepPoint;
use crate::mc::{ArchKind, InputDist, MeasuredSnr};
use crate::util::json::{num, obj, s, Json};

const CACHE_VERSION: f64 = 1.0;

/// Cache index filename (`key -> label`), also carried verbatim inside
/// registry artifact payloads so a pulled cache keeps its labels.
pub const MANIFEST_FILE: &str = "manifest.json";

/// Domain-separation prefix: bump alongside `CACHE_VERSION` whenever the
/// key encoding *or the simulator's semantics* change — the key covers a
/// point's inputs, not the code that computes it, so a physics change
/// must invalidate old records by version bump (or `--no-cache` / a
/// fresh out-dir on the caller's side).
const KEY_PREFIX: &[u8] = b"imclim-sweep-record-v1\0";

/// Domain prefix for memo records (bespoke driver Monte-Carlo values),
/// so a memo key can never collide with a sweep-point key.
const MEMO_PREFIX: &[u8] = b"imclim-memo-record-v1\0";

/// Stable 128-bit content key (32 hex chars) for one sweep point on one
/// backend. Everything that can change the measured result participates;
/// the display id does not.
pub fn cache_key(point: &SweepPoint, backend_id: &str) -> String {
    let mut bytes = Vec::with_capacity(KEY_PREFIX.len() + 192 + backend_id.len());
    bytes.extend_from_slice(KEY_PREFIX);
    bytes.push(match point.kind {
        ArchKind::Qs => 1,
        ArchKind::Qr => 2,
        ArchKind::Cm => 3,
    });
    bytes.extend_from_slice(&(point.trials as u64).to_le_bytes());
    bytes.extend_from_slice(&point.seed.to_le_bytes());
    match point.dist {
        InputDist::Uniform => bytes.push(0),
        InputDist::ClippedGaussian { sx, sw } => {
            bytes.push(1);
            bytes.extend_from_slice(&sx.to_bits().to_le_bytes());
            bytes.extend_from_slice(&sw.to_bits().to_le_bytes());
        }
    }
    for p in &point.params {
        bytes.extend_from_slice(&p.to_bits().to_le_bytes());
    }
    // Adaptive-precision runs are a separate key dimension: the tagged
    // block is appended *only* when present, so every fixed-trials key
    // byte stream — and therefore every pre-existing record key — is
    // untouched, while an adaptive record can never alias a fixed one
    // (for adaptive points `trials` is the cap, not the ensemble size).
    if let Some(half_width_db) = point.precision {
        bytes.extend_from_slice(b"precision\0");
        bytes.extend_from_slice(&half_width_db.to_bits().to_le_bytes());
    }
    bytes.extend_from_slice(backend_id.as_bytes());
    format!(
        "{:016x}{:016x}",
        absorb(&bytes, 0x243F_6A88_85A3_08D3),
        absorb(&bytes, 0x1319_8A2E_0370_7344)
    )
}

/// Stable 128-bit content key for one memo quantity: a named (`tag`)
/// deterministic function of the `params` vector. Backend-independent —
/// memo values come from the bespoke native Monte-Carlo in the fig2/fig4
/// drivers, which no execution backend participates in.
pub fn memo_key(tag: &str, params: &[f64]) -> String {
    let mut bytes = Vec::with_capacity(MEMO_PREFIX.len() + tag.len() + 1 + 8 * params.len());
    bytes.extend_from_slice(MEMO_PREFIX);
    bytes.extend_from_slice(tag.as_bytes());
    bytes.push(0);
    for p in params {
        bytes.extend_from_slice(&p.to_bits().to_le_bytes());
    }
    format!(
        "{:016x}{:016x}",
        absorb(&bytes, 0x243F_6A88_85A3_08D3),
        absorb(&bytes, 0x1319_8A2E_0370_7344)
    )
}

/// SplitMix64-absorption hash: XOR each little-endian 8-byte word into
/// the state and run the SplitMix64 finalizer. Not cryptographic — just
/// a stable, well-mixed content fingerprint.
fn absorb(bytes: &[u8], seed: u64) -> u64 {
    let mut h = seed;
    for chunk in bytes.chunks(8) {
        let mut word = [0u8; 8];
        word[..chunk.len()].copy_from_slice(chunk);
        h ^= u64::from_le_bytes(word);
        h = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
    }
    h ^ bytes.len() as u64
}

fn f64_hex(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

/// On-disk result cache rooted at one directory, bound to one backend.
pub struct ResultCache {
    dir: PathBuf,
    backend_id: String,
}

impl ResultCache {
    pub fn new(dir: impl Into<PathBuf>, backend_id: impl Into<String>) -> Self {
        Self {
            dir: dir.into(),
            backend_id: backend_id.into(),
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn key(&self, point: &SweepPoint) -> String {
        cache_key(point, &self.backend_id)
    }

    fn record_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    /// Look up a point; `None` on miss *or* on any record defect. A hit
    /// refreshes the record's mtime so [`gc`]'s LRU order tracks use.
    /// Probe latency (hit or miss) feeds the
    /// `imclim_cache_probe_seconds` histogram.
    pub fn load(&self, point: &SweepPoint) -> Option<MeasuredSnr> {
        let t0 = std::time::Instant::now();
        let decoded = self.load_untimed(point);
        crate::obs::registry::CACHE_PROBE_SECONDS.observe(t0.elapsed());
        decoded
    }

    fn load_untimed(&self, point: &SweepPoint) -> Option<MeasuredSnr> {
        let key = self.key(point);
        let path = self.record_path(&key);
        let text = std::fs::read_to_string(&path).ok()?;
        let decoded = decode_record(&text, &key);
        if decoded.is_some() {
            touch(&path);
        }
        decoded
    }

    /// Look up a memo quantity; `None` on miss or any record defect.
    /// Hits refresh the record's mtime (LRU, as in [`ResultCache::load`]).
    pub fn load_memo(&self, tag: &str, params: &[f64]) -> Option<Vec<f64>> {
        let key = memo_key(tag, params);
        let path = self.record_path(&key);
        let text = std::fs::read_to_string(&path).ok()?;
        let decoded = decode_memo(&text, &key, tag);
        if decoded.is_some() {
            touch(&path);
        }
        decoded
    }

    /// Persist a memo quantity (bit-exact, like sweep records).
    pub fn store_memo(&self, tag: &str, params: &[f64], values: &[f64]) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating cache dir {}", self.dir.display()))?;
        let key = memo_key(tag, params);
        let record = obj(vec![
            ("version", num(CACHE_VERSION)),
            ("key", s(&key)),
            ("tag", s(tag)),
            (
                "params",
                Json::Arr(params.iter().map(|&p| f64_hex(p)).collect()),
            ),
            (
                "values",
                Json::Arr(values.iter().map(|&v| f64_hex(v)).collect()),
            ),
        ]);
        let path = self.record_path(&key);
        std::fs::write(&path, record.to_string())
            .with_context(|| format!("writing memo record {}", path.display()))?;
        Ok(())
    }

    /// Persist a computed result for a point.
    pub fn store(&self, point: &SweepPoint, measured: &MeasuredSnr) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating cache dir {}", self.dir.display()))?;
        let key = self.key(point);
        let record = encode_record(point, &self.backend_id, &key, measured);
        let path = self.record_path(&key);
        std::fs::write(&path, record.to_string())
            .with_context(|| format!("writing cache record {}", path.display()))?;
        Ok(())
    }

    /// Merge `(key, id)` pairs into `manifest.json`. A missing or corrupt
    /// manifest is rebuilt from scratch.
    pub fn update_manifest(&self, entries: &[(String, String)]) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        std::fs::create_dir_all(&self.dir)?;
        let mut index = read_manifest_entries(&self.dir);
        for (key, id) in entries {
            index.insert(key.clone(), Json::Str(id.clone()));
        }
        write_manifest(&self.dir, &self.backend_id, index)
    }
}

/// Best-effort mtime refresh (LRU bookkeeping); failure is harmless.
fn touch(path: &Path) {
    if let Ok(f) = std::fs::OpenOptions::new().write(true).open(path) {
        let _ = f.set_modified(SystemTime::now());
    }
}

/// `entries` map of a directory's manifest (empty on missing/corrupt).
fn read_manifest_entries(dir: &Path) -> BTreeMap<String, Json> {
    std::fs::read_to_string(dir.join(MANIFEST_FILE))
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| j.get("entries").and_then(|e| e.as_obj()).cloned())
        .unwrap_or_default()
}

/// The `key -> label` index of a directory's manifest as plain strings
/// (empty on missing/corrupt). The registry packer embeds these labels
/// in `artifact.json` so published records stay human-identifiable.
pub fn manifest_labels(dir: &Path) -> BTreeMap<String, String> {
    read_manifest_entries(dir)
        .into_iter()
        .filter_map(|(k, v)| v.as_str().map(|s| (k, s.to_string())))
        .collect()
}

/// `backend` field of a directory's manifest, if readable. Public for
/// the registry (artifacts record which backend produced their cache)
/// and `cache stats`.
pub fn manifest_backend(dir: &Path) -> Option<String> {
    read_manifest_backend(dir)
}

/// `backend` field of a directory's manifest, if readable.
fn read_manifest_backend(dir: &Path) -> Option<String> {
    std::fs::read_to_string(dir.join(MANIFEST_FILE))
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| j.get("backend").and_then(|b| b.as_str()).map(str::to_string))
}

fn write_manifest(dir: &Path, backend: &str, entries: BTreeMap<String, Json>) -> Result<()> {
    let path = dir.join(MANIFEST_FILE);
    let manifest = obj(vec![
        ("version", num(CACHE_VERSION)),
        ("backend", s(backend)),
        ("entries", Json::Obj(entries)),
    ]);
    std::fs::write(&path, manifest.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

fn encode_record(point: &SweepPoint, backend_id: &str, key: &str, m: &MeasuredSnr) -> Json {
    let dist = match point.dist {
        InputDist::Uniform => "uniform".to_string(),
        InputDist::ClippedGaussian { sx, sw } => {
            format!("gauss:{:016x}:{:016x}", sx.to_bits(), sw.to_bits())
        }
    };
    let mut fields = vec![
        ("version", num(CACHE_VERSION)),
        ("key", s(key)),
        ("id", s(&point.id)),
        ("kind", s(point.kind.artifact_name())),
        ("backend", s(backend_id)),
        ("trials", num(point.trials as f64)),
        ("seed", s(&format!("{:016x}", point.seed))),
        ("dist", s(&dist)),
    ];
    // present only on adaptive records (decode ignores unknown fields,
    // and fixed-trials record bytes stay exactly as before this field
    // existed — the warm-cache byte-identity contract)
    if let Some(half_width_db) = point.precision {
        fields.push(("precision_db", f64_hex(half_width_db)));
    }
    fields.extend([
        (
            "params",
            Json::Arr(point.params.iter().map(|&p| f64_hex(p)).collect()),
        ),
        ("measured_trials", num(m.trials as f64)),
        (
            "measured_bits",
            obj(vec![
                ("sigma_yo2", f64_hex(m.sigma_yo2)),
                ("sigma_qiy2", f64_hex(m.sigma_qiy2)),
                ("sigma_eta_a2", f64_hex(m.sigma_eta_a2)),
                ("sigma_qy2", f64_hex(m.sigma_qy2)),
                ("sqnr_qiy_db", f64_hex(m.sqnr_qiy_db)),
                ("snr_a_db", f64_hex(m.snr_a_db)),
                ("snr_a_total_db", f64_hex(m.snr_a_total_db)),
                ("snr_t_db", f64_hex(m.snr_t_db)),
            ]),
        ),
    ]);
    obj(fields)
}

fn decode_record(text: &str, key: &str) -> Option<MeasuredSnr> {
    let j = Json::parse(text).ok()?;
    if j.get("version")?.as_f64()? != CACHE_VERSION {
        return None;
    }
    if j.get("key")?.as_str()? != key {
        return None;
    }
    let bits = j.get("measured_bits")?;
    let field = |name: &str| -> Option<f64> {
        let hex = bits.get(name)?.as_str()?;
        u64::from_str_radix(hex, 16).ok().map(f64::from_bits)
    };
    Some(MeasuredSnr {
        sigma_yo2: field("sigma_yo2")?,
        sigma_qiy2: field("sigma_qiy2")?,
        sigma_eta_a2: field("sigma_eta_a2")?,
        sigma_qy2: field("sigma_qy2")?,
        sqnr_qiy_db: field("sqnr_qiy_db")?,
        snr_a_db: field("snr_a_db")?,
        snr_a_total_db: field("snr_a_total_db")?,
        snr_t_db: field("snr_t_db")?,
        trials: j.get("measured_trials")?.as_f64()? as u64,
    })
}

fn decode_memo(text: &str, key: &str, tag: &str) -> Option<Vec<f64>> {
    let j = Json::parse(text).ok()?;
    if j.get("version")?.as_f64()? != CACHE_VERSION {
        return None;
    }
    if j.get("key")?.as_str()? != key {
        return None;
    }
    if j.get("tag")?.as_str()? != tag {
        return None;
    }
    j.get("values")?
        .as_arr()?
        .iter()
        .map(|v| {
            let hex = v.as_str()?;
            u64::from_str_radix(hex, 16).ok().map(f64::from_bits)
        })
        .collect()
}

// ---------------------------------------------------------------------
// Shard-directory merge (distributed sweeps).
// ---------------------------------------------------------------------

/// Outcome of one [`merge_cache_dirs`] call.
#[derive(Clone, Debug, Default)]
pub struct MergeReport {
    /// Records copied into the destination.
    pub copied: usize,
    /// Records already present with byte-identical payloads.
    pub identical: usize,
    /// Keys present in both source and destination with *differing*
    /// payloads (the destination's copy is kept).
    pub collisions: Vec<String>,
    /// Distinct manifest `backend` ids seen across all directories.
    pub backends: Vec<String>,
}

/// Union the content-addressed records of `sources` into `dst` and
/// rebuild a consolidated `manifest.json` there. Keys are content
/// hashes, so disjoint shard caches merge by plain file copy; a key
/// present on both sides with different bytes is reported as a
/// collision (and the destination's payload wins). The rebuilt manifest
/// only indexes keys that exist as records in `dst`.
pub fn merge_cache_dirs(dst: &Path, sources: &[PathBuf]) -> Result<MergeReport> {
    let _span = crate::obs::trace::span_with("cache_merge", "cache", || {
        format!("{} sources", sources.len())
    });
    std::fs::create_dir_all(dst).with_context(|| format!("creating {}", dst.display()))?;
    let mut report = MergeReport::default();
    let mut entries = read_manifest_entries(dst);
    let mut backends: Vec<String> = read_manifest_backend(dst).into_iter().collect();

    for src in sources {
        for (key, path) in list_record_files(src)? {
            let bytes = match std::fs::read(&path) {
                Ok(b) => b,
                Err(_) => continue, // vanished mid-merge: skip
            };
            let dst_path = dst.join(format!("{key}.json"));
            match std::fs::read(&dst_path) {
                Ok(existing) if existing == bytes => report.identical += 1,
                Ok(_) => report.collisions.push(key),
                Err(_) => {
                    std::fs::write(&dst_path, &bytes)
                        .with_context(|| format!("writing {}", dst_path.display()))?;
                    report.copied += 1;
                }
            }
        }
        for (key, id) in read_manifest_entries(src) {
            entries.entry(key).or_insert(id);
        }
        if let Some(b) = read_manifest_backend(src) {
            if !backends.contains(&b) {
                backends.push(b);
            }
        }
    }

    // the consolidated manifest only indexes records that exist on disk
    entries.retain(|key, _| dst.join(format!("{key}.json")).exists());
    let backend = backends.first().cloned().unwrap_or_else(|| "unknown".into());
    write_manifest(dst, &backend, entries)?;
    report.backends = backends;
    report.collisions.sort();
    Ok(report)
}

/// All `(key, path)` record files in a cache dir (manifest excluded).
/// Sorted by key for deterministic iteration; an absent directory is
/// just empty. This is the enumeration hook the registry packer and
/// verifier share with `merge`/`gc`: anything it lists is a record an
/// artifact must carry and checksum.
pub fn list_record_files(dir: &Path) -> Result<Vec<(String, PathBuf)>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(out),
    };
    for entry in entries.flatten() {
        let path = entry.path();
        let is_json = path.extension().and_then(|e| e.to_str()) == Some("json");
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if !is_json || name == MANIFEST_FILE || !path.is_file() {
            continue;
        }
        if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
            out.push((stem.to_string(), path.clone()));
        }
    }
    out.sort();
    Ok(out)
}

// ---------------------------------------------------------------------
// Garbage collection (size/age LRU eviction).
// ---------------------------------------------------------------------

/// One record's on-disk metadata, as seen by [`gc`] and `cache stats`.
#[derive(Clone, Debug)]
pub struct RecordInfo {
    pub key: String,
    pub path: PathBuf,
    pub bytes: u64,
    pub modified: SystemTime,
}

/// Scan a cache directory's records (manifest excluded), oldest first
/// (mtime order = LRU order, since cache hits refresh mtimes). A record
/// whose mtime cannot be read sorts as *newest* — an unreadable
/// timestamp must never promote a just-written record to the front of
/// the eviction queue.
pub fn scan_records(dir: &Path) -> Result<Vec<RecordInfo>> {
    let now = SystemTime::now();
    let mut out = Vec::new();
    for (key, path) in list_record_files(dir)? {
        let meta = match std::fs::metadata(&path) {
            Ok(m) => m,
            Err(_) => continue,
        };
        let modified = meta.modified().unwrap_or(now);
        out.push(RecordInfo {
            key,
            path,
            bytes: meta.len(),
            modified,
        });
    }
    out.sort_by(|a, b| (a.modified, &a.key).cmp(&(b.modified, &b.key)));
    Ok(out)
}

#[derive(Clone, Copy, Debug, Default)]
pub struct GcOptions {
    /// Target total record size; least-recently-used records are evicted
    /// until the directory fits. Records newer than `max_age` (when set)
    /// are protected from size eviction.
    pub max_bytes: Option<u64>,
    /// Records last used longer ago than this are expired outright;
    /// records newer than this are never evicted.
    pub max_age: Option<Duration>,
    /// Report what would be evicted without deleting anything.
    pub dry_run: bool,
}

#[derive(Clone, Debug, Default)]
pub struct GcReport {
    pub scanned: usize,
    pub evicted: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
    pub evicted_keys: Vec<String>,
}

/// Evict cache records by age and size. Age first: anything older than
/// `max_age` expires. Then size: while the total exceeds `max_bytes`,
/// evict least-recently-used records — but never one newer than
/// `max_age` (when both are given, `max_age` acts as a protection
/// floor, so `max_bytes` is best-effort). Evicted keys are dropped from
/// the manifest. With `dry_run`, nothing is deleted (the manifest is
/// left alone) and the report shows what would happen.
pub fn gc(dir: &Path, opts: &GcOptions) -> Result<GcReport> {
    let records = scan_records(dir)?; // oldest first
    let now = SystemTime::now();
    let total: u64 = records.iter().map(|r| r.bytes).sum();
    let mut report = GcReport {
        scanned: records.len(),
        bytes_before: total,
        bytes_after: total,
        ..GcReport::default()
    };

    let evict_idx = plan_evictions(&records, now, opts);
    let evict: Vec<&RecordInfo> = evict_idx.iter().map(|&i| &records[i]).collect();
    let evicted_bytes: u64 = evict.iter().map(|r| r.bytes).sum();

    report.evicted = evict.len();
    report.bytes_after = total - evicted_bytes;
    report.evicted_keys = evict.iter().map(|r| r.key.clone()).collect();
    report.evicted_keys.sort();
    if opts.dry_run || evict.is_empty() {
        return Ok(report);
    }
    for r in &evict {
        let _ = std::fs::remove_file(&r.path);
    }
    // drop evicted keys from the manifest (if one exists)
    if dir.join(MANIFEST_FILE).exists() {
        let mut entries = read_manifest_entries(dir);
        for r in &evict {
            entries.remove(&r.key);
        }
        let backend = read_manifest_backend(dir).unwrap_or_else(|| "unknown".into());
        write_manifest(dir, &backend, entries)?;
    }
    Ok(report)
}

/// Pure eviction planner over an oldest-first record list: age-expiry
/// pass, then LRU size pass with the `max_age` protection floor.
/// Returns indices into `records` to evict. Split from [`gc`] so the
/// ordering semantics — including the unreadable-mtime "sorts newest,
/// never evicted first" fallback from [`scan_records`] — are testable
/// without faking filesystem metadata.
fn plan_evictions(records: &[RecordInfo], now: SystemTime, opts: &GcOptions) -> Vec<usize> {
    let age_of = |r: &RecordInfo| now.duration_since(r.modified).unwrap_or(Duration::ZERO);
    let mut keep = vec![true; records.len()];
    let mut remaining: u64 = records.iter().map(|r| r.bytes).sum();
    for (i, r) in records.iter().enumerate() {
        if matches!(opts.max_age, Some(max) if age_of(r) > max) {
            keep[i] = false;
            remaining -= r.bytes;
        }
    }
    if let Some(max_bytes) = opts.max_bytes {
        for (i, r) in records.iter().enumerate() {
            if remaining <= max_bytes {
                break;
            }
            if !keep[i] {
                continue;
            }
            if matches!(opts.max_age, Some(max) if age_of(r) <= max) {
                continue; // protected: newer than max_age
            }
            keep[i] = false;
            remaining -= r.bytes;
        }
    }
    keep.iter()
        .enumerate()
        .filter(|(_, &k)| !k)
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pvec;

    fn point(id: &str) -> SweepPoint {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = 64.0;
        p[pvec::IDX_BX] = 6.0;
        p[pvec::IDX_BW] = 6.0;
        p[pvec::IDX_B_ADC] = 8.0;
        SweepPoint::new(id, ArchKind::Qs, p)
            .with_trials(128)
            .with_seed(0xFEED)
    }

    fn tmp_cache(name: &str) -> ResultCache {
        let dir = std::env::temp_dir().join(format!("imclim-cache-unit-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        ResultCache::new(dir, "native")
    }

    #[test]
    fn key_is_stable_and_content_addressed() {
        let p = point("a");
        assert_eq!(cache_key(&p, "native"), cache_key(&p, "native"));
        assert_eq!(cache_key(&p, "native").len(), 32);
        // the label does not participate
        let renamed = point("totally-different-label");
        assert_eq!(cache_key(&p, "native"), cache_key(&renamed, "native"));
        // the backend does
        assert_ne!(cache_key(&p, "native"), cache_key(&p, "pjrt"));
    }

    #[test]
    fn roundtrip_is_bit_identical_even_for_non_finite() {
        let cache = tmp_cache("roundtrip");
        let p = point("r");
        let m = MeasuredSnr {
            sigma_yo2: 1.234e-5,
            sigma_qiy2: 0.0,
            sigma_eta_a2: 7.7,
            sigma_qy2: f64::NAN,
            sqnr_qiy_db: f64::INFINITY,
            snr_a_db: -13.25,
            snr_a_total_db: f64::NEG_INFINITY,
            snr_t_db: 42.125,
            trials: 128,
        };
        cache.store(&p, &m).unwrap();
        let got = cache.load(&p).expect("hit");
        assert_eq!(got.sigma_yo2.to_bits(), m.sigma_yo2.to_bits());
        assert_eq!(got.sigma_qy2.to_bits(), m.sigma_qy2.to_bits());
        assert_eq!(got.sqnr_qiy_db.to_bits(), m.sqnr_qiy_db.to_bits());
        assert_eq!(got.snr_a_total_db.to_bits(), m.snr_a_total_db.to_bits());
        assert_eq!(got.snr_t_db.to_bits(), m.snr_t_db.to_bits());
        assert_eq!(got.trials, m.trials);
    }

    #[test]
    fn defective_records_are_misses_not_errors() {
        let cache = tmp_cache("defects");
        let p = point("d");
        assert!(cache.load(&p).is_none(), "cold cache misses");
        cache.store(&p, &MeasuredSnr::default()).unwrap();
        assert!(cache.load(&p).is_some());
        let path = cache.record_path(&cache.key(&p));
        for garbage in ["", "{ not json", "{\"version\": 1}", "[1,2,3]"] {
            std::fs::write(&path, garbage).unwrap();
            assert!(cache.load(&p).is_none(), "corrupt record {garbage:?}");
        }
        // a record stored under the wrong key is rejected too
        cache.store(&p, &MeasuredSnr::default()).unwrap();
        let other = {
            let mut o = point("d");
            o.seed = 999;
            o
        };
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(cache.record_path(&cache.key(&other)), text).unwrap();
        assert!(cache.load(&other).is_none(), "key mismatch is a miss");
    }

    #[test]
    fn memo_roundtrip_and_key_discrimination() {
        let cache = tmp_cache("memo");
        assert!(cache.load_memo("fig4/mc", &[1.0, 2.0]).is_none());
        let values = vec![40.25, f64::NAN, -3.5e-7];
        cache.store_memo("fig4/mc", &[1.0, 2.0], &values).unwrap();
        let got = cache.load_memo("fig4/mc", &[1.0, 2.0]).expect("hit");
        assert_eq!(got.len(), 3);
        for (a, b) in got.iter().zip(&values) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit-exact memo values");
        }
        // tag and params both participate in the key
        assert!(cache.load_memo("fig4/other", &[1.0, 2.0]).is_none());
        assert!(cache.load_memo("fig4/mc", &[1.0, 2.5]).is_none());
        // memo keys share the 128-bit format but live in their own domain
        assert_eq!(memo_key("fig4/mc", &[1.0, 2.0]).len(), 32);
        assert!(cache.load(&point("memo-vs-sweep")).is_none());
    }

    #[test]
    fn corrupt_memo_is_a_miss() {
        let cache = tmp_cache("memo-corrupt");
        cache.store_memo("t", &[7.0], &[1.0]).unwrap();
        let path = cache.record_path(&memo_key("t", &[7.0]));
        for garbage in ["", "{", "{\"version\": 1}", "{\"values\": [1]}"] {
            std::fs::write(&path, garbage).unwrap();
            assert!(cache.load_memo("t", &[7.0]).is_none(), "{garbage:?}");
        }
    }

    #[test]
    fn manifest_merges_entries() {
        let cache = tmp_cache("manifest");
        cache
            .update_manifest(&[("k1".into(), "id1".into())])
            .unwrap();
        cache
            .update_manifest(&[("k2".into(), "id2".into()), ("k1".into(), "id1b".into())])
            .unwrap();
        let text = std::fs::read_to_string(cache.dir().join("manifest.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        let entries = j.get("entries").unwrap().as_obj().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries["k1"].as_str(), Some("id1b"));
        assert_eq!(entries["k2"].as_str(), Some("id2"));
    }

    #[test]
    fn unreadable_mtime_records_are_last_not_first_eviction_candidates() {
        let now = SystemTime::now();
        let rec = |key: &str, age_secs: u64, bytes: u64| RecordInfo {
            key: key.into(),
            path: PathBuf::from(key),
            bytes,
            modified: now - Duration::from_secs(age_secs),
        };
        // `fresh` models a just-written record whose mtime read failed:
        // scan_records falls back to `now` (the old UNIX_EPOCH fallback
        // made exactly these records the first eviction candidates).
        let mut records = vec![
            rec("fresh", 0, 100),
            rec("old", 3_600, 100),
            rec("older", 7_200, 100),
        ];
        records.sort_by(|a, b| (a.modified, &a.key).cmp(&(b.modified, &b.key)));
        assert_eq!(records[2].key, "fresh", "fallback must sort newest");

        // pure size pressure: LRU evicts the two genuinely old records
        // and the fallback record is the survivor.
        let opts = GcOptions {
            max_bytes: Some(100),
            max_age: None,
            dry_run: false,
        };
        let evicted: Vec<&str> = plan_evictions(&records, now, &opts)
            .iter()
            .map(|&i| records[i].key.as_str())
            .collect();
        assert_eq!(evicted, ["older", "old"]);

        // combined pressure: age expiry takes the old records, and the
        // age-zero fallback record stays protected from the size pass
        // even when max_bytes cannot be met (best-effort floor).
        let opts = GcOptions {
            max_bytes: Some(0),
            max_age: Some(Duration::from_secs(600)),
            dry_run: false,
        };
        let evicted: Vec<&str> = plan_evictions(&records, now, &opts)
            .iter()
            .map(|&i| records[i].key.as_str())
            .collect();
        assert_eq!(evicted, ["older", "old"]);
    }
}
