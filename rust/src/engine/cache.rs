//! Content-addressed result cache for Monte-Carlo sweep points.
//!
//! Every [`SweepPoint`] is identified by a stable 128-bit key over its
//! *content*: (arch kind, normalized parameter vector, trials, seed,
//! input distribution, backend id). The point `id` (display label)
//! deliberately does not participate, so the same physical operating
//! point reached from different figures or CLI sweeps shares one record.
//!
//! Records are JSON files `<dir>/<key>.json` (same hand-rolled JSON
//! style as `runtime::manifest`) holding the [`MeasuredSnr`] with every
//! `f64` serialized as its exact IEEE-754 bit pattern in hex, so a cache
//! hit is *bit-identical* to the run that produced it — including
//! non-finite values, which plain JSON numbers cannot represent. A
//! `manifest.json` in the same directory indexes key -> label for humans
//! and tooling.
//!
//! Robustness contract: any unreadable, corrupt, version-skewed or
//! key-mismatched record is treated as a cache miss (recompute), never
//! an error.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::SweepPoint;
use crate::mc::{ArchKind, InputDist, MeasuredSnr};
use crate::util::json::{num, obj, s, Json};

const CACHE_VERSION: f64 = 1.0;

/// Domain-separation prefix: bump alongside `CACHE_VERSION` whenever the
/// key encoding *or the simulator's semantics* change — the key covers a
/// point's inputs, not the code that computes it, so a physics change
/// must invalidate old records by version bump (or `--no-cache` / a
/// fresh out-dir on the caller's side).
const KEY_PREFIX: &[u8] = b"imclim-sweep-record-v1\0";

/// Stable 128-bit content key (32 hex chars) for one sweep point on one
/// backend. Everything that can change the measured result participates;
/// the display id does not.
pub fn cache_key(point: &SweepPoint, backend_id: &str) -> String {
    let mut bytes = Vec::with_capacity(KEY_PREFIX.len() + 192 + backend_id.len());
    bytes.extend_from_slice(KEY_PREFIX);
    bytes.push(match point.kind {
        ArchKind::Qs => 1,
        ArchKind::Qr => 2,
        ArchKind::Cm => 3,
    });
    bytes.extend_from_slice(&(point.trials as u64).to_le_bytes());
    bytes.extend_from_slice(&point.seed.to_le_bytes());
    match point.dist {
        InputDist::Uniform => bytes.push(0),
        InputDist::ClippedGaussian { sx, sw } => {
            bytes.push(1);
            bytes.extend_from_slice(&sx.to_bits().to_le_bytes());
            bytes.extend_from_slice(&sw.to_bits().to_le_bytes());
        }
    }
    for p in &point.params {
        bytes.extend_from_slice(&p.to_bits().to_le_bytes());
    }
    bytes.extend_from_slice(backend_id.as_bytes());
    format!(
        "{:016x}{:016x}",
        absorb(&bytes, 0x243F_6A88_85A3_08D3),
        absorb(&bytes, 0x1319_8A2E_0370_7344)
    )
}

/// SplitMix64-absorption hash: XOR each little-endian 8-byte word into
/// the state and run the SplitMix64 finalizer. Not cryptographic — just
/// a stable, well-mixed content fingerprint.
fn absorb(bytes: &[u8], seed: u64) -> u64 {
    let mut h = seed;
    for chunk in bytes.chunks(8) {
        let mut word = [0u8; 8];
        word[..chunk.len()].copy_from_slice(chunk);
        h ^= u64::from_le_bytes(word);
        h = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
    }
    h ^ bytes.len() as u64
}

fn f64_hex(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

/// On-disk result cache rooted at one directory, bound to one backend.
pub struct ResultCache {
    dir: PathBuf,
    backend_id: String,
}

impl ResultCache {
    pub fn new(dir: impl Into<PathBuf>, backend_id: impl Into<String>) -> Self {
        Self {
            dir: dir.into(),
            backend_id: backend_id.into(),
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn key(&self, point: &SweepPoint) -> String {
        cache_key(point, &self.backend_id)
    }

    fn record_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    /// Look up a point; `None` on miss *or* on any record defect.
    pub fn load(&self, point: &SweepPoint) -> Option<MeasuredSnr> {
        let key = self.key(point);
        let text = std::fs::read_to_string(self.record_path(&key)).ok()?;
        decode_record(&text, &key)
    }

    /// Persist a computed result for a point.
    pub fn store(&self, point: &SweepPoint, measured: &MeasuredSnr) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating cache dir {}", self.dir.display()))?;
        let key = self.key(point);
        let record = encode_record(point, &self.backend_id, &key, measured);
        let path = self.record_path(&key);
        std::fs::write(&path, record.to_string())
            .with_context(|| format!("writing cache record {}", path.display()))?;
        Ok(())
    }

    /// Merge `(key, id)` pairs into `manifest.json`. A missing or corrupt
    /// manifest is rebuilt from scratch.
    pub fn update_manifest(&self, entries: &[(String, String)]) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        std::fs::create_dir_all(&self.dir)?;
        let path = self.dir.join("manifest.json");
        let mut index: BTreeMap<String, Json> = std::fs::read_to_string(&path)
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|j| j.get("entries").and_then(|e| e.as_obj()).cloned())
            .unwrap_or_default();
        for (key, id) in entries {
            index.insert(key.clone(), Json::Str(id.clone()));
        }
        let manifest = obj(vec![
            ("version", num(CACHE_VERSION)),
            ("backend", s(&self.backend_id)),
            ("entries", Json::Obj(index)),
        ]);
        std::fs::write(&path, manifest.to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

fn encode_record(point: &SweepPoint, backend_id: &str, key: &str, m: &MeasuredSnr) -> Json {
    let dist = match point.dist {
        InputDist::Uniform => "uniform".to_string(),
        InputDist::ClippedGaussian { sx, sw } => {
            format!("gauss:{:016x}:{:016x}", sx.to_bits(), sw.to_bits())
        }
    };
    obj(vec![
        ("version", num(CACHE_VERSION)),
        ("key", s(key)),
        ("id", s(&point.id)),
        ("kind", s(point.kind.artifact_name())),
        ("backend", s(backend_id)),
        ("trials", num(point.trials as f64)),
        ("seed", s(&format!("{:016x}", point.seed))),
        ("dist", s(&dist)),
        (
            "params",
            Json::Arr(point.params.iter().map(|&p| f64_hex(p)).collect()),
        ),
        ("measured_trials", num(m.trials as f64)),
        (
            "measured_bits",
            obj(vec![
                ("sigma_yo2", f64_hex(m.sigma_yo2)),
                ("sigma_qiy2", f64_hex(m.sigma_qiy2)),
                ("sigma_eta_a2", f64_hex(m.sigma_eta_a2)),
                ("sigma_qy2", f64_hex(m.sigma_qy2)),
                ("sqnr_qiy_db", f64_hex(m.sqnr_qiy_db)),
                ("snr_a_db", f64_hex(m.snr_a_db)),
                ("snr_a_total_db", f64_hex(m.snr_a_total_db)),
                ("snr_t_db", f64_hex(m.snr_t_db)),
            ]),
        ),
    ])
}

fn decode_record(text: &str, key: &str) -> Option<MeasuredSnr> {
    let j = Json::parse(text).ok()?;
    if j.get("version")?.as_f64()? != CACHE_VERSION {
        return None;
    }
    if j.get("key")?.as_str()? != key {
        return None;
    }
    let bits = j.get("measured_bits")?;
    let field = |name: &str| -> Option<f64> {
        let hex = bits.get(name)?.as_str()?;
        u64::from_str_radix(hex, 16).ok().map(f64::from_bits)
    };
    Some(MeasuredSnr {
        sigma_yo2: field("sigma_yo2")?,
        sigma_qiy2: field("sigma_qiy2")?,
        sigma_eta_a2: field("sigma_eta_a2")?,
        sigma_qy2: field("sigma_qy2")?,
        sqnr_qiy_db: field("sqnr_qiy_db")?,
        snr_a_db: field("snr_a_db")?,
        snr_a_total_db: field("snr_a_total_db")?,
        snr_t_db: field("snr_t_db")?,
        trials: j.get("measured_trials")?.as_f64()? as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pvec;

    fn point(id: &str) -> SweepPoint {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = 64.0;
        p[pvec::IDX_BX] = 6.0;
        p[pvec::IDX_BW] = 6.0;
        p[pvec::IDX_B_ADC] = 8.0;
        SweepPoint::new(id, ArchKind::Qs, p)
            .with_trials(128)
            .with_seed(0xFEED)
    }

    fn tmp_cache(name: &str) -> ResultCache {
        let dir = std::env::temp_dir().join(format!("imclim-cache-unit-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        ResultCache::new(dir, "native")
    }

    #[test]
    fn key_is_stable_and_content_addressed() {
        let p = point("a");
        assert_eq!(cache_key(&p, "native"), cache_key(&p, "native"));
        assert_eq!(cache_key(&p, "native").len(), 32);
        // the label does not participate
        let renamed = point("totally-different-label");
        assert_eq!(cache_key(&p, "native"), cache_key(&renamed, "native"));
        // the backend does
        assert_ne!(cache_key(&p, "native"), cache_key(&p, "pjrt"));
    }

    #[test]
    fn roundtrip_is_bit_identical_even_for_non_finite() {
        let cache = tmp_cache("roundtrip");
        let p = point("r");
        let m = MeasuredSnr {
            sigma_yo2: 1.234e-5,
            sigma_qiy2: 0.0,
            sigma_eta_a2: 7.7,
            sigma_qy2: f64::NAN,
            sqnr_qiy_db: f64::INFINITY,
            snr_a_db: -13.25,
            snr_a_total_db: f64::NEG_INFINITY,
            snr_t_db: 42.125,
            trials: 128,
        };
        cache.store(&p, &m).unwrap();
        let got = cache.load(&p).expect("hit");
        assert_eq!(got.sigma_yo2.to_bits(), m.sigma_yo2.to_bits());
        assert_eq!(got.sigma_qy2.to_bits(), m.sigma_qy2.to_bits());
        assert_eq!(got.sqnr_qiy_db.to_bits(), m.sqnr_qiy_db.to_bits());
        assert_eq!(got.snr_a_total_db.to_bits(), m.snr_a_total_db.to_bits());
        assert_eq!(got.snr_t_db.to_bits(), m.snr_t_db.to_bits());
        assert_eq!(got.trials, m.trials);
    }

    #[test]
    fn defective_records_are_misses_not_errors() {
        let cache = tmp_cache("defects");
        let p = point("d");
        assert!(cache.load(&p).is_none(), "cold cache misses");
        cache.store(&p, &MeasuredSnr::default()).unwrap();
        assert!(cache.load(&p).is_some());
        let path = cache.record_path(&cache.key(&p));
        for garbage in ["", "{ not json", "{\"version\": 1}", "[1,2,3]"] {
            std::fs::write(&path, garbage).unwrap();
            assert!(cache.load(&p).is_none(), "corrupt record {garbage:?}");
        }
        // a record stored under the wrong key is rejected too
        cache.store(&p, &MeasuredSnr::default()).unwrap();
        let other = {
            let mut o = point("d");
            o.seed = 999;
            o
        };
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(cache.record_path(&cache.key(&other)), text).unwrap();
        assert!(cache.load(&other).is_none(), "key mismatch is a miss");
    }

    #[test]
    fn manifest_merges_entries() {
        let cache = tmp_cache("manifest");
        cache
            .update_manifest(&[("k1".into(), "id1".into())])
            .unwrap();
        cache
            .update_manifest(&[("k2".into(), "id2".into()), ("k1".into(), "id1b".into())])
            .unwrap();
        let text = std::fs::read_to_string(cache.dir().join("manifest.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        let entries = j.get("entries").unwrap().as_obj().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries["k1"].as_str(), Some("id1b"));
        assert_eq!(entries["k2"].as_str(), Some("id2"));
    }
}
