//! Fig. 12: column-ADC energy vs N under MPC vs BGC for the three
//! architectures (Bx = Bw = 6; V_WL = 0.7 V for QS-Arch, 0.8 V for CM,
//! C_o = 3 fF for QR-Arch).
//!
//! Expected shapes (Sec. V-C): QS-Arch E_ADC constant (BGC) / falling
//! (MPC) with N; QR-Arch and CM E_ADC ~ N^2 under BGC vs ~ N under MPC.

use super::{uniform_stats, FigCtx, FigSummary};
use crate::arch::{AdcCriterion, CmArch, ImcArch, OpPoint, QrArch, QsArch};
use crate::compute::{qr::QrModel, qs::QsModel};
use crate::tech::TechNode;
use crate::util::csv::CsvWriter;

pub const NS: [usize; 6] = [16, 32, 64, 128, 256, 512];

pub fn run(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let archs: Vec<(&str, Box<dyn ImcArch>)> = vec![
        (
            "qs",
            Box::new(QsArch::new(QsModel::new(TechNode::n65(), 0.7))),
        ),
        (
            "qr",
            Box::new(QrArch::new(QrModel::new(TechNode::n65(), 3.0))),
        ),
        (
            "cm",
            Box::new(CmArch::new(
                QsModel::new(TechNode::n65(), 0.8),
                QrModel::new(TechNode::n65(), 3.0),
            )),
        ),
    ];

    let mut csv = CsvWriter::new(&[
        "arch", "n", "crit", "b_adc", "e_adc_j", "e_total_j",
    ]);
    let mut checks = Vec::new();
    for (name, arch) in &archs {
        let mut ratios = Vec::new();
        for &n in &NS {
            let op = OpPoint::new(n, 6, 6, 8);
            for (crit, label) in [(AdcCriterion::Mpc, "mpc"), (AdcCriterion::Bgc, "bgc")] {
                let b = arch.b_adc_for(&op, crit, &w, &x);
                let e = arch.energy(&op, crit, &w, &x);
                csv.row(&[
                    name.to_string(),
                    n.to_string(),
                    label.to_string(),
                    b.to_string(),
                    format!("{:.6e}", e.adc),
                    format!("{:.6e}", e.total()),
                ]);
                if label == "mpc" {
                    ratios.push(e.adc);
                }
            }
        }
        // growth of MPC ADC energy from smallest to largest N
        let growth = ratios.last().unwrap() / ratios.first().unwrap();
        checks.push((format!("{name}_mpc_growth"), growth));
        // BGC/MPC energy ratio at the largest N
        let op = OpPoint::new(*NS.last().unwrap(), 6, 6, 8);
        let bgc = arch.energy(&op, AdcCriterion::Bgc, &w, &x).adc;
        let mpc = arch.energy(&op, AdcCriterion::Mpc, &w, &x).adc;
        checks.push((format!("{name}_bgc_over_mpc"), bgc / mpc));
        println!(
            "Fig. 12 [{name}]: MPC E_ADC growth (N 16->512) = {growth:.2}x; BGC/MPC at N=512 = {:.1}x",
            bgc / mpc
        );
    }
    csv.write_to(&ctx.csv_path("fig12"))?;
    Ok(FigSummary {
        name: "fig12".into(),
        rows: NS.len() * archs.len() * 2,
        checks,
    })
}
