//! Table regeneration: Table I (taxonomy), Table II (65 nm parameters),
//! Table III (closed-form expressions validated against the
//! sample-accurate simulator — the paper's E-vs-S methodology, Fig. 8).

use super::{sweep_point, uniform_stats, FigCtx, FigSummary};
use crate::arch::{CmArch, ImcArch, OpPoint, QrArch, QsArch};
use crate::compute::{qr::QrModel, qs::QsModel};
use crate::engine::{AxisValue, SweepSpec};
use crate::mc::ArchKind;
use crate::taxonomy::{model_counts, table1 as tax_table, AdcPrecision, WeightPrecision};
use crate::tech::TechNode;
use crate::util::csv::CsvWriter;
use crate::util::stats::db;
use crate::util::table::Table;

pub fn table1(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let designs = tax_table();
    let mut tbl = Table::new(&["design", "QS", "IS", "QR", "Bx", "Bw", "B_ADC"])
        .with_title("Table I — taxonomy of CMOS IMC designs");
    let mut csv = CsvWriter::new(&["design", "year", "qs", "is", "qr", "bx", "bw", "b_adc"]);
    let fmt_w = |w: &WeightPrecision| match w {
        WeightPrecision::Bits(b) => b.to_string(),
        WeightPrecision::Ternary => "T".into(),
        WeightPrecision::Analog => "A".into(),
    };
    let fmt_a = |a: &AdcPrecision| match a {
        AdcPrecision::Bits(b) => b.to_string(),
        AdcPrecision::Analog => "A".into(),
        AdcPrecision::Effective10x(b) => format!("{:.2}", *b as f64 / 10.0),
    };
    let tick = |b: bool| if b { "x".to_string() } else { String::new() };
    for d in &designs {
        tbl.row(vec![
            d.name.into(),
            tick(d.qs),
            tick(d.is),
            tick(d.qr),
            fmt_w(&d.bx),
            fmt_w(&d.bw),
            fmt_a(&d.b_adc),
        ]);
        csv.row(&[
            d.name.to_string(),
            d.year.to_string(),
            d.qs.to_string(),
            d.is.to_string(),
            d.qr.to_string(),
            fmt_w(&d.bx),
            fmt_w(&d.bw),
            fmt_a(&d.b_adc),
        ]);
    }
    csv.write_to(&ctx.csv_path("table1"))?;
    println!("{}", tbl.render());
    let (qs, is, qr) = model_counts(&designs);
    Ok(FigSummary {
        name: "table1".into(),
        rows: designs.len(),
        checks: vec![
            ("designs".into(), designs.len() as f64),
            ("qs_count".into(), qs as f64),
            ("is_count".into(), is as f64),
            ("qr_count".into(), qr as f64),
        ],
    })
}

pub fn table2(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let t = TechNode::n65();
    let rows: Vec<(&str, String)> = vec![
        ("k' (uA/V^2)", format!("{}", t.k_prime * 1e6)),
        ("alpha", format!("{}", t.alpha)),
        ("sigma_T0 (ps)", format!("{}", t.sigma_t0 * 1e12)),
        ("sigma_Vt (mV)", format!("{}", t.sigma_vt * 1e3)),
        ("dV_BL,max (V)", format!("{}", t.dv_bl_max)),
        ("V_t (V)", format!("{}", t.v_t)),
        ("T_0 (ps)", format!("{}", t.t0 * 1e12)),
        ("WL*Cox (fF)", format!("{}", t.wl_cox * 1e15)),
        ("kappa (fF^0.5)", format!("{}", t.kappa_ff)),
        ("p", format!("{}", t.p_inj)),
        ("V_dd (V)", format!("{}", t.v_dd)),
        ("g_m (uA/V)", format!("{}", t.g_m * 1e6)),
    ];
    let mut tbl = Table::new(&["parameter", "value"])
        .with_title("Table II — 65 nm compute-model parameters");
    let mut csv = CsvWriter::new(&["parameter", "value"]);
    for (k, v) in &rows {
        tbl.row(vec![k.to_string(), v.clone()]);
        csv.row(&[k.to_string(), v.clone()]);
    }
    csv.write_to(&ctx.csv_path("table2"))?;
    println!("{}", tbl.render());
    Ok(FigSummary {
        name: "table2".into(),
        rows: rows.len(),
        checks: vec![("params".into(), rows.len() as f64)],
    })
}

/// Table III validation: closed-form sigma_eta^2 and derived SNRs vs the
/// sample-accurate simulator at a grid of operating points on all three
/// architectures.
pub fn table3(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    struct Case {
        label: String,
        closed_eta2: f64,
        closed_snr_a_db: f64,
        point: crate::coordinator::SweepPoint,
    }
    let mut cases: Vec<Case> = Vec::new();
    let pairs = |items: &[(f64, usize)]| -> Vec<Vec<AxisValue>> {
        items
            .iter()
            .map(|&(knob, dim)| vec![AxisValue::Num(knob), AxisValue::Int(dim as i64)])
            .collect()
    };

    // QS-Arch grid
    let qs_spec = SweepSpec::new("t3/qs").axis_tuples(
        &["vwl", "n"],
        pairs(&[(0.8, 64), (0.8, 128), (0.7, 128), (0.6, 256)]),
    );
    for gp in qs_spec.points() {
        let v_wl = gp.num(0);
        let n = gp.int(1) as usize;
        let arch = QsArch::new(QsModel::new(TechNode::n65(), v_wl));
        let op = OpPoint::new(n, 6, 6, 14);
        let nb = arch.noise(&op, &w, &x);
        cases.push(Case {
            label: format!("QS v={v_wl} N={n}"),
            closed_eta2: nb.sigma_eta_a2(),
            closed_snr_a_db: nb.snr_a_total_db(),
            point: sweep_point(&arch, ArchKind::Qs, gp.id, &op, ctx.trials, 31 + n as u64),
        });
    }
    // QR-Arch grid
    let qr_spec = SweepSpec::new("t3/qr")
        .axis_tuples(&["c", "n"], pairs(&[(1.0, 128), (3.0, 128), (9.0, 256)]));
    for gp in qr_spec.points() {
        let c_ff = gp.num(0);
        let n = gp.int(1) as usize;
        let arch = QrArch::new(QrModel::new(TechNode::n65(), c_ff));
        let op = OpPoint::new(n, 6, 7, 14);
        let nb = arch.noise(&op, &w, &x);
        cases.push(Case {
            label: format!("QR C={c_ff} N={n}"),
            closed_eta2: nb.sigma_eta_a2(),
            closed_snr_a_db: nb.snr_a_total_db(),
            point: sweep_point(&arch, ArchKind::Qr, gp.id, &op, ctx.trials, 57 + n as u64),
        });
    }
    // CM grid
    let cm_spec = SweepSpec::new("t3/cm")
        .axis_tuples(&["vwl", "bw"], pairs(&[(0.8, 5), (0.8, 6), (0.7, 7)]));
    for gp in cm_spec.points() {
        let v_wl = gp.num(0);
        let bw = gp.int(1) as u32;
        let arch = CmArch::new(
            QsModel::new(TechNode::n65(), v_wl),
            QrModel::new(TechNode::n65(), 3.0),
        );
        let op = OpPoint::new(64, 6, bw, 14);
        let nb = arch.noise(&op, &w, &x);
        cases.push(Case {
            label: format!("CM v={v_wl} Bw={bw}"),
            closed_eta2: nb.sigma_eta_a2(),
            closed_snr_a_db: nb.snr_a_total_db(),
            point: sweep_point(&arch, ArchKind::Cm, gp.id, &op, ctx.trials, 91 + bw as u64),
        });
    }

    let points: Vec<_> = cases.iter().map(|c| c.point.clone()).collect();
    let results = ctx.run_points(points);

    let mut tbl = Table::new(&[
        "case",
        "eta2 (E)",
        "eta2 (S)",
        "gap dB",
        "SNR_A E",
        "SNR_A S",
    ])
    .with_title("Table III validation — closed form (E) vs simulation (S)");
    let mut csv = CsvWriter::new(&[
        "case",
        "closed_eta2",
        "sim_eta2",
        "gap_db",
        "closed_snr_a_db",
        "sim_snr_a_db",
    ]);
    let mut max_gap: f64 = 0.0;
    for (c, r) in cases.iter().zip(&results) {
        let sim_eta2 = r.measured.sigma_eta_a2;
        let gap = db(sim_eta2 / c.closed_eta2);
        max_gap = max_gap.max(gap.abs());
        tbl.row(vec![
            c.label.clone(),
            format!("{:.3e}", c.closed_eta2),
            format!("{:.3e}", sim_eta2),
            format!("{gap:+.2}"),
            format!("{:.1}", c.closed_snr_a_db),
            format!("{:.1}", r.measured.snr_a_total_db),
        ]);
        csv.row(&[
            c.label.clone(),
            format!("{:.6e}", c.closed_eta2),
            format!("{:.6e}", sim_eta2),
            format!("{gap:.3}"),
            format!("{:.3}", c.closed_snr_a_db),
            format!("{:.3}", r.measured.snr_a_total_db),
        ]);
    }
    csv.write_to(&ctx.csv_path("table3"))?;
    println!("{}", tbl.render());
    println!("Table III: max |E-S| noise-power gap = {max_gap:.2} dB over {} cases", cases.len());
    Ok(FigSummary {
        name: "table3".into(),
        rows: cases.len(),
        checks: vec![("max_e_s_gap_db".into(), max_gap)],
    })
}
