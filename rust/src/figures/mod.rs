//! Figure/table regeneration drivers: one module per figure or table in
//! the paper's evaluation (see DESIGN.md §4 for the experiment index).
//! Every driver emits a CSV under the output directory and an ASCII
//! rendering to stdout, and returns a short machine-checkable summary
//! used by integration tests and EXPERIMENTS.md.
//!
//! All Monte-Carlo sweeps run through the unified engine
//! ([`FigCtx::run_points`]): grids come from `engine::SweepSpec`, and
//! results are served from the content-addressed cache under
//! `<out_dir>/cache`, so re-running a driver with the same out-dir
//! recomputes nothing and reproduces the cold run byte-for-byte.

pub mod ablation;
pub mod banked;
pub mod fig12;
pub mod fig13;
pub mod fig2;
pub mod fig4;
pub mod fig9;
pub mod fig10;
pub mod fig11;
pub mod tables;

use std::path::PathBuf;

use crate::coordinator::{Backend, SweepPoint, SweepResult};
use crate::engine::Engine;

/// Shared driver context.
pub struct FigCtx {
    pub backend: Backend,
    pub out_dir: PathBuf,
    /// MC trials per sweep point (the trial *cap* when `precision` is
    /// set).
    pub trials: usize,
    /// Adaptive-precision target (95% CI half-width, dB) for the sweep
    /// and pareto-validate drivers; `None` = fixed `trials` ensembles.
    /// Figure drivers ignore it — their golden checks pin fixed-trials
    /// ensembles.
    pub precision: Option<f64>,
    pub workers: usize,
    pub verbose: bool,
    /// Serve repeated points from the content-addressed result cache
    /// under `out_dir/cache` (on by default; `--no-cache` in the CLI).
    pub cache: bool,
    /// Override the cache root. `None` = `out_dir/cache`; the serve
    /// daemon points every job at one shared cache directory while each
    /// job keeps its own out-dir for CSVs.
    pub cache_dir: Option<PathBuf>,
}

impl FigCtx {
    pub fn native(out_dir: impl Into<PathBuf>) -> Self {
        Self {
            backend: Backend::Native,
            out_dir: out_dir.into(),
            trials: 2048,
            precision: None,
            workers: crate::coordinator::SweepOptions::default().workers,
            verbose: false,
            cache: true,
            cache_dir: None,
        }
    }

    pub fn sweep_opts(&self) -> crate::coordinator::SweepOptions {
        crate::coordinator::SweepOptions {
            workers: self.workers,
            verbose: self.verbose,
        }
    }

    /// The sweep engine this context drives (cache rooted at
    /// `cache_dir`, defaulting to `out_dir/cache`, unless disabled).
    pub fn engine(&self) -> Engine {
        let engine = Engine::new(self.backend.clone(), self.sweep_opts());
        if self.cache {
            let dir = self
                .cache_dir
                .clone()
                .unwrap_or_else(|| self.out_dir.join("cache"));
            engine.with_cache(dir)
        } else {
            engine
        }
    }

    /// Run sweep points through the engine (cache-aware, input order).
    pub fn run_points(&self, points: Vec<SweepPoint>) -> Vec<SweepResult> {
        let (results, stats) = self.engine().run_with_stats(points);
        if self.verbose {
            eprintln!(
                "[engine] {} points: {} cache hits, {} computed, {} errors",
                results.len(),
                stats.hits,
                stats.misses,
                stats.errors
            );
        }
        results
    }

    pub fn csv_path(&self, name: &str) -> PathBuf {
        self.out_dir.join(format!("{name}.csv"))
    }
}

/// Summary of one regenerated figure: key quantitative checks that the
/// integration tests (and EXPERIMENTS.md) assert on.
#[derive(Clone, Debug, Default)]
pub struct FigSummary {
    pub name: String,
    pub rows: usize,
    /// (check name, value) pairs; semantics per figure.
    pub checks: Vec<(String, f64)>,
}

impl FigSummary {
    pub fn check(&self, name: &str) -> Option<f64> {
        self.checks
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
    }
}

/// Build a sweep point for an architecture at an operating point, with
/// the default uniform signal statistics used throughout Sec. V.
pub fn sweep_point(
    arch: &dyn crate::arch::ImcArch,
    kind: crate::mc::ArchKind,
    id: String,
    op: &crate::arch::OpPoint,
    trials: usize,
    seed: u64,
) -> crate::coordinator::SweepPoint {
    let w = crate::quant::SignalStats::uniform_signed(1.0);
    let x = crate::quant::SignalStats::uniform_unsigned(1.0);
    crate::coordinator::SweepPoint::new(id, kind, arch.pjrt_params(op, &w, &x))
        .with_trials(trials)
        .with_seed(seed)
}

/// Default uniform signal statistics (w signed, x unsigned).
pub fn uniform_stats() -> (crate::quant::SignalStats, crate::quant::SignalStats) {
    (
        crate::quant::SignalStats::uniform_signed(1.0),
        crate::quant::SignalStats::uniform_unsigned(1.0),
    )
}

/// All figure names, in paper order.
pub const ALL_FIGURES: &[&str] = &[
    "fig2", "fig4a", "fig4b", "fig9a", "fig9b", "fig10a", "fig10b", "fig11a",
    "fig11b", "fig12", "fig13", "banked", "table1", "table2", "table3",
    "ablation",
];

/// Dispatch by name ("all" runs everything).
pub fn run(name: &str, ctx: &FigCtx) -> anyhow::Result<Vec<FigSummary>> {
    let mut out = Vec::new();
    let names: Vec<&str> = if name == "all" {
        ALL_FIGURES.to_vec()
    } else {
        vec![name]
    };
    for n in names {
        let s = match n {
            "fig2" => fig2::run(ctx)?,
            "fig4a" => fig4::run_a(ctx)?,
            "fig4b" => fig4::run_b(ctx)?,
            "fig9a" => fig9::run_a(ctx)?,
            "fig9b" => fig9::run_b(ctx)?,
            "fig10a" => fig10::run_a(ctx)?,
            "fig10b" => fig10::run_b(ctx)?,
            "fig11a" => fig11::run_a(ctx)?,
            "fig11b" => fig11::run_b(ctx)?,
            "fig12" => fig12::run(ctx)?,
            "fig13" => fig13::run(ctx)?,
            "banked" => banked::run(ctx)?,
            "table1" => tables::table1(ctx)?,
            "table2" => tables::table2(ctx)?,
            "table3" => tables::table3(ctx)?,
            "ablation" => ablation::run(ctx)?,
            other => anyhow::bail!("unknown figure '{other}' (try one of {ALL_FIGURES:?})"),
        };
        out.push(s);
    }
    Ok(out)
}
