//! Fig. 4: SQNR_qy of the three output-precision criteria.
//! (a) SQNR_qy vs N for MPC (B_y = 8, zeta = 4), BGC, tBGC (B_y = 8);
//! (b) SQNR_qy^MPC vs zeta at B_y = 8 — the quantization-vs-clipping
//! trade-off, maximized at zeta = 4.
//! Closed forms (eqs. 9, 13, 14) are validated against Monte-Carlo.

use super::{FigCtx, FigSummary};
use crate::engine::EsReport;
use crate::quant::criteria::{bgc_bits, bgc_sqnr_db, mpc_sqnr_db};
use crate::quant::{adc_signed, SignalStats};
use crate::util::csv::CsvWriter;
use crate::util::rng::Pcg64;
use crate::util::stats::{db, Welford};
use crate::util::table::Table;

/// Monte-Carlo SQNR of quantizing DP outputs y_o = w^T x with a B-bit
/// mid-tread quantizer clipped at y_c. Deterministic in its arguments,
/// which is what lets the drivers serve it from the engine's memo cache.
fn mc_sqnr_db(n: usize, by: u32, y_c_over_sigma: f64, trials: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed);
    let mut sig = Welford::new();
    let mut noise = Welford::new();
    // sigma of the DP: sqrt(N * sigma_w^2 * E[x^2]) = sqrt(N/9)
    let sigma = (n as f64 / 9.0).sqrt();
    let y_c = y_c_over_sigma * sigma;
    for _ in 0..trials {
        let mut y = 0.0;
        for _ in 0..n {
            y += rng.uniform_in(-1.0, 1.0) * rng.uniform();
        }
        let yq = adc_signed(y.clamp(-y_c, y_c), y_c, by.min(24));
        sig.push(y);
        noise.push(yq - y);
    }
    db(sig.variance() / noise.variance())
}

pub fn run_a(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let w = SignalStats::uniform_signed(1.0);
    let x = SignalStats::uniform_unsigned(1.0);
    let (bx, bw) = (7u32, 7u32);
    let ns: Vec<usize> = (6..=13).map(|e| 1usize << e).collect();
    let trials = ctx.trials.max(2000);

    // Serve the bespoke DP-quantization MC from the engine's memo cache:
    // a warm re-run of this driver performs zero Monte-Carlo trials.
    let engine = ctx.engine();
    let mut mc_points = 0usize;
    let mut mc_cached = 0usize;
    let mut mc = |label: String, n: usize, by: u32, zeta: f64, seed: u64| -> f64 {
        mc_points += 1;
        let params = [n as f64, by as f64, zeta, trials as f64, seed as f64];
        let (values, hit) = engine.memo("fig4/mc_sqnr", &params, &label, || {
            vec![mc_sqnr_db(n, by, zeta, trials, seed)]
        });
        match values.first().copied() {
            Some(v) => {
                if hit {
                    mc_cached += 1;
                }
                v
            }
            // decodable-but-empty record: degrade to recompute (not
            // counted as cached) and repair the record in place
            None => {
                let v = mc_sqnr_db(n, by, zeta, trials, seed);
                engine.memo_repair("fig4/mc_sqnr", &params, &label, &[v]);
                v
            }
        }
    };

    let mut csv = CsvWriter::new(&[
        "n",
        "mpc_by",
        "mpc_db",
        "mpc_mc_db",
        "bgc_by",
        "bgc_db",
        "tbgc_by",
        "tbgc_db",
        "tbgc_mc_db",
    ]);
    let mut tbl = Table::new(&["N", "MPC(8b)", "BGC", "B_y^BGC", "tBGC(8b)"])
        .with_title("Fig. 4(a) — SQNR_qy (dB) vs N, Bx=Bw=7");
    let mut mpc_mc_err_max: f64 = 0.0;
    for &n in &ns {
        let mpc = mpc_sqnr_db(8, 4.0);
        let mpc_mc = mc(format!("fig4a/mpc/n={n}"), n, 8, 4.0, 42 + n as u64);
        mpc_mc_err_max = mpc_mc_err_max.max((mpc - mpc_mc).abs());
        let bgc = bgc_sqnr_db(bx, bw, n, &w, &x);
        let by_bgc = bgc_bits(bx, bw, n);
        // tBGC at 8 bits: full range (zeta_y = y_m / sigma), no clipping.
        let zeta_y = (n as f64) / (n as f64 / 9.0).sqrt(); // y_m / sigma = 3 sqrt(N)
        let tbgc = crate::quant::sqnr_db_eq1(8, db(zeta_y * zeta_y));
        let tbgc_mc = mc(format!("fig4a/tbgc/n={n}"), n, 8, zeta_y, 77 + n as u64);
        csv.row_f64(&[
            n as f64,
            8.0,
            mpc,
            mpc_mc,
            by_bgc as f64,
            bgc,
            8.0,
            tbgc,
            tbgc_mc,
        ]);
        tbl.row(vec![
            n.to_string(),
            format!("{mpc:.1}"),
            format!("{bgc:.1}"),
            by_bgc.to_string(),
            format!("{tbgc:.1}"),
        ]);
    }
    csv.write_to(&ctx.csv_path("fig4a"))?;
    println!("{}", tbl.render());

    Ok(FigSummary {
        name: "fig4a".into(),
        rows: ns.len(),
        checks: vec![
            ("mpc_at_8b_db".into(), mpc_sqnr_db(8, 4.0)),
            ("mpc_mc_err_max_db".into(), mpc_mc_err_max),
            ("bgc_bits_min".into(), bgc_bits(7, 7, ns[0]) as f64),
            ("bgc_bits_max".into(), bgc_bits(7, 7, *ns.last().unwrap()) as f64),
            ("mc_points".into(), mc_points as f64),
            ("mc_cached_points".into(), mc_cached as f64),
        ],
    })
}

/// Gaussian-output clip+quantize MC (CLT regime: N = 512); deterministic
/// in its arguments, served through the engine's memo cache by `run_b`.
fn gauss_mc_db(by: u32, zeta: f64, trials: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed);
    let mut sig = Welford::new();
    let mut noise = Welford::new();
    for _ in 0..trials {
        let y = rng.normal();
        let yq = adc_signed(y.clamp(-zeta, zeta), zeta, by);
        sig.push(y);
        noise.push(yq - y);
    }
    db(sig.variance() / noise.variance())
}

pub fn run_b(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let by = 8u32;
    let zetas: Vec<f64> = (2..=16).map(|z| z as f64 * 0.5).collect();
    // Clipping events are rare near the optimum (p_c ~ 1e-4 at zeta = 4),
    // so the E-S comparison needs a deep ensemble to resolve them.
    let trials = (ctx.trials * 150).max(300_000);
    let engine = ctx.engine();
    let mut mc_cached = 0usize;
    let mut report = EsReport::new(&["zeta", "mpc_db", "mc_db"]);
    let mut best = (0.0, f64::MIN);
    for &z in &zetas {
        let pred = mpc_sqnr_db(by, z);
        let seed = 1000 + (z * 10.0) as u64;
        let label = format!("fig4b/zeta={z}");
        let params = [by as f64, z, trials as f64, seed as f64];
        let (values, hit) = engine.memo("fig4b/gauss_mc", &params, &label, || {
            vec![gauss_mc_db(by, z, trials, seed)]
        });
        let mc = match values.first().copied() {
            Some(v) => {
                if hit {
                    mc_cached += 1;
                }
                v
            }
            // decodable-but-empty record: degrade to recompute and
            // repair the record in place
            None => {
                let v = gauss_mc_db(by, z, trials, seed);
                engine.memo_repair("fig4b/gauss_mc", &params, &label, &[v]);
                v
            }
        };
        if pred > best.1 {
            best = (z, pred);
        }
        report.push(&[z], pred, mc);
    }
    report.write_to(&ctx.csv_path("fig4b"))?;
    let max_err = report.max_gap();
    println!(
        "Fig. 4(b): SQNR_qy^MPC(B_y=8) maximized at zeta = {} ({:.2} dB); max |E-S| = {:.2} dB",
        best.0, best.1, max_err
    );
    Ok(FigSummary {
        name: "fig4b".into(),
        rows: zetas.len(),
        checks: vec![
            ("best_zeta".into(), best.0),
            ("best_db".into(), best.1),
            ("max_e_s_gap_db".into(), max_err),
            ("mc_points".into(), zetas.len() as f64),
            ("mc_cached_points".into(), mc_cached as f64),
        ],
    })
}
