//! Fig. 9: SNR trade-offs in QS-Arch (Bx = Bw = 6).
//! (a) SNR_A vs N for V_WL in {0.5..0.8 V}: plateau then collapse at
//!     N_max, higher V_WL -> higher plateau but earlier collapse;
//! (b) SNR_T vs B_ADC: saturates at SNR_A once B_ADC clears the Table III
//!     lower bound (circled value).
//! E (closed form) and S (sample-accurate simulation) on every point.

use super::{sweep_point, uniform_stats, FigCtx, FigSummary};
use crate::arch::{ImcArch, OpPoint, QsArch};
use crate::compute::qs::QsModel;
use crate::coordinator::run_sweep;
use crate::mc::ArchKind;
use crate::tech::TechNode;
use crate::util::csv::CsvWriter;

pub const V_WLS: [f64; 4] = [0.5, 0.6, 0.7, 0.8];
pub const NS: [usize; 9] = [16, 32, 48, 64, 96, 128, 192, 320, 512];

pub fn run_a(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let mut points = Vec::new();
    let mut expected = Vec::new();
    for &v_wl in &V_WLS {
        let arch = QsArch::new(QsModel::new(TechNode::n65(), v_wl));
        for &n in &NS {
            let op = OpPoint::new(n, 6, 6, 14);
            expected.push((v_wl, n, arch.noise(&op, &w, &x).snr_a_total_db()));
            points.push(sweep_point(
                &arch,
                ArchKind::Qs,
                format!("fig9a/vwl={v_wl}/n={n}"),
                &op,
                ctx.trials,
                0x9A + n as u64,
            ));
        }
    }
    let results = run_sweep(points, ctx.backend.clone(), ctx.sweep_opts());

    let mut csv = CsvWriter::new(&["v_wl", "n", "snr_a_closed_db", "snr_a_sim_db"]);
    let mut max_gap: f64 = 0.0;
    let mut peak: f64 = f64::MIN;
    for ((v_wl, n, e_db), r) in expected.iter().zip(&results) {
        let s_db = r.measured.snr_a_total_db;
        // E-S agreement only meaningful away from the clipping cliff where
        // the binomial-tail approximation is loose
        if *e_db > 5.0 && s_db > 5.0 {
            max_gap = max_gap.max((e_db - s_db).abs());
        }
        peak = peak.max(s_db);
        csv.row_f64(&[*v_wl, *n as f64, *e_db, s_db]);
    }
    csv.write_to(&ctx.csv_path("fig9a"))?;

    // headline shape checks (V_WL = 0.8)
    let sim = |v: f64, n: usize| {
        results
            .iter()
            .find(|r| r.id == format!("fig9a/vwl={v}/n={n}"))
            .unwrap()
            .measured
            .snr_a_total_db
    };
    let plateau_08 = sim(0.8, 64);
    let collapse_08 = plateau_08 - sim(0.8, 512);
    let plateau_06 = sim(0.6, 64);
    println!(
        "Fig. 9(a): QS-Arch plateau(0.8V)={plateau_08:.1} dB, collapse(512)={collapse_08:.1} dB, plateau(0.6V)={plateau_06:.1} dB, max|E-S|={max_gap:.2} dB"
    );
    Ok(FigSummary {
        name: "fig9a".into(),
        rows: results.len(),
        checks: vec![
            ("plateau_08_db".into(), plateau_08),
            ("collapse_08_db".into(), collapse_08),
            ("plateau_06_db".into(), plateau_06),
            ("max_e_s_gap_db".into(), max_gap),
        ],
    })
}

pub fn run_b(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let b_adcs: Vec<u32> = (2..=11).collect();
    let configs = [(0.8, 128usize), (0.7, 128), (0.8, 48)];

    let mut points = Vec::new();
    let mut meta = Vec::new();
    for &(v_wl, n) in &configs {
        let arch = QsArch::new(QsModel::new(TechNode::n65(), v_wl));
        let bound = arch.b_adc_min(&OpPoint::new(n, 6, 6, 8), &w, &x);
        for &b in &b_adcs {
            let op = OpPoint::new(n, 6, 6, b);
            meta.push((v_wl, n, b, bound, arch.noise(&op, &w, &x).snr_a_total_db()));
            points.push(sweep_point(
                &arch,
                ArchKind::Qs,
                format!("fig9b/vwl={v_wl}/n={n}/b={b}"),
                &op,
                ctx.trials,
                0x9B + b as u64,
            ));
        }
    }
    let results = run_sweep(points, ctx.backend.clone(), ctx.sweep_opts());

    let mut csv = CsvWriter::new(&[
        "v_wl",
        "n",
        "b_adc",
        "b_adc_min_pred",
        "snr_a_closed_db",
        "snr_t_sim_db",
    ]);
    let mut gap_at_bound: f64 = f64::MIN;
    for ((v_wl, n, b, bound, e_a), r) in meta.iter().zip(&results) {
        csv.row_f64(&[
            *v_wl,
            *n as f64,
            *b as f64,
            *bound as f64,
            *e_a,
            r.measured.snr_t_db,
        ]);
        if b == bound {
            // at the predicted minimum, SNR_T should be within ~1 dB of
            // the simulated SNR_A
            gap_at_bound = gap_at_bound.max(r.measured.snr_a_total_db - r.measured.snr_t_db);
        }
    }
    csv.write_to(&ctx.csv_path("fig9b"))?;
    println!(
        "Fig. 9(b): max SNR_A - SNR_T at the predicted minimum B_ADC = {gap_at_bound:.2} dB"
    );
    Ok(FigSummary {
        name: "fig9b".into(),
        rows: results.len(),
        checks: vec![("gap_at_bound_db".into(), gap_at_bound)],
    })
}
