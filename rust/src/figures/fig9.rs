//! Fig. 9: SNR trade-offs in QS-Arch (Bx = Bw = 6).
//! (a) SNR_A vs N for V_WL in {0.5..0.8 V}: plateau then collapse at
//!     N_max, higher V_WL -> higher plateau but earlier collapse;
//! (b) SNR_T vs B_ADC: saturates at SNR_A once B_ADC clears the Table III
//!     lower bound (circled value).
//! E (closed form) and S (sample-accurate simulation) on every point,
//! executed through the cached sweep engine.

use super::{sweep_point, uniform_stats, FigCtx, FigSummary};
use crate::arch::{ImcArch, OpPoint, QsArch};
use crate::compute::qs::QsModel;
use crate::engine::{AxisValue, BoundReport, EsReport, SweepSpec};
use crate::mc::ArchKind;
use crate::tech::TechNode;

pub const V_WLS: [f64; 4] = [0.5, 0.6, 0.7, 0.8];
pub const NS: [usize; 9] = [16, 32, 48, 64, 96, 128, 192, 320, 512];

pub fn run_a(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let spec = SweepSpec::new("fig9a")
        .axis_f64("vwl", &V_WLS)
        .axis_usize("n", &NS);
    let mut points = Vec::with_capacity(spec.len());
    let mut expected = Vec::with_capacity(spec.len());
    for gp in spec.points() {
        let v_wl = gp.num(0);
        let n = gp.int(1) as usize;
        let arch = QsArch::new(QsModel::new(TechNode::n65(), v_wl));
        let op = OpPoint::new(n, 6, 6, 14);
        expected.push((v_wl, n, arch.noise(&op, &w, &x).snr_a_total_db()));
        points.push(sweep_point(
            &arch,
            ArchKind::Qs,
            gp.id,
            &op,
            ctx.trials,
            0x9A + n as u64,
        ));
    }
    let results = ctx.run_points(points);

    // E-S agreement only meaningful away from the clipping cliff where
    // the binomial-tail approximation is loose, hence the 5 dB gate.
    let mut report = EsReport::gated(&["v_wl", "n", "snr_a_closed_db", "snr_a_sim_db"], 5.0);
    for ((v_wl, n, e_db), r) in expected.iter().zip(&results) {
        report.push(&[*v_wl, *n as f64], *e_db, r.measured.snr_a_total_db);
    }
    report.write_to(&ctx.csv_path("fig9a"))?;
    let max_gap = report.max_gap();

    // headline shape checks (V_WL = 0.8)
    let sim = |v: f64, n: usize| {
        results
            .iter()
            .find(|r| r.id == format!("fig9a/vwl={v}/n={n}"))
            .unwrap()
            .measured
            .snr_a_total_db
    };
    let plateau_08 = sim(0.8, 64);
    let collapse_08 = plateau_08 - sim(0.8, 512);
    let plateau_06 = sim(0.6, 64);
    println!(
        "Fig. 9(a): QS-Arch plateau(0.8V)={plateau_08:.1} dB, collapse(512)={collapse_08:.1} dB, plateau(0.6V)={plateau_06:.1} dB, max|E-S|={max_gap:.2} dB"
    );
    Ok(FigSummary {
        name: "fig9a".into(),
        rows: results.len(),
        checks: vec![
            ("plateau_08_db".into(), plateau_08),
            ("collapse_08_db".into(), collapse_08),
            ("plateau_06_db".into(), plateau_06),
            ("max_e_s_gap_db".into(), max_gap),
        ],
    })
}

pub fn run_b(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let b_adcs: Vec<u32> = (2..=11).collect();
    let configs = [(0.8, 128usize), (0.7, 128), (0.8, 48)];

    let spec = SweepSpec::new("fig9b")
        .axis_tuples(
            &["vwl", "n"],
            configs
                .iter()
                .map(|&(v, n)| vec![AxisValue::Num(v), AxisValue::Int(n as i64)])
                .collect(),
        )
        .axis_u32("b", &b_adcs);
    let mut points = Vec::with_capacity(spec.len());
    let mut meta = Vec::with_capacity(spec.len());
    for gp in spec.points() {
        let v_wl = gp.num(0);
        let n = gp.int(1) as usize;
        let b = gp.int(2) as u32;
        let arch = QsArch::new(QsModel::new(TechNode::n65(), v_wl));
        let bound = arch.b_adc_min(&OpPoint::new(n, 6, 6, 8), &w, &x);
        let op = OpPoint::new(n, 6, 6, b);
        meta.push((v_wl, n, b, bound, arch.noise(&op, &w, &x).snr_a_total_db()));
        points.push(sweep_point(
            &arch,
            ArchKind::Qs,
            gp.id,
            &op,
            ctx.trials,
            0x9B + b as u64,
        ));
    }
    let results = ctx.run_points(points);

    let mut report = BoundReport::new(&[
        "v_wl",
        "n",
        "b_adc",
        "b_adc_min_pred",
        "snr_a_closed_db",
        "snr_t_sim_db",
    ]);
    for ((v_wl, n, b, bound, e_a), r) in meta.iter().zip(&results) {
        report.push(
            &[
                *v_wl,
                *n as f64,
                *b as f64,
                *bound as f64,
                *e_a,
                r.measured.snr_t_db,
            ],
            *b,
            *bound,
            r.measured.snr_a_total_db,
            r.measured.snr_t_db,
        );
    }
    report.write_to(&ctx.csv_path("fig9b"))?;
    let gap_at_bound = report.gap_at_bound();
    println!(
        "Fig. 9(b): max SNR_A - SNR_T at the predicted minimum B_ADC = {gap_at_bound:.2} dB"
    );
    Ok(FigSummary {
        name: "fig9b".into(),
        rows: results.len(),
        checks: vec![("gap_at_bound_db".into(), gap_at_bound)],
    })
}
