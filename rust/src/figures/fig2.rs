//! Fig. 2: per-layer SNR_T requirements of DP computations in a DNN.
//! (Substituted workload: 3-layer MLP on the synthetic dataset; see
//! DESIGN.md §1.)

use super::{FigCtx, FigSummary};
use crate::dnn::{
    layer_snr_requirements, Dataset, DatasetConfig, Mlp, NoisyEvalConfig, TrainConfig,
};
use crate::util::csv::CsvWriter;
use crate::util::table::Table;

pub fn run(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let ds = Dataset::generate(&DatasetConfig::default());
    let mut mlp = Mlp::new(&[64, 128, 64, 10], 7);
    let curve = mlp.train(&ds, &TrainConfig::default());
    let clean = mlp.accuracy(&ds, true);

    let grid: Vec<f64> = (-4..=48).step_by(2).map(|v| v as f64).collect();
    let reqs = layer_snr_requirements(&mlp, &ds, &grid, 0.01, &NoisyEvalConfig::default());

    let mut csv = CsvWriter::new(&["layer", "snr_t_req_db", "clean_acc"]);
    let mut tbl = Table::new(&["layer", "SNR_T* (dB)"])
        .with_title("Fig. 2 — per-layer SNR_T requirement (<=1% accuracy loss)");
    for (l, r) in reqs.iter().enumerate() {
        csv.row_f64(&[l as f64 + 1.0, *r, clean]);
        tbl.row(vec![format!("{}", l + 1), format!("{r:.1}")]);
    }
    csv.write_to(&ctx.csv_path("fig2"))?;
    println!("{}", tbl.render());
    println!(
        "clean test accuracy {:.3} after {} epochs (final loss {:.4})",
        clean,
        curve.len(),
        curve.last().map(|c| c.0).unwrap_or(f64::NAN)
    );

    let mut checks = vec![
        ("clean_acc".to_string(), clean),
        ("max_req_db".to_string(), reqs.iter().cloned().fold(f64::MIN, f64::max)),
        ("min_req_db".to_string(), reqs.iter().cloned().fold(f64::MAX, f64::min)),
    ];
    checks.extend(
        reqs.iter()
            .enumerate()
            .map(|(l, r)| (format!("layer{}_req_db", l + 1), *r)),
    );
    Ok(FigSummary {
        name: "fig2".into(),
        rows: reqs.len(),
        checks,
    })
}
