//! Fig. 2: per-layer SNR_T requirements of DP computations in a DNN.
//! (Substituted workload: 3-layer MLP on the synthetic dataset; see
//! DESIGN.md §1.)
//!
//! The whole measurement — dataset generation, MLP training, and the
//! noisy per-layer SNR sweep — is deterministic in its configuration, so
//! it is served through the engine's memo cache: a warm re-run trains
//! nothing and performs zero Monte-Carlo trials.

use super::{FigCtx, FigSummary};
use crate::dnn::{
    layer_snr_requirements, Dataset, DatasetConfig, Mlp, NoisyEvalConfig, TrainConfig,
};
use crate::util::csv::CsvWriter;
use crate::util::table::Table;

/// Network shape shared with the AOT `mlp_fwd` artifact.
const DIMS: [usize; 4] = [64, 128, 64, 10];
/// `Mlp::new` weight-init seed.
const INIT_SEED: u64 = 7;
/// Accuracy-loss tolerance defining the SNR_T requirement.
const TOLERANCE: f64 = 0.01;

pub fn run(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let grid: Vec<f64> = (-4..=48).step_by(2).map(|v| v as f64).collect();
    let train = TrainConfig::default();
    let noisy = NoisyEvalConfig::default();

    // Memo key: every knob the measurement depends on. (The dataset
    // generator's internal defaults are code constants; changing them is
    // a physics change and must bump the cache version, like any other
    // simulator-semantics change.)
    let mut params: Vec<f64> = vec![
        INIT_SEED as f64,
        train.epochs as f64,
        train.batch as f64,
        train.lr as f64,
        train.momentum as f64,
        train.seed as f64,
        noisy.repeats as f64,
        noisy.seed as f64,
        TOLERANCE,
    ];
    params.extend(DIMS.iter().map(|&d| d as f64));
    params.extend(grid.iter().copied());

    let engine = ctx.engine();
    let compute = || {
        let ds = Dataset::generate(&DatasetConfig::default());
        let mut mlp = Mlp::new(&DIMS, INIT_SEED);
        let curve = mlp.train(&ds, &train);
        let clean = mlp.accuracy(&ds, true);
        let reqs = layer_snr_requirements(&mlp, &ds, &grid, TOLERANCE, &noisy);
        let mut v = vec![
            clean,
            curve.len() as f64,
            curve.last().map(|c| c.0).unwrap_or(f64::NAN),
        ];
        v.extend(reqs);
        v
    };
    let (mut values, mut cached) = engine.memo("fig2/mlp", &params, "fig2", || compute());
    if values.len() <= 3 {
        // decodable-but-defective record (too few values to hold any
        // layer): degrade to recompute like every other cache defect,
        // and repair the record so the next run is a real hit again
        values = compute();
        cached = false;
        engine.memo_repair("fig2/mlp", &params, "fig2", &values);
    }
    anyhow::ensure!(values.len() > 3, "fig2 measurement produced no layers");
    let clean = values[0];
    let epochs_run = values[1] as usize;
    let final_loss = values[2];
    let reqs = &values[3..];

    let mut csv = CsvWriter::new(&["layer", "snr_t_req_db", "clean_acc"]);
    let mut tbl = Table::new(&["layer", "SNR_T* (dB)"])
        .with_title("Fig. 2 — per-layer SNR_T requirement (<=1% accuracy loss)");
    for (l, r) in reqs.iter().enumerate() {
        csv.row_f64(&[l as f64 + 1.0, *r, clean]);
        tbl.row(vec![format!("{}", l + 1), format!("{r:.1}")]);
    }
    csv.write_to(&ctx.csv_path("fig2"))?;
    println!("{}", tbl.render());
    println!(
        "clean test accuracy {clean:.3} after {epochs_run} epochs (final loss {final_loss:.4}){}",
        if cached { " [cached]" } else { "" }
    );

    let mut checks = vec![
        ("clean_acc".to_string(), clean),
        (
            "max_req_db".to_string(),
            reqs.iter().cloned().fold(f64::MIN, f64::max),
        ),
        (
            "min_req_db".to_string(),
            reqs.iter().cloned().fold(f64::MAX, f64::min),
        ),
        ("mc_cached".to_string(), if cached { 1.0 } else { 0.0 }),
    ];
    checks.extend(
        reqs.iter()
            .enumerate()
            .map(|(l, r)| (format!("layer{}_req_db", l + 1), *r)),
    );
    Ok(FigSummary {
        name: "fig2".into(),
        rows: reqs.len(),
        checks,
    })
}
