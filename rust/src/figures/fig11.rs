//! Fig. 11: SNR trade-offs in CM (B_x = 6, N = 64).
//! (a) SNR_A vs B_w: quantization noise falls and headroom-clipping noise
//!     rises with B_w => an SNR-optimal B_w, shifting right as V_WL drops;
//! (b) SNR_T vs B_ADC at B_w = 6: MPC bound << BGC's 19 bits.
//! Executed through the cached sweep engine.

use super::{sweep_point, uniform_stats, FigCtx, FigSummary};
use crate::arch::{CmArch, ImcArch, OpPoint};
use crate::compute::{qr::QrModel, qs::QsModel};
use crate::engine::{BoundReport, EsReport, SweepSpec};
use crate::mc::ArchKind;
use crate::tech::TechNode;

pub const V_WLS: [f64; 3] = [0.6, 0.7, 0.8];

fn cm(v_wl: f64) -> CmArch {
    CmArch::new(
        QsModel::new(TechNode::n65(), v_wl),
        QrModel::new(TechNode::n65(), 3.0),
    )
}

pub fn run_a(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let bws: Vec<u32> = (2..=8).collect();
    let n = 64;

    let spec = SweepSpec::new("fig11a")
        .axis_f64("vwl", &V_WLS)
        .axis_u32("bw", &bws);
    let mut points = Vec::with_capacity(spec.len());
    let mut meta = Vec::with_capacity(spec.len());
    for gp in spec.points() {
        let v = gp.num(0);
        let bw = gp.int(1) as u32;
        let arch = cm(v);
        let op = OpPoint::new(n, 6, bw, 14);
        let nb = arch.noise(&op, &w, &x);
        meta.push((v, bw, nb.snr_a_total_db(), nb.sigma_eta_h2, nb.sigma_eta_e2));
        points.push(sweep_point(
            &arch,
            ArchKind::Cm,
            gp.id,
            &op,
            ctx.trials,
            0xC0 + bw as u64,
        ));
    }
    let results = ctx.run_points(points);

    let mut report = EsReport::gated_on_expected(
        &[
            "v_wl",
            "b_w",
            "sigma_eta_h2",
            "sigma_eta_e2",
            "snr_a_closed_db",
            "snr_a_sim_db",
        ],
        5.0,
    );
    for ((v, bw, e_db, h2, e2), r) in meta.iter().zip(&results) {
        report.push(
            &[*v, *bw as f64, *h2, *e2],
            *e_db,
            r.measured.snr_a_total_db,
        );
    }
    report.write_to(&ctx.csv_path("fig11a"))?;
    let max_gap = report.max_gap();

    // optimum B_w per V_WL from the simulation
    let best_bw = |v: f64| -> u32 {
        bws.iter()
            .cloned()
            .max_by(|&a, &b| {
                let sa = results
                    .iter()
                    .find(|r| r.id == format!("fig11a/vwl={v}/bw={a}"))
                    .unwrap()
                    .measured
                    .snr_a_total_db;
                let sb = results
                    .iter()
                    .find(|r| r.id == format!("fig11a/vwl={v}/bw={b}"))
                    .unwrap()
                    .measured
                    .snr_a_total_db;
                sa.partial_cmp(&sb).unwrap()
            })
            .unwrap()
    };
    let (b08, b07) = (best_bw(0.8), best_bw(0.7));
    println!(
        "Fig. 11(a): optimal B_w = {b08} at 0.8 V, {b07} at 0.7 V (paper: 6, 7); max|E-S|={max_gap:.2} dB"
    );
    Ok(FigSummary {
        name: "fig11a".into(),
        rows: results.len(),
        checks: vec![
            ("best_bw_08".into(), b08 as f64),
            ("best_bw_07".into(), b07 as f64),
            ("max_e_s_gap_db".into(), max_gap),
        ],
    })
}

pub fn run_b(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let b_adcs: Vec<u32> = (2..=11).collect();
    let n = 64;

    let spec = SweepSpec::new("fig11b")
        .axis_f64("vwl", &V_WLS)
        .axis_u32("b", &b_adcs);
    let mut points = Vec::with_capacity(spec.len());
    let mut meta = Vec::with_capacity(spec.len());
    for gp in spec.points() {
        let v = gp.num(0);
        let b = gp.int(1) as u32;
        let arch = cm(v);
        let bound = arch.b_adc_min(&OpPoint::new(n, 6, 6, 8), &w, &x);
        let op = OpPoint::new(n, 6, 6, b);
        meta.push((v, b, bound));
        points.push(sweep_point(
            &arch,
            ArchKind::Cm,
            gp.id,
            &op,
            ctx.trials,
            0xD0 + b as u64,
        ));
    }
    let results = ctx.run_points(points);

    let mut report =
        BoundReport::new(&["v_wl", "b_adc", "b_adc_min_pred", "snr_t_sim_db"]);
    for ((v, b, bound), r) in meta.iter().zip(&results) {
        report.push(
            &[*v, *b as f64, *bound as f64, r.measured.snr_t_db],
            *b,
            *bound,
            r.measured.snr_a_total_db,
            r.measured.snr_t_db,
        );
    }
    report.write_to(&ctx.csv_path("fig11b"))?;
    let gap_at_bound = report.gap_at_bound();
    let bound_max = report.bound_max();
    println!(
        "Fig. 11(b): MPC assigns <= {bound_max} bits (BGC: {}); max SNR_A - SNR_T at bound = {gap_at_bound:.2} dB",
        crate::quant::criteria::bgc_bits(6, 6, n)
    );
    Ok(FigSummary {
        name: "fig11b".into(),
        rows: results.len(),
        checks: vec![
            ("gap_at_bound_db".into(), gap_at_bound),
            ("bound_max_bits".into(), bound_max as f64),
        ],
    })
}
