//! Fig. 13: impact of technology scaling on the compute-SNR vs energy
//! trade-off (Bx = 3, Bw = 4, N = 100), nodes 65 nm -> 7 nm.
//! Swept knob: V_WL for QS-Arch and CM, C_o for QR-Arch.
//!
//! The scan runs on the design-space optimizer (`crate::opt`): each
//! operating point is an opt [`Family`] costed through [`FamilyEval`]
//! (closed-form noise once per family, energy at the MPC ADC
//! assignment), and the per-node energy-delay-accuracy frontier of the
//! same families is extracted with `opt::frontier_of_families` — the
//! figure's trade-off curves are exactly the domain the `imclim pareto`
//! verb searches.
//!
//! Expected shapes (Sec. V-D): per node, energy drops ~2x (QS/CM) or ~4x
//! (QR) per 6 dB of SNR_A given up; the maximum achievable SNR_A of
//! QS-Arch/CM *decreases* with scaling, while QR-Arch approaches the
//! input-quantization limit at every node.

use super::{uniform_stats, FigCtx, FigSummary};
use crate::opt::{frontier_of_families, ArchChoice, Family, FamilyEval};
use crate::tech::TechNode;
use crate::util::csv::CsvWriter;

/// The figure's operating shape: N = 100, Bx = 3, Bw = 4.
fn family(arch: ArchChoice, node: TechNode, v_wl: Option<f64>, c_ff: Option<f64>) -> Family {
    Family {
        arch,
        node,
        v_wl,
        c_ff,
        n: 100,
        bx: 3,
        bw: 4,
        banks: 1,
    }
}

pub fn run(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let nodes = TechNode::scaling_set();

    let mut csv = CsvWriter::new(&[
        "arch", "node_nm", "knob", "snr_a_db", "energy_j",
    ]);
    let mut checks = Vec::new();

    for node in &nodes {
        let mut families = Vec::new();

        // QS-Arch and CM: sweep V_WL across the usable overdrive range.
        let v_min = node.v_t + 0.12;
        let v_max = node.v_dd;
        let v_steps: Vec<f64> = (0..10)
            .map(|i| v_min + (v_max - v_min) * i as f64 / 9.0)
            .collect();

        let mut qs_max_snr: f64 = f64::MIN;
        for &v in &v_steps {
            for arch in [ArchChoice::Qs, ArchChoice::Cm] {
                let c_ff = Some(3.0).filter(|_| arch == ArchChoice::Cm);
                let fam = family(arch, *node, Some(v), c_ff);
                let eval = FamilyEval::new(fam.clone(), &w, &x);
                let p = eval.design_point(eval.b_adc_mpc, &w, &x);
                if arch == ArchChoice::Qs {
                    qs_max_snr = qs_max_snr.max(p.snr_a_total_db);
                }
                csv.row(&[
                    arch.name().into(),
                    node.node_nm.to_string(),
                    format!("{v:.3}"),
                    format!("{:.3}", p.snr_a_total_db),
                    format!("{:.6e}", p.energy_j),
                ]);
                families.push(fam);
            }
        }
        checks.push((format!("qs_max_snr_{}", node.node_nm), qs_max_snr));

        // QR-Arch: sweep C_o.
        let mut qr_max_snr: f64 = f64::MIN;
        for c_ff in [0.5, 1.0, 2.0, 3.0, 6.0, 9.0] {
            let fam = family(ArchChoice::Qr, *node, None, Some(c_ff));
            let eval = FamilyEval::new(fam.clone(), &w, &x);
            let p = eval.design_point(eval.b_adc_mpc, &w, &x);
            qr_max_snr = qr_max_snr.max(p.snr_a_total_db);
            csv.row(&[
                "qr".into(),
                node.node_nm.to_string(),
                format!("{c_ff:.1}"),
                format!("{:.3}", p.snr_a_total_db),
                format!("{:.6e}", p.energy_j),
            ]);
            families.push(fam);
        }
        checks.push((format!("qr_max_snr_{}", node.node_nm), qr_max_snr));

        // The node's energy-delay-accuracy frontier over the same scan
        // families (B_ADC 4..10): a non-empty strict subset of the scan.
        let fr = frontier_of_families(&families, &[4, 5, 6, 7, 8, 9, 10], 1, &w, &x);
        anyhow::ensure!(
            !fr.points.is_empty() && fr.points.len() < fr.points_total,
            "degenerate fig13 frontier at {} nm: {} of {}",
            node.node_nm,
            fr.points.len(),
            fr.points_total
        );
        checks.push((
            format!("frontier_{}", node.node_nm),
            fr.points.len() as f64,
        ));
    }
    csv.write_to(&ctx.csv_path("fig13"))?;

    let get = |k: &str| checks.iter().find(|(n, _)| n == k).map(|(_, v)| *v).unwrap();
    println!(
        "Fig. 13: QS-Arch max SNR_A 65nm={:.1} dB -> 7nm={:.1} dB (scaling hurts); QR-Arch 65nm={:.1} -> 7nm={:.1} dB (quantization-limited: SQNR_qiy={:.1} dB); per-node frontier sizes 65nm={} 7nm={}",
        get("qs_max_snr_65"),
        get("qs_max_snr_7"),
        get("qr_max_snr_65"),
        get("qr_max_snr_7"),
        crate::quant::sqnr_qiy_db(100, 4, 3, &w, &x),
        get("frontier_65"),
        get("frontier_7"),
    );
    Ok(FigSummary {
        name: "fig13".into(),
        rows: checks.len(),
        checks,
    })
}
