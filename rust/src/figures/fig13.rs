//! Fig. 13: impact of technology scaling on the compute-SNR vs energy
//! trade-off (Bx = 3, Bw = 4, N = 100), nodes 65 nm -> 7 nm.
//! Swept knob: V_WL for QS-Arch and CM, C_o for QR-Arch.
//!
//! Expected shapes (Sec. V-D): per node, energy drops ~2x (QS/CM) or ~4x
//! (QR) per 6 dB of SNR_A given up; the maximum achievable SNR_A of
//! QS-Arch/CM *decreases* with scaling, while QR-Arch approaches the
//! input-quantization limit at every node.

use super::{uniform_stats, FigCtx, FigSummary};
use crate::arch::{AdcCriterion, CmArch, ImcArch, OpPoint, QrArch, QsArch};
use crate::compute::{qr::QrModel, qs::QsModel};
use crate::tech::TechNode;
use crate::util::csv::CsvWriter;

pub fn run(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let op = OpPoint::new(100, 3, 4, 8);
    let nodes = TechNode::scaling_set();

    let mut csv = CsvWriter::new(&[
        "arch", "node_nm", "knob", "snr_a_db", "energy_j",
    ]);
    let mut checks = Vec::new();

    for node in &nodes {
        // QS-Arch and CM: sweep V_WL across the usable overdrive range.
        let v_min = node.v_t + 0.12;
        let v_max = node.v_dd;
        let v_steps: Vec<f64> = (0..10)
            .map(|i| v_min + (v_max - v_min) * i as f64 / 9.0)
            .collect();

        let mut qs_max_snr: f64 = f64::MIN;
        for &v in &v_steps {
            let mut qs_model = QsModel::new(*node, v);
            qs_model.c_bl = node.c_bl_512;
            let arch = QsArch::new(qs_model);
            let nb = arch.noise(&op, &w, &x);
            let e = arch.energy(&op, AdcCriterion::Mpc, &w, &x).total();
            qs_max_snr = qs_max_snr.max(nb.snr_a_total_db());
            csv.row(&[
                "qs".into(),
                node.node_nm.to_string(),
                format!("{v:.3}"),
                format!("{:.3}", nb.snr_a_total_db()),
                format!("{:.6e}", e),
            ]);

            let cm = CmArch::new(qs_model, QrModel::new(*node, 3.0));
            let nb = cm.noise(&op, &w, &x);
            let e = cm.energy(&op, AdcCriterion::Mpc, &w, &x).total();
            csv.row(&[
                "cm".into(),
                node.node_nm.to_string(),
                format!("{v:.3}"),
                format!("{:.3}", nb.snr_a_total_db()),
                format!("{:.6e}", e),
            ]);
        }
        checks.push((format!("qs_max_snr_{}", node.node_nm), qs_max_snr));

        // QR-Arch: sweep C_o.
        let mut qr_max_snr: f64 = f64::MIN;
        for c_ff in [0.5, 1.0, 2.0, 3.0, 6.0, 9.0] {
            let arch = QrArch::new(QrModel::new(*node, c_ff));
            let nb = arch.noise(&op, &w, &x);
            let e = arch.energy(&op, AdcCriterion::Mpc, &w, &x).total();
            qr_max_snr = qr_max_snr.max(nb.snr_a_total_db());
            csv.row(&[
                "qr".into(),
                node.node_nm.to_string(),
                format!("{c_ff:.1}"),
                format!("{:.3}", nb.snr_a_total_db()),
                format!("{:.6e}", e),
            ]);
        }
        checks.push((format!("qr_max_snr_{}", node.node_nm), qr_max_snr));
    }
    csv.write_to(&ctx.csv_path("fig13"))?;

    let get = |k: &str| checks.iter().find(|(n, _)| n == k).map(|(_, v)| *v).unwrap();
    println!(
        "Fig. 13: QS-Arch max SNR_A 65nm={:.1} dB -> 7nm={:.1} dB (scaling hurts); QR-Arch 65nm={:.1} -> 7nm={:.1} dB (quantization-limited: SQNR_qiy={:.1} dB)",
        get("qs_max_snr_65"),
        get("qs_max_snr_7"),
        get("qr_max_snr_65"),
        get("qr_max_snr_7"),
        crate::quant::sqnr_qiy_db(100, 4, 3, &w, &x),
    );
    Ok(FigSummary {
        name: "fig13".into(),
        rows: checks.len(),
        checks,
    })
}
