//! Banked ceiling escape (conclusion 4, Sec. VI): SNR_A vs N for
//! QS-Arch at V_WL = 0.8 with banks in {1, 2, 4, 8}.
//!
//! A single-bank QS array collapses past N_max (headroom clipping,
//! Fig. 9(a)); splitting the same DP across banks of N/banks rows keeps
//! every bank inside its headroom, so the banked curves stay on the
//! plateau while the single-bank curve falls off a cliff. The figure
//! reports closed form and native Monte-Carlo per point (through the
//! cached engine — the bank count rides in the parameter vector, so
//! banked points cache like any others), plus the area and energy cost
//! of banking from the Table III models.

use super::{sweep_point, uniform_stats, FigCtx, FigSummary};
use crate::arch::{AdcCriterion, Banked, ImcArch, OpPoint, QsArch};
use crate::compute::qs::QsModel;
use crate::mc::ArchKind;
use crate::tech::TechNode;
use crate::util::csv::CsvWriter;

pub const NS: [usize; 5] = [64, 128, 256, 512, 1024];
pub const BANKS: [usize; 4] = [1, 2, 4, 8];

pub fn run(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let qs = QsArch::new(QsModel::new(TechNode::n65(), 0.8));

    struct Row {
        n: usize,
        banks: usize,
        closed_db: f64,
        b_adc_mpc: u32,
        energy_j: f64,
        delay_ns: f64,
        area_mm2: f64,
    }
    let mut rows = Vec::new();
    let mut points = Vec::new();
    for &n in &NS {
        for &banks in &BANKS {
            let arch = Banked::new(Box::new(qs), banks);
            // B_ADC = 14: measure the analog ceiling, not the quantizer
            let op = OpPoint::new(n, 6, 6, 14).with_banks(banks);
            // cost columns at the operating ADC precision MPC would
            // deploy (a 14-bit cap-DAC would swamp the area story)
            let b_adc_mpc = arch.b_adc_min(&op, &w, &x);
            let cost_op = OpPoint::new(n, 6, 6, b_adc_mpc).with_banks(banks);
            rows.push(Row {
                n,
                banks,
                closed_db: arch.noise(&op, &w, &x).snr_a_total_db(),
                b_adc_mpc,
                energy_j: arch.energy(&cost_op, AdcCriterion::Mpc, &w, &x).total(),
                delay_ns: arch.delay(&cost_op) * 1e9,
                area_mm2: arch.area(&cost_op).total_mm2(),
            });
            points.push(sweep_point(
                &arch,
                ArchKind::Qs,
                format!("banked/n={n}/banks={banks}"),
                &op,
                ctx.trials,
                0xBA + n as u64,
            ));
        }
    }
    let results = ctx.run_points(points);

    let mut csv = CsvWriter::new(&[
        "n",
        "banks",
        "snr_a_closed_db",
        "snr_a_sim_db",
        "b_adc_mpc",
        "energy_mpc_j",
        "delay_ns",
        "area_mm2",
    ]);
    for (row, r) in rows.iter().zip(&results) {
        csv.row(&[
            row.n.to_string(),
            row.banks.to_string(),
            format!("{:.4}", row.closed_db),
            format!("{:.4}", r.measured.snr_a_total_db),
            row.b_adc_mpc.to_string(),
            format!("{:.6e}", row.energy_j),
            format!("{:.4}", row.delay_ns),
            format!("{:.6e}", row.area_mm2),
        ]);
    }
    csv.write_to(&ctx.csv_path("banked"))?;

    let at = |n: usize, banks: usize| {
        rows.iter()
            .position(|r| r.n == n && r.banks == banks)
            .expect("grid point exists")
    };
    // the headline: 8 banks rescue the N = 512 DP from the cliff
    let single = at(512, 1);
    let eight = at(512, 8);
    let escape_closed = rows[eight].closed_db - rows[single].closed_db;
    let escape_sim =
        results[eight].measured.snr_a_total_db - results[single].measured.snr_a_total_db;
    // agreement between closed form and MC on the plateau (away from
    // the clipping cliff, where the binomial tail bound is loose)
    let mut max_gap = 0f64;
    for (row, r) in rows.iter().zip(&results) {
        if row.closed_db > 5.0 {
            max_gap = max_gap.max((row.closed_db - r.measured.snr_a_total_db).abs());
        }
    }
    let area_ratio = rows[eight].area_mm2 / rows[single].area_mm2;
    let energy_ratio = rows[eight].energy_j / rows[single].energy_j;
    println!(
        "Banked: N=512 escape {escape_closed:.1} dB closed / {escape_sim:.1} dB sim \
         (8 banks; area x{area_ratio:.2}, energy x{energy_ratio:.2}); \
         plateau max|E-S|={max_gap:.2} dB"
    );
    Ok(FigSummary {
        name: "banked".into(),
        rows: results.len(),
        checks: vec![
            ("escape_closed_db".into(), escape_closed),
            ("escape_sim_db".into(), escape_sim),
            ("area_ratio_512_8".into(), area_ratio),
            ("energy_ratio_512_8".into(), energy_ratio),
            ("max_e_s_gap_db".into(), max_gap),
        ],
    })
}
