//! Ablation studies on the modelling choices DESIGN.md §6 calls out —
//! extensions beyond the paper's own figures:
//!
//! (a) Noise-correlation mode (EXPERIMENTS.md §Deviations 7): the paper's
//!     appendix assumes per-bit-plane-pair independent mismatch; the
//!     physical array has V_t mismatch static across the B_x bit-serial
//!     cycles. Cost: ~3 dB of SNR_a.
//! (b) Input distribution (Sec. V-A draws x, w "from two different
//!     distributions"): uniform vs clipped-Gaussian inputs shift PAR and
//!     therefore SQNR_qiy, but analog SNR_a is distribution-robust.

use super::{sweep_point, uniform_stats, FigCtx, FigSummary};
use crate::arch::{pvec, ImcArch, OpPoint, QsArch};
use crate::compute::qs::QsModel;
use crate::engine::SweepSpec;
use crate::mc::{ArchKind, InputDist};
use crate::tech::TechNode;
use crate::util::csv::CsvWriter;

pub fn run(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let mut checks = Vec::new();

    // (a) correlated vs independent mismatch, QS-Arch SNR_A vs N.
    let arch = QsArch::new(QsModel::new(TechNode::n65(), 0.8));
    let ns = [32usize, 64, 96, 128];
    let spec = SweepSpec::new("abl/corr")
        .axis_usize("n", &ns)
        .axis_f64("mode", &[0.0, 1.0]);
    let mut points = Vec::with_capacity(spec.len());
    for gp in spec.points() {
        let n = gp.int(0) as usize;
        let mode = gp.num(1);
        let op = OpPoint::new(n, 6, 6, 14);
        let mut p = arch.pjrt_params(&op, &w, &x);
        p[pvec::QS_IDX_MODE] = mode;
        points.push(
            crate::coordinator::SweepPoint::new(gp.id, ArchKind::Qs, p)
                .with_trials(ctx.trials)
                .with_seed(0xAB1 + n as u64),
        );
    }
    let results = ctx.run_points(points);
    let mut csv = CsvWriter::new(&["n", "mode", "snr_a_sim_db"]);
    let mut drops = Vec::new();
    for (i, &n) in ns.iter().enumerate() {
        let indep = results[2 * i].measured.snr_a_db;
        let corr = results[2 * i + 1].measured.snr_a_db;
        csv.row_f64(&[n as f64, 0.0, indep]);
        csv.row_f64(&[n as f64, 1.0, corr]);
        drops.push(indep - corr);
    }
    let mean_drop = drops.iter().sum::<f64>() / drops.len() as f64;
    checks.push(("corr_mean_drop_db".to_string(), mean_drop));

    // (b) input distribution robustness at one op point.
    let op = OpPoint::new(128, 6, 6, 14);
    let base = sweep_point(&arch, ArchKind::Qs, "abl/dist/uniform".into(), &op, ctx.trials, 0xD1);
    let mut gauss = base.clone();
    gauss.id = "abl/dist/gauss".into();
    gauss.dist = InputDist::ClippedGaussian { sx: 0.35, sw: 0.35 };
    let r = ctx.run_points(vec![base, gauss]);
    csv.row_f64(&[-1.0, 0.0, r[0].measured.snr_a_db]);
    csv.row_f64(&[-1.0, 1.0, r[1].measured.snr_a_db]);
    checks.push((
        "dist_snr_a_shift_db".to_string(),
        (r[0].measured.snr_a_db - r[1].measured.snr_a_db).abs(),
    ));
    checks.push((
        "dist_sqnr_qiy_shift_db".to_string(),
        (r[0].measured.sqnr_qiy_db - r[1].measured.sqnr_qiy_db).abs(),
    ));
    csv.write_to(&ctx.csv_path("ablation"))?;

    println!(
        "Ablation: correlated-mismatch SNR_a drop = {mean_drop:.2} dB (mode 1 vs 0); \
input-distribution SNR_a shift = {:.2} dB, SQNR_qiy shift = {:.2} dB",
        checks[1].1, checks[2].1
    );
    Ok(FigSummary {
        name: "ablation".into(),
        rows: ns.len() * 2 + 2,
        checks,
    })
}
