//! Fig. 10: SNR trade-offs in QR-Arch (B_w = 7, N = 128).
//! (a) SNR_A vs B_x for C_o in {1, 3, 9 fF}: SNR improves with C_o
//!     (~+8 dB at 3 fF, ~+12 dB at 9 fF over 1 fF);
//! (b) SNR_T vs B_ADC at B_x = 6: MPC's 6-8 bits suffice (BGC: 12+).

use super::{sweep_point, uniform_stats, FigCtx, FigSummary};
use crate::arch::{ImcArch, OpPoint, QrArch};
use crate::compute::qr::QrModel;
use crate::coordinator::run_sweep;
use crate::mc::ArchKind;
use crate::tech::TechNode;
use crate::util::csv::CsvWriter;

pub const CAPS_FF: [f64; 3] = [1.0, 3.0, 9.0];

pub fn run_a(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let bxs: Vec<u32> = (2..=8).collect();
    let n = 128;

    let mut points = Vec::new();
    let mut meta = Vec::new();
    for &c in &CAPS_FF {
        let arch = QrArch::new(QrModel::new(TechNode::n65(), c));
        for &bx in &bxs {
            let op = OpPoint::new(n, bx, 7, 14);
            meta.push((c, bx, arch.noise(&op, &w, &x).snr_a_total_db()));
            points.push(sweep_point(
                &arch,
                ArchKind::Qr,
                format!("fig10a/c={c}/bx={bx}"),
                &op,
                ctx.trials,
                0xA0 + bx as u64,
            ));
        }
    }
    let results = run_sweep(points, ctx.backend.clone(), ctx.sweep_opts());

    let mut csv = CsvWriter::new(&["c_o_ff", "b_x", "snr_a_closed_db", "snr_a_sim_db"]);
    let mut max_gap: f64 = 0.0;
    for ((c, bx, e_db), r) in meta.iter().zip(&results) {
        let s_db = r.measured.snr_a_total_db;
        max_gap = max_gap.max((e_db - s_db).abs());
        csv.row_f64(&[*c, *bx as f64, *e_db, s_db]);
    }
    csv.write_to(&ctx.csv_path("fig10a"))?;

    let sim_at = |c: f64, bx: u32| {
        results
            .iter()
            .find(|r| r.id == format!("fig10a/c={c}/bx={bx}"))
            .unwrap()
            .measured
            .snr_a_total_db
    };
    // analog-limited regime at high Bx: cap-size gains
    let gain_3 = sim_at(3.0, 8) - sim_at(1.0, 8);
    let gain_9 = sim_at(9.0, 8) - sim_at(1.0, 8);
    println!(
        "Fig. 10(a): SNR_a gain at C_o 3 fF = {gain_3:.1} dB, 9 fF = {gain_9:.1} dB (paper: ~8, ~12); max|E-S|={max_gap:.2} dB"
    );
    Ok(FigSummary {
        name: "fig10a".into(),
        rows: results.len(),
        checks: vec![
            ("gain_3ff_db".into(), gain_3),
            ("gain_9ff_db".into(), gain_9),
            ("max_e_s_gap_db".into(), max_gap),
        ],
    })
}

pub fn run_b(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let b_adcs: Vec<u32> = (2..=12).collect();
    let n = 128;

    let mut points = Vec::new();
    let mut meta = Vec::new();
    for &c in &CAPS_FF {
        let arch = QrArch::new(QrModel::new(TechNode::n65(), c));
        let bound = arch.b_adc_min(&OpPoint::new(n, 6, 7, 8), &w, &x);
        for &b in &b_adcs {
            let op = OpPoint::new(n, 6, 7, b);
            meta.push((c, b, bound, arch.noise(&op, &w, &x).snr_a_total_db()));
            points.push(sweep_point(
                &arch,
                ArchKind::Qr,
                format!("fig10b/c={c}/b={b}"),
                &op,
                ctx.trials,
                0xB0 + b as u64,
            ));
        }
    }
    let results = run_sweep(points, ctx.backend.clone(), ctx.sweep_opts());

    let mut csv = CsvWriter::new(&[
        "c_o_ff",
        "b_adc",
        "b_adc_min_pred",
        "snr_a_closed_db",
        "snr_t_sim_db",
    ]);
    let mut gap_at_bound: f64 = f64::MIN;
    let mut bound_max = 0u32;
    for ((c, b, bound, e_a), r) in meta.iter().zip(&results) {
        csv.row_f64(&[*c, *b as f64, *bound as f64, *e_a, r.measured.snr_t_db]);
        bound_max = bound_max.max(*bound);
        if b == bound {
            gap_at_bound =
                gap_at_bound.max(r.measured.snr_a_total_db - r.measured.snr_t_db);
        }
    }
    csv.write_to(&ctx.csv_path("fig10b"))?;
    println!(
        "Fig. 10(b): MPC bound <= {bound_max} bits; max SNR_A - SNR_T at bound = {gap_at_bound:.2} dB (BGC would need {})",
        crate::quant::criteria::bgc_bits(6, 7, n)
    );
    Ok(FigSummary {
        name: "fig10b".into(),
        rows: results.len(),
        checks: vec![
            ("gap_at_bound_db".into(), gap_at_bound),
            ("bound_max_bits".into(), bound_max as f64),
        ],
    })
}
