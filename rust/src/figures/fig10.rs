//! Fig. 10: SNR trade-offs in QR-Arch (B_w = 7, N = 128).
//! (a) SNR_A vs B_x for C_o in {1, 3, 9 fF}: SNR improves with C_o
//!     (~+8 dB at 3 fF, ~+12 dB at 9 fF over 1 fF);
//! (b) SNR_T vs B_ADC at B_x = 6: MPC's 6-8 bits suffice (BGC: 12+).
//! Executed through the cached sweep engine.

use super::{sweep_point, uniform_stats, FigCtx, FigSummary};
use crate::arch::{ImcArch, OpPoint, QrArch};
use crate::compute::qr::QrModel;
use crate::engine::{BoundReport, EsReport, SweepSpec};
use crate::mc::ArchKind;
use crate::tech::TechNode;

pub const CAPS_FF: [f64; 3] = [1.0, 3.0, 9.0];

pub fn run_a(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let bxs: Vec<u32> = (2..=8).collect();
    let n = 128;

    let spec = SweepSpec::new("fig10a")
        .axis_f64("c", &CAPS_FF)
        .axis_u32("bx", &bxs);
    let mut points = Vec::with_capacity(spec.len());
    let mut meta = Vec::with_capacity(spec.len());
    for gp in spec.points() {
        let c = gp.num(0);
        let bx = gp.int(1) as u32;
        let arch = QrArch::new(QrModel::new(TechNode::n65(), c));
        let op = OpPoint::new(n, bx, 7, 14);
        meta.push((c, bx, arch.noise(&op, &w, &x).snr_a_total_db()));
        points.push(sweep_point(
            &arch,
            ArchKind::Qr,
            gp.id,
            &op,
            ctx.trials,
            0xA0 + bx as u64,
        ));
    }
    let results = ctx.run_points(points);

    let mut report = EsReport::new(&["c_o_ff", "b_x", "snr_a_closed_db", "snr_a_sim_db"]);
    for ((c, bx, e_db), r) in meta.iter().zip(&results) {
        report.push(&[*c, *bx as f64], *e_db, r.measured.snr_a_total_db);
    }
    report.write_to(&ctx.csv_path("fig10a"))?;
    let max_gap = report.max_gap();

    let sim_at = |c: f64, bx: u32| {
        results
            .iter()
            .find(|r| r.id == format!("fig10a/c={c}/bx={bx}"))
            .unwrap()
            .measured
            .snr_a_total_db
    };
    // analog-limited regime at high Bx: cap-size gains
    let gain_3 = sim_at(3.0, 8) - sim_at(1.0, 8);
    let gain_9 = sim_at(9.0, 8) - sim_at(1.0, 8);
    println!(
        "Fig. 10(a): SNR_a gain at C_o 3 fF = {gain_3:.1} dB, 9 fF = {gain_9:.1} dB (paper: ~8, ~12); max|E-S|={max_gap:.2} dB"
    );
    Ok(FigSummary {
        name: "fig10a".into(),
        rows: results.len(),
        checks: vec![
            ("gain_3ff_db".into(), gain_3),
            ("gain_9ff_db".into(), gain_9),
            ("max_e_s_gap_db".into(), max_gap),
        ],
    })
}

pub fn run_b(ctx: &FigCtx) -> anyhow::Result<FigSummary> {
    let (w, x) = uniform_stats();
    let b_adcs: Vec<u32> = (2..=12).collect();
    let n = 128;

    let spec = SweepSpec::new("fig10b")
        .axis_f64("c", &CAPS_FF)
        .axis_u32("b", &b_adcs);
    let mut points = Vec::with_capacity(spec.len());
    let mut meta = Vec::with_capacity(spec.len());
    for gp in spec.points() {
        let c = gp.num(0);
        let b = gp.int(1) as u32;
        let arch = QrArch::new(QrModel::new(TechNode::n65(), c));
        let bound = arch.b_adc_min(&OpPoint::new(n, 6, 7, 8), &w, &x);
        let op = OpPoint::new(n, 6, 7, b);
        meta.push((c, b, bound, arch.noise(&op, &w, &x).snr_a_total_db()));
        points.push(sweep_point(
            &arch,
            ArchKind::Qr,
            gp.id,
            &op,
            ctx.trials,
            0xB0 + b as u64,
        ));
    }
    let results = ctx.run_points(points);

    let mut report = BoundReport::new(&[
        "c_o_ff",
        "b_adc",
        "b_adc_min_pred",
        "snr_a_closed_db",
        "snr_t_sim_db",
    ]);
    for ((c, b, bound, e_a), r) in meta.iter().zip(&results) {
        report.push(
            &[*c, *b as f64, *bound as f64, *e_a, r.measured.snr_t_db],
            *b,
            *bound,
            r.measured.snr_a_total_db,
            r.measured.snr_t_db,
        );
    }
    report.write_to(&ctx.csv_path("fig10b"))?;
    let gap_at_bound = report.gap_at_bound();
    let bound_max = report.bound_max();
    println!(
        "Fig. 10(b): MPC bound <= {bound_max} bits; max SNR_A - SNR_T at bound = {gap_at_bound:.2} dB (BGC would need {})",
        crate::quant::criteria::bgc_bits(6, 7, n)
    );
    Ok(FigSummary {
        name: "fig10b".into(),
        rows: results.len(),
        checks: vec![
            ("gap_at_bound_db".into(), gap_at_bound),
            ("bound_max_bits".into(), bound_max as f64),
        ],
    })
}
