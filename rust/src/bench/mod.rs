//! Micro/throughput benchmark harness (offline build: no criterion).
//!
//! `cargo bench` runs `rust/benches/paper_benches.rs` (harness = false),
//! which uses this module: warmup, adaptive iteration count targeting a
//! wall-clock budget, median / MAD reporting, and a simple name filter
//! from the command line.

use std::time::{Duration, Instant};

use crate::util::stats::{median_abs_dev, quantile};

#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchReport {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mad: Duration,
    pub mean: Duration,
    /// Optional caller-supplied throughput denominator (items/iter).
    pub items_per_iter: f64,
}

impl BenchReport {
    pub fn items_per_sec(&self) -> f64 {
        self.items_per_iter / self.median.as_secs_f64()
    }

    pub fn line(&self) -> String {
        let thr = if self.items_per_iter > 0.0 {
            format!("  {:>12.1} items/s", self.items_per_sec())
        } else {
            String::new()
        };
        format!(
            "{:<48} {:>10} iters  median {:>12?}  mad {:>10?}{}",
            self.name, self.iters, self.median, self.mad, thr
        )
    }
}

/// A bench suite with a name filter (argv[1..] substrings).
pub struct Suite {
    cfg: BenchConfig,
    filters: Vec<String>,
    pub reports: Vec<BenchReport>,
}

impl Suite {
    pub fn from_args(cfg: BenchConfig) -> Self {
        let filters: Vec<String> = std::env::args()
            .skip(1)
            .filter(|a| !a.starts_with('-'))
            .collect();
        Self {
            cfg,
            filters,
            reports: Vec::new(),
        }
    }

    fn enabled(&self, name: &str) -> bool {
        self.filters.is_empty() || self.filters.iter().any(|f| name.contains(f))
    }

    /// Benchmark `f`, which performs one logical iteration covering
    /// `items` items (for throughput reporting; 0 to omit).
    pub fn bench(&mut self, name: &str, items: f64, mut f: impl FnMut()) {
        if !self.enabled(name) {
            return;
        }
        // warmup
        let start = Instant::now();
        while start.elapsed() < self.cfg.warmup {
            f();
        }
        // measure
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.cfg.budget || samples.len() < self.cfg.min_iters)
            && samples.len() < self.cfg.max_iters
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let median = quantile(&samples, 0.5);
        let mad = median_abs_dev(&samples);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let report = BenchReport {
            name: name.to_string(),
            iters: samples.len(),
            median: Duration::from_secs_f64(median),
            mad: Duration::from_secs_f64(mad),
            mean: Duration::from_secs_f64(mean),
            items_per_iter: items,
        };
        println!("{}", report.line());
        self.reports.push(report);
    }
}

/// Opaque value sink preventing dead-code elimination of benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut suite = Suite {
            cfg: BenchConfig {
                warmup: Duration::from_millis(1),
                budget: Duration::from_millis(20),
                min_iters: 3,
                max_iters: 1000,
            },
            filters: Vec::new(),
            reports: Vec::new(),
        };
        let mut acc = 0u64;
        suite.bench("spin", 1000.0, || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert_eq!(suite.reports.len(), 1);
        let r = &suite.reports[0];
        assert!(r.iters >= 3);
        assert!(r.median.as_nanos() > 0);
        assert!(r.items_per_sec() > 0.0);
    }

    #[test]
    fn filter_skips() {
        let mut suite = Suite {
            cfg: BenchConfig::default(),
            filters: vec!["only-this".into()],
            reports: Vec::new(),
        };
        suite.bench("something-else", 0.0, || {});
        assert!(suite.reports.is_empty());
    }
}
