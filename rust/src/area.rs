//! Silicon-area closed forms (the ROADMAP's fourth objective): per-DP
//! mm² for each architecture from the Table III array geometry, scaling
//! with technology node, DP dimension N, precision (B_x, B_w, B_ADC)
//! and bank count.
//!
//! Geometry per Table III / Fig. 7:
//!
//! * **QS-Arch** — an N-row x B_w-column 6T SRAM array (one bit plane
//!   per column), one SAR ADC per column, N word-line drivers and
//!   B_w·B_x digital POT recombination slices.
//! * **QR-Arch** — a B_w-row x N-column array of capacitor-augmented
//!   bitcells (unit cap C_o each), one SAR ADC per row, a B_x-bit DAC
//!   slice per column.
//! * **CM** — an N-column x B_w-row array, one sampling cap and one
//!   mixed-signal multiplier per column, a single DP-level SAR ADC.
//! * **Banked** — `banks` copies of the N/banks-row geometry plus a
//!   `banks - 1`-slice digital adder tree (`arch::Banked` composes this
//!   from the per-bank breakdown).
//!
//! Digital/bitcell blocks scale with F² (F = feature size); MOM caps
//! and the SAR cap-DAC are matching-limited and therefore roughly
//! node-independent — which is why cap-heavy QR arrays stop shrinking
//! with scaling while QS arrays keep pace (the area-side counterpart of
//! the Fig. 13 energy story).
//!
//! All block constants below are layout-typical standard-cell numbers,
//! not extracted from any one chip; the closed forms are pinned by
//! `tests/golden_snr.rs` and exercised as the fourth Pareto objective
//! throughout `crate::opt`.

use crate::arch::OpPoint;
use crate::tech::TechNode;

/// MOM (lateral-flux) capacitor density [fF/µm²], node-independent.
pub const MOM_CAP_DENSITY_FF_UM2: f64 = 2.0;
/// SAR cap-DAC unit capacitor [fF] (matching-limited).
pub const ADC_UNIT_CAP_FF: f64 = 0.5;
/// 6T SRAM bitcell [F²] (QS-Arch array).
pub const SRAM_6T_F2: f64 = 150.0;
/// Capacitor-augmented 8T compute bitcell [F²] (QR-Arch / CM array),
/// excluding its unit cap (costed separately at MOM density).
pub const CELL_8T_F2: f64 = 190.0;
/// Word-line driver slice per row [F²].
pub const WL_DRIVER_F2: f64 = 40.0;
/// Digital POT recombination slice per (weight, input) bit plane [F²]
/// (QS-Arch).
pub const POT_LOGIC_F2: f64 = 60.0;
/// Per-column activation-DAC slice per input bit [F²] (QR-Arch).
pub const DAC_SLICE_F2: f64 = 80.0;
/// Mixed-signal multiplier per column [F²] (CM).
pub const MULT_F2: f64 = 350.0;
/// Comparator + SAR logic per ADC bit [F²].
pub const ADC_LOGIC_F2: f64 = 900.0;
/// One two-input adder slice of the bank recombination tree [F²].
pub const BANK_ADDER_F2: f64 = 2000.0;

const UM2_TO_MM2: f64 = 1e-6;

/// Feature size in µm.
pub fn f_um(node: &TechNode) -> f64 {
    node.node_nm as f64 * 1e-3
}

/// Area of `f2` squared-feature units at this node, in µm².
pub fn f2_um2(node: &TechNode, f2: f64) -> f64 {
    let f = f_um(node);
    f2 * f * f
}

/// One SAR column/row ADC [µm²]: per-bit comparator/logic slices (scale
/// with F²) plus a binary-weighted cap-DAC of 2^B unit caps (matching-
/// limited, node-independent). Strictly increasing in `b_adc` — the
/// monotonicity the branch-and-bound area bound relies on.
pub fn adc_um2(node: &TechNode, b_adc: u32) -> f64 {
    f2_um2(node, ADC_LOGIC_F2) * b_adc as f64
        + 2f64.powi(b_adc as i32) * ADC_UNIT_CAP_FF / MOM_CAP_DENSITY_FF_UM2
}

/// The `banks - 1` adder slices of a bank recombination tree [µm²].
pub fn bank_adder_um2(node: &TechNode, banks: usize) -> f64 {
    banks.saturating_sub(1) as f64 * f2_um2(node, BANK_ADDER_F2)
}

/// The adder tree in mm² — the unit `arch::Banked` composes into its
/// [`AreaBreakdown`], so the µm²->mm² conversion lives in one place.
pub fn bank_adder_mm2(node: &TechNode, banks: usize) -> f64 {
    bank_adder_um2(node, banks) * UM2_TO_MM2
}

/// Per-DP area decomposition [mm²] (the area analogue of
/// `arch::EnergyBreakdown`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AreaBreakdown {
    /// Bitcell array [mm²].
    pub array_mm2: f64,
    /// MOM sampling/unit capacitors [mm²] (QR/CM only).
    pub caps_mm2: f64,
    /// Column/row/DP ADCs [mm²].
    pub adc_mm2: f64,
    /// Drivers, DACs, multipliers, recombination logic, bank adder
    /// tree [mm²].
    pub periphery_mm2: f64,
}

impl AreaBreakdown {
    pub fn total_mm2(&self) -> f64 {
        self.array_mm2 + self.caps_mm2 + self.adc_mm2 + self.periphery_mm2
    }

    /// Every component scaled by `k` (banking replicates the per-bank
    /// geometry `banks` times).
    pub fn scaled(&self, k: f64) -> AreaBreakdown {
        AreaBreakdown {
            array_mm2: self.array_mm2 * k,
            caps_mm2: self.caps_mm2 * k,
            adc_mm2: self.adc_mm2 * k,
            periphery_mm2: self.periphery_mm2 * k,
        }
    }
}

/// QS-Arch per-DP area (N x B_w 6T array, B_w column ADCs).
pub fn qs_area(node: &TechNode, op: &OpPoint) -> AreaBreakdown {
    let n = op.n as f64;
    let bw = op.bw as f64;
    let bx = op.bx as f64;
    AreaBreakdown {
        array_mm2: n * bw * f2_um2(node, SRAM_6T_F2) * UM2_TO_MM2,
        caps_mm2: 0.0,
        adc_mm2: bw * adc_um2(node, op.b_adc) * UM2_TO_MM2,
        periphery_mm2: (n * f2_um2(node, WL_DRIVER_F2)
            + bw * bx * f2_um2(node, POT_LOGIC_F2))
            * UM2_TO_MM2,
    }
}

/// QR-Arch per-DP area (B_w x N cap-augmented array with a C_o unit cap
/// per cell, B_w row ADCs, a B_x-bit DAC slice per column).
pub fn qr_area(node: &TechNode, c_o_ff: f64, op: &OpPoint) -> AreaBreakdown {
    let n = op.n as f64;
    let bw = op.bw as f64;
    let bx = op.bx as f64;
    AreaBreakdown {
        array_mm2: n * bw * f2_um2(node, CELL_8T_F2) * UM2_TO_MM2,
        caps_mm2: n * bw * c_o_ff / MOM_CAP_DENSITY_FF_UM2 * UM2_TO_MM2,
        adc_mm2: bw * adc_um2(node, op.b_adc) * UM2_TO_MM2,
        periphery_mm2: n * bx * f2_um2(node, DAC_SLICE_F2) * UM2_TO_MM2,
    }
}

/// CM per-DP area (N x B_w array, one sampling cap + multiplier per
/// column, a single DP ADC).
pub fn cm_area(node: &TechNode, c_o_ff: f64, op: &OpPoint) -> AreaBreakdown {
    let n = op.n as f64;
    let bw = op.bw as f64;
    AreaBreakdown {
        array_mm2: n * bw * f2_um2(node, CELL_8T_F2) * UM2_TO_MM2,
        caps_mm2: n * c_o_ff / MOM_CAP_DENSITY_FF_UM2 * UM2_TO_MM2,
        adc_mm2: adc_um2(node, op.b_adc) * UM2_TO_MM2,
        periphery_mm2: n
            * (f2_um2(node, WL_DRIVER_F2) + f2_um2(node, MULT_F2))
            * UM2_TO_MM2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(n: usize, b_adc: u32) -> OpPoint {
        OpPoint::new(n, 6, 6, b_adc)
    }

    #[test]
    fn magnitudes_are_plausible_at_65nm() {
        // a 512x6 QS macro slice: a few thousand µm², dominated by cells
        let t = TechNode::n65();
        let a = qs_area(&t, &op(512, 8));
        assert!(a.total_mm2() > 1e-3 && a.total_mm2() < 1e-2, "{a:?}");
        assert!(a.array_mm2 > a.adc_mm2, "cells dominate ADCs");
        assert_eq!(a.caps_mm2, 0.0, "QS has no MOM caps");
    }

    #[test]
    fn qr_caps_dominate_and_resist_scaling() {
        let big = TechNode::n65();
        let small = TechNode::n7();
        let o = op(512, 8);
        let a65 = qr_area(&big, 3.0, &o);
        let a7 = qr_area(&small, 3.0, &o);
        assert!(a65.caps_mm2 > a65.array_mm2, "3 fF caps outweigh cells");
        // digital shrinks ~(65/7)^2, caps not at all
        assert!(a7.array_mm2 < a65.array_mm2 / 50.0);
        assert_eq!(a7.caps_mm2, a65.caps_mm2, "MOM density is node-flat");
        assert!(a7.total_mm2() > a65.total_mm2() * 0.3);
    }

    #[test]
    fn adc_area_strictly_grows_with_bits() {
        let t = TechNode::n65();
        for b in 1..14 {
            assert!(adc_um2(&t, b + 1) > adc_um2(&t, b));
        }
        // cap-DAC takes over at high resolution
        assert!(adc_um2(&t, 14) > 4.0 * adc_um2(&t, 8));
    }

    #[test]
    fn per_arch_ordering_at_reference_shape() {
        // same cell count everywhere; QR adds N*Bw caps, CM N caps — so
        // area orders QS < CM < QR at the 512-row reference.
        let t = TechNode::n65();
        let o = op(512, 8);
        let qs = qs_area(&t, &o).total_mm2();
        let cm = cm_area(&t, 3.0, &o).total_mm2();
        let qr = qr_area(&t, 3.0, &o).total_mm2();
        assert!(qs < cm, "{qs} {cm}");
        assert!(cm < qr, "{cm} {qr}");
    }

    #[test]
    fn bank_adder_is_zero_for_one_bank() {
        let t = TechNode::n65();
        assert_eq!(bank_adder_um2(&t, 1), 0.0);
        assert_eq!(bank_adder_um2(&t, 0), 0.0);
        assert!(bank_adder_um2(&t, 4) > bank_adder_um2(&t, 2));
        assert_eq!(bank_adder_mm2(&t, 1), 0.0);
        assert_eq!(
            bank_adder_mm2(&t, 4).to_bits(),
            (bank_adder_um2(&t, 4) * 1e-6).to_bits()
        );
    }

    #[test]
    fn scaled_breakdown_scales_every_component() {
        let t = TechNode::n65();
        let a = qr_area(&t, 3.0, &op(128, 6));
        let b = a.scaled(4.0);
        assert_eq!(b.array_mm2, a.array_mm2 * 4.0);
        assert_eq!(b.caps_mm2, a.caps_mm2 * 4.0);
        assert_eq!(b.adc_mm2, a.adc_mm2 * 4.0);
        assert_eq!(b.periphery_mm2, a.periphery_mm2 * 4.0);
        assert!((b.total_mm2() - 4.0 * a.total_mm2()).abs() < 1e-15);
    }
}
