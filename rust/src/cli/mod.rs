//! The `imclim` command-line interface.
//!
//! Subcommands:
//!   figure <name|all>    regenerate a paper figure/table (CSV + stdout)
//!   table <t1|t2|t3>     aliases for table1/table2/table3
//!   sweep                user-defined design-space grid through the
//!                        cached sweep engine (lists + ranges per axis);
//!                        distributes across shard subprocesses with
//!                        --procs k, or runs one shard with --shard i/k
//!   pareto               energy-delay-accuracy Pareto frontier of a
//!                        design domain (closed forms, branch-and-bound),
//!                        optional MC validation through the engine cache,
//!                        optional QS-vs-QR crossover report
//!   optimize             constrained design-space optimum: min-energy /
//!                        min-delay / max-snr subject to SNR_T, energy
//!                        and delay bounds
//!   merge                union shard cache directories into one
//!                        (--strict exits nonzero on payload collisions)
//!   cache                cache maintenance: gc (size/age LRU), stats;
//!                        portable artifacts + registry exchange:
//!                        pack / verify / push <url> / pull <url>
//!   serve                sweep-as-a-service HTTP daemon: accepts
//!                        sweep/pareto/optimize jobs as JSON POSTs,
//!                        runs them through the same code paths as the
//!                        CLI against one shared cache (warm queries
//!                        answer with zero Monte-Carlo); doubles as the
//!                        coordinator for remote workers
//!   worker               remote execution worker: leases sweep shards
//!                        from a serve daemon and publishes results
//!                        back as verified cache artifacts
//!   dnn                  train the Fig. 2 MLP and report accuracy/SNR
//!   smoke                PJRT round-trip smoke test
//!   assign               precision assignment for a target SNR (Sec. III-B)
//!   info                 architecture/design-space summary

pub mod args;
pub mod serve;

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Context as _;

use crate::arch::{pvec, AdcCriterion, CmArch, ImcArch, OpPoint, QrArch, QsArch};
use crate::compute::{qr::QrModel, qs::QsModel};
use crate::coordinator::{run_shard_procs, Backend, PjrtService, ShardCommand};
use crate::engine::{
    gc, merge_cache_dirs, parse_grid_f64, parse_grid_u32, parse_grid_usize, parse_shard,
    scan_records, GcOptions, SweepSpec,
};
use crate::figures::FigCtx;
use crate::mc::{ArchKind, InputDist};
use crate::registry;
use crate::tech::TechNode;
use crate::util::csv::CsvWriter;
use crate::util::table::{fmt_area, fmt_db, fmt_energy, Table};
use args::{parse_bytes, parse_duration_secs, Args};

const USAGE: &str = "\
imclim — fundamental limits of in-memory computing architectures

USAGE: imclim <command> [options]

COMMANDS:
  figure <name|all>   regenerate a figure/table (fig2 fig4a fig4b fig9a
                      fig9b fig10a fig10b fig11a fig11b fig12 fig13
                      banked table1 table2 table3)
  table <1|2|3>       shorthand for table1/table2/table3
  sweep               design-space grid through the cached engine; every
                      axis takes lists \"a,b,c\" and ranges \"lo:hi[:step]\":
                      --arch qs,qr,cm --n 64,128 --bx 6 --bw 6
                      --b-adc 4:10 --vwl 0.6:0.8:0.1 --co 1,3,9
                      --node 65,7 --banks 1,2,4 --dist uniform,gauss
                      [--seed S]
                      emits <out-dir>/sweep.csv (closed forms incl. the
                      Table III area model per point); repeated points
                      are served from the cache under <out-dir>/cache;
                      --banks K splits each DP over K arrays of N/K rows
                      (Sec. VI ceiling escape; native backend only)
                        --procs K    distribute over K shard subprocesses,
                                     merge their caches, then emit the
                                     canonical CSV from the merged cache
                                     (byte-identical to a 1-process run);
                                     --keep-shards keeps shard-i/ dirs
                        --shard i/K  run only shard i of a K-way split
                                     (point ids and cache keys unchanged)
  pareto              four-objective Pareto frontier (max SNR_T, min
                      energy, min delay, min area) of a design domain,
                      from the closed-form models by dominance-pruned
                      branch-and-bound; same axis syntax as sweep plus
                      QS/CM knob --vwl and QR knob --co (irrelevant
                      knobs are dropped per architecture):
                      --arch qs,qr --node 65 --vwl 0.6:0.9:0.1 --co 3
                      --n 64:512:64 --bx 6 --bw 6 --b-adc 4:10
                      --banks 1,2,4
                      emits <out-dir>/pareto.csv (no row is dominated)
                        --procs K     extract over K worker threads
                                      (round-robin family shards merged
                                      and re-pruned; output identical to
                                      a 1-thread run)
                        --validate    Monte-Carlo-check frontier points
                                      through the cached sweep engine
                                      ([--trials N] [--seed S]; a cache
                                      populated by `sweep` over the same
                                      axes serves it without recompute)
                        --crossover   append the QS-vs-QR preference
                                      report over --targets (default
                                      1:28:1 dB), emitting crossover.csv
  optimize            constrained optimum over the same domain axes:
                      --objective min-energy|min-delay|max-snr|min-area
                      with any of --snr-t-min DB, --energy-max J,
                      --delay-max NS, --area-max MM2; prints the winning
                      design (always a Pareto point of its domain) + its
                      MPC ADC assignment, and emits
                      <out-dir>/optimize.csv
  merge <dir>...      union shard cache dirs (or their out-dirs) into
                      <out-dir>/cache, rebuilding the manifest; reports
                      key collisions with differing payloads (--strict
                      exits nonzero and lists every colliding key)
  cache gc            evict cache records: --max-bytes N[k|m|g] (LRU to
                      fit) and/or --max-age T[s|m|h|d] (expire older;
                      newer records are never evicted); --dry-run
  cache stats         record count / size / age summary of the cache,
                      plus the backend cache id and — when an artifact
                      has been packed — its schema/provenance line
  cache pack          snapshot <out-dir>/cache into a portable artifact
                      (<out-dir>/artifact/{artifact.json,payload.tar.gz}
                      or --artifact-dir DIR): per-record sha256 manifest
                      + deterministic tarball, content-addressed so
                      identical caches pack to identical artifacts
  cache verify        re-hash every record of a packed artifact against
                      its manifest; tampered, truncated or mislabeled
                      payloads exit nonzero
  cache push <url>    publish the packed artifact to a registry
                      (file:///path or http://host/base) under its
                      content address; re-pushing identical content is
                      a no-op. The registry index assumes one pusher at
                      a time: concurrent pushes can drop each other's
                      index rows (artifacts stay fetchable via --id;
                      re-push the artifact to repair its index entry)
  cache pull <url>    fetch artifacts (all in the registry index, or
                      one via --id), verify, then merge their records
                      into <out-dir>/cache under the same collision
                      rules as `merge` (--strict exits nonzero on any
                      differing-payload collision)
  serve               sweep-as-a-service daemon: accept sweep / pareto /
                      optimize jobs over HTTP and run them through the
                      exact CLI code paths against one shared cache
                      under <out-dir>/cache (served results are
                      byte-identical to their CLI twins; warm queries
                      recompute nothing). --addr HOST:PORT (default
                      127.0.0.1:7878; port 0 picks a free port, printed
                      on the \"listening on\" line), --queue-depth N
                      (default 64; a full queue answers HTTP 429).
                      Endpoints: GET /healthz, GET /stats,
                      GET /metrics (Prometheus text exposition of the
                      counter/gauge/histogram registry), POST /jobs,
                      GET /jobs/<id> (status JSON incl. queued_at /
                      started_at / finished_at / duration_ms),
                      GET /jobs/<id>/events (live NDJSON progress
                      stream over chunked transfer-encoding, ending
                      with the job's terminal event),
                      GET /jobs/<id>/result, POST /jobs/<id>/cancel,
                      POST /shutdown. SIGTERM / SIGINT / POST /shutdown
                      drain gracefully: the in-flight job completes,
                      queued jobs are canceled. The daemon is also the
                      coordinator for `imclim worker` processes:
                      registered workers get sweep jobs sharded across
                      them (--lease-timeout DUR, default 30s: a worker
                      silent that long is reaped and its shards
                      re-queued); with none registered, jobs run
                      locally exactly as before
  worker              attach to a serve daemon and execute leased sweep
                      shards: --connect http://HOST:PORT (required),
                      --name N (default worker-<pid>), --scratch DIR
                      (per-shard out-dirs + a local cache that stays
                      warm across leases), --poll-ms MS (idle lease
                      poll, default 500), --heartbeat-ms MS (keep-alive
                      while executing, default 1000). Results travel
                      back as verified cache artifacts (`cache pack` /
                      `push` over the coordinator's /fabric store); the
                      coordinator merges them and emits a CSV
                      byte-identical to a single-process run. Exits 0
                      when the coordinator drains or disappears —
                      workers are disposable; a killed worker's shards
                      are re-leased to the survivors or run locally by
                      the coordinator
  assign              precision assignment: --snr-a DB [--margin DB]
  dnn                 train the Fig. 2 MLP: [--epochs E]
  smoke               PJRT artifact round-trip check
  info                design-space summary

GRID SYNTAX (every axis):
  lists \"a,b,c\" and inclusive ranges \"lo:hi[:step]\" (step defaults
  to 1), composable: \"8,16:64:16\". Range endpoint rule: hi is included
  iff (hi-lo)/step is within 1e-9 relative tolerance of an integer —
  non-dividing steps stop at the last in-range value (\"1:10:4\" ->
  1,5,9), and when the endpoint divides, the last value is exactly the
  hi you typed (\"0.55:0.9:0.05\" ends on 0.9), immune to float
  representation drift. Values are lo + i*step (no accumulation).

COMMON OPTIONS:
  --out-dir DIR       output directory for CSVs (default: results)
  --cache-dir DIR     result cache root (default: <out-dir>/cache); lets
                      many out-dirs share one cache, the way the serve
                      daemon points every job at its shared cache
  --backend B         native | pjrt (default: native)
  --artifacts DIR     artifact directory for pjrt (default: artifacts)
  --trials N          MC trials per point (default: 2048); under
                      --precision it is unavailable (mutually exclusive)
  --precision DB      adaptive-precision trials: grow each native
                      ensemble in 256-trial chunks until the 95% CI
                      half-width of SNR_a and SNR_T is within DB
                      (capped at 65536 trials; native backend only;
                      cached separately from fixed-trials records)
  --workers N         worker threads (default: all cores, max 16);
                      fixed-trials native points are split into
                      per-chunk jobs across the pool, merged in chunk
                      order (bit-identical to --workers 1)
  --no-cache          bypass the content-addressed result cache
  --verbose           progress output
  --quiet             suppress progress output (errors still print);
                      wins over --verbose and --progress
  --progress MODE     progress stream mode: human (rate-limited stderr
                      lines, >=100 ms apart) or json (one NDJSON event
                      per line on stderr — the same events `serve`
                      streams at /jobs/<id>/events)
  --trace FILE        record structured spans (grid parse, cache probe,
                      MC chunks, adaptive rounds, frontier phases, cache
                      merge, CSV emit) and write a Chrome-trace-format
                      JSON file on exit; load it in Perfetto or
                      chrome://tracing. Tracing never changes outputs:
                      sweep.csv and cache records are byte-identical
                      with and without --trace
";

pub fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

pub fn run(args: &Args) -> anyhow::Result<()> {
    configure_observability(args)?;
    let result = dispatch(args);
    // The trace is written even when the command failed: a trace of the
    // work done up to the error is exactly what --trace is for. Trace
    // write failures are reported but never mask the command's result.
    if let Some(path) = args.opt("trace").map(PathBuf::from) {
        match crate::obs::trace::write_chrome_trace(&path) {
            Ok(n) => eprintln!("trace: {n} spans -> {}", path.display()),
            Err(e) => eprintln!("trace: failed to write {}: {e:#}", path.display()),
        }
    }
    result
}

fn dispatch(args: &Args) -> anyhow::Result<()> {
    match args.pos(0) {
        Some("figure") => cmd_figure(args),
        Some("table") => cmd_table(args),
        Some("sweep") => cmd_sweep(args),
        Some("pareto") => cmd_pareto(args),
        Some("optimize") => cmd_optimize(args),
        Some("merge") => cmd_merge(args),
        Some("cache") => cmd_cache(args),
        Some("serve") => serve::cmd_serve(args),
        Some("worker") => serve::cmd_worker(args),
        Some("assign") => cmd_assign(args),
        Some("dnn") => cmd_dnn(args),
        Some("smoke") => cmd_smoke(args),
        Some("info") => cmd_info(),
        _ => {
            print!("{USAGE}");
            print!("{}", args::EXAMPLES);
            Ok(())
        }
    }
}

/// Apply the global observability switches before any command runs:
/// progress-stream mode (--quiet wins over --progress, which wins over
/// --verbose) and span recording (--trace). Both are process-global and
/// inert by default, so commands that never emit stay zero-cost.
fn configure_observability(args: &Args) -> anyhow::Result<()> {
    use crate::obs::progress::{set_mode, ProgressMode};
    let mode = if args.has("quiet") {
        ProgressMode::Off
    } else {
        match args.opt("progress") {
            Some("json") => ProgressMode::Json,
            Some("human") => ProgressMode::Human,
            Some(other) => anyhow::bail!("--progress expects 'human' or 'json', got '{other}'"),
            None if args.has("verbose") => ProgressMode::Human,
            None => ProgressMode::Off,
        }
    };
    set_mode(mode);
    if args.opt("trace").is_some() {
        crate::obs::trace::enable();
    }
    Ok(())
}

/// Build the figure context (and keep the PJRT service alive with it).
fn make_ctx(args: &Args) -> anyhow::Result<(FigCtx, Option<PjrtService>)> {
    let out_dir: PathBuf = args.opt("out-dir").unwrap_or("results").into();
    let precision = match args.opt("precision") {
        None => None,
        Some(raw) => {
            anyhow::ensure!(
                args.opt("trials").is_none(),
                "--precision and --trials are mutually exclusive: --trials \
                 fixes the ensemble size, --precision lets the stopping \
                 rule choose it (the adaptive cap is {} trials)",
                crate::mc::ADAPTIVE_MAX_TRIALS
            );
            let half_width_db: f64 = raw
                .parse()
                .map_err(|_| anyhow::anyhow!("--precision expects a dB half-width, got '{raw}'"))?;
            anyhow::ensure!(
                half_width_db.is_finite() && half_width_db > 0.0,
                "--precision must be a positive finite dB half-width, got {half_width_db}"
            );
            Some(half_width_db)
        }
    };
    // under --precision, `trials` becomes the stopping rule's cap
    let trials = if precision.is_some() {
        crate::mc::ADAPTIVE_MAX_TRIALS
    } else {
        args.opt_parse("trials", 2048usize)
    };
    let workers = args.opt_parse(
        "workers",
        crate::coordinator::SweepOptions::default().workers,
    );
    let verbose = args.has("verbose") && !args.has("quiet");
    let (backend, service) = match args.opt("backend").unwrap_or("native") {
        "native" => (Backend::Native, None),
        "pjrt" => {
            let dir: PathBuf = args
                .opt("artifacts")
                .map(PathBuf::from)
                .unwrap_or_else(crate::runtime::default_artifacts_dir);
            let service = PjrtService::spawn(dir, 4);
            (
                Backend::Pjrt {
                    handle: service.handle(),
                    suffix: "",
                },
                Some(service),
            )
        }
        other => anyhow::bail!("unknown backend '{other}'"),
    };
    Ok((
        FigCtx {
            backend,
            out_dir,
            trials,
            precision,
            workers,
            verbose,
            cache: !args.has("no-cache"),
            cache_dir: args.opt("cache-dir").map(PathBuf::from),
        },
        service,
    ))
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let name = args.pos(1).unwrap_or("all");
    let (ctx, _service) = make_ctx(args)?;
    std::fs::create_dir_all(&ctx.out_dir)?;
    let summaries = crate::figures::run(name, &ctx)?;
    for s in &summaries {
        println!(
            "[{}] {} rows -> {}",
            s.name,
            s.rows,
            ctx.csv_path(&s.name).display()
        );
    }
    Ok(())
}

fn cmd_table(args: &Args) -> anyhow::Result<()> {
    let t = match args.pos(1) {
        Some("1") | Some("taxonomy") => "table1",
        Some("2") | Some("params") => "table2",
        Some("3") | Some("table3-check") => "table3",
        other => anyhow::bail!("unknown table {other:?} (1, 2 or 3)"),
    };
    let (ctx, _service) = make_ctx(args)?;
    std::fs::create_dir_all(&ctx.out_dir)?;
    crate::figures::run(t, &ctx)?;
    Ok(())
}

/// Instantiate an architecture model for the sweep CLI — through
/// `opt::Family::build`, the same constructor the design-space
/// optimizer uses, so `imclim sweep` and `pareto --validate` produce
/// identical `pjrt_params` (and therefore share cache records) by
/// construction. A bank count > 1 yields the `arch::Banked` variant.
/// The shape fields of the throwaway family are dummies: only (arch,
/// node, knobs, banks) feed the model.
fn build_arch(
    name: &str,
    node: TechNode,
    v_wl: f64,
    c_ff: f64,
    banks: usize,
) -> anyhow::Result<(Box<dyn ImcArch>, ArchKind)> {
    let arch = crate::opt::ArchChoice::parse(name)?;
    let family = crate::opt::Family {
        arch,
        node,
        v_wl: Some(v_wl),
        c_ff: Some(c_ff),
        n: 1,
        bx: 1,
        bw: 1,
        banks,
    };
    Ok((family.build(), arch.kind()))
}

/// Per-point metadata carried alongside the sweep: the grid coordinates
/// plus the closed-form predictions that accompany the simulation.
struct SweepMeta {
    arch: String,
    node_nm: u32,
    v_wl: f64,
    c_ff: f64,
    n: usize,
    bx: u32,
    bw: u32,
    b_adc: u32,
    banks: usize,
    dist: String,
    nb: crate::arch::NoiseBreakdown,
    b_adc_min: u32,
    energy_mpc_j: f64,
    delay_ns: f64,
    area_mm2: f64,
}

fn csv_list(raw: &str) -> Vec<String> {
    raw.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let shard = args.opt("shard").map(parse_shard).transpose()?;
    let procs = args.opt_parse("procs", 1usize);
    if procs > 1 {
        anyhow::ensure!(
            shard.is_none(),
            "--procs and --shard are mutually exclusive (the parent assigns shards)"
        );
        anyhow::ensure!(
            !args.has("no-cache"),
            "--procs needs the result cache: shard outputs are exchanged by merging caches"
        );
        orchestrate_sharded_sweep(args, procs)?;
        // warm pass over the merged cache computes nothing and emits the
        // canonical full-grid sweep.csv (byte-identical to a one-process
        // run, since every record round-trips bit-exactly).
        return run_sweep_grid(args, None);
    }
    run_sweep_grid(args, shard)
}

/// Spawn `procs` shard subprocesses of this same sweep, stream their
/// progress, and merge their cache directories into `<out-dir>/cache`.
fn orchestrate_sharded_sweep(args: &Args, procs: usize) -> anyhow::Result<()> {
    let out_dir: PathBuf = args.opt("out-dir").unwrap_or("results").into();
    std::fs::create_dir_all(&out_dir)?;
    let exe = std::env::current_exe().context("locating the imclim executable")?;
    let mut shards = Vec::with_capacity(procs);
    let mut shard_dirs = Vec::with_capacity(procs);
    for i in 0..procs {
        let dir = out_dir.join(format!("shard-{i}"));
        let mut command = std::process::Command::new(&exe);
        command.arg("sweep");
        // trace/progress stay with the parent: shards sharing the
        // parent's trace path would race on the file, and forwarded
        // shard lines carry a "[shard i/k]" prefix that would corrupt
        // an NDJSON stream (--verbose and --quiet still pass through).
        for (k, v) in &args.options {
            if matches!(k.as_str(), "out-dir" | "procs" | "shard" | "trace" | "progress") {
                continue;
            }
            command.arg(format!("--{k}")).arg(v);
        }
        for sw in &args.switches {
            if sw == "keep-shards" {
                continue;
            }
            command.arg(format!("--{sw}"));
        }
        // split the default thread budget across the shard processes so
        // --procs doesn't oversubscribe the CPU K-fold; an explicit
        // --workers is the user's per-shard choice and passes through.
        if args.opt("workers").is_none() {
            let per_shard = crate::coordinator::SweepOptions::default()
                .workers
                .div_ceil(procs)
                .max(1);
            command.arg("--workers").arg(per_shard.to_string());
        }
        command.arg("--shard").arg(format!("{i}/{procs}"));
        command.arg("--out-dir").arg(&dir);
        shards.push(ShardCommand {
            label: format!("shard {i}/{procs}"),
            command,
        });
        shard_dirs.push(dir);
    }
    let quiet = args.has("quiet");
    if !quiet {
        eprintln!(
            "sweep: distributing over {procs} shard processes under {}",
            out_dir.display()
        );
    }
    run_shard_procs(shards)?;

    let dst = out_dir.join("cache");
    let sources: Vec<PathBuf> = shard_dirs.iter().map(|d| d.join("cache")).collect();
    let report = merge_cache_dirs(&dst, &sources)?;
    if !quiet {
        eprintln!(
            "sweep: merged {} shard caches into {} ({} new records, {} already shared)",
            procs,
            dst.display(),
            report.copied,
            report.identical
        );
    }
    if !report.collisions.is_empty() {
        eprintln!(
            "warning: {} cache keys collided with differing payloads (kept existing): {:?}",
            report.collisions.len(),
            report.collisions
        );
    }
    if !args.has("keep-shards") && report.collisions.is_empty() {
        for d in &shard_dirs {
            let _ = std::fs::remove_dir_all(d);
        }
    }
    Ok(())
}

/// Run the sweep grid in-process (optionally restricted to one shard of
/// a `--shard i/k` split) and emit `<out-dir>/sweep.csv`. `pub(crate)`
/// so the serve daemon can execute submitted sweeps through the exact
/// code path the CLI uses.
pub(crate) fn run_sweep_grid(args: &Args, shard: Option<(usize, usize)>) -> anyhow::Result<()> {
    let (ctx, _service) = make_ctx(args)?;
    std::fs::create_dir_all(&ctx.out_dir)?;

    // spans grid parsing + validation through point/meta construction
    let parse_span = crate::obs::trace::span("grid_parse", "sweep");
    let archs = csv_list(args.opt("arch").unwrap_or("qs"));
    let nodes = csv_list(args.opt("node").unwrap_or("65"));
    let dists = csv_list(args.opt("dist").unwrap_or("uniform"));
    for a in &archs {
        anyhow::ensure!(
            matches!(a.as_str(), "qs" | "qr" | "cm"),
            "unknown arch '{a}' (qs, qr or cm)"
        );
    }
    for nd in &nodes {
        anyhow::ensure!(TechNode::by_name(nd).is_some(), "unknown node '{nd}'");
    }
    for d in &dists {
        anyhow::ensure!(
            matches!(d.as_str(), "uniform" | "gauss"),
            "unknown dist '{d}' (uniform or gauss)"
        );
    }
    let vwls = parse_grid_f64(args.opt("vwl").unwrap_or("0.8"))?;
    let cos = parse_grid_f64(args.opt("co").unwrap_or("3"))?;
    let ns = parse_grid_usize(args.opt("n").unwrap_or("128"))?;
    let bxs = parse_grid_u32(args.opt("bx").unwrap_or("6"))?;
    let bws = parse_grid_u32(args.opt("bw").unwrap_or("6"))?;
    let b_adcs = parse_grid_u32(args.opt("b-adc").unwrap_or("8"))?;
    let banks_axis = parse_grid_usize(args.opt("banks").unwrap_or("1"))?;
    for &k in &banks_axis {
        anyhow::ensure!(k >= 1, "bank count must be >= 1, got {k}");
        // the sweep grid is a cartesian product, so every bank count
        // pairs with every N: splitting an N-row DP into more than N
        // banks would mislabel a larger machine as that N
        if let Some(&n_min) = ns.iter().min() {
            anyhow::ensure!(
                k <= n_min,
                "bank count {k} exceeds the smallest N in the grid ({n_min}): \
                 every --banks value must divide into every --n value's rows"
            );
        }
    }
    let seed = args.opt_parse("seed", 7u64);

    let arch_refs: Vec<&str> = archs.iter().map(String::as_str).collect();
    let node_refs: Vec<&str> = nodes.iter().map(String::as_str).collect();
    let dist_refs: Vec<&str> = dists.iter().map(String::as_str).collect();
    let mut spec = SweepSpec::new("sweep")
        .axis_strs("arch", &arch_refs)
        .axis_strs("node", &node_refs)
        .axis_f64("vwl", &vwls)
        .axis_f64("co", &cos)
        .axis_usize("n", &ns)
        .axis_u32("bx", &bxs)
        .axis_u32("bw", &bws)
        .axis_u32("badc", &b_adcs)
        .axis_usize("banks", &banks_axis)
        .axis_strs("dist", &dist_refs);
    // the *full* grid must be non-empty; an individual shard may still
    // be (more shards than points), which is fine — it emits zero rows.
    anyhow::ensure!(spec.full_len() > 0, "empty sweep grid");
    if let Some((i, k)) = shard {
        spec = spec.shard(i, k)?;
    }

    // Closed forms use the paper's uniform signal statistics throughout;
    // the input distribution axis only changes the simulated ensemble.
    let (w, x) = crate::figures::uniform_stats();
    let mut points = Vec::with_capacity(spec.len());
    let mut meta: Vec<SweepMeta> = Vec::with_capacity(spec.len());
    for gp in spec.points() {
        let arch_name = gp.text(0).to_string();
        let node = TechNode::by_name(gp.text(1)).expect("validated above");
        let v_wl = gp.num(2);
        let c_ff = gp.num(3);
        let n = gp.int(4) as usize;
        let bx = gp.int(5) as u32;
        let bw = gp.int(6) as u32;
        let b_adc = gp.int(7) as u32;
        let banks = gp.int(8) as usize;
        let dist = gp.text(9).to_string();
        let (arch, kind) = build_arch(&arch_name, node, v_wl, c_ff, banks)?;
        let op = OpPoint::new(n, bx, bw, b_adc).with_banks(banks);
        let mut point =
            crate::figures::sweep_point(arch.as_ref(), kind, gp.id.clone(), &op, ctx.trials, seed);
        if dist == "gauss" {
            point.dist = InputDist::ClippedGaussian { sx: 0.35, sw: 0.35 };
        }
        point.precision = ctx.precision;
        meta.push(SweepMeta {
            arch: arch_name,
            node_nm: node.node_nm,
            v_wl,
            c_ff,
            n,
            bx,
            bw,
            b_adc,
            banks,
            dist,
            nb: arch.noise(&op, &w, &x),
            b_adc_min: arch.b_adc_min(&op, &w, &x),
            energy_mpc_j: arch.energy(&op, AdcCriterion::Mpc, &w, &x).total(),
            delay_ns: arch.delay(&op) * 1e9,
            area_mm2: arch.area(&op).total_mm2(),
        });
        points.push(point);
    }
    drop(parse_span);

    let (results, stats) = ctx.engine().run_with_stats(points);

    let emit_span = crate::obs::trace::span_with("csv_emit", "sweep", || {
        format!("{} rows", results.len())
    });
    let mut csv = CsvWriter::new(&[
        "arch",
        "node_nm",
        "vwl",
        "co_ff",
        "n",
        "bx",
        "bw",
        "b_adc",
        "banks",
        "dist",
        "snr_a_closed_db",
        "snr_a_sim_db",
        "snr_t_sim_db",
        "b_adc_min_mpc",
        "energy_mpc_j",
        "delay_ns",
        "area_mm2",
        "error",
    ]);
    for (m, r) in meta.iter().zip(&results) {
        csv.row(&[
            m.arch.clone(),
            m.node_nm.to_string(),
            m.v_wl.to_string(),
            m.c_ff.to_string(),
            m.n.to_string(),
            m.bx.to_string(),
            m.bw.to_string(),
            m.b_adc.to_string(),
            m.banks.to_string(),
            m.dist.clone(),
            format!("{:.4}", m.nb.snr_a_total_db()),
            format!("{:.4}", r.measured.snr_a_total_db),
            format!("{:.4}", r.measured.snr_t_db),
            m.b_adc_min.to_string(),
            format!("{:.6e}", m.energy_mpc_j),
            format!("{:.4}", m.delay_ns),
            format!("{:.6e}", m.area_mm2),
            r.error.clone().unwrap_or_default(),
        ]);
    }
    let csv_path = ctx.csv_path("sweep");
    csv.write_to(&csv_path)?;
    drop(emit_span);

    if results.len() == 1 {
        let m = &meta[0];
        let r = &results[0];
        if let Some(e) = &r.error {
            anyhow::bail!("sweep point failed: {e}");
        }
        let mut t = Table::new(&["metric", "closed form", "simulated"]).with_title(&format!(
            "{} at N={} Bx={} Bw={} B_ADC={}{} ({} nm)",
            m.arch,
            m.n,
            m.bx,
            m.bw,
            m.b_adc,
            if m.banks > 1 {
                format!(" banks={}", m.banks)
            } else {
                String::new()
            },
            m.node_nm
        ));
        t.row(vec![
            "SQNR_qiy (dB)".into(),
            fmt_db(m.nb.sqnr_qiy_db()),
            fmt_db(r.measured.sqnr_qiy_db),
        ]);
        t.row(vec![
            "SNR_a (dB)".into(),
            fmt_db(m.nb.snr_a_db()),
            fmt_db(r.measured.snr_a_db),
        ]);
        t.row(vec![
            "SNR_A (dB)".into(),
            fmt_db(m.nb.snr_a_total_db()),
            fmt_db(r.measured.snr_a_total_db),
        ]);
        t.row(vec![
            "SNR_T (dB)".into(),
            "-".into(),
            fmt_db(r.measured.snr_t_db),
        ]);
        t.row(vec![
            "B_ADC min (MPC)".into(),
            m.b_adc_min.to_string(),
            "-".into(),
        ]);
        t.row(vec![
            "energy/DP (MPC)".into(),
            fmt_energy(m.energy_mpc_j),
            "-".into(),
        ]);
        t.row(vec![
            "delay/DP".into(),
            format!("{:.2} ns", m.delay_ns),
            "-".into(),
        ]);
        t.row(vec!["area".into(), fmt_area(m.area_mm2), "-".into()]);
        println!("{}", t.render());
    } else {
        let shown = results.len().min(10);
        let mut t = Table::new(&["point", "SNR_A sim (dB)", "SNR_T sim (dB)"])
            .with_title(&format!("sweep: {} points", results.len()));
        for r in results.iter().take(shown) {
            t.row(vec![
                r.id.clone(),
                fmt_db(r.measured.snr_a_total_db),
                fmt_db(r.measured.snr_t_db),
            ]);
        }
        println!("{}", t.render());
        if results.len() > shown {
            println!("... {} more rows in the CSV", results.len() - shown);
        }
    }
    println!(
        "sweep{}: {} points ({} cache hits, {} computed{}) -> {}",
        shard
            .map(|(i, k)| format!(" [shard {i}/{k}]"))
            .unwrap_or_default(),
        results.len(),
        stats.hits,
        stats.misses,
        if stats.errors > 0 {
            format!(", {} errors", stats.errors)
        } else {
            String::new()
        },
        csv_path.display()
    );
    // the CSV (with its error column) is written either way, but failed
    // points must be observable to scripts and the --procs parent
    anyhow::ensure!(
        stats.errors == 0,
        "{} sweep point(s) failed (see the error column in {})",
        stats.errors,
        csv_path.display()
    );
    Ok(())
}

/// Parse the shared design-domain axes of `pareto` / `optimize`. The
/// defaults span the reference design space: QS vs QR at 65 nm over the
/// usable V_WL range, N up to the 512-row array, B_ADC 4..10.
fn parse_opt_domain(args: &Args) -> anyhow::Result<crate::opt::Domain> {
    let archs = csv_list(args.opt("arch").unwrap_or("qs,qr"))
        .iter()
        .map(|a| crate::opt::ArchChoice::parse(a))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let nodes = csv_list(args.opt("node").unwrap_or("65"))
        .iter()
        .map(|nd| TechNode::by_name(nd).ok_or_else(|| anyhow::anyhow!("unknown node '{nd}'")))
        .collect::<anyhow::Result<Vec<_>>>()?;
    crate::opt::Domain {
        archs,
        nodes,
        vwls: parse_grid_f64(args.opt("vwl").unwrap_or("0.6:0.9:0.1"))?,
        cos: parse_grid_f64(args.opt("co").unwrap_or("3"))?,
        ns: parse_grid_usize(args.opt("n").unwrap_or("64:512:64"))?,
        bxs: parse_grid_u32(args.opt("bx").unwrap_or("6"))?,
        bws: parse_grid_u32(args.opt("bw").unwrap_or("6"))?,
        b_adcs: parse_grid_u32(args.opt("b-adc").unwrap_or("4:10"))?,
        banks: parse_grid_usize(args.opt("banks").unwrap_or("1"))?,
    }
    .normalized()
}

/// Shared CSV emission for design points: the closed-form columns plus
/// (for `pareto --validate`) the simulated SNR_T and any point error.
fn design_point_csv() -> CsvWriter {
    CsvWriter::new(&[
        "arch",
        "node_nm",
        "vwl",
        "co_ff",
        "n",
        "bx",
        "bw",
        "banks",
        "b_adc",
        "b_adc_mpc",
        "snr_a_db",
        "snr_t_db",
        "energy_j",
        "delay_ns",
        "area_mm2",
        "snr_t_sim_db",
        "sim_error",
    ])
}

fn design_point_row(csv: &mut CsvWriter, p: &crate::opt::DesignPoint, sim: &str, err: &str) {
    csv.row(&[
        p.family.arch.name().to_string(),
        p.family.node.node_nm.to_string(),
        p.family.v_wl.map(|v| v.to_string()).unwrap_or_default(),
        p.family.c_ff.map(|c| c.to_string()).unwrap_or_default(),
        p.family.n.to_string(),
        p.family.bx.to_string(),
        p.family.bw.to_string(),
        p.family.banks.to_string(),
        p.b_adc.to_string(),
        p.b_adc_mpc.to_string(),
        format!("{:.4}", p.snr_a_total_db),
        format!("{:.4}", p.snr_t_db),
        format!("{:.6e}", p.energy_j),
        format!("{:.4}", p.delay_ns()),
        format!("{:.6e}", p.area_mm2),
        sim.to_string(),
        err.to_string(),
    ]);
}

pub(crate) fn cmd_pareto(args: &Args) -> anyhow::Result<()> {
    let domain = parse_opt_domain(args)?;
    let procs = args.opt_parse("procs", 1usize);
    anyhow::ensure!(procs >= 1, "--procs must be >= 1");
    let (ctx, _service) = make_ctx(args)?;
    std::fs::create_dir_all(&ctx.out_dir)?;
    let (w, x) = crate::figures::uniform_stats();

    let frontier = crate::opt::frontier(&domain, procs, &w, &x);

    // Optional Monte-Carlo validation of the frontier points, through
    // the cached sweep engine: content keys ignore labels, so a cache
    // populated by `imclim sweep` (sharded or not) over the same axes
    // serves these points without recomputation.
    let mut sims: Vec<(String, String)> =
        vec![(String::new(), String::new()); frontier.points.len()];
    let mut sim_errors = 0usize;
    if args.has("validate") {
        let seed = args.opt_parse("seed", 7u64);
        let points: Vec<crate::coordinator::SweepPoint> = frontier
            .points
            .iter()
            .map(|p| p.validation_point(&w, &x, ctx.trials, seed, ctx.precision))
            .collect();
        let (results, stats) = ctx.engine().run_with_stats(points);
        for (slot, r) in sims.iter_mut().zip(&results) {
            if let Some(e) = &r.error {
                slot.1 = e.clone();
            } else {
                slot.0 = format!("{:.4}", r.measured.snr_t_db);
            }
        }
        println!(
            "pareto: validated {} frontier points ({} cache hits, {} computed{})",
            results.len(),
            stats.hits,
            stats.misses,
            if stats.errors > 0 {
                format!(", {} errors", stats.errors)
            } else {
                String::new()
            }
        );
        sim_errors = stats.errors;
    }

    // the CSV (with its sim_error column) is written even when
    // validation points failed, so the failure below is inspectable
    let emit_span = crate::obs::trace::span_with("csv_emit", "pareto", || {
        format!("{} rows", frontier.points.len())
    });
    let mut csv = design_point_csv();
    for (p, (sim, err)) in frontier.points.iter().zip(&sims) {
        design_point_row(&mut csv, p, sim, err);
    }
    let csv_path = ctx.csv_path("pareto");
    csv.write_to(&csv_path)?;
    drop(emit_span);
    anyhow::ensure!(
        sim_errors == 0,
        "{} validation point(s) failed (see the sim_error column in {})",
        sim_errors,
        csv_path.display()
    );

    let shown = frontier.points.len().min(10);
    let mut t = Table::new(&["design", "SNR_T (dB)", "energy/DP", "delay", "area"]).with_title(
        &format!(
            "Pareto frontier: {} of {} candidates survive",
            frontier.points.len(),
            frontier.points_total
        ),
    );
    for p in frontier.points.iter().take(shown) {
        t.row(vec![
            p.label(),
            fmt_db(p.snr_t_db),
            fmt_energy(p.energy_j),
            format!("{:.2} ns", p.delay_ns()),
            fmt_area(p.area_mm2),
        ]);
    }
    println!("{}", t.render());
    if frontier.points.len() > shown {
        println!("... {} more rows in the CSV", frontier.points.len() - shown);
    }
    println!(
        "pareto: {} families ({} pruned by corner bounds), {} of {} candidates evaluated, frontier {} -> {}",
        frontier.families,
        frontier.families_pruned,
        frontier.points_evaluated,
        frontier.points_total,
        frontier.points.len(),
        csv_path.display()
    );

    if args.has("crossover") {
        let targets = parse_grid_f64(args.opt("targets").unwrap_or("1:28:1"))?;
        let report = crate::opt::crossover(&domain, &targets, &w, &x)?;
        let mut csv = CsvWriter::new(&[
            "target_snr_t_db",
            "qs_energy_j",
            "qs_design",
            "qr_energy_j",
            "qr_design",
            "preferred",
        ]);
        for row in &report.rows {
            let fmt = |p: &Option<crate::opt::DesignPoint>| match p {
                Some(p) => (format!("{:.6e}", p.energy_j), p.label()),
                None => (String::new(), String::new()),
            };
            let (qs_e, qs_d) = fmt(&row.qs);
            let (qr_e, qr_d) = fmt(&row.qr);
            csv.row(&[
                format!("{:.2}", row.target_snr_t_db),
                qs_e,
                qs_d,
                qr_e,
                qr_d,
                row.preferred.map(|a| a.name().to_string()).unwrap_or_default(),
            ]);
        }
        let cross_path = ctx.csv_path("crossover");
        {
            let _span = crate::obs::trace::span_with("csv_emit", "pareto", || {
                format!("{} crossover rows", report.rows.len())
            });
            csv.write_to(&cross_path)?;
        }
        match report.crossover_snr_t_db {
            Some(c) => println!(
                "crossover: QS-Arch preferred below {c:.2} dB, QR-Arch at and above \
                 (conclusion 3; QS ceiling {:.2} dB, QR ceiling {:.2} dB) -> {}",
                report.qs_max_snr_t_db,
                report.qr_max_snr_t_db,
                cross_path.display()
            ),
            None => println!(
                "crossover: no preference flip inside this domain \
                 (QS ceiling {:.2} dB, QR ceiling {:.2} dB) -> {}",
                report.qs_max_snr_t_db,
                report.qr_max_snr_t_db,
                cross_path.display()
            ),
        }
    }
    Ok(())
}

pub(crate) fn cmd_optimize(args: &Args) -> anyhow::Result<()> {
    let domain = parse_opt_domain(args)?;
    let objective = crate::opt::Objective::parse(args.opt("objective").unwrap_or("min-energy"))?;
    let parse_f64_opt = |name: &str| -> anyhow::Result<Option<f64>> {
        args.opt(name)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad --{name} '{v}'"))
            })
            .transpose()
    };
    let constraints = crate::opt::Constraints {
        snr_t_min_db: parse_f64_opt("snr-t-min")?,
        energy_max_j: parse_f64_opt("energy-max")?,
        delay_max_s: parse_f64_opt("delay-max")?.map(|ns| ns * 1e-9),
        area_max_mm2: parse_f64_opt("area-max")?,
    };
    let (ctx, _service) = make_ctx(args)?;
    std::fs::create_dir_all(&ctx.out_dir)?;
    let (w, x) = crate::figures::uniform_stats();

    let report = crate::opt::optimize(&domain, objective, &constraints, &w, &x);
    let Some(best) = &report.best else {
        anyhow::bail!(
            "no design in the domain satisfies the constraints \
             ({} families: {} pruned by bounds, {} evaluated)",
            report.families,
            report.families_pruned,
            report.families_evaluated
        );
    };

    let emit_span = crate::obs::trace::span("csv_emit", "optimize");
    let mut csv = design_point_csv();
    design_point_row(&mut csv, best, "", "");
    let csv_path = ctx.csv_path("optimize");
    csv.write_to(&csv_path)?;
    drop(emit_span);

    let mut t = Table::new(&["metric", "value"]).with_title(&format!(
        "{} optimum: {}",
        objective.name(),
        best.label()
    ));
    t.row(vec!["SNR_A (dB)".into(), fmt_db(best.snr_a_total_db)]);
    t.row(vec!["SNR_T (dB)".into(), fmt_db(best.snr_t_db)]);
    t.row(vec!["energy/DP".into(), fmt_energy(best.energy_j)]);
    t.row(vec!["delay/DP".into(), format!("{:.2} ns", best.delay_ns())]);
    t.row(vec!["area".into(), fmt_area(best.area_mm2)]);
    t.row(vec![
        "B_ADC".into(),
        if best.b_adc == best.b_adc_mpc {
            format!("{} (matches MPC assignment)", best.b_adc)
        } else {
            format!("{} (MPC would assign {})", best.b_adc, best.b_adc_mpc)
        },
    ]);
    println!("{}", t.render());
    println!(
        "optimize: {} families ({} pruned by bounds, {} behind the incumbent cut), \
         {} evaluated -> {}",
        report.families,
        report.families_pruned,
        report.families_cut,
        report.families_evaluated,
        csv_path.display()
    );
    Ok(())
}

fn cmd_merge(args: &Args) -> anyhow::Result<()> {
    let sources: Vec<PathBuf> = args.positionals[1..].iter().map(PathBuf::from).collect();
    anyhow::ensure!(
        !sources.is_empty(),
        "usage: imclim merge <shard-dir>... [--out-dir DIR]"
    );
    let out_dir: PathBuf = args.opt("out-dir").unwrap_or("results").into();
    let dst = out_dir.join("cache");
    // accept either an out-dir (containing cache/) or a cache dir itself
    let resolved: Vec<PathBuf> = sources
        .iter()
        .map(|p| {
            let nested = p.join("cache");
            if nested.is_dir() {
                nested
            } else {
                p.clone()
            }
        })
        .collect();
    let report = merge_cache_dirs(&dst, &resolved)?;
    println!(
        "merged {} dirs into {}: {} new records, {} identical, {} collisions",
        resolved.len(),
        dst.display(),
        report.copied,
        report.identical,
        report.collisions.len()
    );
    if report.backends.len() > 1 {
        println!(
            "warning: mixed backends across merged caches: {:?}",
            report.backends
        );
    }
    if !report.collisions.is_empty() {
        if args.has("strict") {
            eprintln!("keys with differing payloads (existing copy kept):");
            for k in &report.collisions {
                eprintln!("  {k}");
            }
            anyhow::bail!(
                "merge --strict: {} key(s) collided with differing payloads",
                report.collisions.len()
            );
        }
        println!("warning: keys with differing payloads (existing copy kept):");
        for k in report.collisions.iter().take(20) {
            println!("  {k}");
        }
        if report.collisions.len() > 20 {
            println!("  ... and {} more", report.collisions.len() - 20);
        }
    }
    Ok(())
}

/// Artifact directory for `cache pack/verify/push/pull`: `--artifact-dir`
/// or `<out-dir>/artifact` (sibling of the cache dir).
fn cache_artifact_dir(args: &Args) -> PathBuf {
    match args.opt("artifact-dir") {
        Some(d) => d.into(),
        None => PathBuf::from(args.opt("out-dir").unwrap_or("results")).join("artifact"),
    }
}

fn cmd_cache(args: &Args) -> anyhow::Result<()> {
    let dir: PathBuf = match args.opt("dir") {
        Some(d) => d.into(),
        None => PathBuf::from(args.opt("out-dir").unwrap_or("results")).join("cache"),
    };
    match args.pos(1) {
        Some("gc") => {
            let max_bytes = args.opt("max-bytes").map(parse_bytes).transpose()?;
            let max_age = args
                .opt("max-age")
                .map(parse_duration_secs)
                .transpose()?
                .map(Duration::from_secs);
            anyhow::ensure!(
                max_bytes.is_some() || max_age.is_some(),
                "cache gc needs --max-bytes and/or --max-age"
            );
            let report = gc(
                &dir,
                &GcOptions {
                    max_bytes,
                    max_age,
                    dry_run: args.has("dry-run"),
                },
            )?;
            println!(
                "cache gc{}: {} records scanned, {} evicted, {} -> {} bytes in {}",
                if args.has("dry-run") { " (dry run)" } else { "" },
                report.scanned,
                report.evicted,
                report.bytes_before,
                report.bytes_after,
                dir.display()
            );
            Ok(())
        }
        Some("stats") => {
            let records = scan_records(&dir)?;
            let total: u64 = records.iter().map(|r| r.bytes).sum();
            let oldest = records
                .first()
                .and_then(|r| r.modified.elapsed().ok())
                .map(|d| d.as_secs())
                .unwrap_or(0);
            println!(
                "cache {}: {} records, {} bytes, oldest last used {}s ago",
                dir.display(),
                records.len(),
                total,
                oldest
            );
            if let Some(backend) = crate::engine::manifest_backend(&dir) {
                println!("backend: {backend}");
            }
            let artifact_dir = cache_artifact_dir(args);
            if artifact_dir.join(registry::ARTIFACT_FILE).is_file() {
                let artifact = registry::read_manifest(&artifact_dir)?;
                println!("artifact: {}", artifact.provenance_line());
            }
            Ok(())
        }
        Some("pack") => {
            let artifact_dir = cache_artifact_dir(args);
            let params = format!("cache pack --dir {}", dir.display());
            let report = registry::pack(&dir, &artifact_dir, &params)?;
            println!(
                "packed {} records ({} payload bytes) from {} into {}",
                report.records,
                report.payload_bytes,
                dir.display(),
                artifact_dir.display()
            );
            println!("artifact id: {}", report.id);
            Ok(())
        }
        Some("verify") => {
            let artifact_dir = cache_artifact_dir(args);
            let report = registry::verify(&artifact_dir)?;
            println!(
                "verified artifact {} ({}): backend {}, {} records, {} payload bytes — OK",
                report.id,
                artifact_dir.display(),
                report.backend,
                report.records,
                report.payload_bytes
            );
            Ok(())
        }
        Some("push") => {
            let url = args
                .pos(2)
                .context("usage: imclim cache push <url> [--artifact-dir DIR]")?;
            let store = registry::open_store(url)?;
            let report = registry::push(&cache_artifact_dir(args), store.as_ref())?;
            if report.already_present {
                println!(
                    "artifact {} already present at {} ({} records) — nothing to do",
                    report.id,
                    store.describe(),
                    report.records
                );
            } else {
                println!(
                    "pushed artifact {} ({} records, {} payload bytes) to {}",
                    report.id,
                    report.records,
                    report.payload_bytes,
                    store.describe()
                );
            }
            Ok(())
        }
        Some("pull") => {
            let url = args
                .pos(2)
                .context("usage: imclim cache pull <url> [--id ID] [--strict]")?;
            let store = registry::open_store(url)?;
            let report = registry::pull(store.as_ref(), &dir, args.opt("id"))?;
            println!(
                "pulled {} artifact(s) from {} into {}: {} new records, {} identical, {} collisions",
                report.artifacts.len(),
                store.describe(),
                dir.display(),
                report.copied,
                report.identical,
                report.collisions.len()
            );
            if report.backends.len() > 1 {
                println!(
                    "warning: mixed backends across pulled caches: {:?}",
                    report.backends
                );
            }
            if !report.collisions.is_empty() {
                if args.has("strict") {
                    eprintln!("keys with differing payloads (existing copy kept):");
                    for k in &report.collisions {
                        eprintln!("  {k}");
                    }
                    anyhow::bail!(
                        "pull --strict: {} key(s) collided with differing payloads",
                        report.collisions.len()
                    );
                }
                println!("warning: keys with differing payloads (existing copy kept):");
                for k in report.collisions.iter().take(20) {
                    println!("  {k}");
                }
                if report.collisions.len() > 20 {
                    println!("  ... and {} more", report.collisions.len() - 20);
                }
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown cache subcommand {other:?} (gc, stats, pack, verify, push or pull)"
        ),
    }
}

fn cmd_assign(args: &Args) -> anyhow::Result<()> {
    let snr_a = args.opt_parse("snr-a", 30.0f64);
    let margin = args.opt_parse("margin", 9.0f64);
    let (w, x) = crate::figures::uniform_stats();
    let a = crate::snr::assign_precisions(snr_a, margin, &w, &x);
    println!(
        "SNR_a = {snr_a} dB, margin = {margin} dB -> Bx = {}, Bw = {}, By(MPC) = {}; predicted SNR_T = {:.2} dB",
        a.bx, a.bw, a.by, a.predicted_snr_t_db
    );
    Ok(())
}

fn cmd_dnn(args: &Args) -> anyhow::Result<()> {
    use crate::dnn::*;
    let epochs = args.opt_parse("epochs", 30usize);
    let ds = Dataset::generate(&DatasetConfig::default());
    let mut mlp = Mlp::new(&[64, 128, 64, 10], 7);
    println!(
        "training {}-param MLP on {} samples for {} epochs...",
        mlp.n_params(),
        ds.train_len(),
        epochs
    );
    let curve = mlp.train(
        &ds,
        &TrainConfig {
            epochs,
            ..Default::default()
        },
    );
    for (e, (loss, acc)) in curve.iter().enumerate() {
        if e % 5 == 0 || e + 1 == curve.len() {
            println!("epoch {e:>3}: loss {loss:.4}  test-acc {acc:.3}");
        }
    }
    let grid: Vec<f64> = (-4..=48).step_by(4).map(|v| v as f64).collect();
    let reqs = layer_snr_requirements(&mlp, &ds, &grid, 0.01, &NoisyEvalConfig::default());
    println!("per-layer SNR_T requirements (dB): {reqs:?}");
    Ok(())
}

fn cmd_smoke(args: &Args) -> anyhow::Result<()> {
    let dir: PathBuf = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(crate::runtime::default_artifacts_dir);
    let service = PjrtService::spawn(dir, 2);
    let out = service.handle().smoke()?;
    anyhow::ensure!(
        out == vec![5.0, 5.0, 9.0, 9.0],
        "smoke mismatch: {out:?}"
    );
    println!("PJRT smoke OK: {out:?}");

    // one qs_arch batch through the full pipeline
    let handle = service.handle();
    let (m, n_max) = handle.arch_shape("qs_arch")?;
    let mut p = [0.0f64; pvec::P];
    p[pvec::IDX_N_ACTIVE] = 64.0;
    p[pvec::IDX_BX] = 6.0;
    p[pvec::IDX_BW] = 6.0;
    p[pvec::IDX_B_ADC] = 8.0;
    p[pvec::QS_IDX_SIGMA_D] = 0.107;
    p[pvec::QS_IDX_K_H] = 57.0;
    p[pvec::QS_IDX_V_C] = 55.0;
    let point = crate::coordinator::SweepPoint::new("smoke/qs", ArchKind::Qs, p)
        .with_trials(m);
    let measured = crate::coordinator::run_point(
        &point,
        &Backend::Pjrt {
            handle,
            suffix: "",
        },
    )?;
    println!(
        "qs_arch artifact ({m}x{n_max}): SNR_T = {:.2} dB over {} trials",
        measured.snr_t_db, measured.trials
    );
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let (w, x) = crate::figures::uniform_stats();
    let mut t = Table::new(&[
        "arch", "knob", "SNR_a (dB)", "B_ADC", "energy/DP", "delay", "area",
    ])
    .with_title("Design space at N=128, Bx=Bw=6 (65 nm)");
    let op = OpPoint::new(128, 6, 6, 8);
    let archs: Vec<(Box<dyn ImcArch>, String)> = vec![
        (
            Box::new(QsArch::new(QsModel::new(TechNode::n65(), 0.8))),
            "V_WL=0.8".into(),
        ),
        (
            Box::new(QsArch::new(QsModel::new(TechNode::n65(), 0.6))),
            "V_WL=0.6".into(),
        ),
        (
            Box::new(QrArch::new(QrModel::new(TechNode::n65(), 1.0))),
            "C_o=1fF".into(),
        ),
        (
            Box::new(QrArch::new(QrModel::new(TechNode::n65(), 9.0))),
            "C_o=9fF".into(),
        ),
        (
            Box::new(CmArch::new(
                QsModel::new(TechNode::n65(), 0.8),
                QrModel::new(TechNode::n65(), 3.0),
            )),
            "V_WL=0.8".into(),
        ),
    ];
    for (a, knob) in &archs {
        let nb = a.noise(&op, &w, &x);
        let e = a.energy(&op, AdcCriterion::Mpc, &w, &x);
        t.row(vec![
            a.name().into(),
            knob.clone(),
            fmt_db(nb.snr_a_db()),
            a.b_adc_min(&op, &w, &x).to_string(),
            fmt_energy(e.total()),
            format!("{:.1} ns", a.delay(&op) * 1e9),
            fmt_area(a.area(&op).total_mm2()),
        ]);
    }
    println!("{}", t.render());
    let (qs, is, qr) = crate::taxonomy::model_counts(&crate::taxonomy::table1());
    println!("Table I designs: {} (QS {qs}, IS {is}, QR {qr})", crate::taxonomy::table1().len());
    Ok(())
}
