//! The `imclim` command-line interface.
//!
//! Subcommands:
//!   figure <name|all>    regenerate a paper figure/table (CSV + stdout)
//!   table <t1|t2|t3>     aliases for table1/table2/table3
//!   sweep                ad-hoc operating-point sweep on one arch
//!   dnn                  train the Fig. 2 MLP and report accuracy/SNR
//!   smoke                PJRT round-trip smoke test
//!   assign               precision assignment for a target SNR (Sec. III-B)
//!   info                 architecture/design-space summary

pub mod args;

use std::path::PathBuf;

use crate::arch::{pvec, AdcCriterion, CmArch, ImcArch, OpPoint, QrArch, QsArch};
use crate::compute::{qr::QrModel, qs::QsModel};
use crate::coordinator::{Backend, PjrtService};
use crate::figures::FigCtx;
use crate::mc::ArchKind;
use crate::tech::TechNode;
use crate::util::table::{fmt_db, fmt_energy, Table};
use args::Args;

const USAGE: &str = "\
imclim — fundamental limits of in-memory computing architectures

USAGE: imclim <command> [options]

COMMANDS:
  figure <name|all>   regenerate a figure/table (fig2 fig4a fig4b fig9a
                      fig9b fig10a fig10b fig11a fig11b fig12 fig13
                      table1 table2 table3)
  table <1|2|3>       shorthand for table1/table2/table3
  sweep               custom sweep: --arch qs|qr|cm --n N --bx B --bw B
                      --b-adc B [--vwl V] [--co FF] [--node 65|45|...]
  assign              precision assignment: --snr-a DB [--margin DB]
  dnn                 train the Fig. 2 MLP: [--epochs E]
  smoke               PJRT artifact round-trip check
  info                design-space summary

COMMON OPTIONS:
  --out-dir DIR       output directory for CSVs (default: results)
  --backend B         native | pjrt (default: native)
  --artifacts DIR     artifact directory for pjrt (default: artifacts)
  --trials N          MC trials per point (default: 2048)
  --workers N         worker threads (default: all cores, max 16)
  --verbose           progress output
";

pub fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

pub fn run(args: &Args) -> anyhow::Result<()> {
    match args.pos(0) {
        Some("figure") => cmd_figure(args),
        Some("table") => cmd_table(args),
        Some("sweep") => cmd_sweep(args),
        Some("assign") => cmd_assign(args),
        Some("dnn") => cmd_dnn(args),
        Some("smoke") => cmd_smoke(args),
        Some("info") => cmd_info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// Build the figure context (and keep the PJRT service alive with it).
fn make_ctx(args: &Args) -> anyhow::Result<(FigCtx, Option<PjrtService>)> {
    let out_dir: PathBuf = args.opt("out-dir").unwrap_or("results").into();
    let trials = args.opt_parse("trials", 2048usize);
    let workers = args.opt_parse(
        "workers",
        crate::coordinator::SweepOptions::default().workers,
    );
    let verbose = args.has("verbose");
    let (backend, service) = match args.opt("backend").unwrap_or("native") {
        "native" => (Backend::Native, None),
        "pjrt" => {
            let dir: PathBuf = args
                .opt("artifacts")
                .map(PathBuf::from)
                .unwrap_or_else(crate::runtime::default_artifacts_dir);
            let service = PjrtService::spawn(dir, 4);
            (
                Backend::Pjrt {
                    handle: service.handle(),
                    suffix: "",
                },
                Some(service),
            )
        }
        other => anyhow::bail!("unknown backend '{other}'"),
    };
    Ok((
        FigCtx {
            backend,
            out_dir,
            trials,
            workers,
            verbose,
        },
        service,
    ))
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let name = args.pos(1).unwrap_or("all");
    let (ctx, _service) = make_ctx(args)?;
    std::fs::create_dir_all(&ctx.out_dir)?;
    let summaries = crate::figures::run(name, &ctx)?;
    for s in &summaries {
        println!(
            "[{}] {} rows -> {}",
            s.name,
            s.rows,
            ctx.csv_path(&s.name).display()
        );
    }
    Ok(())
}

fn cmd_table(args: &Args) -> anyhow::Result<()> {
    let t = match args.pos(1) {
        Some("1") | Some("taxonomy") => "table1",
        Some("2") | Some("params") => "table2",
        Some("3") | Some("table3-check") => "table3",
        other => anyhow::bail!("unknown table {other:?} (1, 2 or 3)"),
    };
    let (ctx, _service) = make_ctx(args)?;
    std::fs::create_dir_all(&ctx.out_dir)?;
    crate::figures::run(t, &ctx)?;
    Ok(())
}

fn parse_arch(args: &Args) -> anyhow::Result<(Box<dyn ImcArch>, ArchKind)> {
    let node = TechNode::by_name(args.opt("node").unwrap_or("65"))
        .ok_or_else(|| anyhow::anyhow!("unknown node"))?;
    let v_wl = args.opt_parse("vwl", 0.8f64);
    let c_ff = args.opt_parse("co", 3.0f64);
    Ok(match args.opt("arch").unwrap_or("qs") {
        "qs" => (
            Box::new(QsArch::new(QsModel::new(node, v_wl))),
            ArchKind::Qs,
        ),
        "qr" => (
            Box::new(QrArch::new(QrModel::new(node, c_ff))),
            ArchKind::Qr,
        ),
        "cm" => (
            Box::new(CmArch::new(
                QsModel::new(node, v_wl),
                QrModel::new(node, c_ff),
            )),
            ArchKind::Cm,
        ),
        other => anyhow::bail!("unknown arch '{other}'"),
    })
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let (arch, kind) = parse_arch(args)?;
    let (ctx, _service) = make_ctx(args)?;
    let op = OpPoint::new(
        args.opt_parse("n", 128usize),
        args.opt_parse("bx", 6u32),
        args.opt_parse("bw", 6u32),
        args.opt_parse("b-adc", 8u32),
    );
    let (w, x) = crate::figures::uniform_stats();

    let nb = arch.noise(&op, &w, &x);
    let e_mpc = arch.energy(&op, AdcCriterion::Mpc, &w, &x);
    let point = crate::figures::sweep_point(
        arch.as_ref(),
        kind,
        format!("sweep/{}", arch.name()),
        &op,
        ctx.trials,
        args.opt_parse("seed", 7u64),
    );
    let measured = crate::coordinator::run_point(&point, &ctx.backend)?;

    let mut t = Table::new(&["metric", "closed form", "simulated"])
        .with_title(&format!("{} at N={} Bx={} Bw={} B_ADC={}",
            arch.name(), op.n, op.bx, op.bw, op.b_adc));
    t.row(vec![
        "SQNR_qiy (dB)".into(),
        fmt_db(nb.sqnr_qiy_db()),
        fmt_db(measured.sqnr_qiy_db),
    ]);
    t.row(vec![
        "SNR_a (dB)".into(),
        fmt_db(nb.snr_a_db()),
        fmt_db(measured.snr_a_db),
    ]);
    t.row(vec![
        "SNR_A (dB)".into(),
        fmt_db(nb.snr_a_total_db()),
        fmt_db(measured.snr_a_total_db),
    ]);
    t.row(vec![
        "SNR_T (dB)".into(),
        "-".into(),
        fmt_db(measured.snr_t_db),
    ]);
    t.row(vec![
        "B_ADC min (MPC)".into(),
        arch.b_adc_min(&op, &w, &x).to_string(),
        "-".into(),
    ]);
    t.row(vec![
        "energy/DP (MPC)".into(),
        fmt_energy(e_mpc.total()),
        "-".into(),
    ]);
    t.row(vec![
        "delay/DP".into(),
        format!("{:.2} ns", arch.delay(&op) * 1e9),
        "-".into(),
    ]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_assign(args: &Args) -> anyhow::Result<()> {
    let snr_a = args.opt_parse("snr-a", 30.0f64);
    let margin = args.opt_parse("margin", 9.0f64);
    let (w, x) = crate::figures::uniform_stats();
    let a = crate::snr::assign_precisions(snr_a, margin, &w, &x);
    println!(
        "SNR_a = {snr_a} dB, margin = {margin} dB -> Bx = {}, Bw = {}, By(MPC) = {}; predicted SNR_T = {:.2} dB",
        a.bx, a.bw, a.by, a.predicted_snr_t_db
    );
    Ok(())
}

fn cmd_dnn(args: &Args) -> anyhow::Result<()> {
    use crate::dnn::*;
    let epochs = args.opt_parse("epochs", 30usize);
    let ds = Dataset::generate(&DatasetConfig::default());
    let mut mlp = Mlp::new(&[64, 128, 64, 10], 7);
    println!(
        "training {}-param MLP on {} samples for {} epochs...",
        mlp.n_params(),
        ds.train_len(),
        epochs
    );
    let curve = mlp.train(
        &ds,
        &TrainConfig {
            epochs,
            ..Default::default()
        },
    );
    for (e, (loss, acc)) in curve.iter().enumerate() {
        if e % 5 == 0 || e + 1 == curve.len() {
            println!("epoch {e:>3}: loss {loss:.4}  test-acc {acc:.3}");
        }
    }
    let grid: Vec<f64> = (-4..=48).step_by(4).map(|v| v as f64).collect();
    let reqs = layer_snr_requirements(&mlp, &ds, &grid, 0.01, &NoisyEvalConfig::default());
    println!("per-layer SNR_T requirements (dB): {reqs:?}");
    Ok(())
}

fn cmd_smoke(args: &Args) -> anyhow::Result<()> {
    let dir: PathBuf = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(crate::runtime::default_artifacts_dir);
    let service = PjrtService::spawn(dir, 2);
    let out = service.handle().smoke()?;
    anyhow::ensure!(
        out == vec![5.0, 5.0, 9.0, 9.0],
        "smoke mismatch: {out:?}"
    );
    println!("PJRT smoke OK: {out:?}");

    // one qs_arch batch through the full pipeline
    let handle = service.handle();
    let (m, n_max) = handle.arch_shape("qs_arch")?;
    let mut p = [0.0f64; pvec::P];
    p[pvec::IDX_N_ACTIVE] = 64.0;
    p[pvec::IDX_BX] = 6.0;
    p[pvec::IDX_BW] = 6.0;
    p[pvec::IDX_B_ADC] = 8.0;
    p[pvec::QS_IDX_SIGMA_D] = 0.107;
    p[pvec::QS_IDX_K_H] = 57.0;
    p[pvec::QS_IDX_V_C] = 55.0;
    let point = crate::coordinator::SweepPoint::new("smoke/qs", ArchKind::Qs, p)
        .with_trials(m);
    let measured = crate::coordinator::run_point(
        &point,
        &Backend::Pjrt {
            handle,
            suffix: "",
        },
    )?;
    println!(
        "qs_arch artifact ({m}x{n_max}): SNR_T = {:.2} dB over {} trials",
        measured.snr_t_db, measured.trials
    );
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let (w, x) = crate::figures::uniform_stats();
    let mut t = Table::new(&[
        "arch", "knob", "SNR_a (dB)", "B_ADC", "energy/DP", "delay",
    ])
    .with_title("Design space at N=128, Bx=Bw=6 (65 nm)");
    let op = OpPoint::new(128, 6, 6, 8);
    let archs: Vec<(Box<dyn ImcArch>, String)> = vec![
        (
            Box::new(QsArch::new(QsModel::new(TechNode::n65(), 0.8))),
            "V_WL=0.8".into(),
        ),
        (
            Box::new(QsArch::new(QsModel::new(TechNode::n65(), 0.6))),
            "V_WL=0.6".into(),
        ),
        (
            Box::new(QrArch::new(QrModel::new(TechNode::n65(), 1.0))),
            "C_o=1fF".into(),
        ),
        (
            Box::new(QrArch::new(QrModel::new(TechNode::n65(), 9.0))),
            "C_o=9fF".into(),
        ),
        (
            Box::new(CmArch::new(
                QsModel::new(TechNode::n65(), 0.8),
                QrModel::new(TechNode::n65(), 3.0),
            )),
            "V_WL=0.8".into(),
        ),
    ];
    for (a, knob) in &archs {
        let nb = a.noise(&op, &w, &x);
        let e = a.energy(&op, AdcCriterion::Mpc, &w, &x);
        t.row(vec![
            a.name().into(),
            knob.clone(),
            fmt_db(nb.snr_a_db()),
            a.b_adc_min(&op, &w, &x).to_string(),
            fmt_energy(e.total()),
            format!("{:.1} ns", a.delay(&op) * 1e9),
        ]);
    }
    println!("{}", t.render());
    let (qs, is, qr) = crate::taxonomy::model_counts(&crate::taxonomy::table1());
    println!("Table I designs: {} (QS {qs}, IS {is}, QR {qr})", crate::taxonomy::table1().len());
    Ok(())
}
