//! `imclim serve` — sweep-as-a-service.
//!
//! A long-running HTTP daemon that accepts sweep/pareto/optimize
//! submissions as JSON POSTs and runs them through the exact CLI code
//! paths ([`super::run_sweep_grid`], [`super::cmd_pareto`],
//! [`super::cmd_optimize`]) against one shared content-addressed cache,
//! so a served query is byte-identical to its command-line twin and a
//! warm submission performs zero Monte-Carlo.
//!
//! Layout under `--out-dir DIR`:
//!   DIR/cache/       the shared result cache (every job reads/writes it)
//!   DIR/jobs/<id>/   one out-dir per job (its CSV lands here)
//!
//! Endpoints:
//!   GET  /healthz            liveness probe ("ok")
//!   GET  /stats              process counters + per-state job counts
//!   GET  /metrics            Prometheus text exposition of the whole
//!                            `obs::registry` (counters, job gauges,
//!                            cache-probe / MC-chunk latency histograms)
//!   POST /jobs               submit {"cmd","options","switches"} → 202
//!   GET  /jobs/<id>          job status JSON (state, per-job metrics,
//!                            queued/started/finished timestamps)
//!   GET  /jobs/<id>/events   live NDJSON progress stream (chunked
//!                            transfer-encoding); events appear as the
//!                            job produces them and the stream ends
//!                            with the job's terminal event
//!   GET  /jobs/<id>/result   the result CSV once the job is done
//!   POST /jobs/<id>/cancel   cancel a queued job (in-flight ones finish)
//!   POST /shutdown           graceful drain (same path as SIGTERM)
//!
//! Transport: the dependency-free HTTP/1.1 server half in
//! `registry::http` — one request per connection, `Content-Length`
//! bodies, thread per connection. Job execution itself is sequential
//! (see `coordinator::jobs`), so concurrency lives entirely in the
//! serving layer where it is cheap and safe.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Context as _;

use crate::coordinator::jobs::{
    CancelOutcome, JobManager, JobSpec, JobState, JobStatus, SubmitError,
};
use crate::coordinator::metrics;
use crate::obs::progress::EventLog;
use crate::obs::registry as obs_registry;
use crate::registry::http::{
    finish_chunked, read_request, write_chunk, write_chunked_head, write_response, HttpRequest,
};
use crate::util::json::{num, obj, s, Json};

use super::args::Args;

/// Set by the SIGTERM/SIGINT handler; every accept loop polls it, so a
/// signal drains the daemon exactly like `POST /shutdown`.
static SIGNAL_SHUTDOWN: AtomicBool = AtomicBool::new(false);

const ACCEPT_POLL: Duration = Duration::from_millis(20);
const CONN_TIMEOUT: Duration = Duration::from_secs(30);
/// How often an idle `/events` stream re-checks its job's log for new
/// lines (and the daemon for a drain request).
const EVENT_POLL: Duration = Duration::from_millis(100);

/// A running daemon. Used in-process by the integration tests; the CLI
/// wraps it in [`cmd_serve`].
pub struct ServeHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ServeHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn base_url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Flag the daemon to drain (non-blocking).
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Block until the daemon has drained and stopped.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Graceful stop: request the drain and wait for it.
    pub fn shutdown(self) {
        self.request_shutdown();
        self.wait();
    }
}

/// Bind `addr` and start serving. `queue_depth` bounds the submission
/// queue (backpressure: an over-full queue answers HTTP 429).
pub fn start(addr: &str, out_dir: PathBuf, queue_depth: usize) -> anyhow::Result<ServeHandle> {
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating out-dir {}", out_dir.display()))?;
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let manager = Arc::new(JobManager::new(queue_depth, job_runner(out_dir)));
    let accept = {
        let shutdown = Arc::clone(&shutdown);
        std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || accept_loop(listener, manager, shutdown))
            .context("spawning the accept loop")?
    };
    Ok(ServeHandle {
        addr: local,
        shutdown,
        accept: Some(accept),
    })
}

/// `imclim serve --addr HOST:PORT --out-dir DIR [--queue-depth N]`.
pub fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let addr = args.opt("addr").unwrap_or("127.0.0.1:7878");
    let out_dir: PathBuf = args.opt("out-dir").unwrap_or("results").into();
    let queue_depth = args.opt_parse("queue-depth", 64usize);
    install_signal_handlers();
    let handle = start(addr, out_dir.clone(), queue_depth)?;
    // the "listening on" line is the daemon's readiness signal (tests
    // and scripts parse it to learn a port-0 assignment)
    println!("imclim serve: listening on {}", handle.base_url());
    println!(
        "imclim serve: jobs under {}, shared cache {}",
        out_dir.join("jobs").display(),
        out_dir.join("cache").display()
    );
    handle.wait();
    println!("imclim serve: drained, shutting down");
    Ok(())
}

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        // only an atomic store: async-signal-safe by construction
        SIGNAL_SHUTDOWN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// The executor closure handed to the job manager: run the submitted
/// verb through the CLI's own entry points, with the job's private
/// out-dir and the daemon's shared cache, and return the result CSV.
fn job_runner(out_dir: PathBuf) -> Box<crate::coordinator::jobs::JobRunner> {
    let jobs_root = out_dir.join("jobs");
    let cache_dir = out_dir.join("cache");
    Box::new(move |id: u64, spec: &JobSpec| {
        let job_dir = jobs_root.join(id.to_string());
        let mut cli = Args {
            positionals: vec![spec.verb.clone()],
            options: spec.options.clone(),
            switches: spec.switches.clone(),
        };
        cli.options.insert("out-dir".into(), job_dir.to_string_lossy().into_owned());
        cli.options.insert("cache-dir".into(), cache_dir.to_string_lossy().into_owned());
        let result_name = match spec.verb.as_str() {
            "sweep" => {
                super::run_sweep_grid(&cli, None)?;
                "sweep.csv"
            }
            "pareto" => {
                super::cmd_pareto(&cli)?;
                "pareto.csv"
            }
            "optimize" => {
                super::cmd_optimize(&cli)?;
                "optimize.csv"
            }
            other => anyhow::bail!("unsupported job verb '{other}'"),
        };
        Ok(job_dir.join(result_name))
    })
}

fn accept_loop(listener: TcpListener, manager: Arc<JobManager>, shutdown: Arc<AtomicBool>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) || SIGNAL_SHUTDOWN.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                let manager = Arc::clone(&manager);
                let shutdown = Arc::clone(&shutdown);
                let spawned = std::thread::Builder::new()
                    .name("serve-conn".into())
                    .spawn(move || handle_connection(&mut stream, &manager, &shutdown));
                if let Ok(h) = spawned {
                    handlers.push(h);
                }
                handlers.retain(|h| !h.is_finished());
            }
            // nonblocking accept: poll the shutdown flag between waits
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    // graceful drain: finish open connections, then let the job manager
    // complete its in-flight job and cancel the rest of the queue
    for h in handlers {
        let _ = h.join();
    }
    manager.shutdown();
}

fn handle_connection(stream: &mut TcpStream, manager: &JobManager, shutdown: &AtomicBool) {
    let _ = stream.set_read_timeout(Some(CONN_TIMEOUT));
    let _ = stream.set_write_timeout(Some(CONN_TIMEOUT));
    let req = match read_request(stream) {
        Ok(r) => r,
        // a hung-up or garbled client costs nothing but this connection
        Err(_) => return,
    };
    let _ = route(stream, &req, manager, shutdown);
}

fn route(
    stream: &mut TcpStream,
    req: &HttpRequest,
    manager: &JobManager,
    shutdown: &AtomicBool,
) -> anyhow::Result<()> {
    let path = req.path.split('?').next().unwrap_or("");
    let path = if path.len() > 1 {
        path.trim_end_matches('/')
    } else {
        path
    };
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => write_response(stream, 200, "text/plain", b"ok\n"),
        ("GET", "/stats") => write_response(
            stream,
            200,
            "application/json",
            stats_json(manager).to_string().as_bytes(),
        ),
        ("GET", "/metrics") => {
            // job gauges are sampled at scrape time: the registry's
            // counters accumulate on their own, but queue depths are
            // the manager's state
            let q = manager.queue_stats();
            obs_registry::JOBS_QUEUED.set(q.queued as u64);
            obs_registry::JOBS_RUNNING.set(q.running as u64);
            write_response(
                stream,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                obs_registry::render_prometheus().as_bytes(),
            )
        }
        ("POST", "/jobs") => match parse_job_spec(&req.body) {
            Err(msg) => error_response(stream, 400, &msg),
            Ok(spec) => match manager.submit(spec) {
                Ok(id) => {
                    let st = manager.status(id).expect("freshly submitted job exists");
                    write_response(
                        stream,
                        202,
                        "application/json",
                        status_json(&st).to_string().as_bytes(),
                    )
                }
                Err(SubmitError::QueueFull) => {
                    error_response(stream, 429, "job queue is full — retry later")
                }
                Err(SubmitError::ShuttingDown) => {
                    error_response(stream, 503, "daemon is draining — no new jobs")
                }
            },
        },
        ("POST", "/shutdown") => {
            shutdown.store(true, Ordering::SeqCst);
            write_response(stream, 200, "text/plain", b"draining\n")
        }
        (method, p) if p.starts_with("/jobs/") => job_route(stream, method, p, manager, shutdown),
        ("GET" | "POST", _) => error_response(stream, 404, "no such route"),
        _ => error_response(stream, 405, "method not allowed"),
    }
}

fn job_route(
    stream: &mut TcpStream,
    method: &str,
    path: &str,
    manager: &JobManager,
    shutdown: &AtomicBool,
) -> anyhow::Result<()> {
    let rest = &path["/jobs/".len()..];
    let (id_str, tail) = match rest.split_once('/') {
        Some((a, b)) => (a, Some(b)),
        None => (rest, None),
    };
    let Ok(id) = id_str.parse::<u64>() else {
        return error_response(stream, 400, &format!("bad job id '{id_str}'"));
    };
    match (method, tail) {
        ("GET", None) => match manager.status(id) {
            Some(st) => write_response(
                stream,
                200,
                "application/json",
                status_json(&st).to_string().as_bytes(),
            ),
            None => error_response(stream, 404, "no such job"),
        },
        ("GET", Some("events")) => match manager.events(id) {
            None => error_response(stream, 404, "no such job"),
            Some(log) => stream_job_events(stream, &log, shutdown),
        },
        ("GET", Some("result")) => match manager.status(id) {
            None => error_response(stream, 404, "no such job"),
            Some(st) if st.state == JobState::Done => {
                let path = st.result_path.expect("done jobs carry a result path");
                match std::fs::read(&path) {
                    Ok(bytes) => write_response(stream, 200, "text/csv", &bytes),
                    Err(e) => error_response(stream, 500, &format!("reading result: {e}")),
                }
            }
            Some(st) => error_response(
                stream,
                409,
                &format!("job is {} — no result to serve", st.state.as_str()),
            ),
        },
        ("POST", Some("cancel")) => match manager.cancel(id) {
            CancelOutcome::Unknown => error_response(stream, 404, "no such job"),
            outcome => {
                let msg = match outcome {
                    CancelOutcome::Canceled => "canceled",
                    CancelOutcome::Running => "running — in-flight jobs complete",
                    CancelOutcome::Finished => "already finished",
                    CancelOutcome::Unknown => unreachable!(),
                };
                let body = obj(vec![("id", num(id as f64)), ("outcome", s(msg))]).to_string();
                write_response(stream, 200, "application/json", body.as_bytes())
            }
        },
        _ => error_response(stream, 404, "no such route"),
    }
}

/// Stream a job's progress log as NDJSON over chunked transfer
/// encoding: everything logged so far immediately, then new events as
/// the job appends them, terminating once the log closes (its last
/// line is the job's terminal event). The drain check matters for
/// correctness, not just latency: the accept loop joins connection
/// handlers *before* `JobManager::shutdown` cancels queued jobs, so a
/// queued job's log would never close during a drain — the stream must
/// end itself rather than hold the join hostage.
fn stream_job_events(
    stream: &mut TcpStream,
    log: &EventLog,
    shutdown: &AtomicBool,
) -> anyhow::Result<()> {
    // a long-running job may be silent between events; the connection
    // timeout bounds a single blocked write, not the stream's lifetime
    write_chunked_head(stream, 200, "application/x-ndjson")?;
    let mut from = 0usize;
    loop {
        let (lines, closed) = log.wait_since(from, EVENT_POLL);
        from += lines.len();
        for line in &lines {
            write_chunk(stream, format!("{line}\n").as_bytes())?;
        }
        if closed {
            break;
        }
        if shutdown.load(Ordering::SeqCst) || SIGNAL_SHUTDOWN.load(Ordering::SeqCst) {
            break;
        }
    }
    finish_chunked(stream)
}

fn error_response(stream: &mut TcpStream, status: u16, msg: &str) -> anyhow::Result<()> {
    let body = obj(vec![("error", s(msg))]).to_string();
    write_response(stream, status, "application/json", body.as_bytes())
}

/// Parse a submission body:
/// `{"cmd": "sweep", "options": {"arch": "qs", "n": "64:512:64"},
///   "switches": ["validate"]}`.
/// Option values are the exact strings the CLI takes, so the served
/// grid grammar is the CLI's grid grammar by construction.
fn parse_job_spec(body: &[u8]) -> Result<JobSpec, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let json = Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let verb = json
        .get("cmd")
        .and_then(|j| j.as_str())
        .ok_or_else(|| "missing 'cmd' (sweep | pareto | optimize)".to_string())?
        .to_string();
    if !matches!(verb.as_str(), "sweep" | "pareto" | "optimize") {
        return Err(format!("unsupported cmd '{verb}' (sweep | pareto | optimize)"));
    }
    let mut options = BTreeMap::new();
    if let Some(section) = json.get("options") {
        let map = section
            .as_obj()
            .ok_or_else(|| "'options' must be an object of strings".to_string())?;
        for (k, v) in map {
            let v = v.as_str().ok_or_else(|| {
                format!("option '{k}' must be a string (grids use the CLI grammar, e.g. \"4:10\")")
            })?;
            options.insert(k.clone(), v.to_string());
        }
    }
    let mut switches = Vec::new();
    if let Some(section) = json.get("switches") {
        let list = section
            .as_arr()
            .ok_or_else(|| "'switches' must be an array of strings".to_string())?;
        for sw in list {
            let sw = sw
                .as_str()
                .ok_or_else(|| "'switches' must be an array of strings".to_string())?;
            switches.push(sw.to_string());
        }
    }
    for k in options.keys() {
        // trace and progress are process-global observability switches:
        // a job toggling them would retarget the daemon's own trace
        // slab / stderr stream (use GET /jobs/<id>/events instead)
        if matches!(
            k.as_str(),
            "out-dir" | "cache-dir" | "procs" | "shard" | "backend" | "artifacts" | "trace"
                | "progress"
        ) {
            return Err(format!("option '--{k}' is reserved by the daemon"));
        }
    }
    for sw in &switches {
        if matches!(sw.as_str(), "no-cache" | "keep-shards") {
            return Err(format!("switch '--{sw}' is not available under serve"));
        }
    }
    Ok(JobSpec {
        verb,
        options,
        switches,
    })
}

fn status_json(st: &JobStatus) -> Json {
    let mut fields = vec![
        ("id", num(st.id as f64)),
        ("cmd", s(&st.verb)),
        ("state", s(st.state.as_str())),
        ("cache_hits", num(st.metrics.cache_hits as f64)),
        ("cache_misses", num(st.metrics.cache_misses as f64)),
        ("points_computed", num(st.metrics.points_computed as f64)),
        ("trials_completed", num(st.metrics.trials_completed as f64)),
        ("queued_at_ms", num(st.queued_at_ms as f64)),
    ];
    if let Some(t) = st.started_at_ms {
        fields.push(("started_at_ms", num(t as f64)));
    }
    if let Some(t) = st.finished_at_ms {
        fields.push(("finished_at_ms", num(t as f64)));
    }
    if let Some(d) = st.duration_ms() {
        fields.push(("duration_ms", num(d as f64)));
    }
    if let Some(e) = &st.error {
        fields.push(("error", s(e)));
    }
    if st.state == JobState::Done {
        fields.push(("result", s(&format!("/jobs/{}/result", st.id))));
    }
    obj(fields)
}

fn stats_json(manager: &JobManager) -> Json {
    let m = metrics::snapshot();
    let q = manager.queue_stats();
    obj(vec![
        ("cache_hits", num(m.cache_hits as f64)),
        ("cache_misses", num(m.cache_misses as f64)),
        ("points_computed", num(m.points_computed as f64)),
        ("trials_completed", num(m.trials_completed as f64)),
        ("mc_errors", num(m.mc_errors as f64)),
        ("jobs_in_flight", num((q.queued + q.running) as f64)),
        (
            "jobs",
            obj(vec![
                ("queued", num(q.queued as f64)),
                ("running", num(q.running as f64)),
                ("done", num(q.done as f64)),
                ("failed", num(q.failed as f64)),
                ("canceled", num(q.canceled as f64)),
            ]),
        ),
        ("draining", Json::Bool(manager.is_shutting_down())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_parsing_accepts_cli_grammar_and_rejects_reserved() {
        let body = br#"{"cmd":"sweep","options":{"arch":"qs,qr","n":"8,16:64:16","trials":"48"},"switches":["verbose"]}"#;
        let spec = parse_job_spec(body).unwrap();
        assert_eq!(spec.verb, "sweep");
        assert_eq!(spec.options["n"], "8,16:64:16");
        assert_eq!(spec.switches, ["verbose"]);

        // minimal body: options/switches are optional
        let spec = parse_job_spec(br#"{"cmd":"optimize"}"#).unwrap();
        assert_eq!(spec.verb, "optimize");
        assert!(spec.options.is_empty());

        for (body, needle) in [
            (&br#"{"options":{}}"#[..], "missing 'cmd'"),
            (br#"{"cmd":"figure"}"#, "unsupported cmd"),
            (br#"{"cmd":"sweep","options":{"n":16}}"#, "must be a string"),
            (br#"{"cmd":"sweep","options":{"out-dir":"/x"}}"#, "reserved"),
            (br#"{"cmd":"sweep","options":{"procs":"4"}}"#, "reserved"),
            (br#"{"cmd":"sweep","options":{"trace":"/t.json"}}"#, "reserved"),
            (br#"{"cmd":"sweep","options":{"progress":"json"}}"#, "reserved"),
            (br#"{"cmd":"sweep","switches":["no-cache"]}"#, "not available"),
            (b"not json", "bad JSON"),
            (b"\xff\xfe", "not UTF-8"),
        ] {
            let err = parse_job_spec(body).unwrap_err();
            assert!(err.contains(needle), "{err:?} should mention {needle:?}");
        }
    }

    #[test]
    fn status_json_shape() {
        let st = JobStatus {
            id: 3,
            verb: "sweep".into(),
            state: JobState::Done,
            error: None,
            result_path: Some(PathBuf::from("/x/sweep.csv")),
            metrics: crate::coordinator::MetricsSnapshot {
                cache_hits: 6,
                ..Default::default()
            },
            queued_at_ms: 1_000,
            started_at_ms: Some(1_250),
            finished_at_ms: Some(1_900),
        };
        let j = status_json(&st);
        assert_eq!(j.get("id").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("state").and_then(|v| v.as_str()), Some("done"));
        assert_eq!(j.get("cache_hits").and_then(Json::as_usize), Some(6));
        assert_eq!(j.get("result").and_then(|v| v.as_str()), Some("/jobs/3/result"));
        assert_eq!(j.get("queued_at_ms").and_then(Json::as_usize), Some(1_000));
        assert_eq!(j.get("started_at_ms").and_then(Json::as_usize), Some(1_250));
        assert_eq!(j.get("finished_at_ms").and_then(Json::as_usize), Some(1_900));
        assert_eq!(j.get("duration_ms").and_then(Json::as_usize), Some(650));
        let text = j.to_string();
        let reparsed = Json::parse(&text).unwrap();
        let computed = reparsed.get("points_computed").and_then(Json::as_usize);
        assert_eq!(computed, Some(0));

        // timestamps a queued job doesn't have yet are simply absent
        let st = JobStatus {
            started_at_ms: None,
            finished_at_ms: None,
            state: JobState::Queued,
            result_path: None,
            ..st
        };
        let j = status_json(&st);
        assert!(j.get("started_at_ms").is_none());
        assert!(j.get("duration_ms").is_none());
        assert!(j.get("result").is_none());
    }
}
