//! `imclim serve` — sweep-as-a-service.
//!
//! A long-running HTTP daemon that accepts sweep/pareto/optimize
//! submissions as JSON POSTs and runs them through the exact CLI code
//! paths ([`super::run_sweep_grid`], [`super::cmd_pareto`],
//! [`super::cmd_optimize`]) against one shared content-addressed cache,
//! so a served query is byte-identical to its command-line twin and a
//! warm submission performs zero Monte-Carlo.
//!
//! Layout under `--out-dir DIR`:
//!   DIR/cache/       the shared result cache (every job reads/writes it)
//!   DIR/jobs/<id>/   one out-dir per job (its CSV lands here)
//!
//! Endpoints:
//!   GET  /healthz            liveness probe ("ok")
//!   GET  /stats              process counters + per-state job counts
//!   GET  /metrics            Prometheus text exposition of the whole
//!                            `obs::registry` (counters, job gauges,
//!                            cache-probe / MC-chunk latency histograms)
//!   POST /jobs               submit {"cmd","options","switches"} → 202
//!   GET  /jobs/<id>          job status JSON (state, per-job metrics,
//!                            queued/started/finished timestamps)
//!   GET  /jobs/<id>/events   live NDJSON progress stream (chunked
//!                            transfer-encoding); events appear as the
//!                            job produces them and the stream ends
//!                            with the job's terminal event
//!   GET  /jobs/<id>/result   the result CSV once the job is done
//!   POST /jobs/<id>/cancel   cancel a queued job (in-flight ones finish)
//!   POST /shutdown           graceful drain (same path as SIGTERM)
//!
//! Worker fabric (see `coordinator::remote`): `imclim worker
//! --connect URL` processes on other hosts register here, lease
//! deterministic `--shard i/k` slices of the running sweep job, and
//! publish results back as verified cache artifacts. A daemon with no
//! registered workers runs every job locally, exactly as before.
//!   POST /workers/register   {"name"} → {"worker_id"} (503 draining)
//!   POST /workers/heartbeat  {"worker_id"} keep-alive → 200 | 404
//!   POST /workers/lease      {"worker_id"} → 200 lease | 204 no work
//!                            | 404 re-register | 503 draining
//!   POST /workers/complete   {"worker_id","job_id","shard",
//!                            "artifact"|"error"} → 200 | 404 | 409
//!   GET  /workers            registered workers (id, name, leases)
//!   GET|PUT /fabric/...      per-shard artifact stores (the push/pull
//!                            transport; files under DIR/fabric/)
//!
//! Transport: the dependency-free HTTP/1.1 server half in
//! `registry::http` — one request per connection, `Content-Length`
//! bodies, thread per connection. Job execution itself is sequential
//! (see `coordinator::jobs`), so concurrency lives entirely in the
//! serving layer where it is cheap and safe.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Context as _;

use crate::coordinator::jobs::{
    CancelOutcome, JobManager, JobSpec, JobState, JobStatus, SubmitError,
};
use crate::coordinator::metrics;
use crate::coordinator::remote::{
    self, CompleteReply, Fabric, LeaseReply, ShardLease, FABRIC_PREFIX,
};
use crate::obs::progress::EventLog;
use crate::obs::registry as obs_registry;
use crate::registry::http::{
    finish_chunked, read_request, write_chunk, write_chunked_head, write_response, HttpEndpoint,
    HttpRequest, RequestError,
};
use crate::util::json::{arr, num, obj, s, Json};

use super::args::{parse_duration_secs, Args};

/// Set by the SIGTERM/SIGINT handler; every accept loop polls it, so a
/// signal drains the daemon exactly like `POST /shutdown`.
static SIGNAL_SHUTDOWN: AtomicBool = AtomicBool::new(false);

const ACCEPT_POLL: Duration = Duration::from_millis(20);
const CONN_TIMEOUT: Duration = Duration::from_secs(30);
/// How often an idle `/events` stream re-checks its job's log for new
/// lines (and the daemon for a drain request).
const EVENT_POLL: Duration = Duration::from_millis(100);

/// A running daemon. Used in-process by the integration tests; the CLI
/// wraps it in [`cmd_serve`].
pub struct ServeHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ServeHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn base_url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Flag the daemon to drain (non-blocking).
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Block until the daemon has drained and stopped.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Graceful stop: request the drain and wait for it.
    pub fn shutdown(self) {
        self.request_shutdown();
        self.wait();
    }
}

/// Bind `addr` and start serving. `queue_depth` bounds the submission
/// queue (backpressure: an over-full queue answers HTTP 429). Uses the
/// default worker lease timeout; see [`start_with`].
pub fn start(addr: &str, out_dir: PathBuf, queue_depth: usize) -> anyhow::Result<ServeHandle> {
    start_with(addr, out_dir, queue_depth, remote::DEFAULT_LEASE_TIMEOUT)
}

/// [`start`] with an explicit worker lease timeout: how long a worker
/// may go silent before its shards are re-queued.
pub fn start_with(
    addr: &str,
    out_dir: PathBuf,
    queue_depth: usize,
    lease_timeout: Duration,
) -> anyhow::Result<ServeHandle> {
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating out-dir {}", out_dir.display()))?;
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let fabric = Arc::new(Fabric::new(out_dir.join("fabric"), lease_timeout));
    let manager = Arc::new(JobManager::new(
        queue_depth,
        job_runner(out_dir, Arc::clone(&fabric)),
    ));
    let accept = {
        let shutdown = Arc::clone(&shutdown);
        std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || accept_loop(listener, manager, fabric, shutdown))
            .context("spawning the accept loop")?
    };
    Ok(ServeHandle {
        addr: local,
        shutdown,
        accept: Some(accept),
    })
}

/// `imclim serve --addr HOST:PORT --out-dir DIR [--queue-depth N]
/// [--lease-timeout DUR]`.
pub fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let addr = args.opt("addr").unwrap_or("127.0.0.1:7878");
    let out_dir: PathBuf = args.opt("out-dir").unwrap_or("results").into();
    let queue_depth = args.opt_parse("queue-depth", 64usize);
    let lease_timeout = match args.opt("lease-timeout") {
        Some(v) => Duration::from_secs(parse_duration_secs(v)?),
        None => remote::DEFAULT_LEASE_TIMEOUT,
    };
    install_signal_handlers();
    let handle = start_with(addr, out_dir.clone(), queue_depth, lease_timeout)?;
    // the "listening on" line is the daemon's readiness signal (tests
    // and scripts parse it to learn a port-0 assignment)
    println!("imclim serve: listening on {}", handle.base_url());
    println!(
        "imclim serve: jobs under {}, shared cache {}",
        out_dir.join("jobs").display(),
        out_dir.join("cache").display()
    );
    println!(
        "imclim serve: worker fabric at /workers (lease timeout {}s)",
        lease_timeout.as_secs()
    );
    handle.wait();
    println!("imclim serve: drained, shutting down");
    Ok(())
}

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        // only an atomic store: async-signal-safe by construction
        SIGNAL_SHUTDOWN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// The executor closure handed to the job manager: run the submitted
/// verb through the CLI's own entry points, with the job's private
/// out-dir and the daemon's shared cache, and return the result CSV.
///
/// Sweep jobs first go through the worker fabric: with workers
/// registered, the grid is sharded across them and their artifacts are
/// merged into the shared cache; the final full-grid pass is then all
/// cache hits and emits the canonical CSV, byte-identical to a local
/// run. With no workers the fabric is a no-op and the full pass does
/// the computing itself — the pre-fabric behaviour.
fn job_runner(out_dir: PathBuf, fabric: Arc<Fabric>) -> Box<crate::coordinator::jobs::JobRunner> {
    let jobs_root = out_dir.join("jobs");
    let cache_dir = out_dir.join("cache");
    Box::new(move |id: u64, spec: &JobSpec| {
        let job_dir = jobs_root.join(id.to_string());
        let mut cli = Args {
            positionals: vec![spec.verb.clone()],
            options: spec.options.clone(),
            switches: spec.switches.clone(),
        };
        cli.options.insert("out-dir".into(), job_dir.to_string_lossy().into_owned());
        cli.options.insert("cache-dir".into(), cache_dir.to_string_lossy().into_owned());
        let result_name = match spec.verb.as_str() {
            "sweep" => {
                let local_shard = |i: usize, k: usize| -> anyhow::Result<()> {
                    // the executor thread is the shared cache's single
                    // writer, so the fallback writes it directly; only
                    // the partial CSV is diverted (and discarded)
                    let mut shard_cli = cli.clone();
                    let shard_dir = job_dir.join(format!("local-shard-{i}"));
                    shard_cli
                        .options
                        .insert("out-dir".into(), shard_dir.to_string_lossy().into_owned());
                    super::run_sweep_grid(&shard_cli, Some((i, k)))?;
                    let _ = std::fs::remove_dir_all(&shard_dir);
                    Ok(())
                };
                let report = fabric.run_distributed(id, spec, &cache_dir, &local_shard)?;
                if report.shards > 0 {
                    println!(
                        "serve: job {id} distributed over {} shards \
                         ({} merged from workers, {} run locally, {} records pulled)",
                        report.shards, report.merged, report.local, report.records
                    );
                }
                super::run_sweep_grid(&cli, None)?;
                "sweep.csv"
            }
            "pareto" => {
                super::cmd_pareto(&cli)?;
                "pareto.csv"
            }
            "optimize" => {
                super::cmd_optimize(&cli)?;
                "optimize.csv"
            }
            other => anyhow::bail!("unsupported job verb '{other}'"),
        };
        Ok(job_dir.join(result_name))
    })
}

fn accept_loop(
    listener: TcpListener,
    manager: Arc<JobManager>,
    fabric: Arc<Fabric>,
    shutdown: Arc<AtomicBool>,
) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) || SIGNAL_SHUTDOWN.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                let manager = Arc::clone(&manager);
                let fabric = Arc::clone(&fabric);
                let shutdown = Arc::clone(&shutdown);
                let spawned = std::thread::Builder::new()
                    .name("serve-conn".into())
                    .spawn(move || handle_connection(&mut stream, &manager, &fabric, &shutdown));
                if let Ok(h) = spawned {
                    handlers.push(h);
                }
                handlers.retain(|h| !h.is_finished());
            }
            // nonblocking accept: poll the shutdown flag between waits
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    // graceful drain: finish open connections, then let the job manager
    // complete its in-flight job and cancel the rest of the queue
    for h in handlers {
        let _ = h.join();
    }
    manager.shutdown();
}

fn handle_connection(
    stream: &mut TcpStream,
    manager: &JobManager,
    fabric: &Fabric,
    shutdown: &AtomicBool,
) {
    let _ = stream.set_read_timeout(Some(CONN_TIMEOUT));
    let _ = stream.set_write_timeout(Some(CONN_TIMEOUT));
    let req = match read_request(stream) {
        Ok(r) => r,
        // protocol violations get their status before the close...
        Err(RequestError::Rejected { status, reason }) => {
            let _ = error_response(stream, status, &reason);
            return;
        }
        // ...while a hung-up client costs nothing but this connection
        Err(RequestError::Io(_)) => return,
    };
    let _ = route(stream, &req, manager, fabric, shutdown);
}

fn route(
    stream: &mut TcpStream,
    req: &HttpRequest,
    manager: &JobManager,
    fabric: &Fabric,
    shutdown: &AtomicBool,
) -> anyhow::Result<()> {
    let path = req.path.split('?').next().unwrap_or("");
    let path = if path.len() > 1 {
        path.trim_end_matches('/')
    } else {
        path
    };
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => write_response(stream, 200, "text/plain", b"ok\n"),
        ("GET", "/stats") => write_response(
            stream,
            200,
            "application/json",
            stats_json(manager, fabric).to_string().as_bytes(),
        ),
        ("GET", "/metrics") => {
            // job/worker gauges are sampled at scrape time: the
            // registry's counters accumulate on their own, but queue
            // depths and worker liveness are the manager's/fabric's
            // state
            let q = manager.queue_stats();
            obs_registry::JOBS_QUEUED.set(q.queued as u64);
            obs_registry::JOBS_RUNNING.set(q.running as u64);
            obs_registry::WORKERS_REGISTERED.set(fabric.live_workers() as u64);
            write_response(
                stream,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                obs_registry::render_prometheus().as_bytes(),
            )
        }
        ("POST", "/jobs") => match parse_job_spec(&req.body) {
            Err(msg) => error_response(stream, 400, &msg),
            Ok(spec) => match manager.submit(spec) {
                Ok(id) => {
                    let st = manager.status(id).expect("freshly submitted job exists");
                    write_response(
                        stream,
                        202,
                        "application/json",
                        status_json(&st).to_string().as_bytes(),
                    )
                }
                Err(SubmitError::QueueFull) => {
                    error_response(stream, 429, "job queue is full — retry later")
                }
                Err(SubmitError::ShuttingDown) => {
                    error_response(stream, 503, "daemon is draining — no new jobs")
                }
            },
        },
        ("POST", "/shutdown") => {
            shutdown.store(true, Ordering::SeqCst);
            write_response(stream, 200, "text/plain", b"draining\n")
        }
        ("GET", "/workers") => write_response(
            stream,
            200,
            "application/json",
            workers_json(fabric).to_string().as_bytes(),
        ),
        ("POST", p) if p.starts_with("/workers/") => {
            let draining = shutdown.load(Ordering::SeqCst) || SIGNAL_SHUTDOWN.load(Ordering::SeqCst);
            worker_route(stream, &p["/workers/".len()..], &req.body, fabric, draining)
        }
        (method, p) if p.starts_with("/jobs/") => job_route(stream, method, p, manager, shutdown),
        (method, p)
            if p.starts_with(&format!("{FABRIC_PREFIX}/")) && matches!(method, "GET" | "PUT") =>
        {
            fabric_store_route(stream, method, &p[FABRIC_PREFIX.len() + 1..], &req.body, fabric)
        }
        ("GET" | "POST", _) => error_response(stream, 404, "no such route"),
        _ => error_response(stream, 405, "method not allowed"),
    }
}

/// The worker-fabric control endpoints: register / heartbeat / lease /
/// complete. All take a small JSON body; registration and leasing are
/// refused while draining so workers detach cleanly (in-flight shards
/// still complete — heartbeat and complete stay open).
fn worker_route(
    stream: &mut TcpStream,
    tail: &str,
    body: &[u8],
    fabric: &Fabric,
    draining: bool,
) -> anyhow::Result<()> {
    let json = match std::str::from_utf8(body)
        .ok()
        .and_then(|t| Json::parse(t).ok())
    {
        Some(j) => j,
        None => return error_response(stream, 400, "body is not valid JSON"),
    };
    let worker_id = || {
        json.get("worker_id")
            .and_then(Json::as_usize)
            .map(|v| v as u64)
    };
    match tail {
        "register" => {
            if draining {
                return error_response(stream, 503, "daemon is draining — no new workers");
            }
            let Some(name) = json.get("name").and_then(Json::as_str) else {
                return error_response(stream, 400, "registration needs a 'name'");
            };
            let id = fabric.register(name);
            let reply = obj(vec![
                ("worker_id", num(id as f64)),
                (
                    "lease_timeout_ms",
                    num(fabric.lease_timeout().as_millis() as f64),
                ),
            ]);
            write_response(stream, 200, "application/json", reply.to_string().as_bytes())
        }
        "heartbeat" => match worker_id() {
            None => error_response(stream, 400, "heartbeat needs a numeric 'worker_id'"),
            Some(id) if fabric.heartbeat(id) => {
                write_response(stream, 200, "application/json", b"{\"ok\": true}")
            }
            Some(_) => error_response(stream, 404, "unknown worker — re-register"),
        },
        "lease" => {
            let Some(id) = worker_id() else {
                return error_response(stream, 400, "lease needs a numeric 'worker_id'");
            };
            if draining {
                return error_response(stream, 503, "daemon is draining — no new leases");
            }
            match fabric.lease(id) {
                LeaseReply::UnknownWorker => {
                    error_response(stream, 404, "unknown worker — re-register")
                }
                LeaseReply::NoWork => write_response(stream, 204, "application/json", b""),
                LeaseReply::Lease(lease) => write_response(
                    stream,
                    200,
                    "application/json",
                    remote::lease_json(&lease).to_string().as_bytes(),
                ),
            }
        }
        "complete" => {
            let (Some(id), Some(job_id), Some(shard)) = (
                worker_id(),
                json.get("job_id").and_then(Json::as_usize),
                json.get("shard").and_then(Json::as_usize),
            ) else {
                return error_response(
                    stream,
                    400,
                    "complete needs numeric 'worker_id', 'job_id', 'shard'",
                );
            };
            let outcome = match json.get("error").and_then(Json::as_str) {
                Some(msg) => Err(msg.to_string()),
                None => Ok(json
                    .get("artifact")
                    .and_then(Json::as_str)
                    .map(str::to_string)),
            };
            match fabric.complete(id, job_id as u64, shard, outcome) {
                CompleteReply::Accepted => {
                    write_response(stream, 200, "application/json", b"{\"ok\": true}")
                }
                CompleteReply::UnknownWorker => {
                    error_response(stream, 404, "unknown worker — re-register")
                }
                CompleteReply::NotLeased => error_response(
                    stream,
                    409,
                    "shard is no longer leased to this worker (re-queued)",
                ),
            }
        }
        _ => error_response(stream, 404, "no such route"),
    }
}

fn workers_json(fabric: &Fabric) -> Json {
    let rows = fabric
        .workers()
        .into_iter()
        .map(|w| {
            obj(vec![
                ("id", num(w.id as f64)),
                ("name", s(&w.name)),
                ("leased", num(w.leased as f64)),
                ("idle_ms", num(w.idle_ms as f64)),
            ])
        })
        .collect();
    obj(vec![("workers", arr(rows))])
}

/// The dumb file store under `/fabric/...` that workers push shard
/// artifacts to (and `registry::pull` later reads server-side, straight
/// from disk). Paths are sanitized component-by-component; the body cap
/// in `read_request` bounds upload size.
fn fabric_store_route(
    stream: &mut TcpStream,
    method: &str,
    rel: &str,
    body: &[u8],
    fabric: &Fabric,
) -> anyhow::Result<()> {
    let Some(path) = remote::sanitize_store_rel(fabric.store_root(), rel) else {
        return error_response(stream, 400, "bad fabric path");
    };
    match method {
        "GET" => match std::fs::read(&path) {
            Ok(bytes) => write_response(stream, 200, "application/octet-stream", &bytes),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                error_response(stream, 404, "no such fabric object")
            }
            Err(e) => error_response(stream, 500, &format!("reading fabric object: {e}")),
        },
        "PUT" => {
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(&path, body)?;
            write_response(stream, 201, "text/plain", b"stored\n")
        }
        _ => error_response(stream, 405, "method not allowed"),
    }
}

fn job_route(
    stream: &mut TcpStream,
    method: &str,
    path: &str,
    manager: &JobManager,
    shutdown: &AtomicBool,
) -> anyhow::Result<()> {
    let rest = &path["/jobs/".len()..];
    let (id_str, tail) = match rest.split_once('/') {
        Some((a, b)) => (a, Some(b)),
        None => (rest, None),
    };
    let Ok(id) = id_str.parse::<u64>() else {
        return error_response(stream, 400, &format!("bad job id '{id_str}'"));
    };
    match (method, tail) {
        ("GET", None) => match manager.status(id) {
            Some(st) => write_response(
                stream,
                200,
                "application/json",
                status_json(&st).to_string().as_bytes(),
            ),
            None => error_response(stream, 404, "no such job"),
        },
        ("GET", Some("events")) => match manager.events(id) {
            None => error_response(stream, 404, "no such job"),
            Some(log) => stream_job_events(stream, &log, shutdown),
        },
        ("GET", Some("result")) => match manager.status(id) {
            None => error_response(stream, 404, "no such job"),
            Some(st) if st.state == JobState::Done => {
                let path = st.result_path.expect("done jobs carry a result path");
                match std::fs::read(&path) {
                    Ok(bytes) => write_response(stream, 200, "text/csv", &bytes),
                    Err(e) => error_response(stream, 500, &format!("reading result: {e}")),
                }
            }
            Some(st) => error_response(
                stream,
                409,
                &format!("job is {} — no result to serve", st.state.as_str()),
            ),
        },
        ("POST", Some("cancel")) => match manager.cancel(id) {
            CancelOutcome::Unknown => error_response(stream, 404, "no such job"),
            outcome => {
                let msg = match outcome {
                    CancelOutcome::Canceled => "canceled",
                    CancelOutcome::Running => "running — in-flight jobs complete",
                    CancelOutcome::Finished => "already finished",
                    CancelOutcome::Unknown => unreachable!(),
                };
                let body = obj(vec![("id", num(id as f64)), ("outcome", s(msg))]).to_string();
                write_response(stream, 200, "application/json", body.as_bytes())
            }
        },
        _ => error_response(stream, 404, "no such route"),
    }
}

/// Stream a job's progress log as NDJSON over chunked transfer
/// encoding: everything logged so far immediately, then new events as
/// the job appends them, terminating once the log closes (its last
/// line is the job's terminal event). The drain check matters for
/// correctness, not just latency: the accept loop joins connection
/// handlers *before* `JobManager::shutdown` cancels queued jobs, so a
/// queued job's log would never close during a drain — the stream must
/// end itself rather than hold the join hostage.
fn stream_job_events(
    stream: &mut TcpStream,
    log: &EventLog,
    shutdown: &AtomicBool,
) -> anyhow::Result<()> {
    // a long-running job may be silent between events; the connection
    // timeout bounds a single blocked write, not the stream's lifetime
    write_chunked_head(stream, 200, "application/x-ndjson")?;
    let mut from = 0usize;
    loop {
        let (lines, closed) = log.wait_since(from, EVENT_POLL);
        from += lines.len();
        for line in &lines {
            write_chunk(stream, format!("{line}\n").as_bytes())?;
        }
        if closed {
            break;
        }
        if shutdown.load(Ordering::SeqCst) || SIGNAL_SHUTDOWN.load(Ordering::SeqCst) {
            break;
        }
    }
    finish_chunked(stream)
}

fn error_response(stream: &mut TcpStream, status: u16, msg: &str) -> anyhow::Result<()> {
    let body = obj(vec![("error", s(msg))]).to_string();
    write_response(stream, status, "application/json", body.as_bytes())
}

/// Parse a submission body:
/// `{"cmd": "sweep", "options": {"arch": "qs", "n": "64:512:64"},
///   "switches": ["validate"]}`.
/// Option values are the exact strings the CLI takes, so the served
/// grid grammar is the CLI's grid grammar by construction.
fn parse_job_spec(body: &[u8]) -> Result<JobSpec, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let json = Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let verb = json
        .get("cmd")
        .and_then(|j| j.as_str())
        .ok_or_else(|| "missing 'cmd' (sweep | pareto | optimize)".to_string())?
        .to_string();
    if !matches!(verb.as_str(), "sweep" | "pareto" | "optimize") {
        return Err(format!("unsupported cmd '{verb}' (sweep | pareto | optimize)"));
    }
    let mut options = BTreeMap::new();
    if let Some(section) = json.get("options") {
        let map = section
            .as_obj()
            .ok_or_else(|| "'options' must be an object of strings".to_string())?;
        for (k, v) in map {
            let v = v.as_str().ok_or_else(|| {
                format!("option '{k}' must be a string (grids use the CLI grammar, e.g. \"4:10\")")
            })?;
            options.insert(k.clone(), v.to_string());
        }
    }
    let mut switches = Vec::new();
    if let Some(section) = json.get("switches") {
        let list = section
            .as_arr()
            .ok_or_else(|| "'switches' must be an array of strings".to_string())?;
        for sw in list {
            let sw = sw
                .as_str()
                .ok_or_else(|| "'switches' must be an array of strings".to_string())?;
            switches.push(sw.to_string());
        }
    }
    for k in options.keys() {
        // trace and progress are process-global observability switches:
        // a job toggling them would retarget the daemon's own trace
        // slab / stderr stream (use GET /jobs/<id>/events instead)
        if matches!(
            k.as_str(),
            "out-dir" | "cache-dir" | "procs" | "shard" | "backend" | "artifacts" | "trace"
                | "progress"
        ) {
            return Err(format!("option '--{k}' is reserved by the daemon"));
        }
    }
    for sw in &switches {
        if matches!(sw.as_str(), "no-cache" | "keep-shards") {
            return Err(format!("switch '--{sw}' is not available under serve"));
        }
    }
    Ok(JobSpec {
        verb,
        options,
        switches,
    })
}

fn status_json(st: &JobStatus) -> Json {
    let mut fields = vec![
        ("id", num(st.id as f64)),
        ("cmd", s(&st.verb)),
        ("state", s(st.state.as_str())),
        ("cache_hits", num(st.metrics.cache_hits as f64)),
        ("cache_misses", num(st.metrics.cache_misses as f64)),
        ("points_computed", num(st.metrics.points_computed as f64)),
        ("trials_completed", num(st.metrics.trials_completed as f64)),
        ("queued_at_ms", num(st.queued_at_ms as f64)),
    ];
    if let Some(t) = st.started_at_ms {
        fields.push(("started_at_ms", num(t as f64)));
    }
    if let Some(t) = st.finished_at_ms {
        fields.push(("finished_at_ms", num(t as f64)));
    }
    if let Some(d) = st.duration_ms() {
        fields.push(("duration_ms", num(d as f64)));
    }
    if let Some(e) = &st.error {
        fields.push(("error", s(e)));
    }
    if st.state == JobState::Done {
        fields.push(("result", s(&format!("/jobs/{}/result", st.id))));
    }
    obj(fields)
}

fn stats_json(manager: &JobManager, fabric: &Fabric) -> Json {
    let m = metrics::snapshot();
    let q = manager.queue_stats();
    let (sh_pending, sh_active, sh_done) = fabric.shard_counts();
    obj(vec![
        ("workers", num(fabric.live_workers() as f64)),
        (
            "shards",
            obj(vec![
                ("pending", num(sh_pending as f64)),
                ("active", num(sh_active as f64)),
                ("done", num(sh_done as f64)),
            ]),
        ),
        ("cache_hits", num(m.cache_hits as f64)),
        ("cache_misses", num(m.cache_misses as f64)),
        ("points_computed", num(m.points_computed as f64)),
        ("trials_completed", num(m.trials_completed as f64)),
        ("mc_errors", num(m.mc_errors as f64)),
        ("jobs_in_flight", num((q.queued + q.running) as f64)),
        (
            "jobs",
            obj(vec![
                ("queued", num(q.queued as f64)),
                ("running", num(q.running as f64)),
                ("done", num(q.done as f64)),
                ("failed", num(q.failed as f64)),
                ("canceled", num(q.canceled as f64)),
            ]),
        ),
        ("draining", Json::Bool(manager.is_shutting_down())),
    ])
}

/// `imclim worker --connect http://coordinator:PORT [--name N]
/// [--scratch DIR] [--poll-ms MS] [--heartbeat-ms MS] [--hold-ms MS]`
/// — attach to a running `imclim serve` daemon and execute leased
/// sweep shards until the coordinator drains or SIGTERM/SIGINT.
pub fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let url = args
        .opt("connect")
        .context("imclim worker needs --connect http://coordinator:PORT")?;
    let coordinator = HttpEndpoint::parse(url)?;
    let name = args
        .opt("name")
        .map(str::to_string)
        .unwrap_or_else(|| format!("worker-{}", std::process::id()));
    let scratch: PathBuf = args
        .opt("scratch")
        .map(PathBuf::from)
        .unwrap_or_else(|| std::env::temp_dir().join(format!("imclim-worker-{name}")));
    let cfg = remote::WorkerConfig {
        coordinator,
        name,
        scratch,
        poll: Duration::from_millis(args.opt_parse("poll-ms", 500u64)),
        heartbeat: Duration::from_millis(args.opt_parse("heartbeat-ms", 1_000u64)),
        hold: Duration::from_millis(args.opt_parse("hold-ms", 0u64)),
    };
    install_signal_handlers();
    remote::run_worker(&cfg, &execute_shard, &|| {
        SIGNAL_SHUTDOWN.load(Ordering::SeqCst)
    })
}

/// Execute one leased shard through the same grid entry point the CLI
/// and the daemon use, against the worker's scratch cache. The shard's
/// partial CSV lands in (and dies with) the per-lease out-dir; only
/// cache records travel back to the coordinator.
fn execute_shard(lease: &ShardLease, out_dir: &Path, cache_dir: &Path) -> anyhow::Result<()> {
    anyhow::ensure!(
        lease.spec.verb == "sweep",
        "coordinator leased unsupported verb '{}'",
        lease.spec.verb
    );
    let mut cli = Args {
        positionals: vec![lease.spec.verb.clone()],
        options: lease.spec.options.clone(),
        switches: lease.spec.switches.clone(),
    };
    cli.options
        .insert("out-dir".into(), out_dir.to_string_lossy().into_owned());
    cli.options
        .insert("cache-dir".into(), cache_dir.to_string_lossy().into_owned());
    super::run_sweep_grid(&cli, Some((lease.index, lease.total)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_parsing_accepts_cli_grammar_and_rejects_reserved() {
        let body = br#"{"cmd":"sweep","options":{"arch":"qs,qr","n":"8,16:64:16","trials":"48"},"switches":["verbose"]}"#;
        let spec = parse_job_spec(body).unwrap();
        assert_eq!(spec.verb, "sweep");
        assert_eq!(spec.options["n"], "8,16:64:16");
        assert_eq!(spec.switches, ["verbose"]);

        // minimal body: options/switches are optional
        let spec = parse_job_spec(br#"{"cmd":"optimize"}"#).unwrap();
        assert_eq!(spec.verb, "optimize");
        assert!(spec.options.is_empty());

        for (body, needle) in [
            (&br#"{"options":{}}"#[..], "missing 'cmd'"),
            (br#"{"cmd":"figure"}"#, "unsupported cmd"),
            (br#"{"cmd":"sweep","options":{"n":16}}"#, "must be a string"),
            (br#"{"cmd":"sweep","options":{"out-dir":"/x"}}"#, "reserved"),
            (br#"{"cmd":"sweep","options":{"procs":"4"}}"#, "reserved"),
            (br#"{"cmd":"sweep","options":{"trace":"/t.json"}}"#, "reserved"),
            (br#"{"cmd":"sweep","options":{"progress":"json"}}"#, "reserved"),
            (br#"{"cmd":"sweep","switches":["no-cache"]}"#, "not available"),
            (b"not json", "bad JSON"),
            (b"\xff\xfe", "not UTF-8"),
        ] {
            let err = parse_job_spec(body).unwrap_err();
            assert!(err.contains(needle), "{err:?} should mention {needle:?}");
        }
    }

    #[test]
    fn status_json_shape() {
        let st = JobStatus {
            id: 3,
            verb: "sweep".into(),
            state: JobState::Done,
            error: None,
            result_path: Some(PathBuf::from("/x/sweep.csv")),
            metrics: crate::coordinator::MetricsSnapshot {
                cache_hits: 6,
                ..Default::default()
            },
            queued_at_ms: 1_000,
            started_at_ms: Some(1_250),
            finished_at_ms: Some(1_900),
        };
        let j = status_json(&st);
        assert_eq!(j.get("id").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("state").and_then(|v| v.as_str()), Some("done"));
        assert_eq!(j.get("cache_hits").and_then(Json::as_usize), Some(6));
        assert_eq!(j.get("result").and_then(|v| v.as_str()), Some("/jobs/3/result"));
        assert_eq!(j.get("queued_at_ms").and_then(Json::as_usize), Some(1_000));
        assert_eq!(j.get("started_at_ms").and_then(Json::as_usize), Some(1_250));
        assert_eq!(j.get("finished_at_ms").and_then(Json::as_usize), Some(1_900));
        assert_eq!(j.get("duration_ms").and_then(Json::as_usize), Some(650));
        let text = j.to_string();
        let reparsed = Json::parse(&text).unwrap();
        let computed = reparsed.get("points_computed").and_then(Json::as_usize);
        assert_eq!(computed, Some(0));

        // timestamps a queued job doesn't have yet are simply absent
        let st = JobStatus {
            started_at_ms: None,
            finished_at_ms: None,
            state: JobState::Queued,
            result_path: None,
            ..st
        };
        let j = status_json(&st);
        assert!(j.get("started_at_ms").is_none());
        assert!(j.get("duration_ms").is_none());
        assert!(j.get("result").is_none());
    }
}
