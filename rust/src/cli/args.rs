//! Hand-rolled argument parser (offline build: no clap): positional
//! arguments plus `--flag value` / `--switch` options.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse argv-style input. A token `--name` followed by a non-flag
    /// token is an option; a trailing or flag-followed `--name` is a
    /// switch.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let tokens: Vec<String> = argv.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positionals.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(String::as_str)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("figure fig9a --out-dir results --trials 500 --verbose");
        assert_eq!(a.pos(0), Some("figure"));
        assert_eq!(a.pos(1), Some("fig9a"));
        assert_eq!(a.opt("out-dir"), Some("results"));
        assert_eq!(a.opt_parse("trials", 0usize), 500);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("sweep --arch=qs --n=128");
        assert_eq!(a.opt("arch"), Some("qs"));
        assert_eq!(a.opt_parse("n", 0usize), 128);
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("--verbose --trials 10");
        assert!(a.has("verbose"));
        assert_eq!(a.opt_parse("trials", 0usize), 10);
    }

    #[test]
    fn default_on_missing_or_garbage() {
        let a = parse("--trials abc");
        assert_eq!(a.opt_parse("trials", 7usize), 7);
        assert_eq!(a.opt_parse("missing", 3.5f64), 3.5);
    }
}
