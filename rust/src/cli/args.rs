//! Hand-rolled argument parser (offline build: no clap): positional
//! arguments plus `--flag value` / `--switch` options, and the
//! human-unit value parsers (byte sizes, durations) used by `cache gc`.

use std::collections::BTreeMap;

use anyhow::{anyhow, ensure, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse argv-style input. A token `--name` followed by a non-flag
    /// token is an option; a trailing or flag-followed `--name` is a
    /// switch.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let tokens: Vec<String> = argv.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positionals.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(String::as_str)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// Worked examples appended to the `imclim` usage screen.
pub const EXAMPLES: &str = "
EXAMPLES:
  # arbitrary design-space grid, cached + distributed over 4 processes
  imclim sweep --arch qs,qr --n 64,128,256 --b-adc 4:10 \\
      --vwl 0.6:0.8:0.1 --trials 4096 --procs 4 --out-dir results

  # energy-delay-accuracy Pareto frontier of the same space, with each
  # frontier point Monte-Carlo-validated through the shared cache
  imclim pareto --arch qs,qr --n 64:512:64 --b-adc 4:10 \\
      --vwl 0.6:0.9:0.1 --validate --out-dir results

  # cheapest design reaching 21.5 dB SNR_T (the MPC operating point of
  # the 512-row reference: B_ADC comes out at the eq. (15) assignment)
  imclim optimize --objective min-energy --snr-t-min 21.5

  # highest-accuracy design under an energy budget
  imclim optimize --objective max-snr --energy-max 5e-12 --delay-max 2.5

  # banked ceiling escape (conclusion 4): let the optimizer split large
  # arrays into banks, with silicon area as the fourth frontier axis
  imclim pareto --arch qs,qr --n 64:512:64 --banks 1,2,4 --b-adc 4:10

  # smallest design reaching 18 dB, and a hard area budget variant
  imclim optimize --objective min-area --snr-t-min 18
  imclim optimize --objective min-energy --snr-t-min 18 --area-max 5e-3

  # machine-check conclusion 3: the QS->QR preference flip appears once
  # Bx/Bw scale with the target (precision assignment), N held at 512
  imclim pareto --crossover --n 512 --bx 1:8 --bw 1:8 --b-adc 1:14 \\
      --vwl 0.55:0.9:0.05 --co 0.5,1,2,3,6,9 --targets 1:28:1

  # share Monte-Carlo results: snapshot the cache as a verifiable
  # artifact (per-record sha256 + deterministic tarball) and publish it
  imclim sweep --arch qs --n 64,128 --b-adc 4:8 --out-dir results
  imclim cache pack --out-dir results
  imclim cache verify --out-dir results
  imclim cache push file:///shared/imclim-registry --out-dir results

  # warm a fresh machine from the registry: pull fetches + verifies +
  # merges, so the re-run below does zero Monte-Carlo and its sweep.csv
  # is byte-identical to the publisher's
  imclim cache pull file:///shared/imclim-registry --out-dir fresh
  imclim sweep --arch qs --n 64,128 --b-adc 4:8 --out-dir fresh

  # strict mode for CI: any differing-payload collision is a failure
  imclim merge shard-0 shard-1 --strict --out-dir results
  imclim cache pull http://reg.internal/imclim --strict --out-dir results

  # adaptive-precision trials: grow each ensemble (256-trial chunks)
  # until SNR_a and SNR_T are pinned to a 0.25 dB 95% CI half-width —
  # noisy corners get more trials, clean corners stop early. Adaptive
  # records are cached under their own keys, so they never shadow a
  # fixed-trials sweep over the same grid (and vice versa)
  imclim sweep --arch qs --n 64:512:64 --b-adc 4:10 --precision 0.25

  # the same stopping rule on pareto frontier validation
  imclim pareto --arch qs,qr --n 64:512:64 --b-adc 4:10 \\
      --validate --precision 0.5

  # intra-point parallelism: one 65536-trial point saturates the pool
  # anyway — fixed-trials native points split into 256-trial chunk jobs
  # whose merged result is bit-identical to a --workers 1 run
  imclim sweep --arch qr --n 512 --b-adc 8 --trials 65536 --workers 8

  # sweep-as-a-service: a long-running daemon that takes sweep/pareto/
  # optimize jobs over HTTP and runs them through the exact CLI code
  # paths against one shared cache — a served CSV is byte-identical to
  # its CLI twin, and a repeated query recomputes nothing
  imclim serve --addr 0.0.0.0:7878 --out-dir /srv/imclim

  # submit a job: \"cmd\" is the CLI verb; \"options\"/\"switches\" are
  # the CLI flags verbatim (string values; grids use the CLI grammar),
  # so any sweep/pareto/optimize invocation translates 1:1
  curl -s -X POST http://host:7878/jobs -d '{
      \"cmd\": \"sweep\",
      \"options\": {\"arch\": \"qs,qr\", \"n\": \"64:512:64\",
                  \"b-adc\": \"4:10\", \"trials\": \"4096\"},
      \"switches\": []
    }'                                     # -> 202 {\"id\": 1, ...}

  # poll, then fetch the CSV; per-job metrics prove warmth (a cache-hit
  # job reports points_computed 0)
  curl -s http://host:7878/jobs/1           # status + per-job metrics
  curl -s http://host:7878/jobs/1/result    # the job's CSV (200 when done)
  curl -s -X POST http://host:7878/jobs/1/cancel

  # observability + graceful drain (SIGTERM does the same): the
  # in-flight job completes, queued jobs are canceled, exit code 0
  curl -s http://host:7878/healthz
  curl -s http://host:7878/stats
  curl -s -X POST http://host:7878/shutdown

  # Prometheus scrape target: counters (cache hits/misses, points,
  # trials), queue gauges, and cache-probe / MC-chunk latency
  # histograms in text exposition format
  curl -s http://host:7878/metrics

  # watch a job live: NDJSON progress events stream over chunked
  # transfer-encoding as the job runs (one per finished point) and the
  # stream ends with the job's terminal event; a warm job goes straight
  # to the terminal event
  curl -sN http://host:7878/jobs/1/events

  # trace where a sweep spends its time: spans for grid parse, cache
  # probes, MC chunks, adaptive rounds and CSV emit land in t.json
  # (Chrome trace format — open in Perfetto); outputs are byte-identical
  # with and without --trace
  imclim sweep --arch qs --n 64,128 --b-adc 4:8 --trace t.json

  # progress as data on stderr (same events serve streams), or silence
  imclim sweep --arch qs --n 64:512:64 --b-adc 4:10 --progress json
  imclim sweep --arch qs --n 64:512:64 --b-adc 4:10 --quiet

  # fan sweeps out across hosts: workers attach to a running daemon,
  # lease deterministic --shard i/k slices of each job, and ship the
  # records back as verified cache artifacts; the coordinator merges
  # them and emits a CSV byte-identical to a single-process run
  imclim serve --addr 0.0.0.0:7878 --out-dir /srv/imclim --lease-timeout 30s
  imclim worker --connect http://coordinator:7878 --name $(hostname)
  curl -s http://coordinator:7878/workers   # who is attached, who holds leases

  # workers are disposable: kill one mid-job and its shards re-queue to
  # the survivors (watch for shard_requeued in the job's event stream);
  # with no workers left the coordinator finishes the job itself
  curl -sN http://coordinator:7878/jobs/1/events | grep shard_
";

/// Parse a byte size with optional binary-unit suffix: `"4096"`,
/// `"512k"`, `"10M"`, `"2g"` (k/m/g = KiB/MiB/GiB).
pub fn parse_bytes(s: &str) -> Result<u64> {
    let t = s.trim();
    ensure!(!t.is_empty(), "empty byte size");
    let (digits, mult) = match t.chars().next_back().unwrap() {
        'k' | 'K' => (&t[..t.len() - 1], 1u64 << 10),
        'm' | 'M' => (&t[..t.len() - 1], 1u64 << 20),
        'g' | 'G' => (&t[..t.len() - 1], 1u64 << 30),
        _ => (t, 1u64),
    };
    let n: u64 = digits
        .trim()
        .parse()
        .map_err(|_| anyhow!("bad byte size '{s}' (want N, Nk, Nm or Ng)"))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow!("byte size '{s}' overflows"))
}

/// Parse a duration in seconds with optional suffix: `"90"`, `"45s"`,
/// `"10m"`, `"6h"`, `"7d"`.
pub fn parse_duration_secs(s: &str) -> Result<u64> {
    let t = s.trim();
    ensure!(!t.is_empty(), "empty duration");
    let (digits, mult) = match t.chars().next_back().unwrap() {
        's' | 'S' => (&t[..t.len() - 1], 1u64),
        'm' | 'M' => (&t[..t.len() - 1], 60),
        'h' | 'H' => (&t[..t.len() - 1], 3600),
        'd' | 'D' => (&t[..t.len() - 1], 86_400),
        _ => (t, 1),
    };
    let n: u64 = digits
        .trim()
        .parse()
        .map_err(|_| anyhow!("bad duration '{s}' (want N, Ns, Nm, Nh or Nd)"))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow!("duration '{s}' overflows"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("figure fig9a --out-dir results --trials 500 --verbose");
        assert_eq!(a.pos(0), Some("figure"));
        assert_eq!(a.pos(1), Some("fig9a"));
        assert_eq!(a.opt("out-dir"), Some("results"));
        assert_eq!(a.opt_parse("trials", 0usize), 500);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("sweep --arch=qs --n=128");
        assert_eq!(a.opt("arch"), Some("qs"));
        assert_eq!(a.opt_parse("n", 0usize), 128);
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("--verbose --trials 10");
        assert!(a.has("verbose"));
        assert_eq!(a.opt_parse("trials", 0usize), 10);
    }

    #[test]
    fn default_on_missing_or_garbage() {
        let a = parse("--trials abc");
        assert_eq!(a.opt_parse("trials", 7usize), 7);
        assert_eq!(a.opt_parse("missing", 3.5f64), 3.5);
    }

    #[test]
    fn byte_sizes_with_binary_suffixes() {
        assert_eq!(parse_bytes("4096").unwrap(), 4096);
        assert_eq!(parse_bytes("512k").unwrap(), 512 * 1024);
        assert_eq!(parse_bytes("10M").unwrap(), 10 * 1024 * 1024);
        assert_eq!(parse_bytes("2g").unwrap(), 2 * 1024 * 1024 * 1024);
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("k").is_err());
        assert!(parse_bytes("ten").is_err());
        assert!(parse_bytes("99999999999999999999g").is_err());
    }

    #[test]
    fn durations_with_suffixes() {
        assert_eq!(parse_duration_secs("90").unwrap(), 90);
        assert_eq!(parse_duration_secs("45s").unwrap(), 45);
        assert_eq!(parse_duration_secs("10m").unwrap(), 600);
        assert_eq!(parse_duration_secs("6h").unwrap(), 21_600);
        assert_eq!(parse_duration_secs("7d").unwrap(), 604_800);
        assert!(parse_duration_secs("").is_err());
        assert!(parse_duration_secs("soon").is_err());
    }
}
