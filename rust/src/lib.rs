//! # imclim — fundamental limits of in-memory computing architectures
//!
//! A production-grade reproduction of Gonugondla et al., *"Fundamental
//! Limits on Energy-Delay-Accuracy of In-memory Architectures in
//! Inference Applications"* (2020), built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) implement the
//!   analog-core contractions of the sample-accurate Monte-Carlo
//!   simulator; AOT-lowered to HLO text at build time.
//! * **L2** — JAX models (`python/compile/model.py`) of the three IMC
//!   architectures (QS-Arch, QR-Arch, CM) over the full signal chain.
//! * **L3** — this crate: the closed-form analytical models (every
//!   equation in the paper), the sweep engine (`engine`: declarative
//!   grids, a content-addressed result cache, cached execution), the
//!   experiment coordinator (lock-free sweep scheduler, worker pool,
//!   PJRT execution of the AOT artifacts), a native Monte-Carlo oracle,
//!   the fixed-point DNN substrate, the design-space optimizer (`opt`:
//!   Pareto frontiers, constrained search, the QS-vs-QR crossover
//!   report behind `imclim pareto` / `imclim optimize`), and drivers
//!   that regenerate every figure and table of the paper's evaluation —
//!   all through the same cached, parallel path, so arbitrary
//!   design-space queries (the `imclim sweep` subcommand) are
//!   first-class, not just the paper's fixed figures.
//!
//! Python never runs on the experiment path: `make artifacts` is the only
//! Python invocation; everything else is this binary.

pub mod arch;
pub mod area;
pub mod bench;
pub mod cli;
pub mod compute;
pub mod coordinator;
pub mod dnn;
pub mod energy;
pub mod engine;
pub mod figures;
pub mod mc;
pub mod obs;
pub mod opt;
pub mod prop;
pub mod quant;
pub mod registry;
pub mod runtime;
pub mod snr;
pub mod taxonomy;
pub mod tech;
pub mod util;
