//! Table I: a taxonomy of published CMOS IMC designs classified by
//! in-memory compute model (QS / IS / QR) and analog-core / ADC precision,
//! as data, plus the consistency queries used to regenerate the table.

use crate::mc::ArchKind;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdcPrecision {
    Bits(u32),
    Analog,    // continuous-valued input (Liu et al.)
    Effective10x(u32), // e.g. 3.46 b stored as 34.6/10
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightPrecision {
    Bits(u32),
    Ternary,
    Analog,
}

#[derive(Clone, Debug)]
pub struct ImcDesign {
    pub name: &'static str,
    pub year: u32,
    pub qs: bool,
    pub is: bool,
    pub qr: bool,
    pub bx: WeightPrecision,
    pub bw: WeightPrecision,
    pub b_adc: AdcPrecision,
}

impl ImcDesign {
    pub fn compute_models(&self) -> Vec<ArchKind> {
        let mut v = Vec::new();
        if self.qs {
            v.push(ArchKind::Qs);
        }
        if self.qr {
            v.push(ArchKind::Qr);
        }
        // IS maps onto the QS noise physics at the architecture level.
        v
    }
}

use AdcPrecision as A;
use WeightPrecision as W;

/// The 23 designs of Table I.
pub fn table1() -> Vec<ImcDesign> {
    fn d(
        name: &'static str,
        year: u32,
        (qs, is, qr): (bool, bool, bool),
        bx: W,
        bw: W,
        b_adc: A,
    ) -> ImcDesign {
        ImcDesign {
            name,
            year,
            qs,
            is,
            qr,
            bx,
            bw,
            b_adc,
        }
    }
    vec![
        d("Kang et al. [6]", 2018, (true, false, true), W::Bits(8), W::Bits(8), A::Bits(8)),
        d("Biswas et al. [8]", 2018, (false, false, true), W::Bits(8), W::Bits(1), A::Bits(7)),
        d("Zhang et al. [5]", 2017, (true, false, false), W::Bits(5), W::Bits(1), A::Bits(1)),
        d("Valavi et al. [12]", 2018, (false, false, true), W::Bits(1), W::Bits(1), A::Bits(1)),
        d("Khwa et al. [11]", 2018, (false, true, false), W::Bits(1), W::Bits(1), A::Bits(1)),
        d("Jiang et al. [7]", 2018, (false, true, false), W::Bits(1), W::Bits(1), A::Effective10x(35)),
        d("Si et al. [38]", 2019, (true, false, true), W::Bits(2), W::Bits(5), A::Bits(5)),
        d("Jia et al. [39]", 2018, (false, false, true), W::Bits(1), W::Bits(1), A::Bits(8)),
        d("Okumura et al. [40]", 2019, (false, true, false), W::Bits(1), W::Ternary, A::Bits(8)),
        d("Kim et al. [13]", 2019, (false, true, false), W::Bits(1), W::Bits(1), A::Bits(1)),
        d("Guo et al. [41]", 2019, (true, false, false), W::Bits(1), W::Bits(1), A::Bits(3)),
        d("Yue et al. [42]", 2020, (true, false, true), W::Bits(2), W::Bits(5), A::Bits(5)),
        d("Su et al. [15]", 2020, (true, false, false), W::Bits(2), W::Bits(1), A::Bits(5)),
        d("Dong et al. [14]", 2020, (true, false, true), W::Bits(4), W::Bits(4), A::Bits(4)),
        d("Si et al. [16]", 2020, (true, false, false), W::Bits(2), W::Bits(2), A::Bits(5)),
        d("Jiang et al. [43]", 2020, (false, false, true), W::Bits(1), W::Bits(1), A::Bits(5)),
        d("Jaiswal et al. [17]", 2019, (false, true, false), W::Bits(4), W::Bits(4), A::Bits(4)),
        d("Ali et al. [18]", 2020, (true, false, true), W::Bits(4), W::Bits(4), A::Bits(4)),
        d("Si et al. [19]", 2019, (true, false, false), W::Bits(1), W::Bits(1), A::Bits(1)),
        d("Liu et al. [20]", 2020, (false, true, false), W::Analog, W::Bits(1), A::Bits(1)),
        d("Zhang et al. [21]", 2020, (false, true, false), W::Bits(8), W::Bits(8), A::Bits(8)),
        d("Gong et al. [22]", 2020, (true, false, false), W::Bits(2), W::Bits(3), A::Bits(8)),
        d("Agrawal et al. [23]", 2019, (false, false, true), W::Bits(1), W::Bits(1), A::Bits(5)),
    ]
}

/// Count designs per compute model (designs may use several).
pub fn model_counts(designs: &[ImcDesign]) -> (usize, usize, usize) {
    (
        designs.iter().filter(|d| d.qs).count(),
        designs.iter().filter(|d| d.is).count(),
        designs.iter().filter(|d| d.qr).count(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twenty_three_designs() {
        assert_eq!(table1().len(), 23);
    }

    #[test]
    fn every_design_uses_at_least_one_model() {
        for d in table1() {
            assert!(d.qs || d.is || d.qr, "{}", d.name);
        }
    }

    #[test]
    fn model_counts_plausible() {
        let (qs, is, qr) = model_counts(&table1());
        assert!(qs >= 10, "{qs}");
        assert!(is >= 6, "{is}");
        assert!(qr >= 8, "{qr}");
    }

    #[test]
    fn binarized_designs_dominate() {
        // Paper Sec. IV-B2: most IMCs binarize to cope with limited SNR_a.
        let low_prec = table1()
            .iter()
            .filter(|d| matches!(d.bw, WeightPrecision::Bits(b) if b <= 2)
                || matches!(d.bw, WeightPrecision::Ternary))
            .count();
        assert!(low_prec >= 12, "{low_prec}");
    }
}
