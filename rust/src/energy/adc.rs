//! Column-ADC energy model (Sec. V-C, eq. 26), after Murmann [48]:
//!
//!   E_ADC = k1 (B + log2(V_dd/V_c)) + k2 (V_dd/V_c)^2 4^B
//!
//! with k1 = 100 fJ (logic/offset term) and k2 = 1 aJ (noise-limited
//! term); V_c is the quantized voltage range at the ADC input.

#[derive(Clone, Copy, Debug)]
pub struct AdcEnergyModel {
    pub k1: f64,
    pub k2: f64,
    pub v_dd: f64,
}

impl AdcEnergyModel {
    pub fn paper(v_dd: f64) -> Self {
        Self {
            k1: 100e-15,
            k2: 1e-18,
            v_dd,
        }
    }

    /// Eq. (26). `v_c` is clamped to V_dd (a range above the rail is
    /// realized by attenuation, not by a wider ADC).
    pub fn energy(&self, b_adc: u32, v_c: f64) -> f64 {
        let ratio = self.v_dd / v_c.min(self.v_dd).max(1e-6);
        self.k1 * (b_adc as f64 + ratio.log2().max(0.0))
            + self.k2 * ratio * ratio * 4f64.powi(b_adc as i32)
    }

    /// SAR-style conversion latency: one comparison per bit.
    pub fn delay(&self, b_adc: u32, t_comp: f64) -> f64 {
        b_adc as f64 * t_comp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> AdcEnergyModel {
        AdcEnergyModel::paper(1.0)
    }

    #[test]
    fn magnitude_sub_pj_at_8b() {
        let e = m().energy(8, 1.0);
        assert!(e > 0.5e-12 && e < 2e-12, "{e}");
    }

    #[test]
    fn exponential_term_dominates_at_high_bits() {
        // 4^B term: +2 bits multiplies the noise-limited part by 16.
        let e12 = m().energy(12, 1.0);
        let e14 = m().energy(14, 1.0);
        assert!(e14 / e12 > 8.0, "{}", e14 / e12);
    }

    #[test]
    fn small_range_costs_energy() {
        // Quantizing a smaller V_c at fixed B needs a lower noise floor.
        assert!(m().energy(8, 0.1) > m().energy(8, 0.9));
    }

    #[test]
    fn monotone_in_bits() {
        for b in 1..15 {
            assert!(m().energy(b + 1, 0.5) > m().energy(b, 0.5));
        }
    }
}
