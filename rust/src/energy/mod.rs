//! Energy models: the ADC model of eq. (26) plus shared helpers. The
//! per-architecture DP energy expressions (Table III row "Energy cost per
//! DP") live with their architectures in `crate::arch`.

pub mod adc;

/// Energy-delay product helper.
pub fn edp(energy_j: f64, delay_s: f64) -> f64 {
    energy_j * delay_s
}

/// Energy efficiency in TOPS/W for `ops` operations at `energy_j` joules.
pub fn tops_per_watt(ops: f64, energy_j: f64) -> f64 {
    ops / energy_j / 1e12
}

#[cfg(test)]
mod tests {
    #[test]
    fn tops_per_watt_sane() {
        // 2N ops per DP, N=512, at 5 pJ -> ~0.2 TOPS/W per... sanity only.
        let t = super::tops_per_watt(1024.0, 5e-12);
        assert!(t > 100.0 && t < 1000.0, "{t}");
    }
}
