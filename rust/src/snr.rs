//! Compute-SNR composition (Sec. III-A/B): eqs. (10)-(11) and the
//! precision-assignment procedure that drives SNR_T -> SNR_a.

use crate::util::stats::{db, from_db};

/// Noise-power composition of parallel noise sources (all relative to the
/// same signal power): 1/SNR_total = sum_i 1/SNR_i.
pub fn compose(snrs: &[f64]) -> f64 {
    let inv: f64 = snrs
        .iter()
        .map(|&s| if s.is_infinite() { 0.0 } else { 1.0 / s })
        .sum();
    if inv == 0.0 {
        f64::INFINITY
    } else {
        1.0 / inv
    }
}

/// Eq. (10): SNR_A = [1/SNR_a + 1/SQNR_qiy]^-1, in dB.
pub fn snr_a_total_db(snr_a_db: f64, sqnr_qiy_db: f64) -> f64 {
    db(compose(&[from_db(snr_a_db), from_db(sqnr_qiy_db)]))
}

/// Eq. (11): SNR_T = [1/SNR_A + 1/SQNR_qy]^-1, in dB.
pub fn snr_t_db(snr_a_cap_db: f64, sqnr_qy_db: f64) -> f64 {
    db(compose(&[from_db(snr_a_cap_db), from_db(sqnr_qy_db)]))
}

/// The full decomposition of one operating point, as estimated from
/// Monte-Carlo ensembles or evaluated from closed forms.
#[derive(Clone, Copy, Debug, Default)]
pub struct SnrBreakdown {
    /// Signal power sigma_yo^2.
    pub signal_var: f64,
    /// Input-quantization noise sigma_qiy^2.
    pub qiy_var: f64,
    /// Analog noise sigma_eta_a^2 (eta_e + eta_h).
    pub analog_var: f64,
    /// Output/ADC quantization noise sigma_qy^2.
    pub qy_var: f64,
}

impl SnrBreakdown {
    pub fn sqnr_qiy_db(&self) -> f64 {
        db(self.signal_var / self.qiy_var)
    }

    pub fn snr_a_db(&self) -> f64 {
        db(self.signal_var / self.analog_var)
    }

    /// Pre-ADC SNR_A (eq. 10).
    pub fn snr_a_total_db(&self) -> f64 {
        db(self.signal_var / (self.qiy_var + self.analog_var))
    }

    /// Total SNR_T (eq. 11).
    pub fn snr_t_db(&self) -> f64 {
        db(self.signal_var / (self.qiy_var + self.analog_var + self.qy_var))
    }
}

/// Precision assignment procedure of Sec. III-B: given a target SNR_T*
/// and the analog core's SNR_a, pick (B_x, B_w, B_y) so SNR_T -> SNR_a.
#[derive(Clone, Copy, Debug)]
pub struct PrecisionAssignment {
    pub bx: u32,
    pub bw: u32,
    pub by: u32,
    pub predicted_snr_t_db: f64,
}

/// Assign minimal (B_x, B_w) such that SQNR_qiy >= SNR_a + margin, and
/// B_y per MPC such that SQNR_qy >= SNR_A + margin.
pub fn assign_precisions(
    snr_a_db: f64,
    margin_db: f64,
    w: &crate::quant::SignalStats,
    x: &crate::quant::SignalStats,
) -> PrecisionAssignment {
    let mut bx = 1;
    let mut bw = 1;
    // grow the smaller contributor until the joint SQNR_qiy clears target
    while crate::quant::sqnr_qiy_db(1, bw, bx, w, x) < snr_a_db + margin_db
        && (bx < 16 || bw < 16)
    {
        // adding a bit where the marginal gain is larger
        let grow_x = crate::quant::sqnr_qiy_db(1, bw, bx + 1, w, x)
            >= crate::quant::sqnr_qiy_db(1, bw + 1, bx, w, x);
        if grow_x {
            bx += 1;
        } else {
            bw += 1;
        }
    }
    let sqnr_qiy = crate::quant::sqnr_qiy_db(1, bw, bx, w, x);
    let snr_a_cap = snr_a_total_db(snr_a_db, sqnr_qiy);
    let by = crate::quant::criteria::mpc_min_bits(snr_a_cap, 0.5);
    let sqnr_qy = crate::quant::criteria::mpc_sqnr_db(by, 4.0);
    PrecisionAssignment {
        bx,
        bw,
        by,
        predicted_snr_t_db: snr_t_db(snr_a_cap, sqnr_qy),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::SignalStats;

    #[test]
    fn compose_basics() {
        assert!((compose(&[100.0, 100.0]) - 50.0).abs() < 1e-9);
        assert_eq!(compose(&[f64::INFINITY, f64::INFINITY]), f64::INFINITY);
        assert!((compose(&[f64::INFINITY, 10.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn nine_db_margin_gives_half_db_loss() {
        // Sec. III-B: if SQNR >= SNR_a + 9 dB then SNR loss <= 0.5 dB.
        let t = snr_a_total_db(30.0, 39.0);
        assert!(30.0 - t <= 0.52, "{t}");
        assert!(30.0 - t >= 0.4);
    }

    #[test]
    fn snr_t_bounded_by_snr_a() {
        for snr_a in [10.0, 20.0, 35.0] {
            for q in [snr_a - 5.0, snr_a, snr_a + 20.0] {
                assert!(snr_t_db(snr_a, q) <= snr_a + 1e-9);
            }
        }
    }

    #[test]
    fn breakdown_consistency() {
        let b = SnrBreakdown {
            signal_var: 100.0,
            qiy_var: 0.1,
            analog_var: 1.0,
            qy_var: 0.1,
        };
        let composed = snr_t_db(
            snr_a_total_db(b.snr_a_db(), b.sqnr_qiy_db()),
            crate::util::stats::db(b.signal_var / b.qy_var),
        );
        assert!((b.snr_t_db() - composed).abs() < 1e-9);
    }

    #[test]
    fn assignment_reaches_snr_a() {
        let w = SignalStats::uniform_signed(1.0);
        let x = SignalStats::uniform_unsigned(1.0);
        let a = assign_precisions(30.0, 9.0, &w, &x);
        assert!(30.0 - a.predicted_snr_t_db < 1.0, "{a:?}");
        assert!(a.bx <= 8 && a.bw <= 8, "{a:?}");
        // Higher SNR_a needs more bits everywhere.
        let a2 = assign_precisions(40.0, 9.0, &w, &x);
        assert!(a2.bx + a2.bw > a.bx + a.bw);
        assert!(a2.by > a.by);
    }
}
