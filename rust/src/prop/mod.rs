//! Minimal property-based testing framework (offline build: no proptest).
//!
//! Provides seeded generators and a runner that, on failure, reports the
//! failing case's seed so it can be pinned as a regression. Used by the
//! coordinator invariant tests (rust/tests/prop_coordinator.rs) and
//! kernel/model property tests.

use crate::util::rng::Pcg64;

/// A generator of values from a PRNG.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Pcg64) -> T;
}

impl<T, F: Fn(&mut Pcg64) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Pcg64) -> T {
        self(rng)
    }
}

/// Outcome of a property check over many cases.
#[derive(Debug)]
pub struct PropResult {
    pub cases: usize,
    pub failure: Option<PropFailure>,
}

#[derive(Debug)]
pub struct PropFailure {
    pub case_index: usize,
    pub seed: u64,
    pub message: String,
}

impl PropResult {
    /// Panic with a reproducible report if any case failed.
    pub fn unwrap(self) {
        if let Some(f) = self.failure {
            panic!(
                "property failed at case {} (rerun with seed {:#x}): {}",
                f.case_index, f.seed, f.message
            );
        }
    }
}

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xDEFA_017,
        }
    }
}

/// Run `prop` over `cases` generated inputs. The property returns
/// `Err(message)` to fail. Each case gets an independent, derivable seed.
pub fn check<T>(
    cfg: Config,
    gen: impl Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) -> PropResult {
    let root = Pcg64::new(cfg.seed);
    for i in 0..cfg.cases {
        let case_seed = cfg.seed ^ ((i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = root.stream(i as u64);
        let value = gen.generate(&mut rng);
        if let Err(message) = prop(&value) {
            return PropResult {
                cases: i + 1,
                failure: Some(PropFailure {
                    case_index: i,
                    seed: case_seed,
                    message,
                }),
            };
        }
    }
    PropResult {
        cases: cfg.cases,
        failure: None,
    }
}

/// Common generators.
pub mod gens {
    use crate::util::rng::Pcg64;

    pub fn usize_in(lo: usize, hi: usize) -> impl Fn(&mut Pcg64) -> usize {
        move |rng| lo + rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(lo: f64, hi: f64) -> impl Fn(&mut Pcg64) -> f64 {
        move |rng| rng.uniform_in(lo, hi)
    }

    pub fn u32_in(lo: u32, hi: u32) -> impl Fn(&mut Pcg64) -> u32 {
        move |rng| lo + rng.below((hi - lo + 1) as u64) as u32
    }

    pub fn vec_f64(len: usize, lo: f64, hi: f64) -> impl Fn(&mut Pcg64) -> Vec<f64> {
        move |rng| (0..len).map(|_| rng.uniform_in(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let r = check(
            Config {
                cases: 32,
                seed: 1,
            },
            gens::usize_in(1, 100),
            |&n| {
                if n >= 1 && n <= 100 {
                    Ok(())
                } else {
                    Err(format!("{n} out of range"))
                }
            },
        );
        assert_eq!(r.cases, 32);
        assert!(r.failure.is_none());
        r.unwrap();
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = check(
            Config {
                cases: 100,
                seed: 2,
            },
            gens::usize_in(0, 10),
            |&n| if n < 9 { Ok(()) } else { Err("too big".into()) },
        );
        let f = r.failure.expect("should fail eventually");
        assert!(!f.message.is_empty());
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn unwrap_panics_on_failure() {
        check(
            Config { cases: 5, seed: 3 },
            |_rng: &mut Pcg64| 1usize,
            |_| Err("always".into()),
        )
        .unwrap();
    }

    #[test]
    fn generators_are_deterministic_per_case() {
        use std::sync::Mutex;
        let collect = |seed| {
            let vals = Mutex::new(Vec::new());
            check(
                Config { cases: 10, seed },
                gens::f64_in(0.0, 1.0),
                |&v| {
                    vals.lock().unwrap().push(v);
                    Ok(())
                },
            )
            .unwrap();
            vals.into_inner().unwrap()
        };
        let a = collect(42);
        let b = collect(42);
        assert_eq!(a, b);
        assert_ne!(a, collect(43));
    }
}
