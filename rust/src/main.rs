//! L3 coordinator CLI entrypoint.
fn main() {
    imclim::cli::main();
}
