//! Minimal MLP with softmax-cross-entropy SGD training, built from
//! scratch (offline build: no ML crates). Layer shapes match the AOT
//! `mlp_fwd` artifact: 64 -> 128 -> 64 -> 10 by default.

use crate::util::rng::Pcg64;

use super::Dataset;

#[derive(Clone, Debug)]
pub struct Mlp {
    /// [d0, d1, d2, d3]
    pub dims: Vec<usize>,
    /// Row-major [out, in] per layer.
    pub w: Vec<Vec<f32>>,
    pub b: Vec<Vec<f32>>,
}

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch: usize,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 30,
            batch: 64,
            lr: 0.08,
            momentum: 0.9,
            seed: 7,
        }
    }
}

impl Mlp {
    pub fn new(dims: &[usize], seed: u64) -> Self {
        assert!(dims.len() >= 2);
        let mut rng = Pcg64::new(seed);
        let mut w = Vec::new();
        let mut b = Vec::new();
        for l in 0..dims.len() - 1 {
            let (fan_in, fan_out) = (dims[l], dims[l + 1]);
            let scale = (2.0 / fan_in as f64).sqrt();
            w.push(
                (0..fan_in * fan_out)
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect(),
            );
            b.push(vec![0.0; fan_out]);
        }
        Self {
            dims: dims.to_vec(),
            w,
            b,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.w.len()
    }

    pub fn n_params(&self) -> usize {
        self.w.iter().map(Vec::len).sum::<usize>() + self.b.iter().map(Vec::len).sum::<usize>()
    }

    /// Forward one sample; returns all layer activations (post-ReLU,
    /// logits last). `noise[l]` (if given) is added to layer l's
    /// pre-activation DP outputs — the eq. (6) output-referred injection.
    pub fn forward_noisy(
        &self,
        x: &[f32],
        noise_sigma: &[f32],
        rng: &mut Pcg64,
    ) -> Vec<Vec<f32>> {
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        for l in 0..self.n_layers() {
            let (fan_in, fan_out) = (self.dims[l], self.dims[l + 1]);
            let inp = &acts[l];
            let mut out = vec![0.0f32; fan_out];
            let sigma = noise_sigma.get(l).copied().unwrap_or(0.0);
            for o in 0..fan_out {
                let row = &self.w[l][o * fan_in..(o + 1) * fan_in];
                let mut acc = self.b[l][o];
                for (wi, xi) in row.iter().zip(inp.iter()) {
                    acc += wi * xi;
                }
                if sigma > 0.0 {
                    acc += sigma * rng.normal() as f32;
                }
                if l + 1 < self.n_layers() + 1 && l != self.n_layers() - 1 {
                    acc = acc.max(0.0); // ReLU on hidden layers
                }
                out[o] = acc;
            }
            acts.push(out);
        }
        acts
    }

    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut rng = Pcg64::new(0);
        self.forward_noisy(x, &[], &mut rng).pop().unwrap()
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        argmax(&self.forward(x))
    }

    pub fn accuracy(&self, ds: &Dataset, test: bool) -> f64 {
        let count = if test { ds.test_len() } else { ds.train_len() };
        let mut correct = 0usize;
        for i in 0..count {
            let (x, y) = if test {
                ds.test_sample(i)
            } else {
                ds.train_sample(i)
            };
            if self.predict(x) == y as usize {
                correct += 1;
            }
        }
        correct as f64 / count as f64
    }

    /// SGD with momentum on softmax cross-entropy. Returns per-epoch
    /// (train-loss, test-accuracy) pairs — the logged learning curve.
    pub fn train(&mut self, ds: &Dataset, cfg: &TrainConfig) -> Vec<(f64, f64)> {
        let mut rng = Pcg64::new(cfg.seed);
        let mut vel_w: Vec<Vec<f32>> = self.w.iter().map(|w| vec![0.0; w.len()]).collect();
        let mut vel_b: Vec<Vec<f32>> = self.b.iter().map(|b| vec![0.0; b.len()]).collect();
        let n = ds.train_len();
        let mut order: Vec<usize> = (0..n).collect();
        let mut curve = Vec::new();

        for _epoch in 0..cfg.epochs {
            // Fisher-Yates shuffle
            for i in (1..n).rev() {
                let j = rng.below(i as u64 + 1) as usize;
                order.swap(i, j);
            }
            let mut loss_sum = 0.0f64;
            for chunk in order.chunks(cfg.batch) {
                let mut gw: Vec<Vec<f32>> =
                    self.w.iter().map(|w| vec![0.0; w.len()]).collect();
                let mut gb: Vec<Vec<f32>> =
                    self.b.iter().map(|b| vec![0.0; b.len()]).collect();
                for &idx in chunk {
                    let (x, y) = ds.train_sample(idx);
                    loss_sum += self.backprop(x, y as usize, &mut gw, &mut gb);
                }
                let scale = cfg.lr / chunk.len() as f32;
                for l in 0..self.n_layers() {
                    for (v, g) in vel_w[l].iter_mut().zip(&gw[l]) {
                        *v = cfg.momentum * *v - scale * g;
                    }
                    for (wv, v) in self.w[l].iter_mut().zip(&vel_w[l]) {
                        *wv += v;
                    }
                    for (v, g) in vel_b[l].iter_mut().zip(&gb[l]) {
                        *v = cfg.momentum * *v - scale * g;
                    }
                    for (bv, v) in self.b[l].iter_mut().zip(&vel_b[l]) {
                        *bv += v;
                    }
                }
            }
            curve.push((loss_sum / n as f64, self.accuracy(ds, true)));
        }
        curve
    }

    /// Accumulate gradients for one sample; returns its CE loss.
    fn backprop(
        &self,
        x: &[f32],
        y: usize,
        gw: &mut [Vec<f32>],
        gb: &mut [Vec<f32>],
    ) -> f64 {
        let mut rng = Pcg64::new(0);
        let acts = self.forward_noisy(x, &[], &mut rng);
        let logits = acts.last().unwrap();
        let probs = softmax(logits);
        let loss = -(probs[y].max(1e-12) as f64).ln();

        // delta at output
        let mut delta: Vec<f32> = probs.clone();
        delta[y] -= 1.0;

        for l in (0..self.n_layers()).rev() {
            let (fan_in, fan_out) = (self.dims[l], self.dims[l + 1]);
            let inp = &acts[l];
            for o in 0..fan_out {
                let d = delta[o];
                gb[l][o] += d;
                let row = &mut gw[l][o * fan_in..(o + 1) * fan_in];
                for (g, xi) in row.iter_mut().zip(inp.iter()) {
                    *g += d * xi;
                }
            }
            if l > 0 {
                let mut prev = vec![0.0f32; fan_in];
                for o in 0..fan_out {
                    let d = delta[o];
                    let row = &self.w[l][o * fan_in..(o + 1) * fan_in];
                    for (p, wi) in prev.iter_mut().zip(row.iter()) {
                        *p += d * wi;
                    }
                }
                // ReLU gradient
                for (p, a) in prev.iter_mut().zip(acts[l].iter()) {
                    if *a <= 0.0 {
                        *p = 0.0;
                    }
                }
                delta = prev;
            }
        }
        loss
    }
}

pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / sum).collect()
}

pub fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::DatasetConfig;

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn training_learns_the_task() {
        let ds = Dataset::generate(&DatasetConfig {
            train: 1500,
            test: 500,
            ..Default::default()
        });
        let mut mlp = Mlp::new(&[64, 64, 10], 3);
        let before = mlp.accuracy(&ds, true);
        let curve = mlp.train(
            &ds,
            &TrainConfig {
                epochs: 25,
                lr: 0.15,
                ..Default::default()
            },
        );
        let after = mlp.accuracy(&ds, true);
        assert!(after > 0.80, "accuracy {before} -> {after}, curve {curve:?}");
        // loss decreases
        assert!(curve.last().unwrap().0 < curve[0].0);
    }

    #[test]
    fn param_count() {
        let mlp = Mlp::new(&[64, 128, 64, 10], 1);
        assert_eq!(
            mlp.n_params(),
            64 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10
        );
    }

    #[test]
    fn noise_degrades_predictions() {
        let ds = Dataset::generate(&DatasetConfig {
            train: 800,
            test: 300,
            ..Default::default()
        });
        let mut mlp = Mlp::new(&[64, 32, 10], 3);
        mlp.train(
            &ds,
            &TrainConfig {
                epochs: 6,
                ..Default::default()
            },
        );
        let mut rng = Pcg64::new(11);
        let (x, _) = ds.test_sample(0);
        let clean = mlp.forward(x);
        let noisy = mlp
            .forward_noisy(x, &[50.0, 50.0], &mut rng)
            .pop()
            .unwrap();
        assert_ne!(clean, noisy);
    }
}
