//! Synthetic multi-class dataset generator (stand-in for the paper's
//! image benchmarks): class prototypes on a sphere, per-sample Gaussian
//! jitter, a smooth nonlinear warp so the task is not linearly separable,
//! all mapped into the unsigned activation range [0, 1).

use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct DatasetConfig {
    pub dim: usize,
    pub classes: usize,
    pub train: usize,
    pub test: usize,
    /// Within-class jitter relative to prototype separation.
    pub noise: f64,
    pub seed: u64,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        Self {
            dim: 64,
            classes: 10,
            train: 4096,
            test: 1024,
            noise: 0.25,
            seed: 2024,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<u32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<u32>,
}

impl Dataset {
    pub fn generate(cfg: &DatasetConfig) -> Self {
        let mut rng = Pcg64::new(cfg.seed);
        // class prototypes: unit Gaussian directions
        let protos: Vec<Vec<f64>> = (0..cfg.classes)
            .map(|_| {
                let mut v: Vec<f64> = (0..cfg.dim).map(|_| rng.normal()).collect();
                let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
                v.iter_mut().for_each(|x| *x /= norm);
                v
            })
            .collect();

        let gen = |count: usize, rng: &mut Pcg64| -> (Vec<f32>, Vec<u32>) {
            let mut xs = Vec::with_capacity(count * cfg.dim);
            let mut ys = Vec::with_capacity(count);
            for s in 0..count {
                let c = s % cfg.classes;
                let phase = rng.uniform() * std::f64::consts::TAU;
                for d in 0..cfg.dim {
                    let raw = protos[c][d] + cfg.noise * rng.normal();
                    // smooth nonlinear warp (class-dependent ripple) to
                    // require a hidden layer
                    let warped =
                        raw + 0.25 * (3.0 * raw + phase + c as f64).sin() * cfg.noise;
                    // squash to [0, 1): activations are unsigned (ReLU-like)
                    let squashed = 1.0 / (1.0 + (-2.0 * warped).exp());
                    xs.push(squashed as f32);
                }
                ys.push(c as u32);
            }
            (xs, ys)
        };

        let (train_x, train_y) = gen(cfg.train, &mut rng);
        let (test_x, test_y) = gen(cfg.test, &mut rng);
        Self {
            dim: cfg.dim,
            classes: cfg.classes,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }

    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }

    pub fn train_sample(&self, i: usize) -> (&[f32], u32) {
        (
            &self.train_x[i * self.dim..(i + 1) * self.dim],
            self.train_y[i],
        )
    }

    pub fn test_sample(&self, i: usize) -> (&[f32], u32) {
        (
            &self.test_x[i * self.dim..(i + 1) * self.dim],
            self.test_y[i],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_sizes_and_ranges() {
        let ds = Dataset::generate(&DatasetConfig {
            train: 300,
            test: 100,
            ..Default::default()
        });
        assert_eq!(ds.train_len(), 300);
        assert_eq!(ds.test_len(), 100);
        assert!(ds.train_x.iter().all(|&x| (0.0..1.0).contains(&x)));
        // all classes present
        let mut seen = vec![false; ds.classes];
        for &y in &ds.train_y {
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Dataset::generate(&DatasetConfig::default());
        let b = Dataset::generate(&DatasetConfig::default());
        assert_eq!(a.train_x, b.train_x);
        let c = Dataset::generate(&DatasetConfig {
            seed: 1,
            ..Default::default()
        });
        assert_ne!(a.train_x, c.train_x);
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        // nearest-prototype classification on clean data beats chance by a
        // wide margin (the task carries signal)
        let ds = Dataset::generate(&DatasetConfig {
            train: 1000,
            test: 500,
            ..Default::default()
        });
        // estimate class means from train
        let mut means = vec![vec![0.0f64; ds.dim]; ds.classes];
        let mut counts = vec![0usize; ds.classes];
        for i in 0..ds.train_len() {
            let (x, y) = ds.train_sample(i);
            counts[y as usize] += 1;
            for d in 0..ds.dim {
                means[y as usize][d] += x[d] as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            m.iter_mut().for_each(|v| *v /= c as f64);
        }
        let mut correct = 0;
        for i in 0..ds.test_len() {
            let (x, y) = ds.test_sample(i);
            let best = (0..ds.classes)
                .min_by(|&a, &b| {
                    let da: f64 = (0..ds.dim)
                        .map(|d| (x[d] as f64 - means[a][d]).powi(2))
                        .sum();
                    let db: f64 = (0..ds.dim)
                        .map(|d| (x[d] as f64 - means[b][d]).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == y as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.test_len() as f64;
        assert!(acc > 0.5, "nearest-prototype acc {acc}");
    }
}
