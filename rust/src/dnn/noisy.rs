//! Per-layer noise injection and the Fig. 2 measurement: the minimum
//! per-layer SNR_T at which fixed-point/IMC inference stays within 1% of
//! the floating-point baseline.

use crate::util::rng::Pcg64;
use crate::util::stats::Welford;

use super::{Dataset, Mlp};

#[derive(Clone, Copy, Debug)]
pub struct NoisyEvalConfig {
    /// Monte-Carlo repeats over the test set per SNR point.
    pub repeats: usize,
    pub seed: u64,
}

impl Default for NoisyEvalConfig {
    fn default() -> Self {
        Self {
            repeats: 3,
            seed: 99,
        }
    }
}

/// Per-layer DP-output standard deviations on clean data — the signal
/// power against which an SNR_T target is converted into a noise sigma.
pub fn layer_signal_stds(mlp: &Mlp, ds: &Dataset, samples: usize) -> Vec<f64> {
    let mut stats: Vec<Welford> = (0..mlp.n_layers()).map(|_| Welford::new()).collect();
    let mut rng = Pcg64::new(1);
    let count = samples.min(ds.test_len());
    for i in 0..count {
        let (x, _) = ds.test_sample(i);
        let acts = mlp.forward_noisy(x, &[], &mut rng);
        for l in 0..mlp.n_layers() {
            for &a in &acts[l + 1] {
                stats[l].push(a as f64);
            }
        }
    }
    stats.iter().map(|w| w.std().max(1e-9)).collect()
}

/// Test accuracy with per-layer noise at the given SNR_T targets (dB);
/// `f64::INFINITY` means a clean layer.
pub fn noisy_accuracy(
    mlp: &Mlp,
    ds: &Dataset,
    snr_t_db: &[f64],
    cfg: &NoisyEvalConfig,
) -> f64 {
    let stds = layer_signal_stds(mlp, ds, 256);
    let sigmas: Vec<f32> = snr_t_db
        .iter()
        .zip(&stds)
        .map(|(&snr, &sd)| {
            if snr.is_infinite() {
                0.0
            } else {
                (sd / 10f64.powf(snr / 20.0)) as f32
            }
        })
        .collect();
    let mut rng = Pcg64::new(cfg.seed);
    let mut correct = 0usize;
    let total = ds.test_len() * cfg.repeats;
    for _ in 0..cfg.repeats {
        for i in 0..ds.test_len() {
            let (x, y) = ds.test_sample(i);
            let logits = mlp.forward_noisy(x, &sigmas, &mut rng).pop().unwrap();
            if super::mlp::argmax(&logits) == y as usize {
                correct += 1;
            }
        }
    }
    correct as f64 / total as f64
}

/// Fig. 2: for each layer, the minimum SNR_T (dB) at which accuracy is
/// within `tolerance` (absolute, e.g. 0.01) of the clean baseline, other
/// layers kept clean. Swept over `grid` (ascending dB).
pub fn layer_snr_requirements(
    mlp: &Mlp,
    ds: &Dataset,
    grid: &[f64],
    tolerance: f64,
    cfg: &NoisyEvalConfig,
) -> Vec<f64> {
    let clean = mlp.accuracy(ds, true);
    (0..mlp.n_layers())
        .map(|l| {
            for &snr in grid {
                let mut targets = vec![f64::INFINITY; mlp.n_layers()];
                targets[l] = snr;
                let acc = noisy_accuracy(mlp, ds, &targets, cfg);
                if clean - acc <= tolerance {
                    return snr;
                }
            }
            *grid.last().unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::{DatasetConfig, TrainConfig};

    fn trained() -> (Mlp, Dataset) {
        let ds = Dataset::generate(&DatasetConfig {
            train: 1200,
            test: 400,
            ..Default::default()
        });
        let mut mlp = Mlp::new(&[64, 32, 10], 5);
        mlp.train(
            &ds,
            &TrainConfig {
                epochs: 6,
                ..Default::default()
            },
        );
        (mlp, ds)
    }

    #[test]
    fn high_snr_preserves_accuracy_low_snr_destroys_it() {
        let (mlp, ds) = trained();
        let clean = mlp.accuracy(&ds, true);
        let cfg = NoisyEvalConfig::default();
        let hi = noisy_accuracy(&mlp, &ds, &[40.0, 40.0], &cfg);
        let lo = noisy_accuracy(&mlp, &ds, &[-5.0, -5.0], &cfg);
        assert!(clean - hi < 0.02, "clean={clean} hi={hi}");
        assert!(clean - lo > 0.15, "clean={clean} lo={lo}");
    }

    #[test]
    fn requirements_fall_in_papers_band() {
        // Fig. 2: SNR_T* in the ~10-40 dB band.
        let (mlp, ds) = trained();
        let grid: Vec<f64> = (0..=40).step_by(4).map(|v| v as f64).collect();
        let reqs = layer_snr_requirements(
            &mlp,
            &ds,
            &grid,
            0.01,
            &NoisyEvalConfig::default(),
        );
        assert_eq!(reqs.len(), 2);
        for r in &reqs {
            assert!((0.0..=40.0).contains(r), "{reqs:?}");
        }
    }

    #[test]
    fn signal_stds_positive() {
        let (mlp, ds) = trained();
        for s in layer_signal_stds(&mlp, &ds, 64) {
            assert!(s > 0.0);
        }
    }
}
