//! Fixed-point DNN substrate for the Fig. 2 experiment: per-layer SNR_T
//! requirements of DP computations in a network deployed on an IMC.
//!
//! Substitution (DESIGN.md §1): the paper measures VGG-16 on ImageNet; we
//! train a small MLP on a synthetic multi-class dataset and apply the
//! identical mechanism — output-referred Gaussian noise injected at each
//! layer's DP outputs (lumping q_iy + eta_a + q_y of eq. 6), sweeping the
//! per-layer SNR_T and reporting the level at which accuracy stays within
//! 1% of the floating-point baseline.

pub mod dataset;
pub mod mlp;
pub mod noisy;

pub use dataset::{Dataset, DatasetConfig};
pub use mlp::{Mlp, TrainConfig};
pub use noisy::{
    layer_signal_stds, layer_snr_requirements, noisy_accuracy, NoisyEvalConfig,
};
