//! Frozen scalar Monte-Carlo path: the pre-batching per-trial
//! implementation, kept verbatim as the differential-test oracle for the
//! chunked kernels in `mc::kernels` (see `rust/tests/mc_kernels.rs` and
//! EXPERIMENTS.md §Perf P5).
//!
//! This module is *not* a fallback — the production entry point is
//! [`crate::mc::simulate`]. It exists so every kernel optimization can be
//! pinned against an independent implementation of the same physics:
//! the batched kernels must reproduce this module's ensemble statistics
//! (same distributions, different RNG consumption order), and any drift
//! is a bug in one of the two.
//!
//! Do not optimize this file. Its value is that it stays simple and
//! obviously equal to `python/compile/model.py`.

use crate::arch::pvec;
use crate::util::rng::Pcg64;

use super::{
    adc_signed, adc_unsigned, bank_seed, w_bit, w_code, w_plane_weight, x_bit, x_code, ArchKind,
    InputDist, McOutput,
};

/// Run `trials` strictly sequential scalar trials (pre-chunking
/// semantics: one RNG stream for the whole ensemble, per-bank streams
/// derived with [`bank_seed`] directly off the user seed).
pub fn simulate(
    kind: ArchKind,
    params: &[f64; pvec::P],
    trials: usize,
    seed: u64,
    dist: InputDist,
) -> McOutput {
    let banks = params[pvec::IDX_BANKS] as usize;
    if banks >= 2 {
        let mut bank_params = *params;
        bank_params[pvec::IDX_BANKS] = 0.0;
        let mut out = simulate(kind, &bank_params, trials, bank_seed(seed, 0), dist);
        for b in 1..banks {
            let sub = simulate(kind, &bank_params, trials, bank_seed(seed, b as u64), dist);
            out.add_assign(&sub);
        }
        return out;
    }
    let mut out = McOutput::with_capacity(trials);
    let mut rng = Pcg64::new(seed);
    let n = params[pvec::IDX_N_ACTIVE] as usize;
    let mut x = vec![0.0; n];
    let mut w = vec![0.0; n];
    for _ in 0..trials {
        for v in x.iter_mut() {
            *v = dist.draw_x(&mut rng);
        }
        for v in w.iter_mut() {
            *v = dist.draw_w(&mut rng);
        }
        let r = match kind {
            ArchKind::Qs => qs_trial(params, &x, &w, &mut rng),
            ArchKind::Qr => qr_trial(params, &x, &w, &mut rng),
            ArchKind::Cm => cm_trial(params, &x, &w, &mut rng),
        };
        out.push(r.0, r.1, r.2, r.3);
    }
    out
}

// ---------------------------------------------------------------------
// QS-Arch trial (model.py qs_arch).
// ---------------------------------------------------------------------

fn qs_trial(p: &[f64; pvec::P], x: &[f64], w: &[f64], rng: &mut Pcg64) -> (f64, f64, f64, f64) {
    let n = x.len();
    let bx = p[pvec::IDX_BX] as u32;
    let bw = p[pvec::IDX_BW] as u32;
    let b_adc = p[pvec::IDX_B_ADC];
    let sigma_d = p[pvec::QS_IDX_SIGMA_D];
    let sigma_t = p[pvec::QS_IDX_SIGMA_T];
    let t_rf = p[pvec::QS_IDX_T_RF];
    let sigma_theta = p[pvec::QS_IDX_SIGMA_THETA];
    let k_h = p[pvec::QS_IDX_K_H];
    let v_c = p[pvec::QS_IDX_V_C];
    let correlated = p[pvec::QS_IDX_MODE] >= 0.5;

    let mut y_ideal = 0.0;
    let mut y_fx = 0.0;
    let mut xc = vec![0u32; n];
    let mut wc = vec![0u32; n];
    for k in 0..n {
        y_ideal += x[k] * w[k];
        xc[k] = x_code(x[k], bx);
        wc[k] = w_code(w[k], bw);
        let xq = xc[k] as f64 / (1u32 << bx) as f64;
        let wq = wc[k] as f64 * 2f64.powi(1 - bw as i32) - 1.0;
        y_fx += xq * wq;
    }

    // Optional correlated per-cell noise (mode 1): spatial mismatch fixed
    // across input cycles, pulse jitter shared across weight columns.
    let g_cell: Vec<f64> = if correlated {
        (0..n * bw as usize).map(|_| rng.normal()).collect()
    } else {
        Vec::new()
    };
    let g_pulse: Vec<f64> = if correlated {
        (0..n * bx as usize).map(|_| rng.normal()).collect()
    } else {
        Vec::new()
    };

    let sigma_eff = (sigma_d * sigma_d + sigma_t * sigma_t).sqrt();
    let mut y_a = 0.0;
    let mut y_hat = 0.0;
    for i in 1..=bw {
        let pw = w_plane_weight(bw, i);
        for j in 1..=bx {
            let px = 2f64.powi(-(j as i32));
            let mut count = 0u32;
            let mut noisy = 0.0;
            if correlated {
                for k in 0..n {
                    if w_bit(wc[k], bw, i) & x_bit(xc[k], bx, j) == 1 {
                        count += 1;
                        noisy += sigma_d * g_cell[(i as usize - 1) * n + k]
                            + sigma_t * g_pulse[(j as usize - 1) * n + k];
                    }
                }
            } else {
                for k in 0..n {
                    count += w_bit(wc[k], bw, i) & x_bit(xc[k], bx, j);
                }
            }
            let c = count as f64;
            let mut y_bl = if correlated {
                c + noisy
            } else {
                c + c.sqrt() * sigma_eff * rng.normal()
            };
            y_bl -= t_rf * c;
            let y_cl = y_bl.clamp(0.0, k_h);
            let y_a_bl = y_cl + sigma_theta * rng.normal();
            let y_hat_bl = adc_unsigned(y_a_bl, v_c, b_adc);
            y_a += pw * px * y_a_bl;
            y_hat += pw * px * y_hat_bl;
        }
    }
    (y_ideal, y_fx, y_a, y_hat)
}

// ---------------------------------------------------------------------
// QR-Arch trial (model.py qr_arch).
// ---------------------------------------------------------------------

fn qr_trial(p: &[f64; pvec::P], x: &[f64], w: &[f64], rng: &mut Pcg64) -> (f64, f64, f64, f64) {
    let n = x.len();
    let bx = p[pvec::IDX_BX] as u32;
    let bw = p[pvec::IDX_BW] as u32;
    let b_adc = p[pvec::IDX_B_ADC];
    let sigma_c = p[pvec::QR_IDX_SIGMA_C];
    let inj_a = p[pvec::QR_IDX_INJ_A];
    let inj_b = p[pvec::QR_IDX_INJ_B];
    let sigma_theta = p[pvec::QR_IDX_SIGMA_THETA];
    let v_c = p[pvec::QR_IDX_V_C];
    let v_lo = p[pvec::QR_IDX_V_LO];

    let mut y_ideal = 0.0;
    let mut y_fx = 0.0;
    let mut xq = vec![0.0; n];
    let mut wc = vec![0u32; n];
    for k in 0..n {
        y_ideal += x[k] * w[k];
        xq[k] = x_code(x[k], bx) as f64 / (1u32 << bx) as f64;
        wc[k] = w_code(w[k], bw);
        let wq = wc[k] as f64 * 2f64.powi(1 - bw as i32) - 1.0;
        y_fx += xq[k] * wq;
    }

    // Aggregate noise sampling (EXPERIMENTS.md §Perf P2): 3 draws per
    // row replace ~2N per-cell draws via the jointly-Gaussian (A, B, T)
    // decomposition of the charge-share numerator/denominator.
    let mut y_a = 0.0;
    let mut y_hat = 0.0;
    let nf = n as f64;
    for i in 1..=bw {
        let pw = w_plane_weight(bw, i);
        let mut sum_b = 0.0;
        let mut sum_b2 = 0.0;
        for (k, &xqk) in xq.iter().enumerate() {
            let v = if w_bit(wc[k], bw, i) == 1 { xqk } else { 0.0 };
            let b = v + inj_a - inj_b * v;
            sum_b += b;
            sum_b2 += b * b;
        }
        let big_b = sigma_c * nf.sqrt() * rng.normal();
        let resid_var = (sum_b2 - sum_b * sum_b / nf).max(0.0);
        let big_a = (sum_b / nf) * big_b + sigma_c * resid_var.sqrt() * rng.normal();
        let th_var =
            sigma_theta * sigma_theta * (nf + 2.0 * big_b + nf * sigma_c * sigma_c).max(0.0);
        let big_t = th_var.sqrt() * rng.normal();
        let v_row = (sum_b + big_a + big_t) / (nf + big_b).max(1e-6);
        let v_row_hat = v_lo + adc_unsigned(v_row - v_lo, v_c, b_adc);
        y_a += nf * pw * v_row;
        y_hat += nf * pw * v_row_hat;
    }
    (y_ideal, y_fx, y_a, y_hat)
}

// ---------------------------------------------------------------------
// CM trial (model.py cm_arch; sign-magnitude weights).
// ---------------------------------------------------------------------

fn cm_trial(p: &[f64; pvec::P], x: &[f64], w: &[f64], rng: &mut Pcg64) -> (f64, f64, f64, f64) {
    let n = x.len();
    let bx = p[pvec::IDX_BX] as u32;
    let bw = p[pvec::IDX_BW] as u32;
    let b_adc = p[pvec::IDX_B_ADC];
    let sigma_d = p[pvec::CM_IDX_SIGMA_D];
    let w_h = p[pvec::CM_IDX_W_H];
    let sigma_c = p[pvec::CM_IDX_SIGMA_C];
    let inj_a = p[pvec::CM_IDX_INJ_A];
    let inj_b = p[pvec::CM_IDX_INJ_B];
    let sigma_theta = p[pvec::CM_IDX_SIGMA_THETA];
    let v_c = p[pvec::CM_IDX_V_C];

    let half = (1u32 << (bw - 1)) as f64;
    let mut y_ideal = 0.0;
    let mut y_fx = 0.0;
    // Aggregate sampling (EXPERIMENTS.md §Perf P3): per-column plane
    // mismatch in one draw, then the same (A, B, T) trick as qr_trial.
    let nf = n as f64;
    let mut sum_b = 0.0;
    let mut sum_b2 = 0.0;
    for k in 0..n {
        y_ideal += x[k] * w[k];
        let xqk = x_code(x[k], bx) as f64 / (1u32 << bx) as f64;
        // sign-magnitude code: t in [0, 2^{bw-1})
        let sgn = if w[k] < 0.0 { -1.0 } else { 1.0 };
        let t = ((w[k].abs() * half + 0.5).floor()).min(half - 1.0) as u32;
        let wq = sgn * t as f64 / half;
        y_fx += xqk * wq;

        // analog multi-bit weight: plane mismatch aggregated per column
        let mut mag = 0.0;
        let mut var = 0.0;
        for i in 1..=(bw - 1) {
            if (t >> (bw - 1 - i)) & 1 == 1 {
                let pm = 2f64.powi(-(i as i32));
                mag += pm;
                var += pm * pm;
            }
        }
        let w_eff = sgn * (mag + sigma_d * var.sqrt() * rng.normal());
        let w_cl = w_eff.clamp(-w_h, w_h);
        let u = w_cl * xqk;
        let b = u + inj_a - inj_b * u.abs();
        sum_b += b;
        sum_b2 += b * b;
    }
    let big_b = sigma_c * nf.sqrt() * rng.normal();
    let resid_var = (sum_b2 - sum_b * sum_b / nf).max(0.0);
    let big_a = (sum_b / nf) * big_b + sigma_c * resid_var.sqrt() * rng.normal();
    let th_var = sigma_theta * sigma_theta * (nf + 2.0 * big_b + nf * sigma_c * sigma_c).max(0.0);
    let big_t = th_var.sqrt() * rng.normal();
    let v_out = (sum_b + big_a + big_t) / (nf + big_b).max(1e-6);
    let v_hat = adc_signed(v_out, v_c, b_adc);
    (y_ideal, y_fx, n as f64 * v_out, n as f64 * v_hat)
}
