//! Batched chunk kernels: the production Monte-Carlo trial loops,
//! restructured around a chunk-of-trials layout.
//!
//! Where `mc::reference` re-derives every constant inside the trial (and
//! allocates its code buffers per trial), these kernels build a per-point
//! *plan* once per chunk — plane-weight products, hoisted `2^b` ADC
//! levels/deltas, code scales, the CM per-code magnitude/mismatch table —
//! and reuse one set of scratch buffers across all trials of the chunk.
//! Inner loops are written branch-free over flat slices so LLVM
//! auto-vectorizes the per-cell work (bit-plane extraction, plane
//! counting, masked accumulation); the RNG draw *order within a trial*
//! matches `mc::reference`, so the two paths sample identical
//! distributions and differ only in float-summation association.
//!
//! Measured speedups are recorded in EXPERIMENTS.md §Perf P5 and tracked
//! by the `mc_*` benches (BENCH_mc.json).

use crate::arch::pvec;
use crate::util::rng::Pcg64;

use super::{w_plane_weight, ArchKind, InputDist, McOutput};

/// Run one chunk of `trials` trials on a single-bank parameter vector.
/// Each chunk is one span in the trace ("mc_chunk") and one observation
/// in the `imclim_mc_chunk_seconds` latency histogram — this is the
/// choke point every MC path (scheduler chunk jobs, sequential
/// `simulate`, adaptive rounds, banked sub-ensembles) flows through.
pub(super) fn run_chunk(
    kind: ArchKind,
    params: &[f64; pvec::P],
    trials: usize,
    seed: u64,
    dist: InputDist,
) -> McOutput {
    let _span =
        crate::obs::trace::span_with("mc_chunk", "mc", || format!("{kind:?} {trials} trials"));
    let t0 = std::time::Instant::now();
    let mut out = McOutput::with_capacity(trials);
    let mut rng = Pcg64::new(seed);
    match kind {
        ArchKind::Qs => qs_chunk(params, trials, &mut rng, dist, &mut out),
        ArchKind::Qr => qr_chunk(params, trials, &mut rng, dist, &mut out),
        ArchKind::Cm => cm_chunk(params, trials, &mut rng, dist, &mut out),
    }
    crate::obs::registry::MC_CHUNK_SECONDS.observe(t0.elapsed());
    out
}

/// Mid-tread ADC over [0, range] with hoisted step size.
#[inline]
fn adc_u(v: f64, delta: f64, levels_m1: f64) -> f64 {
    (v / delta).round().clamp(0.0, levels_m1) * delta
}

// ---------------------------------------------------------------------
// QS-Arch chunk (physics of model.py qs_arch; see mc::reference).
// ---------------------------------------------------------------------

fn qs_chunk(
    p: &[f64; pvec::P],
    trials: usize,
    rng: &mut Pcg64,
    dist: InputDist,
    out: &mut McOutput,
) {
    let n = p[pvec::IDX_N_ACTIVE] as usize;
    let bx = p[pvec::IDX_BX] as u32;
    let bw = p[pvec::IDX_BW] as u32;
    let sigma_d = p[pvec::QS_IDX_SIGMA_D];
    let sigma_t = p[pvec::QS_IDX_SIGMA_T];
    let t_rf = p[pvec::QS_IDX_T_RF];
    let sigma_theta = p[pvec::QS_IDX_SIGMA_THETA];
    let k_h = p[pvec::QS_IDX_K_H];
    let correlated = p[pvec::QS_IDX_MODE] >= 0.5;
    let sigma_eff = (sigma_d * sigma_d + sigma_t * sigma_t).sqrt();

    // plan: every power-of-two and plane weight the trial loop needs
    let xs = (1u32 << bx) as f64;
    let inv_xs = 1.0 / xs;
    let w_half = (1u32 << (bw - 1)) as f64;
    let wq_scale = 2f64.powi(1 - bw as i32);
    let levels = 2f64.powf(p[pvec::IDX_B_ADC]);
    let delta = p[pvec::QS_IDX_V_C] / levels;
    let levels_m1 = levels - 1.0;
    let mut pwpx = vec![0.0; (bw * bx) as usize];
    for i in 1..=bw {
        let pw = w_plane_weight(bw, i);
        for j in 1..=bx {
            pwpx[((i - 1) * bx + (j - 1)) as usize] = pw * 2f64.powi(-(j as i32));
        }
    }

    // scratch, reused across all trials of the chunk
    let mut x = vec![0.0; n];
    let mut w = vec![0.0; n];
    let mut xc = vec![0u32; n];
    let mut wc = vec![0u32; n];
    let mut xb = vec![0u8; bx as usize * n];
    let mut wb = vec![0u8; bw as usize * n];
    let (mut g_cell, mut g_pulse) = if correlated {
        (vec![0.0; n * bw as usize], vec![0.0; n * bx as usize])
    } else {
        (Vec::new(), Vec::new())
    };

    for _ in 0..trials {
        for v in x.iter_mut() {
            *v = dist.draw_x(rng);
        }
        for v in w.iter_mut() {
            *v = dist.draw_w(rng);
        }
        let mut y_ideal = 0.0;
        let mut y_fx = 0.0;
        for k in 0..n {
            y_ideal += x[k] * w[k];
            let xcode = (x[k] * xs + 0.5).floor().clamp(0.0, xs - 1.0);
            let wcode = ((w[k] + 1.0) * w_half + 0.5).floor().clamp(0.0, 2.0 * w_half - 1.0);
            xc[k] = xcode as u32;
            wc[k] = wcode as u32;
            y_fx += (xcode * inv_xs) * (wcode * wq_scale - 1.0);
        }

        // trial-local 0/1 bit-plane rows (plane-major over cells): the
        // count below becomes a pure u8 AND-reduction over contiguous
        // rows. NOTE (EXPERIMENTS.md §Perf P4, reverted): a bit-*packed*
        // AND+popcount formulation measured 3.5x slower than letting
        // LLVM vectorize these byte rows — the mask-packing pass
        // defeated the vectorizer.
        for j in 1..=bx {
            let shift = bx - j;
            let row = &mut xb[(j - 1) as usize * n..][..n];
            for (r, &c) in row.iter_mut().zip(xc.iter()) {
                *r = ((c >> shift) & 1) as u8;
            }
        }
        for i in 1..=bw {
            let shift = bw - i;
            let comp = u32::from(i == 1); // sign plane is complemented
            let row = &mut wb[(i - 1) as usize * n..][..n];
            for (r, &c) in row.iter_mut().zip(wc.iter()) {
                *r = (((c >> shift) & 1) ^ comp) as u8;
            }
        }

        if correlated {
            // spatial mismatch fixed across input cycles, pulse jitter
            // shared across weight columns (same draw order as reference)
            for g in g_cell.iter_mut() {
                *g = rng.normal();
            }
            for g in g_pulse.iter_mut() {
                *g = rng.normal();
            }
        }

        let mut y_a = 0.0;
        let mut y_hat = 0.0;
        for i in 1..=bw {
            let wrow = &wb[(i - 1) as usize * n..][..n];
            for j in 1..=bx {
                let xrow = &xb[(j - 1) as usize * n..][..n];
                let pwx = pwpx[((i - 1) * bx + (j - 1)) as usize];
                let (c, noisy) = if correlated {
                    let gc = &g_cell[(i - 1) as usize * n..][..n];
                    let gp = &g_pulse[(j - 1) as usize * n..][..n];
                    let mut count = 0u32;
                    let mut noisy = 0.0;
                    for k in 0..n {
                        if wrow[k] & xrow[k] == 1 {
                            count += 1;
                            noisy += sigma_d * gc[k] + sigma_t * gp[k];
                        }
                    }
                    (count as f64, noisy)
                } else {
                    let count: u32 =
                        wrow.iter().zip(xrow).map(|(a, b)| u32::from(a & b)).sum();
                    (count as f64, 0.0)
                };
                let mut y_bl = if correlated {
                    c + noisy
                } else {
                    c + c.sqrt() * sigma_eff * rng.normal()
                };
                y_bl -= t_rf * c;
                let y_cl = y_bl.clamp(0.0, k_h);
                let y_a_bl = y_cl + sigma_theta * rng.normal();
                let y_hat_bl = adc_u(y_a_bl, delta, levels_m1);
                y_a += pwx * y_a_bl;
                y_hat += pwx * y_hat_bl;
            }
        }
        out.push(y_ideal, y_fx, y_a, y_hat);
    }
}

// ---------------------------------------------------------------------
// QR-Arch chunk (aggregate (A, B, T) sampling, EXPERIMENTS.md §Perf P2).
// ---------------------------------------------------------------------

fn qr_chunk(
    p: &[f64; pvec::P],
    trials: usize,
    rng: &mut Pcg64,
    dist: InputDist,
    out: &mut McOutput,
) {
    let n = p[pvec::IDX_N_ACTIVE] as usize;
    let bx = p[pvec::IDX_BX] as u32;
    let bw = p[pvec::IDX_BW] as u32;
    let sigma_c = p[pvec::QR_IDX_SIGMA_C];
    let inj_a = p[pvec::QR_IDX_INJ_A];
    let inj_b = p[pvec::QR_IDX_INJ_B];
    let sigma_theta = p[pvec::QR_IDX_SIGMA_THETA];
    let v_lo = p[pvec::QR_IDX_V_LO];

    let xs = (1u32 << bx) as f64;
    let inv_xs = 1.0 / xs;
    let w_half = (1u32 << (bw - 1)) as f64;
    let wq_scale = 2f64.powi(1 - bw as i32);
    let levels = 2f64.powf(p[pvec::IDX_B_ADC]);
    let delta = p[pvec::QR_IDX_V_C] / levels;
    let levels_m1 = levels - 1.0;
    let nf = n as f64;
    let sqrt_n = nf.sqrt();
    let th2_base = sigma_theta * sigma_theta;
    // nf * pw hoisted per plane (exact: nf integer-valued, pw = ±2^k)
    let pw_nf: Vec<f64> = (1..=bw).map(|i| nf * w_plane_weight(bw, i)).collect();

    let mut x = vec![0.0; n];
    let mut w = vec![0.0; n];
    let mut xq = vec![0.0; n];
    let mut wc = vec![0u32; n];

    for _ in 0..trials {
        for v in x.iter_mut() {
            *v = dist.draw_x(rng);
        }
        for v in w.iter_mut() {
            *v = dist.draw_w(rng);
        }
        let mut y_ideal = 0.0;
        let mut y_fx = 0.0;
        for k in 0..n {
            y_ideal += x[k] * w[k];
            let xcode = (x[k] * xs + 0.5).floor().clamp(0.0, xs - 1.0);
            let wcode = ((w[k] + 1.0) * w_half + 0.5).floor().clamp(0.0, 2.0 * w_half - 1.0);
            xq[k] = xcode * inv_xs;
            wc[k] = wcode as u32;
            y_fx += xq[k] * (wcode * wq_scale - 1.0);
        }

        let mut y_a = 0.0;
        let mut y_hat = 0.0;
        for i in 1..=bw {
            let shift = bw - i;
            let comp = u32::from(i == 1);
            // masked per-row sums in 4 independent lanes so the f64
            // reduction vectorizes (association differs from reference
            // by design; ensemble-equivalence is pinned in tests)
            let mut sb = [0.0f64; 4];
            let mut sb2 = [0.0f64; 4];
            let whole = n - n % 4;
            for k in (0..whole).step_by(4) {
                for l in 0..4 {
                    let m = f64::from(((wc[k + l] >> shift) & 1) ^ comp);
                    let v = m * xq[k + l];
                    let b = v + inj_a - inj_b * v;
                    sb[l] += b;
                    sb2[l] += b * b;
                }
            }
            for k in whole..n {
                let m = f64::from(((wc[k] >> shift) & 1) ^ comp);
                let v = m * xq[k];
                let b = v + inj_a - inj_b * v;
                sb[0] += b;
                sb2[0] += b * b;
            }
            let sum_b = (sb[0] + sb[1]) + (sb[2] + sb[3]);
            let sum_b2 = (sb2[0] + sb2[1]) + (sb2[2] + sb2[3]);

            let big_b = sigma_c * sqrt_n * rng.normal();
            let resid_var = (sum_b2 - sum_b * sum_b / nf).max(0.0);
            let big_a = (sum_b / nf) * big_b + sigma_c * resid_var.sqrt() * rng.normal();
            let th_var = th2_base * (nf + 2.0 * big_b + nf * sigma_c * sigma_c).max(0.0);
            let big_t = th_var.sqrt() * rng.normal();
            let v_row = (sum_b + big_a + big_t) / (nf + big_b).max(1e-6);
            let v_row_hat = v_lo + adc_u(v_row - v_lo, delta, levels_m1);
            y_a += pw_nf[(i - 1) as usize] * v_row;
            y_hat += pw_nf[(i - 1) as usize] * v_row_hat;
        }
        out.push(y_ideal, y_fx, y_a, y_hat);
    }
}

// ---------------------------------------------------------------------
// CM chunk (per-code magnitude/mismatch table, EXPERIMENTS.md §Perf P3).
// ---------------------------------------------------------------------

fn cm_chunk(
    p: &[f64; pvec::P],
    trials: usize,
    rng: &mut Pcg64,
    dist: InputDist,
    out: &mut McOutput,
) {
    let n = p[pvec::IDX_N_ACTIVE] as usize;
    let bx = p[pvec::IDX_BX] as u32;
    let bw = p[pvec::IDX_BW] as u32;
    let sigma_d = p[pvec::CM_IDX_SIGMA_D];
    let w_h = p[pvec::CM_IDX_W_H];
    let sigma_c = p[pvec::CM_IDX_SIGMA_C];
    let inj_a = p[pvec::CM_IDX_INJ_A];
    let inj_b = p[pvec::CM_IDX_INJ_B];
    let sigma_theta = p[pvec::CM_IDX_SIGMA_THETA];

    let xs = (1u32 << bx) as f64;
    let inv_xs = 1.0 / xs;
    let half = (1u32 << (bw - 1)) as f64;
    let inv_half = 1.0 / half;
    // signed mid-tread ADC over [-v_c, v_c], hoisted
    let levels = 2f64.powf(p[pvec::IDX_B_ADC]);
    let delta = 2.0 * p[pvec::CM_IDX_V_C] / levels;
    let clamp_lo = -levels / 2.0;
    let clamp_hi = levels / 2.0 - 1.0;
    let nf = n as f64;
    let sqrt_n = nf.sqrt();
    let th2_base = sigma_theta * sigma_theta;

    // per-code plane table: magnitude and aggregated mismatch sigma of
    // every sign-magnitude code t (<= 2^{B_MAX-1} = 128 entries), so the
    // per-cell plane loop of the reference becomes two table lookups
    let codes = 1usize << (bw - 1);
    let mut mag_lut = vec![0.0; codes];
    let mut vsq_lut = vec![0.0; codes];
    for (t, (m, v)) in mag_lut.iter_mut().zip(vsq_lut.iter_mut()).enumerate() {
        let mut mag = 0.0;
        let mut var = 0.0;
        for i in 1..=(bw - 1) {
            if (t >> (bw - 1 - i)) & 1 == 1 {
                let pm = 2f64.powi(-(i as i32));
                mag += pm;
                var += pm * pm;
            }
        }
        *m = mag;
        *v = var.sqrt();
    }

    let mut x = vec![0.0; n];
    let mut w = vec![0.0; n];

    for _ in 0..trials {
        for v in x.iter_mut() {
            *v = dist.draw_x(rng);
        }
        for v in w.iter_mut() {
            *v = dist.draw_w(rng);
        }
        let mut y_ideal = 0.0;
        let mut y_fx = 0.0;
        let mut sum_b = 0.0;
        let mut sum_b2 = 0.0;
        for k in 0..n {
            y_ideal += x[k] * w[k];
            let xqk = (x[k] * xs + 0.5).floor().clamp(0.0, xs - 1.0) * inv_xs;
            let sgn = if w[k] < 0.0 { -1.0 } else { 1.0 };
            let t = ((w[k].abs() * half + 0.5).floor()).min(half - 1.0) as usize;
            y_fx += xqk * (sgn * t as f64 * inv_half);

            let w_eff = sgn * (mag_lut[t] + sigma_d * vsq_lut[t] * rng.normal());
            let w_cl = w_eff.clamp(-w_h, w_h);
            let u = w_cl * xqk;
            let b = u + inj_a - inj_b * u.abs();
            sum_b += b;
            sum_b2 += b * b;
        }
        let big_b = sigma_c * sqrt_n * rng.normal();
        let resid_var = (sum_b2 - sum_b * sum_b / nf).max(0.0);
        let big_a = (sum_b / nf) * big_b + sigma_c * resid_var.sqrt() * rng.normal();
        let th_var = th2_base * (nf + 2.0 * big_b + nf * sigma_c * sigma_c).max(0.0);
        let big_t = th_var.sqrt() * rng.normal();
        let v_out = (sum_b + big_a + big_t) / (nf + big_b).max(1e-6);
        let v_hat = (v_out / delta).round().clamp(clamp_lo, clamp_hi) * delta;
        out.push(y_ideal, y_fx, nf * v_out, nf * v_hat);
    }
}
