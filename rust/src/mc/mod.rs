//! Native sample-accurate Monte-Carlo simulator (Fig. 8 methodology).
//!
//! Mirrors `python/compile/model.py` bit-for-bit in structure: identical
//! quantizers, bit-slicing, noise injection points, clipping and ADC
//! models, driven by the *same* normalized parameter vector
//! (`arch::pvec`). It serves three roles:
//!
//! 1. Cross-check oracle for the PJRT/Pallas path (integration tests
//!    assert ensemble-statistical agreement).
//! 2. Validation target for the Table III closed forms (E-vs-S curves).
//! 3. Fallback/base implementation when artifacts are not built.

mod measure;
pub use measure::{measure, MeasuredSnr, SnrAccumulator};

use crate::arch::pvec;
use crate::util::rng::Pcg64;

pub const B_MAX: usize = 8;

/// Which architecture a parameter vector drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArchKind {
    Qs,
    Qr,
    Cm,
}

impl ArchKind {
    pub fn artifact_name(&self) -> &'static str {
        match self {
            ArchKind::Qs => "qs_arch",
            ArchKind::Qr => "qr_arch",
            ArchKind::Cm => "cm_arch",
        }
    }
}

/// Input distributions for the MC ensembles. The paper draws unsigned
/// activations and zero-mean signed weights from two distributions
/// (Sec. V-A); uniform is the default used in Sec. III-E.
#[derive(Clone, Copy, Debug)]
pub enum InputDist {
    /// x ~ U[0,1), w ~ U[-1,1).
    Uniform,
    /// x ~ |N(0, sx)| clipped to [0,1), w ~ N(0, sw) clipped to [-1,1).
    ClippedGaussian { sx: f64, sw: f64 },
}

impl InputDist {
    fn draw_x(&self, rng: &mut Pcg64) -> f64 {
        match self {
            InputDist::Uniform => rng.uniform(),
            InputDist::ClippedGaussian { sx, .. } => {
                (rng.normal().abs() * sx).min(0.999_999)
            }
        }
    }

    fn draw_w(&self, rng: &mut Pcg64) -> f64 {
        match self {
            InputDist::Uniform => rng.uniform_in(-1.0, 1.0),
            InputDist::ClippedGaussian { sw, .. } => {
                (rng.normal() * sw).clamp(-0.999_999, 0.999_999)
            }
        }
    }
}

/// One MC ensemble: the four output streams of eq. (6)'s decomposition.
#[derive(Clone, Debug, Default)]
pub struct McOutput {
    pub y_ideal: Vec<f64>,
    pub y_fx: Vec<f64>,
    pub y_a: Vec<f64>,
    pub y_hat: Vec<f64>,
}

impl McOutput {
    /// Preallocate all four streams for `trials` entries, so the
    /// per-trial accumulate path never reallocates.
    pub fn with_capacity(trials: usize) -> Self {
        Self {
            y_ideal: Vec::with_capacity(trials),
            y_fx: Vec::with_capacity(trials),
            y_a: Vec::with_capacity(trials),
            y_hat: Vec::with_capacity(trials),
        }
    }

    pub fn len(&self) -> usize {
        self.y_ideal.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y_ideal.is_empty()
    }

    pub fn push(&mut self, yi: f64, yfx: f64, ya: f64, yh: f64) {
        self.y_ideal.push(yi);
        self.y_fx.push(yfx);
        self.y_a.push(ya);
        self.y_hat.push(yh);
    }

    pub fn extend(&mut self, other: &McOutput) {
        self.y_ideal.extend_from_slice(&other.y_ideal);
        self.y_fx.extend_from_slice(&other.y_fx);
        self.y_a.extend_from_slice(&other.y_a);
        self.y_hat.extend_from_slice(&other.y_hat);
    }
}

/// Derive the RNG seed of one bank's sub-ensemble: a SplitMix64-style
/// odd-constant mix (offset by one so even bank 0 moves off the raw
/// seed) keeps bank streams disjoint from each other *and* from a
/// single-bank run at the same user seed.
fn bank_seed(seed: u64, bank: u64) -> u64 {
    seed.wrapping_add((bank + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Run `trials` Monte-Carlo trials of the given architecture.
///
/// A parameter vector with `pvec::IDX_BANKS >= 2` describes a banked DP
/// (Sec. VI): the arch-specific slots are *per-bank* (slot 0 holds the
/// per-bank row count), and the banked ensemble is the per-trial sum of
/// `banks` independent per-bank ensembles — partial DPs digitized per
/// bank and recombined digitally, exactly the `arch::Banked` closed
/// form's decomposition. Slot values 0.0 and 1.0 both mean single-bank
/// (0.0 is the legacy encoding that keeps existing cache keys).
pub fn simulate(
    kind: ArchKind,
    params: &[f64; pvec::P],
    trials: usize,
    seed: u64,
    dist: InputDist,
) -> McOutput {
    let banks = params[pvec::IDX_BANKS] as usize;
    if banks >= 2 {
        let mut bank_params = *params;
        bank_params[pvec::IDX_BANKS] = 0.0;
        let mut out = simulate(kind, &bank_params, trials, bank_seed(seed, 0), dist);
        for b in 1..banks {
            let sub = simulate(kind, &bank_params, trials, bank_seed(seed, b as u64), dist);
            for (acc, v) in out.y_ideal.iter_mut().zip(&sub.y_ideal) {
                *acc += v;
            }
            for (acc, v) in out.y_fx.iter_mut().zip(&sub.y_fx) {
                *acc += v;
            }
            for (acc, v) in out.y_a.iter_mut().zip(&sub.y_a) {
                *acc += v;
            }
            for (acc, v) in out.y_hat.iter_mut().zip(&sub.y_hat) {
                *acc += v;
            }
        }
        return out;
    }
    let mut out = McOutput::with_capacity(trials);
    let mut rng = Pcg64::new(seed);
    let n = params[pvec::IDX_N_ACTIVE] as usize;
    let mut x = vec![0.0; n];
    let mut w = vec![0.0; n];
    for _ in 0..trials {
        for v in x.iter_mut() {
            *v = dist.draw_x(&mut rng);
        }
        for v in w.iter_mut() {
            *v = dist.draw_w(&mut rng);
        }
        let r = match kind {
            ArchKind::Qs => qs_trial(params, &x, &w, &mut rng),
            ArchKind::Qr => qr_trial(params, &x, &w, &mut rng),
            ArchKind::Cm => cm_trial(params, &x, &w, &mut rng),
        };
        out.push(r.0, r.1, r.2, r.3);
    }
    out
}

// ---------------------------------------------------------------------
// Shared bit-slicing (mirrors model.py unsigned_bits / signed_bits /
// signed_mag_bits, round-to-nearest).
// ---------------------------------------------------------------------

/// Unsigned activation code t in [0, 2^bx) and value t/2^bx.
#[inline]
fn x_code(x: f64, bx: u32) -> u32 {
    let s = (1u32 << bx) as f64;
    ((x * s + 0.5).floor().clamp(0.0, s - 1.0)) as u32
}

/// Two's-complement weight code t in [0, 2^bw); value t*2^{1-bw} - 1.
#[inline]
fn w_code(w: f64, bw: u32) -> u32 {
    let half = (1u32 << (bw - 1)) as f64;
    (((w + 1.0) * half + 0.5).floor().clamp(0.0, 2.0 * half - 1.0)) as u32
}

/// Input plane bit (plane j = 1..bx holds weight 2^-j).
#[inline]
fn x_bit(code: u32, bx: u32, j: u32) -> u32 {
    if j > bx {
        0
    } else {
        (code >> (bx - j)) & 1
    }
}

/// Weight plane bit with complemented sign plane (plane 1).
#[inline]
fn w_bit(code: u32, bw: u32, i: u32) -> u32 {
    if i > bw {
        return 0;
    }
    let raw = (code >> (bw - i)) & 1;
    if i == 1 {
        1 - raw
    } else {
        raw
    }
}

/// Weight plane recombination weights pw: [-1, 2^-1, ..., 2^{2-bw}].
#[inline]
fn w_plane_weight(bw: u32, i: u32) -> f64 {
    if i > bw {
        0.0
    } else if i == 1 {
        -1.0
    } else {
        2f64.powi(1 - i as i32)
    }
}

/// Mid-tread ADC over [0, range].
#[inline]
fn adc_unsigned(v: f64, range: f64, b: f64) -> f64 {
    let levels = 2f64.powf(b);
    let delta = range / levels;
    (v / delta).round().clamp(0.0, levels - 1.0) * delta
}

/// Mid-tread ADC over [-range, range].
#[inline]
fn adc_signed(v: f64, range: f64, b: f64) -> f64 {
    let levels = 2f64.powf(b);
    let delta = 2.0 * range / levels;
    (v / delta).round().clamp(-levels / 2.0, levels / 2.0 - 1.0) * delta
}

// ---------------------------------------------------------------------
// QS-Arch trial (model.py qs_arch).
// ---------------------------------------------------------------------

fn qs_trial(p: &[f64; pvec::P], x: &[f64], w: &[f64], rng: &mut Pcg64) -> (f64, f64, f64, f64) {
    let n = x.len();
    let bx = p[pvec::IDX_BX] as u32;
    let bw = p[pvec::IDX_BW] as u32;
    let b_adc = p[pvec::IDX_B_ADC];
    let sigma_d = p[pvec::QS_IDX_SIGMA_D];
    let sigma_t = p[pvec::QS_IDX_SIGMA_T];
    let t_rf = p[pvec::QS_IDX_T_RF];
    let sigma_theta = p[pvec::QS_IDX_SIGMA_THETA];
    let k_h = p[pvec::QS_IDX_K_H];
    let v_c = p[pvec::QS_IDX_V_C];
    let correlated = p[pvec::QS_IDX_MODE] >= 0.5;

    let mut y_ideal = 0.0;
    let mut y_fx = 0.0;
    let mut xc = vec![0u32; n];
    let mut wc = vec![0u32; n];
    for k in 0..n {
        y_ideal += x[k] * w[k];
        xc[k] = x_code(x[k], bx);
        wc[k] = w_code(w[k], bw);
        let xq = xc[k] as f64 / (1u32 << bx) as f64;
        let wq = wc[k] as f64 * 2f64.powi(1 - bw as i32) - 1.0;
        y_fx += xq * wq;
    }

    // Optional correlated per-cell noise (mode 1): spatial mismatch fixed
    // across input cycles, pulse jitter shared across weight columns.
    let g_cell: Vec<f64> = if correlated {
        (0..n * bw as usize).map(|_| rng.normal()).collect()
    } else {
        Vec::new()
    };
    let g_pulse: Vec<f64> = if correlated {
        (0..n * bx as usize).map(|_| rng.normal()).collect()
    } else {
        Vec::new()
    };

    // NOTE (EXPERIMENTS.md §Perf P4, reverted): a bit-packed AND+popcount
    // formulation of the plane counts measured 3.5x *slower* than this
    // plain per-cell loop — LLVM auto-vectorizes the shift/mask reduction
    // over k, and the branchy mask-building pass defeated it.
    let sigma_eff = (sigma_d * sigma_d + sigma_t * sigma_t).sqrt();
    let mut y_a = 0.0;
    let mut y_hat = 0.0;
    for i in 1..=bw {
        let pw = w_plane_weight(bw, i);
        for j in 1..=bx {
            let px = 2f64.powi(-(j as i32));
            let mut count = 0u32;
            let mut noisy = 0.0;
            if correlated {
                for k in 0..n {
                    if w_bit(wc[k], bw, i) & x_bit(xc[k], bx, j) == 1 {
                        count += 1;
                        noisy += sigma_d * g_cell[(i as usize - 1) * n + k]
                            + sigma_t * g_pulse[(j as usize - 1) * n + k];
                    }
                }
            } else {
                for k in 0..n {
                    count += w_bit(wc[k], bw, i) & x_bit(xc[k], bx, j);
                }
            }
            let c = count as f64;
            let mut y_bl = if correlated {
                c + noisy
            } else {
                c + c.sqrt() * sigma_eff * rng.normal()
            };
            y_bl -= t_rf * c;
            let y_cl = y_bl.clamp(0.0, k_h);
            let y_a_bl = y_cl + sigma_theta * rng.normal();
            let y_hat_bl = adc_unsigned(y_a_bl, v_c, b_adc);
            y_a += pw * px * y_a_bl;
            y_hat += pw * px * y_hat_bl;
        }
    }
    (y_ideal, y_fx, y_a, y_hat)
}

// ---------------------------------------------------------------------
// QR-Arch trial (model.py qr_arch).
// ---------------------------------------------------------------------

fn qr_trial(p: &[f64; pvec::P], x: &[f64], w: &[f64], rng: &mut Pcg64) -> (f64, f64, f64, f64) {
    let n = x.len();
    let bx = p[pvec::IDX_BX] as u32;
    let bw = p[pvec::IDX_BW] as u32;
    let b_adc = p[pvec::IDX_B_ADC];
    let sigma_c = p[pvec::QR_IDX_SIGMA_C];
    let inj_a = p[pvec::QR_IDX_INJ_A];
    let inj_b = p[pvec::QR_IDX_INJ_B];
    let sigma_theta = p[pvec::QR_IDX_SIGMA_THETA];
    let v_c = p[pvec::QR_IDX_V_C];
    let v_lo = p[pvec::QR_IDX_V_LO];

    let mut y_ideal = 0.0;
    let mut y_fx = 0.0;
    let mut xq = vec![0.0; n];
    let mut wc = vec![0u32; n];
    for k in 0..n {
        y_ideal += x[k] * w[k];
        xq[k] = x_code(x[k], bx) as f64 / (1u32 << bx) as f64;
        wc[k] = w_code(w[k], bw);
        let wq = wc[k] as f64 * 2f64.powi(1 - bw as i32) - 1.0;
        y_fx += xq[k] * wq;
    }

    // Aggregate noise sampling (EXPERIMENTS.md §Perf P2): with
    // b_k = v_k + inj_k deterministic given the data, the charge-share
    // numerator/denominator pair
    //   num = sum (1 + c_k)(b_k + th_k),   den = sum (1 + c_k)
    // is jointly Gaussian given the data:
    //   B = sum c_k            ~ N(0, sigma_c^2 n)
    //   A = sum c_k b_k        ~ N(0, sigma_c^2 sum b^2), Cov = sigma_c^2 sum b
    //   T = sum (1 + c_k) th_k ~ N(0, sigma_th^2 (n + 2B + n sigma_c^2)) | B
    // so 3 draws per row replace ~2N per-cell draws, distributionally
    // exact up to the O(sigma_th^2 sigma_c^2) concentration of sum c^2.
    let mut y_a = 0.0;
    let mut y_hat = 0.0;
    let nf = n as f64;
    for i in 1..=bw {
        let pw = w_plane_weight(bw, i);
        let mut sum_b = 0.0;
        let mut sum_b2 = 0.0;
        for (k, &xqk) in xq.iter().enumerate() {
            let v = if w_bit(wc[k], bw, i) == 1 { xqk } else { 0.0 };
            let b = v + inj_a - inj_b * v;
            sum_b += b;
            sum_b2 += b * b;
        }
        let big_b = sigma_c * nf.sqrt() * rng.normal();
        let resid_var = (sum_b2 - sum_b * sum_b / nf).max(0.0);
        let big_a = (sum_b / nf) * big_b + sigma_c * resid_var.sqrt() * rng.normal();
        let th_var = sigma_theta * sigma_theta
            * (nf + 2.0 * big_b + nf * sigma_c * sigma_c).max(0.0);
        let big_t = th_var.sqrt() * rng.normal();
        let v_row = (sum_b + big_a + big_t) / (nf + big_b).max(1e-6);
        let v_row_hat = v_lo + adc_unsigned(v_row - v_lo, v_c, b_adc);
        y_a += nf * pw * v_row;
        y_hat += nf * pw * v_row_hat;
    }
    (y_ideal, y_fx, y_a, y_hat)
}

// ---------------------------------------------------------------------
// CM trial (model.py cm_arch; sign-magnitude weights).
// ---------------------------------------------------------------------

fn cm_trial(p: &[f64; pvec::P], x: &[f64], w: &[f64], rng: &mut Pcg64) -> (f64, f64, f64, f64) {
    let n = x.len();
    let bx = p[pvec::IDX_BX] as u32;
    let bw = p[pvec::IDX_BW] as u32;
    let b_adc = p[pvec::IDX_B_ADC];
    let sigma_d = p[pvec::CM_IDX_SIGMA_D];
    let w_h = p[pvec::CM_IDX_W_H];
    let sigma_c = p[pvec::CM_IDX_SIGMA_C];
    let inj_a = p[pvec::CM_IDX_INJ_A];
    let inj_b = p[pvec::CM_IDX_INJ_B];
    let sigma_theta = p[pvec::CM_IDX_SIGMA_THETA];
    let v_c = p[pvec::CM_IDX_V_C];

    let half = (1u32 << (bw - 1)) as f64;
    let mut y_ideal = 0.0;
    let mut y_fx = 0.0;
    // Aggregate sampling (EXPERIMENTS.md §Perf P3): the per-plane
    // mismatch of a column sums to N(0, sigma_d^2 sum_i pm_i^2 mb_i) —
    // one draw per column; clipping is applied after, exactly as in the
    // per-plane formulation. The QR aggregation stage uses the same
    // correlated (A, B, T) trick as qr_trial.
    let nf = n as f64;
    let mut sum_b = 0.0;
    let mut sum_b2 = 0.0;
    for k in 0..n {
        y_ideal += x[k] * w[k];
        let xqk = x_code(x[k], bx) as f64 / (1u32 << bx) as f64;
        // sign-magnitude code: t in [0, 2^{bw-1})
        let sgn = if w[k] < 0.0 { -1.0 } else { 1.0 };
        let t = ((w[k].abs() * half + 0.5).floor()).min(half - 1.0) as u32;
        let wq = sgn * t as f64 / half;
        y_fx += xqk * wq;

        // analog multi-bit weight: plane mismatch aggregated per column
        let mut mag = 0.0;
        let mut var = 0.0;
        for i in 1..=(bw - 1) {
            if (t >> (bw - 1 - i)) & 1 == 1 {
                let pm = 2f64.powi(-(i as i32));
                mag += pm;
                var += pm * pm;
            }
        }
        let w_eff = sgn * (mag + sigma_d * var.sqrt() * rng.normal());
        let w_cl = w_eff.clamp(-w_h, w_h);
        let u = w_cl * xqk;
        let b = u + inj_a - inj_b * u.abs();
        sum_b += b;
        sum_b2 += b * b;
    }
    let big_b = sigma_c * nf.sqrt() * rng.normal();
    let resid_var = (sum_b2 - sum_b * sum_b / nf).max(0.0);
    let big_a = (sum_b / nf) * big_b + sigma_c * resid_var.sqrt() * rng.normal();
    let th_var = sigma_theta * sigma_theta
        * (nf + 2.0 * big_b + nf * sigma_c * sigma_c).max(0.0);
    let big_t = th_var.sqrt() * rng.normal();
    let v_out = (sum_b + big_a + big_t) / (nf + big_b).max(1e-6);
    let v_hat = adc_signed(v_out, v_c, b_adc);
    (y_ideal, y_fx, n as f64 * v_out, n as f64 * v_hat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pvec;

    fn base_params(n: usize, bx: u32, bw: u32) -> [f64; pvec::P] {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = n as f64;
        p[pvec::IDX_BX] = bx as f64;
        p[pvec::IDX_BW] = bw as f64;
        p[pvec::IDX_B_ADC] = 14.0;
        p
    }

    #[test]
    fn qs_noiseless_equals_fixed_point() {
        let mut p = base_params(100, 6, 6);
        p[pvec::QS_IDX_K_H] = 1e9;
        p[pvec::QS_IDX_V_C] = 200.0;
        let out = simulate(ArchKind::Qs, &p, 64, 1, InputDist::Uniform);
        for i in 0..out.len() {
            assert!((out.y_a[i] - out.y_fx[i]).abs() < 1e-9);
            assert!((out.y_hat[i] - out.y_a[i]).abs() < 0.02);
        }
    }

    #[test]
    fn qr_noiseless_equals_fixed_point() {
        let mut p = base_params(128, 6, 7);
        p[pvec::QR_IDX_V_C] = 1.0;
        let out = simulate(ArchKind::Qr, &p, 64, 2, InputDist::Uniform);
        for i in 0..out.len() {
            assert!((out.y_a[i] - out.y_fx[i]).abs() < 1e-9);
            assert!((out.y_hat[i] - out.y_a[i]).abs() < 0.05);
        }
    }

    #[test]
    fn cm_noiseless_equals_fixed_point() {
        let mut p = base_params(64, 6, 6);
        p[pvec::CM_IDX_W_H] = 1e9;
        p[pvec::CM_IDX_V_C] = 0.5;
        let out = simulate(ArchKind::Cm, &p, 64, 3, InputDist::Uniform);
        for i in 0..out.len() {
            assert!((out.y_a[i] - out.y_fx[i]).abs() < 1e-9, "{i}");
        }
    }

    #[test]
    fn qs_electrical_noise_matches_closed_form() {
        let mut p = base_params(100, 6, 6);
        p[pvec::QS_IDX_SIGMA_D] = 0.107;
        p[pvec::QS_IDX_K_H] = 1e9;
        p[pvec::QS_IDX_V_C] = 300.0;
        let out = simulate(ArchKind::Qs, &p, 4000, 4, InputDist::Uniform);
        let m = measure(&out);
        let pred = 100.0 * 0.107 * 0.107 * (1.0 - 4f64.powi(-6)).powi(2) / 9.0;
        let ratio = m.sigma_eta_a2 / pred;
        assert!((0.85..1.18).contains(&ratio), "{ratio}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut p = base_params(64, 6, 6);
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 50.0;
        p[pvec::QS_IDX_V_C] = 50.0;
        let a = simulate(ArchKind::Qs, &p, 16, 9, InputDist::Uniform);
        let b = simulate(ArchKind::Qs, &p, 16, 9, InputDist::Uniform);
        assert_eq!(a.y_hat, b.y_hat);
        let c = simulate(ArchKind::Qs, &p, 16, 10, InputDist::Uniform);
        assert_ne!(a.y_hat, c.y_hat);
    }

    #[test]
    fn banked_params_sum_independent_bank_ensembles() {
        // banks = 4 with per-bank params must equal the hand-built sum
        // of 4 independent per-bank simulations on the derived seeds.
        let mut p = base_params(64, 6, 6);
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 50.0;
        p[pvec::QS_IDX_V_C] = 50.0;
        let mut banked = p;
        banked[pvec::IDX_BANKS] = 4.0;
        let got = simulate(ArchKind::Qs, &banked, 32, 9, InputDist::Uniform);
        let mut want = vec![0.0; 32];
        for b in 0..4u64 {
            let sub = simulate(ArchKind::Qs, &p, 32, super::bank_seed(9, b), InputDist::Uniform);
            for (acc, v) in want.iter_mut().zip(&sub.y_hat) {
                *acc += v;
            }
        }
        assert_eq!(got.y_hat, want);
        assert_eq!(got.len(), 32);
        // a banks slot of 1.0 is single-bank, same as the 0.0 encoding
        let mut one = p;
        one[pvec::IDX_BANKS] = 1.0;
        let a = simulate(ArchKind::Qs, &one, 16, 3, InputDist::Uniform);
        let b = simulate(ArchKind::Qs, &p, 16, 3, InputDist::Uniform);
        assert_eq!(a.y_hat, b.y_hat);
    }

    #[test]
    fn bank_streams_are_disjoint() {
        let mut p = base_params(32, 4, 4);
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 40.0;
        p[pvec::QS_IDX_V_C] = 40.0;
        let a = simulate(ArchKind::Qs, &p, 8, super::bank_seed(7, 0), InputDist::Uniform);
        let b = simulate(ArchKind::Qs, &p, 8, super::bank_seed(7, 1), InputDist::Uniform);
        assert_ne!(a.y_hat, b.y_hat, "banks draw independent ensembles");
        // and bank 0 must not alias a single-bank run at the raw seed:
        // the same per-bank params at user seed 7 are a legitimate
        // stand-alone point whose ensemble stays uncorrelated
        let raw = simulate(ArchKind::Qs, &p, 8, 7, InputDist::Uniform);
        assert_ne!(a.y_hat, raw.y_hat, "bank 0 is mixed off the user seed");
    }

    #[test]
    fn with_capacity_preallocates_all_streams() {
        let out = McOutput::with_capacity(100);
        assert!(out.is_empty());
        assert!(out.y_ideal.capacity() >= 100);
        assert!(out.y_fx.capacity() >= 100);
        assert!(out.y_a.capacity() >= 100);
        assert!(out.y_hat.capacity() >= 100);
        let sim = simulate(ArchKind::Qs, &base_params(16, 4, 4), 33, 1, InputDist::Uniform);
        assert_eq!(sim.len(), 33);
    }

    #[test]
    fn clipped_gaussian_dist_in_range() {
        let mut rng = Pcg64::new(5);
        let d = InputDist::ClippedGaussian { sx: 0.3, sw: 0.3 };
        for _ in 0..1000 {
            let x = d.draw_x(&mut rng);
            let w = d.draw_w(&mut rng);
            assert!((0.0..1.0).contains(&x));
            assert!((-1.0..1.0).contains(&w));
        }
    }
}
