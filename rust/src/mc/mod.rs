//! Native sample-accurate Monte-Carlo simulator (Fig. 8 methodology).
//!
//! Mirrors `python/compile/model.py` bit-for-bit in structure: identical
//! quantizers, bit-slicing, noise injection points, clipping and ADC
//! models, driven by the *same* normalized parameter vector
//! (`arch::pvec`). It serves three roles:
//!
//! 1. Cross-check oracle for the PJRT/Pallas path (integration tests
//!    assert ensemble-statistical agreement).
//! 2. Validation target for the Table III closed forms (E-vs-S curves).
//! 3. Fallback/base implementation when artifacts are not built.
//!
//! Execution is *chunked*: an ensemble of `trials` trials is the
//! concatenation of [`CHUNK_TRIALS`]-sized chunks, each on its own
//! deterministic RNG stream ([`chunk_seed`]). Chunks are the unit of
//! three things at once — the batched kernels in [`kernels`] (reusable
//! scratch + hoisted per-point plan), intra-point parallelism in the
//! sweep scheduler (chunks of one point fan out across workers and are
//! merged in chunk order, so same-build runs stay byte-deterministic),
//! and the adaptive stopping rule in [`simulate_adaptive`] (the
//! confidence interval is estimated over per-chunk SNR batch means).
//! The frozen pre-chunking scalar path survives as [`reference`], the
//! differential-test oracle for every kernel change.

mod adaptive;
mod kernels;
mod measure;
pub mod reference;

pub use adaptive::{simulate_adaptive, AdaptiveRun, ADAPTIVE_MAX_TRIALS};
pub use measure::{measure, MeasuredSnr, SnrAccumulator};

use crate::arch::pvec;
use crate::util::rng::Pcg64;

pub const B_MAX: usize = 8;

/// Trials per chunk: the scheduling, batching and stopping-rule unit.
/// Large enough that per-chunk setup (plan + scratch allocation)
/// amortizes to noise, small enough that single-point runs split into
/// plenty of parallel work items and the adaptive rule gets enough
/// batch means (2048 default trials = 8 chunks).
pub const CHUNK_TRIALS: usize = 256;

/// Which architecture a parameter vector drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArchKind {
    Qs,
    Qr,
    Cm,
}

impl ArchKind {
    pub fn artifact_name(&self) -> &'static str {
        match self {
            ArchKind::Qs => "qs_arch",
            ArchKind::Qr => "qr_arch",
            ArchKind::Cm => "cm_arch",
        }
    }
}

/// Input distributions for the MC ensembles. The paper draws unsigned
/// activations and zero-mean signed weights from two distributions
/// (Sec. V-A); uniform is the default used in Sec. III-E.
#[derive(Clone, Copy, Debug)]
pub enum InputDist {
    /// x ~ U[0,1), w ~ U[-1,1).
    Uniform,
    /// x ~ |N(0, sx)| clipped to [0,1), w ~ N(0, sw) clipped to [-1,1).
    ClippedGaussian { sx: f64, sw: f64 },
}

impl InputDist {
    fn draw_x(&self, rng: &mut Pcg64) -> f64 {
        match self {
            InputDist::Uniform => rng.uniform(),
            InputDist::ClippedGaussian { sx, .. } => {
                (rng.normal().abs() * sx).min(0.999_999)
            }
        }
    }

    fn draw_w(&self, rng: &mut Pcg64) -> f64 {
        match self {
            InputDist::Uniform => rng.uniform_in(-1.0, 1.0),
            InputDist::ClippedGaussian { sw, .. } => {
                (rng.normal() * sw).clamp(-0.999_999, 0.999_999)
            }
        }
    }
}

/// One MC ensemble: the four output streams of eq. (6)'s decomposition.
#[derive(Clone, Debug, Default)]
pub struct McOutput {
    pub y_ideal: Vec<f64>,
    pub y_fx: Vec<f64>,
    pub y_a: Vec<f64>,
    pub y_hat: Vec<f64>,
}

impl McOutput {
    /// Preallocate all four streams for `trials` entries, so the
    /// per-trial accumulate path never reallocates.
    pub fn with_capacity(trials: usize) -> Self {
        Self {
            y_ideal: Vec::with_capacity(trials),
            y_fx: Vec::with_capacity(trials),
            y_a: Vec::with_capacity(trials),
            y_hat: Vec::with_capacity(trials),
        }
    }

    pub fn len(&self) -> usize {
        self.y_ideal.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y_ideal.is_empty()
    }

    pub fn push(&mut self, yi: f64, yfx: f64, ya: f64, yh: f64) {
        self.y_ideal.push(yi);
        self.y_fx.push(yfx);
        self.y_a.push(ya);
        self.y_hat.push(yh);
    }

    /// Concatenate `other`'s trials after this ensemble's (chunk merge).
    pub fn extend(&mut self, other: &McOutput) {
        self.y_ideal.extend_from_slice(&other.y_ideal);
        self.y_fx.extend_from_slice(&other.y_fx);
        self.y_a.extend_from_slice(&other.y_a);
        self.y_hat.extend_from_slice(&other.y_hat);
    }

    /// Per-trial in-place sum with an equal-length ensemble (banked DP
    /// recombination: partial dot products added digitally).
    pub fn add_assign(&mut self, other: &McOutput) {
        debug_assert_eq!(self.len(), other.len());
        for (acc, v) in self.y_ideal.iter_mut().zip(&other.y_ideal) {
            *acc += v;
        }
        for (acc, v) in self.y_fx.iter_mut().zip(&other.y_fx) {
            *acc += v;
        }
        for (acc, v) in self.y_a.iter_mut().zip(&other.y_a) {
            *acc += v;
        }
        for (acc, v) in self.y_hat.iter_mut().zip(&other.y_hat) {
            *acc += v;
        }
    }
}

/// Derive the RNG seed of one bank's sub-ensemble: a SplitMix64-style
/// odd-constant mix (offset by one so even bank 0 moves off the raw
/// seed) keeps bank streams disjoint from each other *and* from a
/// single-bank run at the same user seed.
fn bank_seed(seed: u64, bank: u64) -> u64 {
    seed.wrapping_add((bank + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Derive the RNG seed of one chunk's sub-ensemble. Same shape as
/// [`bank_seed`] with a different odd constant; because both are
/// wrapping *adds*, the two derivations commute —
/// `chunk_seed(bank_seed(s, b), c) == bank_seed(chunk_seed(s, c), b)` —
/// so the banked decomposition invariant (banked ensemble == per-trial
/// sum of per-bank ensembles at `bank_seed`-derived seeds) holds
/// chunk-by-chunk and for the whole concatenated ensemble alike.
pub fn chunk_seed(seed: u64, chunk: u64) -> u64 {
    seed.wrapping_add((chunk + 1).wrapping_mul(0xA24B_AED4_963E_E407))
}

/// Number of chunks an ensemble of `trials` splits into (0 for 0).
pub fn n_chunks(trials: usize) -> usize {
    trials.div_ceil(CHUNK_TRIALS)
}

/// Run one chunk of `trials` trials at an already chunk-derived seed.
///
/// This is the scheduler's work item: `simulate(kind, p, T, s, d)` is
/// bit-identical to concatenating
/// `simulate_chunk(kind, p, min(CHUNK_TRIALS, T - c*CHUNK_TRIALS),
/// chunk_seed(s, c), d)` over `c in 0..n_chunks(T)` in chunk order,
/// which is exactly how `coordinator::run_sweep` fans a single point
/// out across workers.
///
/// A parameter vector with `pvec::IDX_BANKS >= 2` describes a banked DP
/// (Sec. VI): the arch-specific slots are *per-bank* (slot 0 holds the
/// per-bank row count), and the banked chunk is the per-trial sum of
/// `banks` independent per-bank chunks — partial DPs digitized per bank
/// and recombined digitally, exactly the `arch::Banked` closed form's
/// decomposition. Slot values 0.0 and 1.0 both mean single-bank (0.0 is
/// the legacy encoding that keeps existing cache keys).
pub fn simulate_chunk(
    kind: ArchKind,
    params: &[f64; pvec::P],
    trials: usize,
    seed: u64,
    dist: InputDist,
) -> McOutput {
    let banks = params[pvec::IDX_BANKS] as usize;
    if banks >= 2 {
        let mut bank_params = *params;
        bank_params[pvec::IDX_BANKS] = 0.0;
        let mut out = kernels::run_chunk(kind, &bank_params, trials, bank_seed(seed, 0), dist);
        for b in 1..banks {
            let sub =
                kernels::run_chunk(kind, &bank_params, trials, bank_seed(seed, b as u64), dist);
            out.add_assign(&sub);
        }
        return out;
    }
    kernels::run_chunk(kind, params, trials, seed, dist)
}

/// Run `trials` Monte-Carlo trials of the given architecture: the
/// in-order concatenation of all chunks (see [`simulate_chunk`]).
pub fn simulate(
    kind: ArchKind,
    params: &[f64; pvec::P],
    trials: usize,
    seed: u64,
    dist: InputDist,
) -> McOutput {
    let mut out = McOutput::with_capacity(trials);
    for c in 0..n_chunks(trials) {
        let done = c * CHUNK_TRIALS;
        let t = CHUNK_TRIALS.min(trials - done);
        let sub = simulate_chunk(kind, params, t, chunk_seed(seed, c as u64), dist);
        out.extend(&sub);
    }
    out
}

// ---------------------------------------------------------------------
// Shared bit-slicing (mirrors model.py unsigned_bits / signed_bits /
// signed_mag_bits, round-to-nearest). The batched kernels inline these
// per-plane; `mc::reference` and the PJRT cross-checks call them as-is.
// ---------------------------------------------------------------------

/// Unsigned activation code t in [0, 2^bx) and value t/2^bx.
#[inline]
fn x_code(x: f64, bx: u32) -> u32 {
    let s = (1u32 << bx) as f64;
    ((x * s + 0.5).floor().clamp(0.0, s - 1.0)) as u32
}

/// Two's-complement weight code t in [0, 2^bw); value t*2^{1-bw} - 1.
#[inline]
fn w_code(w: f64, bw: u32) -> u32 {
    let half = (1u32 << (bw - 1)) as f64;
    (((w + 1.0) * half + 0.5).floor().clamp(0.0, 2.0 * half - 1.0)) as u32
}

/// Input plane bit (plane j = 1..bx holds weight 2^-j).
#[inline]
fn x_bit(code: u32, bx: u32, j: u32) -> u32 {
    if j > bx {
        0
    } else {
        (code >> (bx - j)) & 1
    }
}

/// Weight plane bit with complemented sign plane (plane 1).
#[inline]
fn w_bit(code: u32, bw: u32, i: u32) -> u32 {
    if i > bw {
        return 0;
    }
    let raw = (code >> (bw - i)) & 1;
    if i == 1 {
        1 - raw
    } else {
        raw
    }
}

/// Weight plane recombination weights pw: [-1, 2^-1, ..., 2^{2-bw}].
#[inline]
fn w_plane_weight(bw: u32, i: u32) -> f64 {
    if i > bw {
        0.0
    } else if i == 1 {
        -1.0
    } else {
        2f64.powi(1 - i as i32)
    }
}

/// Mid-tread ADC over [0, range].
#[inline]
fn adc_unsigned(v: f64, range: f64, b: f64) -> f64 {
    let levels = 2f64.powf(b);
    let delta = range / levels;
    (v / delta).round().clamp(0.0, levels - 1.0) * delta
}

/// Mid-tread ADC over [-range, range].
#[inline]
fn adc_signed(v: f64, range: f64, b: f64) -> f64 {
    let levels = 2f64.powf(b);
    let delta = 2.0 * range / levels;
    (v / delta).round().clamp(-levels / 2.0, levels / 2.0 - 1.0) * delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pvec;

    fn base_params(n: usize, bx: u32, bw: u32) -> [f64; pvec::P] {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = n as f64;
        p[pvec::IDX_BX] = bx as f64;
        p[pvec::IDX_BW] = bw as f64;
        p[pvec::IDX_B_ADC] = 14.0;
        p
    }

    #[test]
    fn qs_noiseless_equals_fixed_point() {
        let mut p = base_params(100, 6, 6);
        p[pvec::QS_IDX_K_H] = 1e9;
        p[pvec::QS_IDX_V_C] = 200.0;
        let out = simulate(ArchKind::Qs, &p, 64, 1, InputDist::Uniform);
        for i in 0..out.len() {
            assert!((out.y_a[i] - out.y_fx[i]).abs() < 1e-9);
            assert!((out.y_hat[i] - out.y_a[i]).abs() < 0.02);
        }
    }

    #[test]
    fn qr_noiseless_equals_fixed_point() {
        let mut p = base_params(128, 6, 7);
        p[pvec::QR_IDX_V_C] = 1.0;
        let out = simulate(ArchKind::Qr, &p, 64, 2, InputDist::Uniform);
        for i in 0..out.len() {
            assert!((out.y_a[i] - out.y_fx[i]).abs() < 1e-9);
            assert!((out.y_hat[i] - out.y_a[i]).abs() < 0.05);
        }
    }

    #[test]
    fn cm_noiseless_equals_fixed_point() {
        let mut p = base_params(64, 6, 6);
        p[pvec::CM_IDX_W_H] = 1e9;
        p[pvec::CM_IDX_V_C] = 0.5;
        let out = simulate(ArchKind::Cm, &p, 64, 3, InputDist::Uniform);
        for i in 0..out.len() {
            assert!((out.y_a[i] - out.y_fx[i]).abs() < 1e-9, "{i}");
        }
    }

    #[test]
    fn qs_electrical_noise_matches_closed_form() {
        let mut p = base_params(100, 6, 6);
        p[pvec::QS_IDX_SIGMA_D] = 0.107;
        p[pvec::QS_IDX_K_H] = 1e9;
        p[pvec::QS_IDX_V_C] = 300.0;
        let out = simulate(ArchKind::Qs, &p, 4000, 4, InputDist::Uniform);
        let m = measure(&out);
        let pred = 100.0 * 0.107 * 0.107 * (1.0 - 4f64.powi(-6)).powi(2) / 9.0;
        let ratio = m.sigma_eta_a2 / pred;
        assert!((0.85..1.18).contains(&ratio), "{ratio}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut p = base_params(64, 6, 6);
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 50.0;
        p[pvec::QS_IDX_V_C] = 50.0;
        let a = simulate(ArchKind::Qs, &p, 16, 9, InputDist::Uniform);
        let b = simulate(ArchKind::Qs, &p, 16, 9, InputDist::Uniform);
        assert_eq!(a.y_hat, b.y_hat);
        let c = simulate(ArchKind::Qs, &p, 16, 10, InputDist::Uniform);
        assert_ne!(a.y_hat, c.y_hat);
    }

    #[test]
    fn simulate_is_chunk_concatenation() {
        // the ensemble is bit-identical to hand-running every chunk at
        // its chunk_seed-derived stream and concatenating in order —
        // the invariant the intra-point scheduler relies on
        let mut p = base_params(48, 5, 5);
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 40.0;
        p[pvec::QS_IDX_V_C] = 40.0;
        let trials = 2 * CHUNK_TRIALS + 100;
        let whole = simulate(ArchKind::Qs, &p, trials, 11, InputDist::Uniform);
        assert_eq!(whole.len(), trials);
        let mut cat = McOutput::with_capacity(trials);
        for c in 0..n_chunks(trials) {
            let t = CHUNK_TRIALS.min(trials - c * CHUNK_TRIALS);
            let sub =
                simulate_chunk(ArchKind::Qs, &p, t, chunk_seed(11, c as u64), InputDist::Uniform);
            cat.extend(&sub);
        }
        assert_eq!(whole.y_ideal, cat.y_ideal);
        assert_eq!(whole.y_fx, cat.y_fx);
        assert_eq!(whole.y_a, cat.y_a);
        assert_eq!(whole.y_hat, cat.y_hat);
    }

    #[test]
    fn chunk_streams_are_disjoint() {
        let mut p = base_params(32, 4, 4);
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 40.0;
        p[pvec::QS_IDX_V_C] = 40.0;
        let a = simulate_chunk(ArchKind::Qs, &p, 8, chunk_seed(7, 0), InputDist::Uniform);
        let b = simulate_chunk(ArchKind::Qs, &p, 8, chunk_seed(7, 1), InputDist::Uniform);
        assert_ne!(a.y_hat, b.y_hat, "chunks draw independent sub-ensembles");
    }

    #[test]
    fn banked_params_sum_independent_bank_ensembles() {
        // banks = 4 with per-bank params must equal the hand-built sum
        // of 4 independent per-bank simulations on the derived seeds.
        // (chunk_seed and bank_seed are both wrapping adds, so the
        // decompositions commute and this holds chunk-by-chunk too.)
        let mut p = base_params(64, 6, 6);
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 50.0;
        p[pvec::QS_IDX_V_C] = 50.0;
        let mut banked = p;
        banked[pvec::IDX_BANKS] = 4.0;
        let got = simulate(ArchKind::Qs, &banked, 32, 9, InputDist::Uniform);
        let mut want = vec![0.0; 32];
        for b in 0..4u64 {
            let sub = simulate(ArchKind::Qs, &p, 32, super::bank_seed(9, b), InputDist::Uniform);
            for (acc, v) in want.iter_mut().zip(&sub.y_hat) {
                *acc += v;
            }
        }
        assert_eq!(got.y_hat, want);
        assert_eq!(got.len(), 32);
        // a banks slot of 1.0 is single-bank, same as the 0.0 encoding
        let mut one = p;
        one[pvec::IDX_BANKS] = 1.0;
        let a = simulate(ArchKind::Qs, &one, 16, 3, InputDist::Uniform);
        let b = simulate(ArchKind::Qs, &p, 16, 3, InputDist::Uniform);
        assert_eq!(a.y_hat, b.y_hat);
    }

    #[test]
    fn bank_streams_are_disjoint() {
        let mut p = base_params(32, 4, 4);
        p[pvec::QS_IDX_SIGMA_D] = 0.1;
        p[pvec::QS_IDX_K_H] = 40.0;
        p[pvec::QS_IDX_V_C] = 40.0;
        let a = simulate(ArchKind::Qs, &p, 8, super::bank_seed(7, 0), InputDist::Uniform);
        let b = simulate(ArchKind::Qs, &p, 8, super::bank_seed(7, 1), InputDist::Uniform);
        assert_ne!(a.y_hat, b.y_hat, "banks draw independent ensembles");
        // and bank 0 must not alias a single-bank run at the raw seed:
        // the same per-bank params at user seed 7 are a legitimate
        // stand-alone point whose ensemble stays uncorrelated
        let raw = simulate(ArchKind::Qs, &p, 8, 7, InputDist::Uniform);
        assert_ne!(a.y_hat, raw.y_hat, "bank 0 is mixed off the user seed");
    }

    #[test]
    fn add_assign_sums_all_four_streams() {
        let mut a = McOutput::default();
        a.push(1.0, 2.0, 3.0, 4.0);
        a.push(10.0, 20.0, 30.0, 40.0);
        let mut b = McOutput::default();
        b.push(0.5, 0.25, 0.125, 0.0625);
        b.push(-1.0, -2.0, -3.0, -4.0);
        a.add_assign(&b);
        assert_eq!(a.y_ideal, vec![1.5, 9.0]);
        assert_eq!(a.y_fx, vec![2.25, 18.0]);
        assert_eq!(a.y_a, vec![3.125, 27.0]);
        assert_eq!(a.y_hat, vec![4.0625, 36.0]);
    }

    #[test]
    fn with_capacity_preallocates_all_streams() {
        let out = McOutput::with_capacity(100);
        assert!(out.is_empty());
        assert!(out.y_ideal.capacity() >= 100);
        assert!(out.y_fx.capacity() >= 100);
        assert!(out.y_a.capacity() >= 100);
        assert!(out.y_hat.capacity() >= 100);
        let sim = simulate(ArchKind::Qs, &base_params(16, 4, 4), 33, 1, InputDist::Uniform);
        assert_eq!(sim.len(), 33);
    }

    #[test]
    fn clipped_gaussian_dist_in_range() {
        let mut rng = Pcg64::new(5);
        let d = InputDist::ClippedGaussian { sx: 0.3, sw: 0.3 };
        for _ in 0..1000 {
            let x = d.draw_x(&mut rng);
            let w = d.draw_w(&mut rng);
            assert!((0.0..1.0).contains(&x));
            assert!((-1.0..1.0).contains(&w));
        }
    }
}
